#!/usr/bin/env python3
"""Inject measured figure results into EXPERIMENTS.md placeholders."""
import csv, json, pathlib, re

root = pathlib.Path("/root/repo")
exp = (root / "EXPERIMENTS.md").read_text()

def table(rows, header):
    out = ["| " + " | ".join(header) + " |", "|" + "---|" * len(header)]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)

# Fig4
p = root / "results/fig4_summary.csv"
if p.exists() and p.stat().st_size > 40:
    rows = []
    for r in csv.DictReader(open(p)):
        sw = int(float(r["switch_epoch"])); fr = int(float(r["freeze_epoch"]))
        rows.append([r["run"], sw if sw >= 0 else "—", fr if fr >= 0 else "—",
                     f"{float(r['mean_epoch_s']):.2f}", f"{float(r['speedup_pct']):.1f}%",
                     f"{float(r['final_loss']):.4f}"])
    t = table(rows, ["run", "switch", "freeze", "mean epoch s", "speedup", "final loss"])
    exp = exp.replace("<!-- FIG4_RESULTS -->", "Measured (30 epochs, vit-small):\n\n" + t)

# Fig5: freeze epochs + final losses per w from curves
p = root / "results/fig5_epoch_time.csv"
q = root / "results/fig5_loss.csv"
if p.exists() and p.stat().st_size > 40:
    times, losses, firstlora = {}, {}, {}
    for r in csv.DictReader(open(p)):
        times.setdefault(r["run"], []).append(float(r["epoch_seconds"]))
        if float(r["phase"]) == 2.0 and r["run"] not in firstlora:
            firstlora[r["run"]] = int(float(r["epoch"]))
    for r in csv.DictReader(open(q)):
        losses.setdefault(r["run"], []).append(float(r["train_loss"]))
    rows = []
    for run in sorted(times):
        rows.append([run, firstlora.get(run, "—"),
                     f"{sum(times[run])/len(times[run]):.2f}",
                     f"{losses[run][-1]:.4f}"])
    t = table(rows, ["run", "first LoRA-only epoch", "mean epoch s", "final loss"])
    exp = exp.replace("<!-- FIG5_RESULTS -->", "Measured (30 epochs, vit-small):\n\n" + t)

# Fig7
p = root / "results/fig7.csv"
if p.exists() and p.stat().st_size > 40:
    names = ["epoch_time_s", "throughput_img_s", "memory_bytes(saving)", "trainable_params"]
    rows = []
    for r in csv.DictReader(open(p)):
        i = int(float(r["metric_id"]))
        rows.append([names[i], f"{float(r['baseline']):.2f}", f"{float(r['prelora']):.2f}",
                     f"{float(r['ratio']):.3f}"])
    t = table(rows, ["metric", "baseline", "prelora", "ratio"])
    exp = exp.replace("<!-- FIG7_RESULTS -->", "Measured (24 epochs, vit-small, whole-cycle averages):\n\n" + t)

# e2e
p = root / "results/e2e_summary.json"
if p.exists():
    s = json.loads(p.read_text())
    lines = [
        f"Measured ({s['model']}, {s['epochs']} epochs): switch at {s['switch_epoch']}, "
        f"freeze at {s['freeze_epoch']}; final train loss {s['final_train_loss']:.4f}, "
        f"val acc {s['final_val_acc']:.3f}; trainable {s['trainable_full']} -> "
        f"{s['trainable_lora']}"]
    if s.get("epoch_time_ratio"):
        lines.append(f"; epoch-time ratio {s['epoch_time_ratio']:.2f}x, "
                     f"throughput ratio {s['throughput_ratio']:.2f}x, "
                     f"memory saving {100*s['memory_saving_frac']:.1f}%.")
    exp = exp.replace("<!-- E2E_RESULTS -->", "".join(lines))

# ablation
p = root / "results/ablation_strategies.csv"
if p.exists() and p.stat().st_size > 40:
    rows = []
    for r in csv.DictReader(open(p)):
        sw = int(float(r["switch"])); fr = int(float(r["freeze"]))
        rows.append([r["run"], sw if sw >= 0 else "—", fr if fr >= 0 else "—",
                     f"{float(r['final_loss']):.4f}", int(float(r["trainable_params"])),
                     f"{float(r['mean_epoch_s']):.3f}"])
    t = table(rows, ["run", "switch", "freeze", "final loss", "trainable", "mean epoch s"])
    exp = exp.replace("<!-- ABLATION_RESULTS -->", "Measured (20 epochs, vit-micro):\n\n" + t)

(root / "EXPERIMENTS.md").write_text(exp)
print("filled")
