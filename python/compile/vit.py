"""L2: Vision Transformer over flat parameter vectors.

The L2↔L3 contract keeps *all* parameters in flat f32 vectors so the Rust
coordinator can own the optimizer, weight-norm telemetry, convergence test
and rank assignment without understanding pytrees:

* ``base_param_specs(cfg)``   — deterministic tensor table for the base model
* ``lora_param_specs(cfg)``   — tensor table + adapter table for LoRA params
* ``forward(cfg, base, images, lora=...)`` — the model, unflattening via
  static slices (free at HLO level) and routing every dense projection
  through the L1 Pallas kernels (``kernels.lora_matmul``).

Module taxonomy follows the paper's target set alpha =
{query, key, value, output, dense} (Section 4.1); ``mlp_out`` and the
patch-embed / head / layernorm tensors are tracked in telemetry but never
adapted. The same spec tables are serialized into ``manifest.json`` by
``aot.py`` and re-parsed by ``rust/src/manifest.rs`` — they are the single
source of truth for offsets.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ADAPTED_MODULES, ModelConfig
from .kernels import lora_matmul as km


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """One tensor inside a flat parameter vector."""

    name: str
    shape: tuple[int, ...]
    module: str  # query|key|value|output|dense|mlp_out|ln|embed|head|lora_a|lora_b
    layer: int  # -1 for non-layer tensors (embeddings, final ln, head)
    offset: int

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


@dataclasses.dataclass(frozen=True)
class AdapterSpec:
    """One LoRA adapter (an A/B pair) attached to a base matrix."""

    name: str  # e.g. "layer3.query"
    layer: int
    module: str
    in_dim: int
    out_dim: int
    a_offset: int  # offset of A [in_dim, r_max] in the lora flat vector
    b_offset: int  # offset of B [r_max, out_dim] in the lora flat vector
    cfg_offset: int  # offset of [mask(r_max) ++ scale(1)] in adapter_cfg


def base_param_specs(cfg: ModelConfig) -> list[TensorSpec]:
    """Deterministic tensor table for the base (full) model."""
    specs: list[TensorSpec] = []
    off = 0

    def add(name: str, shape: tuple[int, ...], module: str, layer: int) -> None:
        nonlocal off
        specs.append(TensorSpec(name, shape, module, layer, off))
        off += int(np.prod(shape))

    d, f = cfg.hidden_dim, cfg.mlp_dim
    add("patch_embed.w", (cfg.patch_dim, d), "embed", -1)
    add("patch_embed.b", (d,), "embed", -1)
    add("pos_embed", (cfg.tokens, d), "embed", -1)
    for l in range(cfg.depth):
        p = f"layer{l}."
        add(p + "ln1.scale", (d,), "ln", l)
        add(p + "ln1.bias", (d,), "ln", l)
        add(p + "query.w", (d, d), "query", l)
        add(p + "query.b", (d,), "query", l)
        add(p + "key.w", (d, d), "key", l)
        add(p + "key.b", (d,), "key", l)
        add(p + "value.w", (d, d), "value", l)
        add(p + "value.b", (d,), "value", l)
        add(p + "output.w", (d, d), "output", l)
        add(p + "output.b", (d,), "output", l)
        add(p + "ln2.scale", (d,), "ln", l)
        add(p + "ln2.bias", (d,), "ln", l)
        add(p + "dense.w", (d, f), "dense", l)
        add(p + "dense.b", (f,), "dense", l)
        add(p + "mlp_out.w", (f, d), "mlp_out", l)
        add(p + "mlp_out.b", (d,), "mlp_out", l)
    add("ln_f.scale", (d,), "ln", -1)
    add("ln_f.bias", (d,), "ln", -1)
    add("head.w", (d, cfg.num_classes), "head", -1)
    add("head.b", (cfg.num_classes,), "head", -1)
    return specs


def base_param_count(cfg: ModelConfig) -> int:
    specs = base_param_specs(cfg)
    return specs[-1].offset + specs[-1].size


def lora_param_specs(cfg: ModelConfig) -> tuple[list[TensorSpec], list[AdapterSpec]]:
    """Tensor + adapter tables for the LoRA flat vector.

    Adapter order is layer-major then the paper's module order; the same
    order indexes ``adapter_cfg`` = concat per adapter of [mask(r_max),
    scale]. Every A is allocated at r_max; Algorithm 2's dynamic per-layer
    rank r_l is expressed purely through mask/scale (see kernels doc).
    """
    d, f, r = cfg.hidden_dim, cfg.mlp_dim, cfg.r_max
    dims = {"query": (d, d), "key": (d, d), "value": (d, d), "output": (d, d), "dense": (d, f)}
    tensors: list[TensorSpec] = []
    adapters: list[AdapterSpec] = []
    off = 0
    for l in range(cfg.depth):
        for mod in ADAPTED_MODULES:
            din, dout = dims[mod]
            name = f"layer{l}.{mod}"
            a_off, b_off = off, off + din * r
            tensors.append(TensorSpec(name + ".lora_a", (din, r), "lora_a", l, a_off))
            tensors.append(TensorSpec(name + ".lora_b", (r, dout), "lora_b", l, b_off))
            idx = len(adapters)
            adapters.append(
                AdapterSpec(name, l, mod, din, dout, a_off, b_off, idx * (r + 1))
            )
            off = b_off + r * dout
    return tensors, adapters


def lora_param_count(cfg: ModelConfig) -> int:
    tensors, _ = lora_param_specs(cfg)
    return tensors[-1].offset + tensors[-1].size


def adapter_cfg_size(cfg: ModelConfig) -> int:
    _, adapters = lora_param_specs(cfg)
    return len(adapters) * (cfg.r_max + 1)


# ---------------------------------------------------------------------------
# initialization (numpy: reproducible, dumped to init_base.f32 by aot.py)
# ---------------------------------------------------------------------------


def init_base(cfg: ModelConfig, seed: int = 0) -> np.ndarray:
    """Initial base parameters, truncated-normal-style ViT init."""
    rng = np.random.default_rng(seed)
    flat = np.zeros(base_param_count(cfg), dtype=np.float32)
    for spec in base_param_specs(cfg):
        if spec.name.endswith(".scale"):
            val = np.ones(spec.shape, np.float32)
        elif spec.name.endswith((".bias", ".b")) or spec.module == "head":
            # zero biases; zero head => uniform initial predictions
            val = np.zeros(spec.shape, np.float32)
        elif spec.name == "pos_embed":
            val = rng.normal(0.0, 0.02, spec.shape).astype(np.float32)
        else:
            val = rng.normal(0.0, 0.02, spec.shape).astype(np.float32)
        flat[spec.offset : spec.offset + spec.size] = val.ravel()
    return flat


def init_lora(cfg: ModelConfig, seed: int = 0) -> np.ndarray:
    """LoRA init: A ~ N(0, 0.02), B = 0 (adapter starts as identity delta).

    The Rust coordinator performs the same-policy init at switch time with
    its own RNG; this Python version exists for the pytest suite.
    """
    rng = np.random.default_rng(seed)
    flat = np.zeros(lora_param_count(cfg), dtype=np.float32)
    tensors, _ = lora_param_specs(cfg)
    for spec in tensors:
        if spec.module == "lora_a":
            v = rng.normal(0.0, 0.02, spec.shape).astype(np.float32)
            flat[spec.offset : spec.offset + spec.size] = v.ravel()
    return flat


def uniform_adapter_cfg(cfg: ModelConfig, rank: int) -> np.ndarray:
    """adapter_cfg with every adapter at the same rank (testing / baseline)."""
    _, adapters = lora_param_specs(cfg)
    out = np.zeros(adapter_cfg_size(cfg), np.float32)
    for ad in adapters:
        out[ad.cfg_offset : ad.cfg_offset + rank] = 1.0
        out[ad.cfg_offset + cfg.r_max] = cfg.lora_alpha / rank
    return out


# ---------------------------------------------------------------------------
# forward pass
# ---------------------------------------------------------------------------


class _Params:
    """Name → array view over a flat vector (static slices: free in HLO)."""

    def __init__(self, flat: jnp.ndarray, specs: list[TensorSpec]):
        self._flat = flat
        self._specs = {s.name: s for s in specs}

    def __getitem__(self, name: str) -> jnp.ndarray:
        s = self._specs[name]
        return self._flat[s.offset : s.offset + s.size].reshape(s.shape)


def _ln(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * scale + bias


def patchify(cfg: ModelConfig, images: jnp.ndarray) -> jnp.ndarray:
    """[B, H, W, C] → [B, T, patch_dim] non-overlapping patches."""
    b = images.shape[0]
    p, s = cfg.patch_size, cfg.image_size // cfg.patch_size
    x = images.reshape(b, s, p, s, p, cfg.in_channels)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, s * s, cfg.patch_dim)


def forward(
    cfg: ModelConfig,
    base_flat: jnp.ndarray,
    images: jnp.ndarray,
    lora: tuple[jnp.ndarray, jnp.ndarray] | None = None,
) -> jnp.ndarray:
    """ViT forward pass → logits [B, num_classes].

    ``lora``: optional ``(lora_flat, adapter_cfg)``. When present, every
    projection in the paper's alpha set goes through the fused Pallas
    ``lora_matmul``; otherwise through the plain Pallas ``matmul``.
    """
    p = _Params(base_flat, base_param_specs(cfg))
    adapters: dict[str, AdapterSpec] = {}
    lp: _Params | None = None
    acfg = None
    if lora is not None:
        lora_flat, acfg = lora
        tensors, adapter_list = lora_param_specs(cfg)
        lp = _Params(lora_flat, tensors)
        adapters = {a.name: a for a in adapter_list}

    b = images.shape[0]
    t, d, h, dh = cfg.tokens, cfg.hidden_dim, cfg.num_heads, cfg.head_dim

    def proj(x2d: jnp.ndarray, layer: int, module: str) -> jnp.ndarray:
        """Dense projection through the L1 kernels (+ bias)."""
        name = f"layer{layer}.{module}"
        w = p[name + ".w"]
        bias = p[name + ".b"]
        if lp is not None and module in ADAPTED_MODULES:
            ad = adapters[name]
            a = lp[name + ".lora_a"]
            bb = lp[name + ".lora_b"]
            mask = acfg[ad.cfg_offset : ad.cfg_offset + cfg.r_max]
            scale = acfg[ad.cfg_offset + cfg.r_max]
            y = km.lora_matmul(x2d, w, a, bb, mask, scale)
        else:
            y = km.matmul(x2d, w)
        return y + bias

    x = km.matmul(patchify(cfg, images).reshape(b * t, cfg.patch_dim), p["patch_embed.w"])
    x = x + p["patch_embed.b"]
    x = x.reshape(b, t, d) + p["pos_embed"]

    for l in range(cfg.depth):
        pre = f"layer{l}."
        # --- multi-head self-attention ---
        y = _ln(x, p[pre + "ln1.scale"], p[pre + "ln1.bias"])
        y2 = y.reshape(b * t, d)
        q = proj(y2, l, "query").reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        k = proj(y2, l, "key").reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        v = proj(y2, l, "value").reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(dh))
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        o = o.transpose(0, 2, 1, 3).reshape(b * t, d)
        x = x + proj(o, l, "output").reshape(b, t, d)
        # --- MLP ---
        y = _ln(x, p[pre + "ln2.scale"], p[pre + "ln2.bias"])
        y2 = y.reshape(b * t, d)
        zz = jax.nn.gelu(proj(y2, l, "dense"))
        zz = km.matmul(zz, p["layer%d.mlp_out.w" % l]) + p["layer%d.mlp_out.b" % l]
        x = x + zz.reshape(b, t, d)

    x = _ln(x, p["ln_f.scale"], p["ln_f.bias"])
    pooled = jnp.mean(x, axis=1)  # GAP head (Steiner et al. variant)
    return km.matmul(pooled, p["head.w"]) + p["head.b"]
