"""L1 Pallas kernels: fused LoRA matmul (forward + custom VJP backward).

The paper's compute hot spot is the dense projection ``x @ W`` plus the
low-rank bypass ``(x @ A) @ B * scale`` on the target modules
(query/key/value/output/dense). On the paper's A100s the bypass is a second
GEMM fused by cuBLAS/torch; the TPU-style Pallas adaptation here fuses the
bypass into the *epilogue of the base GEMM's output tile* so the adapter
costs no extra HBM round-trip:

* grid tiles the (M, N) output; each cell holds one (bm, bn) output tile in
  VMEM, streams the full-K x/W panels plus the (K, R_MAX) / (R_MAX, bn)
  adapter panels, and writes the fused result once.
* the backward pass is FOUR SEPARATE ``pallas_call``s (dx, dW, dA, dB). This
  is deliberate: when the coordinator freezes the base model (LoRA-only
  phase) it lowers the loss with ``stop_gradient`` on the base parameters,
  the ``dW`` cotangent becomes dead, and XLA dead-code-eliminates the whole
  dW kernel — the kernel-level realization of the paper's "freeze the full
  model" speedup. A fused single-kernel backward could not be DCE'd.

Rank masking: ``mask`` ([R_MAX] of 0/1) and ``scale`` (= alpha / r_l) carry
Algorithm 2's per-layer dynamic rank through a *static* shape — the first
``r_l`` mask entries are 1, the rest 0, so masked A-columns/B-rows are inert
and receive zero gradient. One compiled HLO serves every rank assignment.

All kernels run ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode (which lowers to plain HLO) is both
the correctness path and what ships in the AOT artifacts. Real-TPU VMEM /
MXU estimates live in DESIGN.md §Perf.

``set_backend("jnp")`` swaps every call site to the pure-jnp oracle in
``ref.py`` (identical semantics, asserted by pytest); ``aot.py --backend``
exposes it so the perf harness can measure kernel overhead on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# The CPU PJRT client can only run interpret-mode Pallas (see module doc).
INTERPRET = True

_BACKEND = "pallas"


def set_backend(name: str) -> None:
    """Select the kernel backend: ``"pallas"`` (default) or ``"jnp"``."""
    global _BACKEND
    if name not in ("pallas", "jnp"):
        raise ValueError(f"unknown kernel backend {name!r}")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def _pick_block(dim: int, cap: int = 256) -> int:
    """Largest power-of-two ≤ cap that divides ``dim``.

    Pallas interpret mode requires the grid to tile the array exactly for
    the index maps used here; model dims are chosen so M = batch*(tokens)
    and the hidden dims always have a power-of-two divisor ≥ 8. On a real
    TPU the caps below keep a (bm, K) x-panel + (K, bn) w-panel + (bm, bn)
    accumulator comfortably inside the ~16 MiB VMEM budget for every model
    in the zoo (worst case vit-base-sim: 256*1024*4B * 3 panels ≈ 3 MiB).

    Perf note (EXPERIMENTS.md §Perf): cap=256 vs the initial cap=128
    quarters the grid-cell count; in interpret mode each cell pays a
    while-loop iteration of dispatch overhead, and the measured fused
    lora_matmul at vit-small projection shapes drops 4.9ms -> 2.4ms,
    matching the pure-jnp roofline. cap=512 measured no further gain.
    """
    b = 1
    while b * 2 <= min(dim, cap) and dim % (b * 2) == 0:
        b *= 2
    return b


# ---------------------------------------------------------------------------
# forward kernels
# ---------------------------------------------------------------------------


def _matmul_fwd_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...]
    w = w_ref[...]
    o_ref[...] = jnp.dot(x, w, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _pallas_matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn = _pick_block(m), _pick_block(n)
    return pl.pallas_call(
        _matmul_fwd_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=INTERPRET,
    )(x, w)


def _lora_fwd_kernel(x_ref, w_ref, a_ref, b_ref, mask_ref, scale_ref, o_ref):
    # One (bm, bn) output tile: base GEMM + fused low-rank epilogue.
    x = x_ref[...]  # [bm, K]
    w = w_ref[...]  # [K, bn]
    a = a_ref[...]  # [K, R]
    b = b_ref[...]  # [R, bn]
    mask = mask_ref[...]  # [1, R]
    scale = scale_ref[0, 0]
    base = jnp.dot(x, w, preferred_element_type=jnp.float32)
    z = jnp.dot(x, a, preferred_element_type=jnp.float32) * mask
    low = jnp.dot(z, b, preferred_element_type=jnp.float32)
    o_ref[...] = (base + scale * low).astype(o_ref.dtype)


def _pallas_lora_matmul(x, w, a, b, mask2d, scale2d):
    m, k = x.shape
    _, n = w.shape
    r = a.shape[1]
    bm, bn = _pick_block(m), _pick_block(n)
    return pl.pallas_call(
        _lora_fwd_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((k, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, r), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=INTERPRET,
    )(x, w, a, b, mask2d, scale2d)


# ---------------------------------------------------------------------------
# backward kernels — one pallas_call per cotangent (DCE-friendly, see doc)
# ---------------------------------------------------------------------------


def _dx_base_kernel(dy_ref, w_ref, o_ref):
    # dx tile [bm, bk] = dy[bm, N] @ w[bk, N]^T
    dy = dy_ref[...]
    w = w_ref[...]
    o_ref[...] = jnp.dot(dy, w.T, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _pallas_dx_base(dy, w):
    m, n = dy.shape
    k = w.shape[0]
    bm, bk = _pick_block(m), _pick_block(k)
    return pl.pallas_call(
        _dx_base_kernel,
        grid=(m // bm, k // bk),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), dy.dtype),
        interpret=INTERPRET,
    )(dy, w)


def _dx_lora_kernel(dy_ref, w_ref, a_ref, b_ref, mask_ref, scale_ref, o_ref):
    # dx tile = dy @ w^T + ((dy @ b^T) * mask) @ a^T * scale
    dy = dy_ref[...]  # [bm, N]
    w = w_ref[...]  # [bk, N]
    a = a_ref[...]  # [bk, R]
    b = b_ref[...]  # [R, N]
    mask = mask_ref[...]  # [1, R]
    scale = scale_ref[0, 0]
    base = jnp.dot(dy, w.T, preferred_element_type=jnp.float32)
    z = jnp.dot(dy, b.T, preferred_element_type=jnp.float32) * mask
    low = jnp.dot(z, a.T, preferred_element_type=jnp.float32)
    o_ref[...] = (base + scale * low).astype(o_ref.dtype)


def _pallas_dx_lora(dy, w, a, b, mask2d, scale2d):
    m, n = dy.shape
    k, r = a.shape
    bm, bk = _pick_block(m), _pick_block(k)
    return pl.pallas_call(
        _dx_lora_kernel,
        grid=(m // bm, k // bk),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, n), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, r), lambda i, j: (j, 0)),
            pl.BlockSpec((r, n), lambda i, j: (0, 0)),
            pl.BlockSpec((1, r), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), dy.dtype),
        interpret=INTERPRET,
    )(dy, w, a, b, mask2d, scale2d)


def _dw_kernel(x_ref, dy_ref, o_ref):
    # dw tile [bk, bn] = x[M, bk]^T @ dy[M, bn]
    x = x_ref[...]
    dy = dy_ref[...]
    o_ref[...] = jnp.dot(x.T, dy, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _pallas_dw(x, dy):
    m, k = x.shape
    n = dy.shape[1]
    bk, bn = _pick_block(k), _pick_block(n)
    return pl.pallas_call(
        _dw_kernel,
        grid=(k // bk, n // bn),
        in_specs=[
            pl.BlockSpec((m, bk), lambda i, j: (0, i)),
            pl.BlockSpec((m, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), x.dtype),
        interpret=INTERPRET,
    )(x, dy)


def _da_kernel(x_ref, dy_ref, b_ref, mask_ref, scale_ref, o_ref):
    # da tile [bk, R] = x[M, bk]^T @ ((dy @ b^T) * mask) * scale
    x = x_ref[...]  # [M, bk]
    dy = dy_ref[...]  # [M, N]
    b = b_ref[...]  # [R, N]
    mask = mask_ref[...]  # [1, R]
    scale = scale_ref[0, 0]
    z = jnp.dot(dy, b.T, preferred_element_type=jnp.float32) * mask
    o_ref[...] = (scale * jnp.dot(x.T, z, preferred_element_type=jnp.float32)).astype(
        o_ref.dtype
    )


def _pallas_da(x, dy, b, mask2d, scale2d):
    m, k = x.shape
    r, n = b.shape
    bk = _pick_block(k)
    return pl.pallas_call(
        _da_kernel,
        grid=(k // bk,),
        in_specs=[
            pl.BlockSpec((m, bk), lambda i: (0, i)),
            pl.BlockSpec((m, n), lambda i: (0, 0)),
            pl.BlockSpec((r, n), lambda i: (0, 0)),
            pl.BlockSpec((1, r), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bk, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, r), x.dtype),
        interpret=INTERPRET,
    )(x, dy, b, mask2d, scale2d)


def _db_kernel(x_ref, a_ref, dy_ref, mask_ref, scale_ref, o_ref):
    # db tile [R, bn] = ((x @ a) * mask)^T @ dy[:, bn] * scale
    x = x_ref[...]  # [M, K]
    a = a_ref[...]  # [K, R]
    dy = dy_ref[...]  # [M, bn]
    mask = mask_ref[...]  # [1, R]
    scale = scale_ref[0, 0]
    z = jnp.dot(x, a, preferred_element_type=jnp.float32) * mask
    o_ref[...] = (scale * jnp.dot(z.T, dy, preferred_element_type=jnp.float32)).astype(
        o_ref.dtype
    )


def _pallas_db(x, a, dy, mask2d, scale2d):
    m, k = x.shape
    r = a.shape[1]
    n = dy.shape[1]
    bn = _pick_block(n)
    return pl.pallas_call(
        _db_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((k, r), lambda j: (0, 0)),
            pl.BlockSpec((m, bn), lambda j: (0, j)),
            pl.BlockSpec((1, r), lambda j: (0, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((r, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((r, n), x.dtype),
        interpret=INTERPRET,
    )(x, a, dy, mask2d, scale2d)


# ---------------------------------------------------------------------------
# public differentiable ops
# ---------------------------------------------------------------------------


@jax.custom_vjp
def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Differentiable base projection ``x @ w`` backed by Pallas kernels."""
    if _BACKEND == "jnp":
        return ref.ref_matmul(x, w)
    return _pallas_matmul(x, w)


def _matmul_fwd(x, w):
    return matmul(x, w), (x, w)


def _matmul_bwd(res, dy):
    x, w = res
    if _BACKEND == "jnp":
        return jnp.dot(dy, w.T).astype(x.dtype), jnp.dot(x.T, dy).astype(w.dtype)
    return _pallas_dx_base(dy, w), _pallas_dw(x, dy)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


@jax.custom_vjp
def lora_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    mask: jnp.ndarray,
    scale: jnp.ndarray,
) -> jnp.ndarray:
    """Differentiable fused LoRA projection (see module doc and ref.py)."""
    if _BACKEND == "jnp":
        return ref.ref_lora_matmul(x, w, a, b, mask, scale)
    mask2d = mask.reshape(1, -1)
    scale2d = scale.reshape(1, 1)
    return _pallas_lora_matmul(x, w, a, b, mask2d, scale2d)


def _lora_fwd(x, w, a, b, mask, scale):
    return lora_matmul(x, w, a, b, mask, scale), (x, w, a, b, mask, scale)


def _lora_bwd(res, dy):
    x, w, a, b, mask, scale = res
    # mask / scale are rank configuration, not parameters: zero cotangents.
    dmask = jnp.zeros_like(mask)
    dscale = jnp.zeros_like(scale)
    if _BACKEND == "jnp":
        z_fwd = jnp.dot(x, a, preferred_element_type=jnp.float32) * mask
        zt = jnp.dot(dy, b.T, preferred_element_type=jnp.float32) * mask
        dx = (jnp.dot(dy, w.T) + scale * jnp.dot(zt, a.T)).astype(x.dtype)
        dw = jnp.dot(x.T, dy).astype(w.dtype)
        da = (scale * jnp.dot(x.T, zt)).astype(a.dtype)
        db = (scale * jnp.dot(z_fwd.T, dy)).astype(b.dtype)
        return dx, dw, da, db, dmask, dscale
    mask2d = mask.reshape(1, -1)
    scale2d = scale.reshape(1, 1)
    dx = _pallas_dx_lora(dy, w, a, b, mask2d, scale2d)
    dw = _pallas_dw(x, dy)  # dead + DCE'd when the base is frozen
    da = _pallas_da(x, dy, b, mask2d, scale2d)
    db = _pallas_db(x, a, dy, mask2d, scale2d)
    return dx, dw, da, db, dmask, dscale


lora_matmul.defvjp(_lora_fwd, _lora_bwd)


@functools.lru_cache(maxsize=None)
def vmem_estimate(m: int, k: int, n: int, r: int, bytes_per_el: int = 4) -> dict:
    """Analytic VMEM footprint (bytes) of one forward grid cell on real TPU.

    Used by DESIGN.md §Perf / EXPERIMENTS.md — interpret mode gives no
    hardware numbers, so the shipping block shapes are justified by this
    estimate staying far below the ~16 MiB VMEM budget.
    """
    bm, bn = _pick_block(m), _pick_block(n)
    panels = {
        "x": bm * k,
        "w": k * bn,
        "a": k * r,
        "b": r * bn,
        "out": bm * bn,
    }
    total = sum(panels.values()) * bytes_per_el
    return {"block": (bm, bn), "panels": panels, "total_bytes": total}
