# Pure-jnp correctness oracle for the L1 Pallas kernels.
#
# These definitions are the *semantic contract*: pytest asserts the Pallas
# kernels (forward and every custom_vjp cotangent) match these to float32
# tolerance across a hypothesis-driven shape/dtype sweep. They are also the
# `jnp` kernel backend used by `aot.py --backend jnp` artifacts.

from __future__ import annotations

import jax.numpy as jnp


def ref_matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain base projection: ``x @ w`` with f32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def ref_lora_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    mask: jnp.ndarray,
    scale: jnp.ndarray,
) -> jnp.ndarray:
    """LoRA-augmented projection.

    ``y = x @ w + ((x @ a) * mask) @ b * scale``

    * ``x``: [M, K] activations
    * ``w``: [K, N] frozen/base weight
    * ``a``: [K, R_MAX] LoRA down-projection
    * ``b``: [R_MAX, N] LoRA up-projection
    * ``mask``: [R_MAX] 0/1 rank mask — the first ``r_l`` entries are 1 for a
      layer assigned rank ``r_l`` by Algorithm 2; columns of ``a`` / rows of
      ``b`` beyond ``r_l`` are inert and receive zero gradient, so a single
      static shape serves every dynamic rank assignment.
    * ``scale``: scalar ``alpha / r_l``.
    """
    base = jnp.dot(x, w, preferred_element_type=jnp.float32)
    z = jnp.dot(x, a, preferred_element_type=jnp.float32) * mask
    low = jnp.dot(z, b, preferred_element_type=jnp.float32)
    return (base + scale * low).astype(x.dtype)
