"""L2: loss + gradient entry points, one per AOT artifact.

Every function here becomes exactly one HLO artifact (see ``aot.py``):

* ``full_grads``   — grads w.r.t. the base vector (pre-switch + baseline).
* ``warmup_grads`` — grads w.r.t. base AND LoRA vectors (paper §3.3: full
  model and adapters train jointly for ``w`` warmup epochs).
* ``lora_grads``   — grads w.r.t. the LoRA vector only; the base vector is
  wrapped in ``stop_gradient`` so XLA dead-code-eliminates the entire base
  backward pass (including the per-adapter dW Pallas kernels) — this is
  where the paper's post-switch speedup physically comes from.
* ``eval_full`` / ``eval_lora`` — forward-only loss/accuracy.

All of them return ``(grads..., loss, correct)`` where ``correct`` is the
number of top-1 hits in the batch as f32 (the Rust side accumulates it into
train/val accuracy). The optimizer lives in Rust; XLA computes fwd/bwd only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import vit
from .configs import ModelConfig


def loss_and_correct(
    cfg: ModelConfig,
    base: jnp.ndarray,
    images: jnp.ndarray,
    labels: jnp.ndarray,
    lora: tuple[jnp.ndarray, jnp.ndarray] | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Mean cross-entropy over the batch + top-1 hit count (f32 scalar)."""
    logits = vit.forward(cfg, base, images, lora=lora)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(nll)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return loss, correct


def make_full_grads(cfg: ModelConfig):
    """(base, images, labels) -> (d_base, loss, correct)"""

    def fn(base, images, labels):
        def loss_fn(b):
            return loss_and_correct(cfg, b, images, labels)

        (loss, correct), d_base = jax.value_and_grad(loss_fn, has_aux=True)(base)
        return d_base, loss, correct

    return fn


def make_warmup_grads(cfg: ModelConfig):
    """(base, lora, adapter_cfg, images, labels) -> (d_base, d_lora, loss, correct)"""

    def fn(base, lora, adapter_cfg, images, labels):
        def loss_fn(b, lo):
            return loss_and_correct(cfg, b, images, labels, lora=(lo, adapter_cfg))

        (loss, correct), (d_base, d_lora) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True
        )(base, lora)
        return d_base, d_lora, loss, correct

    return fn


def make_lora_grads(cfg: ModelConfig):
    """(base, lora, adapter_cfg, images, labels) -> (d_lora, loss, correct)

    ``stop_gradient`` on the base vector makes every base cotangent dead:
    XLA removes the base backward pass (verified by the HLO-size check in
    the pytest suite and by the measured step-latency gap in Fig. 7).
    """

    def fn(base, lora, adapter_cfg, images, labels):
        frozen = jax.lax.stop_gradient(base)

        def loss_fn(lo):
            return loss_and_correct(cfg, frozen, images, labels, lora=(lo, adapter_cfg))

        (loss, correct), d_lora = jax.value_and_grad(loss_fn, has_aux=True)(lora)
        return d_lora, loss, correct

    return fn


def make_eval_full(cfg: ModelConfig):
    """(base, images, labels) -> (loss, correct)"""

    def fn(base, images, labels):
        return loss_and_correct(cfg, base, images, labels)

    return fn


def make_eval_lora(cfg: ModelConfig):
    """(base, lora, adapter_cfg, images, labels) -> (loss, correct)"""

    def fn(base, lora, adapter_cfg, images, labels):
        return loss_and_correct(cfg, base, images, labels, lora=(lora, adapter_cfg))

    return fn


def example_args(cfg: ModelConfig, which: str):
    """ShapeDtypeStructs matching one artifact's input signature."""
    n_base = vit.base_param_count(cfg)
    n_lora = vit.lora_param_count(cfg)
    n_cfg = vit.adapter_cfg_size(cfg)
    f32, i32 = jnp.float32, jnp.int32
    base = jax.ShapeDtypeStruct((n_base,), f32)
    lora = jax.ShapeDtypeStruct((n_lora,), f32)
    acfg = jax.ShapeDtypeStruct((n_cfg,), f32)
    images = jax.ShapeDtypeStruct(
        (cfg.batch_size, cfg.image_size, cfg.image_size, cfg.in_channels), f32
    )
    labels = jax.ShapeDtypeStruct((cfg.batch_size,), i32)
    sigs = {
        "full_grads": (base, images, labels),
        "warmup_grads": (base, lora, acfg, images, labels),
        "lora_grads": (base, lora, acfg, images, labels),
        "eval_full": (base, images, labels),
        "eval_lora": (base, lora, acfg, images, labels),
    }
    return sigs[which]


ARTIFACT_BUILDERS = {
    "full_grads": make_full_grads,
    "warmup_grads": make_warmup_grads,
    "lora_grads": make_lora_grads,
    "eval_full": make_eval_full,
    "eval_lora": make_eval_lora,
}

ARTIFACT_IO = {
    "full_grads": (["base", "images", "labels"], ["d_base", "loss", "correct"]),
    "warmup_grads": (
        ["base", "lora", "adapter_cfg", "images", "labels"],
        ["d_base", "d_lora", "loss", "correct"],
    ),
    "lora_grads": (
        ["base", "lora", "adapter_cfg", "images", "labels"],
        ["d_lora", "loss", "correct"],
    ),
    "eval_full": (["base", "images", "labels"], ["loss", "correct"]),
    "eval_lora": (
        ["base", "lora", "adapter_cfg", "images", "labels"],
        ["loss", "correct"],
    ),
}
