"""AOT compile path: lower every artifact to HLO *text* + emit the manifest.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` /
serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction
ids which the Rust side's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids, so text round-trips cleanly
(see /opt/xla-example/load_hlo/gen_hlo.py).

This module runs ONCE at build time (``make artifacts``) and never on the
request path. Outputs per model, under ``artifacts/<model>/``:

* ``<artifact>.hlo.txt``  — one per entry in ``model.ARTIFACT_BUILDERS``
* ``manifest.json``       — tensor/adapter offset tables + artifact I/O
                            signatures (parsed by ``rust/src/manifest.rs``)
* ``init_base.f32``       — little-endian f32 initial base parameters
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import configs, model, vit
from .kernels import lora_matmul as km


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_manifest(cfg: configs.ModelConfig, backend: str, seed: int) -> dict:
    base_specs = vit.base_param_specs(cfg)
    lora_tensors, adapters = vit.lora_param_specs(cfg)

    def tens(specs):
        return [
            {
                "name": s.name,
                "offset": s.offset,
                "size": s.size,
                "shape": list(s.shape),
                "module": s.module,
                "layer": s.layer,
            }
            for s in specs
        ]

    return {
        "schema_version": 1,
        "model": cfg.name,
        "backend": backend,
        "seed": seed,
        "config": {
            "image_size": cfg.image_size,
            "patch_size": cfg.patch_size,
            "in_channels": cfg.in_channels,
            "hidden_dim": cfg.hidden_dim,
            "depth": cfg.depth,
            "num_heads": cfg.num_heads,
            "mlp_dim": cfg.mlp_dim,
            "num_classes": cfg.num_classes,
            "batch_size": cfg.batch_size,
            "tokens": cfg.tokens,
            "r_min": cfg.r_min,
            "r_max": cfg.r_max,
            "lora_alpha": cfg.lora_alpha,
            "rank_buckets": cfg.rank_buckets,
        },
        "base": {"size": vit.base_param_count(cfg), "tensors": tens(base_specs)},
        "lora": {"size": vit.lora_param_count(cfg), "tensors": tens(lora_tensors)},
        "adapters": [
            {
                "name": a.name,
                "layer": a.layer,
                "module": a.module,
                "in_dim": a.in_dim,
                "out_dim": a.out_dim,
                "a_offset": a.a_offset,
                "a_size": a.in_dim * cfg.r_max,
                "b_offset": a.b_offset,
                "b_size": cfg.r_max * a.out_dim,
                "cfg_offset": a.cfg_offset,
            }
            for a in adapters
        ],
        "adapter_cfg_size": vit.adapter_cfg_size(cfg),
        "artifacts": {
            name: {"file": f"{name}.hlo.txt", "inputs": io[0], "outputs": io[1]}
            for name, io in model.ARTIFACT_IO.items()
        },
    }


def build_model(cfg: configs.ModelConfig, out_dir: pathlib.Path, backend: str, seed: int) -> None:
    km.set_backend(backend)
    mdir = out_dir / cfg.name
    mdir.mkdir(parents=True, exist_ok=True)
    for name, builder in model.ARTIFACT_BUILDERS.items():
        t0 = time.perf_counter()
        fn = builder(cfg)
        lowered = jax.jit(fn).lower(*model.example_args(cfg, name))
        text = to_hlo_text(lowered)
        (mdir / f"{name}.hlo.txt").write_text(text)
        print(
            f"[aot] {cfg.name}/{name}: {len(text)} chars in "
            f"{time.perf_counter() - t0:.1f}s",
            file=sys.stderr,
        )
    init = vit.init_base(cfg, seed=seed)
    (mdir / "init_base.f32").write_bytes(init.tobytes())
    (mdir / "manifest.json").write_text(json.dumps(build_manifest(cfg, backend, seed), indent=1))
    print(f"[aot] {cfg.name}: manifest + init ({init.size} base params)", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts root directory")
    ap.add_argument(
        "--models",
        nargs="+",
        default=["vit-micro", "vit-small", "vit-base-sim"],
        choices=sorted(configs.MODELS),
    )
    ap.add_argument("--backend", default="pallas", choices=["pallas", "jnp"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    for name in args.models:
        build_model(configs.get(name), out, args.backend, args.seed)
    # Build-stamp so `make artifacts` is a no-op when inputs are unchanged.
    (out / ".stamp").write_text(str(time.time()))


if __name__ == "__main__":
    main()
