"""Model zoo for the PreLoRA reproduction.

The paper trains ViT-Large (300M) on ImageNet-1k; our testbed is CPU-only
PJRT, so we provide a scaled family whose *dynamics* (from-scratch training,
module taxonomy q/k/v/output/dense, power-of-two rank buckets) match the
paper while staying runnable. Mirrored on the Rust side by
``rust/src/config/model.rs`` — the manifest emitted by ``aot.py`` is the
source of truth at runtime; this table only drives artifact generation.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static configuration for one ViT variant.

    Attributes mirror the paper's setup scaled down; ``r_min``/``r_max``
    bound the power-of-two rank buckets of Algorithm 2 (paper: 8..64 on
    D=1024; we scale the bounds with the hidden dim so the trainable-param
    fraction lands near the paper's ~10%).
    """

    name: str
    image_size: int
    patch_size: int
    in_channels: int
    hidden_dim: int
    depth: int
    num_heads: int
    mlp_dim: int
    num_classes: int
    batch_size: int
    r_min: int
    r_max: int
    lora_alpha: float  # numerator of the LoRA scale: scale = alpha / r

    @property
    def tokens(self) -> int:
        """Sequence length (no CLS token: we use global average pooling, one
        of the standard ViT head variants in Steiner et al., so token counts
        stay power-of-two friendly for Pallas block tiling)."""
        side = self.image_size // self.patch_size
        return side * side

    @property
    def patch_dim(self) -> int:
        return self.patch_size * self.patch_size * self.in_channels

    @property
    def head_dim(self) -> int:
        assert self.hidden_dim % self.num_heads == 0
        return self.hidden_dim // self.num_heads

    @property
    def rank_buckets(self) -> list[int]:
        """Power-of-two ranks r_min..r_max inclusive (Algorithm 2, lines 3-6)."""
        lo = int(math.log2(self.r_min))
        hi = int(math.log2(self.r_max))
        return [2**p for p in range(lo, hi + 1)]


# Target-module taxonomy (the paper's alpha set, Section 4.1):
#   query/key/value  -> attention projections
#   output           -> attention output projection
#   dense            -> MLP up-projection
# ``mlp_out`` is deliberately NOT adapted (not in the paper's alpha set).
ADAPTED_MODULES = ("query", "key", "value", "output", "dense")

MODELS: dict[str, ModelConfig] = {
    cfg.name: cfg
    for cfg in [
        # Test-scale model: fast enough for pytest + cargo test round trips.
        ModelConfig(
            name="vit-micro",
            image_size=16,
            patch_size=4,
            in_channels=3,
            hidden_dim=32,
            depth=2,
            num_heads=2,
            mlp_dim=64,
            num_classes=8,
            batch_size=8,
            r_min=1,
            r_max=4,
            lora_alpha=8.0,
        ),
        ModelConfig(
            name="vit-tiny",
            image_size=16,
            patch_size=4,
            in_channels=3,
            hidden_dim=64,
            depth=4,
            num_heads=4,
            mlp_dim=128,
            num_classes=10,
            batch_size=16,
            r_min=1,
            r_max=8,
            lora_alpha=16.0,
        ),
        # Default model for the figure harnesses.
        ModelConfig(
            name="vit-small",
            image_size=32,
            patch_size=4,
            in_channels=3,
            hidden_dim=128,
            depth=6,
            num_heads=4,
            mlp_dim=256,
            num_classes=16,
            batch_size=16,
            r_min=2,
            r_max=16,
            lora_alpha=32.0,
        ),
        # Largest CPU-feasible stand-in for ViT-Large in the e2e driver.
        ModelConfig(
            name="vit-base-sim",
            image_size=32,
            patch_size=4,
            in_channels=3,
            hidden_dim=256,
            depth=8,
            num_heads=8,
            mlp_dim=1024,
            num_classes=32,
            batch_size=32,
            r_min=4,
            r_max=32,
            lora_alpha=64.0,
        ),
    ]
}


def get(name: str) -> ModelConfig:
    try:
        return MODELS[name]
    except KeyError as e:
        raise KeyError(f"unknown model {name!r}; available: {sorted(MODELS)}") from e
