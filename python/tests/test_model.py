# L2 model structure tests: spec tables, manifest invariants, forward shapes.
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, vit
from compile.configs import ADAPTED_MODULES

MICRO = configs.get("vit-micro")


def test_model_zoo_sane():
    for cfg in configs.MODELS.values():
        assert cfg.hidden_dim % cfg.num_heads == 0
        assert cfg.image_size % cfg.patch_size == 0
        assert cfg.r_min <= cfg.r_max
        assert all(b & (b - 1) == 0 for b in cfg.rank_buckets)  # powers of two
        assert cfg.rank_buckets[0] == cfg.r_min and cfg.rank_buckets[-1] == cfg.r_max


def test_unknown_model_raises():
    with pytest.raises(KeyError):
        configs.get("vit-huge")


@pytest.mark.parametrize("name", sorted(configs.MODELS))
def test_base_specs_contiguous(name):
    cfg = configs.get(name)
    specs = vit.base_param_specs(cfg)
    off = 0
    for s in specs:
        assert s.offset == off, s.name
        off += s.size
    assert off == vit.base_param_count(cfg)


@pytest.mark.parametrize("name", sorted(configs.MODELS))
def test_lora_specs_contiguous_and_adapters_cover_alpha(name):
    cfg = configs.get(name)
    tensors, adapters = vit.lora_param_specs(cfg)
    off = 0
    for s in tensors:
        assert s.offset == off, s.name
        off += s.size
    assert off == vit.lora_param_count(cfg)
    # exactly depth * |alpha| adapters, every module of the paper's set per layer
    assert len(adapters) == cfg.depth * len(ADAPTED_MODULES)
    for l in range(cfg.depth):
        mods = [a.module for a in adapters if a.layer == l]
        assert mods == list(ADAPTED_MODULES)
    # cfg offsets stride r_max + 1
    for i, a in enumerate(adapters):
        assert a.cfg_offset == i * (cfg.r_max + 1)


def test_trainable_fraction_near_paper_claim():
    """Paper: 300M -> ~30M trainable (~10%). Our scaled models should land
    in the same ballpark at the mid rank bucket."""
    for name in ("vit-small", "vit-base-sim"):
        cfg = configs.get(name)
        _, adapters = vit.lora_param_specs(cfg)
        mid_rank = cfg.rank_buckets[len(cfg.rank_buckets) // 2]
        trainable = sum(mid_rank * (a.in_dim + a.out_dim) for a in adapters)
        frac = trainable / vit.base_param_count(cfg)
        assert 0.02 < frac < 0.30, (name, frac)


def test_init_base_deterministic_and_structured():
    f1 = vit.init_base(MICRO, seed=3)
    f2 = vit.init_base(MICRO, seed=3)
    f3 = vit.init_base(MICRO, seed=4)
    assert np.array_equal(f1, f2)
    assert not np.array_equal(f1, f3)
    specs = {s.name: s for s in vit.base_param_specs(MICRO)}
    ln = specs["layer0.ln1.scale"]
    assert np.all(f1[ln.offset : ln.offset + ln.size] == 1.0)
    head = specs["head.w"]
    assert np.all(f1[head.offset : head.offset + head.size] == 0.0)


def test_init_lora_b_zero_a_nonzero():
    flat = vit.init_lora(MICRO, seed=0)
    tensors, _ = vit.lora_param_specs(MICRO)
    for s in tensors:
        chunk = flat[s.offset : s.offset + s.size]
        if s.module == "lora_b":
            assert np.all(chunk == 0.0), s.name
        else:
            assert np.any(chunk != 0.0), s.name


def test_patchify_reassembles_pixels():
    cfg = MICRO
    img = np.arange(
        cfg.image_size * cfg.image_size * cfg.in_channels, dtype=np.float32
    ).reshape(1, cfg.image_size, cfg.image_size, cfg.in_channels)
    patches = np.asarray(vit.patchify(cfg, jnp.asarray(img)))
    assert patches.shape == (1, cfg.tokens, cfg.patch_dim)
    # first patch == top-left p x p block
    p = cfg.patch_size
    want = img[0, :p, :p, :].reshape(-1)
    np.testing.assert_array_equal(patches[0, 0], want)


def test_forward_shapes_and_finite():
    cfg = MICRO
    base = jnp.asarray(vit.init_base(cfg, seed=0))
    rng = np.random.default_rng(0)
    images = jnp.asarray(
        rng.normal(size=(cfg.batch_size, cfg.image_size, cfg.image_size, cfg.in_channels)).astype(
            np.float32
        )
    )
    logits = vit.forward(cfg, base, images)
    assert logits.shape == (cfg.batch_size, cfg.num_classes)
    assert bool(jnp.isfinite(logits).all())


def test_forward_lora_b_zero_matches_base():
    """Freshly initialized adapters (B = 0) must not change the function —
    the invariant that makes the warmup switch loss-continuous."""
    cfg = MICRO
    base = jnp.asarray(vit.init_base(cfg, seed=1))
    lora = jnp.asarray(vit.init_lora(cfg, seed=2))
    acfg = jnp.asarray(vit.uniform_adapter_cfg(cfg, rank=2))
    rng = np.random.default_rng(1)
    images = jnp.asarray(
        rng.normal(size=(cfg.batch_size, cfg.image_size, cfg.image_size, cfg.in_channels)).astype(
            np.float32
        )
    )
    y0 = vit.forward(cfg, base, images)
    y1 = vit.forward(cfg, base, images, lora=(lora, acfg))
    np.testing.assert_allclose(y0, y1, rtol=1e-5, atol=1e-5)


def test_uniform_adapter_cfg_layout():
    cfg = MICRO
    acfg = vit.uniform_adapter_cfg(cfg, rank=2)
    _, adapters = vit.lora_param_specs(cfg)
    per = cfg.r_max + 1
    assert acfg.size == len(adapters) * per
    first = acfg[:per]
    np.testing.assert_array_equal(first[:2], [1.0, 1.0])
    np.testing.assert_array_equal(first[2 : cfg.r_max], 0.0)
    assert first[cfg.r_max] == cfg.lora_alpha / 2
