# AOT pipeline tests: manifest schema, HLO text emission, init dump.
import json
import pathlib

import jax
import numpy as np
import pytest

from compile import aot, configs, model, vit

CFG = configs.get("vit-micro")


def test_manifest_schema_roundtrip():
    m = aot.build_manifest(CFG, backend="pallas", seed=0)
    s = json.dumps(m)
    m2 = json.loads(s)
    assert m2["model"] == "vit-micro"
    assert m2["base"]["size"] == vit.base_param_count(CFG)
    assert m2["lora"]["size"] == vit.lora_param_count(CFG)
    assert m2["adapter_cfg_size"] == vit.adapter_cfg_size(CFG)
    assert set(m2["artifacts"]) == set(model.ARTIFACT_BUILDERS)
    # offsets tile the flat vectors exactly
    for sec in ("base", "lora"):
        off = 0
        for t in m2[sec]["tensors"]:
            assert t["offset"] == off
            assert t["size"] == int(np.prod(t["shape"]))
            off += t["size"]
        assert off == m2[sec]["size"]
    # adapter table consistent with tensors
    for a in m2["adapters"]:
        assert a["a_size"] == a["in_dim"] * CFG.r_max
        assert a["b_size"] == CFG.r_max * a["out_dim"]
        assert a["b_offset"] == a["a_offset"] + a["a_size"]


def test_manifest_io_signatures_match_model_table():
    m = aot.build_manifest(CFG, backend="pallas", seed=0)
    for name, (ins, outs) in model.ARTIFACT_IO.items():
        assert m["artifacts"][name]["inputs"] == ins
        assert m["artifacts"][name]["outputs"] == outs
        assert m["artifacts"][name]["file"] == f"{name}.hlo.txt"


def test_hlo_text_emission_parses_back():
    """Lowered HLO text must contain an ENTRY and parameter declarations
    matching the artifact signature (what the Rust loader consumes)."""
    fn = model.make_eval_full(CFG)
    lowered = jax.jit(fn).lower(*model.example_args(CFG, "eval_full"))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "parameter(0)" in text and "parameter(2)" in text
    n = vit.base_param_count(CFG)
    assert f"f32[{n}]" in text  # base vector input
    b = CFG.batch_size
    assert f"s32[{b}]" in text  # labels input


def test_build_model_writes_all_files(tmp_path: pathlib.Path):
    aot.build_model(CFG, tmp_path, backend="jnp", seed=0)
    mdir = tmp_path / CFG.name
    for name in model.ARTIFACT_BUILDERS:
        f = mdir / f"{name}.hlo.txt"
        assert f.exists() and f.stat().st_size > 1000, name
    man = json.loads((mdir / "manifest.json").read_text())
    assert man["backend"] == "jnp"
    init = np.fromfile(mdir / "init_base.f32", dtype=np.float32)
    assert init.size == vit.base_param_count(CFG)
    want = vit.init_base(CFG, seed=0)
    np.testing.assert_array_equal(init, want)


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_backends_lower_equivalent_semantics(backend):
    """Both kernel backends must produce the same loss on the same inputs
    (the jnp backend is the oracle; artifacts may ship either)."""
    from compile.kernels import lora_matmul as km

    rng = np.random.default_rng(0)
    base = vit.init_base(CFG, seed=0)
    images = rng.normal(size=(CFG.batch_size, CFG.image_size, CFG.image_size, CFG.in_channels)).astype(np.float32)
    labels = rng.integers(0, CFG.num_classes, CFG.batch_size).astype(np.int32)
    try:
        km.set_backend(backend)
        loss, correct = model.make_eval_full(CFG)(base, images, labels)
    finally:
        km.set_backend("pallas")
    km.set_backend("jnp")
    loss_ref, correct_ref = model.make_eval_full(CFG)(base, images, labels)
    km.set_backend("pallas")
    np.testing.assert_allclose(float(loss), float(loss_ref), rtol=1e-5)
    assert float(correct) == float(correct_ref)
