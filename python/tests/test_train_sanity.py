# End-to-end training sanity at the pure-JAX level: the PreLoRA phases must
# each be able to reduce the loss on a learnable synthetic task. This
# validates L1+L2 before the Rust coordinator is in the loop. Mirrors the
# Rust trainer: Adam on flat vectors, gradients from the artifact entry
# points.
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, vit

CFG = configs.get("vit-micro")


def _synthetic_batch(rng, cfg):
    """Class-conditional oriented sinusoid + noise — the python mirror of
    rust/src/data/synth.rs (statistically similar, not bit-identical)."""
    b, s, c = cfg.batch_size, cfg.image_size, cfg.in_channels
    labels = rng.integers(0, cfg.num_classes, b)
    yy, xx = np.mgrid[0:s, 0:s].astype(np.float32) / s
    images = np.zeros((b, s, s, c), np.float32)
    for i, lab in enumerate(labels):
        theta = 2 * np.pi * lab / cfg.num_classes
        freq = 2.0 + (lab % 4)
        phase = rng.uniform(0, 2 * np.pi)
        pat = np.sin(2 * np.pi * freq * (np.cos(theta) * xx + np.sin(theta) * yy) + phase)
        for ch in range(c):
            images[i, :, :, ch] = pat + rng.normal(0, 0.3, (s, s))
    return jnp.asarray(images), jnp.asarray(labels.astype(np.int32))


class _Adam:
    """Flat-vector Adam, the same update rule as rust/src/optim/adamw.rs
    (wd = 0)."""

    def __init__(self, n, lr=2e-3):
        self.m = jnp.zeros(n)
        self.v = jnp.zeros(n)
        self.t = 0
        self.lr = lr

    def step(self, p, g):
        self.t += 1
        self.m = 0.9 * self.m + 0.1 * g
        self.v = 0.999 * self.v + 0.001 * g * g
        mh = self.m / (1 - 0.9**self.t)
        vh = self.v / (1 - 0.999**self.t)
        return p - self.lr * mh / (jnp.sqrt(vh) + 1e-8)


@pytest.fixture(scope="module")
def trained_base():
    """Run 80 full-parameter Adam steps; reused by the LoRA-phase tests."""
    rng = np.random.default_rng(0)
    base = jnp.asarray(vit.init_base(CFG, seed=0))
    step = jax.jit(model.make_full_grads(CFG))
    opt = _Adam(base.size)
    losses = []
    for _ in range(80):
        images, labels = _synthetic_batch(rng, CFG)
        d_base, loss, _ = step(base, images, labels)
        base = opt.step(base, d_base)
        losses.append(float(loss))
    return base, losses


def test_full_phase_learns(trained_base):
    _, losses = trained_base
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    assert last < first - 0.15, (first, last)


def test_lora_phase_learns_with_frozen_base(trained_base):
    base, _ = trained_base
    rng = np.random.default_rng(1)
    lora = jnp.asarray(vit.init_lora(CFG, seed=1))
    acfg = jnp.asarray(vit.uniform_adapter_cfg(CFG, rank=2))
    step = jax.jit(model.make_lora_grads(CFG))
    opt = _Adam(lora.size)
    base0 = np.asarray(base).copy()
    losses = []
    for _ in range(60):
        images, labels = _synthetic_batch(rng, CFG)
        d_lora, loss, _ = step(base, lora, acfg, images, labels)
        lora = opt.step(lora, d_lora)
        losses.append(float(loss))
    # base untouched; adapters alone keep reducing the loss
    np.testing.assert_array_equal(np.asarray(base), base0)
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.02, losses[::10]


def test_warmup_phase_updates_both(trained_base):
    base, _ = trained_base
    rng = np.random.default_rng(2)
    lora = jnp.asarray(vit.init_lora(CFG, seed=2))
    acfg = jnp.asarray(vit.uniform_adapter_cfg(CFG, rank=2))
    step = jax.jit(model.make_warmup_grads(CFG))
    opt_b = _Adam(base.size)
    opt_l = _Adam(lora.size)
    base_before = np.asarray(base).copy()
    lora_before = np.asarray(lora).copy()
    loss = jnp.inf
    for _ in range(5):
        images, labels = _synthetic_batch(rng, CFG)
        d_base, d_lora, loss, _ = step(base, lora, acfg, images, labels)
        base = opt_b.step(base, d_base)
        lora = opt_l.step(lora, d_lora)
    assert np.any(np.asarray(base) != base_before)
    assert np.any(np.asarray(lora) != lora_before)
    assert np.isfinite(float(loss))
