# Gradient-path tests: the artifact entry points must agree with each other
# and the frozen-base artifact must really drop the base backward pass.
import jax
import jax.numpy as jnp
import numpy as np

from compile import configs, model, vit

CFG = configs.get("vit-micro")


def _batch(seed=0):
    rng = np.random.default_rng(seed)
    images = jnp.asarray(
        rng.normal(size=(CFG.batch_size, CFG.image_size, CFG.image_size, CFG.in_channels)).astype(
            np.float32
        )
    )
    labels = jnp.asarray(rng.integers(0, CFG.num_classes, CFG.batch_size).astype(np.int32))
    return images, labels


def _state(seed=0):
    base = jnp.asarray(vit.init_base(CFG, seed=seed))
    lora = jnp.asarray(vit.init_lora(CFG, seed=seed + 1))
    acfg = jnp.asarray(vit.uniform_adapter_cfg(CFG, rank=2))
    return base, lora, acfg


def test_full_grads_shapes_and_nonzero():
    base = jnp.asarray(vit.init_base(CFG, seed=0))
    images, labels = _batch()
    d_base, loss, correct = model.make_full_grads(CFG)(base, images, labels)
    assert d_base.shape == base.shape
    assert float(jnp.abs(d_base).max()) > 0
    assert np.isfinite(float(loss))
    assert 0 <= float(correct) <= CFG.batch_size
    # initial loss ~ log(num_classes): head starts at zero
    assert abs(float(loss) - np.log(CFG.num_classes)) < 0.2


def test_lora_grads_agree_with_warmup_lora_part():
    """d_lora from the frozen-base artifact must equal the lora part of the
    joint warmup artifact (same loss, same point)."""
    base, lora, acfg = _state()
    images, labels = _batch(1)
    d_base_w, d_lora_w, loss_w, _ = model.make_warmup_grads(CFG)(
        base, lora, acfg, images, labels
    )
    d_lora, loss_l, _ = model.make_lora_grads(CFG)(base, lora, acfg, images, labels)
    np.testing.assert_allclose(loss_w, loss_l, rtol=1e-6)
    np.testing.assert_allclose(d_lora, d_lora_w, rtol=5e-4, atol=1e-6)
    assert float(jnp.abs(d_base_w).max()) > 0


def test_warmup_base_grads_agree_with_full_when_lora_inert():
    """With B = 0 and fresh adapters the joint warmup base-gradient must
    equal the pure full-model gradient (forward functions coincide)."""
    base, lora, acfg = _state(3)
    images, labels = _batch(3)
    d_base_full, loss_f, _ = model.make_full_grads(CFG)(base, images, labels)
    d_base_w, _, loss_w, _ = model.make_warmup_grads(CFG)(base, lora, acfg, images, labels)
    np.testing.assert_allclose(loss_f, loss_w, rtol=1e-6)
    np.testing.assert_allclose(d_base_full, d_base_w, rtol=5e-4, atol=5e-6)


def test_eval_matches_train_loss():
    base, lora, acfg = _state(5)
    images, labels = _batch(5)
    _, loss_g, corr_g = model.make_full_grads(CFG)(base, images, labels)
    loss_e, corr_e = model.make_eval_full(CFG)(base, images, labels)
    np.testing.assert_allclose(loss_g, loss_e, rtol=1e-6)
    assert float(corr_g) == float(corr_e)
    _, loss_lg, _ = model.make_lora_grads(CFG)(base, lora, acfg, images, labels)
    loss_le, _ = model.make_eval_lora(CFG)(base, lora, acfg, images, labels)
    np.testing.assert_allclose(loss_lg, loss_le, rtol=1e-6)


def test_frozen_base_backward_is_dce_d():
    """The lora_grads HLO must be materially smaller than warmup_grads: the
    base backward pass (dW kernels, attention bwd wrt weights) is dead code
    once the base is stop_gradient'ed. This is the compile-time witness of
    the paper's post-switch speedup."""
    from compile.aot import to_hlo_text

    lw = jax.jit(model.make_warmup_grads(CFG)).lower(*model.example_args(CFG, "warmup_grads"))
    ll = jax.jit(model.make_lora_grads(CFG)).lower(*model.example_args(CFG, "lora_grads"))
    warm = to_hlo_text(lw)
    lora = to_hlo_text(ll)
    assert len(lora) < 0.85 * len(warm), (len(lora), len(warm))


def test_rank_mask_restricts_capacity_in_model():
    """Increasing the masked rank changes the lora gradient support.

    Note B must be non-zero here: with the standard B=0 init, dA = x^T (dy
    B^T) = 0 exactly, so a fresh adapter would vacuously pass. The head must
    also be non-zero: the zero-init head makes every trunk gradient vanish
    at initialization (d pooled = head.w @ d logits = 0)."""
    base, _, _ = _state(7)
    rng = np.random.default_rng(7)
    head = {s.name: s for s in vit.base_param_specs(CFG)}["head.w"]
    base = base.at[head.offset : head.offset + head.size].set(
        jnp.asarray(rng.normal(0, 0.05, head.size).astype(np.float32))
    )
    lora = jnp.asarray(rng.normal(0, 0.02, vit.lora_param_count(CFG)).astype(np.float32))
    images, labels = _batch(7)
    tensors, adapters = vit.lora_param_specs(CFG)
    for rank in (1, CFG.r_max):
        acfg = jnp.asarray(vit.uniform_adapter_cfg(CFG, rank=rank))
        d_lora, _, _ = model.make_lora_grads(CFG)(base, lora, acfg, images, labels)
        d = np.asarray(d_lora)
        ad = adapters[0]
        da = d[ad.a_offset : ad.a_offset + ad.in_dim * CFG.r_max].reshape(ad.in_dim, CFG.r_max)
        assert np.any(da[:, :rank] != 0)
        assert np.all(da[:, rank:] == 0)
