# pytest: Pallas kernels vs the pure-jnp oracle — the CORE correctness
# signal for L1. Hypothesis sweeps shapes/ranks/masks; every custom_vjp
# cotangent is checked against jax.grad of the reference.
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import lora_matmul as km
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, scale=0.5):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


dims = st.sampled_from([1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64])
ranks = st.sampled_from([1, 2, 4, 8])


@st.composite
def lora_problem(draw):
    m = draw(dims)
    k = draw(dims)
    n = draw(dims)
    r = draw(ranks)
    r_eff = draw(st.integers(min_value=0, max_value=r))
    seed = draw(st.integers(min_value=0, max_value=2**16))
    return m, k, n, r, r_eff, seed


def _problem_arrays(m, k, n, r, r_eff, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n), 0.2)
    a = _rand(seed + 2, (k, r), 0.2)
    b = _rand(seed + 3, (r, n), 0.2)
    mask = jnp.concatenate([jnp.ones(r_eff), jnp.zeros(r - r_eff)]).astype(jnp.float32)
    scale = jnp.float32(2.0 if r_eff == 0 else 16.0 / r_eff)
    return x, w, a, b, mask, scale


@hypothesis.settings(max_examples=40, deadline=None)
@hypothesis.given(lora_problem())
def test_lora_forward_matches_ref(prob):
    x, w, a, b, mask, scale = _problem_arrays(*prob)
    got = km.lora_matmul(x, w, a, b, mask, scale)
    want = ref.ref_lora_matmul(x, w, a, b, mask, scale)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(lora_problem())
def test_lora_grads_match_ref(prob):
    x, w, a, b, mask, scale = _problem_arrays(*prob)

    def loss_k(args):
        return jnp.sum(km.lora_matmul(*args, mask, scale) ** 2)

    def loss_r(args):
        return jnp.sum(ref.ref_lora_matmul(*args, mask, scale) ** 2)

    gk = jax.grad(loss_k)((x, w, a, b))
    gr = jax.grad(loss_r)((x, w, a, b))
    for name, u, v in zip("xwab", gk, gr):
        np.testing.assert_allclose(u, v, rtol=2e-4, atol=2e-4, err_msg=f"d{name}")


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(lora_problem())
def test_masked_rank_columns_are_inert(prob):
    """Algorithm 2's static-shape rank trick: entries beyond r_eff must not
    affect the output and must receive exactly-zero gradients."""
    m, k, n, r, r_eff, seed = prob
    x, w, a, b, mask, scale = _problem_arrays(m, k, n, r, r_eff, seed)
    y = km.lora_matmul(x, w, a, b, mask, scale)
    # perturb masked-out region -> output unchanged
    a2 = a.at[:, r_eff:].add(100.0)
    b2 = b.at[r_eff:, :].add(-50.0)
    y2 = km.lora_matmul(x, w, a2, b2, mask, scale)
    np.testing.assert_allclose(y, y2, rtol=1e-5, atol=1e-5)
    # masked-out grads are exactly zero
    da, db = jax.grad(
        lambda aa, bb: jnp.sum(km.lora_matmul(x, w, aa, bb, mask, scale) ** 2),
        argnums=(0, 1),
    )(a, b)
    assert np.all(np.asarray(da)[:, r_eff:] == 0.0)
    assert np.all(np.asarray(db)[r_eff:, :] == 0.0)


@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(
    st.sampled_from([1, 3, 8, 16, 40, 64]),
    st.sampled_from([1, 2, 8, 32, 48]),
    st.sampled_from([1, 5, 8, 10, 32]),
    st.integers(min_value=0, max_value=2**16),
)
def test_base_matmul_matches_ref(m, k, n, seed):
    x, w = _rand(seed, (m, k)), _rand(seed + 1, (k, n))
    np.testing.assert_allclose(km.matmul(x, w), ref.ref_matmul(x, w), rtol=1e-5, atol=1e-5)
    gk = jax.grad(lambda t: jnp.sum(km.matmul(*t) ** 2))((x, w))
    gr = jax.grad(lambda t: jnp.sum(ref.ref_matmul(*t) ** 2))((x, w))
    np.testing.assert_allclose(gk[0], gr[0], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(gk[1], gr[1], rtol=2e-4, atol=2e-4)


def test_zero_mask_is_pure_base():
    """All-zero mask => LoRA branch contributes nothing (rank 0)."""
    x, w, a, b, mask, _ = _problem_arrays(8, 16, 12, 4, 0, 7)
    got = km.lora_matmul(x, w, a, b, mask, jnp.float32(3.0))
    np.testing.assert_allclose(got, ref.ref_matmul(x, w), rtol=1e-5, atol=1e-5)


def test_scale_is_linear():
    x, w, a, b, mask, _ = _problem_arrays(8, 16, 12, 4, 4, 11)
    y1 = km.lora_matmul(x, w, a, b, mask, jnp.float32(1.0))
    y3 = km.lora_matmul(x, w, a, b, mask, jnp.float32(3.0))
    base = ref.ref_matmul(x, w)
    np.testing.assert_allclose(y3 - base, 3.0 * (y1 - base), rtol=1e-4, atol=1e-4)


def test_bf16_forward():
    """The kernels accumulate in f32 regardless of input dtype."""
    x, w, a, b, mask, scale = _problem_arrays(8, 16, 12, 4, 2, 3)
    xb, wb = x.astype(jnp.bfloat16), w.astype(jnp.bfloat16)
    ab, bb = a.astype(jnp.bfloat16), b.astype(jnp.bfloat16)
    got = km.lora_matmul(xb, wb, ab, bb, mask, scale).astype(jnp.float32)
    want = ref.ref_lora_matmul(xb, wb, ab, bb, mask, scale).astype(jnp.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_backend_switch_roundtrip():
    x, w, a, b, mask, scale = _problem_arrays(8, 16, 12, 4, 2, 5)
    try:
        km.set_backend("jnp")
        y_jnp = km.lora_matmul(x, w, a, b, mask, scale)
    finally:
        km.set_backend("pallas")
    y_pl = km.lora_matmul(x, w, a, b, mask, scale)
    np.testing.assert_allclose(y_jnp, y_pl, rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError):
        km.set_backend("nope")


def test_vmem_estimate_within_budget():
    """Shipping block shapes must stay far under the ~16 MiB VMEM budget
    for the largest model in the zoo (vit-base-sim projections)."""
    est = km.vmem_estimate(m=32 * 64, k=256, n=1024, r=32)
    assert est["total_bytes"] < 16 * 2**20 / 4, est
