#!/usr/bin/env python3
"""Tests for scripts/bench_gate.py (run in CI: ``python3 scripts/test_bench_gate.py``).

Covers the three behaviors the gate exists for:

1. a step-latency regression beyond the tolerance fails the gate;
2. a case present in the baseline but missing from the fresh results (a
   bench that silently started skipping work) hard-fails;
3. ``--update`` ratifies the fresh results as the new baseline, after
   which the gate passes on them.

Plus the supporting contracts: seeded (null-latency) baselines report
instead of failing, byte-metadata growth beyond tolerance fails, and
within-tolerance drift passes. Uses only the standard library so it runs
in the same bare CI interpreter as the gate itself.
"""

from __future__ import annotations

import contextlib
import copy
import io
import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import bench_gate  # noqa: E402


def bench_doc() -> dict:
    """A minimal bench JSON in the harness schema."""
    return {
        "results": [
            {
                "name": "vit-micro/full/zero-off",
                "iters": 1,
                "mean_s": 0.100,
                "p50_s": 0.100,
                "p95_s": 0.110,
                "units_per_s": 10.0,
            },
            {
                "name": "vit-micro/full/zero-2",
                "iters": 1,
                "mean_s": 0.120,
                "p50_s": 0.120,
                "p95_s": 0.130,
                "units_per_s": 8.3,
            },
        ],
        "opt_state_bytes_per_worker": "1024",
        "grad_bytes_per_worker": "512",
        "model": "vit-micro",
    }


class GateHarness(unittest.TestCase):
    def setUp(self) -> None:
        self.dir = tempfile.TemporaryDirectory()
        self.addCleanup(self.dir.cleanup)
        self.fresh_path = os.path.join(self.dir.name, "fresh.json")
        self.base_path = os.path.join(self.dir.name, "baseline.json")

    def write(self, path: str, doc: dict) -> None:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(doc, f)

    def run_gate(self, *extra: str) -> tuple[int, str, str]:
        """Run bench_gate.main() with patched argv; returns (exit code, stdout, stderr)."""
        argv = [
            "bench_gate.py",
            "--fresh",
            self.fresh_path,
            "--baseline",
            self.base_path,
            *extra,
        ]
        out, err = io.StringIO(), io.StringIO()
        old_argv = sys.argv
        sys.argv = argv
        try:
            with contextlib.redirect_stdout(out), contextlib.redirect_stderr(err):
                try:
                    bench_gate.main()
                    code = 0
                except SystemExit as e:
                    code = e.code if isinstance(e.code, int) else 1
        finally:
            sys.argv = old_argv
        return code, out.getvalue(), err.getvalue()


class TestToleranceBreach(GateHarness):
    def test_latency_regression_beyond_tolerance_fails(self) -> None:
        self.write(self.base_path, bench_doc())
        fresh = bench_doc()
        fresh["results"][0]["mean_s"] = 0.100 * 1.20  # +20% > default 15%
        self.write(self.fresh_path, fresh)
        code, _, err = self.run_gate()
        self.assertEqual(code, 1)
        self.assertIn("regressed", err)
        self.assertIn("vit-micro/full/zero-off", err)

    def test_within_tolerance_passes(self) -> None:
        self.write(self.base_path, bench_doc())
        fresh = bench_doc()
        fresh["results"][0]["mean_s"] = 0.100 * 1.10  # +10% < 15%
        self.write(self.fresh_path, fresh)
        code, out, _ = self.run_gate()
        self.assertEqual(code, 0, out)
        self.assertIn("OK", out)

    def test_tolerance_env_override_tightens_the_gate(self) -> None:
        self.write(self.base_path, bench_doc())
        fresh = bench_doc()
        fresh["results"][0]["mean_s"] = 0.100 * 1.10
        self.write(self.fresh_path, fresh)
        os.environ["PRELORA_BENCH_TOL_PCT"] = "5"
        self.addCleanup(os.environ.pop, "PRELORA_BENCH_TOL_PCT", None)
        code, _, err = self.run_gate()
        self.assertEqual(code, 1, "+10% must fail a 5% tolerance")
        self.assertIn("tolerance 5%", err)

    def test_byte_metadata_growth_beyond_tolerance_fails(self) -> None:
        self.write(self.base_path, bench_doc())
        fresh = bench_doc()
        fresh["grad_bytes_per_worker"] = "2048"  # 4x the baseline 512
        self.write(self.fresh_path, fresh)
        code, _, err = self.run_gate()
        self.assertEqual(code, 1)
        self.assertIn("grad_bytes_per_worker", err)

    def test_seeded_null_baseline_reports_but_passes(self) -> None:
        base = bench_doc()
        for m in base["results"]:
            m["mean_s"] = None  # the shipped seeded baseline
        self.write(self.base_path, base)
        self.write(self.fresh_path, bench_doc())
        code, out, _ = self.run_gate()
        self.assertEqual(code, 0, out)
        self.assertIn("no recorded latency", out)


class TestVanishedCase(GateHarness):
    def test_case_missing_from_fresh_hard_fails(self) -> None:
        self.write(self.base_path, bench_doc())
        fresh = bench_doc()
        del fresh["results"][1]  # the bench "started skipping" zero-2
        self.write(self.fresh_path, fresh)
        code, _, err = self.run_gate()
        self.assertEqual(code, 1)
        self.assertIn("missing from fresh results", err)
        self.assertIn("vit-micro/full/zero-2", err)

    def test_new_case_is_a_note_not_a_failure(self) -> None:
        self.write(self.base_path, bench_doc())
        fresh = bench_doc()
        fresh["results"].append(dict(fresh["results"][0], name="vit-micro/new-case"))
        self.write(self.fresh_path, fresh)
        code, out, _ = self.run_gate()
        self.assertEqual(code, 0, out)
        self.assertIn("new case", out)


class TestUpdateRatification(GateHarness):
    def test_update_rewrites_baseline_then_gate_passes(self) -> None:
        # a fresh file that would fail against the old baseline...
        self.write(self.base_path, bench_doc())
        fresh = bench_doc()
        fresh["results"][0]["mean_s"] = 0.200
        self.write(self.fresh_path, fresh)
        code, _, err = self.run_gate()
        self.assertEqual(code, 1, "sanity: the regression must fail pre-update")

        # ...is ratified by --update...
        code, out, _ = self.run_gate("--update")
        self.assertEqual(code, 0, out)
        self.assertIn("updated", out)
        with open(self.base_path, encoding="utf-8") as f:
            ratified = json.load(f)
        self.assertEqual(ratified["results"][0]["mean_s"], 0.200)

        # ...after which the same fresh results gate green
        code, out, _ = self.run_gate()
        self.assertEqual(code, 0, out)

    def test_update_does_not_read_the_old_baseline(self) -> None:
        # ratifying must work even when no baseline exists yet
        self.write(self.fresh_path, bench_doc())
        self.assertFalse(os.path.exists(self.base_path))
        code, out, _ = self.run_gate("--update")
        self.assertEqual(code, 0, out)
        self.assertTrue(os.path.exists(self.base_path))

    def test_update_refuses_seeded_null_means(self) -> None:
        # a fresh file whose cases were never actually timed must not be
        # ratifiable by default: it would disarm the latency gate forever
        self.write(self.base_path, bench_doc())
        fresh = bench_doc()
        fresh["results"][1]["mean_s"] = None
        self.write(self.fresh_path, fresh)
        code, _, err = self.run_gate("--update")
        self.assertEqual(code, 1)
        self.assertIn("refusing to ratify", err)
        self.assertIn("vit-micro/full/zero-2", err)
        self.assertIn("--allow-first-run", err)
        # the baseline must be untouched by the refused update
        with open(self.base_path, encoding="utf-8") as f:
            self.assertEqual(json.load(f), bench_doc())

    def test_allow_first_run_permits_seeding_a_null_baseline(self) -> None:
        fresh = bench_doc()
        for m in fresh["results"]:
            m["mean_s"] = None
        self.write(self.fresh_path, fresh)
        code, out, _ = self.run_gate("--update", "--allow-first-run")
        self.assertEqual(code, 0, out)
        with open(self.base_path, encoding="utf-8") as f:
            ratified = json.load(f)
        self.assertIsNone(ratified["results"][0]["mean_s"])
        # and fully-timed results never need the escape hatch
        self.write(self.fresh_path, bench_doc())
        code, out, _ = self.run_gate("--update")
        self.assertEqual(code, 0, out)


class TestMalformedInput(GateHarness):
    def test_non_bench_json_is_rejected(self) -> None:
        self.write(self.fresh_path, {"not": "a bench file"})
        self.write(self.base_path, bench_doc())
        code, _, _ = self.run_gate()
        self.assertNotEqual(code, 0)


if __name__ == "__main__":
    unittest.main(verbosity=2)
