#!/usr/bin/env python3
"""Bench regression gate: diff a fresh BENCH_step_latency.json against the
committed baseline and fail on step-latency or memory-bytes regressions.

Usage:
    python scripts/bench_gate.py \
        --fresh rust/results/BENCH_step_latency.json \
        --baseline results/baseline.json
    python scripts/bench_gate.py --fresh ... --baseline ... --update

Both files use the bench harness's JSON schema (``util::bench::Bench::
write_json``): a ``results`` array of ``{name, iters, mean_s, p50_s,
p95_s, units_per_s}`` measurements plus free-form string metadata keys.

Checks, in order:

1. **Coverage** — every case named in the baseline must be present in the
   fresh results. A case disappearing means the bench started *skipping*
   work (e.g. the model-skip path when artifacts are missing), which is
   exactly the silent regression this gate exists to catch. Fails hard.
2. **Memory bytes** — metadata keys ending in ``_bytes`` / ``_bytes_
   per_rank`` / ``_bytes_per_worker`` are compared numerically; a fresh
   value above ``baseline * (1 + tol)`` fails. These are deterministic
   (they derive from the model manifest and the shard arithmetic), so in
   practice any growth is a real accounting regression.
3. **Step latency** — per case, ``fresh.mean_s > baseline.mean_s *
   (1 + tol)`` fails, unless the baseline's ``mean_s`` is null (a seeded
   baseline that has not yet recorded real CI timings — reported, not
   failed) or the baseline mean is below the noise floor (smoke-mode
   timings under a few ms flap far beyond any useful tolerance).

Environment:
    PRELORA_BENCH_TOL_PCT     latency/bytes tolerance in percent (default 15)
    PRELORA_BENCH_MIN_S       latency noise floor in seconds (default 0.002);
                              baseline means below it are coverage-checked
                              but not latency-gated

``--update`` rewrites the baseline from the fresh file (keeping it in the
same schema) instead of gating — run it locally and commit the result to
ratify an intended change. Ratification refuses fresh results that carry
seeded-null latency means (a ``mean_s`` of null means the bench never
actually timed that case — ratifying it would silently disarm the
latency gate forever) unless ``--allow-first-run`` is passed, the escape
hatch for seeding a brand-new baseline before the first trusted CI run.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

BYTE_KEY_SUFFIXES = ("_bytes", "_bytes_per_rank", "_bytes_per_worker")


def load(path: str) -> dict:
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    if "results" not in doc or not isinstance(doc["results"], list):
        sys.exit(f"bench_gate: {path} has no 'results' array (not a bench JSON?)")
    return doc


def by_name(doc: dict) -> dict[str, dict]:
    out = {}
    for m in doc["results"]:
        out[m["name"]] = m
    return out


def byte_metadata(doc: dict) -> dict[str, int]:
    out = {}
    for key, value in doc.items():
        if key == "results" or not any(key.endswith(s) for s in BYTE_KEY_SUFFIXES):
            continue
        try:
            out[key] = int(str(value))
        except ValueError:
            sys.exit(f"bench_gate: metadata key {key!r} is not an integer: {value!r}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", required=True, help="freshly produced bench JSON")
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument(
        "--update",
        action="store_true",
        help="rewrite the baseline from the fresh results instead of gating",
    )
    ap.add_argument(
        "--allow-first-run",
        action="store_true",
        help=(
            "with --update: permit ratifying results whose latency means are "
            "null (seeded placeholders) — only for seeding a brand-new baseline"
        ),
    )
    args = ap.parse_args()

    tol = float(os.environ.get("PRELORA_BENCH_TOL_PCT", "15")) / 100.0
    min_s = float(os.environ.get("PRELORA_BENCH_MIN_S", "0.002"))

    fresh = load(args.fresh)

    if args.update:
        null_means = sorted(
            m["name"] for m in fresh["results"] if m.get("mean_s") is None
        )
        if null_means and not args.allow_first_run:
            print(
                "bench_gate: refusing to ratify: "
                f"{len(null_means)} case(s) carry seeded-null latency means "
                f"({', '.join(null_means)}) — a null mean_s was never actually "
                "timed, and ratifying it disarms the latency gate for that case; "
                "re-run the bench so every case records a mean, or pass "
                "--allow-first-run to seed a brand-new baseline deliberately",
                file=sys.stderr,
            )
            sys.exit(1)
        with open(args.baseline, "w", encoding="utf-8") as f:
            json.dump(fresh, f, indent=1)
            f.write("\n")
        print(f"bench_gate: baseline {args.baseline} updated from {args.fresh}")
        return

    base = load(args.baseline)
    fresh_cases = by_name(fresh)
    base_cases = by_name(base)
    failures: list[str] = []
    notes: list[str] = []

    # 1. coverage: the bench must still run everything the baseline ran
    missing = sorted(set(base_cases) - set(fresh_cases))
    for name in missing:
        failures.append(
            f"case {name!r} present in baseline but missing from fresh results "
            "(did the bench start skipping models?)"
        )
    for name in sorted(set(fresh_cases) - set(base_cases)):
        notes.append(f"new case {name!r} (not in baseline; run --update to ratify)")

    # 2. deterministic memory metadata
    fresh_bytes = byte_metadata(fresh)
    for key, want in sorted(byte_metadata(base).items()):
        got = fresh_bytes.get(key)
        if got is None:
            failures.append(f"byte metadata {key!r} missing from fresh results")
        elif got > want * (1.0 + tol):
            failures.append(
                f"{key}: {got} B exceeds baseline {want} B by more than {tol:.0%}"
            )
        elif got != want:
            notes.append(f"{key}: {got} B vs baseline {want} B (within tolerance)")

    # 3. latency per case
    for name in sorted(set(base_cases) & set(fresh_cases)):
        want = base_cases[name].get("mean_s")
        got = fresh_cases[name].get("mean_s")
        if want is None:
            fresh_desc = "also null" if got is None else f"{got:.6f}s"
            notes.append(
                f"{name}: baseline has no recorded latency (seeded); fresh mean "
                f"{fresh_desc} — run --update to start gating it"
            )
            continue
        if got is None:
            failures.append(f"{name}: fresh result has no mean_s")
            continue
        if want < min_s:
            notes.append(
                f"{name}: baseline mean {want:.6f}s below noise floor {min_s}s, "
                "latency not gated"
            )
            continue
        if got > want * (1.0 + tol):
            failures.append(
                f"{name}: mean {got:.6f}s regressed vs baseline {want:.6f}s "
                f"(+{(got / want - 1.0):.1%}, tolerance {tol:.0%})"
            )

    for n in notes:
        print(f"bench_gate: note: {n}")
    if failures:
        print(f"bench_gate: {len(failures)} regression(s):", file=sys.stderr)
        for f_ in failures:
            print(f"  FAIL: {f_}", file=sys.stderr)
        sys.exit(1)
    print(
        f"bench_gate: OK — {len(base_cases)} baseline case(s) covered, "
        f"tolerance {tol:.0%}"
    )


if __name__ == "__main__":
    main()
