#!/usr/bin/env bash
# Replace the in-tree offline stub of the vendored `xla` crate
# (rust/vendor/xla) with the real xla-rs bindings plus the xla_extension
# runtime, so CI can compile and execute the checked-in HLO artifacts
# (rust/artifacts/vit-micro) instead of taking the model-skip path.
#
# The stub mirrors the real crate's API surface exactly (see
# rust/vendor/xla/src/lib.rs), so swapping the directory is the entire
# integration — no caller changes. Locally you can run this script too;
# the stub is only there because the offline build environment cannot
# fetch these.
#
# Pinned versions (keep in sync with rust/vendor/xla/Cargo.toml and the
# HLO-text interchange rationale in python/compile/aot.py):
#   xla-rs        — the bindings crate, crate name `xla`
#   xla_extension — 0.5.1 CPU build (elixir-nx/xla release tarball); the
#                   0.5.x text parser reassigns 64-bit instruction ids,
#                   which is why the artifacts are HLO *text*
set -euo pipefail

VENDOR_DIR="${1:-rust/vendor/xla}"
XLA_RS_REPO="${XLA_RS_REPO:-https://github.com/LaurentMazare/xla-rs}"
# Pinned: the bindings rev is part of the bench-gate's reproducibility
# surface (an upstream API or codegen change would shift both the build
# and the gated step latencies). Bump deliberately, together with
# results/baseline.json if timings move.
XLA_RS_REV="${XLA_RS_REV:-v0.1.6}"
XLA_EXT_VERSION="${XLA_EXT_VERSION:-0.5.1}"
XLA_EXT_URL="https://github.com/elixir-nx/xla/releases/download/v${XLA_EXT_VERSION}/xla_extension-x86_64-linux-gnu-cpu.tar.gz"
CACHE_DIR="${XLA_CACHE_DIR:-$HOME/.cache/prelora-xla}"

mkdir -p "$CACHE_DIR"

# 1. xla_extension runtime (cached across CI runs via actions/cache)
EXT_DIR="$CACHE_DIR/xla_extension-${XLA_EXT_VERSION}"
if [ ! -d "$EXT_DIR/xla_extension" ]; then
    echo "fetching xla_extension ${XLA_EXT_VERSION} (cpu) ..."
    mkdir -p "$EXT_DIR"
    curl -fsSL --retry 3 "$XLA_EXT_URL" | tar -xz -C "$EXT_DIR"
fi
export XLA_EXTENSION_DIR="$EXT_DIR/xla_extension"
echo "XLA_EXTENSION_DIR=$XLA_EXTENSION_DIR"

# 2. xla-rs bindings (cached checkout; skip the network when the cache
#    already holds the pinned rev, so the actions/cache hit is a real hit)
SRC_DIR="$CACHE_DIR/xla-rs"
MARKER="$CACHE_DIR/xla-rs.rev"
if [ ! -d "$SRC_DIR/.git" ] || [ "$(cat "$MARKER" 2>/dev/null)" != "$XLA_RS_REV" ]; then
    rm -rf "$SRC_DIR"
    git clone --depth 1 --branch "$XLA_RS_REV" "$XLA_RS_REPO" "$SRC_DIR" || {
        # tags and branches work with --branch; a bare commit SHA needs a
        # fetch-by-rev instead
        git init -q "$SRC_DIR"
        git -C "$SRC_DIR" remote add origin "$XLA_RS_REPO"
        git -C "$SRC_DIR" fetch --depth 1 origin "$XLA_RS_REV"
        git -C "$SRC_DIR" checkout --force FETCH_HEAD
    }
    echo "$XLA_RS_REV" > "$MARKER"
fi

# 3. swap the stub for the real crate, preserving the vendored name and
#    version so rust/Cargo.toml's `xla = { path = "vendor/xla" }` resolves
#    unchanged
rm -rf "$VENDOR_DIR"
mkdir -p "$(dirname "$VENDOR_DIR")"
cp -r "$SRC_DIR" "$VENDOR_DIR"
rm -rf "$VENDOR_DIR/.git"

# export for the subsequent cargo steps (GitHub Actions env file)
if [ -n "${GITHUB_ENV:-}" ]; then
    echo "XLA_EXTENSION_DIR=$XLA_EXTENSION_DIR" >> "$GITHUB_ENV"
fi
echo "real xla-rs bindings installed at $VENDOR_DIR"
