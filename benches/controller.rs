//! Coordinator hot-path microbenches: everything the L3 does per step or
//! per epoch besides executing the artifact. Targets (DESIGN.md §Perf):
//! the coordinator must stay well under 10% of step time.
//!
//! Writes results/bench_controller.csv.

use std::collections::BTreeMap;

use prelora::config::{PreLoraConfig, TrainConfig};
use prelora::convergence::{ConvergenceStrategy, WelchTTest, WindowedThreshold};
use prelora::manifest::Manifest;
use prelora::optim::{self, Optimizer as _};
use prelora::rank::assign_ranks;
use prelora::telemetry::{NormHistory, NormSnapshot};
use prelora::tensor::{clip_by_global_norm, Pcg64};
use prelora::util::bench::Bench;

fn synthetic_history(modules: &[&str], layers: usize, epochs: usize) -> NormHistory {
    let mut h = NormHistory::new();
    for e in 0..epochs {
        let mut by_module = BTreeMap::new();
        for m in modules {
            by_module.insert(
                m.to_string(),
                (0..layers).map(|l| 10.0 + 0.01 * e as f64 + l as f64).collect(),
            );
        }
        h.push(NormSnapshot { epoch: e, by_module }, 2.0 - 0.001 * e as f64);
    }
    h
}

fn main() {
    let mut b = Bench::new();
    let modules = ["query", "key", "value", "output", "dense"];

    // Algorithm 1 check over realistic history sizes
    let h = synthetic_history(&modules, 24, 300);
    let strat =
        WindowedThreshold::new(3, 3, 0.5, 2.5, modules.iter().map(|s| s.to_string()).collect());
    b.run("alg1_convergence_check_300ep", || {
        std::hint::black_box(strat.check(&h, 300));
    });
    let ttest = WelchTTest::new(3, 3, 0.05);
    b.run("welch_ttest_check_300ep", || {
        std::hint::black_box(ttest.check(&h, 300));
    });

    // Algorithm 2 over ViT-Large-like module/layer counts (5 x 24)
    let mut deltas = BTreeMap::new();
    let mut rng = Pcg64::new(1);
    for m in modules {
        deltas.insert(m.to_string(), (0..24).map(|_| rng.next_f64()).collect());
    }
    b.run("alg2_rank_assignment_5x24", || {
        std::hint::black_box(assign_ranks(&deltas, 8, 64));
    });

    // weight-norm snapshot on real manifests
    for name in ["vit-micro", "vit-small", "vit-base-sim"] {
        let dir = std::path::Path::new("artifacts").join(name);
        if let Ok(m) = Manifest::load(&dir) {
            let base = m.load_init_base().unwrap();
            b.run(&format!("norm_snapshot/{name}"), || {
                std::hint::black_box(NormSnapshot::measure(&m, 0, &base));
            });
        }
    }

    // optimizer + clipping on model-scale vectors
    for n in [800_000usize, 6_400_000] {
        let cfg = TrainConfig::default();
        let mut opt = optim::build(&cfg, n);
        let mut params = vec![0.1f32; n];
        let mut grads = vec![0.01f32; n];
        Pcg64::new(2).fill_normal(&mut grads, 0.01);
        b.run_units(&format!("adamw_step/{n}"), n as f64, || {
            opt.step(&mut params, &grads, 1e-3);
        });
        b.run_units(&format!("grad_clip/{n}"), n as f64, || {
            std::hint::black_box(clip_by_global_norm(&mut grads, 1.0));
        });
    }

    // controller-config plumbing (should be ~free)
    let pcfg = PreLoraConfig::default();
    b.run("prelora_config_validate", || {
        std::hint::black_box(pcfg.validate().is_ok());
    });

    b.write_csv("results/bench_controller.csv").unwrap();
}
