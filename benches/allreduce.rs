//! All-reduce algorithm comparison: naive vs tree vs ring across worker
//! counts and gradient sizes (the DP substrate ablation in DESIGN.md).
//!
//! Writes results/bench_allreduce.csv.

use prelora::dp::{reduce_mean, Algorithm};
use prelora::tensor::Pcg64;
use prelora::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    let mut rng = Pcg64::new(3);
    // gradient sizes: vit-small base (0.8M) and vit-base-sim (6.4M)
    for &len in &[811_664usize, 6_355_744] {
        for &workers in &[2usize, 4, 8] {
            let proto: Vec<Vec<f32>> = (0..workers)
                .map(|_| (0..len).map(|_| rng.next_f32()).collect())
                .collect();
            for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
                let mut bufs = proto.clone();
                b.run_units(
                    &format!("{alg:?}/w{workers}/n{len}"),
                    (len * workers) as f64,
                    || {
                        // reduce in place; buffers drift but stay finite and
                        // the arithmetic per iteration is identical
                        reduce_mean(alg, &mut bufs);
                    },
                );
            }
        }
    }
    b.write_csv("results/bench_allreduce.csv").unwrap();
}
