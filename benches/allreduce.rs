//! All-reduce algorithm comparison: naive vs tree vs ring across worker
//! counts and gradient sizes (the DP substrate ablation in DESIGN.md),
//! plus reduce-scatter vs full reduce — the ZeRO-2 hot-path question:
//! what does ending the reduce at the scattered layout (each worker keeps
//! only its owned chunk, nothing full-length materialized) save over
//! producing the replicated mean vector?
//!
//! The owned-buffer cases (`full_owned` / `scatter`) clone the input set
//! every iteration because `reduce_scatter` consumes its buffers (that
//! consumption *is* the ZeRO-2 free of the non-owned chunks), so compare
//! them against each other, not against the in-place `inplace` cases.
//!
//! Writes results/bench_allreduce.csv.

use prelora::dp::{reduce_mean, reduce_owned, reduce_scatter, Algorithm};
use prelora::tensor::Pcg64;
use prelora::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    let mut rng = Pcg64::new(3);
    // gradient sizes: vit-small base (0.8M) and vit-base-sim (6.4M)
    for &len in &[811_664usize, 6_355_744] {
        for &workers in &[2usize, 4, 8] {
            let proto: Vec<Vec<f32>> = (0..workers)
                .map(|_| (0..len).map(|_| rng.next_f32()).collect())
                .collect();
            let units = (len * workers) as f64;
            for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
                let mut bufs = proto.clone();
                b.run_units(&format!("{alg:?}/w{workers}/n{len}/inplace"), units, || {
                    // reduce in place; buffers drift but stay finite and
                    // the arithmetic per iteration is identical
                    reduce_mean(alg, &mut bufs);
                });
                // full reduce with the per-iteration clone both owned
                // cases pay (the replicated-output reference point)
                b.run_units(&format!("{alg:?}/w{workers}/n{len}/full_owned"), units, || {
                    let out = reduce_owned(alg, proto.clone()).unwrap();
                    std::hint::black_box(out.len());
                });
                // terminal reduce-scatter into one chunk per worker: the
                // ZeRO-2 hot-path op (genuinely scattered schedules for
                // naive/tree, gather-free ring)
                b.run_units(&format!("{alg:?}/w{workers}/n{len}/scatter"), units, || {
                    let chunks = reduce_scatter(alg, proto.clone(), workers).unwrap();
                    std::hint::black_box(chunks.len());
                });
            }
        }
    }
    b.write_csv("results/bench_allreduce.csv").unwrap();
}
