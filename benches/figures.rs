//! Figure-regeneration bench: miniature versions of every paper table and
//! figure on vit-micro, fast enough for `cargo bench`. The full-size
//! harnesses live in `examples/` (fig1_baseline, fig4_strictness,
//! fig5_warmup, fig7_resources); this bench proves the same machinery end
//! to end and prints the figure-shaped rows the paper reports.
//!
//! Writes results/bench_figures.csv.

use prelora::config::{RunConfig, StrictnessPreset};
use prelora::trainer::Trainer;
use prelora::util::bench::Bench;

fn micro_cfg(name: &str, epochs: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "vit-micro".into();
    cfg.run_name = name.into();
    cfg.train.epochs = epochs;
    cfg.train.data.train_samples = 192;
    cfg.train.data.val_samples = 64;
    cfg.train.eval_every = epochs; // eval once at the end
    cfg.prelora.windows = 2;
    cfg.prelora.window_epochs = 2;
    cfg.prelora.warmup_epochs = 2;
    cfg.prelora.tau = 6.0;
    cfg.prelora.zeta = 25.0;
    cfg
}

fn main() {
    let mut b = Bench::heavy();
    let epochs = 10;

    // Fig 1/3: baseline telemetry epoch (norm snapshots + loss tracking)
    {
        let mut cfg = micro_cfg("fig1", epochs);
        cfg.prelora.enabled = false;
        let mut t = Trainer::new(cfg).unwrap();
        b.run("fig1_baseline_epoch", || {
            t.run_epoch().unwrap();
        });
        let h = t.history();
        println!(
            "fig1 series: {} epochs, query norm {:.3} -> {:.3}, loss {:.3} -> {:.3}",
            h.epochs(),
            h.snapshot(0).module_mean("query").unwrap(),
            h.last().unwrap().module_mean("query").unwrap(),
            h.losses()[0],
            h.losses()[h.epochs() - 1],
        );
    }

    // Table 1 / Fig 4: one miniature cycle per strictness preset
    for preset in StrictnessPreset::all() {
        let label = format!("{preset:?}").to_lowercase();
        let mut cfg = micro_cfg(&label, epochs);
        let (tau, zeta) = preset.thresholds();
        cfg.prelora.tau = tau * 12.0; // micro-scaled as in examples/
        cfg.prelora.zeta = zeta * 12.0;
        b.run(&format!("fig4_cycle_{label}"), || {
            let mut t = Trainer::new(cfg.clone()).unwrap();
            for _ in 0..epochs {
                t.run_epoch().unwrap();
            }
            std::hint::black_box(t.summary());
        });
    }

    // Fig 5/6: warmup windows
    for w in [2usize, 4] {
        let mut cfg = micro_cfg(&format!("w{w}"), epochs);
        cfg.prelora.warmup_epochs = w;
        b.run(&format!("fig5_cycle_w{w}"), || {
            let mut t = Trainer::new(cfg.clone()).unwrap();
            for _ in 0..epochs {
                t.run_epoch().unwrap();
            }
            std::hint::black_box(t.lora_module_norm("query"));
        });
    }

    // Fig 7: resource ratios from one full PreLoRA cycle
    {
        let cfg = micro_cfg("fig7", 12);
        let mut t = Trainer::new(cfg).unwrap();
        for _ in 0..12 {
            t.run_epoch().unwrap();
        }
        let s = t.summary();
        println!(
            "fig7 rows: epoch_time_ratio={:?} throughput_ratio={:?} mem_saving={:?} trainable {} -> {:?}",
            s.epoch_time_ratio, s.throughput_ratio, s.memory_saving_frac,
            s.trainable_full, s.trainable_lora
        );
    }

    b.write_csv("results/bench_figures.csv").unwrap();
}
