//! Data-pipeline throughput: synthetic generation and batch gathering
//! must never bottleneck the step loop (DESIGN.md §Perf: coordinator
//! overhead < 10% of step time).
//!
//! Writes results/bench_data_gen.csv.

use prelora::data::{Dataset, EpochLoader, SynthSpec};
use prelora::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    for (name, size, n) in [("16px", 16usize, 512usize), ("32px", 32, 512)] {
        let spec = SynthSpec {
            samples: n,
            image_size: size,
            channels: 3,
            num_classes: 16,
            noise: 0.35,
            phase_jitter: true,
            seed: 5,
        };
        b.run_units(&format!("generate/{name}/{n}"), n as f64, || {
            std::hint::black_box(Dataset::generate(&spec));
        });
        let data = Dataset::generate(&spec);
        let loader = EpochLoader::new(16, 2, 0);
        let steps = loader.steps_per_epoch(&data);
        b.run_units(&format!("gather_epoch/{name}/{n}"), n as f64, || {
            for s in 0..steps {
                std::hint::black_box(loader.step_batches(&data, 1, s));
            }
        });
    }
    b.write_csv("results/bench_data_gen.csv").unwrap();
}
