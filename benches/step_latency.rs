//! Fig. 7 microbench: per-phase gradient-step latency on real artifacts.
//!
//! The paper's epoch-time/throughput gains come from the cheaper backward
//! pass after the base is frozen. This bench measures exactly that at the
//! step level: full_grads vs warmup_grads vs lora_grads vs eval, on every
//! model with built artifacts. Expect lora < full < warmup. Also measures
//! the staged pipeline vs the serial loop and the `dist::Strategy` sweep
//! (ZeRO off / stage 1 / stage 2 / stage 3 — same losses, per-rank
//! optimizer, gradient and parameter bytes shrinking stage by stage) and
//! the bucketed gradient-sync sweep (`epoch_bucketed_*`: same losses at
//! every bucket size, leader comm_wait dropping as buckets overlap).
//!
//! Writes results/bench_step_latency.csv and the CI artifact
//! results/BENCH_step_latency.json. `PRELORA_BENCH_SMOKE=1` runs one
//! iteration per case (CI smoke mode).

use std::sync::Arc;

use prelora::config::{PipelineConfig, TrainConfig};
use prelora::data::{Dataset, EpochLoader, SynthSpec};
use prelora::dist::{self, ZeroStage};
use prelora::dp::{Algorithm, BucketPlan, GradEngine, StepMode};
use prelora::manifest::{Manifest, ADAPTED_MODULES};
use prelora::optim::ShardedOptimizer;
use prelora::pipeline::{ModelState, StepPipeline, UpdateStage};
use prelora::rank::{build_adapter_cfg, uniform_ranks};
use prelora::tensor::Pcg64;
use prelora::trainer::MemoryBreakdown;
use prelora::util::bench::Bench;

fn bench_model(b: &mut Bench, name: &str) {
    let dir = std::path::Path::new("artifacts").join(name);
    let Ok(m) = Manifest::load(&dir) else {
        eprintln!("skipping {name}: no artifacts (run `make artifacts`)");
        return;
    };
    let m = Arc::new(m);
    let c = m.config.clone();
    let data = Dataset::generate(&SynthSpec {
        samples: c.batch_size * 4,
        image_size: c.image_size,
        channels: c.in_channels,
        num_classes: c.num_classes,
        noise: 0.3,
        phase_jitter: true,
        seed: 1,
    });
    let loader = EpochLoader::new(c.batch_size, 1, 0);
    let mut engine = GradEngine::new(m.clone(), 1, false, Algorithm::Naive).unwrap();
    let base = m.load_init_base().unwrap();
    let mut lora = vec![0.0f32; m.lora.size];
    Pcg64::new(7).fill_normal(&mut lora, 0.02);
    let modules: Vec<String> = ADAPTED_MODULES.iter().map(|s| s.to_string()).collect();
    let mid_rank = c.rank_buckets[c.rank_buckets.len() / 2];
    let assign = uniform_ranks(&modules, c.depth, mid_rank);
    let acfg = build_adapter_cfg(&m, &assign, c.lora_alpha).unwrap();
    let batches = loader.step_batches(&data, 0, 0);
    let bsz = c.batch_size as f64;

    b.run_units(&format!("{name}/full_grads"), bsz, || {
        engine
            .compute(StepMode::Full, &base, None, batches.clone())
            .unwrap();
    });
    b.run_units(&format!("{name}/warmup_grads"), bsz, || {
        engine
            .compute(StepMode::Warmup, &base, Some((&lora, &acfg.values)), batches.clone())
            .unwrap();
    });
    b.run_units(&format!("{name}/lora_grads"), bsz, || {
        engine
            .compute(StepMode::LoraOnly, &base, Some((&lora, &acfg.values)), batches.clone())
            .unwrap();
    });
    b.run_units(&format!("{name}/eval_full"), bsz, || {
        engine.evaluate(&base, None, batches.clone()).unwrap();
    });
}

/// Pipeline-on vs pipeline-off: one full-phase epoch at 2 threaded
/// workers through the staged engine vs the serial reference loop. The
/// overlap claim is that the pipelined per-step wall clock is <= the
/// sequential one (prefetch + deferred accounting hide the data and
/// bookkeeping work behind the workers' compute).
fn bench_pipeline(b: &mut Bench, name: &str) {
    let dir = std::path::Path::new("artifacts").join(name);
    let Ok(m) = Manifest::load(&dir) else {
        eprintln!("skipping {name} pipeline bench: no artifacts");
        return;
    };
    let m = Arc::new(m);
    let c = m.config.clone();
    let workers = 2;
    let epoch_steps = 4;
    let data = Arc::new(Dataset::generate(&SynthSpec {
        samples: c.batch_size * workers * epoch_steps,
        image_size: c.image_size,
        channels: c.in_channels,
        num_classes: c.num_classes,
        noise: 0.3,
        phase_jitter: true,
        seed: 2,
    }));
    let loader = EpochLoader::new(c.batch_size, workers, 0);
    let steps = loader.steps_per_epoch(&data);
    let mut engine = GradEngine::new(m.clone(), workers, true, Algorithm::Tree).unwrap();
    let tcfg = TrainConfig::default();
    let base = m.load_init_base().unwrap();
    let update = UpdateStage::new(tcfg.grad_clip);
    let units = (c.batch_size * workers * steps) as f64;
    let strategy =
        dist::strategy_for(ZeroStage::Off, workers, dist::collective_for(engine.algorithm()));
    let mut means = [0.0f64; 2];
    for enabled in [false, true] {
        let pcfg = PipelineConfig {
            enabled,
            prefetch_depth: 2,
            overlap_reduce: None,
            bucket_bytes: 0,
        };
        let mut pipe = StepPipeline::new(&pcfg, strategy.clone()).unwrap();
        let mut model = ModelState::new(
            strategy.park_params(base.clone()),
            strategy.optimizer(&tcfg, base.len()),
        );
        let label = format!(
            "{name}/epoch_pipeline_{}",
            if enabled { "on" } else { "off" }
        );
        let mean = b
            .run_units(&label, units, || {
                pipe.run_epoch(
                    &mut engine,
                    &loader,
                    &data,
                    &mut model,
                    &update,
                    StepMode::Full,
                    0,
                    steps,
                    1e-3,
                )
                .unwrap();
            })
            .mean;
        means[enabled as usize] = mean.as_secs_f64();
    }
    let [off, on] = means;
    println!(
        "{name}: per-step wall clock pipelined {:.3} ms vs sequential {:.3} ms ({:.2}x, expect <= 1 at {workers} workers)",
        on * 1e3 / steps as f64,
        off * 1e3 / steps as f64,
        on / off
    );
}

/// The `dist::Strategy` sweep — ZeRO off vs stages 1/2/3: one full-phase
/// epoch at 2 workers per strategy. The claim is the memory one, not a
/// speed one — losses are bit-identical across all four while per-worker
/// optimizer state (stages 1+), per-worker gradient bytes (stages 2+:
/// terminal reduce-scatter) and per-worker parameter bytes (stage 3:
/// owned partitions, per-step gathered view) drop to ~1/workers
/// (chunk-rounded). The per-rank `MemoryBreakdown` numbers are asserted
/// and exported as bench metadata for the CI regression gate
/// (`scripts/bench_gate.py`).
fn bench_zero(b: &mut Bench, name: &str) {
    let dir = std::path::Path::new("artifacts").join(name);
    let Ok(m) = Manifest::load(&dir) else {
        eprintln!("skipping {name} zero bench: no artifacts");
        return;
    };
    let m = Arc::new(m);
    let c = m.config.clone();
    let workers = 2;
    let epoch_steps = 4;
    let data = Arc::new(Dataset::generate(&SynthSpec {
        samples: c.batch_size * workers * epoch_steps,
        image_size: c.image_size,
        channels: c.in_channels,
        num_classes: c.num_classes,
        noise: 0.3,
        phase_jitter: true,
        seed: 3,
    }));
    let loader = EpochLoader::new(c.batch_size, workers, 0);
    let steps = loader.steps_per_epoch(&data);
    let mut engine = GradEngine::new(m.clone(), workers, true, Algorithm::Ring).unwrap();
    let mut tcfg = TrainConfig::default();
    tcfg.dp.workers = workers;
    let base = m.load_init_base().unwrap();
    let update = UpdateStage::new(tcfg.grad_clip);
    let units = (c.batch_size * workers * steps) as f64;
    let stages = [ZeroStage::Off, ZeroStage::Zero1, ZeroStage::Zero2, ZeroStage::Zero3];
    let mut losses = [0.0f64; 4];
    for (i, stage) in stages.into_iter().enumerate() {
        let strategy =
            dist::strategy_for(stage, workers, dist::collective_for(engine.algorithm()));
        let pcfg = PipelineConfig {
            enabled: true,
            prefetch_depth: 2,
            overlap_reduce: None,
            bucket_bytes: 0,
        };
        let mut pipe = StepPipeline::new(&pcfg, strategy.clone()).unwrap();
        let label = match stage {
            ZeroStage::Off => format!("{name}/epoch_zero_off"),
            s => format!("{name}/epoch_zero_stage{s}"),
        };
        let mut last_loss = 0.0f64;
        b.run_units(&label, units, || {
            // fresh model per iteration: epoch 0 from init every mode, so
            // the recorded losses are directly comparable
            let mut model = ModelState::new(
                strategy.park_params(base.clone()),
                strategy.optimizer(&tcfg, base.len()),
            );
            let run = pipe
                .run_epoch(
                    &mut engine,
                    &loader,
                    &data,
                    &mut model,
                    &update,
                    StepMode::Full,
                    0,
                    steps,
                    1e-3,
                )
                .unwrap();
            last_loss = run.loss_sum;
        });
        losses[i] = last_loss;
    }
    for (i, stage) in stages.iter().enumerate().skip(1) {
        assert_eq!(losses[i], losses[0], "{name}: ZeRO stage {stage} changed the losses");
    }
    let opt_total = ShardedOptimizer::new(&tcfg, base.len(), 1).state_bytes();
    let opt_per = ShardedOptimizer::new(&tcfg, base.len(), workers).per_worker_state_bytes();
    // Measure the layouts the actual strategies produce — one explicit
    // step through the stage-2 terminal reduce-scatter and the stage-3
    // parked parameter store — rather than asserting a formula against
    // itself: if the strategy ever stopped scattering, these would fail.
    let z2 = dist::strategy_for(
        ZeroStage::Zero2,
        workers,
        dist::collective_for(engine.algorithm()),
    );
    engine
        .submit(StepMode::Full, &base, None, loader.step_batches(&data, 0, 0))
        .unwrap();
    let measured = z2.reduce_step(engine.collect().unwrap());
    let grad_per = measured.grad_bytes_per_rank();
    let grad_total = measured.grad_total_bytes();
    assert_eq!(grad_total, base.len() * 4, "{name}: full gradient footprint");
    assert_eq!(
        grad_per,
        base.len().div_ceil(workers) * 4,
        "{name}: measured per-rank bytes must equal the partition formula \
         (the baseline.json metadata relies on it)"
    );
    let z3 = dist::strategy_for(
        ZeroStage::Zero3,
        workers,
        dist::collective_for(engine.algorithm()),
    );
    let parked = z3.park_params(base.clone());
    let param_per = parked.per_rank_elems() * 4;
    assert_eq!(
        param_per,
        base.len().div_ceil(workers) * 4,
        "{name}: stage-3 per-rank parameter bytes must equal the partition formula"
    );
    // the reported per-rank accounting, built from the measured layouts
    let mem = MemoryBreakdown::new(
        base.len(),
        m.lora.size,
        base.len(),
        (base.len() + m.lora.size) * 4,
        grad_per,
        grad_total,
        opt_per,
        opt_total,
    );
    println!(
        "{name}: zero off/s1/s2/s3 epoch loss {} / {} / {} / {} ({}), opt {} B vs {} B/worker, grads {} B vs {} B/rank, params {} B vs {} B/rank (expect ~1/{workers})",
        losses[0],
        losses[1],
        losses[2],
        losses[3],
        if losses.iter().all(|&l| l == losses[0]) {
            "bit-identical"
        } else {
            "MISMATCH"
        },
        opt_total,
        mem.optimizer_bytes,
        mem.grad_total_bytes,
        mem.grad_bytes,
        base.len() * 4,
        param_per,
    );
    assert!(
        opt_per as f64 <= opt_total as f64 / workers as f64 + 16.0,
        "{name}: per-worker optimizer state did not shrink to ~1/{workers}"
    );
    // the ZeRO-2 acceptance claim: grad_bytes per rank ~ grad_total / N
    assert!(
        mem.grad_bytes as f64 <= mem.grad_total_bytes as f64 / workers as f64 + 8.0,
        "{name}: per-rank gradient bytes {} did not shrink to ~1/{workers} of {}",
        mem.grad_bytes,
        mem.grad_total_bytes,
    );
    assert!(mem.grad_bytes > 0, "{name}: gradient accounting vanished");
    // the ZeRO-3 acceptance claim: param bytes per rank ~ param_total / N
    assert!(
        param_per as f64 <= (base.len() * 4) as f64 / workers as f64 + 8.0,
        "{name}: per-rank parameter bytes {param_per} did not shrink to ~1/{workers}"
    );
}

/// Bucketed gradient sync sweep: one full-phase epoch at 2 threaded
/// workers per bucket size, whole-buffer (`bucket_bytes = 0`) first. The
/// bit contract is asserted — every bucket size produces the identical
/// epoch loss — and the overlap claim is reported: the leader's
/// `comm_wait_s` should drop once early buckets reduce on the
/// accumulator thread while later backward slices still compute.
fn bench_bucketed(b: &mut Bench, name: &str) {
    let dir = std::path::Path::new("artifacts").join(name);
    let Ok(m) = Manifest::load(&dir) else {
        eprintln!("skipping {name} bucketed bench: no artifacts");
        return;
    };
    let m = Arc::new(m);
    let c = m.config.clone();
    let workers = 2;
    let epoch_steps = 4;
    let data = Arc::new(Dataset::generate(&SynthSpec {
        samples: c.batch_size * workers * epoch_steps,
        image_size: c.image_size,
        channels: c.in_channels,
        num_classes: c.num_classes,
        noise: 0.3,
        phase_jitter: true,
        seed: 4,
    }));
    let loader = EpochLoader::new(c.batch_size, workers, 0);
    let steps = loader.steps_per_epoch(&data);
    let mut engine = GradEngine::new(m.clone(), workers, true, Algorithm::Ring).unwrap();
    let tcfg = TrainConfig::default();
    let base = m.load_init_base().unwrap();
    let update = UpdateStage::new(tcfg.grad_clip);
    let units = (c.batch_size * workers * steps) as f64;
    let strategy =
        dist::strategy_for(ZeroStage::Off, workers, dist::collective_for(engine.algorithm()));
    let sweep = BUCKET_SWEEP;
    let mut losses = [0.0f64; BUCKET_SWEEP.len()];
    let mut waits = [0.0f64; BUCKET_SWEEP.len()];
    for (i, &bytes) in sweep.iter().enumerate() {
        let pcfg = PipelineConfig {
            enabled: true,
            prefetch_depth: 2,
            overlap_reduce: None,
            bucket_bytes: bytes,
        };
        let mut pipe = StepPipeline::new(&pcfg, strategy.clone()).unwrap();
        let label = if bytes == 0 {
            format!("{name}/epoch_bucketed_off")
        } else {
            format!("{name}/epoch_bucketed_{bytes}")
        };
        let mut last_loss = 0.0f64;
        let mut wait_sum = 0.0f64;
        let mut iters = 0usize;
        b.run_units(&label, units, || {
            // fresh model per iteration: epoch 0 from init every time, so
            // the recorded losses are directly comparable
            let mut model = ModelState::new(
                strategy.park_params(base.clone()),
                strategy.optimizer(&tcfg, base.len()),
            );
            let run = pipe
                .run_epoch(
                    &mut engine,
                    &loader,
                    &data,
                    &mut model,
                    &update,
                    StepMode::Full,
                    0,
                    steps,
                    1e-3,
                )
                .unwrap();
            last_loss = run.loss_sum;
            wait_sum += run.comm_wait_s;
            iters += 1;
        });
        losses[i] = last_loss;
        waits[i] = wait_sum / iters.max(1) as f64;
    }
    for (i, &bytes) in sweep.iter().enumerate().skip(1) {
        assert_eq!(
            losses[i], losses[0],
            "{name}: bucket_bytes = {bytes} changed the epoch loss (must be bitwise the \
             whole-buffer sync's)"
        );
    }
    let fmt: Vec<String> = sweep
        .iter()
        .zip(&waits)
        .map(|(&bytes, &w)| format!("{bytes}B: {:.3} ms", w * 1e3))
        .collect();
    println!(
        "{name}: losses bit-identical across the bucket sweep; epoch comm_wait [{}] (expect \
         bucketed < whole-buffer at {workers} workers)",
        fmt.join(", ")
    );
}

/// The bucket sizes `bench_bucketed` sweeps (0 = whole-buffer reference).
const BUCKET_SWEEP: [usize; 3] = [0, 4096, 16384];

/// The tcp transport over loopback: one full-phase epoch with two
/// `TcpEndpoint` ranks in this process (each driving its own 1-worker
/// engine + pipeline, exactly the per-process layout `--dist tcp` runs),
/// timed against an in-memory 2-worker reference epoch. The bit contract
/// is asserted — the wire epoch's loss must equal the in-memory one —
/// and rank 0's `comm_wait_s` (time blocked on the wire reduce + scalar
/// exchange) is returned for the bench metadata.
fn bench_tcp(b: &mut Bench, name: &str) -> Option<f64> {
    let dir = std::path::Path::new("artifacts").join(name);
    let Ok(m) = Manifest::load(&dir) else {
        eprintln!("skipping {name} tcp bench: no artifacts");
        return None;
    };
    let m = Arc::new(m);
    let c = m.config.clone();
    let ranks = 2;
    let epoch_steps = 4;
    let data = Arc::new(Dataset::generate(&SynthSpec {
        samples: c.batch_size * ranks * epoch_steps,
        image_size: c.image_size,
        channels: c.in_channels,
        num_classes: c.num_classes,
        noise: 0.3,
        phase_jitter: true,
        seed: 5,
    }));
    let loader = EpochLoader::new(c.batch_size, ranks, 0);
    let steps = loader.steps_per_epoch(&data);
    let tcfg = TrainConfig::default();
    let base = m.load_init_base().unwrap();
    let update = UpdateStage::new(tcfg.grad_clip);
    let units = (c.batch_size * ranks * steps) as f64;
    let pcfg = PipelineConfig {
        enabled: true,
        prefetch_depth: 2,
        overlap_reduce: None,
        bucket_bytes: 0,
    };

    // in-memory reference: the same epoch at 2 simulated workers
    let mut ref_engine = GradEngine::new(m.clone(), ranks, true, Algorithm::Ring).unwrap();
    let ref_strategy =
        dist::strategy_for(ZeroStage::Off, ranks, dist::collective_for(ref_engine.algorithm()));
    let mut ref_pipe = StepPipeline::new(&pcfg, ref_strategy.clone()).unwrap();
    let mut ref_model = ModelState::new(
        ref_strategy.park_params(base.clone()),
        ref_strategy.optimizer(&tcfg, base.len()),
    );
    let want_loss = ref_pipe
        .run_epoch(
            &mut ref_engine,
            &loader,
            &data,
            &mut ref_model,
            &update,
            StepMode::Full,
            0,
            steps,
            1e-3,
        )
        .unwrap()
        .loss_sum;

    // two tcp ranks over loopback, in-process (rank 1's peer entry is
    // identity only — leaves dial peers[0])
    let addr = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let peers = vec![addr, "127.0.0.1:1".to_string()];
    let timeout = std::time::Duration::from_secs(30);
    let p0 = peers.clone();
    let root_ep =
        std::thread::spawn(move || dist::TcpEndpoint::connect(Algorithm::Ring, 0, &p0, timeout));
    let leaf_ep = dist::TcpEndpoint::connect(Algorithm::Ring, 1, &peers, timeout).unwrap();
    let root_ep = root_ep.join().unwrap().unwrap();

    let mut rank_state = [root_ep, leaf_ep].map(|ep| {
        let col: Arc<dyn dist::Collective> = Arc::new(dist::EndpointCollective::new(ep));
        let strategy = dist::strategy_for(ZeroStage::Off, ranks, col);
        let engine = GradEngine::new(m.clone(), 1, false, Algorithm::Ring).unwrap();
        let pipe = StepPipeline::new(&pcfg, strategy.clone()).unwrap();
        (engine, pipe, strategy)
    });
    let [root, leaf] = &mut rank_state;

    let mut last_loss = 0.0f64;
    let mut wait_sum = 0.0f64;
    let mut iters = 0usize;
    b.run_units(&format!("{name}/epoch_tcp_loopback"), units, || {
        // fresh model per rank per iteration: epoch 0 from init, so the
        // loss is comparable to the reference and the op sequence is
        // identical every iteration (lockstep across ranks)
        std::thread::scope(|s| {
            let (engine, pipe, strategy) = leaf;
            let mut model = ModelState::new(
                strategy.park_params(base.clone()),
                strategy.optimizer(&tcfg, base.len()),
            );
            let loader = &loader;
            let data = &data;
            let update = &update;
            s.spawn(move || {
                pipe.run_epoch(
                    engine,
                    loader,
                    data,
                    &mut model,
                    update,
                    StepMode::Full,
                    0,
                    steps,
                    1e-3,
                )
                .unwrap();
            });
            let (engine, pipe, strategy) = root;
            let mut model = ModelState::new(
                strategy.park_params(base.clone()),
                strategy.optimizer(&tcfg, base.len()),
            );
            let run = pipe
                .run_epoch(engine, loader, data, &mut model, update, StepMode::Full, 0, steps, 1e-3)
                .unwrap();
            last_loss = run.loss_sum;
            wait_sum += run.comm_wait_s;
        });
        iters += 1;
    });
    assert_eq!(
        last_loss, want_loss,
        "{name}: the tcp-loopback epoch loss must be bitwise the in-memory 2-worker epoch's"
    );
    let wait = wait_sum / iters.max(1) as f64;
    println!(
        "{name}: tcp loopback epoch loss bit-identical to in-memory; rank-0 comm_wait {:.3} ms/epoch",
        wait * 1e3
    );
    Some(wait)
}

fn main() {
    // fault injection (train.faults) is an adversity-testing knob; a
    // benched step must never carry an armed plan, or the gated numbers
    // would measure the faults instead of the pipeline
    assert!(
        !TrainConfig::default().faults.is_enabled(),
        "benches must run with train.faults disabled"
    );
    let smoke = std::env::var("PRELORA_BENCH_SMOKE").is_ok_and(|v| v == "1");
    let mut b = if smoke { Bench::smoke() } else { Bench::heavy() };
    // PRELORA_BENCH_MODELS=vit-small,... restricts the sweep
    let models = std::env::var("PRELORA_BENCH_MODELS")
        .unwrap_or_else(|_| "vit-micro,vit-small,vit-base-sim".into());
    let mut tcp_waits: Vec<(String, f64)> = Vec::new();
    for model in models.split(',') {
        bench_model(&mut b, model);
        bench_pipeline(&mut b, model);
        bench_zero(&mut b, model);
        bench_bucketed(&mut b, model);
        if let Some(wait) = bench_tcp(&mut b, model) {
            tcp_waits.push((model.to_string(), wait));
        }
    }
    b.write_csv("results/bench_step_latency.csv").unwrap();
    let mut meta: Vec<(&str, String)> = vec![
        ("bench", "step_latency".to_string()),
        ("mode", if smoke { "smoke" } else { "full" }.to_string()),
        ("models", models.clone()),
    ];
    // deterministic memory metadata for the CI regression gate: the
    // per-rank vs total grad/opt/param bytes of a 2-worker vit-micro run
    // under ZeRO stages 2 and 3 (scripts/bench_gate.py compares them
    // exactly against the baseline)
    if let Ok(m) = Manifest::load(std::path::Path::new("artifacts").join("vit-micro")) {
        let workers = 2usize;
        let tcfg = TrainConfig::default();
        let opt_total = ShardedOptimizer::new(&tcfg, m.base.size, 1).state_bytes();
        let opt_per = ShardedOptimizer::new(&tcfg, m.base.size, workers).per_worker_state_bytes();
        meta.push(("zero_workers", workers.to_string()));
        meta.push((
            "zero2_grad_bytes_per_rank",
            (m.base.size.div_ceil(workers) * 4).to_string(),
        ));
        meta.push(("zero_grad_total_bytes", (m.base.size * 4).to_string()));
        meta.push((
            "zero3_param_bytes_per_rank",
            (m.base.size.div_ceil(workers) * 4).to_string(),
        ));
        meta.push(("zero_param_total_bytes", (m.base.size * 4).to_string()));
        meta.push(("zero_opt_bytes_per_worker", opt_per.to_string()));
        meta.push(("zero_opt_total_bytes", opt_total.to_string()));
        // the bucketed-sync sweep's layout: space size and per-size bucket
        // counts for the unsharded (parts = 1) epoch cases — deterministic
        // functions of the manifest, compared exactly by the gate
        meta.push(("bucketed_workers", workers.to_string()));
        meta.push(("bucketed_grad_space_bytes", (m.base.size * 4).to_string()));
        meta.push((
            "bucketed_4096_bucket_count",
            BucketPlan::derive(m.base.size, 1, 4096).count().to_string(),
        ));
        meta.push((
            "bucketed_16384_bucket_count",
            BucketPlan::derive(m.base.size, 1, 16384).count().to_string(),
        ));
        // the tcp transport's deterministic wire contract: group size and
        // the fixed per-frame overhead (length prefix + version + kind +
        // rank + seq + CRC around an empty payload) — gated exactly
        meta.push(("tcp_loopback_ranks", "2".to_string()));
        let empty = dist::net::Frame {
            kind: dist::net::FrameKind::Op,
            rank: 0,
            seq: 1,
            payload: Vec::new(),
        };
        meta.push(("tcp_frame_overhead_bytes", empty.encode().len().to_string()));
    }
    // rank-0 wire wait per epoch — timing telemetry next to the gated
    // latency case, not itself a deterministic gate
    let tcp_wait_meta: Vec<(String, String)> = tcp_waits
        .iter()
        .map(|(model, wait)| (format!("tcp_comm_wait_s_{model}"), format!("{wait:.6}")))
        .collect();
    for (k, v) in &tcp_wait_meta {
        meta.push((k.as_str(), v.clone()));
    }
    b.write_json("results/BENCH_step_latency.json", &meta).unwrap();
    // Fig. 7 shape assertion: the frozen-base step must beat the full step
    // on every model where both ran.
    let r = b.results();
    for model in models.split(',') {
        let get = |suffix: &str| {
            r.iter()
                .find(|m| m.name == format!("{model}/{suffix}"))
                .map(|m| m.mean.as_secs_f64())
        };
        if let (Some(full), Some(lora)) = (get("full_grads"), get("lora_grads")) {
            println!(
                "{model}: lora step / full step = {:.3} (expect < 1)",
                lora / full
            );
        }
    }
}
