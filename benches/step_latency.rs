//! Fig. 7 microbench: per-phase gradient-step latency on real artifacts.
//!
//! The paper's epoch-time/throughput gains come from the cheaper backward
//! pass after the base is frozen. This bench measures exactly that at the
//! step level: full_grads vs warmup_grads vs lora_grads vs eval, on every
//! model with built artifacts. Expect lora < full < warmup. Also measures
//! the staged pipeline vs the serial loop and ZeRO-1 optimizer-state
//! sharding on vs off (same losses, ~1/N per-worker state).
//!
//! Writes results/bench_step_latency.csv and the CI artifact
//! results/BENCH_step_latency.json. `PRELORA_BENCH_SMOKE=1` runs one
//! iteration per case (CI smoke mode).

use std::sync::Arc;

use prelora::config::{PipelineConfig, TrainConfig};
use prelora::data::{Dataset, EpochLoader, SynthSpec};
use prelora::dp::{Algorithm, GradEngine, StepMode};
use prelora::manifest::{Manifest, ADAPTED_MODULES};
use prelora::optim::ShardedOptimizer;
use prelora::pipeline::{ModelState, StepPipeline, UpdateStage};
use prelora::rank::{build_adapter_cfg, uniform_ranks};
use prelora::tensor::Pcg64;
use prelora::util::bench::Bench;

fn bench_model(b: &mut Bench, name: &str) {
    let dir = std::path::Path::new("artifacts").join(name);
    let Ok(m) = Manifest::load(&dir) else {
        eprintln!("skipping {name}: no artifacts (run `make artifacts`)");
        return;
    };
    let m = Arc::new(m);
    let c = m.config.clone();
    let data = Dataset::generate(&SynthSpec {
        samples: c.batch_size * 4,
        image_size: c.image_size,
        channels: c.in_channels,
        num_classes: c.num_classes,
        noise: 0.3,
        phase_jitter: true,
        seed: 1,
    });
    let loader = EpochLoader::new(c.batch_size, 1, 0);
    let mut engine = GradEngine::new(m.clone(), 1, false, Algorithm::Naive).unwrap();
    let base = m.load_init_base().unwrap();
    let mut lora = vec![0.0f32; m.lora.size];
    Pcg64::new(7).fill_normal(&mut lora, 0.02);
    let modules: Vec<String> = ADAPTED_MODULES.iter().map(|s| s.to_string()).collect();
    let mid_rank = c.rank_buckets[c.rank_buckets.len() / 2];
    let assign = uniform_ranks(&modules, c.depth, mid_rank);
    let acfg = build_adapter_cfg(&m, &assign, c.lora_alpha).unwrap();
    let batches = loader.step_batches(&data, 0, 0);
    let bsz = c.batch_size as f64;

    b.run_units(&format!("{name}/full_grads"), bsz, || {
        engine
            .compute(StepMode::Full, &base, None, batches.clone())
            .unwrap();
    });
    b.run_units(&format!("{name}/warmup_grads"), bsz, || {
        engine
            .compute(StepMode::Warmup, &base, Some((&lora, &acfg.values)), batches.clone())
            .unwrap();
    });
    b.run_units(&format!("{name}/lora_grads"), bsz, || {
        engine
            .compute(StepMode::LoraOnly, &base, Some((&lora, &acfg.values)), batches.clone())
            .unwrap();
    });
    b.run_units(&format!("{name}/eval_full"), bsz, || {
        engine.evaluate(&base, None, batches.clone()).unwrap();
    });
}

/// Pipeline-on vs pipeline-off: one full-phase epoch at 2 threaded
/// workers through the staged engine vs the serial reference loop. The
/// overlap claim is that the pipelined per-step wall clock is <= the
/// sequential one (prefetch + deferred accounting hide the data and
/// bookkeeping work behind the workers' compute).
fn bench_pipeline(b: &mut Bench, name: &str) {
    let dir = std::path::Path::new("artifacts").join(name);
    let Ok(m) = Manifest::load(&dir) else {
        eprintln!("skipping {name} pipeline bench: no artifacts");
        return;
    };
    let m = Arc::new(m);
    let c = m.config.clone();
    let workers = 2;
    let epoch_steps = 4;
    let data = Arc::new(Dataset::generate(&SynthSpec {
        samples: c.batch_size * workers * epoch_steps,
        image_size: c.image_size,
        channels: c.in_channels,
        num_classes: c.num_classes,
        noise: 0.3,
        phase_jitter: true,
        seed: 2,
    }));
    let loader = EpochLoader::new(c.batch_size, workers, 0);
    let steps = loader.steps_per_epoch(&data);
    let mut engine = GradEngine::new(m.clone(), workers, true, Algorithm::Tree).unwrap();
    let tcfg = TrainConfig::default();
    let base = m.load_init_base().unwrap();
    let update = UpdateStage::new(tcfg.grad_clip);
    let units = (c.batch_size * workers * steps) as f64;
    let mut means = [0.0f64; 2];
    for enabled in [false, true] {
        let pcfg = PipelineConfig { enabled, prefetch_depth: 2, overlap_reduce: true };
        let mut pipe = StepPipeline::new(&pcfg, engine.algorithm(), 1).unwrap();
        let mut model =
            ModelState::new(base.clone(), ShardedOptimizer::new(&tcfg, base.len(), 1));
        let label = format!(
            "{name}/epoch_pipeline_{}",
            if enabled { "on" } else { "off" }
        );
        let mean = b
            .run_units(&label, units, || {
                pipe.run_epoch(
                    &mut engine,
                    &loader,
                    &data,
                    &mut model,
                    &update,
                    StepMode::Full,
                    0,
                    steps,
                    1e-3,
                )
                .unwrap();
            })
            .mean;
        means[enabled as usize] = mean.as_secs_f64();
    }
    let [off, on] = means;
    println!(
        "{name}: per-step wall clock pipelined {:.3} ms vs sequential {:.3} ms ({:.2}x, expect <= 1 at {workers} workers)",
        on * 1e3 / steps as f64,
        off * 1e3 / steps as f64,
        on / off
    );
}

/// ZeRO-1 on vs off: one full-phase epoch at 2 workers. The claim is the
/// memory one, not a speed one — losses are bit-identical while the
/// per-worker optimizer state drops to ~1/workers (chunk-rounded).
fn bench_zero(b: &mut Bench, name: &str) {
    let dir = std::path::Path::new("artifacts").join(name);
    let Ok(m) = Manifest::load(&dir) else {
        eprintln!("skipping {name} zero bench: no artifacts");
        return;
    };
    let m = Arc::new(m);
    let c = m.config.clone();
    let workers = 2;
    let epoch_steps = 4;
    let data = Arc::new(Dataset::generate(&SynthSpec {
        samples: c.batch_size * workers * epoch_steps,
        image_size: c.image_size,
        channels: c.in_channels,
        num_classes: c.num_classes,
        noise: 0.3,
        phase_jitter: true,
        seed: 3,
    }));
    let loader = EpochLoader::new(c.batch_size, workers, 0);
    let steps = loader.steps_per_epoch(&data);
    let mut engine = GradEngine::new(m.clone(), workers, true, Algorithm::Ring).unwrap();
    let mut tcfg = TrainConfig::default();
    tcfg.dp.workers = workers;
    let base = m.load_init_base().unwrap();
    let update = UpdateStage::new(tcfg.grad_clip);
    let units = (c.batch_size * workers * steps) as f64;
    let mut losses = [0.0f64; 2];
    for zero in [false, true] {
        tcfg.zero.enabled = zero;
        let shards = tcfg.zero_shards();
        let pcfg = PipelineConfig { enabled: true, prefetch_depth: 2, overlap_reduce: true };
        let mut pipe = StepPipeline::new(&pcfg, engine.algorithm(), shards).unwrap();
        let label = format!("{name}/epoch_zero_{}", if zero { "on" } else { "off" });
        let mut last_loss = 0.0f64;
        b.run_units(&label, units, || {
            // fresh model per iteration: epoch 0 from init both ways, so
            // the recorded losses are directly comparable
            let mut model =
                ModelState::new(base.clone(), ShardedOptimizer::new(&tcfg, base.len(), shards));
            let run = pipe
                .run_epoch(
                    &mut engine,
                    &loader,
                    &data,
                    &mut model,
                    &update,
                    StepMode::Full,
                    0,
                    steps,
                    1e-3,
                )
                .unwrap();
            last_loss = run.loss_sum;
        });
        losses[zero as usize] = last_loss;
    }
    let total = ShardedOptimizer::new(&tcfg, base.len(), 1).state_bytes();
    let per_worker = ShardedOptimizer::new(&tcfg, base.len(), workers).per_worker_state_bytes();
    println!(
        "{name}: zero on/off epoch loss {} vs {} ({}), per-worker opt state {} B vs {} B ({:.3}x, expect ~1/{workers})",
        losses[1],
        losses[0],
        if losses[1] == losses[0] { "bit-identical" } else { "MISMATCH" },
        per_worker,
        total,
        per_worker as f64 / total as f64,
    );
    assert_eq!(losses[1], losses[0], "{name}: ZeRO changed the losses");
    assert!(
        per_worker as f64 <= total as f64 / workers as f64 + 16.0,
        "{name}: per-worker optimizer state did not shrink to ~1/{workers}"
    );
}

fn main() {
    let smoke = std::env::var("PRELORA_BENCH_SMOKE").is_ok_and(|v| v == "1");
    let mut b = if smoke { Bench::smoke() } else { Bench::heavy() };
    // PRELORA_BENCH_MODELS=vit-small,... restricts the sweep
    let models = std::env::var("PRELORA_BENCH_MODELS")
        .unwrap_or_else(|_| "vit-micro,vit-small,vit-base-sim".into());
    for model in models.split(',') {
        bench_model(&mut b, model);
        bench_pipeline(&mut b, model);
        bench_zero(&mut b, model);
    }
    b.write_csv("results/bench_step_latency.csv").unwrap();
    b.write_json(
        "results/BENCH_step_latency.json",
        &[
            ("bench", "step_latency".to_string()),
            ("mode", if smoke { "smoke" } else { "full" }.to_string()),
            ("models", models.clone()),
        ],
    )
    .unwrap();
    // Fig. 7 shape assertion: the frozen-base step must beat the full step
    // on every model where both ran.
    let r = b.results();
    for model in models.split(',') {
        let get = |suffix: &str| {
            r.iter()
                .find(|m| m.name == format!("{model}/{suffix}"))
                .map(|m| m.mean.as_secs_f64())
        };
        if let (Some(full), Some(lora)) = (get("full_grads"), get("lora_grads")) {
            println!(
                "{model}: lora step / full step = {:.3} (expect < 1)",
                lora / full
            );
        }
    }
}
