//! Fig. 7 microbench: per-phase gradient-step latency on real artifacts.
//!
//! The paper's epoch-time/throughput gains come from the cheaper backward
//! pass after the base is frozen. This bench measures exactly that at the
//! step level: full_grads vs warmup_grads vs lora_grads vs eval, on every
//! model with built artifacts. Expect lora < full < warmup.
//!
//! Writes results/bench_step_latency.csv.

use std::sync::Arc;

use prelora::data::{Dataset, EpochLoader, SynthSpec};
use prelora::dp::{Algorithm, GradEngine, StepMode};
use prelora::manifest::{Manifest, ADAPTED_MODULES};
use prelora::rank::{build_adapter_cfg, uniform_ranks};
use prelora::tensor::Pcg64;
use prelora::util::bench::Bench;

fn bench_model(b: &mut Bench, name: &str) {
    let dir = std::path::Path::new("artifacts").join(name);
    let Ok(m) = Manifest::load(&dir) else {
        eprintln!("skipping {name}: no artifacts (run `make artifacts`)");
        return;
    };
    let m = Arc::new(m);
    let c = m.config.clone();
    let data = Dataset::generate(&SynthSpec {
        samples: c.batch_size * 4,
        image_size: c.image_size,
        channels: c.in_channels,
        num_classes: c.num_classes,
        noise: 0.3,
        phase_jitter: true,
        seed: 1,
    });
    let loader = EpochLoader::new(c.batch_size, 1, 0);
    let mut engine = GradEngine::new(m.clone(), 1, false, Algorithm::Naive).unwrap();
    let base = m.load_init_base().unwrap();
    let mut lora = vec![0.0f32; m.lora.size];
    Pcg64::new(7).fill_normal(&mut lora, 0.02);
    let modules: Vec<String> = ADAPTED_MODULES.iter().map(|s| s.to_string()).collect();
    let mid_rank = c.rank_buckets[c.rank_buckets.len() / 2];
    let assign = uniform_ranks(&modules, c.depth, mid_rank);
    let acfg = build_adapter_cfg(&m, &assign, c.lora_alpha).unwrap();
    let batches = loader.step_batches(&data, 0, 0);
    let bsz = c.batch_size as f64;

    b.run_units(&format!("{name}/full_grads"), bsz, || {
        engine
            .compute(StepMode::Full, &base, None, batches.clone())
            .unwrap();
    });
    b.run_units(&format!("{name}/warmup_grads"), bsz, || {
        engine
            .compute(StepMode::Warmup, &base, Some((&lora, &acfg.values)), batches.clone())
            .unwrap();
    });
    b.run_units(&format!("{name}/lora_grads"), bsz, || {
        engine
            .compute(StepMode::LoraOnly, &base, Some((&lora, &acfg.values)), batches.clone())
            .unwrap();
    });
    b.run_units(&format!("{name}/eval_full"), bsz, || {
        engine.evaluate(&base, None, batches.clone()).unwrap();
    });
}

fn main() {
    let mut b = Bench::heavy();
    // PRELORA_BENCH_MODELS=vit-small,... restricts the sweep
    let models = std::env::var("PRELORA_BENCH_MODELS")
        .unwrap_or_else(|_| "vit-micro,vit-small,vit-base-sim".into());
    for model in models.split(',') {
        bench_model(&mut b, model);
    }
    b.write_csv("results/bench_step_latency.csv").unwrap();
    // Fig. 7 shape assertion: the frozen-base step must beat the full step
    // on every model where both ran.
    let r = b.results();
    for model in models.split(',') {
        let get = |suffix: &str| {
            r.iter()
                .find(|m| m.name == format!("{model}/{suffix}"))
                .map(|m| m.mean.as_secs_f64())
        };
        if let (Some(full), Some(lora)) = (get("full_grads"), get("lora_grads")) {
            println!(
                "{model}: lora step / full step = {:.3} (expect < 1)",
                lora / full
            );
        }
    }
}
