//! Shutdown-path tests: thread lifecycle and loud failure.
//!
//! The bucket-sync protocol's liveness properties are model-checked
//! exhaustively in `tests/loom_bucket.rs`; these tests pin the same
//! properties against the real runtime — a panicking compute worker must
//! fail the epoch with a contextful error instead of hanging the leader,
//! and tearing the trainer down (the engine's workers, the reduce stage's
//! accumulator, the prefetcher) must leave no live threads behind.
//!
//! Thread accounting reads `/proc/self/task/*/comm`, so those tests are
//! Linux-only and serialize on a file-local mutex (the default test
//! harness runs tests concurrently in one process).
//!
//! Requires `make artifacts` (vit-micro) to have run.

use std::sync::{Arc, Mutex, MutexGuard};

use prelora::config::RunConfig;
use prelora::data::{Dataset, EpochLoader, SynthSpec};
use prelora::dp::{Algorithm, BucketPlan, BucketRoute, BucketTx, GradEngine, StepMode};
use prelora::manifest::Manifest;
use prelora::trainer::Trainer;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn micro() -> Arc<Manifest> {
    let dir = format!("{}/artifacts/vit-micro", env!("CARGO_MANIFEST_DIR"));
    Arc::new(Manifest::load(dir).expect("run `make artifacts` first"))
}

fn data(m: &Manifest, samples: usize) -> Dataset {
    let c = &m.config;
    Dataset::generate(&SynthSpec {
        samples,
        image_size: c.image_size,
        channels: c.in_channels,
        num_classes: c.num_classes,
        noise: 0.3,
        phase_jitter: true,
        seed: 11,
    })
}

/// Count live threads this crate spawned, by name prefix. Thread names are
/// set at every spawn site (PL005 markers list them); `comm` truncates to
/// 15 bytes but every prefix below fits.
#[cfg(target_os = "linux")]
fn prelora_threads() -> usize {
    let names =
        ["dp-worker", "bucket-reduce", "reduce-stage", "data-prefetch", "net-tx-r", "net-rx-r"];
    std::fs::read_dir("/proc/self/task")
        .map(|it| {
            it.filter_map(|e| e.ok())
                .filter(|e| {
                    std::fs::read_to_string(e.path().join("comm"))
                        .map(|c| names.iter().any(|n| c.trim_end().starts_with(n)))
                        .unwrap_or(false)
                })
                .count()
        })
        .unwrap_or(0)
}

/// Drops are synchronous joins, but give `/proc` a beat to reap entries.
#[cfg(target_os = "linux")]
fn assert_threads_return_to(baseline: usize, what: &str) {
    for _ in 0..100 {
        if prelora_threads() <= baseline {
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    panic!("{what}: {} threads still live (baseline {baseline})", prelora_threads());
}

#[test]
fn worker_panic_fails_epoch_loudly_instead_of_hanging() {
    let _g = lock();
    let m = micro();
    let d = data(&m, 64);
    let workers = 2;
    let loader = EpochLoader::new(m.config.batch_size, workers, 0);
    let base = m.load_init_base().unwrap();
    let mut eng = GradEngine::new(m.clone(), workers, true, Algorithm::Naive).unwrap();

    // A bucket plan whose length disagrees with the gradient buffer trips
    // the publish-side assert *inside the worker thread*. Before the
    // worker loop caught panics, the worker died with its result unsent
    // and collect() blocked forever (the engine's own results-sender clone
    // keeps the channel open — modeled in tests/loom_bucket.rs).
    let plan = Arc::new(BucketPlan::derive(m.base.size - 1, 1, 4096));
    let (tx, _rx) = BucketTx::channel(1024);
    eng.set_bucket_route(Some(BucketRoute { base: Some(plan), lora: None, tx }));
    eng.submit(StepMode::Full, &base, None, loader.step_batches(&d, 0, 0)).unwrap();
    let err = eng.collect().expect_err("panicking worker must fail the step");
    let text = format!("{err:#}");
    assert!(text.contains("panicked"), "error must say a worker panicked: {text}");

    // the engine must stay usable: clear the bad route, run a clean step
    eng.set_bucket_route(None);
    let r = eng.compute(StepMode::Full, &base, None, loader.step_batches(&d, 0, 1)).unwrap();
    assert!(r.loss.is_finite() && r.loss > 0.0, "post-panic step must run normally");
}

#[test]
#[cfg(target_os = "linux")]
fn engine_drop_joins_its_worker_threads() {
    let _g = lock();
    let m = micro();
    let before = prelora_threads();
    let eng = GradEngine::new(m, 2, true, Algorithm::Naive).unwrap();
    assert!(prelora_threads() >= before + 2, "threaded engine must spawn its workers");
    drop(eng);
    assert_threads_return_to(before, "GradEngine::drop must join its workers");
}

#[test]
#[cfg(target_os = "linux")]
fn tcp_endpoint_teardown_joins_its_per_peer_net_workers() {
    use prelora::dist::{CollectiveEndpoint, TcpEndpoint};
    let _g = lock();
    let before = prelora_threads();
    // grab a free loopback port for rank 0's rendezvous; rank 1's entry is
    // identity only (leaves dial peers[0]), so any placeholder works
    let addr = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let peers = vec![addr, "127.0.0.1:1".to_string()];
    let timeout = std::time::Duration::from_secs(10);
    let p0 = peers.clone();
    let root = std::thread::spawn(move || TcpEndpoint::connect(Algorithm::Naive, 0, &p0, timeout));
    let leaf = TcpEndpoint::connect(Algorithm::Naive, 1, &peers, timeout).unwrap();
    let root = root.join().unwrap().unwrap();
    // one live op proves the per-peer send/recv workers are up, then
    // teardown must join every "net-tx-r*"/"net-rx-r*" thread
    let l = std::thread::spawn(move || {
        let mut buf = vec![1.0f32, 2.0];
        leaf.all_reduce(&mut buf).unwrap();
        buf
    });
    let mut buf = vec![3.0f32, 4.0];
    root.all_reduce(&mut buf).unwrap();
    assert!(prelora_threads() > before, "live tcp endpoints must run net worker threads");
    assert_eq!(l.join().unwrap(), buf, "both ranks see the same reduced buffer");
    assert_eq!(buf, vec![2.0, 3.0], "two-rank mean of [1,2] and [3,4]");
    drop(root);
    assert_threads_return_to(before, "TcpEndpoint teardown must join its net workers");
}

#[test]
#[cfg(target_os = "linux")]
fn pipelined_trainer_teardown_leaves_no_live_threads() {
    let _g = lock();
    let before = prelora_threads();
    let mut cfg = RunConfig::default();
    cfg.model = "vit-micro".into();
    cfg.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    cfg.run_name = "shutdown-test".into();
    cfg.train.epochs = 2;
    cfg.train.data.train_samples = 96;
    cfg.train.data.val_samples = 32;
    cfg.train.dp.workers = 2;
    cfg.train.dp.threaded = true;
    cfg.train.pipeline.enabled = true;
    // bucketed sync on, so the reduce stage runs its accumulator thread
    cfg.train.pipeline.bucket_bytes = 1024;
    let mut t = Trainer::new(cfg).unwrap();
    t.run_epoch().unwrap();
    assert!(prelora_threads() > before, "threaded pipelined run must have live stage threads");
    drop(t);
    // teardown joins everything: dp workers, bucket-reduce accumulator,
    // reduce-stage overlap thread, data-prefetch — regardless of the order
    // their owners drop in (engine-held route senders must not keep the
    // accumulator alive: BucketCtrl::Shutdown overrides them)
    assert_threads_return_to(before, "Trainer teardown must join every stage thread");
}
