//! Exhaustive interleaving checks for the bucket-sync protocol.
//!
//! The fixed-seed integration sweep proves the bucketed reduce is bitwise
//! correct on the interleavings the OS scheduler happens to produce;
//! these tests prove liveness and delivery on *every* interleaving of
//! small instances. Each test states a faithful model of the protocol in
//! `pipeline/reduce.rs` + `dp/engine.rs` — workers publishing over the
//! bounded [`BucketTx`] queue, the accumulator thread, the leader's
//! collect/drain — and hands it to the [`prelora::mc`] checker, which
//! walks the whole schedule space (see `src/sync.rs` for why the vendored
//! checker stands in for loom here).
//!
//! The models mirror `std::sync::mpsc` semantics exactly where the
//! protocol depends on them: a bounded `sync_channel` send blocks while
//! the queue is full but fails *immediately* once the receiver is gone
//! (that failure is what un-sticks publishers after a teardown), and an
//! unbounded channel recv blocks while any sender is alive — which is
//! exactly how a vanished worker used to hang the leader.
//!
//! [`BucketTx`]: prelora::dp::BucketTx

use std::collections::VecDeque;

use prelora::mc::{explore, Model, Step, ViolationKind};

const WORKERS: usize = 2;
const BUCKETS: usize = 3;
/// Queue bound; smaller than WORKERS * BUCKETS so publishers really block.
const CAP: usize = 2;

/// What travels the bucket queue (mirrors `dp::BucketCtrl`).
#[derive(Clone, PartialEq, Eq, Hash)]
enum Ctrl {
    Bucket { worker: u8, bucket: u8 },
    Reset,
    Shutdown,
}

/// The full pipeline: WORKERS publisher threads, the accumulator, and the
/// leader draining reduced buckets then shutting the accumulator down.
/// Thread ids: `0..WORKERS` = workers, `WORKERS` = accumulator,
/// `WORKERS + 1` = leader.
#[derive(Clone, PartialEq, Eq, Hash)]
struct Pipeline {
    /// Next bucket index each worker will publish.
    published: [u8; WORKERS],
    /// The bounded bucket queue.
    queue: VecDeque<Ctrl>,
    /// Worker slices the accumulator holds per bucket.
    got: [u8; BUCKETS],
    /// Reduced buckets in flight to the leader (unbounded channel).
    reduced: VecDeque<u8>,
    /// How many times the leader received each reduced bucket.
    leader: [u8; BUCKETS],
    /// How many reduced buckets the leader consumes before tearing down
    /// (BUCKETS = a full step; fewer = a mid-epoch abort).
    leader_takes: u8,
    /// Leader dropped its reduced-bucket receiver (teardown).
    rx_alive: bool,
    shutdown_sent: bool,
    /// Accumulator exited (Shutdown, or its result send failed).
    acc_done: bool,
}

impl Pipeline {
    fn new(leader_takes: u8) -> Self {
        Self {
            published: [0; WORKERS],
            queue: VecDeque::new(),
            got: [0; BUCKETS],
            reduced: VecDeque::new(),
            leader: [0; BUCKETS],
            leader_takes,
            rx_alive: true,
            shutdown_sent: false,
            acc_done: false,
        }
    }

    fn taken(&self) -> u8 {
        self.leader.iter().sum()
    }
}

impl Model for Pipeline {
    fn threads(&self) -> usize {
        WORKERS + 2
    }

    fn step(&mut self, tid: usize) -> Step {
        if tid < WORKERS {
            // worker: publish buckets in index order; a send on the
            // closed queue fails immediately and is ignored, like
            // publish_buckets' `let _ = route.tx.send(...)`
            let next = self.published[tid];
            if usize::from(next) == BUCKETS {
                return Step::Done;
            }
            if self.acc_done {
                self.published[tid] = next + 1;
                return Step::Progress;
            }
            if self.queue.len() == CAP {
                return Step::Blocked;
            }
            self.queue.push_back(Ctrl::Bucket { worker: tid as u8, bucket: next });
            self.published[tid] = next + 1;
            Step::Progress
        } else if tid == WORKERS {
            // accumulator: accumulate_buckets' loop
            if self.acc_done {
                return Step::Done;
            }
            let Some(ctrl) = self.queue.pop_front() else {
                // senders never all drop before Shutdown (the stage owns
                // one for its whole lifetime), so an empty queue blocks
                return Step::Blocked;
            };
            match ctrl {
                Ctrl::Shutdown => self.acc_done = true,
                Ctrl::Reset => self.got = [0; BUCKETS],
                Ctrl::Bucket { bucket, .. } => {
                    let b = usize::from(bucket);
                    self.got[b] += 1;
                    if usize::from(self.got[b]) == WORKERS {
                        if self.rx_alive {
                            self.reduced.push_back(bucket);
                        } else {
                            // result send failed: leader is gone, exit
                            self.acc_done = true;
                        }
                    }
                }
            }
            Step::Progress
        } else {
            // leader: drain `leader_takes` reduced buckets, drop the
            // receiver, send Shutdown, join the accumulator
            if self.taken() < self.leader_takes {
                let Some(b) = self.reduced.pop_front() else {
                    return Step::Blocked;
                };
                self.leader[usize::from(b)] += 1;
                Step::Progress
            } else if self.rx_alive {
                self.rx_alive = false; // drop(self.reduced_rx.take())
                Step::Progress
            } else if !self.shutdown_sent {
                if self.queue.len() == CAP && !self.acc_done {
                    return Step::Blocked; // bounded send waits for space
                }
                if !self.acc_done {
                    self.queue.push_back(Ctrl::Shutdown);
                }
                self.shutdown_sent = true;
                Step::Progress
            } else if !self.acc_done {
                Step::Blocked // join
            } else {
                Step::Done
            }
        }
    }

    fn check(&self) -> Result<(), String> {
        for (b, &n) in self.leader.iter().enumerate() {
            if n > 1 {
                return Err(format!("bucket {b} delivered to the leader {n} times"));
            }
        }
        for (b, &n) in self.got.iter().enumerate() {
            if usize::from(n) > WORKERS {
                return Err(format!("bucket {b} over-filled: {n} slices"));
            }
        }
        Ok(())
    }

    fn accept(&self) -> Result<(), String> {
        if self.taken() != self.leader_takes {
            return Err(format!(
                "leader ended with {} of {} buckets",
                self.taken(),
                self.leader_takes
            ));
        }
        if !self.acc_done {
            return Err("accumulator outlived the leader's join".into());
        }
        Ok(())
    }
}

#[test]
fn full_step_delivers_every_bucket_once_in_every_interleaving() {
    // the happy path: the leader drains a complete step, then tears down.
    // No interleaving of publishes, reduces and the teardown may deadlock,
    // lose a bucket, or deliver one twice.
    let report = explore(Pipeline::new(BUCKETS as u8)).unwrap_or_else(|v| panic!("{v}"));
    assert!(report.terminals > 0, "at least one complete schedule must exist");
}

#[test]
fn mid_epoch_teardown_cannot_hang_leader_or_workers() {
    // the drop-order scenario behind ReduceStage::drop: the leader takes
    // only one reduced bucket, drops its receiver, sends Shutdown and
    // joins — while workers may still be publishing into a bounded queue.
    // Every interleaving must terminate: the accumulator exits on
    // Shutdown or on its failed result send, and closed-queue publishes
    // fail immediately instead of blocking forever.
    for takes in [0u8, 1] {
        explore(Pipeline::new(takes)).unwrap_or_else(|v| panic!("takes={takes}: {v}"));
    }
}

/// A worker dying mid-job vs. the leader's blocking collect. The results
/// channel never disconnects — the engine keeps its own sender clone —
/// so `recv` can only be released by an actual message. Thread 0 is the
/// worker, thread 1 the leader.
#[derive(Clone, PartialEq, Eq, Hash)]
struct WorkerDeath {
    /// true = the fixed engine: catch_unwind turns the panic into an
    /// error on the results channel. false = the old engine: the worker
    /// thread just vanishes.
    catches: bool,
    results: u8,
    worker_done: bool,
    leader_got: bool,
}

impl Model for WorkerDeath {
    fn threads(&self) -> usize {
        2
    }

    fn step(&mut self, tid: usize) -> Step {
        if tid == 0 {
            if self.worker_done {
                return Step::Done;
            }
            if self.catches {
                self.results += 1; // send Err("worker panicked")
            }
            self.worker_done = true;
            Step::Progress
        } else {
            if self.leader_got {
                return Step::Done;
            }
            if self.results == 0 {
                return Step::Blocked; // recv_all: channel still open
            }
            self.results -= 1;
            self.leader_got = true;
            Step::Progress
        }
    }

    fn accept(&self) -> Result<(), String> {
        if self.leader_got {
            Ok(())
        } else {
            Err("leader never observed the worker's fate".into())
        }
    }
}

#[test]
fn uncaught_worker_panic_deadlocks_the_leader_and_the_catch_fixes_it() {
    // the old protocol really hangs: the checker must find the lost-result
    // interleaving, not just fail to prove liveness
    let v = explore(WorkerDeath {
        catches: false,
        results: 0,
        worker_done: false,
        leader_got: false,
    })
    .unwrap_err();
    assert_eq!(v.kind, ViolationKind::Deadlock, "{v}");

    // with catch_unwind the panic reaches the leader as an error in every
    // interleaving
    explore(WorkerDeath { catches: true, results: 0, worker_done: false, leader_got: false })
        .unwrap_or_else(|v| panic!("{v}"));
}

/// The phase-overlap handoff (`ReduceStage`'s base-vs-LoRA pair): the
/// leader ships base buffers to the stage thread, reduces LoRA itself,
/// receives the base result, and on drop closes the job channel and
/// joins. Thread 0 is the leader, thread 1 the stage thread.
#[derive(Clone, PartialEq, Eq, Hash)]
struct Handoff {
    jobs: VecDeque<u8>,
    outs: VecDeque<u8>,
    steps_left: u8,
    awaiting: bool,
    tx_alive: bool,
    stage_done: bool,
}

impl Model for Handoff {
    fn threads(&self) -> usize {
        2
    }

    fn step(&mut self, tid: usize) -> Step {
        if tid == 0 {
            if self.steps_left > 0 {
                if !self.awaiting {
                    self.jobs.push_back(self.steps_left);
                    self.awaiting = true;
                    return Step::Progress;
                }
                if self.outs.pop_front().is_none() {
                    return Step::Blocked; // rx.recv() for the base result
                }
                self.awaiting = false;
                self.steps_left -= 1;
                Step::Progress
            } else if self.tx_alive {
                self.tx_alive = false; // Drop: close the job channel
                Step::Progress
            } else if !self.stage_done {
                Step::Blocked // join
            } else {
                Step::Done
            }
        } else {
            if self.stage_done {
                return Step::Done;
            }
            match self.jobs.pop_front() {
                Some(job) => {
                    self.outs.push_back(job);
                    Step::Progress
                }
                // `while let Ok(bufs) = job_rx.recv()`: exits only when
                // the channel is both empty and closed
                None if !self.tx_alive => {
                    self.stage_done = true;
                    Step::Progress
                }
                None => Step::Blocked,
            }
        }
    }

    fn accept(&self) -> Result<(), String> {
        if self.steps_left == 0 && self.stage_done {
            Ok(())
        } else {
            Err(format!("steps_left={}, stage_done={}", self.steps_left, self.stage_done))
        }
    }
}

#[test]
fn reduce_update_handoff_completes_and_joins_in_every_interleaving() {
    explore(Handoff {
        jobs: VecDeque::new(),
        outs: VecDeque::new(),
        steps_left: 2,
        awaiting: false,
        tx_alive: true,
        stage_done: false,
    })
    .unwrap_or_else(|v| panic!("{v}"));
}

/// Two epochs around an aborted step: worker 0's epoch-1 slice is already
/// queued when the step fails; both workers then publish fresh slices in
/// epoch 2. Models the accumulator's pending map for one bucket. With
/// `reset` (the shipped protocol) the epoch barrier clears the stale
/// slice; without it — the pre-fix protocol — some interleaving either
/// completes the bucket from mixed-epoch data or trips the duplicate
/// assert. Thread ids: 0/1 = workers, 2 = accumulator, 3 = leader.
#[derive(Clone, PartialEq, Eq, Hash)]
enum Slice {
    Stale,
    Fresh,
}

#[derive(Clone, PartialEq, Eq, Hash)]
struct EpochReset {
    reset: bool,
    queue: VecDeque<(u8, Slice)>, // (worker, slice) — Reset = worker 255
    epoch2: bool,
    /// Worker pcs: w0 publishes stale then (in epoch 2) fresh; w1 only
    /// fresh.
    w0: u8,
    w1: u8,
    slots: [Option<Slice>; 2],
    delivered: Option<[Slice; 2]>,
    acc_done: bool,
    leader_done: bool,
}

impl Model for EpochReset {
    fn threads(&self) -> usize {
        4
    }

    fn step(&mut self, tid: usize) -> Step {
        match tid {
            0 => match self.w0 {
                0 => {
                    self.queue.push_back((0, Slice::Stale));
                    self.w0 = 1;
                    Step::Progress
                }
                1 if self.epoch2 => {
                    self.queue.push_back((0, Slice::Fresh));
                    self.w0 = 2;
                    Step::Progress
                }
                1 => Step::Blocked, // waiting out the epoch barrier
                _ => Step::Done,
            },
            1 => match self.w1 {
                0 if self.epoch2 => {
                    self.queue.push_back((1, Slice::Fresh));
                    self.w1 = 1;
                    Step::Progress
                }
                0 => Step::Blocked,
                _ => Step::Done,
            },
            2 => {
                // accumulator
                if self.acc_done {
                    return Step::Done;
                }
                if self.delivered.is_some() {
                    // one-bucket model: nothing further to do
                    self.acc_done = true;
                    return Step::Progress;
                }
                let Some((w, slice)) = self.queue.pop_front() else {
                    return Step::Blocked;
                };
                if w == 255 {
                    self.slots = [None, None]; // Reset
                    return Step::Progress;
                }
                let slot = &mut self.slots[usize::from(w)];
                if slot.is_some() {
                    // the pre-fix duplicate assert: accumulator dies; the
                    // checker reports it as an unserviceable leader below
                    self.acc_done = true;
                    return Step::Progress;
                }
                *slot = Some(slice);
                if let [Some(a), Some(b)] = self.slots.clone() {
                    self.delivered = Some([a, b]);
                }
                Step::Progress
            }
            _ => {
                // leader: epoch barrier after the aborted step, then wait
                // for the reduced bucket
                if !self.epoch2 {
                    if self.w0 == 0 {
                        return Step::Blocked; // drain: w0's publish lands first
                    }
                    if self.reset {
                        self.queue.push_back((255, Slice::Stale));
                    }
                    self.epoch2 = true;
                    return Step::Progress;
                }
                if self.leader_done {
                    return Step::Done;
                }
                if self.delivered.is_none() {
                    if self.acc_done {
                        // rtx dropped: recv errors out — the step fails
                        // loudly; terminal, but accept() flags it
                        self.leader_done = true;
                        return Step::Progress;
                    }
                    return Step::Blocked;
                }
                self.leader_done = true;
                Step::Progress
            }
        }
    }

    fn check(&self) -> Result<(), String> {
        if let Some(slices) = &self.delivered {
            if slices.iter().any(|s| *s == Slice::Stale) {
                return Err("bucket completed from mixed-epoch slices".into());
            }
        }
        Ok(())
    }

    fn accept(&self) -> Result<(), String> {
        match &self.delivered {
            Some(_) => Ok(()),
            None => Err("leader never received the epoch-2 bucket".into()),
        }
    }
}

fn epoch_reset(reset: bool) -> EpochReset {
    EpochReset {
        reset,
        queue: VecDeque::new(),
        epoch2: false,
        w0: 0,
        w1: 0,
        slots: [None, None],
        delivered: None,
        acc_done: false,
        leader_done: false,
    }
}

#[test]
fn epoch_reset_isolates_aborted_step_leftovers() {
    // shipped protocol: every interleaving delivers a fresh-only bucket
    explore(epoch_reset(true)).unwrap_or_else(|v| panic!("{v}"));

    // pre-fix protocol: the checker finds an interleaving that corrupts
    // the bucket with the stale slice (or kills the accumulator on the
    // duplicate) — the bug class the fixed-seed sweep cannot surface
    let v = explore(epoch_reset(false)).unwrap_err();
    assert!(
        matches!(v.kind, ViolationKind::Invariant | ViolationKind::Accept),
        "expected corruption or a lost bucket, got {v}"
    );
}
