//! Multi-process TCP parity: real `prelora` OS processes over loopback
//! TCP must reproduce the in-memory run bit-for-bit.
//!
//! Each leg launches one `prelora train` subprocess per rank with
//! `--dist tcp --rank N --peers ...` and compares rank 0's final
//! checkpoint — per-epoch losses, grad norms, accuracies, phase-switch
//! epochs, final base/LoRA parameters and gathered optimizer state —
//! against a single-process run of the same config with the in-memory
//! collective (`train.dist.transport = "local"`, two simulated workers).
//! The run crosses both PreLoRA phase boundaries (Full -> Warmup ->
//! LoraOnly), and the sweep covers ZeRO off and ZeRO-3 so the wire path
//! is exercised under both the replicated all-reduce and the terminal
//! reduce-scatter + parameter sharding.
//!
//! Requires `make artifacts` (vit-micro) to have run.

use std::io::Write;
use std::process::Command;

use prelora::config::RunConfig;
use prelora::trainer::{Checkpoint, Trainer};

const EPOCHS: usize = 16;

/// The shared run config, written to disk for the subprocesses and parsed
/// back for the in-process reference leg — one source of truth per leg.
/// Mirrors `tests/integration.rs::micro_config`: relaxed thresholds so the
/// micro model crosses both phase boundaries within [`EPOCHS`].
fn config_toml(results_dir: &std::path::Path, stage: u8) -> String {
    format!(
        r#"
model = "vit-micro"
artifacts_dir = "{artifacts}"
results_dir = "{results}"
run_name = "parity"
seed = 0

[train]
epochs = {EPOCHS}
eval_every = 4
checkpoint_every = {EPOCHS}

[train.data]
train_samples = 192
val_samples = 64

[train.zero]
stage = {stage}

[prelora]
tau = 6.0
zeta = 25.0
windows = 2
window_epochs = 2
warmup_epochs = 2
"#,
        artifacts = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")),
        results = results_dir.display(),
    )
}

/// Wait for rank 0's advertised listen address (written atomically via
/// `PRELORA_TCP_ADVERTISE` once its port-0 bind resolves).
fn wait_for_advert(path: &std::path::Path) -> String {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        if let Ok(s) = std::fs::read_to_string(path) {
            let s = s.trim().to_string();
            if !s.is_empty() {
                return s;
            }
        }
        assert!(
            std::time::Instant::now() < deadline,
            "rank 0 never advertised its address at {}",
            path.display()
        );
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
}

/// Launch `world` ranks with a port-0 rendezvous: rank 0 binds
/// `127.0.0.1:0`, advertises the OS-assigned address through
/// `PRELORA_TCP_ADVERTISE`, and the remaining ranks are spawned with the
/// discovered address. No port is ever guessed, so parallel test runs
/// cannot race each other for a fixed port.
fn run_tcp_group(cfg_path: &std::path::Path, tmp: &std::path::Path, world: usize) {
    let advert = tmp.join("root.addr");
    let spawn = |rank: usize, peers: &str| {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_prelora"));
        cmd.args([
            "train",
            "--config",
            cfg_path.to_str().unwrap(),
            "--run-name",
            "parity-tcp",
            "--dist",
            "tcp",
            "--rank",
            &rank.to_string(),
            "--peers",
            peers,
            "--connect-timeout-ms",
            "30000",
        ])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped());
        if rank == 0 {
            cmd.env("PRELORA_TCP_ADVERTISE", &advert);
        }
        cmd.spawn().unwrap_or_else(|e| panic!("spawning rank {rank}: {e}"))
    };
    // rank 0 binds port 0; the placeholder entries only size the world
    let unbound = vec!["127.0.0.1:0".to_string(); world];
    let mut children = vec![spawn(0, &unbound.join(","))];
    let mut peers = unbound;
    peers[0] = wait_for_advert(&advert);
    children.extend((1..world).map(|r| spawn(r, &peers.join(","))));
    for (rank, child) in children.into_iter().enumerate() {
        let out = child.wait_with_output().unwrap();
        assert!(
            out.status.success(),
            "rank {rank} exited with {}\n--- stderr ---\n{}",
            out.status,
            String::from_utf8_lossy(&out.stderr)
        );
    }
}

/// Per-epoch observables compared bitwise between the two transports.
fn epoch_bits(ck: &Checkpoint) -> Vec<(u64, u64, u64, u64)> {
    let tr = ck.trajectory.as_ref().expect("v3 checkpoint must carry the trajectory");
    tr.stats
        .iter()
        .map(|s| {
            (
                s.train_loss.to_bits(),
                s.grad_norm.to_bits(),
                s.train_acc.to_bits(),
                // NaN on non-eval epochs: both legs skip the same epochs,
                // and f64::NAN has one bit pattern here
                s.val_loss.to_bits(),
            )
        })
        .collect()
}

fn parity_leg(stage: u8) {
    let tmp = std::env::temp_dir().join(format!("prelora_tcp_{}_{stage}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let cfg_path = tmp.join("parity.toml");
    let mut f = std::fs::File::create(&cfg_path).unwrap();
    f.write_all(config_toml(&tmp, stage).as_bytes()).unwrap();
    drop(f);

    // in-process reference: the same config, two simulated in-memory
    // workers (the tcp group's world is the two ranks launched below)
    let mut cfg = RunConfig::from_toml_file(&cfg_path).unwrap();
    cfg.train.dp.workers = 2;
    let mut reference = Trainer::new(cfg).unwrap();
    reference.run().unwrap();
    let want = reference.checkpoint();
    let want_tr = want.trajectory.as_ref().unwrap();
    assert!(
        want_tr.switch_epoch.is_some() && want_tr.freeze_epoch.is_some(),
        "reference run must cross both phase boundaries to make the parity meaningful"
    );

    // two real OS processes over loopback; rank 0 writes the checkpoint
    run_tcp_group(&cfg_path, &tmp, 2);
    let got = Checkpoint::load(tmp.join("parity-tcp.ckpt")).unwrap();
    let got_tr = got.trajectory.as_ref().unwrap();

    assert_eq!(epoch_bits(&got), epoch_bits(&want), "stage {stage}: per-epoch observables");
    assert_eq!(got_tr.switch_epoch, want_tr.switch_epoch, "stage {stage}: switch epoch");
    assert_eq!(got_tr.freeze_epoch, want_tr.freeze_epoch, "stage {stage}: freeze epoch");
    assert_eq!(got.epoch, want.epoch);
    assert_eq!(got.base, want.base, "stage {stage}: final base params must be bitwise equal");
    assert_eq!(got.lora, want.lora, "stage {stage}: final LoRA params must be bitwise equal");
    assert_eq!(got.ranks, want.ranks, "stage {stage}: adapter rank assignment");
    assert_eq!(got.opt_base, want.opt_base, "stage {stage}: gathered base optimizer state");
    assert_eq!(got.opt_lora, want.opt_lora, "stage {stage}: gathered LoRA optimizer state");

    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn two_processes_over_loopback_match_the_in_memory_run_bitwise() {
    parity_leg(0);
}

#[test]
fn two_processes_over_loopback_match_the_in_memory_run_bitwise_under_zero3() {
    parity_leg(3);
}
