//! The resume-equals-continuous harness: saving a v3 checkpoint at epoch
//! k and restoring it must be indistinguishable — **bitwise** — from
//! never having stopped.
//!
//! For a fixed seed the uninterrupted reference run and every
//! save-at-k + resume run must agree on per-epoch train losses, mean
//! grad norms, LR values, phase labels, the switch/freeze epochs and the
//! assigned per-adapter ranks. The sweep covers:
//!
//! * interruption inside every phase — Full, *inside* Warmup (the phase
//!   whose schedule position was historically dropped), and LoraOnly;
//! * ZeRO off / stage 1 / stage 2 on either side of the interruption
//!   (save sharded, resume unsharded and vice versa — the v3 payload is
//!   gathered, so layouts may change freely);
//! * pipeline on/off on either side (both drivers are bit-identical, so
//!   a checkpoint must be too);
//! * a worker-count change on restore. Changing `dp.workers` changes the
//!   global batch (worker count × per-worker batch), so a bitwise *loss*
//!   comparison against the old-worker-count run is not defined — what
//!   must survive bitwise is the **state**: parameters, gathered
//!   optimizer state (re-partitioned onto the new layout), the phase
//!   machine and the history, plus the schedule semantics (the freeze
//!   still fires exactly `warmup_epochs` after the restored switch).
//!
//! Every case round-trips the checkpoint through disk, so the format —
//! not just the in-memory struct — is what's being proven.
//!
//! Requires `make artifacts` (vit-micro) to have run.

use std::sync::OnceLock;

use prelora::config::RunConfig;
use prelora::dist::ZeroStage;
use prelora::trainer::{Checkpoint, Trainer};

const EPOCHS: usize = 16;

fn micro_config() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "vit-micro".into();
    cfg.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    cfg.run_name = "resume".into();
    cfg.train.epochs = EPOCHS;
    cfg.train.data.train_samples = 192;
    cfg.train.data.val_samples = 64;
    cfg.train.eval_every = 4; // leaves NaN val columns in most stats rows
    cfg.train.dp.workers = 2;
    // relaxed thresholds so the micro run crosses both phase boundaries
    cfg.prelora.tau = 6.0;
    cfg.prelora.zeta = 25.0;
    cfg.prelora.windows = 2;
    cfg.prelora.window_epochs = 2;
    cfg.prelora.warmup_epochs = 2;
    cfg
}

#[derive(Debug, Clone, Copy)]
struct Variant {
    zero: ZeroStage,
    pipeline: bool,
    /// Gradient-sync bucket size (0 = whole-buffer). Pure scheduling —
    /// bucketed and whole-buffer runs are bitwise identical, so a
    /// checkpoint must restore across the toggle too.
    bucket_bytes: usize,
}

const DEFAULT: Variant = Variant { zero: ZeroStage::Off, pipeline: true, bucket_bytes: 0 };

fn config_of(v: Variant) -> RunConfig {
    let mut cfg = micro_config();
    cfg.train.pipeline.enabled = v.pipeline;
    cfg.train.pipeline.bucket_bytes = v.bucket_bytes;
    // explicit, so the reference trajectory is the same regardless of the
    // integration suite's PRELORA_TEST_ZERO_STAGE env knob
    cfg.train.zero.stage = Some(v.zero);
    cfg
}

/// Everything the bitwise comparison covers, with floats as raw bits so
/// equality is exact and NaN-proof.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Fingerprint {
    losses: Vec<u64>,
    grad_norms: Vec<u64>,
    lrs: Vec<u64>,
    phases: Vec<&'static str>,
    switch_epoch: Option<usize>,
    freeze_epoch: Option<usize>,
    ranks: Option<Vec<usize>>,
}

fn fingerprint(t: &Trainer) -> Fingerprint {
    Fingerprint {
        losses: t.stats.iter().map(|s| s.train_loss.to_bits()).collect(),
        grad_norms: t.stats.iter().map(|s| s.grad_norm.to_bits()).collect(),
        lrs: t.stats.iter().map(|s| s.lr.to_bits()).collect(),
        phases: t.stats.iter().map(|s| s.phase).collect(),
        switch_epoch: t.controller().switch_epoch(),
        freeze_epoch: t.controller().freeze_epoch(),
        ranks: t.adapter_cfg().map(|a| a.ranks.clone()),
    }
}

fn drive(t: &mut Trainer, upto: usize) {
    while t.history().epochs() < upto {
        t.run_epoch().expect("epoch failed");
    }
}

struct Reference {
    fp: Fingerprint,
    base: Vec<f32>,
    /// First epoch of the warmup phase + 1 — an interruption point
    /// strictly inside warmup.
    k_warm: usize,
    k_lora: usize,
}

/// The uninterrupted reference run (computed once, shared by every case).
fn reference() -> &'static Reference {
    static REF: OnceLock<Reference> = OnceLock::new();
    REF.get_or_init(|| {
        let mut t = Trainer::new(config_of(DEFAULT)).unwrap();
        drive(&mut t, EPOCHS);
        let fp = fingerprint(&t);
        let (Some(switch), Some(freeze)) = (fp.switch_epoch, fp.freeze_epoch) else {
            panic!("reference run must cross both phase boundaries; got {fp:?}");
        };
        assert!(switch + 1 < freeze, "need an epoch strictly inside warmup");
        assert!(freeze + 1 < EPOCHS, "need epochs after the freeze");
        Reference {
            fp,
            base: t.base_params().to_vec(),
            k_warm: switch + 1,
            k_lora: freeze + 1,
        }
    })
}

/// Run `save_variant` for `k` epochs, checkpoint through disk, restore
/// into a fresh `resume_variant` trainer, finish the run, and return the
/// resumed trainer.
fn save_and_resume(save_variant: Variant, resume_variant: Variant, k: usize, tag: &str) -> Trainer {
    let mut a = Trainer::new(config_of(save_variant)).unwrap();
    drive(&mut a, k);
    let path = std::env::temp_dir().join(format!(
        "prelora_resume_{}_{tag}.ckpt",
        std::process::id()
    ));
    a.checkpoint().save(&path).unwrap();
    let back = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(back.epoch, k);
    let mut b = Trainer::new(config_of(resume_variant)).unwrap();
    b.restore(&back).unwrap();
    assert_eq!(b.history().epochs(), k, "{tag}: epoch cursor must restore");
    drive(&mut b, EPOCHS);
    assert_eq!(
        b.summary().resumed_from,
        Some(k),
        "{tag}: summary must carry the resume provenance note"
    );
    b
}

fn assert_resume_matches(save_variant: Variant, resume_variant: Variant, k: usize, tag: &str) {
    let resumed = save_and_resume(save_variant, resume_variant, k, tag);
    let want = &reference().fp;
    let got = fingerprint(&resumed);
    assert_eq!(got.losses, want.losses, "{tag}: per-epoch losses must be bitwise identical");
    assert_eq!(got.grad_norms, want.grad_norms, "{tag}: grad norms must be bitwise identical");
    assert_eq!(got.lrs, want.lrs, "{tag}: LR trajectory must match");
    assert_eq!(got.phases, want.phases, "{tag}: phase labels must match");
    assert_eq!(got.switch_epoch, want.switch_epoch, "{tag}: switch epoch must match");
    assert_eq!(got.freeze_epoch, want.freeze_epoch, "{tag}: freeze epoch must match");
    assert_eq!(got.ranks, want.ranks, "{tag}: assigned ranks must match");
}

// ---------------------------------------------------------------------------
// interruption point inside every phase (default config both sides)
// ---------------------------------------------------------------------------

#[test]
fn resume_from_full_phase_is_bitwise_continuous() {
    // epoch 2 is before any window boundary: the resumed run must redo
    // convergence detection from the restored history and switch on the
    // reference's epoch
    assert_resume_matches(DEFAULT, DEFAULT, 2, "full");
}

#[test]
fn resume_from_inside_warmup_is_bitwise_continuous() {
    // strictly inside warmup: the restored controller must freeze exactly
    // warmup_epochs after the *restored* switch epoch, not re-detect
    let k = reference().k_warm;
    assert_resume_matches(DEFAULT, DEFAULT, k, "warmup");
}

#[test]
fn resume_from_lora_phase_is_bitwise_continuous() {
    let k = reference().k_lora;
    let resumed = save_and_resume(DEFAULT, DEFAULT, k, "lora");
    let want = &reference().fp;
    assert_eq!(fingerprint(&resumed), *want, "lora: fingerprint must be bitwise identical");
    // the strongest claim: the final parameter vectors agree bit-for-bit
    assert_eq!(
        resumed.base_params(),
        &reference().base[..],
        "lora: final base params must be bitwise identical"
    );
}

// ---------------------------------------------------------------------------
// ZeRO / pipeline layout changes across the interruption
// ---------------------------------------------------------------------------

#[test]
fn resume_across_zero_stage_changes_is_bitwise_continuous() {
    let k = reference().k_warm;
    // save sharded (stage 1), resume stage 2: the gathered optimizer
    // state re-scatters onto the gradient-sharded layout
    assert_resume_matches(
        Variant { zero: ZeroStage::Zero1, pipeline: true, bucket_bytes: 0 },
        Variant { zero: ZeroStage::Zero2, pipeline: true, bucket_bytes: 0 },
        k,
        "zero1->zero2",
    );
    // save stage 2, resume unsharded
    assert_resume_matches(
        Variant { zero: ZeroStage::Zero2, pipeline: true, bucket_bytes: 0 },
        DEFAULT,
        k,
        "zero2->off",
    );
}

#[test]
fn resume_across_parameter_sharding_is_bitwise_continuous() {
    // the stage-3 legs of the resume contract: the v3 payload is gathered
    // (parameters included — a stage-3 run's owned partitions all-gather
    // on save), so parameter sharding may appear or disappear across the
    // interruption with a bitwise-continuous trajectory either way
    let k = reference().k_warm;
    // save under stage 3, resume under stage 0
    assert_resume_matches(
        Variant { zero: ZeroStage::Zero3, pipeline: true, bucket_bytes: 0 },
        DEFAULT,
        k,
        "zero3->off",
    );
    // save unsharded, resume under stage 3 (the restore scatters the
    // gathered payload onto owned partitions)
    assert_resume_matches(
        DEFAULT,
        Variant { zero: ZeroStage::Zero3, pipeline: true, bucket_bytes: 0 },
        k,
        "off->zero3",
    );
}

#[test]
fn resume_across_pipeline_toggle_is_bitwise_continuous() {
    // save pipelined, resume through the serial reference loop...
    let k = reference().k_warm;
    assert_resume_matches(
        DEFAULT,
        Variant { zero: ZeroStage::Off, pipeline: false, bucket_bytes: 0 },
        k,
        "pipe->serial",
    );
    // ...and the other way round, interrupted back in the full phase
    assert_resume_matches(
        Variant { zero: ZeroStage::Off, pipeline: false, bucket_bytes: 0 },
        DEFAULT,
        2,
        "serial->pipe",
    );
}

#[test]
fn resume_across_bucketed_sync_toggle_is_bitwise_continuous() {
    // bucket layouts are pure scheduling: a checkpoint saved under
    // bucketed gradient sync restores bitwise under whole-buffer sync and
    // vice versa (k inside warmup, where base AND LoRA gradient spaces
    // are both live and bucketed independently)
    let k = reference().k_warm;
    assert_resume_matches(
        Variant { zero: ZeroStage::Off, pipeline: true, bucket_bytes: 1024 },
        DEFAULT,
        k,
        "bucketed->whole",
    );
    assert_resume_matches(
        DEFAULT,
        Variant { zero: ZeroStage::Off, pipeline: true, bucket_bytes: 1024 },
        k,
        "whole->bucketed",
    );
    // and across a simultaneous shard-layout change: bucketed ZeRO-2 save,
    // whole-buffer ZeRO-3 resume
    assert_resume_matches(
        Variant { zero: ZeroStage::Zero2, pipeline: true, bucket_bytes: 1024 },
        Variant { zero: ZeroStage::Zero3, pipeline: true, bucket_bytes: 0 },
        k,
        "zero2-bucketed->zero3-whole",
    );
}

// ---------------------------------------------------------------------------
// worker-count change on restore
// ---------------------------------------------------------------------------

#[test]
fn worker_count_change_restores_state_bitwise_and_keeps_the_schedule() {
    // a 2-worker ZeRO-2 run, preempted inside warmup...
    let k = reference().k_warm;
    let mut a =
        Trainer::new(config_of(Variant { zero: ZeroStage::Zero2, pipeline: true, bucket_bytes: 0 })).unwrap();
    drive(&mut a, k);
    let ck = a.checkpoint();
    assert_eq!(ck.zero_shards, 2);
    let path = std::env::temp_dir().join(format!("prelora_resume_wc_{}.ckpt", std::process::id()));
    ck.save(&path).unwrap();
    let back = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();

    // a disabled-controller (baseline) run must refuse a mid-warmup
    // checkpoint: its phase machine could never continue the schedule
    let mut baseline_cfg = micro_config();
    baseline_cfg.prelora.enabled = false;
    let mut baseline = Trainer::new(baseline_cfg).unwrap();
    let err = baseline.restore(&back).unwrap_err().to_string();
    assert!(err.contains("controller"), "{err}");

    // ...restores onto a single unsharded worker
    let mut cfg = micro_config();
    cfg.train.dp.workers = 1;
    let mut b = Trainer::new(cfg).unwrap();
    b.restore(&back).unwrap();

    // the phase machine and history restore exactly
    assert_eq!(b.history().epochs(), k);
    assert_eq!(b.phase(), a.phase(), "restored phase must match");
    assert_eq!(b.controller().switch_epoch(), a.controller().switch_epoch());
    assert_eq!(
        b.adapter_cfg().map(|x| x.ranks.clone()),
        a.adapter_cfg().map(|x| x.ranks.clone()),
        "assigned ranks must survive the worker-count change"
    );
    let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(
        bits(b.history().losses()),
        bits(a.history().losses()),
        "loss history must be bitwise identical"
    );
    // the parameters and the (re-partitioned) optimizer state are bitwise
    // the saved ones: re-gathering reproduces the checkpoint exactly
    assert_eq!(b.base_params(), a.base_params());
    let re = b.checkpoint();
    assert_eq!(re.zero_shards, 1);
    assert_eq!(re.opt_base, back.opt_base, "1-way re-gather must equal the 2-way save");
    assert_eq!(re.opt_lora, back.opt_lora);
    // evaluation is bitwise identical (eval order is worker-count free)
    let (la, aa) = a.evaluate().unwrap();
    let (lb, ab) = b.evaluate().unwrap();
    assert_eq!(la.to_bits(), lb.to_bits(), "restored eval loss differs");
    assert_eq!(aa.to_bits(), ab.to_bits(), "restored eval accuracy differs");

    // a different global batch means a different loss trajectory — but
    // the *schedule* semantics must continue: warmup still ends exactly
    // warmup_epochs after the restored switch, and training proceeds
    drive(&mut b, EPOCHS);
    let switch = b.controller().switch_epoch().unwrap();
    assert_eq!(
        b.controller().freeze_epoch(),
        Some(switch + 2), // micro_config's warmup_epochs
        "freeze must fire warmup_epochs after the restored switch"
    );
    assert!(b.phase().is_lora_only());
    for s in &b.stats {
        assert!(s.train_loss.is_finite(), "epoch {}: loss diverged", s.epoch);
    }
}

#[test]
fn stage3_checkpoint_restores_under_stage0_and_a_new_worker_count() {
    // the full stage-3 layout-independence claim: a parameter-sharded
    // 2-worker run's checkpoint (saved mid-warmup, when base AND adapter
    // spaces are both partitioned) restores onto one unsharded worker
    // with bitwise state — parameters, history, re-gathered optimizer
    // state — and the phase schedule continues
    let k = reference().k_warm;
    let mut a =
        Trainer::new(config_of(Variant { zero: ZeroStage::Zero3, pipeline: true, bucket_bytes: 0 })).unwrap();
    drive(&mut a, k);
    let ck = a.checkpoint();
    assert_eq!(ck.stage, ZeroStage::Zero3, "checkpoint must carry the saving stage");
    assert_eq!(ck.zero_shards, 2);
    let path =
        std::env::temp_dir().join(format!("prelora_resume_z3wc_{}.ckpt", std::process::id()));
    ck.save(&path).unwrap();
    let back = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();

    let mut cfg = micro_config();
    cfg.train.dp.workers = 1;
    cfg.train.zero.stage = Some(ZeroStage::Off);
    let mut b = Trainer::new(cfg).unwrap();
    b.restore(&back).unwrap();
    assert_eq!(b.history().epochs(), k);
    assert_eq!(b.phase(), a.phase(), "restored phase must match");
    assert_eq!(
        b.base_params(),
        a.base_params(),
        "gathered stage-3 params must restore bitwise onto the replicated layout"
    );
    let re = b.checkpoint();
    assert_eq!(re.stage, ZeroStage::Off);
    assert_eq!(re.zero_shards, 1);
    assert_eq!(re.opt_base, back.opt_base, "re-gathered state must equal the stage-3 save");
    assert_eq!(re.opt_lora, back.opt_lora);
    // evaluation is bitwise identical (eval order is worker-count free)
    let (la, aa) = a.evaluate().unwrap();
    let (lb, ab) = b.evaluate().unwrap();
    assert_eq!(la.to_bits(), lb.to_bits(), "restored eval loss differs");
    assert_eq!(aa.to_bits(), ab.to_bits(), "restored eval accuracy differs");
    // the schedule continues: the freeze still fires warmup_epochs after
    // the restored switch, and training proceeds to completion
    drive(&mut b, EPOCHS);
    let switch = b.controller().switch_epoch().unwrap();
    assert_eq!(b.controller().freeze_epoch(), Some(switch + 2));
    assert!(b.phase().is_lora_only());
}

// ---------------------------------------------------------------------------
// guard rails: config mismatches must be loud errors, not silent drift
// ---------------------------------------------------------------------------

#[test]
fn resume_rejects_seed_and_schedule_mismatches() {
    let mut a = Trainer::new(config_of(DEFAULT)).unwrap();
    drive(&mut a, 2);
    let ck = a.checkpoint();

    let mut cfg = config_of(DEFAULT);
    cfg.seed = 1; // reference seed is 0
    let mut b = Trainer::new(cfg).unwrap();
    let err = b.restore(&ck).unwrap_err().to_string();
    assert!(err.contains("seed"), "{err}");

    let mut cfg = config_of(DEFAULT);
    cfg.train.epochs = EPOCHS + 4; // would reshape the cosine schedule
    let mut b = Trainer::new(cfg).unwrap();
    let err = b.restore(&ck).unwrap_err().to_string();
    assert!(err.contains("LR schedule"), "{err}");
}
