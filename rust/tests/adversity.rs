//! The adversity matrix: deterministic fault injection
//! (`train.faults.*`, `prelora::faults`) swept across scenario × ZeRO
//! stage × PreLoRA phase. Every cell asserts one of exactly two
//! outcomes, always under a per-cell watchdog:
//!
//! * **bitwise-identical recovery** — scheduling faults (compute
//!   stragglers, wire delays) and kill-then-resume must reproduce the
//!   uninterrupted reference trajectory bit for bit; or
//! * **a loud, contextful error** — panics, mid-step aborts, dropped
//!   peers, corrupted frames and torn checkpoint writes must fail with
//!   the fault's coordinates in the message. Never a hang, never silent
//!   corruption.
//!
//! Cell map (stage Off is the replicated baseline; Zero3 adds parameter
//! sharding — the ZeRO contract makes all stages bitwise-equal, so one
//! reference fingerprint serves both):
//!
//! | cell                                   | scenario      | stage | phase  | outcome            |
//! |----------------------------------------|---------------|-------|--------|--------------------|
//! | straggler_in_full_phase_is_invisible   | straggle      | Off   | Full   | bitwise            |
//! | straggler_in_warmup_is_invisible       | straggle      | Off   | Warmup | bitwise            |
//! | straggler_in_lora_phase_is_invisible   | straggle      | Off   | Lora   | bitwise            |
//! | straggler_under_zero3_full             | straggle      | Zero3 | Full   | bitwise            |
//! | straggler_under_zero3_warmup           | straggle      | Zero3 | Warmup | bitwise            |
//! | straggler_under_zero3_lora             | straggle      | Zero3 | Lora   | bitwise            |
//! | worker_panic_in_full_phase_is_loud     | panic         | Off   | Full   | contextful error   |
//! | worker_panic_in_warmup_is_loud         | panic         | Off   | Warmup | contextful error   |
//! | worker_panic_under_zero3_lora_is_loud  | panic         | Zero3 | Lora   | contextful error   |
//! | midstep_abort_in_warmup_is_loud        | abort         | Off   | Warmup | contextful error   |
//! | midstep_abort_under_zero3_is_loud      | abort         | Zero3 | Warmup | contextful error   |
//! | torn_header_write_fails_loud_on_load   | ckpt-torn     | Off   | —      | contextful error   |
//! | torn_payload_write_fails_loud_on_load  | ckpt-torn     | Off   | —      | contextful error   |
//! | kill_then_resume_in_warmup             | abort+resume  | Off   | Warmup | bitwise            |
//! | kill_then_resume_under_zero3_lora      | abort+resume  | Zero3 | Lora   | bitwise            |
//! | same_plan_same_bits                    | straggle ×2   | Off   | Warmup | identical outcomes |
//! | same_plan_same_error                   | panic ×2      | Off   | Warmup | identical errors   |
//! | tcp_stall_trips_the_watchdog           | net-stall     | Off   | Full   | contextful error   |
//! | tcp_peer_drop_is_loud_on_both_ranks    | net-drop      | Off   | Full   | contextful error   |
//! | tcp_corrupt_frame_is_rejected          | net-corrupt   | Off   | Full   | contextful error   |
//! | tcp_delays_keep_bitwise_parity         | net-delay     | Off   | Full   | bitwise            |
//!
//! Requires `make artifacts` (vit-micro) to have run; the tcp cells also
//! need the `prelora` binary (cargo builds it for integration tests).

use std::io::Write;
use std::process::Command;
use std::sync::{mpsc, OnceLock};
use std::thread;
use std::time::Duration;

use prelora::config::RunConfig;
use prelora::dist::ZeroStage;
use prelora::trainer::{Checkpoint, Trainer};

const EPOCHS: usize = 16;

/// Per-cell watchdog: a fault scenario may fail, but it may never hang.
/// The cell body runs on its own thread; blowing the deadline panics the
/// test with the cell's name instead of letting the harness sit forever.
fn cell<T: Send + 'static>(
    name: &'static str,
    deadline: Duration,
    body: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = mpsc::channel();
    let worker = thread::Builder::new()
        .name(format!("cell-{name}"))
        .spawn(move || {
            let _ = tx.send(body());
        })
        .unwrap();
    match rx.recv_timeout(deadline) {
        Ok(v) => {
            let _ = worker.join();
            v
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => match worker.join() {
            Err(p) => std::panic::resume_unwind(p),
            Ok(()) => panic!("cell '{name}' worker exited without a result"),
        },
        Err(mpsc::RecvTimeoutError::Timeout) => panic!(
            "adversity cell '{name}' hung past {deadline:?} — a fault must fail \
             loudly, never hang"
        ),
    }
}

const DEADLINE: Duration = Duration::from_secs(300);

/// Mirrors `tests/resume.rs::micro_config`: relaxed thresholds so the
/// micro model crosses both phase boundaries within [`EPOCHS`].
fn micro_config(stage: ZeroStage, run_name: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "vit-micro".into();
    cfg.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    cfg.run_name = run_name.into();
    cfg.train.epochs = EPOCHS;
    cfg.train.data.train_samples = 192;
    cfg.train.data.val_samples = 64;
    cfg.train.eval_every = 4;
    cfg.train.dp.workers = 2;
    cfg.train.pipeline.enabled = true;
    // explicit, so the trajectory is stable against the integration
    // suite's PRELORA_TEST_ZERO_STAGE env knob
    cfg.train.zero.stage = Some(stage);
    cfg.prelora.tau = 6.0;
    cfg.prelora.zeta = 25.0;
    cfg.prelora.windows = 2;
    cfg.prelora.window_epochs = 2;
    cfg.prelora.warmup_epochs = 2;
    cfg
}

/// Floats as raw bits so equality is exact and NaN-proof.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Fingerprint {
    losses: Vec<u64>,
    grad_norms: Vec<u64>,
    lrs: Vec<u64>,
    phases: Vec<&'static str>,
    switch_epoch: Option<usize>,
    freeze_epoch: Option<usize>,
    base: Vec<u32>,
}

fn fingerprint(t: &Trainer) -> Fingerprint {
    Fingerprint {
        losses: t.stats.iter().map(|s| s.train_loss.to_bits()).collect(),
        grad_norms: t.stats.iter().map(|s| s.grad_norm.to_bits()).collect(),
        lrs: t.stats.iter().map(|s| s.lr.to_bits()).collect(),
        phases: t.stats.iter().map(|s| s.phase).collect(),
        switch_epoch: t.controller().switch_epoch(),
        freeze_epoch: t.controller().freeze_epoch(),
        base: t.base_params().iter().map(|x| x.to_bits()).collect(),
    }
}

fn drive(t: &mut Trainer, upto: usize) {
    while t.history().epochs() < upto {
        t.run_epoch().expect("epoch failed");
    }
}

struct Reference {
    fp: Fingerprint,
    /// An epoch strictly inside each phase, each a fault coordinate.
    k_full: usize,
    k_warm: usize,
    k_lora: usize,
}

/// The uninterrupted, fault-free reference (computed once, shared by
/// every bitwise cell — including the ZeRO-3 ones, which the stage
/// contract pins to the same bits).
fn reference() -> &'static Reference {
    static REF: OnceLock<Reference> = OnceLock::new();
    REF.get_or_init(|| {
        let mut t = Trainer::new(micro_config(ZeroStage::Off, "adv-ref")).unwrap();
        drive(&mut t, EPOCHS);
        let fp = fingerprint(&t);
        let (Some(switch), Some(freeze)) = (fp.switch_epoch, fp.freeze_epoch) else {
            panic!("reference run must cross both phase boundaries; got {fp:?}");
        };
        assert!(switch + 1 < freeze, "need an epoch strictly inside warmup");
        assert!(freeze + 1 < EPOCHS, "need epochs after the freeze");
        Reference { fp, k_full: 1, k_warm: switch + 1, k_lora: freeze + 1 }
    })
}

// ---------------------------------------------------------------------------
// stragglers: deterministic compute delays must be bitwise invisible
// ---------------------------------------------------------------------------

fn assert_straggler_invisible(stage: ZeroStage, k: usize, tag: &'static str) {
    // two stragglers: worker 0 at step 0, worker 1 at step 1 of epoch k
    let mut cfg = micro_config(stage, "adv-straggle");
    cfg.train.faults.plan = format!("straggle@{k}.0.0:ms=20;straggle@{k}.1.1:ms=12");
    let mut t = Trainer::new(cfg).unwrap();
    drive(&mut t, EPOCHS);
    assert_eq!(
        fingerprint(&t),
        reference().fp,
        "{tag}: a straggling worker must not change the trajectory"
    );
}

#[test]
fn straggler_in_full_phase_is_invisible() {
    cell("straggler_in_full_phase_is_invisible", DEADLINE, || {
        let k = reference().k_full;
        assert_straggler_invisible(ZeroStage::Off, k, "full/off");
    });
}

#[test]
fn straggler_in_warmup_is_invisible() {
    cell("straggler_in_warmup_is_invisible", DEADLINE, || {
        let k = reference().k_warm;
        assert_straggler_invisible(ZeroStage::Off, k, "warmup/off");
    });
}

#[test]
fn straggler_in_lora_phase_is_invisible() {
    cell("straggler_in_lora_phase_is_invisible", DEADLINE, || {
        let k = reference().k_lora;
        assert_straggler_invisible(ZeroStage::Off, k, "lora/off");
    });
}

#[test]
fn straggler_under_zero3_full() {
    cell("straggler_under_zero3_full", DEADLINE, || {
        let k = reference().k_full;
        assert_straggler_invisible(ZeroStage::Zero3, k, "full/zero3");
    });
}

#[test]
fn straggler_under_zero3_warmup() {
    cell("straggler_under_zero3_warmup", DEADLINE, || {
        let k = reference().k_warm;
        assert_straggler_invisible(ZeroStage::Zero3, k, "warmup/zero3");
    });
}

#[test]
fn straggler_under_zero3_lora() {
    cell("straggler_under_zero3_lora", DEADLINE, || {
        let k = reference().k_lora;
        assert_straggler_invisible(ZeroStage::Zero3, k, "lora/zero3");
    });
}

// ---------------------------------------------------------------------------
// worker panic / mid-step abort: loud, contextful, bounded
// ---------------------------------------------------------------------------

/// Drive to epoch `k`, then run the faulted epoch and return its error.
fn faulted_epoch_error(stage: ZeroStage, k: usize, plan: String) -> String {
    let mut cfg = micro_config(stage, "adv-loud");
    cfg.train.faults.plan = plan;
    let mut t = Trainer::new(cfg).unwrap();
    drive(&mut t, k);
    let e = t.run_epoch().expect_err("the armed epoch must fail");
    format!("{e:#}")
}

fn assert_panic_is_loud(stage: ZeroStage, k: usize, tag: &'static str) {
    let msg = faulted_epoch_error(stage, k, format!("panic@{k}.1.1"));
    assert!(msg.contains("worker 1 panicked"), "{tag}: must name the worker: {msg}");
    assert!(msg.contains("fault injected"), "{tag}: must say it was deliberate: {msg}");
    assert!(msg.contains(&format!("epoch {k}, step 1")), "{tag}: must carry coordinates: {msg}");
}

#[test]
fn worker_panic_in_full_phase_is_loud() {
    cell("worker_panic_in_full_phase_is_loud", DEADLINE, || {
        let k = reference().k_full;
        assert_panic_is_loud(ZeroStage::Off, k, "full/off");
    });
}

#[test]
fn worker_panic_in_warmup_is_loud() {
    cell("worker_panic_in_warmup_is_loud", DEADLINE, || {
        let k = reference().k_warm;
        assert_panic_is_loud(ZeroStage::Off, k, "warmup/off");
    });
}

#[test]
fn worker_panic_under_zero3_lora_is_loud() {
    cell("worker_panic_under_zero3_lora_is_loud", DEADLINE, || {
        let k = reference().k_lora;
        assert_panic_is_loud(ZeroStage::Zero3, k, "lora/zero3");
    });
}

fn assert_abort_is_loud(stage: ZeroStage, k: usize, tag: &'static str) {
    let msg = faulted_epoch_error(stage, k, format!("abort@{k}.1.0"));
    assert!(msg.contains("fault injected"), "{tag}: must say it was deliberate: {msg}");
    assert!(msg.contains("abort"), "{tag}: must name the scenario: {msg}");
    assert!(msg.contains(&format!("epoch {k}, step 1")), "{tag}: must carry coordinates: {msg}");
}

#[test]
fn midstep_abort_in_warmup_is_loud() {
    cell("midstep_abort_in_warmup_is_loud", DEADLINE, || {
        let k = reference().k_warm;
        assert_abort_is_loud(ZeroStage::Off, k, "warmup/off");
    });
}

#[test]
fn midstep_abort_under_zero3_is_loud() {
    cell("midstep_abort_under_zero3_is_loud", DEADLINE, || {
        let k = reference().k_warm;
        assert_abort_is_loud(ZeroStage::Zero3, k, "warmup/zero3");
    });
}

// ---------------------------------------------------------------------------
// torn checkpoint writes: the next load must fail loudly, never parse junk
// ---------------------------------------------------------------------------

/// Run 4 epochs twice into the same rolling checkpoint path: once clean
/// (to learn the deterministic on-disk size and prove the file loads),
/// once with a `ckpt-torn` fault cutting the file at `byte_of(size)`.
fn torn_cell(tag: &str, byte_of: impl Fn(u64) -> u64, expect: &str) {
    let tmp = std::env::temp_dir().join(format!("prelora_adv_torn_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let mut cfg = micro_config(ZeroStage::Off, "adv-torn");
    cfg.results_dir = tmp.to_str().unwrap().to_string();
    cfg.train.epochs = 4;
    cfg.train.checkpoint_every = 4;
    let mut clean = Trainer::new(cfg.clone()).unwrap();
    clean.run().unwrap();
    let path = clean.checkpoint_path();
    let len = std::fs::metadata(&path).unwrap().len();
    Checkpoint::load(&path).unwrap_or_else(|e| panic!("{tag}: clean file must load: {e:#}"));

    let cut = byte_of(len);
    cfg.train.faults.plan = format!("ckpt-torn@4.0.0:byte={cut}");
    let mut torn = Trainer::new(cfg).unwrap();
    torn.run().unwrap(); // the tear happens at save time; training is clean
    assert_eq!(std::fs::metadata(&path).unwrap().len(), cut, "{tag}: the cut must be exact");
    let e = Checkpoint::load(&path).expect_err("a torn checkpoint must not load");
    let msg = format!("{e:#}");
    assert!(msg.contains(expect), "{tag}: load error must have context: {msg}");
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn torn_header_write_fails_loud_on_load() {
    cell("torn_header_write_fails_loud_on_load", DEADLINE, || {
        torn_cell("header", |_| 3, "header");
    });
}

#[test]
fn torn_payload_write_fails_loud_on_load() {
    cell("torn_payload_write_fails_loud_on_load", DEADLINE, || {
        torn_cell("payload", |len| len - 8, "truncated");
    });
}

// ---------------------------------------------------------------------------
// kill-then-resume: abort a run mid-flight, resume the rolling
// checkpoint, and land on the reference trajectory bit for bit
// ---------------------------------------------------------------------------

fn assert_kill_resume_matches(stage: ZeroStage, k: usize, tag: &str) {
    let tmp = std::env::temp_dir().join(format!(
        "prelora_adv_resume_{}_{tag}",
        std::process::id()
    ));
    std::fs::create_dir_all(&tmp).unwrap();
    let mut cfg = micro_config(stage, "adv-kill");
    cfg.results_dir = tmp.to_str().unwrap().to_string();
    cfg.train.checkpoint_every = 2;
    cfg.train.faults.plan = format!("abort@{k}.1.0");
    let mut a = Trainer::new(cfg.clone()).unwrap();
    let e = a.run().expect_err("the armed run must die");
    assert!(format!("{e:#}").contains("fault injected"), "{tag}: {e:#}");
    assert_eq!(a.history().epochs(), k, "{tag}: the run must die inside epoch {k}");

    // the rolling file holds the last even-epoch save before the kill
    let back = Checkpoint::load(a.checkpoint_path()).unwrap();
    assert_eq!(back.epoch, k - (k % 2), "{tag}: rolling save cadence");
    cfg.train.faults.plan = String::new();
    cfg.train.checkpoint_every = 0;
    let mut b = Trainer::new(cfg).unwrap();
    b.restore(&back).unwrap();
    drive(&mut b, EPOCHS);
    assert_eq!(
        fingerprint(&b),
        reference().fp,
        "{tag}: kill-then-resume must equal the uninterrupted run"
    );
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn kill_then_resume_in_warmup() {
    cell("kill_then_resume_in_warmup", DEADLINE, || {
        let k = reference().k_warm;
        assert_kill_resume_matches(ZeroStage::Off, k, "warmup-off");
    });
}

#[test]
fn kill_then_resume_under_zero3_lora() {
    cell("kill_then_resume_under_zero3_lora", DEADLINE, || {
        let k = reference().k_lora;
        assert_kill_resume_matches(ZeroStage::Zero3, k, "lora-zero3");
    });
}

// ---------------------------------------------------------------------------
// determinism of the faults themselves: same seed + same plan twice
// must yield byte-identical outcomes — trajectories AND error text
// ---------------------------------------------------------------------------

#[test]
fn same_plan_same_bits() {
    cell("same_plan_same_bits", DEADLINE, || {
        let k = reference().k_warm;
        let run = || {
            let mut cfg = micro_config(ZeroStage::Off, "adv-repro");
            cfg.train.faults.plan = format!("straggle@{k}.0.0:ms=15;straggle@{k}.0.1:ms=5");
            let mut t = Trainer::new(cfg).unwrap();
            drive(&mut t, EPOCHS);
            fingerprint(&t)
        };
        assert_eq!(run(), run(), "one plan, one seed, one trajectory");
    });
}

#[test]
fn same_plan_same_error() {
    cell("same_plan_same_error", DEADLINE, || {
        let k = reference().k_warm;
        let run = || faulted_epoch_error(ZeroStage::Off, k, format!("panic@{k}.1.1"));
        assert_eq!(run(), run(), "one plan, one seed, one error message");
    });
}

// ---------------------------------------------------------------------------
// tcp cells: real OS processes over loopback, faults in the wire layer
// ---------------------------------------------------------------------------

fn tcp_config_toml(results_dir: &std::path::Path, epochs: usize, plan: &str) -> String {
    format!(
        r#"
model = "vit-micro"
artifacts_dir = "{artifacts}"
results_dir = "{results}"
run_name = "adv"
seed = 0

[train]
epochs = {epochs}
eval_every = 4
checkpoint_every = {epochs}

[train.data]
train_samples = 192
val_samples = 64

[train.zero]
stage = 0

[train.faults]
plan = "{plan}"

[prelora]
tau = 6.0
zeta = 25.0
windows = 2
window_epochs = 2
warmup_epochs = 2
"#,
        artifacts = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")),
        results = results_dir.display(),
    )
}

fn wait_for_advert(path: &std::path::Path) -> String {
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        if let Ok(s) = std::fs::read_to_string(path) {
            let s = s.trim().to_string();
            if !s.is_empty() {
                return s;
            }
        }
        assert!(
            std::time::Instant::now() < deadline,
            "rank 0 never advertised its address at {}",
            path.display()
        );
        thread::sleep(Duration::from_millis(20));
    }
}

/// Launch a 2-rank group (port-0 rendezvous via `PRELORA_TCP_ADVERTISE`)
/// and return each rank's `(success, stderr)` without asserting — fault
/// cells expect failures and inspect the error text.
fn run_tcp_pair(
    cfg_path: &std::path::Path,
    tmp: &std::path::Path,
    run_name: &str,
    timeout_ms: u32,
) -> Vec<(bool, String)> {
    let advert = tmp.join("root.addr");
    let spawn = |rank: usize, peers: &str| {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_prelora"));
        cmd.args([
            "train",
            "--config",
            cfg_path.to_str().unwrap(),
            "--run-name",
            run_name,
            "--dist",
            "tcp",
            "--rank",
            &rank.to_string(),
            "--peers",
            peers,
            "--connect-timeout-ms",
            &timeout_ms.to_string(),
        ])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped());
        if rank == 0 {
            cmd.env("PRELORA_TCP_ADVERTISE", &advert);
        }
        cmd.spawn().unwrap_or_else(|e| panic!("spawning rank {rank}: {e}"))
    };
    let mut children = vec![spawn(0, "127.0.0.1:0,127.0.0.1:0")];
    let root = wait_for_advert(&advert);
    children.push(spawn(1, &format!("{root},127.0.0.1:0")));
    children
        .into_iter()
        .map(|c| {
            let out = c.wait_with_output().unwrap();
            (out.status.success(), String::from_utf8_lossy(&out.stderr).into_owned())
        })
        .collect()
}

fn tcp_cell_dir(tag: &str) -> std::path::PathBuf {
    let tmp = std::env::temp_dir().join(format!("prelora_adv_tcp_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    tmp
}

fn write_cfg(tmp: &std::path::Path, toml: &str) -> std::path::PathBuf {
    let cfg_path = tmp.join("adv.toml");
    let mut f = std::fs::File::create(&cfg_path).unwrap();
    f.write_all(toml.as_bytes()).unwrap();
    cfg_path
}

#[test]
fn tcp_stall_trips_the_watchdog() {
    cell("tcp_stall_trips_the_watchdog", DEADLINE, || {
        let tmp = tcp_cell_dir("stall");
        // rank 1 stalls 8s mid-collective; rank 0's 5s recv watchdog
        // must fire first and name the silent rank
        let cfg = write_cfg(&tmp, &tcp_config_toml(&tmp, 2, "net-stall@1.0.1:ms=8000"));
        let out = run_tcp_pair(&cfg, &tmp, "adv-stall", 5000);
        assert!(!out[0].0, "rank 0 must fail: {}", out[0].1);
        assert!(
            out[0].1.contains("stalled") && out[0].1.contains("rank 1"),
            "rank 0 must name the stalled rank: {}",
            out[0].1
        );
        assert!(!out[1].0, "rank 1 must fail: {}", out[1].1);
        assert!(out[1].1.contains("fault injected"), "{}", out[1].1);
        std::fs::remove_dir_all(&tmp).ok();
    });
}

#[test]
fn tcp_peer_drop_is_loud_on_both_ranks() {
    cell("tcp_peer_drop_is_loud_on_both_ranks", DEADLINE, || {
        let tmp = tcp_cell_dir("drop");
        let cfg = write_cfg(&tmp, &tcp_config_toml(&tmp, 2, "net-drop@1.0.1"));
        let out = run_tcp_pair(&cfg, &tmp, "adv-drop", 30000);
        assert!(!out[0].0, "rank 0 must fail: {}", out[0].1);
        assert!(out[0].1.contains("rank 1"), "rank 0 must name the dead rank: {}", out[0].1);
        assert!(!out[1].0, "rank 1 must fail: {}", out[1].1);
        assert!(
            out[1].1.contains("fault injected") && out[1].1.contains("dropped"),
            "{}",
            out[1].1
        );
        std::fs::remove_dir_all(&tmp).ok();
    });
}

#[test]
fn tcp_corrupt_frame_is_rejected() {
    cell("tcp_corrupt_frame_is_rejected", DEADLINE, || {
        let tmp = tcp_cell_dir("corrupt");
        let cfg = write_cfg(&tmp, &tcp_config_toml(&tmp, 2, "net-corrupt@1.0.1"));
        let out = run_tcp_pair(&cfg, &tmp, "adv-corrupt", 30000);
        assert!(!out[0].0, "rank 0 must fail: {}", out[0].1);
        assert!(out[0].1.contains("CRC"), "rank 0 must reject the frame by CRC: {}", out[0].1);
        assert!(!out[1].0, "rank 1 must fail too: {}", out[1].1);
        std::fs::remove_dir_all(&tmp).ok();
    });
}

#[test]
fn tcp_delays_keep_bitwise_parity() {
    cell("tcp_delays_keep_bitwise_parity", DEADLINE, || {
        let tmp = tcp_cell_dir("delay");
        // one delay per rank, different steps; the run must still match
        // the in-process reference bit for bit. The same config drives
        // both legs: net faults are wire-layer, so the local-transport
        // reference is untouched by the plan.
        let toml = tcp_config_toml(&tmp, 6, "net-delay@1.0.0:ms=30;net-delay@2.0.1:ms=30");
        let cfg_path = write_cfg(&tmp, &toml);
        let mut cfg = RunConfig::from_toml_file(&cfg_path).unwrap();
        cfg.train.dp.workers = 2; // the tcp group's world is two ranks
        let mut reference = Trainer::new(cfg).unwrap();
        reference.run().unwrap();
        let want = reference.checkpoint();

        let out = run_tcp_pair(&cfg_path, &tmp, "adv-delay", 30000);
        for (rank, (ok, stderr)) in out.iter().enumerate() {
            assert!(ok, "rank {rank} must survive a delay:\n{stderr}");
        }
        let got = Checkpoint::load(tmp.join("adv-delay.ckpt")).unwrap();
        assert_eq!(got.epoch, want.epoch);
        assert_eq!(got.base, want.base, "delayed run must keep bitwise parity");
        assert_eq!(got.lora, want.lora);
        assert_eq!(got.opt_base, want.opt_base);
        assert_eq!(got.opt_lora, want.opt_lora);
        let bits = |ck: &Checkpoint| {
            ck.trajectory
                .as_ref()
                .expect("v3 checkpoint carries the trajectory")
                .stats
                .iter()
                .map(|s| (s.train_loss.to_bits(), s.grad_norm.to_bits()))
                .collect::<Vec<_>>()
        };
        assert_eq!(bits(&got), bits(&want), "per-epoch observables must be bitwise equal");
        std::fs::remove_dir_all(&tmp).ok();
    });
}
