//! Integration tests: the full PreLoRA lifecycle through real artifacts,
//! plus property-based invariants over the coordinator components.
//!
//! Requires `make artifacts` (vit-micro) to have run.

use std::collections::BTreeMap;

use prelora::config::{RunConfig, StrictnessPreset, TrainConfig};
use prelora::coordinator::Phase;
use prelora::data::{Dataset, EpochLoader, SynthSpec};
use prelora::dist::{collective_for, strategy_for, ModelState, ZeroStage};
use prelora::dp::{
    all_gather, reduce_bucket, reduce_mean, reduce_owned, reduce_scatter, scatter, Algorithm,
    BucketPlan, GradResult, Reduced,
};
use prelora::pipeline::UpdateStage;
use prelora::rank::{assign_ranks, rank_buckets};
use prelora::tensor::Pcg64;
use prelora::trainer::{Checkpoint, Trainer};
use prelora::util::prop::{check, Arbitrary};

fn micro_config(epochs: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "vit-micro".into();
    cfg.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    cfg.run_name = "itest".into();
    cfg.train.epochs = epochs;
    cfg.train.data.train_samples = 192;
    cfg.train.data.val_samples = 64;
    cfg.train.eval_every = 4;
    // relaxed thresholds so the micro run switches quickly
    cfg.prelora.tau = 6.0;
    cfg.prelora.zeta = 25.0;
    cfg.prelora.windows = 2;
    cfg.prelora.window_epochs = 2;
    cfg.prelora.warmup_epochs = 2;
    // CI knob: rerun the whole suite under one forced ZeRO stage (the
    // smoke job runs it once more with PRELORA_TEST_ZERO_STAGE=3, so
    // every lifecycle/pipeline/restore test also exercises parameter
    // sharding). Tests that sweep stages explicitly override this.
    if let Ok(s) = std::env::var("PRELORA_TEST_ZERO_STAGE") {
        let stage: ZeroStage = s
            .parse()
            .unwrap_or_else(|e| panic!("bad PRELORA_TEST_ZERO_STAGE: {e}"));
        cfg.train.zero.stage = Some(stage);
    }
    // CI knob: rerun the whole suite with bucketed gradient sync forced on
    // (the smoke job runs it once more with PRELORA_TEST_BUCKET_BYTES=256,
    // so every lifecycle/pipeline/restore test also exercises the
    // bucket-level overlap path). Tests that sweep bucket sizes explicitly
    // override this.
    if let Ok(s) = std::env::var("PRELORA_TEST_BUCKET_BYTES") {
        let bytes: usize = s
            .parse()
            .unwrap_or_else(|e| panic!("bad PRELORA_TEST_BUCKET_BYTES: {e}"));
        cfg.train.pipeline.bucket_bytes = bytes;
    }
    cfg
}

#[test]
fn full_prelora_lifecycle_reaches_lora_phase_and_learns() {
    let mut t = Trainer::new(micro_config(16)).unwrap();
    let summary = t.run().unwrap();
    // the controller must have walked Full -> Warmup -> LoraOnly
    assert!(summary.switch_epoch.is_some(), "never switched");
    assert!(summary.freeze_epoch.is_some(), "never froze");
    assert!(t.phase().is_lora_only());
    // learning happened overall
    let first = t.stats[0].train_loss;
    let last = t.stats.last().unwrap().train_loss;
    assert!(last < first - 0.3, "no learning: {first} -> {last}");
    // trainable params dropped to a small fraction (paper: ~10%)
    let frac = summary.trainable_lora.unwrap() as f64 / summary.trainable_full as f64;
    assert!(frac < 0.35, "trainable fraction {frac}");
    // rank histogram only uses bucket ranks
    let c = &t.manifest.config;
    let buckets = rank_buckets(c.r_min, c.r_max);
    for r in summary.rank_histogram.unwrap().keys() {
        assert!(buckets.contains(r), "rank {r} not in {buckets:?}");
    }
    // memory accounting: lora phase cheaper than full phase (requires at
    // least one post-freeze epoch to have run)
    assert!(
        summary.by_phase.get("lora").map_or(0, |a| a.epochs) > 0,
        "no lora-phase epochs ran; freeze too late for this test's length"
    );
    assert!(summary.memory_saving_frac.unwrap() > 0.0);
}

#[test]
fn baseline_never_switches() {
    let mut cfg = micro_config(6);
    cfg.prelora.enabled = false;
    let mut t = Trainer::new(cfg).unwrap();
    let summary = t.run().unwrap();
    assert!(summary.switch_epoch.is_none());
    assert!(t.phase().is_full());
    assert!(summary.by_phase.contains_key("full"));
    assert!(!summary.by_phase.contains_key("lora"));
}

#[test]
fn strict_preset_switches_later_than_relaxed() {
    let run = |preset: StrictnessPreset| {
        let mut cfg = micro_config(20);
        cfg.prelora = cfg.prelora.with_preset(preset);
        cfg.prelora.windows = 2;
        cfg.prelora.window_epochs = 2;
        let mut t = Trainer::new(cfg).unwrap();
        for _ in 0..20 {
            t.run_epoch().unwrap();
            if t.controller().switch_epoch().is_some() {
                break;
            }
        }
        t.controller().switch_epoch()
    };
    let relaxed = run(StrictnessPreset::Exp1);
    let strict = run(StrictnessPreset::Exp3);
    // Exp1 must not switch after Exp3 (strictly-ordered thresholds);
    // either may not switch at all in 20 micro-epochs
    if let (Some(r), Some(s)) = (relaxed, strict) {
        assert!(r <= s, "relaxed switched at {r}, strict at {s}");
    }
    if relaxed.is_none() {
        assert!(strict.is_none(), "strict switched but relaxed did not");
    }
}

#[test]
fn dp_workers_match_single_worker_numerics() {
    // 2-worker global batch == 1-worker with the same sample set is NOT
    // the same batch split, so instead check determinism: same config
    // twice => identical loss trajectories.
    let mut a = Trainer::new(micro_config(3)).unwrap();
    let mut b = Trainer::new(micro_config(3)).unwrap();
    for _ in 0..3 {
        let sa = a.run_epoch().unwrap();
        let sb = b.run_epoch().unwrap();
        assert_eq!(sa.train_loss, sb.train_loss, "non-deterministic training");
    }
}

#[test]
fn threaded_two_worker_run_is_deterministic() {
    let make = || {
        let mut cfg = micro_config(2);
        cfg.train.dp.workers = 2;
        cfg.train.dp.threaded = true;
        Trainer::new(cfg).unwrap()
    };
    let mut a = make();
    let mut b = make();
    for _ in 0..2 {
        let sa = a.run_epoch().unwrap();
        let sb = b.run_epoch().unwrap();
        assert_eq!(sa.train_loss, sb.train_loss);
    }
}

#[test]
fn pipeline_matches_sequential_bitwise_across_phase_switch() {
    // the determinism contract: with a fixed seed the staged pipeline and
    // the serial reference loop produce bit-identical per-epoch losses in
    // every phase, and the controller switches on the same epochs
    let run = |enabled: bool| {
        let mut cfg = micro_config(16);
        cfg.train.dp.workers = 2;
        cfg.train.pipeline.enabled = enabled;
        cfg.train.pipeline.prefetch_depth = 2;
        let mut t = Trainer::new(cfg).unwrap();
        let mut losses = Vec::new();
        for _ in 0..16 {
            losses.push(t.run_epoch().unwrap().train_loss);
        }
        (losses, t.controller().switch_epoch(), t.controller().freeze_epoch())
    };
    let (pipelined, ps, pf) = run(true);
    let (serial, ss, sf) = run(false);
    assert_eq!(pipelined, serial, "pipelined losses must be bit-identical");
    assert_eq!(ps, ss, "switch epoch must match");
    assert_eq!(pf, sf, "freeze epoch must match");
    assert!(
        ps.is_some() && pf.is_some(),
        "run must cross both phase boundaries to exercise the barrier"
    );
}

#[test]
fn zero_stages_match_unsharded_bitwise_across_phase_switch() {
    // the dist::Strategy acceptance contract, every stage: at stage 1
    // (optimizer state sharded), stage 2 (+ gradient buffers
    // reduce-scattered terminally) or stage 3 (+ the parameters
    // themselves as owned partitions, working views gathered per step),
    // fixed-seed per-epoch losses are bit-identical to the unsharded path
    // across the Full -> Warmup -> LoraOnly lifecycle (every shard layout
    // re-partitions at the switch), while per-worker optimizer state is
    // <= (1/N + eps) of the unsharded total — at stage 2+ per-worker
    // gradient bytes are ~1/N of grad_total_bytes, and at stage 3
    // per-rank parameter bytes are ~1/N of the replicated footprint
    let workers = 2;
    struct ZeroRun {
        losses: Vec<f64>,
        switch: Option<usize>,
        freeze: Option<usize>,
        opt_per: Vec<usize>,
        opt_tot: Vec<usize>,
        grad_per: Vec<usize>,
        grad_tot: Vec<usize>,
        param_per: Vec<usize>,
        param_tot: Vec<usize>,
    }
    let run = |stage: ZeroStage| {
        let mut cfg = micro_config(16);
        cfg.train.dp.workers = workers;
        cfg.train.zero.stage = Some(stage); // explicit: the sweep overrides the CI env knob
        let mut t = Trainer::new(cfg).unwrap();
        let mut out = ZeroRun {
            losses: Vec::new(),
            switch: None,
            freeze: None,
            opt_per: Vec::new(),
            opt_tot: Vec::new(),
            grad_per: Vec::new(),
            grad_tot: Vec::new(),
            param_per: Vec::new(),
            param_tot: Vec::new(),
        };
        for _ in 0..16 {
            out.losses.push(t.run_epoch().unwrap().train_loss);
            let mem = t.memory();
            out.opt_per.push(mem.optimizer_bytes);
            out.opt_tot.push(mem.optimizer_total_bytes);
            out.grad_per.push(mem.grad_bytes);
            out.grad_tot.push(mem.grad_total_bytes);
            out.param_per.push(mem.param_bytes_per_rank);
            out.param_tot.push(mem.base_param_bytes + mem.lora_param_bytes);
        }
        out.switch = t.controller().switch_epoch();
        out.freeze = t.controller().freeze_epoch();
        out
    };
    let off = run(ZeroStage::Off);
    let s1 = run(ZeroStage::Zero1);
    let s2 = run(ZeroStage::Zero2);
    let s3 = run(ZeroStage::Zero3);
    for (name, z) in [("stage 1", &s1), ("stage 2", &s2), ("stage 3", &s3)] {
        assert_eq!(z.losses, off.losses, "{name}: losses must be bit-identical to unsharded");
        assert_eq!(z.switch, off.switch, "{name}: switch epoch must match");
        assert_eq!(z.freeze, off.freeze, "{name}: freeze epoch must match");
        // total state is layout-independent
        assert_eq!(z.opt_tot, off.opt_tot, "{name}: optimizer total changed");
        assert_eq!(z.grad_tot, off.grad_tot, "{name}: gradient total changed");
        assert_eq!(z.param_tot, off.param_tot, "{name}: parameter total changed");
        for (epoch, (&per, &tot)) in z.opt_per.iter().zip(&z.opt_tot).enumerate() {
            // eps: ceil-chunking rounds each state buffer up by at most
            // one element per shard (two optimizers of two bufs in warmup)
            assert!(
                per as f64 <= tot as f64 / workers as f64 + 32.0,
                "{name} epoch {epoch}: per-worker state {per} B exceeds total {tot} B / {workers} + eps"
            );
            assert!(per > 0, "{name} epoch {epoch}: optimizer state vanished");
        }
    }
    assert!(
        off.switch.is_some() && off.freeze.is_some(),
        "run must cross both phase boundaries to exercise the shard-layout change"
    );
    // without ZeRO (and at stage 1) a worker holds the full buffers
    assert_eq!(off.opt_per, off.opt_tot);
    assert_eq!(off.grad_per, off.grad_tot);
    assert_eq!(off.param_per, off.param_tot);
    assert_eq!(s1.grad_per, s1.grad_tot, "stage 1 must keep gradients replicated");
    assert_eq!(s2.param_per, s2.param_tot, "stage 2 must keep parameters replicated");
    // stage 2+: per-worker gradient bytes are ~1/N of the replicated
    // footprint in every phase (ceil-chunked per live buffer: base and/or
    // LoRA, so at most 2 * 4-byte rounding)
    for (name, z) in [("stage 2", &s2), ("stage 3", &s3)] {
        for (epoch, (&per, &tot)) in z.grad_per.iter().zip(&z.grad_tot).enumerate() {
            assert!(
                per as f64 <= tot as f64 / workers as f64 + 8.0,
                "{name} epoch {epoch}: per-worker grads {per} B exceed total {tot} B / {workers} + eps"
            );
            assert!(per > 0, "{name} epoch {epoch}: gradient accounting vanished");
        }
    }
    // stage 3: per-rank parameter bytes are ~1/N of the replicated
    // footprint in every phase (base + LoRA spaces partition separately)
    for (epoch, (&per, &tot)) in s3.param_per.iter().zip(&s3.param_tot).enumerate() {
        assert!(
            per as f64 <= tot as f64 / workers as f64 + 8.0,
            "stage 3 epoch {epoch}: per-rank params {per} B exceed total {tot} B / {workers} + eps"
        );
        assert!(per > 0, "stage 3 epoch {epoch}: parameter accounting vanished");
    }
}

#[test]
fn zero3_matches_unsharded_bitwise_at_odd_worker_counts() {
    // the stage-3 acceptance property at a worker count that does not
    // divide the parameter spaces: losses, per-epoch mean grad norms and
    // the final base parameters are bitwise the unsharded run's across
    // the full Full -> Warmup -> LoraOnly lifecycle, while per-rank
    // parameter bytes shrink to ~1/3
    let workers = 3;
    let run = |stage: ZeroStage| {
        let mut cfg = micro_config(16);
        cfg.train.dp.workers = workers;
        cfg.train.zero.stage = Some(stage);
        let mut t = Trainer::new(cfg).unwrap();
        let mut losses = Vec::new();
        let mut norms = Vec::new();
        for _ in 0..16 {
            let s = t.run_epoch().unwrap();
            losses.push(s.train_loss.to_bits());
            norms.push(s.grad_norm.to_bits());
        }
        let mem = t.memory();
        (losses, norms, t.base_params(), t.controller().switch_epoch(), mem)
    };
    let (l_off, n_off, p_off, sw_off, _) = run(ZeroStage::Off);
    let (l_z3, n_z3, p_z3, sw_z3, mem) = run(ZeroStage::Zero3);
    assert_eq!(l_z3, l_off, "stage-3 losses must be bitwise the unsharded run's");
    assert_eq!(n_z3, n_off, "stage-3 grad norms must be bitwise the unsharded run's");
    assert_eq!(p_z3, p_off, "stage-3 final base params must be bitwise the unsharded run's");
    assert_eq!(sw_z3, sw_off, "switch epoch must match");
    assert!(sw_off.is_some(), "run must cross the phase boundary");
    let tot = mem.base_param_bytes + mem.lora_param_bytes;
    assert!(
        mem.param_bytes_per_rank as f64 <= tot as f64 / workers as f64 + 8.0,
        "per-rank params {} B must be ~1/{workers} of {tot} B",
        mem.param_bytes_per_rank
    );
}

#[test]
fn bucketed_sync_matches_whole_buffer_bitwise_across_stages_and_phase_switch() {
    // the bucketed-sync acceptance contract: with bucket-level overlap on,
    // fixed-seed per-epoch losses, grad norms and the final parameters are
    // bitwise the whole-buffer run's at every ZeRO stage and across the
    // Full -> Warmup -> LoraOnly lifecycle (bucket layouts re-derive at
    // each Repartition; comm_wait_s is timing-only and never compared)
    let run = |stage: ZeroStage, bucket_bytes: usize| {
        let mut cfg = micro_config(16);
        cfg.train.dp.workers = 2;
        // explicit: the sweep overrides both CI env knobs
        cfg.train.zero.stage = Some(stage);
        cfg.train.pipeline.bucket_bytes = bucket_bytes;
        let mut t = Trainer::new(cfg).unwrap();
        let mut losses = Vec::new();
        let mut norms = Vec::new();
        for _ in 0..16 {
            let s = t.run_epoch().unwrap();
            losses.push(s.train_loss.to_bits());
            norms.push(s.grad_norm.to_bits());
        }
        (losses, norms, t.base_params(), t.controller().switch_epoch())
    };
    let (l0, n0, p0, sw0) = run(ZeroStage::Off, 0);
    assert!(sw0.is_some(), "run must cross the phase boundary");
    for stage in [ZeroStage::Off, ZeroStage::Zero1, ZeroStage::Zero2, ZeroStage::Zero3] {
        // 1 KiB buckets split vit-micro's 77 984-byte base space into ~77
        // buckets (re-split per owned partition under sharding)
        let (l, n, p, sw) = run(stage, 1024);
        assert_eq!(l, l0, "{stage}: bucketed losses must be bitwise whole-buffer's");
        assert_eq!(n, n0, "{stage}: bucketed grad norms must be bitwise whole-buffer's");
        assert_eq!(p, p0, "{stage}: bucketed final params must be bitwise whole-buffer's");
        assert_eq!(sw, sw0, "{stage}: switch epoch must match");
    }
}

#[test]
fn sharded_checkpoint_restores_on_single_worker() {
    // a 2-way ZeRO run's checkpoint gathers optimizer shards to full
    // state; an unsharded single-worker trainer must restore it exactly
    let mut cfg = micro_config(16);
    cfg.train.dp.workers = 2;
    cfg.train.zero.stage = Some(ZeroStage::Zero2);
    let mut t = Trainer::new(cfg).unwrap();
    for _ in 0..16 {
        t.run_epoch().unwrap();
    }
    assert!(t.adapter_cfg().is_some(), "run never switched");
    let ck = t.checkpoint();
    assert_eq!(ck.zero_shards, 2);
    assert_eq!(ck.stage, ZeroStage::Zero2);
    assert!(ck.opt_lora.is_some(), "post-switch checkpoint must carry LoRA optimizer state");

    let path = std::env::temp_dir().join(format!("prelora_zero_{}.ckpt", std::process::id()));
    ck.save(&path).unwrap();
    let back = Checkpoint::load(&path).unwrap();
    assert_eq!(back.zero_shards, 2);
    assert_eq!(back.stage, ZeroStage::Zero2, "stage metadata must survive disk");
    assert_eq!(back.opt_lora, ck.opt_lora, "optimizer state must survive disk");

    let mut solo_cfg = micro_config(16); // 1 worker...
    solo_cfg.train.zero.stage = Some(ZeroStage::Off); // ...no sharding, env knob or not
    let mut solo = Trainer::new(solo_cfg).unwrap();
    solo.restore(&back).unwrap();
    let (l1, a1) = t.evaluate().unwrap();
    let (l2, a2) = solo.evaluate().unwrap();
    assert_eq!(l1, l2, "restored eval loss differs");
    assert_eq!(a1, a2, "restored eval accuracy differs");
    // re-gathering the restored (now 1-shard) state reproduces the saved
    // buffers exactly: gather(scatter(state)) is the identity
    let re = solo.checkpoint();
    assert_eq!(re.zero_shards, 1);
    assert_eq!(re.opt_lora, ck.opt_lora, "re-scattered state must gather back identically");
    std::fs::remove_file(path).unwrap();
}

#[test]
fn restore_roundtrips_adapter_state() {
    // drive past the switch so the checkpoint carries LoRA state
    let mut t = Trainer::new(micro_config(16)).unwrap();
    for _ in 0..16 {
        t.run_epoch().unwrap();
    }
    assert!(t.adapter_cfg().is_some(), "run never switched");
    let ck = t.checkpoint();
    let (l1, a1) = t.evaluate().unwrap();

    let mut fresh = Trainer::new(micro_config(16)).unwrap();
    assert!(fresh.adapter_cfg().is_none());
    fresh.restore(&ck).unwrap();
    let acfg = fresh.adapter_cfg().expect("restore must rebuild the adapter cfg");
    assert_eq!(acfg.ranks, t.adapter_cfg().unwrap().ranks);
    assert_eq!(acfg.trainable_params, t.adapter_cfg().unwrap().trainable_params);
    // the restored model must evaluate exactly like the source model
    let (l2, a2) = fresh.evaluate().unwrap();
    assert_eq!(l1, l2, "restored eval loss differs");
    assert_eq!(a1, a2, "restored eval accuracy differs");

    // a rank layout that disagrees with the manifest is rejected
    let mut bad = ck.clone();
    bad.ranks.as_mut().unwrap().pop();
    assert!(fresh.restore(&bad).is_err(), "short rank list must be rejected");
    // partial LoRA state is rejected too
    let mut partial = ck.clone();
    partial.adapter_cfg = None;
    assert!(fresh.restore(&partial).is_err(), "partial state must be rejected");
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let mut t = Trainer::new(micro_config(2)).unwrap();
    t.run_epoch().unwrap();
    let ck = t.checkpoint();
    let path = std::env::temp_dir().join(format!("prelora_itest_{}.ckpt", std::process::id()));
    ck.save(&path).unwrap();
    let back = Checkpoint::load(&path).unwrap();
    assert_eq!(back.base, ck.base);
    assert_eq!(back.epoch, 1);
    t.restore(&back).unwrap();
    std::fs::remove_file(path).unwrap();
}

#[test]
fn warmup_window_length_is_respected() {
    let mut cfg = micro_config(18);
    cfg.prelora.warmup_epochs = 4;
    let mut t = Trainer::new(cfg).unwrap();
    for _ in 0..18 {
        t.run_epoch().unwrap();
    }
    if let (Some(s), Some(f)) = (t.controller().switch_epoch(), t.controller().freeze_epoch()) {
        assert_eq!(f - s, 4, "warmup must last w epochs");
        assert!(matches!(t.phase(), Phase::LoraOnly { .. }));
    } else {
        panic!("run never completed the lifecycle: {:?}", t.controller().switch_epoch());
    }
}

// ---------------------------------------------------------------------------
// property-based invariants (in-tree prop driver, see util::prop)
// ---------------------------------------------------------------------------

/// Random per-module delta tables for Algorithm 2.
#[derive(Debug, Clone)]
struct DeltaTable(BTreeMap<String, Vec<f64>>);

impl Arbitrary for DeltaTable {
    fn generate(rng: &mut Pcg64) -> Self {
        let layers = 1 + rng.next_below(12);
        let mods = ["query", "key", "value", "output", "dense"];
        let n_mods = 1 + rng.next_below(mods.len());
        let mut m = BTreeMap::new();
        for md in mods.iter().take(n_mods) {
            let v: Vec<f64> = (0..layers).map(|_| (rng.next_f64() - 0.3) * 10.0).collect();
            m.insert(md.to_string(), v);
        }
        DeltaTable(m)
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.0.len() > 1 {
            let mut m = self.0.clone();
            let k = m.keys().next().unwrap().clone();
            m.remove(&k);
            out.push(DeltaTable(m));
        }
        if self.0.values().next().map_or(0, |v| v.len()) > 1 {
            let m = self
                .0
                .iter()
                .map(|(k, v)| (k.clone(), v[..v.len() / 2].to_vec()))
                .collect();
            out.push(DeltaTable(m));
        }
        out
    }
}

#[test]
fn prop_rank_assignment_invariants() {
    check::<DeltaTable, _>(101, 300, |t| {
        let a = assign_ranks(&t.0, 2, 16);
        let buckets = rank_buckets(2, 16);
        for (module, deltas) in &t.0 {
            let ranks = &a.by_module[module];
            // every layer assigned, every rank a bucket
            if ranks.len() != deltas.len() || ranks.iter().any(|r| !buckets.contains(r)) {
                return false;
            }
            // monotonicity: larger |delta| never gets a smaller rank
            for i in 0..deltas.len() {
                for j in 0..deltas.len() {
                    if deltas[i].abs() < deltas[j].abs() && ranks[i] > ranks[j] {
                        return false;
                    }
                }
            }
            // extremes hit the extreme buckets (non-degenerate case)
            let lo = deltas.iter().map(|d| d.abs()).fold(f64::INFINITY, f64::min);
            let hi = deltas.iter().map(|d| d.abs()).fold(0.0f64, f64::max);
            if (hi - lo).abs() > 1e-12 {
                let imax = (0..deltas.len())
                    .max_by(|&i, &j| deltas[i].abs().total_cmp(&deltas[j].abs()))
                    .unwrap();
                let imin = (0..deltas.len())
                    .min_by(|&i, &j| deltas[i].abs().total_cmp(&deltas[j].abs()))
                    .unwrap();
                if ranks[imax] != 16 || ranks[imin] != 2 {
                    return false;
                }
            }
        }
        true
    });
}

/// Random all-reduce inputs: (workers, len) sized buffers.
#[derive(Debug, Clone)]
struct ReduceCase {
    bufs: Vec<Vec<f32>>,
}

impl Arbitrary for ReduceCase {
    fn generate(rng: &mut Pcg64) -> Self {
        let n = 2 + rng.next_below(9);
        let len = 1 + rng.next_below(300);
        let bufs = (0..n)
            .map(|_| (0..len).map(|_| rng.next_f32() * 4.0 - 2.0).collect())
            .collect();
        ReduceCase { bufs }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.bufs.len() > 2 {
            out.push(ReduceCase { bufs: self.bufs[..self.bufs.len() - 1].to_vec() });
        }
        let len = self.bufs[0].len();
        if len > 1 {
            out.push(ReduceCase {
                bufs: self.bufs.iter().map(|b| b[..len / 2].to_vec()).collect(),
            });
        }
        out
    }
}

#[test]
fn prop_allreduce_algorithms_agree() {
    check::<ReduceCase, _>(202, 200, |case| {
        let mut naive = case.bufs.clone();
        let mut tree = case.bufs.clone();
        let mut ring = case.bufs.clone();
        reduce_mean(Algorithm::Naive, &mut naive);
        reduce_mean(Algorithm::Tree, &mut tree);
        reduce_mean(Algorithm::Ring, &mut ring);
        naive[0]
            .iter()
            .zip(&tree[0])
            .zip(&ring[0])
            .all(|((&a, &b), &c)| (a - b).abs() < 1e-4 && (a - c).abs() < 1e-4)
    });
}

/// Odd worker counts with buffer lengths the ring chunking does not
/// divide evenly — the ragged-chunk schedule the fixed-size cases miss.
#[derive(Debug, Clone)]
struct OddReduceCase {
    bufs: Vec<Vec<f32>>,
}

impl Arbitrary for OddReduceCase {
    fn generate(rng: &mut Pcg64) -> Self {
        let n = [3usize, 5, 7][rng.next_below(3)];
        let mut len = 1 + rng.next_below(500);
        if len % n == 0 {
            len += 1; // force non-chunk-aligned
        }
        let bufs = (0..n)
            .map(|_| (0..len).map(|_| rng.next_f32() * 4.0 - 2.0).collect())
            .collect();
        OddReduceCase { bufs }
    }

    fn shrink(&self) -> Vec<Self> {
        let len = self.bufs[0].len();
        if len > 1 {
            vec![OddReduceCase {
                bufs: self.bufs.iter().map(|b| b[..len / 2].to_vec()).collect(),
            }]
        } else {
            Vec::new()
        }
    }
}

#[test]
fn prop_reduce_scatter_all_gather_composes_to_reduce_mean() {
    // the ZeRO bit contract, property-tested over odd worker counts and
    // non-chunk-aligned lengths: for every algorithm, gathering the
    // scattered chunks reproduces the all-reduce output *bitwise*
    check::<OddReduceCase, _>(505, 150, |case| {
        let n = case.bufs.len();
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            let want = {
                let mut bufs = case.bufs.clone();
                reduce_mean(alg, &mut bufs);
                bufs.swap_remove(0)
            };
            let Some(chunks) = reduce_scatter(alg, case.bufs.clone(), n) else {
                return false;
            };
            if chunks.len() != n || all_gather(&chunks) != want {
                return false;
            }
        }
        true
    });
}

/// Ragged reduce-scatter layouts: a worker count and an *independent*
/// output partition count that deliberately never match — the foreign-
/// `parts` path (ring stitches output chunks from its schedule's owning
/// ranks; this used to reduce fully then split).
#[derive(Debug, Clone)]
struct RaggedScatterCase {
    bufs: Vec<Vec<f32>>,
    parts: usize,
}

impl Arbitrary for RaggedScatterCase {
    fn generate(rng: &mut Pcg64) -> Self {
        let n = 2 + rng.next_below(7); // 2..=8 workers
        let mut len = 1 + rng.next_below(300);
        if len % n == 0 {
            len += 1; // force a ragged ring chunking
        }
        let mut parts = 1 + rng.next_below(2 * n + 4); // may exceed len (empty chunks)
        if parts == n {
            parts += 1; // the parts == workers case has its own coverage
        }
        let bufs = (0..n)
            .map(|_| (0..len).map(|_| rng.next_f32() * 4.0 - 2.0).collect())
            .collect();
        RaggedScatterCase { bufs, parts }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let len = self.bufs[0].len();
        if len > 1 {
            out.push(RaggedScatterCase {
                bufs: self.bufs.iter().map(|b| b[..len / 2].to_vec()).collect(),
                parts: self.parts,
            });
        }
        if self.parts > 1 {
            let mut c = self.clone();
            c.parts = 1 + self.parts / 2;
            if c.parts != self.bufs.len() {
                out.push(c);
            }
        }
        out
    }
}

#[test]
fn prop_reduce_scatter_foreign_parts_is_bitwise_allreduce() {
    // ROADMAP item closed: for every algorithm — ring included — a
    // partition count that does not match the worker count still yields
    // chunks that concatenate *bitwise* to the all-reduce output
    check::<RaggedScatterCase, _>(808, 150, |case| {
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            let want = {
                let mut bufs = case.bufs.clone();
                reduce_mean(alg, &mut bufs);
                bufs.swap_remove(0)
            };
            let Some(chunks) = reduce_scatter(alg, case.bufs.clone(), case.parts) else {
                return false;
            };
            if chunks.len() != case.parts || all_gather(&chunks) != want {
                return false;
            }
        }
        true
    });
}

/// Bucketed-reduce layouts: ragged lengths, odd worker counts, and
/// bucket/partition counts chosen to disagree with the worker count.
#[derive(Debug, Clone)]
struct BucketReduceCase {
    bufs: Vec<Vec<f32>>,
    parts: usize,
    bucket_bytes: usize,
}

impl Arbitrary for BucketReduceCase {
    fn generate(rng: &mut Pcg64) -> Self {
        let n = [2usize, 3, 5, 7][rng.next_below(4)];
        let mut len = 1 + rng.next_below(400);
        if len % n == 0 {
            len += 1; // force a ragged ring chunking
        }
        // partition counts that may disagree with the worker count
        let parts = 1 + rng.next_below(2 * n + 2);
        // bucket sizes from one element up past the whole space; the odd
        // element counts are usually coprime with the worker count
        let bucket_bytes = match rng.next_below(4) {
            0 => 0,                                // whole-partition buckets
            1 => 4,                                // one element per bucket
            2 => 4 * (1 + 2 * rng.next_below(40)), // odd element counts
            _ => 4 * (len / 2 + 1),                // larger than most partitions
        };
        let bufs = (0..n)
            .map(|_| (0..len).map(|_| rng.next_f32() * 4.0 - 2.0).collect())
            .collect();
        BucketReduceCase { bufs, parts, bucket_bytes }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let len = self.bufs[0].len();
        if len > 1 {
            out.push(BucketReduceCase {
                bufs: self.bufs.iter().map(|b| b[..len / 2].to_vec()).collect(),
                parts: self.parts,
                bucket_bytes: self.bucket_bytes,
            });
        }
        if self.parts > 1 {
            let mut c = self.clone();
            c.parts = 1;
            out.push(c);
        }
        if self.bucket_bytes != 0 {
            let mut c = self.clone();
            c.bucket_bytes = 0;
            out.push(c);
        }
        out
    }
}

#[test]
fn prop_bucketed_reduce_concatenates_bitwise_to_whole_buffer() {
    // the bucketed-sync bit contract at the collective layer, fuzzed: for
    // every schedule, reducing per size-bounded bucket and concatenating
    // in index order reproduces the whole-buffer all-reduce bitwise, and
    // regrouping the same buckets by owning partition reproduces the
    // whole-buffer reduce-scatter bitwise
    check::<BucketReduceCase, _>(909, 200, |case| {
        let len = case.bufs[0].len();
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            // parts = 1: index-order concat vs the all-reduce
            let plan = BucketPlan::derive(len, 1, case.bucket_bytes);
            let Some(want) = reduce_owned(alg, case.bufs.clone()) else { return false };
            let mut got = Vec::with_capacity(len);
            for b in &plan.buckets {
                let slices: Vec<Vec<f32>> =
                    case.bufs.iter().map(|w| w[b.lo..b.hi].to_vec()).collect();
                let Some(r) = reduce_bucket(alg, slices, b.lo, len) else { return false };
                got.extend(r);
            }
            if got != want {
                return false;
            }
            // foreign partition counts: per-partition regrouping vs the
            // whole-buffer reduce-scatter (empty partitions stay empty)
            let plan = BucketPlan::derive(len, case.parts, case.bucket_bytes);
            let Some(chunks) = reduce_scatter(alg, case.bufs.clone(), case.parts) else {
                return false;
            };
            let mut grouped: Vec<Vec<f32>> = vec![Vec::new(); case.parts];
            for b in &plan.buckets {
                let slices: Vec<Vec<f32>> =
                    case.bufs.iter().map(|w| w[b.lo..b.hi].to_vec()).collect();
                let Some(r) = reduce_bucket(alg, slices, b.lo, len) else { return false };
                grouped[b.part].extend(r);
            }
            if grouped != chunks {
                return false;
            }
        }
        true
    });
}

/// Ragged clip inputs: a gradient vector, an odd partition count that
/// does not divide its length, and a clip threshold that sometimes
/// engages (0 = clipping off).
#[derive(Debug, Clone)]
struct ClipCase {
    grads: Vec<f32>,
    parts: usize,
    clip: f64,
}

impl Arbitrary for ClipCase {
    fn generate(rng: &mut Pcg64) -> Self {
        let parts = [3usize, 5, 7][rng.next_below(3)];
        let mut len = 1 + rng.next_below(200);
        if len % parts == 0 {
            len += 1; // force a ragged final chunk
        }
        let grads = (0..len).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let clip = if rng.next_below(4) == 0 {
            0.0
        } else {
            0.25 + rng.next_f64() * 8.0
        };
        ClipCase { grads, parts, clip }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.grads.len() > 1 {
            let mut c = self.clone();
            c.grads.truncate(self.grads.len() / 2);
            out.push(c);
        }
        if self.clip != 0.0 {
            let mut c = self.clone();
            c.clip = 0.0;
            out.push(c);
        }
        out
    }
}

#[test]
fn prop_sharded_partial_norm_clip_is_bitwise_full_clip() {
    // the sharded clip contract, property-tested: clipping through
    // per-shard chunks (whose squared sums combine via the ordered scalar
    // reduce) must equal the full-buffer clip *bitwise* — pre-clip norm,
    // clipped flag, clipped gradient AND the optimizer step it feeds —
    // for odd worker counts and ragged partition lengths, under both the
    // gradient-sharded (stage 2) and parameter-sharded (stage 3) layouts
    check::<ClipCase, _>(606, 150, |case| {
        let n = case.grads.len();
        let tcfg = TrainConfig::default();
        let stage = UpdateStage::new(case.clip);
        let mk = |d: Option<Reduced>| GradResult {
            d_base: d,
            d_lora: None,
            loss: 0.0,
            correct: 0.0,
            samples: 1,
            execute_seconds: 0.0,
        };
        let s_off = strategy_for(ZeroStage::Off, case.parts, collective_for(Algorithm::Naive));
        let mut mf = ModelState::new(s_off.park_params(vec![0.4f32; n]), s_off.optimizer(&tcfg, n));
        let mut rf = mk(Some(Reduced::Full(case.grads.clone())));
        let Ok(nf) = stage.apply(&*s_off, &mut mf, &mut rf, 1e-3) else { return false };

        for zs in [ZeroStage::Zero2, ZeroStage::Zero3] {
            let s = strategy_for(zs, case.parts, collective_for(Algorithm::Naive));
            let mut ms = ModelState::new(s.park_params(vec![0.4f32; n]), s.optimizer(&tcfg, n));
            let mut rs = mk(Some(Reduced::Sharded(scatter(&case.grads, case.parts))));
            let Ok(ns) = stage.apply(&*s, &mut ms, &mut rs, 1e-3) else { return false };
            if nf.pre_clip != ns.pre_clip
                || nf.clipped != ns.clipped
                || mf.base.to_full() != ms.base.to_full()
                || rf.d_base.clone().map(Reduced::into_full)
                    != rs.d_base.clone().map(Reduced::into_full)
            {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_odd_worker_allreduce_agrees_tightly() {
    check::<OddReduceCase, _>(404, 150, |case| {
        let exact: Vec<f64> = (0..case.bufs[0].len())
            .map(|i| {
                case.bufs.iter().map(|b| b[i] as f64).sum::<f64>() / case.bufs.len() as f64
            })
            .collect();
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            let mut bufs = case.bufs.clone();
            reduce_mean(alg, &mut bufs);
            // tight tolerance: a few f32 summation orders over <= 7 values
            if !bufs[0]
                .iter()
                .zip(&exact)
                .all(|(&got, &want)| (got as f64 - want).abs() < 1e-5)
            {
                return false;
            }
        }
        true
    });
}

/// Loader sharding: disjoint cover of the epoch prefix.
#[derive(Debug, Clone)]
struct LoaderCase {
    samples: usize,
    batch: usize,
    workers: usize,
    seed: u64,
}

impl Arbitrary for LoaderCase {
    fn generate(rng: &mut Pcg64) -> Self {
        LoaderCase {
            samples: 16 + rng.next_below(300),
            batch: 1 + rng.next_below(8),
            workers: 1 + rng.next_below(4),
            seed: rng.next_u64(),
        }
    }
}

#[test]
fn prop_loader_shards_are_disjoint_and_deterministic() {
    check::<LoaderCase, _>(303, 60, |c| {
        let data = Dataset::generate(&SynthSpec {
            samples: c.samples,
            image_size: 8,
            channels: 1,
            num_classes: 4,
            noise: 0.1,
            phase_jitter: false,
            seed: c.seed,
        });
        let loader = EpochLoader::new(c.batch, c.workers, c.seed);
        let steps = loader.steps_per_epoch(&data);
        if steps == 0 {
            return true;
        }
        // labels drawn across one epoch must match dataset multiset prefix
        let mut seen = 0usize;
        for step in 0..steps {
            let batches = loader.step_batches(&data, 1, step);
            if batches.len() != c.workers {
                return false;
            }
            for b in &batches {
                if b.labels.len() != c.batch {
                    return false;
                }
                seen += b.labels.len();
            }
        }
        // determinism
        let again = loader.step_batches(&data, 1, 0);
        seen == steps * c.batch * c.workers && again[0].labels == loader.step_batches(&data, 1, 0)[0].labels
    });
}
