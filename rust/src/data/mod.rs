//! Synthetic data substrate (ImageNet-1k stand-in).
//!
//! The paper trains on ImageNet-1k, which we cannot ship; per the
//! substitution rule we generate a deterministic class-conditional image
//! task that is (a) learnable but not trivially separable, so the loss
//! keeps improving after weight norms stabilize — the exact regime the
//! partial convergence test needs — and (b) fully reproducible from one
//! seed so every figure harness sees identical data.

mod loader;
mod synth;

pub use loader::{Batch, EpochLoader, Split};
pub use synth::{Dataset, SynthSpec};
