//! Epoch iteration: deterministic shuffles, fixed-size batches, and
//! per-worker sharding for the simulated data-parallel engine.

use super::synth::Dataset;
use crate::tensor::Pcg64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
}

/// One batch, materialized contiguously in artifact layout.
#[derive(Debug, Clone)]
pub struct Batch {
    pub images: Vec<f32>, // [B, H, W, C]
    pub labels: Vec<i32>, // [B]
}

/// Iterates a dataset in epochs of `batch * workers`-sized super-batches.
///
/// Every global step consumes one local batch per worker; the shard
/// assignment is round-robin over a per-epoch Fisher-Yates shuffle seeded
/// from (seed, epoch), so runs are bit-reproducible regardless of worker
/// thread interleaving — the property the DP equivalence test relies on.
#[derive(Debug, Clone)]
pub struct EpochLoader {
    batch: usize,
    workers: usize,
    seed: u64,
}

impl EpochLoader {
    pub fn new(batch: usize, workers: usize, seed: u64) -> Self {
        assert!(batch > 0 && workers > 0);
        Self { batch, workers, seed }
    }

    /// Number of global steps per epoch (drop-last semantics).
    pub fn steps_per_epoch(&self, data: &Dataset) -> usize {
        data.len() / (self.batch * self.workers)
    }

    /// Shuffled index order for one epoch. Compute once per epoch and feed
    /// [`step_batches_in`](Self::step_batches_in) — the prefetch stage does
    /// this, instead of redoing the O(N) shuffle for every step.
    pub fn epoch_order(&self, data: &Dataset, epoch: usize) -> Vec<usize> {
        let mut order: Vec<usize> = (0..data.len()).collect();
        let mut rng = Pcg64::new(self.seed ^ 0x5eed_0000).fork(epoch as u64);
        rng.shuffle(&mut order);
        order
    }

    /// Materialize one global step's per-worker batches from a precomputed
    /// epoch order.
    pub fn step_batches_in(&self, data: &Dataset, order: &[usize], step: usize) -> Vec<Batch> {
        let stride = self.batch * self.workers;
        let start = step * stride;
        assert!(start + stride <= order.len(), "step out of range");
        (0..self.workers)
            .map(|w| {
                let idx = &order[start + w * self.batch..start + (w + 1) * self.batch];
                self.gather(data, idx)
            })
            .collect()
    }

    /// Materialize the per-worker batches of one global step (convenience
    /// wrapper that recomputes the epoch order).
    pub fn step_batches(&self, data: &Dataset, epoch: usize, step: usize) -> Vec<Batch> {
        self.step_batches_in(data, &self.epoch_order(data, epoch), step)
    }

    /// Sequential (unshuffled) batches for evaluation; remainder dropped.
    pub fn eval_batches(&self, data: &Dataset) -> Vec<Batch> {
        let n = data.len() / self.batch;
        (0..n)
            .map(|b| {
                let idx: Vec<usize> = (b * self.batch..(b + 1) * self.batch).collect();
                self.gather(data, &idx)
            })
            .collect()
    }

    fn gather(&self, data: &Dataset, idx: &[usize]) -> Batch {
        let px = data.pixels_per_image();
        let mut images = Vec::with_capacity(idx.len() * px);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            images.extend_from_slice(data.image(i));
            labels.push(data.labels[i]);
        }
        Batch { images, labels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;

    fn data() -> Dataset {
        Dataset::generate(&SynthSpec {
            samples: 97,
            image_size: 8,
            channels: 3,
            num_classes: 4,
            noise: 0.1,
            phase_jitter: false,
            seed: 1,
        })
    }

    #[test]
    fn steps_per_epoch_drop_last() {
        let d = data();
        let l = EpochLoader::new(8, 2, 0);
        assert_eq!(l.steps_per_epoch(&d), 97 / 16);
    }

    #[test]
    fn epoch_shuffles_differ_but_are_deterministic() {
        let d = data();
        let l = EpochLoader::new(8, 1, 3);
        let a0 = l.step_batches(&d, 0, 0);
        let a0_again = l.step_batches(&d, 0, 0);
        let a1 = l.step_batches(&d, 1, 0);
        assert_eq!(a0[0].labels, a0_again[0].labels);
        assert_ne!(a0[0].labels, a1[0].labels, "epochs should reshuffle");
    }

    #[test]
    fn worker_shards_are_disjoint() {
        let d = data();
        let l = EpochLoader::new(8, 2, 0);
        let batches = l.step_batches(&d, 0, 1);
        assert_eq!(batches.len(), 2);
        // disjointness: images from shard 0 and 1 come from different samples
        assert_ne!(batches[0].images, batches[1].images);
        assert_eq!(batches[0].labels.len(), 8);
        assert_eq!(batches[0].images.len(), 8 * d.pixels_per_image());
    }

    #[test]
    fn eval_batches_cover_prefix_in_order() {
        let d = data();
        let l = EpochLoader::new(8, 1, 0);
        let evs = l.eval_batches(&d);
        assert_eq!(evs.len(), 12);
        assert_eq!(evs[0].labels, d.labels[..8].to_vec());
    }
}
