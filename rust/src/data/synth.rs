//! Class-conditional oriented-sinusoid ("Gabor-like") image generator.
//!
//! Each class owns an orientation theta = 2*pi*c/K, a base frequency
//! 2 + (c mod 4), and a harmonic weight; a sample is the class pattern at a
//! random phase plus per-pixel Gaussian noise. Orientation/frequency live
//! in global image statistics, so a ViT must learn real spatial filters —
//! a fresh model starts at chance and improves for many epochs.

use crate::tensor::Pcg64;

/// Generation parameters for one dataset split.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub samples: usize,
    pub image_size: usize,
    pub channels: usize,
    pub num_classes: usize,
    pub noise: f32,
    pub phase_jitter: bool,
    pub seed: u64,
}

impl SynthSpec {
    /// Derive the spec for regenerating this split at `epoch` in the
    /// infinite-data regime (`DataConfig::fresh_per_epoch`): same
    /// distribution, an epoch-mixed seed. Epoch 0 reproduces the original
    /// spec, so a fresh-per-epoch run's first epoch matches a fixed-data
    /// run's.
    pub fn fresh_epoch(&self, epoch: usize) -> SynthSpec {
        let mut s = self.clone();
        s.seed = self.seed ^ (epoch as u64).wrapping_mul(0x9e37_79b9);
        s
    }
}

/// An in-memory dataset: images as one contiguous [N, H, W, C] f32 block.
pub struct Dataset {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub image_size: usize,
    pub channels: usize,
    pub num_classes: usize,
}

impl Dataset {
    /// Generate deterministically from the spec.
    pub fn generate(spec: &SynthSpec) -> Self {
        let mut rng = Pcg64::new(spec.seed);
        let s = spec.image_size;
        let px = s * s * spec.channels;
        let mut images = vec![0.0f32; spec.samples * px];
        let mut labels = vec![0i32; spec.samples];
        for i in 0..spec.samples {
            // balanced labels with a shuffled remainder
            let label = if i < (spec.samples / spec.num_classes) * spec.num_classes {
                (i % spec.num_classes) as i32
            } else {
                rng.next_below(spec.num_classes) as i32
            };
            labels[i] = label;
            let phase = if spec.phase_jitter {
                rng.next_f32() * std::f32::consts::TAU
            } else {
                0.0
            };
            Self::render_into(
                &mut images[i * px..(i + 1) * px],
                label as usize,
                spec,
                phase,
                &mut rng,
            );
        }
        // deterministic global shuffle so classes are not laid out in order
        let mut order: Vec<usize> = (0..spec.samples).collect();
        rng.shuffle(&mut order);
        let mut shuffled_img = vec![0.0f32; images.len()];
        let mut shuffled_lab = vec![0i32; labels.len()];
        for (dst, &src) in order.iter().enumerate() {
            shuffled_img[dst * px..(dst + 1) * px].copy_from_slice(&images[src * px..(src + 1) * px]);
            shuffled_lab[dst] = labels[src];
        }
        Self {
            images: shuffled_img,
            labels: shuffled_lab,
            image_size: s,
            channels: spec.channels,
            num_classes: spec.num_classes,
        }
    }

    /// Render one sample's pixels (pattern + noise) into `out`.
    fn render_into(out: &mut [f32], class: usize, spec: &SynthSpec, phase: f32, rng: &mut Pcg64) {
        let s = spec.image_size;
        let k = spec.num_classes as f32;
        let theta = std::f32::consts::TAU * class as f32 / k;
        let freq = 2.0 + (class % 4) as f32;
        let harmonic = 0.35 * ((class / 4) % 3) as f32;
        let (ct, st) = (theta.cos(), theta.sin());
        for y in 0..s {
            for x in 0..s {
                let u = x as f32 / s as f32;
                let v = y as f32 / s as f32;
                let proj = ct * u + st * v;
                let ortho = -st * u + ct * v;
                let base = (std::f32::consts::TAU * freq * proj + phase).sin();
                let second = (std::f32::consts::TAU * (freq + 2.0) * ortho + 0.5 * phase).cos();
                let val = base + harmonic * second;
                for c in 0..spec.channels {
                    // mild per-channel gain so channels are informative but correlated
                    let gain = 1.0 - 0.15 * c as f32;
                    out[(y * s + x) * spec.channels + c] =
                        gain * val + spec.noise * rng.next_normal();
                }
            }
        }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn pixels_per_image(&self) -> usize {
        self.image_size * self.image_size * self.channels
    }

    /// Borrow one sample's pixels.
    pub fn image(&self, i: usize) -> &[f32] {
        let px = self.pixels_per_image();
        &self.images[i * px..(i + 1) * px]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SynthSpec {
        SynthSpec {
            samples: 64,
            image_size: 16,
            channels: 3,
            num_classes: 8,
            noise: 0.3,
            phase_jitter: true,
            seed: 7,
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Dataset::generate(&spec());
        let b = Dataset::generate(&spec());
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let mut s2 = spec();
        s2.seed = 8;
        let c = Dataset::generate(&s2);
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn labels_in_range_and_roughly_balanced() {
        let d = Dataset::generate(&spec());
        let mut counts = vec![0usize; 8];
        for &l in &d.labels {
            assert!((0..8).contains(&l));
            counts[l as usize] += 1;
        }
        for &c in &counts {
            assert!(c >= 4, "class too rare: {counts:?}");
        }
    }

    #[test]
    fn images_have_signal_and_noise() {
        let d = Dataset::generate(&spec());
        let img = d.image(0);
        let mean: f32 = img.iter().sum::<f32>() / img.len() as f32;
        let var: f32 = img.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / img.len() as f32;
        assert!(var > 0.1, "image should have structure, var={var}");
        assert!(img.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn same_class_samples_correlate_more_than_cross_class() {
        // the class pattern must be a real signal: average |corr| within a
        // class should exceed across classes
        let mut s = spec();
        s.noise = 0.1;
        s.samples = 128;
        let d = Dataset::generate(&s);
        let corr = |a: &[f32], b: &[f32]| -> f32 {
            let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
            let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
            let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
            (dot / (na * nb)).abs()
        };
        let idx_of = |class: i32, skip: usize| {
            d.labels
                .iter()
                .enumerate()
                .filter(|(_, &l)| l == class)
                .map(|(i, _)| i)
                .nth(skip)
                .unwrap()
        };
        let (a1, a2) = (idx_of(0, 0), idx_of(0, 1));
        let b1 = idx_of(3, 0);
        let within = corr(d.image(a1), d.image(a2));
        let across = corr(d.image(a1), d.image(b1));
        assert!(
            within > across,
            "within-class corr {within} should beat cross-class {across}"
        );
    }
}
