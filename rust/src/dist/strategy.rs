//! The [`Strategy`] trait: one object-safe description of a distributed
//! training layout, consumed by the trainer, the step pipeline, the
//! checkpoint path and the benches — none of which branch on the stage.
//!
//! A strategy is fully characterized by three partition counts over the
//! data-parallel ranks — optimizer shards, gradient parts, parameter
//! parts — plus the [`Collective`] it communicates through. The provided
//! method bodies here *are* the distributed step engine: every stock
//! stage ([`Unsharded`], [`Zero1`], [`Zero2`], [`super::Zero3`]) only
//! declares its counts, so a new strategy (or a real multi-host backend)
//! overrides exactly what it changes. The gradient/parameter layout
//! `match`es live in these defaults and in [`super::model`] — call sites
//! see trait dispatch only.

use std::sync::Arc;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::dp::{partition, BucketPlan, GradResult, Reduced, StepOutputs};
use crate::optim::ShardedOptimizer;

use super::collective::{Collective, CollectiveEndpoint};
use super::model::{ModelState, ParamStore, Repartition};
use super::ZeroStage;

/// A named flat parameter vector a strategy partitions (the base trunk,
/// the LoRA adapter vector, ...).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamSpace {
    pub name: &'static str,
    pub len: usize,
}

impl ParamSpace {
    pub fn new(name: &'static str, len: usize) -> Self {
        Self { name, len }
    }
}

/// How a strategy partitions one [`ParamSpace`]: contiguous per-rank
/// bounds for each of the three sharded dimensions. A replicated
/// dimension has a single `(0, len)` entry. All sharded dimensions use
/// the one [`partition`] chunking, so gradient chunks, optimizer shards
/// and owned parameter slices line up by construction.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    pub len: usize,
    pub param_bounds: Vec<(usize, usize)>,
    pub grad_bounds: Vec<(usize, usize)>,
    pub opt_bounds: Vec<(usize, usize)>,
}

impl ShardPlan {
    fn widest(bounds: &[(usize, usize)]) -> usize {
        bounds.iter().map(|&(lo, hi)| hi - lo).max().unwrap_or(0)
    }

    /// Parameter bytes a single rank holds persistently under this plan.
    pub fn param_bytes_per_rank(&self) -> usize {
        Self::widest(&self.param_bounds) * 4
    }

    /// Gradient bytes a single rank holds after the reduce.
    pub fn grad_bytes_per_rank(&self) -> usize {
        Self::widest(&self.grad_bounds) * 4
    }

    /// The rank whose optimizer shard owns element `i`.
    pub fn opt_owner_of(&self, i: usize) -> usize {
        self.opt_bounds
            .iter()
            .position(|&(lo, hi)| (lo..hi).contains(&i))
            // lint: allow(PL004): documented invariant panic — the bounds
            // cover [0, len) by construction, so a miss means the caller
            // indexed outside the space: a prelora bug, not input.
            .expect("element index outside the parameter space")
    }
}

/// Per-rank / total byte accounting of a live [`ModelState`] under a
/// strategy (feeds `MemoryBreakdown`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StateBytes {
    /// Parameter bytes a single rank holds persistently (owned partitions
    /// under ZeRO-3; the transient gathered view is not counted — it is
    /// the per-step all-gather a real rank frees after the update).
    pub param_bytes_per_rank: usize,
    /// Parameter bytes across all partitions (the replicated footprint).
    pub param_total_bytes: usize,
    /// Optimizer state bytes a single rank holds (largest shard).
    pub opt_bytes_per_rank: usize,
    /// Optimizer state across all shards (the unsharded footprint).
    pub opt_total_bytes: usize,
}

/// Clip a reduced gradient in place by global norm, returning the
/// pre-clip norm. The replicated buffer goes through
/// [`crate::tensor::clip_by_global_norm`]; the sharded layout assembles
/// the *global* pre-clip norm from the chunks' squared sums through the
/// collective's ordered scalar reduce — bitwise the full-buffer fold —
/// then applies the identical `(max / norm) as f32` scale per element.
/// `max <= 0` disables clipping (the norm is still measured).
pub fn clip_reduced(c: &dyn Collective, g: &mut Reduced, max: f64) -> f64 {
    match g {
        Reduced::Full(v) => {
            if max > 0.0 {
                crate::tensor::clip_by_global_norm(v, max)
            } else {
                crate::tensor::l2_norm(v)
            }
        }
        Reduced::Sharded(chunks) => {
            let norm = c.sq_sum_in_order(chunks).sqrt();
            if max > 0.0 && norm > max && norm > 0.0 {
                let s = (max / norm) as f32;
                for chunk in chunks.iter_mut() {
                    crate::tensor::scale(chunk, s);
                }
            }
            norm
        }
    }
}

/// An object-safe distributed-execution strategy. Implementations are
/// shared across the pipeline's threads (`Send + Sync`) behind an
/// `Arc<dyn Strategy>`.
///
/// **Contract.** For a fixed seed every strategy must produce
/// bit-identical losses, gradient norms and parameters to [`Unsharded`]:
/// [`grad_sync`](Self::grad_sync) may change the gradient's *layout* but
/// not its values' summation order, [`step`](Self::step) must perform the
/// elementwise optimizer update of exactly the owned slices, and
/// [`export_params`](Self::export_params) /
/// [`import_params`](Self::import_params) must gather/scatter without
/// arithmetic so checkpoints stay shard-layout independent.
pub trait Strategy: Send + Sync {
    /// The ZeRO stage this strategy implements (metadata: checkpoints,
    /// logs, bench labels).
    fn stage(&self) -> ZeroStage;

    /// Data-parallel ranks the layout partitions over.
    fn workers(&self) -> usize;

    /// The communication backend.
    fn collective(&self) -> &dyn Collective;

    /// The per-rank [`CollectiveEndpoint`] behind this strategy's
    /// collective, if the backend exposes one (the
    /// [`super::EndpointCollective`] adapter does; the in-memory
    /// [`super::AlgoCollective`] does not). The pipeline uses this to
    /// discover rank/world for per-process execution and to run the
    /// per-step scalar exchange.
    fn endpoint(&self) -> Option<Arc<dyn CollectiveEndpoint>> {
        self.collective().endpoint()
    }

    /// Optimizer-state partition count.
    fn opt_shards(&self) -> usize {
        self.stage().opt_shards(self.workers())
    }

    /// Gradient-buffer partition count (`> 1` makes the reduce a terminal
    /// reduce-scatter).
    fn grad_parts(&self) -> usize {
        self.stage().grad_parts(self.workers())
    }

    /// Parameter partition count (`> 1` = ZeRO-3 owned storage).
    fn param_parts(&self) -> usize {
        self.stage().param_parts(self.workers())
    }

    /// How this strategy partitions a parameter space. Layouts re-derive
    /// per space length, which is what makes the phase switch's new
    /// adapter vector re-partition automatically.
    fn plan(&self, space: &ParamSpace) -> ShardPlan {
        ShardPlan {
            len: space.len,
            param_bounds: partition(space.len, self.param_parts()),
            grad_bounds: partition(space.len, self.grad_parts()),
            opt_bounds: partition(space.len, self.opt_shards()),
        }
    }

    /// Put a full parameter vector into this strategy's storage layout.
    fn park_params(&self, full: Vec<f32>) -> ParamStore {
        if self.param_parts() <= 1 {
            ParamStore::replicated(full)
        } else {
            ParamStore::sharded(full, self.param_parts())
        }
    }

    /// Build the configured optimizer over this strategy's shard layout
    /// for a space of `len` elements.
    fn optimizer(&self, cfg: &TrainConfig, len: usize) -> ShardedOptimizer {
        super::model::build_optimizer(cfg, len, self.opt_shards())
    }

    /// Materialize the full working parameter views for the next step
    /// (the ZeRO-3 per-step all-gather; a no-op for replicated storage).
    fn materialize_params(&self, model: &mut ModelState) {
        model.base.materialize(self.collective());
        if let Some(l) = model.lora.as_mut() {
            l.materialize(self.collective());
        }
    }

    /// The parameter slice rank `rank` owns in `store`.
    fn owned_slice<'a>(&self, store: &'a ParamStore, rank: usize) -> &'a [f32] {
        store.owned_slice(rank)
    }

    /// Reduce one step's per-worker gradient buffers into this strategy's
    /// layout: a replicated mean via the collective's all-reduce, or —
    /// when gradients are sharded — a **terminal** reduce-scatter (the
    /// input buffers are consumed, one owned partition per rank survives,
    /// no replicated mean vector is ever materialized).
    #[allow(deprecated)] // one-release shim: route through the matrix API
    fn grad_sync(&self, bufs: Vec<Vec<f32>>) -> Option<Reduced> {
        if self.grad_parts() <= 1 {
            self.collective().all_reduce(bufs).map(Reduced::Full)
        } else {
            self.collective().reduce_scatter(bufs, self.grad_parts()).map(Reduced::Sharded)
        }
    }

    /// [`grad_sync`](Self::grad_sync) with wire-failure propagation: a
    /// backend whose collective reports a transport error (peer death,
    /// stall, desync — see [`Collective::take_error`]) turns `None` into
    /// a loud contextful `Err` instead of a silent skipped sync.
    fn try_grad_sync(&self, bufs: Vec<Vec<f32>>) -> Result<Option<Reduced>> {
        let out = self.grad_sync(bufs);
        match self.collective().take_error() {
            Some(e) => Err(e.context("gradient sync failed")),
            None => Ok(out),
        }
    }

    /// Whether this strategy supports the bucketed reduce path. The
    /// default is `false`, so any custom strategy keeps today's
    /// whole-buffer [`grad_sync`](Self::grad_sync) behavior untouched;
    /// the stock stages opt in because their collective implements
    /// [`Collective::reduce_bucket`] bitwise.
    fn bucketed_sync(&self) -> bool {
        false
    }

    /// Partition a `len`-element gradient space into size-bounded buckets
    /// aligned to this strategy's gradient partition boundaries (so
    /// ZeRO-1/2/3 ownership is bucket-local). `bucket_bytes = 0` means
    /// whole-partition buckets. Layouts re-derive per call, which is what
    /// makes a `Repartition` event's new space lengths pick up fresh
    /// bucket layouts automatically.
    fn bucket_plan(&self, len: usize, bucket_bytes: usize) -> BucketPlan {
        BucketPlan::derive(len, self.grad_parts(), bucket_bytes)
    }

    /// Reduce one bucket — worker slices of `[lo, lo + bufs[0].len())`
    /// within a `full_len`-element space — such that the per-bucket
    /// outputs concatenated in index order are **bitwise** the
    /// [`grad_sync`](Self::grad_sync) of the whole buffers. `None` means
    /// unsupported; callers must fall back to the whole-buffer reduce.
    #[allow(deprecated)] // one-release shim: route through the matrix API
    fn grad_sync_bucket(&self, bufs: Vec<Vec<f32>>, lo: usize, full_len: usize) -> Option<Vec<f32>> {
        self.collective().reduce_bucket(bufs, lo, full_len)
    }

    /// [`grad_sync_bucket`](Self::grad_sync_bucket) with wire-failure
    /// propagation (see [`try_grad_sync`](Self::try_grad_sync)).
    fn try_grad_sync_bucket(
        &self,
        bufs: Vec<Vec<f32>>,
        lo: usize,
        full_len: usize,
    ) -> Result<Option<Vec<f32>>> {
        let len = bufs.first().map_or(0, Vec::len);
        let out = self.grad_sync_bucket(bufs, lo, full_len);
        match self.collective().take_error() {
            Some(e) => Err(e.context(format!(
                "bucket [{lo}, {}) of {full_len} sync failed",
                lo + len
            ))),
            None => Ok(out),
        }
    }

    /// [`grad_sync`](Self::grad_sync) over both of a step's buffer sets
    /// (base + LoRA), scalars passed through.
    fn reduce_step(&self, outs: StepOutputs) -> GradResult {
        let StepOutputs { base_grads, lora_grads, loss, correct, samples, execute_seconds } = outs;
        GradResult {
            d_base: self.grad_sync(base_grads),
            d_lora: self.grad_sync(lora_grads),
            loss,
            correct,
            samples,
            execute_seconds,
        }
    }

    /// [`reduce_step`](Self::reduce_step) with wire-failure propagation
    /// (see [`try_grad_sync`](Self::try_grad_sync)).
    fn try_reduce_step(&self, outs: StepOutputs) -> Result<GradResult> {
        let r = self.reduce_step(outs);
        match self.collective().take_error() {
            Some(e) => Err(e.context("gradient sync failed")),
            None => Ok(r),
        }
    }

    /// Clip one reduced gradient by global norm in place; returns the
    /// pre-clip norm (see [`clip_reduced`]).
    fn clip_grad(&self, g: &mut Reduced, max: f64) -> f64 {
        clip_reduced(self.collective(), g, max)
    }

    /// Apply one optimizer update to a parameter store. Owned-partition
    /// storage steps shard-by-shard and drops its working view; the
    /// elementwise arithmetic is identical across layouts.
    fn step(&self, opt: &mut ShardedOptimizer, store: &mut ParamStore, g: &Reduced, lr: f32) {
        store.step_owned(opt, g, lr);
    }

    /// Gather a store's authoritative full vector (the checkpoint
    /// representation — shard-layout independent by construction). Routed
    /// through the collective: on a real backend this is the gather that
    /// moves owned shards to the writer.
    fn export_params(&self, store: &ParamStore) -> Vec<f32> {
        store.to_full_via(self.collective())
    }

    /// Scatter a checkpointed full vector onto this strategy's layout.
    fn import_params(&self, store: &mut ParamStore, full: &[f32]) -> Result<()> {
        anyhow::ensure!(
            full.len() == store.len(),
            "parameter length mismatch: checkpoint {} vs store {}",
            full.len(),
            store.len()
        );
        store.copy_from_full(full);
        Ok(())
    }

    /// Per-rank / total byte accounting of the live model under this
    /// strategy.
    fn state_bytes(&self, model: &ModelState) -> StateBytes {
        let lora_per = model.lora.as_ref().map_or(0, ParamStore::per_rank_elems);
        let lora_total = model.lora.as_ref().map_or(0, ParamStore::len);
        let opt_per = model.opt_base.as_ref().map_or(0, |o| o.per_worker_state_bytes())
            + model.opt_lora.as_ref().map_or(0, |o| o.per_worker_state_bytes());
        let opt_total = model.opt_base.as_ref().map_or(0, |o| o.state_bytes())
            + model.opt_lora.as_ref().map_or(0, |o| o.state_bytes());
        StateBytes {
            param_bytes_per_rank: (model.base.per_rank_elems() + lora_per) * 4,
            param_total_bytes: (model.base.len() + lora_total) * 4,
            opt_bytes_per_rank: opt_per,
            opt_total_bytes: opt_total,
        }
    }

    /// Apply a phase-switch re-partition event: install freshly
    /// initialized adapter storage + optimizer state in this strategy's
    /// layout, or shed the frozen base's optimizer state. Invoked at the
    /// epoch barrier only — every in-flight step has drained, so the
    /// layout never changes mid-step.
    fn repartition(&self, model: &mut ModelState, event: Repartition, cfg: &TrainConfig) {
        match event {
            Repartition::AdaptersInit { lora, adapter_cfg } => {
                model.opt_lora = Some(self.optimizer(cfg, lora.len()));
                model.lora = Some(self.park_params(lora));
                model.adapter_cfg = Some(adapter_cfg);
            }
            Repartition::FreezeBase => model.freeze_base(),
        }
    }
}

/// Classic DDP: everything replicated (ZeRO off). The reference layout
/// every other strategy must match bit-for-bit.
pub struct Unsharded {
    workers: usize,
    collective: Arc<dyn Collective>,
}

impl Unsharded {
    pub fn new(workers: usize, collective: Arc<dyn Collective>) -> Self {
        Self { workers, collective }
    }
}

impl Strategy for Unsharded {
    fn stage(&self) -> ZeroStage {
        ZeroStage::Off
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn collective(&self) -> &dyn Collective {
        &*self.collective
    }

    fn bucketed_sync(&self) -> bool {
        true
    }
}

/// ZeRO-1: optimizer state sharded (~1/N moments per rank); gradients and
/// parameters stay replicated.
pub struct Zero1 {
    workers: usize,
    collective: Arc<dyn Collective>,
}

impl Zero1 {
    pub fn new(workers: usize, collective: Arc<dyn Collective>) -> Self {
        Self { workers, collective }
    }
}

impl Strategy for Zero1 {
    fn stage(&self) -> ZeroStage {
        ZeroStage::Zero1
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn collective(&self) -> &dyn Collective {
        &*self.collective
    }

    fn bucketed_sync(&self) -> bool {
        true
    }
}

/// ZeRO-2: optimizer state *and* gradient buffers sharded — the reduce is
/// a terminal reduce-scatter, each rank keeps only its owned gradient
/// partition and updates its parameter slice in place (the disjoint
/// writes are the implicit parameter all-gather).
pub struct Zero2 {
    workers: usize,
    collective: Arc<dyn Collective>,
}

impl Zero2 {
    pub fn new(workers: usize, collective: Arc<dyn Collective>) -> Self {
        Self { workers, collective }
    }
}

impl Strategy for Zero2 {
    fn stage(&self) -> ZeroStage {
        ZeroStage::Zero2
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn collective(&self) -> &dyn Collective {
        &*self.collective
    }

    fn bucketed_sync(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{collective_for, strategy_for};
    use crate::dp::Algorithm;

    fn bufs(n: usize, len: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|w| (0..len).map(|i| ((w * 13 + i * 5) % 11) as f32 - 5.0).collect())
            .collect()
    }

    fn strat(stage: ZeroStage, workers: usize) -> Arc<dyn Strategy> {
        strategy_for(stage, workers, collective_for(Algorithm::Ring))
    }

    #[test]
    fn grad_sync_layouts_gather_to_the_same_bits() {
        let want = strat(ZeroStage::Off, 3).grad_sync(bufs(3, 101)).unwrap().into_full();
        for stage in [ZeroStage::Zero1, ZeroStage::Zero2, ZeroStage::Zero3] {
            let got = strat(stage, 3).grad_sync(bufs(3, 101)).unwrap();
            assert_eq!(
                got.per_rank_elems(),
                if stage >= ZeroStage::Zero2 { 34 } else { 101 },
                "{stage:?}: per-rank gradient accounting"
            );
            assert_eq!(got.into_full(), want, "{stage:?} diverged from the all-reduce");
        }
        assert!(strat(ZeroStage::Zero2, 3).grad_sync(Vec::new()).is_none());
    }

    #[test]
    fn bucketed_grad_sync_assembles_bitwise_per_stage() {
        // bucket-by-bucket reduction + index-order assembly must be
        // bitwise the whole-buffer grad_sync in every stage's layout,
        // including bucket counts coprime with the worker count
        let len = 101;
        for stage in [ZeroStage::Off, ZeroStage::Zero1, ZeroStage::Zero2, ZeroStage::Zero3] {
            let s = strat(stage, 3);
            assert!(s.bucketed_sync(), "{stage:?} must opt into bucketing");
            let want = s.grad_sync(bufs(3, len)).unwrap();
            for bytes in [0usize, 28, 52, 4 * len] {
                let plan = s.bucket_plan(len, bytes);
                assert_eq!(plan.parts, s.grad_parts().max(1));
                let src = bufs(3, len);
                let mut chunks = vec![Vec::new(); plan.parts];
                for b in &plan.buckets {
                    let slices: Vec<Vec<f32>> =
                        src.iter().map(|w| w[b.lo..b.hi].to_vec()).collect();
                    chunks[b.part].extend(s.grad_sync_bucket(slices, b.lo, len).unwrap());
                }
                let got = if s.grad_parts() <= 1 {
                    assert_eq!(chunks.len(), 1);
                    Reduced::Full(chunks.pop().unwrap())
                } else {
                    Reduced::Sharded(chunks)
                };
                match (&got, &want) {
                    (Reduced::Full(a), Reduced::Full(b)) => assert_eq!(a, b, "{stage:?} {bytes}"),
                    (Reduced::Sharded(a), Reduced::Sharded(b)) => {
                        assert_eq!(a, b, "{stage:?} {bytes}")
                    }
                    _ => panic!("{stage:?}: layout mismatch between bucketed and whole-buffer"),
                }
            }
        }
    }

    #[test]
    fn custom_strategies_default_to_whole_buffer_sync() {
        struct Custom(Unsharded);
        impl Strategy for Custom {
            fn stage(&self) -> ZeroStage {
                ZeroStage::Off
            }
            fn workers(&self) -> usize {
                self.0.workers()
            }
            fn collective(&self) -> &dyn Collective {
                self.0.collective()
            }
        }
        let c = Custom(Unsharded::new(3, collective_for(Algorithm::Ring)));
        assert!(!c.bucketed_sync(), "custom strategies must keep whole-buffer behavior");
    }

    #[test]
    fn plan_partitions_each_dimension_at_its_stage() {
        let space = ParamSpace::new("base", 23);
        let off = strat(ZeroStage::Off, 5).plan(&space);
        assert_eq!(off.param_bounds, vec![(0, 23)]);
        assert_eq!(off.grad_bounds, vec![(0, 23)]);
        assert_eq!(off.opt_bounds, vec![(0, 23)]);
        assert_eq!(off.param_bytes_per_rank(), 23 * 4);
        let z3 = strat(ZeroStage::Zero3, 5).plan(&space);
        assert_eq!(z3.param_bounds.len(), 5);
        assert_eq!(z3.param_bounds, z3.opt_bounds, "owned slices line up with moments");
        assert_eq!(z3.param_bounds, z3.grad_bounds, "and with gradient chunks");
        // ceil(23/5) = 5-wide chunks
        assert_eq!(z3.param_bytes_per_rank(), 5 * 4);
        assert_eq!(z3.grad_bytes_per_rank(), 5 * 4);
        assert_eq!(z3.opt_owner_of(0), 0);
        assert_eq!(z3.opt_owner_of(22), 4);
        let z1 = strat(ZeroStage::Zero1, 5).plan(&space);
        assert_eq!(z1.param_bounds, vec![(0, 23)]);
        assert_eq!(z1.grad_bounds, vec![(0, 23)]);
        assert_eq!(z1.opt_bounds.len(), 5);
    }

    #[test]
    fn clip_is_bitwise_identical_across_layouts() {
        let g: Vec<f32> = (0..53).map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.5).collect();
        for max in [0.0f64, 1.0, 100.0] {
            let full_strat = strat(ZeroStage::Off, 3);
            let sharded_strat = strat(ZeroStage::Zero2, 3);
            let mut gf = full_strat.grad_sync(vec![g.clone()]).unwrap();
            let mut gs = sharded_strat.grad_sync(vec![g.clone()]).unwrap();
            let nf = full_strat.clip_grad(&mut gf, max);
            let ns = sharded_strat.clip_grad(&mut gs, max);
            assert_eq!(nf.to_bits(), ns.to_bits(), "max={max}: norms diverged");
            assert_eq!(gf.into_full(), gs.into_full(), "max={max}: clipped values diverged");
        }
    }

    #[test]
    fn park_export_import_roundtrip_per_stage() {
        let full: Vec<f32> = (0..31).map(|i| i as f32 * 0.5 - 7.0).collect();
        for stage in [ZeroStage::Off, ZeroStage::Zero2, ZeroStage::Zero3] {
            let s = strat(stage, 4);
            let mut store = s.park_params(full.clone());
            assert_eq!(store.len(), 31);
            assert_eq!(s.export_params(&store), full, "{stage:?}");
            assert_eq!(s.owned_slice(&store, 0).len(), store.per_rank_elems());
            let replacement: Vec<f32> = full.iter().map(|x| x * 2.0).collect();
            s.import_params(&mut store, &replacement).unwrap();
            assert_eq!(s.export_params(&store), replacement, "{stage:?}");
            assert!(s.import_params(&mut store, &full[..7]).is_err(), "length must be checked");
        }
    }

    #[test]
    fn repartition_installs_adapters_and_sheds_the_frozen_base() {
        let cfg = TrainConfig::default();
        let s = strat(ZeroStage::Zero3, 3);
        let mut model =
            ModelState::new(s.park_params(vec![0.5; 20]), s.optimizer(&cfg, 20));
        assert!(model.opt_base.is_some() && model.lora.is_none());
        let acfg = crate::rank::AdapterCfg {
            values: vec![1.0, 0.0],
            ranks: vec![2],
            trainable_params: 12,
        };
        s.repartition(
            &mut model,
            Repartition::AdaptersInit { lora: vec![0.25; 9], adapter_cfg: acfg },
            &cfg,
        );
        let lora = model.lora.as_ref().unwrap();
        assert_eq!(lora.len(), 9);
        assert_eq!(lora.parts(), 3, "the adapter space re-partitions at its own length");
        assert_eq!(model.opt_lora.as_ref().unwrap().shard_count(), 3);
        assert!(model.adapter_cfg.is_some());
        s.repartition(&mut model, Repartition::FreezeBase, &cfg);
        assert!(model.opt_base.is_none(), "the frozen base keeps no optimizer state");
        assert!(model.opt_lora.is_some());
    }

    #[test]
    fn state_bytes_shrink_per_rank_with_the_stage() {
        let cfg = TrainConfig::default();
        let n = 10_000;
        let full = vec![0.1f32; n];
        let per = |stage: ZeroStage| {
            let s = strat(stage, 4);
            let model = ModelState::new(s.park_params(full.clone()), s.optimizer(&cfg, n));
            s.state_bytes(&model)
        };
        let off = per(ZeroStage::Off);
        assert_eq!(off.param_bytes_per_rank, off.param_total_bytes);
        assert_eq!(off.opt_bytes_per_rank, off.opt_total_bytes);
        let z1 = per(ZeroStage::Zero1);
        assert_eq!(z1.param_bytes_per_rank, z1.param_total_bytes);
        assert!(z1.opt_bytes_per_rank as f64 <= z1.opt_total_bytes as f64 / 4.0 + 16.0);
        let z3 = per(ZeroStage::Zero3);
        assert_eq!(z3.param_total_bytes, off.param_total_bytes, "total is layout-free");
        assert!(
            z3.param_bytes_per_rank as f64 <= z3.param_total_bytes as f64 / 4.0 + 16.0,
            "ZeRO-3 per-rank params must shrink to ~1/N: {} vs {}",
            z3.param_bytes_per_rank,
            z3.param_total_bytes
        );
        assert!(z3.opt_bytes_per_rank as f64 <= z3.opt_total_bytes as f64 / 4.0 + 16.0);
    }
}
