//! Strategy-shaped parameter storage + the mutable model bundle.
//!
//! [`ParamStore`] is where a parameter vector *lives* under a
//! [`super::Strategy`]: replicated on every rank (stages 0–2) or as owned
//! contiguous per-rank partitions (ZeRO-3), in which case the full
//! working view the forward/backward pass needs is **all-gathered per
//! step** ([`ParamStore::materialize`]) and dropped again when the step's
//! update lands — per-rank parameter memory is the owned partition, not
//! the vector.
//!
//! **Bit contract.** The gathered view is the exact concatenation of the
//! owned chunks (no arithmetic), and updates apply elementwise to the
//! chunks — the identical per-element operations the replicated update
//! performs on the full vector. Sharding parameters can therefore never
//! change a loss; `rust/tests/integration.rs` and [`super::zero3`]'s
//! property tests assert it bit-for-bit.

use crate::config::TrainConfig;
use crate::dp::Reduced;
use crate::optim::ShardedOptimizer;
use crate::rank::AdapterCfg;

use super::collective::Collective;

/// A flat parameter vector in its strategy-chosen layout.
pub enum ParamStore {
    /// Every rank holds the whole vector (the classic picture).
    Replicated(Vec<f32>),
    /// ZeRO-3: each rank owns one contiguous partition.
    Sharded(ShardedParams),
}

/// The ZeRO-3 layout: owned chunks in [`crate::dp::partition`] order plus
/// the transient gathered working view of the current step.
pub struct ShardedParams {
    chunks: Vec<Vec<f32>>,
    /// Full working view, present only between [`ParamStore::materialize`]
    /// and the step's update (which invalidates it). Deliberately *not*
    /// counted by the per-rank memory accounting — it is the per-step
    /// all-gather a real ZeRO-3 rank performs and frees.
    view: Option<Vec<f32>>,
}

impl ParamStore {
    pub fn replicated(full: Vec<f32>) -> Self {
        ParamStore::Replicated(full)
    }

    /// Scatter a full vector into `parts` owned partitions.
    pub fn sharded(full: Vec<f32>, parts: usize) -> Self {
        ParamStore::Sharded(ShardedParams { chunks: crate::dp::scatter(&full, parts), view: None })
    }

    /// Total element count across the layout.
    pub fn len(&self) -> usize {
        match self {
            ParamStore::Replicated(v) => v.len(),
            ParamStore::Sharded(s) => s.chunks.iter().map(Vec::len).sum(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Partition count (1 when replicated).
    pub fn parts(&self) -> usize {
        match self {
            ParamStore::Replicated(_) => 1,
            ParamStore::Sharded(s) => s.chunks.len(),
        }
    }

    /// Elements a single rank holds persistently: the whole vector when
    /// replicated, the largest owned partition when sharded (the quantity
    /// behind `MemoryBreakdown.param_bytes_per_rank`).
    pub fn per_rank_elems(&self) -> usize {
        match self {
            ParamStore::Replicated(v) => v.len(),
            ParamStore::Sharded(s) => s.chunks.iter().map(Vec::len).max().unwrap_or(0),
        }
    }

    /// The slice rank `rank` owns: everything when replicated, its
    /// partition when sharded.
    pub fn owned_slice(&self, rank: usize) -> &[f32] {
        match self {
            ParamStore::Replicated(v) => v,
            ParamStore::Sharded(s) => &s.chunks[rank],
        }
    }

    /// Build the full working view if it does not exist (the per-step
    /// parameter all-gather; a no-op for replicated storage or while a
    /// valid view is cached — the chunks only change through
    /// [`step_owned`](Self::step_owned), which drops the view).
    pub fn materialize(&mut self, c: &dyn Collective) {
        if let ParamStore::Sharded(s) = self {
            if s.view.is_none() {
                s.view = Some(c.all_gather(&s.chunks));
            }
        }
    }

    /// Drop the transient working view, if any (the "freed after
    /// compute" half of the ZeRO-3 claim). Called by the update stage at
    /// the end of every step for *every* store — a frozen base is never
    /// stepped, so relying on the update to invalidate its view would
    /// leave the full gather resident for the whole LoraOnly phase.
    pub fn drop_view(&mut self) {
        if let ParamStore::Sharded(s) = self {
            s.view = None;
        }
    }

    /// The full vector as a borrowed slice. Panics for a sharded store
    /// whose view has not been [`materialize`](Self::materialize)d — a
    /// step-engine sequencing bug, not a user error.
    pub fn as_full(&self) -> &[f32] {
        match self {
            ParamStore::Replicated(v) => v,
            ParamStore::Sharded(s) => s
                .view
                .as_deref()
                // lint: allow(PL004): documented invariant panic — the
                // doc comment above promises it, callers materialize
                // first, and a miss is a prelora sequencing bug.
                .expect("sharded parameter view used before materialize()"),
        }
    }

    /// The full vector without requiring a materialized view: borrows the
    /// replicated vector (or a live view), gathers a fresh copy otherwise.
    /// Telemetry convenience for the in-memory simulation (a rank-local
    /// concatenation, like [`to_full`](Self::to_full)); the hot path uses
    /// [`as_full`](Self::as_full) on a view materialized through the
    /// [`Collective`].
    pub fn full(&self) -> std::borrow::Cow<'_, [f32]> {
        match self {
            ParamStore::Replicated(v) => std::borrow::Cow::Borrowed(v),
            ParamStore::Sharded(s) => match &s.view {
                Some(v) => std::borrow::Cow::Borrowed(v),
                None => std::borrow::Cow::Owned(crate::dp::all_gather(&s.chunks)),
            },
        }
    }

    /// Gather the authoritative full vector (layout-independent copy —
    /// what checkpoints store). This is the **rank-local** concatenation;
    /// the checkpoint path routes through
    /// [`to_full_via`](Self::to_full_via) so a real backend's gather
    /// traffic goes through the [`Collective`] seam.
    pub fn to_full(&self) -> Vec<f32> {
        match self {
            ParamStore::Replicated(v) => v.clone(),
            ParamStore::Sharded(s) => crate::dp::all_gather(&s.chunks),
        }
    }

    /// [`to_full`](Self::to_full) through a collective: the gather that
    /// actually moves shards between ranks on a real backend
    /// (checkpoint export — `Strategy::export_params` — uses this).
    pub fn to_full_via(&self, c: &dyn Collective) -> Vec<f32> {
        match self {
            ParamStore::Replicated(v) => v.clone(),
            ParamStore::Sharded(s) => c.all_gather(&s.chunks),
        }
    }

    /// Overwrite from a full vector (checkpoint restore): copies in place
    /// for replicated storage, re-scatters onto the owned partitions (and
    /// drops any stale view) otherwise. Lengths must already agree.
    /// Deliberately rank-local — the checkpoint buffer is already present
    /// at the restoring reader, and taking one's own slice of it involves
    /// no communication on any backend.
    pub fn copy_from_full(&mut self, full: &[f32]) {
        assert_eq!(full.len(), self.len(), "parameter length mismatch");
        match self {
            ParamStore::Replicated(v) => v.copy_from_slice(full),
            ParamStore::Sharded(s) => {
                let parts = s.chunks.len();
                s.chunks = crate::dp::scatter(full, parts);
                s.view = None;
            }
        }
    }

    /// Apply one optimizer update in this layout. Replicated storage
    /// steps through [`ShardedOptimizer::step_reduced`] (which itself
    /// dispatches on the gradient layout); owned partitions step
    /// shard-by-shard and then drop the working view — the "params are
    /// freed after compute" half of the ZeRO-3 claim.
    pub fn step_owned(&mut self, opt: &mut ShardedOptimizer, g: &Reduced, lr: f32) {
        match self {
            ParamStore::Replicated(v) => opt.step_reduced(v, g, lr),
            ParamStore::Sharded(s) => {
                match g {
                    Reduced::Sharded(gchunks) => {
                        assert_eq!(
                            gchunks.len(),
                            s.chunks.len(),
                            "gradient partition count must match the parameter partition"
                        );
                        for (i, (p, gc)) in s.chunks.iter_mut().zip(gchunks).enumerate() {
                            opt.step_shard(i, p, gc, lr);
                        }
                    }
                    Reduced::Full(gfull) => {
                        // replicated gradient onto owned partitions: slice
                        // per chunk — elementwise identical either way
                        let mut at = 0;
                        for (i, p) in s.chunks.iter_mut().enumerate() {
                            let gc = &gfull[at..at + p.len()];
                            at += p.len();
                            opt.step_shard(i, p, gc, lr);
                        }
                        assert_eq!(at, gfull.len(), "gradient length mismatch");
                    }
                }
                s.view = None;
            }
        }
    }
}

/// A phase-switch re-partition event. PreLoRA changes the trainable
/// parameter layout mid-run; strategies are told through these events so
/// resharding is a first-class API operation, not a per-call-site special
/// case (the ReLoRA lesson — low-rank phases interleaved with resharding
/// events are the norm).
pub enum Repartition {
    /// The warmup switch: a freshly initialized adapter space enters
    /// training and needs storage + optimizer state in this strategy's
    /// layout (partitioned over the *adapter* vector's length — shard
    /// layouts re-derive per space, they are never shared across spaces).
    AdaptersInit { lora: Vec<f32>, adapter_cfg: AdapterCfg },
    /// The freeze: the base stops training and sheds its optimizer state
    /// entirely (the paper's memory saving made literal). Its parameters
    /// keep their layout — a frozen ZeRO-3 base still materializes per
    /// step for the forward pass.
    FreezeBase,
}

/// The mutable model the update stage advances: strategy-shaped parameter
/// stores plus their (possibly ZeRO-sharded) optimizers. `lora` /
/// `adapter_cfg` / `opt_lora` appear at the warmup switch via
/// [`Repartition::AdaptersInit`]; `opt_base` is dropped at the freeze.
pub struct ModelState {
    pub base: ParamStore,
    pub lora: Option<ParamStore>,
    pub adapter_cfg: Option<AdapterCfg>,
    pub opt_base: Option<ShardedOptimizer>,
    pub opt_lora: Option<ShardedOptimizer>,
}

impl ModelState {
    pub fn new(base: ParamStore, opt_base: ShardedOptimizer) -> Self {
        Self { base, lora: None, adapter_cfg: None, opt_base: Some(opt_base), opt_lora: None }
    }

    /// The full base-parameter view for the engine. Requires a
    /// materialized view under ZeRO-3 (see [`ParamStore::as_full`]).
    pub fn base_view(&self) -> &[f32] {
        self.base.as_full()
    }

    /// The `(lora_params, adapter_cfg)` input pair for the engine, present
    /// only once both halves exist. Same materialization requirement as
    /// [`base_view`](Self::base_view).
    pub fn lora_pair(&self) -> Option<(&[f32], &[f32])> {
        match (&self.lora, &self.adapter_cfg) {
            (Some(l), Some(a)) => Some((l.as_full(), a.values.as_slice())),
            _ => None,
        }
    }

    /// Drop every store's transient working view (the per-step gathered
    /// parameters under ZeRO-3). The update stage calls this at the end
    /// of each step and the trainer after evaluation, so the gathered
    /// full vectors never outlive the computation that needed them —
    /// even for stores the step did not update (the frozen base).
    pub fn drop_views(&mut self) {
        self.base.drop_view();
        if let Some(l) = self.lora.as_mut() {
            l.drop_view();
        }
    }

    /// Freeze the base: drop its optimizer state entirely (the paper's
    /// memory saving made literal) — the controller's FreezeBase
    /// decision, delivered through [`Repartition::FreezeBase`].
    /// Checkpoint restores reach the same end state differently: they
    /// clear *both* optimizers and rebuild whichever states the
    /// checkpoint carries, so a lora-only restore leaves `opt_base` at
    /// `None` without going through this transition.
    pub fn freeze_base(&mut self) {
        self.opt_base = None;
    }
}

/// Build the configured optimizer partitioned `shards` ways (the helper
/// [`super::Strategy::optimizer`] routes through).
pub fn build_optimizer(cfg: &TrainConfig, len: usize, shards: usize) -> ShardedOptimizer {
    ShardedOptimizer::new(cfg, len, shards)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::dist::collective_for;
    use crate::dp::{scatter, Algorithm};

    fn vals(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.25).collect()
    }

    #[test]
    fn sharded_store_roundtrips_and_accounts_per_rank() {
        let full = vals(23);
        let s = ParamStore::sharded(full.clone(), 5);
        assert_eq!(s.len(), 23);
        assert_eq!(s.parts(), 5);
        assert!(!s.is_empty());
        // ceil(23/5) = 5-wide chunks, ragged tail of 3
        assert_eq!(s.per_rank_elems(), 5);
        assert_eq!(s.to_full(), full);
        assert_eq!(&s.full()[..], &full[..]);
        assert_eq!(s.owned_slice(0), &full[..5]);
        assert_eq!(s.owned_slice(4), &full[20..]);
        let r = ParamStore::replicated(full.clone());
        assert_eq!(r.parts(), 1);
        assert_eq!(r.per_rank_elems(), 23);
        assert_eq!(r.owned_slice(0), &full[..]);
    }

    #[test]
    fn materialize_builds_the_view_and_step_drops_it() {
        let c = collective_for(Algorithm::Naive);
        let full = vals(17);
        let mut s = ParamStore::sharded(full.clone(), 3);
        s.materialize(&*c);
        assert_eq!(s.as_full(), &full[..]);
        // update through the owned chunks: bitwise the replicated update
        let cfg = TrainConfig::default();
        let g = vals(17);
        let mut opt_s = ShardedOptimizer::new(&cfg, 17, 3);
        let mut opt_r = ShardedOptimizer::new(&cfg, 17, 3);
        let mut r = ParamStore::replicated(full.clone());
        s.step_owned(&mut opt_s, &Reduced::Sharded(scatter(&g, 3)), 1e-3);
        r.step_owned(&mut opt_r, &Reduced::Sharded(scatter(&g, 3)), 1e-3);
        assert_eq!(s.to_full(), r.to_full(), "layouts diverged");
        // the view was dropped by the update and regathers to the new values
        s.materialize(&*c);
        assert_eq!(s.as_full(), &r.to_full()[..]);
    }

    #[test]
    fn full_gradient_onto_owned_partitions_is_bitwise_sharded() {
        let cfg = TrainConfig::default();
        let full = vals(29);
        let g = vals(29);
        let mut a = ParamStore::sharded(full.clone(), 4);
        let mut b = ParamStore::sharded(full, 4);
        let mut opt_a = ShardedOptimizer::new(&cfg, 29, 4);
        let mut opt_b = ShardedOptimizer::new(&cfg, 29, 4);
        a.step_owned(&mut opt_a, &Reduced::Full(g.clone()), 1e-3);
        b.step_owned(&mut opt_b, &Reduced::Sharded(scatter(&g, 4)), 1e-3);
        assert_eq!(a.to_full(), b.to_full());
    }

    #[test]
    fn copy_from_full_rescatters_and_invalidates_the_view() {
        let c = collective_for(Algorithm::Tree);
        let mut s = ParamStore::sharded(vals(11), 2);
        s.materialize(&*c);
        let replacement: Vec<f32> = vec![7.5; 11];
        s.copy_from_full(&replacement);
        assert_eq!(s.to_full(), replacement);
        s.materialize(&*c);
        assert_eq!(s.as_full(), &replacement[..]);
    }

    #[test]
    #[should_panic(expected = "materialize")]
    fn unmaterialized_sharded_view_is_a_sequencing_bug() {
        let s = ParamStore::sharded(vals(8), 2);
        let _ = s.as_full();
    }

    #[test]
    fn drop_views_clears_even_unstepped_stores() {
        // the frozen-base case: a store that is never stepped must still
        // shed its gathered view when the step ends, or the full vector
        // stays resident for the whole LoraOnly phase
        let c = collective_for(Algorithm::Ring);
        let cfg = TrainConfig::default();
        let mut model = ModelState::new(
            ParamStore::sharded(vals(12), 3),
            ShardedOptimizer::new(&cfg, 12, 3),
        );
        model.lora = Some(ParamStore::sharded(vals(5), 3));
        model.base.materialize(&*c);
        model.lora.as_mut().unwrap().materialize(&*c);
        assert_eq!(model.base_view().len(), 12);
        model.freeze_base();
        model.drop_views();
        // both views are gone; a fresh materialize rebuilds them
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = model.base_view();
        }))
        .is_err();
        assert!(panicked, "the frozen base's view must have been dropped");
        model.base.materialize(&*c);
        assert_eq!(model.base_view().len(), 12);
    }
}
