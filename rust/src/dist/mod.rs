//! `dist` — the single distributed-execution API.
//!
//! Everything the trainer knows about data-parallel sharding goes through
//! two traits defined here:
//!
//! * [`Collective`] — the communication primitives (all-reduce,
//!   reduce-scatter, all-gather, broadcast, the ordered scalar reduce).
//!   The in-memory naive / tree / ring summation schedules from
//!   [`crate::dp::allreduce`] are its stock implementation
//!   ([`AlgoCollective`]), carrying their bitwise contracts unchanged: the
//!   scattered chunks of a reduce-scatter concatenate bit-for-bit to the
//!   all-reduce output, and the ordered scalar reduce folds exactly like
//!   the full-buffer norm accumulation.
//! * [`Strategy`] — an object-safe description of *which* training state
//!   is partitioned across the data-parallel ranks and how the step
//!   engine must route gradients, parameters and optimizer state through
//!   that layout. The four stock strategies are the ZeRO stages
//!   (Rajbhandari et al. 2020): [`Unsharded`], [`Zero1`] (optimizer
//!   state), [`Zero2`] (+ gradient buffers) and [`Zero3`] (+ the
//!   parameters themselves).
//!
//! Call sites — `Trainer`, the step pipeline, checkpoint save/restore,
//! config, CLI and the benches — hold an `Arc<dyn Strategy>` and never
//! branch on the stage. The *only* stage `match` in the crate is
//! [`strategy_for`], and the only gradient-layout `match`es live in this
//! module's defaults. PreLoRA's phase switches (Full -> Warmup ->
//! LoraOnly) are delivered to the strategy as first-class
//! [`Repartition`] events, not per-call-site special cases — the ReLoRA
//! lesson that low-rank phases interleaved with resharding are the norm.
//!
//! **Bitwise contract.** For a fixed seed, every strategy produces
//! bit-identical per-epoch losses, gradient norms and final parameters to
//! [`Unsharded`] (asserted stage-by-stage in `rust/tests/integration.rs`
//! and property-tested over odd worker counts in [`zero3`]). The layout
//! changes *where* bytes live, never which additions happen in which
//! order. See `docs/dist-api.md` for the full contract table.

pub mod collective;
pub mod model;
pub mod net;
pub mod strategy;
pub mod zero3;

pub use collective::{
    AlgoCollective, Collective, CollectiveEndpoint, EndpointCollective, LocalEndpoint, LocalGroup,
    OpDesc,
};
pub use model::{ModelState, ParamStore, Repartition};
pub use net::TcpEndpoint;
pub use strategy::{
    clip_reduced, ParamSpace, ShardPlan, StateBytes, Strategy, Unsharded, Zero1, Zero2,
};
pub use zero3::Zero3;

use std::str::FromStr;
use std::sync::Arc;

use crate::dp::Algorithm;

/// The ZeRO sharding stage: which training state is partitioned across
/// the data-parallel ranks. Stages are cumulative — each shard everything
/// the previous one does, plus one more class of state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ZeroStage {
    /// Classic DDP: everything replicated on every rank.
    Off,
    /// Optimizer state sharded (~1/N moments per rank).
    Zero1,
    /// + gradient buffers: the reduce is a terminal reduce-scatter.
    Zero2,
    /// + the parameters themselves: each rank owns a contiguous base-param
    /// partition; the full working view is all-gathered per step and
    /// dropped after the update.
    Zero3,
}

impl ZeroStage {
    /// Canonical config spelling (the `train.zero.stage` integer).
    pub fn as_str(self) -> &'static str {
        match self {
            ZeroStage::Off => "0",
            ZeroStage::Zero1 => "1",
            ZeroStage::Zero2 => "2",
            ZeroStage::Zero3 => "3",
        }
    }

    pub fn as_u8(self) -> u8 {
        match self {
            ZeroStage::Off => 0,
            ZeroStage::Zero1 => 1,
            ZeroStage::Zero2 => 2,
            ZeroStage::Zero3 => 3,
        }
    }

    pub fn from_usize(x: usize) -> Result<Self, String> {
        match x {
            0 => Ok(ZeroStage::Off),
            1 => Ok(ZeroStage::Zero1),
            2 => Ok(ZeroStage::Zero2),
            3 => Ok(ZeroStage::Zero3),
            other => Err(format!(
                "ZeRO stage must be 0 (off), 1 (optimizer state), 2 (+ gradients) or 3 \
                 (+ parameters), got {other}"
            )),
        }
    }

    /// Optimizer-state partition count at this stage (stages 1+).
    pub fn opt_shards(self, workers: usize) -> usize {
        if self >= ZeroStage::Zero1 {
            workers.max(1)
        } else {
            1
        }
    }

    /// Gradient-buffer partition count at this stage (stages 2+: the
    /// reduce-scatter is terminal).
    pub fn grad_parts(self, workers: usize) -> usize {
        if self >= ZeroStage::Zero2 {
            workers.max(1)
        } else {
            1
        }
    }

    /// Parameter partition count at this stage (stage 3 only).
    pub fn param_parts(self, workers: usize) -> usize {
        if self >= ZeroStage::Zero3 {
            workers.max(1)
        } else {
            1
        }
    }
}

impl std::fmt::Display for ZeroStage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for ZeroStage {
    type Err = String;

    /// Case-insensitive: accepts the canonical integers plus the spelled
    /// forms (`off`, `zero1` / `zero-1` / `stage1`, ...).
    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "0" | "off" | "none" => Ok(ZeroStage::Off),
            "1" | "zero1" | "zero-1" | "stage1" => Ok(ZeroStage::Zero1),
            "2" | "zero2" | "zero-2" | "stage2" => Ok(ZeroStage::Zero2),
            "3" | "zero3" | "zero-3" | "stage3" => Ok(ZeroStage::Zero3),
            other => Err(format!(
                "unknown ZeRO stage {other:?} (expected 0|1|2|3, or off/zero1/zero2/zero3)"
            )),
        }
    }
}

/// The stock [`Collective`] over an in-memory all-reduce algorithm.
pub fn collective_for(alg: Algorithm) -> Arc<dyn Collective> {
    Arc::new(AlgoCollective::new(alg))
}

/// Construct the strategy for a stage. This is the one place in the crate
/// that branches on [`ZeroStage`] — everywhere else dispatches through
/// the [`Strategy`] trait object.
pub fn strategy_for(
    stage: ZeroStage,
    workers: usize,
    collective: Arc<dyn Collective>,
) -> Arc<dyn Strategy> {
    match stage {
        ZeroStage::Off => Arc::new(Unsharded::new(workers, collective)),
        ZeroStage::Zero1 => Arc::new(Zero1::new(workers, collective)),
        ZeroStage::Zero2 => Arc::new(Zero2::new(workers, collective)),
        ZeroStage::Zero3 => Arc::new(Zero3::new(workers, collective)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_display_roundtrips_case_insensitively() {
        for stage in [ZeroStage::Off, ZeroStage::Zero1, ZeroStage::Zero2, ZeroStage::Zero3] {
            assert_eq!(stage.to_string().parse::<ZeroStage>().unwrap(), stage);
            assert_eq!(ZeroStage::from_usize(stage.as_u8() as usize).unwrap(), stage);
        }
        assert_eq!("OFF".parse::<ZeroStage>().unwrap(), ZeroStage::Off);
        assert_eq!("Zero3".parse::<ZeroStage>().unwrap(), ZeroStage::Zero3);
        assert_eq!("STAGE2".parse::<ZeroStage>().unwrap(), ZeroStage::Zero2);
        let err = "4".parse::<ZeroStage>().unwrap_err();
        assert!(err.contains("ZeRO stage"), "{err}");
        assert!(ZeroStage::from_usize(7).is_err());
    }

    #[test]
    fn stages_are_cumulative() {
        let w = 4;
        assert_eq!(ZeroStage::Off.opt_shards(w), 1);
        assert_eq!(ZeroStage::Zero1.opt_shards(w), 4);
        assert_eq!(ZeroStage::Zero1.grad_parts(w), 1);
        assert_eq!(ZeroStage::Zero2.grad_parts(w), 4);
        assert_eq!(ZeroStage::Zero2.param_parts(w), 1);
        assert_eq!(ZeroStage::Zero3.param_parts(w), 4);
        assert_eq!(ZeroStage::Zero3.opt_shards(w), 4);
        assert_eq!(ZeroStage::Zero3.grad_parts(w), 4);
        // a single worker degenerates every stage to the unsharded layout
        assert_eq!(ZeroStage::Zero3.param_parts(1), 1);
    }

    #[test]
    fn strategy_for_matches_stage() {
        let c = collective_for(Algorithm::Tree);
        for stage in [ZeroStage::Off, ZeroStage::Zero1, ZeroStage::Zero2, ZeroStage::Zero3] {
            let s = strategy_for(stage, 3, c.clone());
            assert_eq!(s.stage(), stage);
            assert_eq!(s.workers(), 3);
            assert_eq!(s.opt_shards(), stage.opt_shards(3));
            assert_eq!(s.grad_parts(), stage.grad_parts(3));
            assert_eq!(s.param_parts(), stage.param_parts(3));
        }
    }
}
