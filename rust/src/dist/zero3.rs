//! ZeRO-3: parameter sharding. Each rank persistently owns one contiguous
//! partition of every parameter space (base trunk *and*, after the
//! switch, the adapter vector); the full working view the forward /
//! backward pass needs is all-gathered at the start of each step
//! ([`Strategy::materialize_params`]) and dropped when the step's update
//! lands. Gradients reduce-scatter terminally onto the same partition,
//! and each rank's optimizer shard updates only its owned slice — so
//! per-rank `param_bytes`, `grad_bytes` and `optimizer_bytes` all shrink
//! to ~1/N (chunk-rounded), the full ZeRO memory curve of Rajbhandari et
//! al. 2020.
//!
//! **Bit contract.** The gathered view is an exact concatenation of the
//! owned chunks, the reduce-scatter performs the all-reduce's additions
//! in the all-reduce's order, clipping assembles the global norm through
//! the ordered scalar reduce, and the per-shard optimizer update is the
//! elementwise update of the corresponding full-vector slices. Turning
//! stage 3 on therefore cannot change a single loss bit — property-tested
//! below over odd worker counts and ragged lengths, and end-to-end across
//! the Full -> Warmup -> LoraOnly lifecycle in `rust/tests/`.
//!
//! All behavior comes from the [`Strategy`] defaults: `Zero3` only
//! declares that all three partition dimensions — optimizer, gradient,
//! parameter — follow the worker count.

use std::sync::Arc;

use super::collective::Collective;
use super::strategy::Strategy;
use super::ZeroStage;

/// The stage-3 strategy: optimizer state, gradient buffers and the
/// parameters themselves all partitioned across the ranks.
pub struct Zero3 {
    workers: usize,
    collective: Arc<dyn Collective>,
}

impl Zero3 {
    pub fn new(workers: usize, collective: Arc<dyn Collective>) -> Self {
        Self { workers, collective }
    }
}

impl Strategy for Zero3 {
    fn stage(&self) -> ZeroStage {
        ZeroStage::Zero3
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn collective(&self) -> &dyn Collective {
        &*self.collective
    }

    fn bucketed_sync(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::dist::{collective_for, strategy_for, ModelState};
    use crate::dp::Algorithm;
    use crate::tensor::Pcg64;
    use crate::util::prop::{check, Arbitrary};

    /// A short synthetic training trajectory: worker count, length (kept
    /// deliberately non-aligned), steps, clip threshold.
    #[derive(Debug, Clone)]
    struct TrajCase {
        workers: usize,
        len: usize,
        steps: usize,
        clip: f64,
        seed: u64,
    }

    impl Arbitrary for TrajCase {
        fn generate(rng: &mut Pcg64) -> Self {
            let workers = [2usize, 3, 5, 7][rng.next_below(4)];
            let mut len = 1 + rng.next_below(200);
            if len % workers == 0 {
                len += 1; // force a ragged final partition
            }
            TrajCase {
                workers,
                len,
                steps: 1 + rng.next_below(4),
                clip: if rng.next_below(3) == 0 { 0.0 } else { 0.5 + rng.next_f64() * 4.0 },
                seed: rng.next_u64(),
            }
        }

        fn shrink(&self) -> Vec<Self> {
            let mut out = Vec::new();
            if self.len > 1 {
                let mut c = self.clone();
                c.len = 1 + self.len / 2;
                out.push(c);
            }
            if self.steps > 1 {
                let mut c = self.clone();
                c.steps = 1;
                out.push(c);
            }
            out
        }
    }

    fn worker_grads(rng: &mut Pcg64, workers: usize, len: usize) -> Vec<Vec<f32>> {
        (0..workers)
            .map(|_| {
                let mut g = vec![0.0f32; len];
                rng.fill_normal(&mut g, 0.8);
                g
            })
            .collect()
    }

    /// The core ZeRO-3 equivalence: a multi-step trajectory through
    /// sharded parameters + terminal reduce-scatter + per-shard updates
    /// is bitwise the unsharded trajectory — gathered views, clipped
    /// norms and final parameters all agree exactly, for odd worker
    /// counts and ragged partition lengths.
    #[test]
    fn prop_zero3_trajectory_is_bitwise_unsharded() {
        check::<TrajCase, _>(909, 120, |case| {
            let cfg = TrainConfig::default();
            let init: Vec<f32> = (0..case.len).map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.1).collect();

            let off = strategy_for(ZeroStage::Off, case.workers, collective_for(Algorithm::Ring));
            let z3 = strategy_for(ZeroStage::Zero3, case.workers, collective_for(Algorithm::Ring));

            let mut m_off = ModelState::new(off.park_params(init.clone()), off.optimizer(&cfg, case.len));
            let mut m_z3 = ModelState::new(z3.park_params(init), z3.optimizer(&cfg, case.len));

            let mut rng_a = Pcg64::new(case.seed);
            let mut rng_b = Pcg64::new(case.seed);
            for _ in 0..case.steps {
                // views must agree before the step
                off.materialize_params(&mut m_off);
                z3.materialize_params(&mut m_z3);
                if m_off.base_view() != m_z3.base_view() {
                    return false;
                }
                let mut g_off = off.grad_sync(worker_grads(&mut rng_a, case.workers, case.len));
                let mut g_z3 = z3.grad_sync(worker_grads(&mut rng_b, case.workers, case.len));
                let (Some(g_off), Some(g_z3)) = (g_off.as_mut(), g_z3.as_mut()) else {
                    return false;
                };
                let n_off = off.clip_grad(g_off, case.clip);
                let n_z3 = z3.clip_grad(g_z3, case.clip);
                if n_off.to_bits() != n_z3.to_bits() {
                    return false;
                }
                let opt_off = m_off.opt_base.as_mut().unwrap();
                off.step(opt_off, &mut m_off.base, g_off, 1e-3);
                let opt_z3 = m_z3.opt_base.as_mut().unwrap();
                z3.step(opt_z3, &mut m_z3.base, g_z3, 1e-3);
            }
            // final parameters and gathered optimizer state agree bitwise
            m_off.base.to_full() == m_z3.base.to_full()
                && m_off.opt_base.as_ref().unwrap().export_state()
                    == m_z3.opt_base.as_ref().unwrap().export_state()
        });
    }

    /// Bucket boundaries fuzzed over ragged lengths, odd worker counts
    /// and bucket element counts coprime with the worker count: the
    /// bucketed reduce assembled in index order must be bitwise the
    /// whole-buffer reduce-scatter for the stage-3 layout.
    #[test]
    fn prop_bucketed_reduce_scatter_is_bitwise_whole_buffer() {
        check::<TrajCase, _>(911, 120, |case| {
            let z3 = strategy_for(ZeroStage::Zero3, case.workers, collective_for(Algorithm::Ring));
            let mut rng = Pcg64::new(case.seed);
            let src = worker_grads(&mut rng, case.workers, case.len);
            let Some(want) = z3.grad_sync(src.clone()) else { return false };
            // bucket sizes deliberately coprime with typical worker counts
            for bytes in [0usize, 4, 44, 52, 4 * case.len] {
                let plan = z3.bucket_plan(case.len, bytes);
                let mut chunks = vec![Vec::new(); plan.parts];
                for b in &plan.buckets {
                    let slices: Vec<Vec<f32>> =
                        src.iter().map(|w| w[b.lo..b.hi].to_vec()).collect();
                    let Some(r) = z3.grad_sync_bucket(slices, b.lo, case.len) else {
                        return false;
                    };
                    chunks[b.part].extend(r);
                }
                let got = crate::dp::Reduced::Sharded(chunks);
                let same = match &want {
                    crate::dp::Reduced::Sharded(w) => matches!(&got, crate::dp::Reduced::Sharded(g) if g == w),
                    crate::dp::Reduced::Full(_) => false,
                };
                if !same {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn per_rank_bytes_all_shrink() {
        let cfg = TrainConfig::default();
        let workers = 4;
        let n = 10_001; // ragged
        let z3 = strategy_for(ZeroStage::Zero3, workers, collective_for(Algorithm::Tree));
        let model = ModelState::new(z3.park_params(vec![0.5; n]), z3.optimizer(&cfg, n));
        let st = z3.state_bytes(&model);
        let bound = |per: usize, total: usize| per as f64 <= total as f64 / workers as f64 + 16.0;
        assert!(bound(st.param_bytes_per_rank, st.param_total_bytes), "{st:?}");
        assert!(bound(st.opt_bytes_per_rank, st.opt_total_bytes), "{st:?}");
        let g = z3.grad_sync(vec![vec![1.0f32; n]; workers]).unwrap();
        assert!(
            bound(g.per_rank_elems() * 4, n * 4),
            "per-rank gradient bytes must be ~1/{workers}"
        );
        // the working view exists only between materialize and the update
        let mut model = model;
        z3.materialize_params(&mut model);
        assert_eq!(model.base_view().len(), n);
    }

    #[test]
    fn checkpoint_payload_is_shard_layout_independent() {
        // gather-on-save: a stage-3 store exports the identical bytes an
        // unsharded store would, so files restore onto any layout
        let full: Vec<f32> = (0..57).map(|i| i as f32 * 0.25 - 7.0).collect();
        let z3 = strategy_for(ZeroStage::Zero3, 5, collective_for(Algorithm::Naive));
        let off = strategy_for(ZeroStage::Off, 5, collective_for(Algorithm::Naive));
        let s3 = z3.park_params(full.clone());
        let s0 = off.park_params(full.clone());
        assert_eq!(z3.export_params(&s3), off.export_params(&s0));
        // and a cross-layout import round-trips
        let mut s3 = s3;
        z3.import_params(&mut s3, &off.export_params(&s0)).unwrap();
        assert_eq!(z3.export_params(&s3), full);
    }
}
