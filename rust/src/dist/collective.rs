//! The [`Collective`] trait: communication primitives strategies speak.
//!
//! Every operation carries a **bit contract** inherited from
//! [`crate::dp::allreduce`]:
//!
//! * [`reduce_scatter`] chunks concatenate bit-for-bit to the
//!   [`all_reduce`] output of the same inputs — the per-element summation
//!   order is identical, only the final placement differs;
//! * [`all_gather`] is the exact inverse of the partition chunking (a
//!   plain concatenation — no arithmetic, so no rounding);
//! * [`sq_sum_in_order`] folds the chunks' squared elements in
//!   chunk-then-element order, which is bitwise the f64 left fold over the
//!   concatenated buffer (what keeps sharded gradient clipping identical
//!   to the full-buffer clip);
//! * [`broadcast`] replicates bytes verbatim.
//!
//! These contracts are what let a [`super::Strategy`] change *where*
//! state lives without changing a single bit of the training trajectory.
//!
//! [`reduce_scatter`]: Collective::reduce_scatter
//! [`all_reduce`]: Collective::all_reduce
//! [`all_gather`]: Collective::all_gather
//! [`sq_sum_in_order`]: Collective::sq_sum_in_order
//! [`broadcast`]: Collective::broadcast

use crate::dp::Algorithm;

/// Communication backend for the distributed strategies. Object-safe;
/// implementations must be shareable across the pipeline's stage threads.
pub trait Collective: Send + Sync {
    /// Human-readable backend name (logs, bench labels).
    fn name(&self) -> &'static str;

    /// Elementwise mean of same-length buffers, returned replicated (the
    /// classic DDP all-reduce). `None` for an empty buffer set.
    fn all_reduce(&self, bufs: Vec<Vec<f32>>) -> Option<Vec<f32>>;

    /// Elementwise mean returned as `parts` owned contiguous chunks (the
    /// [`crate::dp::partition`] layout) — the terminal op on the ZeRO-2/3
    /// hot path: the input buffers are consumed and no replicated mean
    /// vector is materialized. The chunks concatenate **bitwise** to the
    /// [`all_reduce`](Self::all_reduce) output.
    fn reduce_scatter(&self, bufs: Vec<Vec<f32>>, parts: usize) -> Option<Vec<Vec<f32>>>;

    /// Reduce one bucket — a contiguous slice `[lo, lo + bufs[0].len())`
    /// of a `full_len`-element gradient space — such that concatenating
    /// the per-bucket outputs in index order reproduces the whole-buffer
    /// [`all_reduce`](Self::all_reduce) **bitwise**. `None` means the
    /// backend does not support bucketed reduction; callers must fall
    /// back to the whole-buffer path (the default, so custom backends
    /// keep today's behavior unchanged).
    fn reduce_bucket(&self, bufs: Vec<Vec<f32>>, lo: usize, full_len: usize) -> Option<Vec<f32>> {
        let _ = (bufs, lo, full_len);
        None
    }

    /// Reassemble the full vector from partition-ordered chunks (exact
    /// concatenation; the step that builds the ZeRO-3 working view).
    fn all_gather(&self, chunks: &[Vec<f32>]) -> Vec<f32> {
        crate::dp::all_gather(chunks)
    }

    /// Replicate one buffer onto `ranks` ranks verbatim.
    fn broadcast(&self, full: &[f32], ranks: usize) -> Vec<Vec<f32>> {
        vec![full.to_vec(); ranks]
    }

    /// Ordered scalar reduction: fold the chunks' squared elements into
    /// one f64 in chunk-then-element order — bitwise the accumulation
    /// [`crate::tensor::sq_norm`] performs over the concatenation, which
    /// is what keeps sharded clipping bit-identical to the full clip.
    fn sq_sum_in_order(&self, chunks: &[Vec<f32>]) -> f64 {
        crate::dp::sq_sum_in_order(chunks)
    }
}

/// The stock collective: the in-memory naive / tree / ring summation
/// schedules of [`crate::dp::allreduce`], unchanged. A real multi-host
/// backend would implement [`Collective`] over NCCL/RCCL instead; the
/// trait is the seam (`docs/dist-api.md` § Adding a backend).
pub struct AlgoCollective {
    alg: Algorithm,
}

impl AlgoCollective {
    pub fn new(alg: Algorithm) -> Self {
        Self { alg }
    }

    pub fn algorithm(&self) -> Algorithm {
        self.alg
    }
}

impl Collective for AlgoCollective {
    fn name(&self) -> &'static str {
        self.alg.as_str()
    }

    fn all_reduce(&self, bufs: Vec<Vec<f32>>) -> Option<Vec<f32>> {
        crate::dp::reduce_owned(self.alg, bufs)
    }

    fn reduce_scatter(&self, bufs: Vec<Vec<f32>>, parts: usize) -> Option<Vec<Vec<f32>>> {
        crate::dp::reduce_scatter(self.alg, bufs, parts)
    }

    fn reduce_bucket(&self, bufs: Vec<Vec<f32>>, lo: usize, full_len: usize) -> Option<Vec<f32>> {
        crate::dp::reduce_bucket(self.alg, bufs, lo, full_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dp::{reduce_owned, scatter};

    fn bufs(n: usize, len: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|w| (0..len).map(|i| ((w * 31 + i * 7) % 13) as f32 - 6.0).collect())
            .collect()
    }

    #[test]
    fn all_reduce_matches_dp_bitwise_per_algorithm() {
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            let c = AlgoCollective::new(alg);
            assert_eq!(c.name(), alg.as_str());
            assert_eq!(c.algorithm(), alg);
            let want = reduce_owned(alg, bufs(5, 101)).unwrap();
            assert_eq!(c.all_reduce(bufs(5, 101)).unwrap(), want, "{alg:?}");
            assert!(c.all_reduce(Vec::new()).is_none());
        }
    }

    #[test]
    fn reduce_scatter_concat_is_bitwise_all_reduce() {
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            let c = AlgoCollective::new(alg);
            let want = c.all_reduce(bufs(3, 103)).unwrap();
            for parts in [1usize, 2, 3, 5, 7] {
                let chunks = c.reduce_scatter(bufs(3, 103), parts).unwrap();
                assert_eq!(chunks.len(), parts);
                assert_eq!(c.all_gather(&chunks), want, "{alg:?} parts={parts}");
            }
        }
    }

    #[test]
    fn bucketed_reduce_concat_is_bitwise_all_reduce() {
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            let c = AlgoCollective::new(alg);
            let len = 101;
            let want = c.all_reduce(bufs(3, len)).unwrap();
            let plan = crate::dp::BucketPlan::derive(len, 1, 52);
            let src = bufs(3, len);
            let mut got = Vec::with_capacity(len);
            for b in &plan.buckets {
                let slices: Vec<Vec<f32>> = src.iter().map(|w| w[b.lo..b.hi].to_vec()).collect();
                got.extend(c.reduce_bucket(slices, b.lo, len).unwrap());
            }
            assert_eq!(got, want, "{alg:?}");
        }
    }

    #[test]
    fn default_reduce_bucket_signals_unsupported() {
        struct Whole;
        impl Collective for Whole {
            fn name(&self) -> &'static str {
                "whole"
            }
            fn all_reduce(&self, bufs: Vec<Vec<f32>>) -> Option<Vec<f32>> {
                crate::dp::reduce_owned(Algorithm::Naive, bufs)
            }
            fn reduce_scatter(&self, bufs: Vec<Vec<f32>>, parts: usize) -> Option<Vec<Vec<f32>>> {
                crate::dp::reduce_scatter(Algorithm::Naive, bufs, parts)
            }
        }
        assert!(Whole.reduce_bucket(bufs(2, 8), 0, 8).is_none());
    }

    #[test]
    fn gather_inverts_scatter_and_broadcast_replicates() {
        let c = AlgoCollective::new(Algorithm::Ring);
        let full: Vec<f32> = (0..37).map(|i| i as f32 * 0.5 - 9.0).collect();
        assert_eq!(c.all_gather(&scatter(&full, 5)), full);
        let reps = c.broadcast(&full, 3);
        assert_eq!(reps.len(), 3);
        assert!(reps.iter().all(|r| r == &full));
    }

    #[test]
    fn ordered_scalar_reduce_is_bitwise_the_full_fold() {
        let c = AlgoCollective::new(Algorithm::Tree);
        let full: Vec<f32> = (0..103).map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.37).collect();
        for parts in [1usize, 3, 5, 103] {
            assert_eq!(
                c.sq_sum_in_order(&scatter(&full, parts)),
                crate::tensor::sq_norm(&full),
                "parts={parts}"
            );
        }
    }
}
