//! Collective communication: the per-rank [`CollectiveEndpoint`] trait
//! (canonical) and the legacy buffer-matrix [`Collective`] trait (shimmed).
//!
//! Every operation carries a **bit contract** inherited from
//! [`crate::dp::allreduce`]:
//!
//! * [`reduce_scatter`] chunks concatenate bit-for-bit to the
//!   [`all_reduce`] output of the same inputs — the per-element summation
//!   order is identical, only the final placement differs;
//! * [`all_gather`] is the exact inverse of the partition chunking (a
//!   plain concatenation — no arithmetic, so no rounding);
//! * [`sq_sum_in_order`] folds the chunks' squared elements in
//!   chunk-then-element order, which is bitwise the f64 left fold over the
//!   concatenated buffer (what keeps sharded gradient clipping identical
//!   to the full-buffer clip);
//! * [`broadcast`] replicates bytes verbatim.
//!
//! These contracts are what let a [`super::Strategy`] change *where*
//! state lives without changing a single bit of the training trajectory.
//!
//! ## The endpoint seam
//!
//! The legacy [`Collective`] methods take `Vec<Vec<f32>>` — every rank's
//! buffer in one address space — which only a single-process simulation
//! can provide. [`CollectiveEndpoint`] is the per-rank replacement: each
//! rank holds one endpoint, contributes **its own** buffer, and the group
//! (in-process [`LocalGroup`] rendezvous or the TCP backend in
//! [`super::net`]) runs the *same* naive/tree/ring summation schedule over
//! the rank-ordered contributions. Results are therefore bitwise identical
//! to the matrix path by construction. The matrix-style methods are
//! `#[deprecated]` with a one-release shim: [`AlgoCollective`] keeps
//! working unchanged, and [`EndpointCollective`] adapts any endpoint back
//! onto the old trait for the strategy machinery.
//!
//! [`reduce_scatter`]: Collective::reduce_scatter
//! [`all_reduce`]: Collective::all_reduce
//! [`all_gather`]: Collective::all_gather
//! [`sq_sum_in_order`]: Collective::sq_sum_in_order
//! [`broadcast`]: Collective::broadcast

use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, ensure, Result};

use crate::dp::Algorithm;

/// What a collective operation does, independent of transport. Every rank
/// of a group must issue the *same* descriptor for the same op index —
/// the lockstep invariant both the in-process rendezvous and the TCP
/// backend check and fail loudly on (a desync means ranks have diverged,
/// and any result would be garbage).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpDesc {
    /// Elementwise mean of every rank's `len`-element buffer, replicated.
    AllReduce { len: usize },
    /// Mean returned as `parts` partition-ordered chunks (all chunks are
    /// delivered to every rank — see [`CollectiveEndpoint::reduce_scatter`]).
    ReduceScatter { len: usize, parts: usize },
    /// Mean of one contiguous bucket `[lo, lo + len)` of a
    /// `full_len`-element space.
    ReduceBucket { len: usize, lo: usize, full_len: usize },
    /// Concatenation fodder: every rank's buffer, rank-ordered. Lengths
    /// may differ per rank (ragged partition tails), so none is pinned.
    AllGather,
    /// Rank `root`'s `len`-element buffer, replicated verbatim.
    Broadcast { len: usize, root: usize },
    /// Every rank's `n` f64 scalars, rank-ordered and bit-exact (the
    /// loss/accuracy fold — f64 on the wire so no precision is lost).
    Scalars { n: usize },
    /// Rendezvous only; no data moves.
    Barrier,
}

/// The result of one collective op, shape depending on the [`OpDesc`].
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum OpOut {
    Full(Vec<f32>),
    Chunks(Vec<Vec<f32>>),
    Scalars(Vec<Vec<f64>>),
    Unit,
}

/// Run one op over the rank-ordered contributions — the *single* place
/// the summation schedule executes, shared by the in-process rendezvous
/// and the TCP backend's root replay, so every transport produces the
/// exact bits [`AlgoCollective`] would.
pub(crate) fn compute_op(
    alg: Algorithm,
    desc: &OpDesc,
    bufs: Vec<Vec<f32>>,
    scalars: Vec<Vec<f64>>,
) -> Result<OpOut> {
    match *desc {
        OpDesc::AllReduce { len } => {
            for (r, b) in bufs.iter().enumerate() {
                ensure!(b.len() == len, "rank {r} contributed {} elements, expected {len}", b.len());
            }
            crate::dp::reduce_owned(alg, bufs)
                .map(OpOut::Full)
                .ok_or_else(|| anyhow!("all_reduce over an empty contribution set"))
        }
        OpDesc::ReduceScatter { len, parts } => {
            for (r, b) in bufs.iter().enumerate() {
                ensure!(b.len() == len, "rank {r} contributed {} elements, expected {len}", b.len());
            }
            crate::dp::reduce_scatter(alg, bufs, parts)
                .map(OpOut::Chunks)
                .ok_or_else(|| anyhow!("reduce_scatter over an empty contribution set"))
        }
        OpDesc::ReduceBucket { len, lo, full_len } => {
            for (r, b) in bufs.iter().enumerate() {
                ensure!(b.len() == len, "rank {r} contributed {} elements, expected {len}", b.len());
            }
            crate::dp::reduce_bucket(alg, bufs, lo, full_len)
                .map(OpOut::Full)
                .ok_or_else(|| anyhow!("reduce_bucket over an empty contribution set"))
        }
        OpDesc::AllGather => Ok(OpOut::Chunks(bufs)),
        OpDesc::Broadcast { len, root } => {
            let b = bufs
                .get(root)
                .ok_or_else(|| anyhow!("broadcast root {root} outside the group"))?;
            ensure!(b.len() == len, "broadcast root buffer is {} elements, expected {len}", b.len());
            Ok(OpOut::Full(b.clone()))
        }
        OpDesc::Scalars { n } => {
            for (r, s) in scalars.iter().enumerate() {
                ensure!(s.len() == n, "rank {r} contributed {} scalars, expected {n}", s.len());
            }
            Ok(OpOut::Scalars(scalars))
        }
        OpDesc::Barrier => Ok(OpOut::Unit),
    }
}

/// One rank's handle on a collective group — the canonical communication
/// API. Each data-parallel rank (whether an in-process endpoint from a
/// [`LocalGroup`] or a separate OS process behind [`super::net`]'s TCP
/// backend) holds exactly one endpoint and contributes only its own
/// buffers; the group executes the shared summation schedule over the
/// rank-ordered contributions.
///
/// **Bit contract.** For identical per-rank inputs, every operation
/// returns bits identical to the matrix-style [`AlgoCollective`] call
/// with the same algorithm — all transports funnel through the one
/// in-memory schedule (see [`compute_op`]), so there is no second
/// summation order to audit.
///
/// **Lockstep contract.** All ranks must issue the same sequence of
/// operations with matching [`OpDesc`]s. Divergence is detected (op
/// descriptors and per-connection sequence numbers are compared) and
/// surfaces as a loud error on every rank, never a hang or a silently
/// wrong result.
pub trait CollectiveEndpoint: Send + Sync {
    /// This endpoint's data-parallel rank, `0 <= rank < world_size`.
    fn rank(&self) -> usize;

    /// Ranks in the group.
    fn world_size(&self) -> usize;

    /// Canonical transport name (`"local"` | `"tcp"`) for logs/config.
    fn transport(&self) -> &'static str;

    /// Elementwise mean of every rank's buffer, replicated in place.
    fn all_reduce(&self, buf: &mut Vec<f32>) -> Result<()>;

    /// Elementwise mean returned as `parts` partition-ordered chunks.
    /// **All** chunks are returned to every rank (not just the caller's
    /// own): the training simulation replicates full model state per rank
    /// so ZeRO update arithmetic stays bitwise identical across
    /// transports, and per-rank *accounting* (what a real rank would
    /// retain) is handled by the strategy layer, not the wire.
    fn reduce_scatter(&self, buf: Vec<f32>, parts: usize) -> Result<Vec<Vec<f32>>>;

    /// Mean of one contiguous bucket `[lo, lo + buf.len())` of a
    /// `full_len`-element gradient space; outputs concatenated in bucket
    /// index order reproduce [`all_reduce`](Self::all_reduce) bitwise.
    fn reduce_bucket(&self, buf: Vec<f32>, lo: usize, full_len: usize) -> Result<Vec<f32>>;

    /// Every rank's buffer, rank-ordered (lengths may be ragged).
    fn all_gather(&self, own: Vec<f32>) -> Result<Vec<Vec<f32>>>;

    /// Overwrite `buf` with rank `root`'s buffer, bytes verbatim.
    fn broadcast(&self, buf: &mut Vec<f32>, root: usize) -> Result<()>;

    /// Every rank's f64 scalars, rank-ordered, bit-exact on the wire (the
    /// per-step loss/accuracy exchange folds these in rank order, which
    /// is bitwise the single-process fold over worker order).
    fn gather_scalars(&self, vals: &[f64]) -> Result<Vec<Vec<f64>>>;

    /// Block until every rank arrives.
    fn barrier(&self) -> Result<()>;
}

/// Communication backend for the distributed strategies — the **legacy**
/// buffer-matrix API. Object-safe; implementations must be shareable
/// across the pipeline's stage threads.
///
/// The matrix-style methods (which take every rank's buffer in one call)
/// are deprecated in favor of the per-rank [`CollectiveEndpoint`]; they
/// remain for one release so `AlgoCollective` callers migrate without
/// behavior change. The chunk-shaped helpers ([`all_gather`], `broadcast`
/// replication, [`sq_sum_in_order`]) stay: they operate on
/// partition-ordered chunks that exist on every rank under the
/// replicated-state simulation.
///
/// [`all_gather`]: Self::all_gather
/// [`sq_sum_in_order`]: Self::sq_sum_in_order
pub trait Collective: Send + Sync {
    /// Human-readable backend name (logs, bench labels).
    fn name(&self) -> &'static str;

    /// Elementwise mean of same-length buffers, returned replicated (the
    /// classic DDP all-reduce). `None` for an empty buffer set.
    #[deprecated(
        note = "matrix-style collective: takes every rank's buffer in one address space; \
                use CollectiveEndpoint::all_reduce (per-rank) — one-release shim"
    )]
    fn all_reduce(&self, bufs: Vec<Vec<f32>>) -> Option<Vec<f32>>;

    /// Elementwise mean returned as `parts` owned contiguous chunks (the
    /// [`crate::dp::partition`] layout) — the terminal op on the ZeRO-2/3
    /// hot path: the input buffers are consumed and no replicated mean
    /// vector is materialized. The chunks concatenate **bitwise** to the
    /// [`all_reduce`](Self::all_reduce) output.
    #[deprecated(
        note = "matrix-style collective: takes every rank's buffer in one address space; \
                use CollectiveEndpoint::reduce_scatter (per-rank) — one-release shim"
    )]
    fn reduce_scatter(&self, bufs: Vec<Vec<f32>>, parts: usize) -> Option<Vec<Vec<f32>>>;

    /// Reduce one bucket — a contiguous slice `[lo, lo + bufs[0].len())`
    /// of a `full_len`-element gradient space — such that concatenating
    /// the per-bucket outputs in index order reproduces the whole-buffer
    /// [`all_reduce`](Self::all_reduce) **bitwise**. `None` means the
    /// backend does not support bucketed reduction; callers must fall
    /// back to the whole-buffer path (the default, so custom backends
    /// keep today's behavior unchanged).
    #[deprecated(
        note = "matrix-style collective: takes every rank's buffer in one address space; \
                use CollectiveEndpoint::reduce_bucket (per-rank) — one-release shim"
    )]
    fn reduce_bucket(&self, bufs: Vec<Vec<f32>>, lo: usize, full_len: usize) -> Option<Vec<f32>> {
        let _ = (bufs, lo, full_len);
        None
    }

    /// Reassemble the full vector from partition-ordered chunks (exact
    /// concatenation; the step that builds the ZeRO-3 working view).
    fn all_gather(&self, chunks: &[Vec<f32>]) -> Vec<f32> {
        crate::dp::all_gather(chunks)
    }

    /// Replicate one buffer onto `ranks` ranks verbatim.
    #[deprecated(
        note = "matrix-style collective: materializes every rank's copy in one address \
                space; use CollectiveEndpoint::broadcast (per-rank) — one-release shim"
    )]
    fn broadcast(&self, full: &[f32], ranks: usize) -> Vec<Vec<f32>> {
        vec![full.to_vec(); ranks]
    }

    /// Ordered scalar reduction: fold the chunks' squared elements into
    /// one f64 in chunk-then-element order — bitwise the accumulation
    /// [`crate::tensor::sq_norm`] performs over the concatenation, which
    /// is what keeps sharded clipping bit-identical to the full clip.
    fn sq_sum_in_order(&self, chunks: &[Vec<f32>]) -> f64 {
        crate::dp::sq_sum_in_order(chunks)
    }

    /// The per-rank endpoint behind this collective, if it is backed by
    /// one ([`EndpointCollective`]); `None` for purely in-memory backends.
    /// The pipeline uses this to detect that the process is one rank of a
    /// multi-process group (batch shard selection, scalar exchange,
    /// rank-0-only checkpoint writes).
    fn endpoint(&self) -> Option<Arc<dyn CollectiveEndpoint>> {
        None
    }

    /// Take the first communication error recorded since the last call.
    /// The legacy matrix signatures return `Option`, which cannot carry a
    /// wire failure — endpoint-backed implementations record the error
    /// here and return `None` from the op, and the strategy's `try_*`
    /// wrappers surface it as a loud contextful `Err` instead of the
    /// indistinguishable "empty buffer set" `None`.
    fn take_error(&self) -> Option<anyhow::Error> {
        None
    }
}

/// The stock collective: the in-memory naive / tree / ring summation
/// schedules of [`crate::dp::allreduce`], unchanged. A real multi-host
/// backend implements [`CollectiveEndpoint`] instead (see [`super::net`]);
/// this trait impl is the one-release shim for matrix-style callers
/// (`docs/dist-api.md` § Adding a backend).
pub struct AlgoCollective {
    alg: Algorithm,
}

impl AlgoCollective {
    pub fn new(alg: Algorithm) -> Self {
        Self { alg }
    }

    pub fn algorithm(&self) -> Algorithm {
        self.alg
    }
}

#[allow(deprecated)] // the one-release shim: the matrix methods live here
impl Collective for AlgoCollective {
    fn name(&self) -> &'static str {
        self.alg.as_str()
    }

    fn all_reduce(&self, bufs: Vec<Vec<f32>>) -> Option<Vec<f32>> {
        crate::dp::reduce_owned(self.alg, bufs)
    }

    fn reduce_scatter(&self, bufs: Vec<Vec<f32>>, parts: usize) -> Option<Vec<Vec<f32>>> {
        crate::dp::reduce_scatter(self.alg, bufs, parts)
    }

    fn reduce_bucket(&self, bufs: Vec<Vec<f32>>, lo: usize, full_len: usize) -> Option<Vec<f32>> {
        crate::dp::reduce_bucket(self.alg, bufs, lo, full_len)
    }
}

/// Rendezvous state shared by a [`LocalGroup`]'s endpoints: one op slot
/// that fills with per-rank contributions, computes once when the last
/// rank arrives, and drains once every rank has taken the result.
struct Rendezvous {
    /// Per-rank f32 contribution of the op in flight.
    bufs: Vec<Option<Vec<f32>>>,
    /// Per-rank f64 contribution (scalar ops).
    scalars: Vec<Option<Vec<f64>>>,
    /// Descriptor set by the first arrival; later ranks must match it.
    desc: Option<OpDesc>,
    arrived: usize,
    result: Option<Arc<OpOut>>,
    consumed: usize,
    /// First lockstep violation or compute failure; all later ops fail
    /// fast with this message (the group is unrecoverable).
    poisoned: Option<String>,
}

/// An in-process collective group: `world` per-rank endpoints over one
/// shared rendezvous, executing the configured in-memory summation
/// schedule once per op. This is the adapter that lets matrix-style
/// [`AlgoCollective`] callers migrate to [`CollectiveEndpoint`] without
/// behavior change — the rendezvous assembles exactly the rank-ordered
/// buffer matrix the old API took as an argument, then runs the identical
/// [`compute_op`] schedule. It also implements the legacy [`Collective`]
/// trait directly (delegating to the same schedules), so it can stand in
/// wherever an `AlgoCollective` is used today.
pub struct LocalGroup {
    alg: Algorithm,
    world: usize,
    shared: Mutex<Rendezvous>,
    cv: Condvar,
}

impl LocalGroup {
    pub fn new(alg: Algorithm, world: usize) -> Arc<Self> {
        assert!(world >= 1, "a collective group needs at least one rank");
        Arc::new(Self {
            alg,
            world,
            shared: Mutex::new(Rendezvous {
                bufs: (0..world).map(|_| None).collect(),
                scalars: (0..world).map(|_| None).collect(),
                desc: None,
                arrived: 0,
                result: None,
                consumed: 0,
                poisoned: None,
            }),
            cv: Condvar::new(),
        })
    }

    pub fn algorithm(&self) -> Algorithm {
        self.alg
    }

    pub fn world_size(&self) -> usize {
        self.world
    }

    /// The endpoint for one rank. Endpoints are cheap handles; each rank's
    /// thread should hold its own.
    pub fn endpoint(self: &Arc<Self>, rank: usize) -> Arc<LocalEndpoint> {
        assert!(rank < self.world, "rank {rank} outside world of {}", self.world);
        Arc::new(LocalEndpoint { rank, group: self.clone() })
    }

    /// One endpoint per rank, rank-ordered.
    pub fn endpoints(self: &Arc<Self>) -> Vec<Arc<LocalEndpoint>> {
        (0..self.world).map(|r| self.endpoint(r)).collect()
    }

    /// One rank's participation in one op: contribute, rendezvous,
    /// compute-once, share the result.
    fn run_op(
        &self,
        rank: usize,
        desc: OpDesc,
        buf: Vec<f32>,
        scalars: Vec<f64>,
    ) -> Result<Arc<OpOut>> {
        let mut g = self.shared.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        // wait for the previous op to fully drain before starting a new one
        while g.result.is_some() && g.poisoned.is_none() {
            g = self.cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        if let Some(p) = &g.poisoned {
            bail!("local collective group poisoned: {p}");
        }
        match &g.desc {
            None => g.desc = Some(desc),
            Some(d) if *d == desc => {}
            Some(d) => {
                let msg = format!("rank {rank} issued {desc:?} while the group is running {d:?}");
                g.poisoned = Some(msg.clone());
                self.cv.notify_all();
                bail!("collective desync: {msg}");
            }
        }
        if g.bufs[rank].is_some() {
            let msg = format!("rank {rank} participated twice in {desc:?}");
            g.poisoned = Some(msg.clone());
            self.cv.notify_all();
            bail!("collective desync: {msg}");
        }
        g.bufs[rank] = Some(buf);
        g.scalars[rank] = Some(scalars);
        g.arrived += 1;
        if g.arrived == self.world {
            // last arrival runs the schedule over rank-ordered contributions
            let bufs: Vec<Vec<f32>> =
                g.bufs.iter_mut().map(|b| b.take().unwrap_or_default()).collect();
            let scs: Vec<Vec<f64>> =
                g.scalars.iter_mut().map(|s| s.take().unwrap_or_default()).collect();
            // lint: allow(PL007): compute_op is pure array math (it
            // dispatches to crate::dp::reduce_*); the lint's name-merged
            // call graph conflates it with the endpoint trait impls.
            // Running it under the lock is the rendezvous design: the
            // last arrival computes once while everyone else waits.
            match compute_op(self.alg, &desc, bufs, scs) {
                Ok(out) => {
                    g.result = Some(Arc::new(out));
                    g.consumed = 0;
                }
                Err(e) => {
                    g.poisoned = Some(format!("{desc:?} failed: {e:#}"));
                }
            }
            self.cv.notify_all();
        } else {
            while g.result.is_none() && g.poisoned.is_none() {
                g = self.cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        }
        if let Some(p) = &g.poisoned {
            bail!("local collective group poisoned: {p}");
        }
        let Some(out) = g.result.clone() else {
            bail!("rendezvous produced no result (prelora bug)");
        };
        g.consumed += 1;
        if g.consumed == self.world {
            // last consumer resets the slot for the next op
            g.result = None;
            g.desc = None;
            g.arrived = 0;
            self.cv.notify_all();
        }
        Ok(out)
    }
}

#[allow(deprecated)] // the one-release shim: matrix callers keep working
impl Collective for LocalGroup {
    fn name(&self) -> &'static str {
        self.alg.as_str()
    }

    fn all_reduce(&self, bufs: Vec<Vec<f32>>) -> Option<Vec<f32>> {
        crate::dp::reduce_owned(self.alg, bufs)
    }

    fn reduce_scatter(&self, bufs: Vec<Vec<f32>>, parts: usize) -> Option<Vec<Vec<f32>>> {
        crate::dp::reduce_scatter(self.alg, bufs, parts)
    }

    fn reduce_bucket(&self, bufs: Vec<Vec<f32>>, lo: usize, full_len: usize) -> Option<Vec<f32>> {
        crate::dp::reduce_bucket(self.alg, bufs, lo, full_len)
    }
}

/// One rank of a [`LocalGroup`].
pub struct LocalEndpoint {
    rank: usize,
    group: Arc<LocalGroup>,
}

impl CollectiveEndpoint for LocalEndpoint {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world_size(&self) -> usize {
        self.group.world
    }

    fn transport(&self) -> &'static str {
        "local"
    }

    fn all_reduce(&self, buf: &mut Vec<f32>) -> Result<()> {
        let desc = OpDesc::AllReduce { len: buf.len() };
        let out = self.group.run_op(self.rank, desc, std::mem::take(buf), Vec::new())?;
        match &*out {
            OpOut::Full(v) => {
                *buf = v.clone();
                Ok(())
            }
            other => bail!("all_reduce returned {other:?} (prelora bug)"),
        }
    }

    fn reduce_scatter(&self, buf: Vec<f32>, parts: usize) -> Result<Vec<Vec<f32>>> {
        let desc = OpDesc::ReduceScatter { len: buf.len(), parts };
        let out = self.group.run_op(self.rank, desc, buf, Vec::new())?;
        match &*out {
            OpOut::Chunks(c) => Ok(c.clone()),
            other => bail!("reduce_scatter returned {other:?} (prelora bug)"),
        }
    }

    fn reduce_bucket(&self, buf: Vec<f32>, lo: usize, full_len: usize) -> Result<Vec<f32>> {
        let desc = OpDesc::ReduceBucket { len: buf.len(), lo, full_len };
        let out = self.group.run_op(self.rank, desc, buf, Vec::new())?;
        match &*out {
            OpOut::Full(v) => Ok(v.clone()),
            other => bail!("reduce_bucket returned {other:?} (prelora bug)"),
        }
    }

    fn all_gather(&self, own: Vec<f32>) -> Result<Vec<Vec<f32>>> {
        let out = self.group.run_op(self.rank, OpDesc::AllGather, own, Vec::new())?;
        match &*out {
            OpOut::Chunks(c) => Ok(c.clone()),
            other => bail!("all_gather returned {other:?} (prelora bug)"),
        }
    }

    fn broadcast(&self, buf: &mut Vec<f32>, root: usize) -> Result<()> {
        let desc = OpDesc::Broadcast { len: buf.len(), root };
        let out = self.group.run_op(self.rank, desc, std::mem::take(buf), Vec::new())?;
        match &*out {
            OpOut::Full(v) => {
                *buf = v.clone();
                Ok(())
            }
            other => bail!("broadcast returned {other:?} (prelora bug)"),
        }
    }

    fn gather_scalars(&self, vals: &[f64]) -> Result<Vec<Vec<f64>>> {
        let desc = OpDesc::Scalars { n: vals.len() };
        let out = self.group.run_op(self.rank, desc, Vec::new(), vals.to_vec())?;
        match &*out {
            OpOut::Scalars(s) => Ok(s.clone()),
            other => bail!("gather_scalars returned {other:?} (prelora bug)"),
        }
    }

    fn barrier(&self) -> Result<()> {
        let out = self.group.run_op(self.rank, OpDesc::Barrier, Vec::new(), Vec::new())?;
        match &*out {
            OpOut::Unit => Ok(()),
            other => bail!("barrier returned {other:?} (prelora bug)"),
        }
    }
}

/// Adapts a per-rank [`CollectiveEndpoint`] back onto the legacy
/// [`Collective`] trait so the strategy machinery runs unchanged when
/// this process is one rank of a multi-process group.
///
/// In that mode the buffer "matrix" has exactly one row — this rank's
/// local worker — and each matrix call becomes one wire op whose result
/// (the mean over the *whole* group, in the group's schedule order) comes
/// back bitwise identical to what the in-memory matrix call with every
/// rank's buffer would have produced.
///
/// The legacy signatures return `Option`, which cannot carry an error:
/// wire failures are recorded in a poison slot and surfaced through
/// [`Collective::take_error`] (the strategies' `try_*` wrappers check it
/// after every reduce, so a dead or stalled peer fails the epoch loudly).
pub struct EndpointCollective {
    ep: Arc<dyn CollectiveEndpoint>,
    err: Mutex<Option<anyhow::Error>>,
}

impl EndpointCollective {
    pub fn new(ep: Arc<dyn CollectiveEndpoint>) -> Self {
        Self { ep, err: Mutex::new(None) }
    }

    fn record(&self, e: anyhow::Error) {
        let mut slot = self.err.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        // keep the first error: it names the rank/op that actually failed
        if slot.is_none() {
            *slot = Some(e);
        }
    }

    fn one_local_row(&self, mut bufs: Vec<Vec<f32>>, what: &str) -> Option<Vec<f32>> {
        if bufs.is_empty() {
            // no local gradient for this space (e.g. no base grads after
            // the freeze) — every rank agrees, so no wire op is issued
            return None;
        }
        if bufs.len() != 1 {
            self.record(anyhow!(
                "endpoint-backed {what} expects exactly one local buffer (this process is a \
                 single rank), got {}",
                bufs.len()
            ));
            return None;
        }
        bufs.pop()
    }
}

#[allow(deprecated)] // the one-release shim: matrix calls adapt to the endpoint
impl Collective for EndpointCollective {
    fn name(&self) -> &'static str {
        self.ep.transport()
    }

    fn all_reduce(&self, bufs: Vec<Vec<f32>>) -> Option<Vec<f32>> {
        let mut buf = self.one_local_row(bufs, "all_reduce")?;
        match self.ep.all_reduce(&mut buf) {
            Ok(()) => Some(buf),
            Err(e) => {
                self.record(e);
                None
            }
        }
    }

    fn reduce_scatter(&self, bufs: Vec<Vec<f32>>, parts: usize) -> Option<Vec<Vec<f32>>> {
        let buf = self.one_local_row(bufs, "reduce_scatter")?;
        match self.ep.reduce_scatter(buf, parts) {
            Ok(chunks) => Some(chunks),
            Err(e) => {
                self.record(e);
                None
            }
        }
    }

    fn reduce_bucket(&self, bufs: Vec<Vec<f32>>, lo: usize, full_len: usize) -> Option<Vec<f32>> {
        let buf = self.one_local_row(bufs, "reduce_bucket")?;
        match self.ep.reduce_bucket(buf, lo, full_len) {
            Ok(v) => Some(v),
            Err(e) => {
                self.record(e);
                None
            }
        }
    }

    fn endpoint(&self) -> Option<Arc<dyn CollectiveEndpoint>> {
        Some(self.ep.clone())
    }

    fn take_error(&self) -> Option<anyhow::Error> {
        self.err.lock().unwrap_or_else(std::sync::PoisonError::into_inner).take()
    }
}

#[cfg(test)]
#[allow(deprecated)] // tests cover the shimmed matrix methods on purpose
mod tests {
    use super::*;
    use crate::dp::{reduce_owned, scatter};

    fn bufs(n: usize, len: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|w| (0..len).map(|i| ((w * 31 + i * 7) % 13) as f32 - 6.0).collect())
            .collect()
    }

    #[test]
    fn all_reduce_matches_dp_bitwise_per_algorithm() {
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            let c = AlgoCollective::new(alg);
            assert_eq!(c.name(), alg.as_str());
            assert_eq!(c.algorithm(), alg);
            let want = reduce_owned(alg, bufs(5, 101)).unwrap();
            assert_eq!(c.all_reduce(bufs(5, 101)).unwrap(), want, "{alg:?}");
            assert!(c.all_reduce(Vec::new()).is_none());
        }
    }

    #[test]
    fn reduce_scatter_concat_is_bitwise_all_reduce() {
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            let c = AlgoCollective::new(alg);
            let want = c.all_reduce(bufs(3, 103)).unwrap();
            for parts in [1usize, 2, 3, 5, 7] {
                let chunks = c.reduce_scatter(bufs(3, 103), parts).unwrap();
                assert_eq!(chunks.len(), parts);
                assert_eq!(c.all_gather(&chunks), want, "{alg:?} parts={parts}");
            }
        }
    }

    #[test]
    fn bucketed_reduce_concat_is_bitwise_all_reduce() {
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            let c = AlgoCollective::new(alg);
            let len = 101;
            let want = c.all_reduce(bufs(3, len)).unwrap();
            let plan = crate::dp::BucketPlan::derive(len, 1, 52);
            let src = bufs(3, len);
            let mut got = Vec::with_capacity(len);
            for b in &plan.buckets {
                let slices: Vec<Vec<f32>> = src.iter().map(|w| w[b.lo..b.hi].to_vec()).collect();
                got.extend(c.reduce_bucket(slices, b.lo, len).unwrap());
            }
            assert_eq!(got, want, "{alg:?}");
        }
    }

    #[test]
    fn default_reduce_bucket_signals_unsupported() {
        struct Whole;
        #[allow(deprecated)]
        impl Collective for Whole {
            fn name(&self) -> &'static str {
                "whole"
            }
            fn all_reduce(&self, bufs: Vec<Vec<f32>>) -> Option<Vec<f32>> {
                crate::dp::reduce_owned(Algorithm::Naive, bufs)
            }
            fn reduce_scatter(&self, bufs: Vec<Vec<f32>>, parts: usize) -> Option<Vec<Vec<f32>>> {
                crate::dp::reduce_scatter(Algorithm::Naive, bufs, parts)
            }
        }
        assert!(Whole.reduce_bucket(bufs(2, 8), 0, 8).is_none());
        // custom backends are not endpoint-backed and carry no error slot
        assert!(Whole.endpoint().is_none());
        assert!(Whole.take_error().is_none());
    }

    #[test]
    fn gather_inverts_scatter_and_broadcast_replicates() {
        let c = AlgoCollective::new(Algorithm::Ring);
        let full: Vec<f32> = (0..37).map(|i| i as f32 * 0.5 - 9.0).collect();
        assert_eq!(c.all_gather(&scatter(&full, 5)), full);
        let reps = c.broadcast(&full, 3);
        assert_eq!(reps.len(), 3);
        assert!(reps.iter().all(|r| r == &full));
    }

    #[test]
    fn ordered_scalar_reduce_is_bitwise_the_full_fold() {
        let c = AlgoCollective::new(Algorithm::Tree);
        let full: Vec<f32> = (0..103).map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.37).collect();
        for parts in [1usize, 3, 5, 103] {
            assert_eq!(
                c.sq_sum_in_order(&scatter(&full, parts)),
                crate::tensor::sq_norm(&full),
                "parts={parts}"
            );
        }
    }

    /// Drive one op on every endpoint of a group concurrently, returning
    /// the per-rank results rank-ordered.
    fn on_all_ranks<T: Send + 'static>(
        group: &Arc<LocalGroup>,
        f: impl Fn(Arc<LocalEndpoint>) -> T + Send + Sync + Copy,
    ) -> Vec<T> {
        std::thread::scope(|s| {
            let handles: Vec<_> = group
                .endpoints()
                .into_iter()
                .map(|ep| s.spawn(move || f(ep)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn local_endpoints_match_the_matrix_path_bitwise() {
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            let world = 3;
            let src = bufs(world, 101);
            let matrix = AlgoCollective::new(alg);
            let want_full = matrix.all_reduce(src.clone()).unwrap();
            let want_chunks = matrix.reduce_scatter(src.clone(), world).unwrap();

            let group = LocalGroup::new(alg, world);
            let src_ref = &src;
            let got = on_all_ranks(&group, move |ep| {
                let mut b = src_ref[ep.rank()].clone();
                ep.all_reduce(&mut b).unwrap();
                b
            });
            for (r, g) in got.iter().enumerate() {
                assert_eq!(g, &want_full, "{alg:?} rank {r}: endpoint all_reduce diverged");
            }

            let got = on_all_ranks(&group, move |ep| {
                ep.reduce_scatter(src_ref[ep.rank()].clone(), 3).unwrap()
            });
            for (r, g) in got.iter().enumerate() {
                assert_eq!(g, &want_chunks, "{alg:?} rank {r}: endpoint reduce_scatter diverged");
            }
        }
    }

    #[test]
    fn local_endpoints_bucket_gather_broadcast_scalars_and_barrier() {
        let world = 3;
        let len = 53;
        let group = LocalGroup::new(Algorithm::Ring, world);
        let src = bufs(world, len);
        let matrix = AlgoCollective::new(Algorithm::Ring);
        let want = matrix.reduce_bucket(src.clone(), 7, 101).unwrap();
        let src_ref = &src;
        let got = on_all_ranks(&group, move |ep| {
            ep.reduce_bucket(src_ref[ep.rank()].clone(), 7, 101).unwrap()
        });
        assert!(got.iter().all(|g| g == &want), "bucket reduce diverged across ranks");

        // all_gather returns every rank's (ragged) buffer rank-ordered
        let got = on_all_ranks(&group, |ep| {
            let own = vec![ep.rank() as f32; ep.rank() + 1];
            ep.all_gather(own).unwrap()
        });
        for g in &got {
            assert_eq!(g.len(), world);
            for (r, chunk) in g.iter().enumerate() {
                assert_eq!(chunk, &vec![r as f32; r + 1]);
            }
        }

        // broadcast replicates the root's bytes verbatim
        let got = on_all_ranks(&group, |ep| {
            let mut b = vec![ep.rank() as f32 + 0.25; 9];
            ep.broadcast(&mut b, 1).unwrap();
            b
        });
        assert!(got.iter().all(|g| g == &vec![1.25f32; 9]));

        // scalars come back rank-ordered and bit-exact
        let got = on_all_ranks(&group, |ep| {
            ep.gather_scalars(&[ep.rank() as f64 * 0.1, -1.0]).unwrap()
        });
        for g in &got {
            for (r, s) in g.iter().enumerate() {
                assert_eq!(s[0].to_bits(), (r as f64 * 0.1).to_bits());
                assert_eq!(s[1], -1.0);
            }
        }

        let got = on_all_ranks(&group, |ep| ep.barrier().is_ok());
        assert!(got.iter().all(|ok| *ok));
    }

    #[test]
    fn mismatched_ops_poison_the_group_loudly() {
        let group = LocalGroup::new(Algorithm::Tree, 2);
        let errs = std::thread::scope(|s| {
            let g0 = group.endpoint(0);
            let g1 = group.endpoint(1);
            let a = s.spawn(move || {
                let mut b = vec![1.0f32; 8];
                g0.all_reduce(&mut b).err()
            });
            let b = s.spawn(move || g1.reduce_scatter(vec![1.0f32; 8], 2).err());
            (a.join().unwrap(), b.join().unwrap())
        });
        // exactly one of the two sees the desync first; the other sees the
        // poisoned group — both fail loudly, neither hangs
        let msgs = [errs.0, errs.1];
        assert!(msgs.iter().flatten().count() >= 1, "at least one rank must error");
        for e in msgs.iter().flatten() {
            let s = format!("{e:#}");
            assert!(
                s.contains("desync") || s.contains("poisoned"),
                "error must name the lockstep violation: {s}"
            );
        }
        // the group stays poisoned for every later op
        let ep = group.endpoint(0);
        let e = ep.barrier().unwrap_err();
        assert!(format!("{e:#}").contains("poisoned"), "{e:#}");
    }

    #[test]
    fn endpoint_collective_adapts_the_matrix_api_per_rank() {
        // a world-1 group: the adapter's one local row is the whole matrix
        let group = LocalGroup::new(Algorithm::Tree, 1);
        let c = EndpointCollective::new(group.endpoint(0));
        assert_eq!(c.name(), "local");
        assert!(c.endpoint().is_some());
        let b = bufs(1, 19);
        assert_eq!(c.all_reduce(b.clone()).unwrap(), b[0], "mean of one buffer is itself");
        let chunks = c.reduce_scatter(b.clone(), 3).unwrap();
        assert_eq!(c.all_gather(&chunks), b[0]);
        assert_eq!(c.reduce_bucket(vec![b[0][2..7].to_vec()], 2, 19).unwrap(), &b[0][2..7]);
        // empty buffer set: no local gradient, no wire op, no error
        assert!(c.all_reduce(Vec::new()).is_none());
        assert!(c.take_error().is_none());
        // more than one local row is a prelora bug, recorded loudly
        assert!(c.all_reduce(bufs(2, 4)).is_none());
        let e = c.take_error().unwrap();
        assert!(format!("{e:#}").contains("exactly one local buffer"), "{e:#}");
        assert!(c.take_error().is_none(), "take_error drains the slot");
    }
}
