//! TCP collective backend: ranks as separate OS processes.
//!
//! ## Topology: root replay
//!
//! The group is a star. Rank 0 (the **root**) binds `peers[0]` and
//! accepts one connection per remaining rank; every other rank (a
//! **leaf**) connects to `peers[0]`. For each collective op, leaves send
//! their contribution ([`Frame`] of kind [`FrameKind::Op`]), the root
//! assembles the rank-ordered buffer matrix — its own contribution first,
//! then ranks 1..N in order — and executes the *same* naive/tree/ring
//! summation schedule as the in-memory backend through
//! [`compute_op`], then fans the result back out
//! ([`FrameKind::Result`], which doubles as the ack). Because the
//! schedule runs once, in one place, over rank-ordered inputs that
//! traveled as raw little-endian bit patterns, the result is **bitwise
//! identical** to [`super::AlgoCollective`] by construction — there is no
//! second summation order to audit, which is the whole point.
//!
//! ## Threads and timeouts
//!
//! Each connection owns two worker threads: `net-tx-r{peer}` drains an
//! `mpsc` channel of outbound frames, `net-rx-r{peer}` blocks on the
//! socket and pushes decoded frames (or the first decode/IO error) into
//! an inbound channel. The rx thread deliberately reads **without** a
//! socket timeout — a rank legitimately goes quiet for however long its
//! compute step takes — so stall detection lives where the expectation
//! is: `recv_timeout` on the inbound channel *while an op is waiting*.
//! A peer that dies mid-op surfaces as the rx thread's IO error with the
//! peer's rank attached; one that merely stalls past the timeout
//! surfaces as a "rank N stalled" error. The first failure poisons the
//! endpoint so every later op fails fast with the original context
//! instead of hanging on a half-dead group.
//!
//! ## Lockstep enforcement
//!
//! Every frame carries a per-connection monotonic `seq` and every op
//! contribution carries its full [`OpDesc`]. The root checks both
//! against its own current op; a mismatch means the ranks' training
//! loops have diverged (different config, different step count — a bug),
//! and the result would be garbage, so it fails loudly as a "collective
//! desync" rather than pairing the wrong buffers.
//!
//! ## Shutdown
//!
//! Dropping a [`TcpEndpoint`] sets the shutdown flag, shuts the sockets
//! down (unblocking any rx thread mid-read), closes the outbound
//! channels (ending the tx loops), and joins all four directions of
//! worker thread — no leaked `net-*` threads, which
//! `rust/tests/shutdown.rs` asserts.

use std::io::Write as _;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::dp::Algorithm;
use crate::faults::{FaultInjector, NetFault};

use super::collective::{compute_op, CollectiveEndpoint, OpDesc, OpOut};

mod frame;

pub use frame::{Frame, FrameKind, FRAME_VERSION, MAX_FRAME_BYTES};

fn world_payload(world: usize) -> Vec<u8> {
    (world as u32).to_le_bytes().to_vec()
}

fn decode_world(payload: &[u8]) -> Result<usize> {
    ensure!(
        payload.len() == 4,
        "hello payload is {} bytes, expected 4 — cannot learn the peer rank",
        payload.len()
    );
    Ok(u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]) as usize)
}

fn lock_inner(inner: &Mutex<Inner>) -> std::sync::MutexGuard<'_, Inner> {
    inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// One live connection: the socket, its two worker threads, and the
/// channels that feed them.
struct PeerLink {
    /// The rank on the other end of this connection.
    peer: usize,
    /// Outbound frames; `None` once closed (dropping the sender is what
    /// ends the tx worker's loop).
    tx: Option<mpsc::Sender<Frame>>,
    /// Inbound frames, or the first read/decode error.
    rx: mpsc::Receiver<Result<Frame>>,
    stream: TcpStream,
    tx_join: Option<thread::JoinHandle<()>>,
    rx_join: Option<thread::JoinHandle<()>>,
    /// Armed by the `net-corrupt` fault: the tx worker flips one bit of
    /// the next outbound frame's CRC trailer, then disarms. Always false
    /// outside adversity testing.
    corrupt_next: Arc<AtomicBool>,
}

impl PeerLink {
    fn spawn(stream: TcpStream, peer: usize, shutdown: Arc<AtomicBool>) -> Result<Self> {
        // Collective frames are latency-bound request/response pairs;
        // Nagle buys nothing here.
        let _ = stream.set_nodelay(true);
        let mut wr = stream
            .try_clone()
            .with_context(|| format!("cloning the stream for the send worker to rank {peer}"))?;
        let mut rd = stream
            .try_clone()
            .with_context(|| format!("cloning the stream for the recv worker to rank {peer}"))?;

        // lint: allow(PL008): the op protocol is stop-and-wait — at most
        // one request and one response frame are in flight per link, so
        // this queue is bounded by the protocol itself.
        let (tx, outbound) = mpsc::channel::<Frame>();
        let corrupt_next = Arc::new(AtomicBool::new(false));
        let corrupt = corrupt_next.clone();
        // lint: thread: joined — PeerLink::close drops the sender (ending
        // this loop) and joins the handle; TcpEndpoint::drop calls close.
        let tx_join = thread::Builder::new()
            .name(format!("net-tx-r{peer}"))
            .spawn(move || {
                while let Ok(f) = outbound.recv() {
                    let mut bytes = f.encode();
                    if corrupt.swap(false, Ordering::SeqCst) {
                        // net-corrupt fault: flip one bit of the CRC
                        // trailer (every frame ends in it), so the peer's
                        // Frame::read_from rejects the frame exactly like
                        // real wire corruption
                        let n = bytes.len();
                        bytes[n - 1] ^= 0x01;
                    }
                    if wr.write_all(&bytes).is_err() {
                        // The rx side surfaces the dead connection with
                        // context; nothing useful to add from here.
                        break;
                    }
                }
            })
            .with_context(|| format!("spawning the send worker for rank {peer}"))?;

        // lint: allow(PL008): inbound mirror of the stop-and-wait link —
        // the peer sends at most one frame per outstanding op, so depth
        // is protocol-bounded.
        let (inbound_tx, rx) = mpsc::channel::<Result<Frame>>();
        let sd = shutdown.clone();
        // lint: thread: joined — PeerLink::close shuts the socket down
        // (unblocking the read) and joins the handle; TcpEndpoint::drop
        // calls close.
        let rx_join = thread::Builder::new()
            .name(format!("net-rx-r{peer}"))
            .spawn(move || loop {
                match Frame::read_from(&mut rd) {
                    Ok(f) => {
                        if inbound_tx.send(Ok(f)).is_err() {
                            break;
                        }
                    }
                    Err(e) => {
                        // A read error during our own shutdown is the
                        // expected way this loop ends; stay quiet then.
                        if !sd.load(Ordering::SeqCst) {
                            let _ = inbound_tx.send(Err(e));
                        }
                        break;
                    }
                }
            })
            .with_context(|| format!("spawning the recv worker for rank {peer}"))?;

        Ok(Self {
            peer,
            tx: Some(tx),
            rx,
            stream,
            tx_join: Some(tx_join),
            rx_join: Some(rx_join),
            corrupt_next,
        })
    }

    /// Arm the `net-corrupt` fault: the next outbound frame on this link
    /// goes out with a flipped CRC bit.
    fn arm_corrupt(&self) {
        self.corrupt_next.store(true, Ordering::SeqCst);
    }

    fn send(&self, f: Frame) -> Result<()> {
        match &self.tx {
            Some(tx) if tx.send(f).is_ok() => Ok(()),
            _ => bail!("connection to rank {} is closed (send worker gone)", self.peer),
        }
    }

    /// Wait up to `timeout` for the next inbound frame. Only called while
    /// an op is outstanding, so silence past the timeout *is* a stall.
    fn recv(&self, timeout: Duration, seq: u64, what: &str) -> Result<Frame> {
        match self.rx.recv_timeout(timeout) {
            Ok(Ok(f)) => Ok(f),
            Ok(Err(e)) => Err(e.context(format!(
                "receiving {what} from rank {} (op seq {seq})",
                self.peer
            ))),
            Err(mpsc::RecvTimeoutError::Timeout) => bail!(
                "rank {} stalled: no frame within {timeout:?} while waiting for {what} \
                 (op seq {seq})",
                self.peer
            ),
            Err(mpsc::RecvTimeoutError::Disconnected) => bail!(
                "connection to rank {} closed while waiting for {what} (op seq {seq})",
                self.peer
            ),
        }
    }

    /// Graceful teardown: unblock and join both workers. Idempotent.
    fn close(&mut self) {
        let _ = self.stream.shutdown(Shutdown::Both);
        self.tx = None;
        if let Some(j) = self.tx_join.take() {
            let _ = j.join();
        }
        if let Some(j) = self.rx_join.take() {
            let _ = j.join();
        }
    }
}

/// Who this rank talks to.
enum Links {
    /// Rank 0: one link per leaf, held in rank order (empty for a
    /// single-rank world, where every op computes locally).
    Root(Vec<PeerLink>),
    /// A leaf: its one link to the root.
    Leaf(PeerLink),
}

struct Inner {
    /// Next op index; stamped on every frame of that op.
    seq: u64,
    /// First failure, verbatim: later ops fail fast with this context.
    failed: Option<String>,
    links: Links,
}

/// A rank's [`CollectiveEndpoint`] over TCP. See the module docs for the
/// topology and the bitwise-parity argument.
pub struct TcpEndpoint {
    alg: Algorithm,
    rank: usize,
    world: usize,
    /// Both the connect deadline and the per-op stall budget.
    timeout: Duration,
    shutdown: Arc<AtomicBool>,
    inner: Mutex<Inner>,
    /// Deterministic fault injection (`train.faults.plan`): consulted
    /// once per op against the pipeline-driven (epoch, step) clock.
    /// `None` outside adversity testing.
    faults: Option<Arc<FaultInjector>>,
}

impl TcpEndpoint {
    /// Join the group: rank 0 binds `peers[0]` and accepts `world - 1`
    /// handshakes; other ranks connect to `peers[0]` with retry until
    /// `timeout`. Returns only once every rank has checked in (the
    /// handshake doubles as the startup barrier), so a missing or
    /// misconfigured rank fails loudly here, not mid-epoch.
    pub fn connect(
        alg: Algorithm,
        rank: usize,
        peers: &[String],
        timeout: Duration,
    ) -> Result<Arc<Self>> {
        Self::connect_with_faults(alg, rank, peers, timeout, None)
    }

    /// [`connect`](Self::connect) plus a fault injector (adversity
    /// testing): the endpoint consults the injector's (epoch, step)
    /// clock once per collective op and applies any `net-*` fault
    /// scheduled for this rank at that coordinate.
    pub fn connect_with_faults(
        alg: Algorithm,
        rank: usize,
        peers: &[String],
        timeout: Duration,
        faults: Option<Arc<FaultInjector>>,
    ) -> Result<Arc<Self>> {
        let world = peers.len();
        ensure!(world >= 1, "tcp transport needs at least one peer address");
        ensure!(rank < world, "rank {rank} is outside the {world}-entry peers list");
        let shutdown = Arc::new(AtomicBool::new(false));
        let links = if world == 1 {
            Links::Root(Vec::new())
        } else if rank == 0 {
            Links::Root(accept_peers(&peers[0], world, timeout, &shutdown)?)
        } else {
            Links::Leaf(join_root(&peers[0], rank, world, timeout, &shutdown)?)
        };
        Ok(Arc::new(Self {
            alg,
            rank,
            world,
            timeout,
            shutdown,
            inner: Mutex::new(Inner { seq: 1, failed: None, links }),
            faults,
        }))
    }

    pub fn algorithm(&self) -> Algorithm {
        self.alg
    }

    /// Run one collective op at this rank: stamp the next `seq`, drive
    /// the wire protocol, and poison the endpoint on the first failure.
    fn run_op(&self, desc: OpDesc, data: Vec<f32>, scalars: Vec<f64>) -> Result<OpOut> {
        let mut g = lock_inner(&self.inner);
        if let Some(f) = &g.failed {
            bail!("collective endpoint already failed at rank {}: {f}", self.rank);
        }
        // adversity testing: one injection point guards every wire op —
        // the first-class generalization of the ad-hoc per-test fakes
        // (silent sockets, hand-corrupted frames) this replaces. A plain
        // `None` check outside adversity runs.
        if let Some(fault) = self.faults.as_ref().and_then(|i| i.net_fault(self.rank)) {
            // lint: allow(PL007): fault injection sleeps/stalls on purpose
            // while the op lock is held — the stall must block the op.
            self.apply_net_fault(fault, &mut g)?;
        }
        let seq = g.seq;
        g.seq += 1;
        // lint: allow(PL007): the endpoint lock *is* the op serializer —
        // one collective at a time per endpoint is the wire protocol's
        // correctness condition, so drive() blocking under it is by design.
        let out = drive(self.alg, self.rank, self.timeout, &g.links, seq, desc, data, scalars);
        if let Err(e) = &out {
            g.failed = Some(format!("{e:#}"));
        }
        out.with_context(|| format!("collective op {desc:?} (seq {seq}) at rank {}", self.rank))
    }

    /// Apply one scheduled wire fault. Called with the endpoint lock held,
    /// before the op's seq is stamped.
    ///
    /// * `net-delay` sleeps and proceeds — pure scheduling, so the run's
    ///   trajectory must not change by a bit (the adversity suite asserts
    ///   exactly that).
    /// * `net-stall` holds the socket open past the peers' stall budget
    ///   without contributing, then abandons the op: the peers' watchdog
    ///   (`recv_timeout`) fires their "rank N stalled" error while this
    ///   rank fails with its own injection notice.
    /// * `net-drop` closes the connections outright: peers observe the
    ///   dead socket as an IO error naming this rank.
    /// * `net-corrupt` arms a one-shot CRC-bit flip on the next outbound
    ///   frame of every link: receivers reject it as wire corruption.
    fn apply_net_fault(&self, fault: NetFault, g: &mut Inner) -> Result<()> {
        let (epoch, step) = match &self.faults {
            Some(i) => i.position(),
            None => (0, 0),
        };
        match fault {
            NetFault::Delay { ms } => {
                thread::sleep(Duration::from_millis(ms));
                Ok(())
            }
            NetFault::Stall { ms } => {
                thread::sleep(Duration::from_millis(ms));
                let msg = format!(
                    "fault injected: rank {} stalled {ms} ms and abandoned the collective op \
                     (epoch {epoch}, step {step})",
                    self.rank
                );
                g.failed = Some(msg.clone());
                // lint: allow(PL009): msg interpolates rank/epoch/step —
                // built three lines up so it can also poison the endpoint.
                bail!(msg);
            }
            NetFault::Drop => {
                // quiet-on-shutdown for our own rx workers; the peers'
                // (whose flag is untouched) surface the dead socket loudly
                self.shutdown.store(true, Ordering::SeqCst);
                match &mut g.links {
                    Links::Root(peers) => {
                        for p in peers.iter_mut() {
                            p.close();
                        }
                    }
                    Links::Leaf(p) => p.close(),
                }
                let msg = format!(
                    "fault injected: rank {} dropped its connections (epoch {epoch}, \
                     step {step})",
                    self.rank
                );
                g.failed = Some(msg.clone());
                // lint: allow(PL009): msg interpolates rank/epoch/step —
                // built above so it can also poison the endpoint.
                bail!(msg);
            }
            NetFault::Corrupt => {
                match &g.links {
                    Links::Root(peers) => {
                        for p in peers {
                            p.arm_corrupt();
                        }
                    }
                    Links::Leaf(p) => p.arm_corrupt(),
                }
                Ok(())
            }
        }
    }
}

/// The wire protocol for one op. Root: collect rank-ordered
/// contributions, replay the schedule, fan out results. Leaf: send, wait.
#[allow(clippy::too_many_arguments)]
fn drive(
    alg: Algorithm,
    rank: usize,
    timeout: Duration,
    links: &Links,
    seq: u64,
    desc: OpDesc,
    data: Vec<f32>,
    scalars: Vec<f64>,
) -> Result<OpOut> {
    match links {
        Links::Root(peers) => {
            let world = peers.len() + 1;
            let mut bufs = Vec::with_capacity(world);
            let mut scs = Vec::with_capacity(world);
            bufs.push(data);
            scs.push(scalars);
            for link in peers.iter() {
                let f = link.recv(timeout, seq, "an op contribution")?;
                ensure!(
                    f.kind == FrameKind::Op,
                    "expected an op frame from rank {}, got {:?}",
                    link.peer,
                    f.kind
                );
                ensure!(
                    f.rank as usize == link.peer,
                    "frame claims rank {} on rank {}'s connection",
                    f.rank,
                    link.peer
                );
                ensure!(
                    f.seq == seq,
                    "collective desync: rank {} is at op seq {} but the group is at {seq}",
                    link.peer,
                    f.seq
                );
                let (their_desc, their_data, their_scalars) = frame::decode_op(&f.payload)?;
                ensure!(
                    their_desc == desc,
                    "collective desync: rank {} issued {their_desc:?} while the group runs \
                     {desc:?}",
                    link.peer
                );
                bufs.push(their_data);
                scs.push(their_scalars);
            }
            let out = compute_op(alg, &desc, bufs, scs)?;
            let payload = frame::encode_out(&out);
            for link in peers.iter() {
                link.send(Frame {
                    kind: FrameKind::Result,
                    rank: 0,
                    seq,
                    payload: payload.clone(),
                })?;
            }
            Ok(out)
        }
        Links::Leaf(link) => {
            link.send(Frame {
                kind: FrameKind::Op,
                rank: rank as u32,
                seq,
                payload: frame::encode_op(&desc, &data, &scalars),
            })?;
            let f = link.recv(timeout, seq, "the op result")?;
            ensure!(
                f.kind == FrameKind::Result,
                "expected a result frame for op seq {seq}, got {:?}",
                f.kind
            );
            ensure!(
                f.seq == seq,
                "collective desync: result for op seq {} arrived while waiting for {seq}",
                f.seq
            );
            frame::decode_out(&f.payload)
        }
    }
}

fn resolve(addr: &str) -> Result<SocketAddr> {
    addr.to_socket_addrs()
        .with_context(|| format!("resolving peer address {addr:?}"))?
        .next()
        .ok_or_else(|| anyhow!("peer address {addr:?} resolved to nothing"))
}

/// Rank 0's side of startup: bind, accept `world - 1` connections,
/// handshake each, then release everyone in one go.
fn accept_peers(
    addr: &str,
    world: usize,
    timeout: Duration,
    shutdown: &Arc<AtomicBool>,
) -> Result<Vec<PeerLink>> {
    let listener = TcpListener::bind(addr).with_context(|| format!("rank 0: binding {addr}"))?;
    advertise_addr(&listener)?;
    listener.set_nonblocking(true).context("rank 0: making the listener pollable")?;
    // lint: allow(PL003): connection deadline bookkeeping — wall time
    // gates accept retry/abort and never flows into reduced values.
    let deadline = Instant::now() + timeout;
    let mut slots: Vec<Option<PeerLink>> = (1..world).map(|_| None).collect();
    let mut missing = world - 1;
    while missing > 0 {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false).context("rank 0: unsetting accept nonblock")?;
                let link = handshake_accept(stream, world, deadline, shutdown)?;
                let r = link.peer;
                ensure!((1..world).contains(&r), "hello from out-of-range rank {r} (world {world})");
                ensure!(slots[r - 1].is_none(), "two connections both claim rank {r}");
                slots[r - 1] = Some(link);
                missing -= 1;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // lint: allow(PL003): connection deadline bookkeeping —
                // wall time gates accept retry/abort, never reduced values.
                if Instant::now() >= deadline {
                    let waiting: Vec<String> = slots
                        .iter()
                        .enumerate()
                        .filter(|(_, s)| s.is_none())
                        .map(|(i, _)| (i + 1).to_string())
                        .collect();
                    bail!(
                        "rank 0: timed out after {timeout:?} waiting for rank(s) {} to connect",
                        waiting.join(", ")
                    );
                }
                thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                return Err(anyhow::Error::from(e).context("rank 0: accepting peer connections"))
            }
        }
    }
    let links: Vec<PeerLink> = slots.into_iter().flatten().collect();
    // Every rank is in: the welcome is the startup barrier's release.
    for link in &links {
        link.send(Frame { kind: FrameKind::Hello, rank: 0, seq: 0, payload: world_payload(world) })?;
    }
    Ok(links)
}

/// Port-0 rendezvous: when `PRELORA_TCP_ADVERTISE` names a file, rank 0
/// publishes the address it actually bound there (write-to-temp + atomic
/// rename, so a polling reader never sees a partial write). This lets a
/// launcher pass `peers[0] = "127.0.0.1:0"`, have the kernel pick a free
/// port, and hand the discovered address to the leaf ranks — instead of
/// racing to re-bind a probed-then-released fixed port.
fn advertise_addr(listener: &TcpListener) -> Result<()> {
    let Ok(path) = std::env::var("PRELORA_TCP_ADVERTISE") else {
        return Ok(());
    };
    if path.is_empty() {
        return Ok(());
    }
    let addr = listener.local_addr().context("rank 0: reading the bound address")?;
    let tmp = format!("{path}.{}.tmp", std::process::id());
    std::fs::write(&tmp, addr.to_string())
        .with_context(|| format!("rank 0: writing the advertised address to {tmp}"))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("rank 0: publishing the advertised address at {path}"))?;
    Ok(())
}

/// Read one accepted connection's hello and spin up its workers.
fn handshake_accept(
    mut stream: TcpStream,
    world: usize,
    deadline: Instant,
    shutdown: &Arc<AtomicBool>,
) -> Result<PeerLink> {
    // lint: allow(PL003): connection deadline bookkeeping — wall time
    // bounds the handshake read and never flows into reduced values.
    let remaining =
        deadline.saturating_duration_since(Instant::now()).max(Duration::from_millis(1));
    stream.set_read_timeout(Some(remaining)).context("rank 0: arming the handshake timeout")?;
    let hello = Frame::read_from(&mut stream).context("rank 0: reading a peer's hello")?;
    ensure!(hello.kind == FrameKind::Hello, "expected a peer's hello frame, got {:?}", hello.kind);
    let their_world = decode_world(&hello.payload)?;
    ensure!(
        their_world == world,
        "rank {} was launched with world size {their_world} but this group has {world} ranks \
         (mismatched --peers lists?)",
        hello.rank
    );
    stream.set_read_timeout(None).context("rank 0: disarming the handshake timeout")?;
    PeerLink::spawn(stream, hello.rank as usize, shutdown.clone())
}

/// A leaf's side of startup: connect with retry (the root may not have
/// bound yet), send hello, wait for the root's welcome.
fn join_root(
    addr: &str,
    rank: usize,
    world: usize,
    timeout: Duration,
    shutdown: &Arc<AtomicBool>,
) -> Result<PeerLink> {
    let sock = resolve(addr)?;
    // lint: allow(PL003): connection deadline bookkeeping — wall time
    // gates connect retry/abort and never flows into reduced values.
    let deadline = Instant::now() + timeout;
    let attempt = Duration::from_millis(250).min(timeout.max(Duration::from_millis(1)));
    let mut stream = loop {
        match TcpStream::connect_timeout(&sock, attempt) {
            Ok(s) => break s,
            Err(e) => {
                // lint: allow(PL003): connection deadline bookkeeping —
                // wall time gates connect retry/abort, never reduced values.
                if Instant::now() >= deadline {
                    return Err(anyhow::Error::from(e).context(format!(
                        "rank {rank}: root {addr} not reachable within {timeout:?}"
                    )));
                }
                thread::sleep(Duration::from_millis(10));
            }
        }
    };
    Frame { kind: FrameKind::Hello, rank: rank as u32, seq: 0, payload: world_payload(world) }
        .write_to(&mut stream)
        .with_context(|| format!("rank {rank}: sending hello to the root"))?;
    // lint: allow(PL003): connection deadline bookkeeping — wall time
    // bounds the welcome read and never flows into reduced values.
    let remaining =
        deadline.saturating_duration_since(Instant::now()).max(Duration::from_millis(1));
    stream.set_read_timeout(Some(remaining)).with_context(|| format!("rank {rank}: arming the welcome timeout"))?;
    let welcome = Frame::read_from(&mut stream)
        .with_context(|| format!("rank {rank}: waiting for the root's welcome (startup barrier)"))?;
    ensure!(
        welcome.kind == FrameKind::Hello && welcome.rank == 0,
        "rank {rank}: expected the root's welcome, got a {:?} frame from rank {}",
        welcome.kind,
        welcome.rank
    );
    let root_world = decode_world(&welcome.payload)?;
    ensure!(
        root_world == world,
        "rank {rank}: the root runs world size {root_world}, this rank was launched with {world}"
    );
    stream.set_read_timeout(None).with_context(|| format!("rank {rank}: disarming the welcome timeout"))?;
    PeerLink::spawn(stream, 0, shutdown.clone())
}

impl CollectiveEndpoint for TcpEndpoint {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world_size(&self) -> usize {
        self.world
    }

    fn transport(&self) -> &'static str {
        "tcp"
    }

    fn all_reduce(&self, buf: &mut Vec<f32>) -> Result<()> {
        let desc = OpDesc::AllReduce { len: buf.len() };
        match self.run_op(desc, std::mem::take(buf), Vec::new())? {
            OpOut::Full(v) => {
                *buf = v;
                Ok(())
            }
            other => bail!("all_reduce at rank {} returned {other:?} (prelora bug)", self.rank),
        }
    }

    fn reduce_scatter(&self, buf: Vec<f32>, parts: usize) -> Result<Vec<Vec<f32>>> {
        let desc = OpDesc::ReduceScatter { len: buf.len(), parts };
        match self.run_op(desc, buf, Vec::new())? {
            OpOut::Chunks(chunks) => Ok(chunks),
            other => {
                bail!("reduce_scatter at rank {} returned {other:?} (prelora bug)", self.rank)
            }
        }
    }

    fn reduce_bucket(&self, buf: Vec<f32>, lo: usize, full_len: usize) -> Result<Vec<f32>> {
        let desc = OpDesc::ReduceBucket { len: buf.len(), lo, full_len };
        match self.run_op(desc, buf, Vec::new())? {
            OpOut::Full(v) => Ok(v),
            other => {
                bail!("reduce_bucket at rank {} returned {other:?} (prelora bug)", self.rank)
            }
        }
    }

    fn all_gather(&self, own: Vec<f32>) -> Result<Vec<Vec<f32>>> {
        match self.run_op(OpDesc::AllGather, own, Vec::new())? {
            OpOut::Chunks(chunks) => Ok(chunks),
            other => bail!("all_gather at rank {} returned {other:?} (prelora bug)", self.rank),
        }
    }

    fn broadcast(&self, buf: &mut Vec<f32>, root: usize) -> Result<()> {
        let desc = OpDesc::Broadcast { len: buf.len(), root };
        match self.run_op(desc, std::mem::take(buf), Vec::new())? {
            OpOut::Full(v) => {
                *buf = v;
                Ok(())
            }
            other => bail!("broadcast at rank {} returned {other:?} (prelora bug)", self.rank),
        }
    }

    fn gather_scalars(&self, vals: &[f64]) -> Result<Vec<Vec<f64>>> {
        let desc = OpDesc::Scalars { n: vals.len() };
        match self.run_op(desc, Vec::new(), vals.to_vec())? {
            OpOut::Scalars(rows) => Ok(rows),
            other => {
                bail!("gather_scalars at rank {} returned {other:?} (prelora bug)", self.rank)
            }
        }
    }

    fn barrier(&self) -> Result<()> {
        match self.run_op(OpDesc::Barrier, Vec::new(), Vec::new())? {
            OpOut::Unit => Ok(()),
            other => bail!("barrier at rank {} returned {other:?} (prelora bug)", self.rank),
        }
    }
}

impl Drop for TcpEndpoint {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let mut g = lock_inner(&self.inner);
        match &mut g.links {
            Links::Root(peers) => {
                for p in peers.iter_mut() {
                    // lint: allow(PL007): teardown — close() joins the
                    // workers under the lock on purpose, so no op can
                    // race the links while they die.
                    p.close();
                }
            }
            // lint: allow(PL007): teardown — same join-under-lock story
            // as the root branch above.
            Links::Leaf(p) => p.close(),
        }
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use std::collections::VecDeque;

    use super::super::collective::{AlgoCollective, Collective};
    use super::*;
    use crate::mc::{explore, Model, Step, ViolationKind};

    /// Reserve a loopback address by binding port 0, then release it for
    /// the endpoint under test to bind for real.
    fn free_addr() -> String {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    }

    fn peer_list(world: usize) -> Vec<String> {
        (0..world).map(|_| free_addr()).collect()
    }

    fn connect_retry(addr: &str) -> TcpStream {
        loop {
            match TcpStream::connect(addr) {
                Ok(s) => return s,
                Err(_) => thread::sleep(Duration::from_millis(5)),
            }
        }
    }

    fn live_net_threads() -> Vec<String> {
        std::fs::read_dir("/proc/self/task")
            .map(|tasks| {
                tasks
                    .flatten()
                    .filter_map(|t| std::fs::read_to_string(t.path().join("comm")).ok())
                    .map(|s| s.trim().to_string())
                    .filter(|s| s.starts_with("net-"))
                    .collect()
            })
            .unwrap_or_default()
    }

    fn rank_data(rank: usize, n: usize) -> Vec<f32> {
        (0..n).map(|i| ((rank * 31 + i * 7) as f32).mul_add(0.01, -1.5)).collect()
    }

    #[test]
    fn loopback_endpoints_match_the_matrix_path_bitwise() {
        const N: usize = 23;
        let world = 3;
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            let peers = peer_list(world);
            let per_rank: Vec<_> = thread::scope(|s| {
                let handles: Vec<_> = (0..world)
                    .map(|r| {
                        let peers = peers.clone();
                        s.spawn(move || {
                            let ep = TcpEndpoint::connect(
                                alg,
                                r,
                                &peers,
                                Duration::from_secs(20),
                            )
                            .unwrap();
                            assert_eq!((ep.rank(), ep.world_size()), (r, world));
                            assert_eq!(ep.transport(), "tcp");
                            let mut ar = rank_data(r, N);
                            ep.all_reduce(&mut ar).unwrap();
                            let rs = ep.reduce_scatter(rank_data(r, N), world).unwrap();
                            let rb =
                                ep.reduce_bucket(rank_data(r, N)[3..9].to_vec(), 3, N).unwrap();
                            let ag = ep.all_gather(vec![r as f32 + 0.5; r + 1]).unwrap();
                            let mut bc =
                                if r == 1 { vec![9.25, -8.5] } else { vec![0.0, 0.0] };
                            ep.broadcast(&mut bc, 1).unwrap();
                            let sc = ep
                                .gather_scalars(&[r as f64 * 0.1, 1.0 / (r as f64 + 3.0)])
                                .unwrap();
                            ep.barrier().unwrap();
                            (ar, rs, rb, ag, bc, sc)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });

            let all: Vec<Vec<f32>> = (0..world).map(|r| rank_data(r, N)).collect();
            let c = AlgoCollective::new(alg);
            let want_ar = c.all_reduce(all.clone()).unwrap();
            let want_rs = c.reduce_scatter(all.clone(), world).unwrap();
            let want_rb = c
                .reduce_bucket(all.iter().map(|b| b[3..9].to_vec()).collect(), 3, N)
                .unwrap();
            for (r, (ar, rs, rb, ag, bc, sc)) in per_rank.iter().enumerate() {
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(ar), bits(&want_ar), "{alg:?} all_reduce at rank {r}");
                assert_eq!(rs.len(), want_rs.len());
                for (got, want) in rs.iter().zip(want_rs.iter()) {
                    assert_eq!(bits(got), bits(want), "{alg:?} reduce_scatter at rank {r}");
                }
                assert_eq!(bits(rb), bits(&want_rb), "{alg:?} reduce_bucket at rank {r}");
                let want_ag: Vec<Vec<f32>> =
                    (0..world).map(|q| vec![q as f32 + 0.5; q + 1]).collect();
                assert_eq!(*ag, want_ag, "{alg:?} all_gather at rank {r}");
                assert_eq!(*bc, vec![9.25, -8.5], "{alg:?} broadcast at rank {r}");
                let want_sc: Vec<Vec<f64>> =
                    (0..world).map(|q| vec![q as f64 * 0.1, 1.0 / (q as f64 + 3.0)]).collect();
                assert_eq!(sc.len(), want_sc.len());
                for (got, want) in sc.iter().zip(want_sc.iter()) {
                    for (a, b) in got.iter().zip(want.iter()) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{alg:?} scalars at rank {r}");
                    }
                }
            }
        }
        assert_eq!(live_net_threads(), Vec::<String>::new(), "net workers must not leak");
    }

    #[test]
    fn a_single_rank_world_needs_no_listener() {
        let ep = TcpEndpoint::connect(
            Algorithm::Ring,
            0,
            &["127.0.0.1:1".into()], // never bound: world 1 must not touch it
            Duration::from_millis(100),
        )
        .unwrap();
        let mut buf = vec![1.0f32, 2.0, 3.0];
        ep.all_reduce(&mut buf).unwrap();
        assert_eq!(buf, vec![1.0, 2.0, 3.0], "world-1 mean is the identity");
        assert_eq!(ep.gather_scalars(&[0.25]).unwrap(), vec![vec![0.25]]);
    }

    #[test]
    fn a_peer_dropping_mid_op_fails_loud_not_hanging() {
        let peers = peer_list(2);
        thread::scope(|s| {
            let p2 = peers.clone();
            s.spawn(move || {
                let ep =
                    TcpEndpoint::connect(Algorithm::Naive, 1, &p2, Duration::from_secs(10))
                        .unwrap();
                drop(ep); // dies without ever contributing
            });
            let ep = TcpEndpoint::connect(Algorithm::Naive, 0, &peers, Duration::from_secs(10))
                .unwrap();
            let e = ep.all_reduce(&mut vec![1.0f32; 8]).unwrap_err();
            let msg = format!("{e:#}");
            assert!(msg.contains("rank 1"), "error must name the dead rank: {msg}");
            // the endpoint is poisoned: later ops fail fast, with context
            let e2 = ep.barrier().unwrap_err();
            assert!(format!("{e2:#}").contains("already failed"), "{e2:#}");
        });
        assert_eq!(live_net_threads(), Vec::<String>::new());
    }

    #[test]
    fn a_stalled_peer_times_out_loudly_instead_of_hanging() {
        let peers = peer_list(2);
        let (hold_tx, hold_rx) = mpsc::channel::<()>();
        thread::scope(|s| {
            let addr = peers[0].clone();
            s.spawn(move || {
                // a fake rank 1 that handshakes, then goes silent
                let mut stream = connect_retry(&addr);
                Frame { kind: FrameKind::Hello, rank: 1, seq: 0, payload: world_payload(2) }
                    .write_to(&mut stream)
                    .unwrap();
                let welcome = Frame::read_from(&mut stream).unwrap();
                assert_eq!(welcome.kind, FrameKind::Hello);
                let _ = hold_rx.recv(); // keep the socket open until the test ends
            });
            let ep = TcpEndpoint::connect(Algorithm::Ring, 0, &peers, Duration::from_millis(500))
                .unwrap();
            let e = ep.all_reduce(&mut vec![0.5f32; 4]).unwrap_err();
            let msg = format!("{e:#}");
            assert!(
                msg.contains("stalled") && msg.contains("rank 1"),
                "stall must be loud and name the rank: {msg}"
            );
            drop(hold_tx);
        });
    }

    #[test]
    fn a_corrupted_frame_on_the_wire_is_rejected() {
        let peers = peer_list(2);
        thread::scope(|s| {
            let addr = peers[0].clone();
            s.spawn(move || {
                let mut stream = connect_retry(&addr);
                Frame { kind: FrameKind::Hello, rank: 1, seq: 0, payload: world_payload(2) }
                    .write_to(&mut stream)
                    .unwrap();
                Frame::read_from(&mut stream).unwrap(); // welcome
                let op = Frame {
                    kind: FrameKind::Op,
                    rank: 1,
                    seq: 1,
                    payload: frame::encode_op(
                        &OpDesc::AllReduce { len: 4 },
                        &[1.0, 2.0, 3.0, 4.0],
                        &[],
                    ),
                };
                let mut bytes = op.encode();
                let n = bytes.len();
                bytes[n - 10] ^= 0x04; // one flipped payload bit
                use std::io::Write as _;
                stream.write_all(&bytes).unwrap();
                let _ = Frame::read_from(&mut stream); // root closes on error
            });
            let ep = TcpEndpoint::connect(Algorithm::Naive, 0, &peers, Duration::from_secs(10))
                .unwrap();
            let e = ep.all_reduce(&mut vec![1.0f32; 4]).unwrap_err();
            let msg = format!("{e:#}");
            assert!(msg.contains("CRC"), "corruption must surface as a CRC error: {msg}");
        });
    }

    #[test]
    fn diverged_ranks_surface_a_desync_error() {
        let peers = peer_list(2);
        thread::scope(|s| {
            let p2 = peers.clone();
            let leaf = s.spawn(move || {
                let ep = TcpEndpoint::connect(Algorithm::Naive, 1, &p2, Duration::from_secs(5))
                    .unwrap();
                // wrong op for this step: the group runs an 8-element
                // all_reduce, this rank issues a 3-element one
                ep.all_reduce(&mut vec![1.0f32; 3])
            });
            let ep = TcpEndpoint::connect(Algorithm::Naive, 0, &peers, Duration::from_secs(5))
                .unwrap();
            let e = ep.all_reduce(&mut vec![1.0f32; 8]).unwrap_err();
            assert!(format!("{e:#}").contains("desync"), "{e:#}");
            drop(ep); // closes the socket, unblocking the leaf
            assert!(leaf.join().unwrap().is_err(), "the diverged leaf must also fail");
        });
    }

    #[test]
    fn world_size_mismatch_is_rejected_at_handshake() {
        let peers = peer_list(2);
        thread::scope(|s| {
            let addr = peers[0].clone();
            s.spawn(move || {
                let mut stream = connect_retry(&addr);
                // claims a 3-rank world; the root was launched with 2
                Frame { kind: FrameKind::Hello, rank: 1, seq: 0, payload: world_payload(3) }
                    .write_to(&mut stream)
                    .unwrap();
                let _ = Frame::read_from(&mut stream);
            });
            let e = TcpEndpoint::connect(Algorithm::Naive, 0, &peers, Duration::from_secs(10))
                .unwrap_err();
            assert!(format!("{e:#}").contains("world size"), "{e:#}");
        });
    }

    #[test]
    fn startup_times_out_when_a_rank_never_shows() {
        let peers = peer_list(2);
        let e = TcpEndpoint::connect(Algorithm::Naive, 0, &peers, Duration::from_millis(200))
            .unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("timed out") && msg.contains("rank(s) 1"), "{msg}");
    }

    // -----------------------------------------------------------------
    // Exhaustive model of the frame send/recv/ack protocol
    // (`crate::mc`): a stop-and-wait sender, an in-order wire that an
    // adversary may duplicate frames on, and a seq-checking receiver.
    // Explores every interleaving and proves each op is delivered
    // exactly once, in order — no lost frame, no double delivery.
    // -----------------------------------------------------------------

    const SENDER: usize = 0;
    const RECEIVER: usize = 1;
    const ADVERSARY: usize = 2;

    #[derive(Clone, PartialEq, Eq, Hash)]
    struct FrameProtocol {
        total: u8,
        /// Sender: next op seq to send (acked ops are `0..next`).
        next: u8,
        /// Sender: an op frame is on the wire awaiting its result/ack.
        inflight: bool,
        /// Op frames in flight, in order (TCP does not reorder; the
        /// adversary models retransmission by duplicating the head).
        wire: VecDeque<u8>,
        /// Result/ack frames in flight, in order.
        acks: VecDeque<u8>,
        /// How many duplications the adversary may still inject.
        dup_budget: u8,
        /// Receiver: seqs accepted for processing, in acceptance order.
        delivered: Vec<u8>,
        /// Receiver: next expected seq (unused when `dedup` is off).
        expect: u8,
        /// Receiver checks seq before accepting (the real protocol);
        /// turning this off is the negative control.
        dedup: bool,
    }

    impl FrameProtocol {
        fn new(total: u8, dup_budget: u8, dedup: bool) -> Self {
            Self {
                total,
                next: 0,
                inflight: false,
                wire: VecDeque::new(),
                acks: VecDeque::new(),
                dup_budget,
                delivered: Vec::new(),
                expect: 0,
                dedup,
            }
        }
    }

    impl Model for FrameProtocol {
        fn threads(&self) -> usize {
            3
        }

        fn step(&mut self, tid: usize) -> Step {
            match tid {
                SENDER => {
                    if self.inflight {
                        match self.acks.front().copied() {
                            Some(a) => {
                                self.acks.pop_front();
                                if a == self.next {
                                    self.next += 1;
                                    self.inflight = false;
                                }
                                // a stale re-ack for an older seq is
                                // dropped: already accounted for
                                Step::Progress
                            }
                            None => Step::Blocked,
                        }
                    } else if self.next < self.total {
                        self.wire.push_back(self.next);
                        self.inflight = true;
                        Step::Progress
                    } else {
                        Step::Done
                    }
                }
                RECEIVER => match self.wire.front().copied() {
                    Some(seq) => {
                        self.wire.pop_front();
                        if !self.dedup {
                            self.delivered.push(seq);
                            self.acks.push_back(seq);
                        } else if seq == self.expect {
                            self.delivered.push(seq);
                            self.expect += 1;
                            self.acks.push_back(seq);
                        } else {
                            // duplicate of an already-processed op:
                            // re-ack without re-delivering
                            self.acks.push_back(seq);
                        }
                        Step::Progress
                    }
                    None => {
                        if self.wire.is_empty() && self.delivered.len() >= self.total as usize {
                            Step::Done
                        } else {
                            Step::Blocked
                        }
                    }
                },
                ADVERSARY => match self.wire.front().copied() {
                    Some(head) if self.dup_budget > 0 => {
                        // retransmission: the same frame arrives twice,
                        // back to back (an in-order wire cannot reorder)
                        self.wire.insert(1, head);
                        self.dup_budget -= 1;
                        Step::Progress
                    }
                    _ => Step::Done,
                },
                _ => Step::Done,
            }
        }

        fn check(&self) -> Result<(), String> {
            for (i, &seq) in self.delivered.iter().enumerate() {
                if seq as usize != i {
                    return Err(format!(
                        "op {seq} delivered at position {i}: duplicate or out-of-order \
                         delivery (delivered = {:?})",
                        self.delivered
                    ));
                }
            }
            Ok(())
        }

        fn accept(&self) -> Result<(), String> {
            if self.delivered.len() == self.total as usize {
                Ok(())
            } else {
                Err(format!(
                    "only {} of {} ops delivered at quiescence (lost frame)",
                    self.delivered.len(),
                    self.total
                ))
            }
        }
    }

    #[test]
    fn frame_protocol_delivers_each_op_exactly_once_in_every_interleaving() {
        let r = explore(FrameProtocol::new(3, 2, true)).unwrap();
        assert!(r.states > 10, "the adversary must actually branch the schedule");
        assert!(r.terminals >= 1);
    }

    #[test]
    fn without_seq_dedup_the_checker_catches_double_delivery() {
        let v = explore(FrameProtocol::new(2, 1, false)).unwrap_err();
        assert_eq!(v.kind, ViolationKind::Invariant);
        assert!(v.message.contains("duplicate"), "{}", v.message);
    }

    // -----------------------------------------------------------------
    // First-class fault injection (`crate::faults`): the same wire
    // failures the ad-hoc fakes above hand-craft, driven through the
    // production seam a `train.faults` plan uses. An injector left at
    // its initial (epoch 0, step 0) position arms every `@0.0.r` entry
    // on the first op.
    // -----------------------------------------------------------------

    fn armed(plan: &str) -> Option<Arc<FaultInjector>> {
        Some(Arc::new(FaultInjector::new(crate::faults::FaultPlan::parse(plan).unwrap())))
    }

    #[test]
    fn injected_delays_shift_time_but_never_the_numbers() {
        const N: usize = 17;
        let run = |f0: Option<Arc<FaultInjector>>, f1: Option<Arc<FaultInjector>>| {
            let peers = peer_list(2);
            thread::scope(|s| {
                let p2 = peers.clone();
                let leaf = s.spawn(move || {
                    let ep = TcpEndpoint::connect_with_faults(
                        Algorithm::Ring,
                        1,
                        &p2,
                        Duration::from_secs(10),
                        f1,
                    )
                    .unwrap();
                    let mut v = rank_data(1, N);
                    ep.all_reduce(&mut v).unwrap();
                    v
                });
                let ep = TcpEndpoint::connect_with_faults(
                    Algorithm::Ring,
                    0,
                    &peers,
                    Duration::from_secs(10),
                    f0,
                )
                .unwrap();
                let mut v = rank_data(0, N);
                ep.all_reduce(&mut v).unwrap();
                (v, leaf.join().unwrap())
            })
        };
        let clean = run(None, None);
        let slow = run(armed("net-delay@0.0.0:ms=40"), armed("net-delay@0.0.1:ms=25"));
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&clean.0), bits(&clean.1), "all_reduce must agree across ranks");
        assert_eq!(bits(&clean.0), bits(&slow.0), "a delayed root must not change results");
        assert_eq!(bits(&clean.1), bits(&slow.1), "a delayed leaf must not change results");
    }

    #[test]
    fn an_injected_corrupt_fault_surfaces_as_a_crc_error_at_the_peer() {
        let peers = peer_list(2);
        thread::scope(|s| {
            let p2 = peers.clone();
            let leaf = s.spawn(move || {
                let ep = TcpEndpoint::connect_with_faults(
                    Algorithm::Naive,
                    1,
                    &p2,
                    Duration::from_secs(10),
                    armed("net-corrupt@0.0.1"),
                )
                .unwrap();
                ep.all_reduce(&mut vec![1.0f32; 4])
            });
            let ep = TcpEndpoint::connect(Algorithm::Naive, 0, &peers, Duration::from_secs(10))
                .unwrap();
            let e = ep.all_reduce(&mut vec![1.0f32; 4]).unwrap_err();
            let msg = format!("{e:#}");
            assert!(msg.contains("CRC"), "injected corruption must trip the CRC: {msg}");
            drop(ep); // closes the sockets, unblocking the waiting leaf
            assert!(leaf.join().unwrap().is_err(), "the corrupting rank must fail too");
        });
        assert_eq!(live_net_threads(), Vec::<String>::new());
    }

    #[test]
    fn an_injected_drop_fault_is_loud_on_both_sides() {
        let peers = peer_list(2);
        thread::scope(|s| {
            let p2 = peers.clone();
            let leaf = s.spawn(move || {
                let ep = TcpEndpoint::connect_with_faults(
                    Algorithm::Naive,
                    1,
                    &p2,
                    Duration::from_secs(10),
                    armed("net-drop@0.0.1"),
                )
                .unwrap();
                ep.all_reduce(&mut vec![2.0f32; 6])
            });
            let ep = TcpEndpoint::connect(Algorithm::Naive, 0, &peers, Duration::from_secs(10))
                .unwrap();
            let e = ep.all_reduce(&mut vec![2.0f32; 6]).unwrap_err();
            let msg = format!("{e:#}");
            assert!(msg.contains("rank 1"), "the survivor must name the dead rank: {msg}");
            let e2 = leaf.join().unwrap().unwrap_err();
            let m2 = format!("{e2:#}");
            assert!(
                m2.contains("fault injected") && m2.contains("dropped"),
                "the dropped rank must say the fault was deliberate: {m2}"
            );
        });
        assert_eq!(live_net_threads(), Vec::<String>::new());
    }

    #[test]
    fn an_injected_stall_trips_the_peer_watchdog() {
        let peers = peer_list(2);
        thread::scope(|s| {
            let p2 = peers.clone();
            let leaf = s.spawn(move || {
                let ep = TcpEndpoint::connect_with_faults(
                    Algorithm::Naive,
                    1,
                    &p2,
                    Duration::from_secs(10),
                    armed("net-stall@0.0.1:ms=1500"),
                )
                .unwrap();
                ep.all_reduce(&mut vec![0.25f32; 4])
            });
            // a short timeout so the root's watchdog fires well before the
            // stalled rank wakes up
            let ep = TcpEndpoint::connect(Algorithm::Naive, 0, &peers, Duration::from_millis(500))
                .unwrap();
            let e = ep.all_reduce(&mut vec![0.25f32; 4]).unwrap_err();
            let msg = format!("{e:#}");
            assert!(
                msg.contains("stalled") && msg.contains("rank 1"),
                "the stall must be loud and name the rank: {msg}"
            );
            let e2 = leaf.join().unwrap().unwrap_err();
            assert!(format!("{e2:#}").contains("fault injected"), "{e2:#}");
        });
    }

    // -----------------------------------------------------------------
    // Exhaustive model of a PeerLink's shutdown protocol (`crate::mc`):
    // the closer (PeerLink::close via TcpEndpoint::drop), the tx worker
    // draining its outbound channel, the rx worker blocked on the
    // socket, and an adversary peer that may sever the remote end at
    // any moment. Every interleaving must terminate with both workers
    // joined (no thread leak, no join deadlock), a real peer failure
    // must surface as a delivered error (an in-flight op is never lost
    // in silence: the rx worker either delivers `Err` or exits, which
    // disconnects the inbound channel and unblocks any waiter), and a
    // graceful close must never masquerade as a peer failure.
    // -----------------------------------------------------------------

    const CLOSER: usize = 0;
    const LINK_TX: usize = 1;
    const LINK_RX: usize = 2;
    const PEER: usize = 3;

    #[derive(Clone, PartialEq, Eq, Hash)]
    struct LinkShutdown {
        /// Real protocol: set the shutdown flag *before* shutting the
        /// socket down, so the rx worker can tell "our close" from "peer
        /// died". `false` is the negative control.
        flag_before_close: bool,
        /// Real protocol: drop the outbound sender *before* joining the
        /// tx worker (the drop is what ends its recv loop). `false` is
        /// the join-deadlock negative control.
        drop_sender_before_join: bool,
        shutdown_flag: bool,
        socket_open: bool,
        peer_open: bool,
        /// Frames queued on the outbound channel (in-flight op traffic).
        queued: u8,
        sender_alive: bool,
        /// How many times the adversary may still sever the remote end.
        peer_drop_budget: u8,
        tx_done: bool,
        rx_done: bool,
        /// The rx worker pushed an `Err` into the inbound channel.
        err_delivered: bool,
        /// Closer program counter.
        pc: u8,
    }

    impl LinkShutdown {
        fn new(
            flag_before_close: bool,
            drop_sender_before_join: bool,
            queued: u8,
            peer_drop_budget: u8,
        ) -> Self {
            Self {
                flag_before_close,
                drop_sender_before_join,
                shutdown_flag: false,
                socket_open: true,
                peer_open: true,
                queued,
                sender_alive: true,
                peer_drop_budget,
                tx_done: false,
                rx_done: false,
                err_delivered: false,
                pc: 0,
            }
        }
    }

    impl Model for LinkShutdown {
        fn threads(&self) -> usize {
            4
        }

        fn step(&mut self, tid: usize) -> Step {
            match tid {
                CLOSER => match self.pc {
                    // steps 0–1: shutdown flag and socket shutdown, in
                    // the order under test
                    0 | 1 => {
                        if (self.pc == 0) == self.flag_before_close {
                            self.shutdown_flag = true;
                        } else {
                            self.socket_open = false;
                        }
                        self.pc += 1;
                        Step::Progress
                    }
                    // steps 2–3: drop the outbound sender and join the
                    // tx worker, in the order under test
                    2 | 3 => {
                        if (self.pc == 2) == self.drop_sender_before_join {
                            self.sender_alive = false;
                            self.pc += 1;
                            Step::Progress
                        } else if self.tx_done {
                            self.pc += 1;
                            Step::Progress
                        } else {
                            Step::Blocked
                        }
                    }
                    4 => {
                        // join the rx worker
                        if self.rx_done {
                            self.pc += 1;
                            Step::Progress
                        } else {
                            Step::Blocked
                        }
                    }
                    _ => Step::Done,
                },
                LINK_TX => {
                    if self.tx_done {
                        Step::Done
                    } else if self.queued > 0 {
                        // pop a frame and write it; a dead socket on
                        // either end is a write error that ends the loop
                        self.queued -= 1;
                        if !self.socket_open || !self.peer_open {
                            self.tx_done = true;
                        }
                        Step::Progress
                    } else if !self.sender_alive {
                        // recv on a closed, drained channel: loop ends
                        self.tx_done = true;
                        Step::Progress
                    } else {
                        Step::Blocked // recv on an empty, open channel
                    }
                }
                LINK_RX => {
                    if self.rx_done {
                        Step::Done
                    } else if self.socket_open && self.peer_open {
                        // blocked in read_from; the peer never speaks in
                        // this model, so only a dead socket unblocks us
                        Step::Blocked
                    } else {
                        // read error: quiet exit if we are shutting down,
                        // otherwise surface the failure to the op waiter
                        if !self.shutdown_flag {
                            self.err_delivered = true;
                        }
                        self.rx_done = true;
                        Step::Progress
                    }
                }
                PEER => {
                    if self.peer_drop_budget == 0 {
                        Step::Done
                    } else {
                        self.peer_drop_budget -= 1;
                        self.peer_open = false;
                        Step::Progress
                    }
                }
                _ => Step::Done,
            }
        }

        fn check(&self) -> Result<(), String> {
            // an error with the peer still connected can only have come
            // from our own socket shutdown: a graceful close leaked out
            // as a fake peer failure
            if self.err_delivered && self.peer_open {
                return Err(
                    "graceful close delivered a spurious error: the rx worker saw its \
                     own socket shut down and reported it as a peer failure"
                        .into(),
                );
            }
            Ok(())
        }

        fn accept(&self) -> Result<(), String> {
            if !self.tx_done || !self.rx_done || self.pc < 5 {
                return Err("a link worker outlived close(): thread leak".into());
            }
            Ok(())
        }
    }

    #[test]
    fn link_close_is_quiet_and_leak_free_in_every_interleaving() {
        // sweep in-flight traffic × whether the peer drops mid-close
        for queued in 0..=2u8 {
            for budget in 0..=1u8 {
                let r = explore(LinkShutdown::new(true, true, queued, budget))
                    .unwrap_or_else(|v| {
                        panic!("queued={queued} budget={budget}: {v}");
                    });
                assert!(r.terminals >= 1);
            }
        }
    }

    #[test]
    fn closing_the_socket_before_the_shutdown_flag_leaks_a_spurious_error() {
        let v = explore(LinkShutdown::new(false, true, 0, 0)).unwrap_err();
        assert_eq!(v.kind, ViolationKind::Invariant);
        assert!(v.message.contains("spurious"), "{}", v.message);
    }

    #[test]
    fn joining_the_tx_worker_before_dropping_its_sender_deadlocks() {
        let v = explore(LinkShutdown::new(true, false, 0, 0)).unwrap_err();
        assert_eq!(v.kind, ViolationKind::Deadlock);
        assert!(!v.schedule.is_empty(), "counterexample schedule must replay");
    }
}
