//! Length-prefixed, CRC-protected binary frames — the TCP backend's wire
//! unit.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! [len: u32]                        // bytes that follow, incl. the CRC
//! [version: u8][kind: u8]           // FRAME_VERSION, FrameKind
//! [rank: u32][seq: u64]             // sender rank, per-connection op seq
//! [payload: len - 18 bytes]
//! [crc: u32]                        // CRC-32 over version..payload
//! ```
//!
//! The CRC ([`crate::util::crc`], the reflected 0xEDB88320 polynomial)
//! covers everything after the length prefix, so any single flipped bit —
//! header or payload — is rejected before the bytes can reach the reduce
//! path. `seq` is the lockstep tripwire: both sides stamp a monotonically
//! increasing op index on every frame, and a mismatch surfaces as a
//! collective-desync error rather than silently pairing the wrong
//! buffers. f32/f64 payloads travel as raw LE bit patterns — no text
//! round-trip, so the wire is bit-exact by construction.

use std::io::{Read, Write};

use anyhow::{bail, ensure, Result};

use crate::util::crc::crc32;

use super::super::collective::{OpDesc, OpOut};

/// Wire protocol version; bumped on any layout change.
pub const FRAME_VERSION: u8 = 1;

/// Upper bound on a frame body. A corrupt length prefix must not make the
/// reader allocate gigabytes before the CRC can catch it.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Fixed header bytes inside the length-counted body:
/// version + kind + rank + seq + crc.
const HEADER_BYTES: usize = 1 + 1 + 4 + 8 + 4;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Connection handshake: payload carries the sender's world size.
    Hello = 0,
    /// A rank's contribution to collective op `seq`.
    Op = 1,
    /// The root's result for collective op `seq` (doubles as the ack: an
    /// op is complete exactly when its result frame arrives).
    Result = 2,
}

impl FrameKind {
    fn from_u8(x: u8) -> Result<Self> {
        match x {
            0 => Ok(FrameKind::Hello),
            1 => Ok(FrameKind::Op),
            2 => Ok(FrameKind::Result),
            // lint: allow(PL009): decoder-local — PeerLink::recv and the
            // handshake wrap this with rank/seq context at the call site.
            other => bail!("unknown frame kind {other}"),
        }
    }
}

/// One decoded wire frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    /// Sender's rank.
    pub rank: u32,
    /// Per-connection monotonic op index (desync tripwire).
    pub seq: u64,
    pub payload: Vec<u8>,
}

fn le_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

fn le_u64(b: &[u8], at: usize) -> u64 {
    u64::from_le_bytes([
        b[at],
        b[at + 1],
        b[at + 2],
        b[at + 3],
        b[at + 4],
        b[at + 5],
        b[at + 6],
        b[at + 7],
    ])
}

impl Frame {
    /// The full wire encoding, length prefix included.
    pub fn encode(&self) -> Vec<u8> {
        let body_len = HEADER_BYTES - 4 + self.payload.len();
        let mut out = Vec::with_capacity(4 + body_len + 4);
        out.extend_from_slice(&((body_len + 4) as u32).to_le_bytes());
        out.push(FRAME_VERSION);
        out.push(self.kind as u8);
        out.extend_from_slice(&self.rank.to_le_bytes());
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&self.payload);
        let crc = crc32(&out[4..]);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        w.write_all(&self.encode())
    }

    /// Read and validate one frame: length sanity, CRC, version, kind.
    pub fn read_from(r: &mut impl Read) -> Result<Frame> {
        let mut len4 = [0u8; 4];
        r.read_exact(&mut len4)?;
        let len = u32::from_le_bytes(len4) as usize;
        // lint: allow(PL009): length prefix precedes the header, so no
        // rank/seq exists yet — callers wrap with link context.
        ensure!(len >= HEADER_BYTES, "frame too short: {len} bytes");
        // lint: allow(PL009): same pre-header position as above.
        ensure!(
            len <= MAX_FRAME_BYTES,
            "frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap (corrupt length prefix?)"
        );
        let mut body = vec![0u8; len];
        r.read_exact(&mut body)?;
        let crc_got = le_u32(&body, len - 4);
        let body = &body[..len - 4];
        let crc_want = crc32(body);
        // lint: allow(PL009): a corrupt frame's header fields are not
        // trustworthy enough to print — callers wrap with link context.
        ensure!(
            crc_got == crc_want,
            "frame CRC mismatch: wire {crc_got:#010x} vs computed {crc_want:#010x} — \
             corrupted in transit"
        );
        // lint: allow(PL009): version gate fires before the header is
        // trusted — callers wrap with link context.
        ensure!(
            body[0] == FRAME_VERSION,
            "frame version {} but this build speaks {FRAME_VERSION}",
            body[0]
        );
        let kind = FrameKind::from_u8(body[1])?;
        let rank = le_u32(body, 2);
        let seq = le_u64(body, 6);
        Ok(Frame { kind, rank, seq, payload: body[14..].to_vec() })
    }
}

// ---------------------------------------------------------------------------
// Payload codecs: op contributions and results.
//
// Op payload:      [tag u8][three u64 args][n_f32 u32][f32 LE ...]
//                  [n_f64 u32][f64 LE ...]
// Result payload:  [tag u8] then Full: [n u32][f32 ...]
//                            Chunks:  [k u32] k * ([n u32][f32 ...])
//                            Scalars: [k u32] k * ([n u32][f64 ...])
//                            Unit:    nothing
// ---------------------------------------------------------------------------

fn desc_code(desc: &OpDesc) -> (u8, u64, u64, u64) {
    match *desc {
        OpDesc::AllReduce { len } => (1, len as u64, 0, 0),
        OpDesc::ReduceScatter { len, parts } => (2, len as u64, parts as u64, 0),
        OpDesc::ReduceBucket { len, lo, full_len } => (3, len as u64, lo as u64, full_len as u64),
        OpDesc::AllGather => (4, 0, 0, 0),
        OpDesc::Broadcast { len, root } => (5, len as u64, root as u64, 0),
        OpDesc::Scalars { n } => (6, n as u64, 0, 0),
        OpDesc::Barrier => (7, 0, 0, 0),
    }
}

fn desc_decode(tag: u8, a: u64, b: u64, c: u64) -> Result<OpDesc> {
    Ok(match tag {
        1 => OpDesc::AllReduce { len: a as usize },
        2 => OpDesc::ReduceScatter { len: a as usize, parts: b as usize },
        3 => OpDesc::ReduceBucket { len: a as usize, lo: b as usize, full_len: c as usize },
        4 => OpDesc::AllGather,
        5 => OpDesc::Broadcast { len: a as usize, root: b as usize },
        6 => OpDesc::Scalars { n: a as usize },
        7 => OpDesc::Barrier,
        // lint: allow(PL009): payload codec — drive() reports which rank's
        // contribution failed to decode, with the op's seq.
        other => bail!("unknown collective op tag {other}"),
    })
}

fn put_f32s(out: &mut Vec<u8>, data: &[f32]) {
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    for x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_f64s(out: &mut Vec<u8>, data: &[f64]) {
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    for x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, at: 0 }
    }

    fn u8(&mut self) -> Result<u8> {
        // lint: allow(PL009): cursor primitive — the decode entry points
        // are wrapped with rank/seq context by their callers in net/mod.
        ensure!(self.at < self.b.len(), "payload truncated");
        self.at += 1;
        Ok(self.b[self.at - 1])
    }

    fn u32(&mut self) -> Result<u32> {
        // lint: allow(PL009): cursor primitive — see u8() above.
        ensure!(self.at + 4 <= self.b.len(), "payload truncated");
        let v = le_u32(self.b, self.at);
        self.at += 4;
        Ok(v)
    }

    fn u64(&mut self) -> Result<u64> {
        // lint: allow(PL009): cursor primitive — see u8() above.
        ensure!(self.at + 8 <= self.b.len(), "payload truncated");
        let v = le_u64(self.b, self.at);
        self.at += 8;
        Ok(v)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        // lint: allow(PL009): cursor primitive — see u8() above.
        ensure!(self.at + 4 * n <= self.b.len(), "payload truncated ({n} f32s declared)");
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(f32::from_le_bytes([
                self.b[self.at],
                self.b[self.at + 1],
                self.b[self.at + 2],
                self.b[self.at + 3],
            ]));
            self.at += 4;
        }
        Ok(out)
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        // lint: allow(PL009): cursor primitive — see u8() above.
        ensure!(self.at + 8 * n <= self.b.len(), "payload truncated ({n} f64s declared)");
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(f64::from_bits(le_u64(self.b, self.at)));
            self.at += 8;
        }
        Ok(out)
    }

    /// Guard an untrusted element count before reserving for it: `count`
    /// elements of at least `min_bytes` each must still fit in the
    /// remaining payload, so a corrupt count can never demand more
    /// memory than the (already length-capped) frame itself carries.
    fn claim(&self, count: usize, min_bytes: usize, what: &str) -> Result<()> {
        // lint: allow(PL009): cursor primitive — see u8() above.
        ensure!(
            self.at + count * min_bytes <= self.b.len(),
            "payload truncated ({count} {what} declared)"
        );
        Ok(())
    }

    fn done(&self) -> Result<()> {
        // lint: allow(PL009): cursor primitive — see u8() above.
        ensure!(self.at == self.b.len(), "{} trailing payload bytes", self.b.len() - self.at);
        Ok(())
    }
}

/// Encode one rank's contribution to an op.
pub(crate) fn encode_op(desc: &OpDesc, data: &[f32], scalars: &[f64]) -> Vec<u8> {
    let (tag, a, b, c) = desc_code(desc);
    let mut out = Vec::with_capacity(1 + 24 + 8 + 4 * data.len() + 8 * scalars.len());
    out.push(tag);
    out.extend_from_slice(&a.to_le_bytes());
    out.extend_from_slice(&b.to_le_bytes());
    out.extend_from_slice(&c.to_le_bytes());
    put_f32s(&mut out, data);
    put_f64s(&mut out, scalars);
    out
}

/// Decode one rank's contribution: `(descriptor, f32 data, f64 scalars)`.
pub(crate) fn decode_op(payload: &[u8]) -> Result<(OpDesc, Vec<f32>, Vec<f64>)> {
    let mut c = Cursor::new(payload);
    let tag = c.u8()?;
    let (a, b, cc) = (c.u64()?, c.u64()?, c.u64()?);
    let desc = desc_decode(tag, a, b, cc)?;
    let data = c.f32s()?;
    let scalars = c.f64s()?;
    c.done()?;
    Ok((desc, data, scalars))
}

/// Encode an op result for the result/ack frame.
pub(crate) fn encode_out(out: &OpOut) -> Vec<u8> {
    let mut buf = Vec::new();
    match out {
        OpOut::Full(v) => {
            buf.push(1);
            put_f32s(&mut buf, v);
        }
        OpOut::Chunks(chunks) => {
            buf.push(2);
            buf.extend_from_slice(&(chunks.len() as u32).to_le_bytes());
            for ch in chunks {
                put_f32s(&mut buf, ch);
            }
        }
        OpOut::Scalars(rows) => {
            buf.push(3);
            buf.extend_from_slice(&(rows.len() as u32).to_le_bytes());
            for row in rows {
                put_f64s(&mut buf, row);
            }
        }
        OpOut::Unit => buf.push(4),
    }
    buf
}

/// Decode an op result.
pub(crate) fn decode_out(payload: &[u8]) -> Result<OpOut> {
    let mut c = Cursor::new(payload);
    let out = match c.u8()? {
        1 => OpOut::Full(c.f32s()?),
        2 => {
            let k = c.u32()? as usize;
            c.claim(k, 4, "chunks")?; // each chunk carries at least its u32 length
            let mut chunks = Vec::with_capacity(k);
            for _ in 0..k {
                chunks.push(c.f32s()?);
            }
            OpOut::Chunks(chunks)
        }
        3 => {
            let k = c.u32()? as usize;
            c.claim(k, 4, "rows")?; // each row carries at least its u32 length
            let mut rows = Vec::with_capacity(k);
            for _ in 0..k {
                rows.push(c.f64s()?);
            }
            OpOut::Scalars(rows)
        }
        4 => OpOut::Unit,
        // lint: allow(PL009): payload codec — drive() wraps the result
        // decode with the op's seq and the link's rank.
        other => bail!("unknown result tag {other}"),
    };
    c.done()?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: &Frame) -> Frame {
        let bytes = f.encode();
        Frame::read_from(&mut bytes.as_slice()).unwrap()
    }

    #[test]
    fn frame_roundtrips_bitwise() {
        let f = Frame {
            kind: FrameKind::Op,
            rank: 3,
            seq: 0xDEAD_BEEF_0123,
            payload: (0..=255u8).collect(),
        };
        assert_eq!(roundtrip(&f), f);
        let empty = Frame { kind: FrameKind::Hello, rank: 0, seq: 0, payload: Vec::new() };
        assert_eq!(roundtrip(&empty), empty);
    }

    #[test]
    fn any_single_flipped_bit_is_rejected() {
        let f = Frame { kind: FrameKind::Result, rank: 1, seq: 7, payload: vec![9, 8, 7, 6, 5] };
        let clean = f.encode();
        // flip every bit after the length prefix in turn: the CRC (or a
        // header check) must reject each one
        for byte in 4..clean.len() {
            for bit in 0..8 {
                let mut bad = clean.clone();
                bad[byte] ^= 1 << bit;
                let got = Frame::read_from(&mut bad.as_slice());
                assert!(got.is_err(), "flipped bit {bit} of byte {byte} went undetected");
            }
        }
        // the pristine bytes still parse
        assert_eq!(Frame::read_from(&mut clean.as_slice()).unwrap(), f);
    }

    #[test]
    fn corrupt_length_prefix_cannot_demand_gigabytes() {
        let mut bytes = (u32::MAX).to_le_bytes().to_vec();
        bytes.extend_from_slice(&[0u8; 32]);
        let e = Frame::read_from(&mut bytes.as_slice()).unwrap_err();
        assert!(format!("{e:#}").contains("cap"), "{e:#}");
        let short = 3u32.to_le_bytes().to_vec();
        assert!(Frame::read_from(&mut short.as_slice()).is_err());
    }

    #[test]
    fn wrong_version_is_rejected() {
        let f = Frame { kind: FrameKind::Op, rank: 0, seq: 0, payload: vec![1] };
        let mut bytes = f.encode();
        bytes[4] = FRAME_VERSION + 1;
        // re-seal the CRC so only the version differs
        let crc = crate::util::crc::crc32(&bytes[4..bytes.len() - 4]);
        let n = bytes.len();
        bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());
        let e = Frame::read_from(&mut bytes.as_slice()).unwrap_err();
        assert!(format!("{e:#}").contains("version"), "{e:#}");
    }

    #[test]
    fn op_payloads_roundtrip_every_descriptor() {
        let data: Vec<f32> = (0..33).map(|i| (i as f32 - 16.0) * 0.125).collect();
        let scalars = [1.5f64, -0.0, f64::MIN_POSITIVE];
        for desc in [
            OpDesc::AllReduce { len: 33 },
            OpDesc::ReduceScatter { len: 33, parts: 5 },
            OpDesc::ReduceBucket { len: 33, lo: 11, full_len: 97 },
            OpDesc::AllGather,
            OpDesc::Broadcast { len: 33, root: 2 },
            OpDesc::Scalars { n: 3 },
            OpDesc::Barrier,
        ] {
            let bytes = encode_op(&desc, &data, &scalars);
            let (d2, data2, sc2) = decode_op(&bytes).unwrap();
            assert_eq!(d2, desc);
            assert_eq!(data2, data);
            assert_eq!(sc2.len(), scalars.len());
            for (a, b) in sc2.iter().zip(scalars.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "f64 transport must be bit-exact");
            }
        }
        assert!(decode_op(&[42]).is_err(), "unknown tags are rejected");
        assert!(decode_op(&[]).is_err(), "truncated payloads are rejected");
    }

    #[test]
    fn out_payloads_roundtrip_every_shape() {
        for out in [
            OpOut::Full(vec![0.5, -1.0, 3.25]),
            OpOut::Chunks(vec![vec![1.0; 4], vec![2.0; 3], Vec::new()]),
            OpOut::Scalars(vec![vec![0.1, 0.2], vec![-0.0]]),
            OpOut::Unit,
        ] {
            let got = decode_out(&encode_out(&out)).unwrap();
            assert_eq!(got, out);
        }
        assert!(decode_out(&[9]).is_err());
        // trailing garbage is rejected, not silently ignored
        let mut bytes = encode_out(&OpOut::Unit);
        bytes.push(0);
        assert!(decode_out(&bytes).is_err());
    }

    /// Deterministic xorshift64 — a seeded stand-in for a fuzzer's
    /// corpus, so the "arbitrary bytes" sweep below replays bit-for-bit.
    fn xorshift(state: &mut u64) -> u64 {
        let mut x = *state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        *state = x;
        x
    }

    #[test]
    fn arbitrary_byte_streams_never_panic_any_decoder() {
        let mut rng = 0x9e37_79b9_7f4a_7c15u64;
        for round in 0..2048u32 {
            let len = (xorshift(&mut rng) % 96) as usize;
            let mut bytes: Vec<u8> = (0..len).map(|_| xorshift(&mut rng) as u8).collect();
            if bytes.len() >= 4 {
                if round % 2 == 0 {
                    // force a plausible length prefix so the decode
                    // reaches the CRC/version/kind checks instead of
                    // stopping at the length-cap gate
                    let small = (xorshift(&mut rng) % 80) as u32;
                    bytes[..4].copy_from_slice(&small.to_le_bytes());
                } else {
                    // force the prefix past the cap: the gate must fire
                    // before the reader can allocate for the phantom body
                    bytes[3] |= 0x80;
                }
            }
            // Err is the expected outcome; a panic or runaway allocation
            // is the failure mode under test
            let _ = Frame::read_from(&mut bytes.as_slice());
            let _ = decode_op(&bytes);
            let _ = decode_out(&bytes);
        }
    }

    #[test]
    fn every_truncation_of_a_valid_frame_errors_cleanly() {
        let frames = [
            Frame { kind: FrameKind::Hello, rank: 2, seq: 0, payload: 4u32.to_le_bytes().into() },
            Frame {
                kind: FrameKind::Op,
                rank: 1,
                seq: 41,
                payload: encode_op(&OpDesc::AllReduce { len: 3 }, &[1.0, 2.0, 3.0], &[]),
            },
            Frame {
                kind: FrameKind::Result,
                rank: 0,
                seq: 41,
                payload: encode_out(&OpOut::Full(vec![0.5; 3])),
            },
        ];
        for f in frames {
            let bytes = f.encode();
            for cut in 0..bytes.len() {
                assert!(
                    Frame::read_from(&mut &bytes[..cut]).is_err(),
                    "a frame cut to {cut} of {} bytes must not decode",
                    bytes.len()
                );
            }
            assert_eq!(Frame::read_from(&mut bytes.as_slice()).unwrap(), f);
        }
    }

    #[test]
    fn corrupt_element_counts_are_rejected_before_any_allocation() {
        // a Chunks result claiming u32::MAX chunks in a 9-byte payload:
        // the count gate must fire before Vec::with_capacity can reserve
        // gigabytes for the phantom chunk table
        let mut bytes = vec![2u8];
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 4]);
        let e = decode_out(&bytes).unwrap_err();
        assert!(format!("{e:#}").contains("truncated"), "{e:#}");
        // same for a Scalars result's row count
        bytes[0] = 3;
        assert!(decode_out(&bytes).is_err());
        // and for a declared f32 run inside an op contribution
        let mut op = vec![1u8]; // AllReduce tag
        op.extend_from_slice(&[0u8; 24]); // three u64 args
        op.extend_from_slice(&u32::MAX.to_le_bytes()); // n_f32
        let e = decode_op(&op).unwrap_err();
        assert!(format!("{e:#}").contains("truncated"), "{e:#}");
    }
}
