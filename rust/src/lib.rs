//! # prelora
//!
//! A reproduction of *PreLoRA: Hybrid Pre-training of Vision Transformers
//! with Full Training and Low-Rank Adapters* as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L1** — Pallas kernels (fused LoRA matmul fwd/bwd) authored in
//!   `python/compile/kernels/`, lowered at build time.
//! * **L2** — a JAX ViT over flat parameter vectors
//!   (`python/compile/vit.py`), AOT-lowered to HLO text artifacts.
//! * **L3** — this crate: the coordinator that owns the training loop,
//!   data pipeline, optimizer, simulated data-parallel engine, and the
//!   paper's contributions — the partial convergence test (Algorithm 1),
//!   dynamic rank assignment (Algorithm 2) and the warmup schedule (§3.3).
//!
//! Python never runs on the training path: the `runtime` module loads the
//! HLO artifacts through PJRT and everything else is Rust.
//!
//! ## The L3 step engine
//!
//! Training steps flow through a staged pipeline ([`pipeline`]) rather
//! than a serial loop:
//!
//! ```text
//!   data ──► compute ──► reduce ──► update
//!   (prefetch   (GradEngine  (all-reduce,   (clip + optimizer
//!    thread)     workers)     overlapped     + grad telemetry)
//!                             base ∥ lora)
//! ```
//!
//! * **data** — a per-epoch prefetch thread materializes the next global
//!   step's per-worker batches (bounded by `train.pipeline.prefetch_depth`)
//!   while the current step computes.
//! * **compute** — the `dp::GradEngine` worker pool, driven through its
//!   `submit`/`collect` split so the leader re-dispatches step *k+1*
//!   right after the step-*k* update and books step *k* while the workers
//!   are busy.
//! * **reduce** — `pipeline::ReduceStage`: a warmup step's base
//!   gradients sync on the stage thread concurrently with its LoRA
//!   gradients on the leader (a double-buffered accumulation pair).
//!   With `train.pipeline.bucket_bytes > 0` the overlap goes
//!   bucket-level: workers publish shard-aligned gradient buckets as
//!   backward fills them and a persistent accumulator thread reduces
//!   them while later buckets are still being computed — bitwise
//!   identical to whole-buffer sync (see `docs/dist-api.md`).
//! * **update** — `pipeline::UpdateStage`: clip + optimizer step + per-step
//!   pre-clip gradient-norm telemetry, shared by the pipelined and the
//!   sequential (`train.pipeline.enabled = false`) paths.
//!
//! ## The distribution API
//!
//! Everything the stack knows about sharding lives behind the two traits
//! in [`dist`]: [`dist::Collective`] (all-reduce / reduce-scatter /
//! all-gather / broadcast over the naive / tree / ring schedules) and
//! [`dist::Strategy`] — the object-safe layout description the trainer,
//! pipeline, checkpoint path and benches dispatch through. The stock
//! strategies are the ZeRO stages (`train.zero.stage = 0|1|2|3`):
//! unsharded DDP, optimizer-state sharding, terminal gradient
//! reduce-scatter, and full parameter sharding (each rank owns a
//! contiguous partition; the working view is all-gathered per step and
//! dropped after the update). Per-rank optimizer / gradient / parameter
//! bytes shrink ~1/N stage by stage with bit-identical losses throughout;
//! PreLoRA's phase switches reach the strategy as first-class
//! `Repartition` events. See `docs/dist-api.md`.
//!
//! **Determinism contract:** for a fixed seed the two paths produce
//! bit-identical per-epoch losses in every phase. Batches are pure
//! functions of `(seed, epoch, step)`, worker gradients reduce in worker
//! order through one summation schedule regardless of thread placement,
//! and epoch boundaries are barriers — the controller can only change the
//! `StepMode` once every in-flight step has drained, so the
//! Full -> Warmup -> LoraOnly transitions land on the same epochs.
//!
//! Quick start (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use prelora::config::RunConfig;
//! use prelora::trainer::Trainer;
//!
//! let mut cfg = RunConfig::default();
//! cfg.model = "vit-micro".into();
//! cfg.train.epochs = 12;
//! let mut trainer = Trainer::new(cfg).unwrap();
//! let summary = trainer.run().unwrap();
//! println!("{}", summary.render());
//! ```

pub mod checkpoint;
pub mod config;
pub mod convergence;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod dp;
pub mod faults;
pub mod manifest;
pub mod mc;
pub mod optim;
pub mod pipeline;
pub mod rank;
pub mod report;
pub mod runtime;
pub(crate) mod sync;
pub mod telemetry;
pub mod tensor;
pub mod trainer;
pub mod util;

pub use config::RunConfig;
pub use coordinator::{Phase, PreLoraController};
pub use manifest::Manifest;
pub use report::RunSummary;
pub use trainer::Trainer;
