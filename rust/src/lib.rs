//! # prelora
//!
//! A reproduction of *PreLoRA: Hybrid Pre-training of Vision Transformers
//! with Full Training and Low-Rank Adapters* as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L1** — Pallas kernels (fused LoRA matmul fwd/bwd) authored in
//!   `python/compile/kernels/`, lowered at build time.
//! * **L2** — a JAX ViT over flat parameter vectors
//!   (`python/compile/vit.py`), AOT-lowered to HLO text artifacts.
//! * **L3** — this crate: the coordinator that owns the training loop,
//!   data pipeline, optimizer, simulated data-parallel engine, and the
//!   paper's contributions — the partial convergence test (Algorithm 1),
//!   dynamic rank assignment (Algorithm 2) and the warmup schedule (§3.3).
//!
//! Python never runs on the training path: the `runtime` module loads the
//! HLO artifacts through PJRT and everything else is Rust.
//!
//! Quick start (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use prelora::config::RunConfig;
//! use prelora::trainer::Trainer;
//!
//! let mut cfg = RunConfig::default();
//! cfg.model = "vit-micro".into();
//! cfg.train.epochs = 12;
//! let mut trainer = Trainer::new(cfg).unwrap();
//! let summary = trainer.run().unwrap();
//! println!("{}", summary.render());
//! ```

pub mod config;
pub mod convergence;
pub mod coordinator;
pub mod data;
pub mod dp;
pub mod manifest;
pub mod optim;
pub mod rank;
pub mod report;
pub mod runtime;
pub mod telemetry;
pub mod tensor;
pub mod trainer;
pub mod util;

pub use config::RunConfig;
pub use coordinator::{Phase, PreLoraController};
pub use manifest::Manifest;
pub use report::RunSummary;
pub use trainer::Trainer;
