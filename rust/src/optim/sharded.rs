//! ZeRO partitioned optimizer state (stages 1 and 2).
//!
//! In classic data-parallel training every worker replicates the full
//! AdamW `m`/`v` buffers — 8 bytes/param regardless of worker count. ZeRO
//! (Rajbhandari et al.) instead gives each worker the optimizer state for
//! *its* contiguous partition of the parameter vector only, so per-worker
//! state shrinks ~1/N while the union of shards is exactly the unsharded
//! state. [`ShardedOptimizer`] is that layout: one inner [`Optimizer`]
//! per shard over the [`partition`] chunking that `dp::reduce_scatter`
//! also uses, so the gradient chunk a worker receives lines up with the
//! state shard it owns by construction. At stage 1 the gradient arrives
//! replicated ([`Reduced::Full`]) and every shard reads its slice; at
//! stage 2 it arrives as owned partitions ([`Reduced::Sharded`]) and each
//! shard consumes exactly its chunk — [`step_reduced`] dispatches on the
//! layout.
//!
//! [`step_reduced`]: ShardedOptimizer::step_reduced
//!
//! **Bit contract.** Both optimizers here are elementwise, so updating a
//! partition with the partition's gradient chunk performs exactly the
//! per-element operations the unsharded optimizer would — sharded and
//! unsharded training produce bit-identical parameters. A single shard
//! (`shards == 1`) *is* the unsharded optimizer; the unsharded
//! `dist::Strategy` builds exactly that degenerate layout.

use anyhow::{ensure, Result};

use super::{build, OptState, Optimizer};
use crate::config::TrainConfig;
use crate::dp::{partition, Reduced};

/// Optimizer state partitioned over contiguous parameter chunks.
pub struct ShardedOptimizer {
    shards: Vec<Box<dyn Optimizer + Send>>,
    bounds: Vec<(usize, usize)>,
    len: usize,
    kind: crate::config::OptimizerKind,
}

impl ShardedOptimizer {
    /// Partition a length-`n` parameter vector into `shards` chunks, each
    /// with its own optimizer instance built from `cfg`.
    pub fn new(cfg: &TrainConfig, n: usize, shards: usize) -> Self {
        let bounds = partition(n, shards);
        let shards = bounds.iter().map(|&(lo, hi)| build(cfg, hi - lo)).collect();
        Self { shards, bounds, len: n, kind: cfg.optimizer }
    }

    /// Number of state partitions (= simulated ZeRO workers).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Optimizer kind every shard runs ([`import_state`](Self::import_state)
    /// rejects state of any other kind before touching a shard).
    pub fn kind(&self) -> crate::config::OptimizerKind {
        self.kind
    }

    /// Partition bounds, in shard order (the [`partition`] chunking).
    pub fn bounds(&self) -> &[(usize, usize)] {
        &self.bounds
    }

    /// Parameter-vector length this optimizer was built for.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Apply one update from a *full* (replicated) gradient: every shard
    /// steps its slice. Bitwise identical to the unsharded optimizer.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), self.len, "param length mismatch");
        assert_eq!(grads.len(), self.len, "grad length mismatch");
        for (shard, &(lo, hi)) in self.shards.iter_mut().zip(&self.bounds) {
            shard.step(&mut params[lo..hi], &grads[lo..hi], lr);
        }
    }

    /// Apply one update with the gradient in either [`Reduced`] layout —
    /// the one entry point the update stage uses, so the layout dispatch
    /// lives next to the shard layout it must agree with.
    pub fn step_reduced(&mut self, params: &mut [f32], grad: &Reduced, lr: f32) {
        match grad {
            Reduced::Full(v) => self.step(params, v, lr),
            Reduced::Sharded(chunks) => self.step_sharded(params, chunks, lr),
        }
    }

    /// Apply one update from reduce-scattered gradient `chunks` (one per
    /// shard, [`partition`] layout): worker `w` updates only its owned
    /// slice of `params` — the ZeRO-2 step. The caller's shared full
    /// vector plays the role of the post-update **parameter all-gather**
    /// (parameters, not gradients: the scattered gradient chunks are
    /// dropped after this step): each shard writes its updated slice back
    /// into place, and because the slices are disjoint and cover the
    /// vector, the replicated parameters the next step's forward pass
    /// needs are re-assembled exactly.
    pub fn step_sharded(&mut self, params: &mut [f32], chunks: &[Vec<f32>], lr: f32) {
        assert_eq!(params.len(), self.len, "param length mismatch");
        assert_eq!(chunks.len(), self.shards.len(), "one gradient chunk per shard required");
        for ((shard, &(lo, hi)), chunk) in self.shards.iter_mut().zip(&self.bounds).zip(chunks) {
            assert_eq!(chunk.len(), hi - lo, "gradient chunk does not match shard bounds");
            shard.step(&mut params[lo..hi], chunk, lr);
        }
    }

    /// Apply one update to a *single* shard: `params` and `grads` are the
    /// shard's owned slices (ZeRO-3, where the parameters themselves live
    /// as owned partitions and each rank steps only its own). Performs
    /// exactly the per-element operations [`step_sharded`] performs for
    /// that shard — callers step every shard each round so the lockstep
    /// `steps()` counter stays meaningful.
    ///
    /// [`step_sharded`]: Self::step_sharded
    pub fn step_shard(&mut self, shard: usize, params: &mut [f32], grads: &[f32], lr: f32) {
        let (lo, hi) = self.bounds[shard];
        assert_eq!(params.len(), hi - lo, "owned parameter slice does not match shard bounds");
        assert_eq!(grads.len(), hi - lo, "gradient chunk does not match shard bounds");
        self.shards[shard].step(params, grads, lr);
    }

    /// Total state bytes across all shards (= the unsharded footprint).
    pub fn state_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.state_bytes()).sum()
    }

    /// State bytes the largest single worker holds — the quantity that
    /// actually bounds accelerator memory per rank under ZeRO (~1/N of
    /// [`state_bytes`](Self::state_bytes), plus chunk-rounding).
    pub fn per_worker_state_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.state_bytes()).max().unwrap_or(0)
    }

    /// Update steps taken (shards advance in lockstep).
    pub fn steps(&self) -> u64 {
        self.shards.first().map_or(0, |s| s.steps())
    }

    /// Gather every shard's state into one full [`OptState`] (the
    /// checkpoint representation — shard-layout independent).
    pub fn export_state(&self) -> OptState {
        let n_bufs = self.shards.first().map_or(0, |s| s.state_bufs().len());
        let mut bufs = vec![Vec::with_capacity(self.len); n_bufs];
        for shard in &self.shards {
            for (full, part) in bufs.iter_mut().zip(shard.state_bufs()) {
                full.extend_from_slice(part);
            }
        }
        OptState { kind: self.kind, t: self.steps(), bufs }
    }

    /// Scatter a full [`OptState`] across this optimizer's shard layout.
    /// The state may come from a run with any shard count (including 1).
    pub fn import_state(&mut self, state: &OptState) -> Result<()> {
        ensure!(
            state.kind == self.kind,
            "optimizer state kind {:?} does not match configured {:?}",
            state.kind,
            self.kind
        );
        ensure!(
            state.bufs.iter().all(|b| b.len() == self.len),
            "optimizer state length mismatch: expected {} per buffer",
            self.len
        );
        for (shard, &(lo, hi)) in self.shards.iter_mut().zip(&self.bounds) {
            let parts: Vec<&[f32]> = state.bufs.iter().map(|b| &b[lo..hi]).collect();
            shard.load_state(&parts, state.t)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::dp::{all_gather, scatter};

    fn grads(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::tensor::Pcg64::new(seed);
        let mut g = vec![0.0f32; n];
        rng.fill_normal(&mut g, 0.5);
        g
    }

    #[test]
    fn sharded_step_is_bitwise_identical_to_unsharded() {
        // odd length + odd shard count: ragged final chunk
        let n = 103;
        let cfg = TrainConfig::default();
        let mut full = ShardedOptimizer::new(&cfg, n, 1);
        let mut sharded = ShardedOptimizer::new(&cfg, n, 3);
        let mut p1 = vec![0.3f32; n];
        let mut p2 = p1.clone();
        for step in 0..5u64 {
            let g = grads(n, step);
            full.step(&mut p1, &g, 1e-3);
            sharded.step_sharded(&mut p2, &scatter(&g, 3), 1e-3);
            assert_eq!(p1, p2, "step {step}: sharded update diverged");
        }
        assert_eq!(full.steps(), 5);
        assert_eq!(sharded.steps(), 5);
    }

    #[test]
    fn per_worker_state_shrinks_with_shards() {
        let cfg = TrainConfig::default();
        let n = 10_000;
        for workers in [1usize, 2, 4, 7] {
            let opt = ShardedOptimizer::new(&cfg, n, workers);
            let total = opt.state_bytes();
            let per = opt.per_worker_state_bytes();
            assert_eq!(total, ShardedOptimizer::new(&cfg, n, 1).state_bytes());
            // <= (1/N + eps) of the unsharded total: ceil-chunking adds at
            // most one element per state buffer
            assert!(
                per as f64 <= total as f64 / workers as f64 + 16.0,
                "workers={workers}: per-worker {per} vs total {total}"
            );
        }
    }

    #[test]
    fn per_shard_steps_match_the_sharded_step_bitwise() {
        // the ZeRO-3 entry point: stepping each shard's owned slices one
        // by one equals one step_sharded call over the same chunks
        let n = 103;
        let cfg = TrainConfig::default();
        let g = grads(n, 5);
        let mut whole = ShardedOptimizer::new(&cfg, n, 3);
        let mut piecewise = ShardedOptimizer::new(&cfg, n, 3);
        let mut p1 = vec![0.3f32; n];
        let mut p2_chunks = scatter(&p1, 3);
        whole.step_sharded(&mut p1, &scatter(&g, 3), 1e-3);
        for (i, (pc, gc)) in p2_chunks.iter_mut().zip(scatter(&g, 3)).enumerate() {
            piecewise.step_shard(i, pc, &gc, 1e-3);
        }
        assert_eq!(p1, all_gather(&p2_chunks), "per-shard stepping diverged");
        assert_eq!(whole.export_state(), piecewise.export_state());
        assert_eq!(piecewise.steps(), 1, "all shards stepped keeps the counter in lockstep");
    }

    #[test]
    fn step_reduced_dispatches_on_layout_bitwise() {
        // the same gradient through both Reduced layouts must move the
        // parameters identically (ragged 3-way split of 23)
        let n = 23;
        let cfg = TrainConfig::default();
        let g = grads(n, 1);
        let mut a = ShardedOptimizer::new(&cfg, n, 3);
        let mut b = ShardedOptimizer::new(&cfg, n, 3);
        let mut pa = vec![0.2f32; n];
        let mut pb = pa.clone();
        a.step_reduced(&mut pa, &Reduced::Full(g.clone()), 1e-3);
        b.step_reduced(&mut pb, &Reduced::Sharded(scatter(&g, 3)), 1e-3);
        assert_eq!(pa, pb, "layout dispatch diverged");
        assert_eq!(a.export_state(), b.export_state());
    }

    #[test]
    fn export_import_roundtrips_across_shard_layouts() {
        let cfg = TrainConfig::default();
        let n = 57;
        let mut a = ShardedOptimizer::new(&cfg, n, 4);
        let mut p = vec![0.1f32; n];
        for step in 0..3u64 {
            a.step(&mut p, &grads(n, step), 1e-3);
        }
        let st = a.export_state();
        assert_eq!(st.t, 3);
        assert_eq!(st.bufs.len(), 2, "AdamW exports [m, v]");
        assert!(st.bufs.iter().all(|b| b.len() == n));

        // single-worker restore of the 4-way sharded run
        let mut b = ShardedOptimizer::new(&cfg, n, 1);
        b.import_state(&st).unwrap();
        // both must now take bit-identical future steps
        let mut pa = p.clone();
        let mut pb = p.clone();
        let g = grads(n, 99);
        a.step(&mut pa, &g, 1e-3);
        b.step(&mut pb, &g, 1e-3);
        assert_eq!(pa, pb, "restored optimizer diverged from source");
        assert_eq!(b.export_state(), a.export_state());
    }

    #[test]
    fn worker_count_change_re_partitions_bitwise() {
        // the resume contract's layout-change leg: state gathered from a
        // 2-way run imports onto a ragged 5-way layout (and back), and
        // every layout takes bit-identical future steps
        let cfg = TrainConfig::default();
        let n = 103;
        let mut two = ShardedOptimizer::new(&cfg, n, 2);
        assert_eq!(two.kind(), cfg.optimizer);
        let mut p = vec![0.2f32; n];
        for step in 0..4u64 {
            two.step(&mut p, &grads(n, step), 1e-3);
        }
        let st = two.export_state();
        let mut five = ShardedOptimizer::new(&cfg, n, 5);
        five.import_state(&st).unwrap();
        assert_eq!(five.steps(), 4, "step counter must survive the re-partition");
        // gather(scatter(state)) is the identity regardless of layout
        assert_eq!(five.export_state(), st);
        let mut p2 = p.clone();
        let g = grads(n, 77);
        two.step(&mut p, &g, 1e-3);
        five.step(&mut p2, &g, 1e-3);
        assert_eq!(p, p2, "re-partitioned optimizer diverged");
    }

    #[test]
    fn import_rejects_mismatches() {
        let cfg = TrainConfig::default();
        let mut opt = ShardedOptimizer::new(&cfg, 10, 2);
        let mut st = opt.export_state();
        st.bufs[0].pop();
        assert!(opt.import_state(&st).is_err(), "short buffer must be rejected");
        let mut st = opt.export_state();
        st.kind = crate::config::OptimizerKind::Sgd;
        assert!(opt.import_state(&st).is_err(), "kind mismatch must be rejected");
    }

    #[test]
    fn bounds_line_up_with_gather() {
        let cfg = TrainConfig::default();
        let opt = ShardedOptimizer::new(&cfg, 23, 5);
        assert_eq!(opt.shard_count(), 5);
        assert_eq!(opt.len(), 23);
        // the shard bounds are exactly the reduce_scatter partition
        let chunks: Vec<Vec<f32>> = opt
            .bounds()
            .iter()
            .map(|&(lo, hi)| (lo..hi).map(|i| i as f32).collect())
            .collect();
        let full = all_gather(&chunks);
        assert_eq!(full.len(), 23);
        assert!(full.iter().enumerate().all(|(i, &v)| v == i as f32));
    }
}
