//! Learning-rate schedules (epoch-resolution, per Steiner et al. recipe).

use crate::config::{LrScheduleKind, TrainConfig};

/// Precomputed per-epoch learning rates.
#[derive(Debug, Clone)]
pub struct LrSchedule {
    per_epoch: Vec<f64>,
}

impl LrSchedule {
    pub fn new(cfg: &TrainConfig) -> Self {
        let e = cfg.epochs.max(1);
        let warmup = ((cfg.epochs as f64) * cfg.lr_warmup_frac).round() as usize;
        let per_epoch = (0..e)
            .map(|i| match cfg.lr_schedule {
                LrScheduleKind::Constant => cfg.lr,
                LrScheduleKind::WarmupCosine => {
                    if i < warmup && warmup > 0 {
                        cfg.lr * (i + 1) as f64 / warmup as f64
                    } else {
                        let p = if e == warmup {
                            1.0
                        } else {
                            (i - warmup) as f64 / (e - warmup) as f64
                        };
                        cfg.min_lr
                            + 0.5 * (cfg.lr - cfg.min_lr) * (1.0 + (std::f64::consts::PI * p).cos())
                    }
                }
                LrScheduleKind::Step => {
                    let frac = i as f64 / e as f64;
                    if frac < 0.6 {
                        cfg.lr
                    } else if frac < 0.85 {
                        cfg.lr * 0.1
                    } else {
                        cfg.lr * 0.01
                    }
                }
            })
            .collect();
        Self { per_epoch }
    }

    #[inline]
    pub fn lr_at(&self, epoch: usize) -> f64 {
        let i = epoch.min(self.per_epoch.len() - 1);
        self.per_epoch[i]
    }

    pub fn epochs(&self) -> usize {
        self.per_epoch.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    fn cfg(kind: LrScheduleKind) -> TrainConfig {
        let mut c = TrainConfig::default();
        c.lr_schedule = kind;
        c.epochs = 100;
        c.lr = 1.0;
        c.min_lr = 0.01;
        c.lr_warmup_frac = 0.1;
        c
    }

    #[test]
    fn warmup_cosine_shape() {
        let s = LrSchedule::new(&cfg(LrScheduleKind::WarmupCosine));
        // ramps up
        assert!(s.lr_at(0) < s.lr_at(5));
        assert!((s.lr_at(9) - 1.0).abs() < 1e-9, "peak at end of warmup");
        // decays monotonically after warmup
        for i in 10..99 {
            assert!(s.lr_at(i) >= s.lr_at(i + 1) - 1e-12);
        }
        assert!(s.lr_at(99) >= 0.01 - 1e-9);
        assert!(s.lr_at(99) < 0.05);
    }

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::new(&cfg(LrScheduleKind::Constant));
        assert_eq!(s.lr_at(0), 1.0);
        assert_eq!(s.lr_at(99), 1.0);
        assert_eq!(s.lr_at(1000), 1.0); // clamps beyond the end
    }

    #[test]
    fn step_decays_twice() {
        let s = LrSchedule::new(&cfg(LrScheduleKind::Step));
        assert_eq!(s.lr_at(0), 1.0);
        assert!((s.lr_at(70) - 0.1).abs() < 1e-12);
        assert!((s.lr_at(90) - 0.01).abs() < 1e-12);
    }
}
