//! SGD with classical momentum + coupled weight decay.

use super::Optimizer;

pub struct Sgd {
    velocity: Vec<f32>,
    momentum: f32,
    weight_decay: f32,
    t: u64,
}

impl Sgd {
    pub fn new(n: usize, momentum: f32, weight_decay: f32) -> Self {
        Self { velocity: vec![0.0; n], momentum, weight_decay, t: 0 }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), self.velocity.len());
        assert_eq!(grads.len(), self.velocity.len());
        self.t += 1;
        let (mu, wd) = (self.momentum, self.weight_decay);
        for i in 0..params.len() {
            let g = grads[i] + wd * params[i];
            self.velocity[i] = mu * self.velocity[i] + g;
            params[i] -= lr * self.velocity[i];
        }
    }

    fn state_bytes(&self) -> usize {
        self.velocity.len() * std::mem::size_of::<f32>()
    }

    fn steps(&self) -> u64 {
        self.t
    }

    fn state_bufs(&self) -> Vec<&[f32]> {
        vec![&self.velocity]
    }

    fn load_state(&mut self, bufs: &[&[f32]], t: u64) -> anyhow::Result<()> {
        anyhow::ensure!(bufs.len() == 1, "SGD state is [velocity], got {} buffers", bufs.len());
        anyhow::ensure!(
            bufs[0].len() == self.velocity.len(),
            "SGD state length mismatch: got {}, expected {}",
            bufs[0].len(),
            self.velocity.len()
        );
        self.velocity.copy_from_slice(bufs[0]);
        self.t = t;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn momentum_accumulates() {
        let mut opt = Sgd::new(1, 0.9, 0.0);
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[1.0], 0.1);
        let first = -p[0];
        opt.step(&mut p, &[1.0], 0.1);
        let second = -p[0] - first;
        assert!(second > first, "second step larger under momentum");
    }
}
