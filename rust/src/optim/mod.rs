//! Optimizers + learning-rate schedules, operating on flat f32 vectors.
//!
//! The optimizer lives on the Rust side of the artifact boundary: XLA
//! computes gradients only, which keeps one HLO artifact valid for every
//! optimizer/schedule configuration and lets the LoRA switch re-use the
//! same machinery on a different (much smaller) parameter vector — the
//! paper's memory saving is precisely that the frozen base keeps *no*
//! optimizer state after the switch.
//!
//! On the training path these are driven exclusively by the pipeline's
//! update stage (`crate::pipeline::UpdateStage`), which owns the
//! clip-then-step ordering shared by the pipelined and sequential loops.

mod adamw;
mod lr;
mod sgd;
mod sharded;

pub use adamw::AdamW;
pub use lr::LrSchedule;
pub use sgd::Sgd;
pub use sharded::ShardedOptimizer;

use crate::config::{OptimizerKind, TrainConfig};

/// A first-order optimizer over a flat parameter vector.
pub trait Optimizer {
    /// Apply one update in place. `lr` comes from the schedule.
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32);

    /// Bytes of optimizer state currently held (memory accounting, Fig. 7).
    fn state_bytes(&self) -> usize;

    /// Number of update steps taken.
    fn steps(&self) -> u64;

    /// The state buffers in a fixed kind-specific order (AdamW: `[m, v]`;
    /// SGD: `[velocity]`) — read by the sharded gather and checkpointing.
    fn state_bufs(&self) -> Vec<&[f32]>;

    /// Restore state from buffers laid out as [`state_bufs`](Self::state_bufs)
    /// returns, plus the step counter. Buffer count and lengths must match.
    fn load_state(&mut self, bufs: &[&[f32]], t: u64) -> anyhow::Result<()>;
}

/// Portable snapshot of an optimizer's *full* (unsharded) state. A
/// sharded run gathers its shards into this before checkpointing, so a
/// restore can re-scatter onto any shard layout — including a
/// single-worker restore of an N-way sharded run.
#[derive(Debug, Clone, PartialEq)]
pub struct OptState {
    pub kind: OptimizerKind,
    /// Update steps taken (bias-correction time for AdamW).
    pub t: u64,
    /// Kind-specific state buffers, each the full parameter length.
    pub bufs: Vec<Vec<f32>>,
}

/// Construct the configured optimizer for a parameter vector of length `n`.
pub fn build(cfg: &TrainConfig, n: usize) -> Box<dyn Optimizer + Send> {
    match cfg.optimizer {
        OptimizerKind::AdamW => Box::new(AdamW::new(
            n,
            cfg.beta1 as f32,
            cfg.beta2 as f32,
            cfg.eps as f32,
            cfg.weight_decay as f32,
        )),
        OptimizerKind::Sgd => Box::new(Sgd::new(n, 0.9, cfg.weight_decay as f32)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    /// Both optimizers must reduce a simple quadratic.
    #[test]
    fn optimizers_descend_quadratic() {
        for kind in [OptimizerKind::AdamW, OptimizerKind::Sgd] {
            let mut cfg = TrainConfig::default();
            cfg.optimizer = kind;
            cfg.weight_decay = 0.0;
            let mut opt = build(&cfg, 4);
            let mut p = vec![1.0f32, -2.0, 3.0, -4.0];
            for _ in 0..300 {
                let g: Vec<f32> = p.iter().map(|&x| 2.0 * x).collect();
                opt.step(&mut p, &g, 0.05);
            }
            let norm: f32 = p.iter().map(|x| x * x).sum();
            assert!(norm < 1e-3, "{kind:?} failed to descend: {p:?}");
            assert_eq!(opt.steps(), 300);
        }
    }

    #[test]
    fn state_bytes_scale_with_params() {
        let cfg = TrainConfig::default();
        let small = build(&cfg, 100).state_bytes();
        let big = build(&cfg, 10_000).state_bytes();
        assert!(big > 50 * small);
    }
}
