//! AdamW (decoupled weight decay) on flat vectors.

use super::Optimizer;

pub struct AdamW {
    m: Vec<f32>,
    v: Vec<f32>,
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    t: u64,
}

impl AdamW {
    pub fn new(n: usize, beta1: f32, beta2: f32, eps: f32, weight_decay: f32) -> Self {
        Self { m: vec![0.0; n], v: vec![0.0; n], beta1, beta2, eps, weight_decay, t: 0 }
    }
}

impl Optimizer for AdamW {
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        let (b1, b2, eps, wd) = (self.beta1, self.beta2, self.eps, self.weight_decay);
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            // decoupled decay (AdamW): decay applied to the parameter, not the gradient
            params[i] -= lr * (mhat / (vhat.sqrt() + eps) + wd * params[i]);
        }
    }

    fn state_bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    fn steps(&self) -> u64 {
        self.t
    }

    fn state_bufs(&self) -> Vec<&[f32]> {
        vec![&self.m, &self.v]
    }

    fn load_state(&mut self, bufs: &[&[f32]], t: u64) -> anyhow::Result<()> {
        anyhow::ensure!(bufs.len() == 2, "AdamW state is [m, v], got {} buffers", bufs.len());
        anyhow::ensure!(
            bufs[0].len() == self.m.len() && bufs[1].len() == self.v.len(),
            "AdamW state length mismatch: got [{}, {}], expected {}",
            bufs[0].len(),
            bufs[1].len(),
            self.m.len()
        );
        self.m.copy_from_slice(bufs[0]);
        self.v.copy_from_slice(bufs[1]);
        self.t = t;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bias_correction_first_step() {
        // After one step with g = 1, AdamW moves by ~lr regardless of betas.
        let mut opt = AdamW::new(1, 0.9, 0.999, 1e-8, 0.0);
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[1.0], 0.01);
        assert!((p[0] + 0.01).abs() < 1e-4, "{}", p[0]);
    }

    #[test]
    fn weight_decay_shrinks_params_without_gradient() {
        let mut opt = AdamW::new(1, 0.9, 0.999, 1e-8, 0.1);
        let mut p = vec![1.0f32];
        for _ in 0..10 {
            opt.step(&mut p, &[0.0], 0.1);
        }
        assert!(p[0] < 1.0 && p[0] > 0.8, "{}", p[0]);
    }

    #[test]
    #[should_panic]
    fn length_mismatch_panics() {
        let mut opt = AdamW::new(2, 0.9, 0.999, 1e-8, 0.0);
        let mut p = vec![0.0f32; 3];
        opt.step(&mut p, &[0.0; 3], 0.1);
    }
}
