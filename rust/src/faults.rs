//! `faults` — deterministic, seed-driven fault injection.
//!
//! A [`FaultPlan`] schedules named faults at exact `(epoch, step, rank)`
//! coordinates, parsed from a compact spec string (config key
//! `train.faults.plan`, CLI `--faults`). The plan is pure data: the same
//! spec against the same seed produces the same faults at the same
//! trajectory positions on every run — adversity tests are as
//! reproducible as the happy path (`rust/tests/adversity.rs` asserts
//! byte-identical outcomes for repeated runs of one seed + plan).
//!
//! Spec grammar, `;`-separated entries:
//!
//! ```text
//! kind@epoch.step.rank[:key=value[,key=value]*]
//! ```
//!
//! | kind          | coordinate `rank` means | effect at the coordinate                                  |
//! |---------------|-------------------------|-----------------------------------------------------------|
//! | `straggle`    | local compute worker id | worker sleeps `ms` before computing (trajectory-neutral)  |
//! | `panic`       | local compute worker id | worker panics (must surface as a loud epoch error)        |
//! | `abort`       | local compute worker id | worker fails its job mid-step (contextful `Err`)          |
//! | `net-delay`   | process (dist) rank     | rank sleeps `ms` before its collective ops (neutral)      |
//! | `net-stall`   | process (dist) rank     | rank sleeps `ms`, then fails — peers see a stall timeout  |
//! | `net-drop`    | process (dist) rank     | rank drops every TCP connection — peers see the loss      |
//! | `net-corrupt` | process (dist) rank     | rank's next outgoing frame gets one bit flipped (CRC)     |
//! | `ckpt-torn`   | unused (write `0`)      | the rolling checkpoint written once `epoch` epochs have completed is truncated at byte `byte` |
//!
//! `epoch`/`step` are the trainer's 0-based counters (epoch = completed
//! epochs when the faulted epoch starts). Entries whose coordinates are
//! never reached simply never fire. Compute-fault ranks are *local*
//! worker ids, so every process of a `--dist tcp` group can share one
//! plan: each entry fires only on the process/worker its coordinate
//! names.
//!
//! Canonical re-emission: [`FaultPlan::to_spec`] emits entries sorted by
//! coordinate with parameters in fixed order, and `parse(to_spec(p)) ==
//! p` — a config round-trip through `prelora gen-config` is stable.
//!
//! Runtime side: [`FaultInjector`] wraps a plan plus the trainer's
//! current `(epoch, step)` position (advanced by the step pipeline).
//! Injection sites hold an `Option<Arc<FaultInjector>>` that is `None`
//! unless `train.faults.plan` is set, so the disabled hot path is a
//! single pointer check — the full parity and bench suites run
//! bitwise-unchanged with faults absent.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{anyhow, bail, ensure, Context, Result};

/// One scheduled fault: what happens, and where in the trajectory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fault {
    pub epoch: usize,
    pub step: usize,
    /// Local compute-worker id for compute faults, distributed process
    /// rank for `net-*` faults, unused (0) for `ckpt-torn`.
    pub rank: usize,
    pub kind: FaultKind,
}

/// The fault catalog. See the module docs for per-kind semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Deterministic sleep in one compute worker — must not change bits.
    Straggle { ms: u64 },
    /// One compute worker panics mid-job.
    PanicWorker,
    /// One compute worker fails its job with a contextful error.
    Abort,
    /// Deterministic sleep before a rank's collective ops — neutral.
    NetDelay { ms: u64 },
    /// Sleep past the peers' recv deadline, then fail loudly.
    NetStall { ms: u64 },
    /// Drop every TCP connection this rank holds.
    NetDrop,
    /// Flip one bit in this rank's next outgoing frame (CRC rejection).
    NetCorrupt,
    /// Truncate the rolling checkpoint at `byte` after the atomic save —
    /// a torn write, as a crash on a rename-free filesystem would leave.
    CkptTorn { byte: u64 },
}

impl FaultKind {
    /// Canonical spec name.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Straggle { .. } => "straggle",
            FaultKind::PanicWorker => "panic",
            FaultKind::Abort => "abort",
            FaultKind::NetDelay { .. } => "net-delay",
            FaultKind::NetStall { .. } => "net-stall",
            FaultKind::NetDrop => "net-drop",
            FaultKind::NetCorrupt => "net-corrupt",
            FaultKind::CkptTorn { .. } => "ckpt-torn",
        }
    }
}

/// A parsed, canonically ordered fault schedule.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    faults: Vec<Fault>,
}

impl FaultPlan {
    /// Parse a spec string (see the module docs for the grammar). Empty
    /// and whitespace-only specs parse to the empty plan; malformed
    /// entries are contextful errors naming the entry.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut faults = Vec::new();
        for raw in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
            faults.push(
                parse_entry(raw).with_context(|| format!("fault spec entry {raw:?}"))?,
            );
        }
        // canonical order: by coordinate, then kind name — to_spec()
        // re-emits this order, so parse/emit round-trips are stable
        faults.sort_by_key(|f| (f.epoch, f.step, f.rank, f.kind.name()));
        Ok(Self { faults })
    }

    /// Canonical re-emission: sorted entries, fixed parameter order.
    /// `parse(p.to_spec())` reproduces `p` exactly.
    pub fn to_spec(&self) -> String {
        self.faults.iter().map(entry_spec).collect::<Vec<_>>().join(";")
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }
}

fn parse_entry(s: &str) -> Result<Fault> {
    let (head, params) = match s.split_once(':') {
        Some((h, p)) => (h, p),
        None => (s, ""),
    };
    let Some((name, at)) = head.split_once('@') else {
        bail!("expected kind@epoch.step.rank, got no '@'");
    };
    let coords: Vec<&str> = at.split('.').collect();
    ensure!(
        coords.len() == 3,
        "coordinates must be epoch.step.rank (three '.'-separated integers), got {at:?}"
    );
    let coord = |i: usize, what: &str| -> Result<usize> {
        coords[i]
            .parse::<usize>()
            .map_err(|_| anyhow!("{what} coordinate {:?} is not an integer", coords[i]))
    };
    let (epoch, step, rank) = (coord(0, "epoch")?, coord(1, "step")?, coord(2, "rank")?);

    let mut kv: BTreeMap<&str, &str> = BTreeMap::new();
    for p in params.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let Some((k, v)) = p.split_once('=') else {
            bail!("parameter {p:?} is not key=value");
        };
        ensure!(kv.insert(k.trim(), v.trim()).is_none(), "duplicate parameter {:?}", k.trim());
    }
    let req_u64 = |kv: &BTreeMap<&str, &str>, key: &str| -> Result<u64> {
        kv.get(key)
            .with_context(|| format!("missing required parameter {key}=<integer>"))?
            .parse::<u64>()
            .map_err(|_| anyhow!("parameter {key} must be an integer"))
    };
    let only = |kv: &BTreeMap<&str, &str>, allowed: &[&str]| -> Result<()> {
        for k in kv.keys() {
            ensure!(allowed.contains(k), "unknown parameter {k:?} (allowed: {allowed:?})");
        }
        Ok(())
    };

    let kind = match name {
        "straggle" => {
            only(&kv, &["ms"])?;
            FaultKind::Straggle { ms: req_u64(&kv, "ms")? }
        }
        "panic" => {
            only(&kv, &[])?;
            FaultKind::PanicWorker
        }
        "abort" => {
            only(&kv, &[])?;
            FaultKind::Abort
        }
        "net-delay" => {
            only(&kv, &["ms"])?;
            FaultKind::NetDelay { ms: req_u64(&kv, "ms")? }
        }
        "net-stall" => {
            only(&kv, &["ms"])?;
            FaultKind::NetStall { ms: req_u64(&kv, "ms")? }
        }
        "net-drop" => {
            only(&kv, &[])?;
            FaultKind::NetDrop
        }
        "net-corrupt" => {
            only(&kv, &[])?;
            FaultKind::NetCorrupt
        }
        "ckpt-torn" => {
            only(&kv, &["byte"])?;
            FaultKind::CkptTorn { byte: req_u64(&kv, "byte")? }
        }
        other => bail!(
            "unknown fault kind {other:?} (expected straggle, panic, abort, net-delay, \
             net-stall, net-drop, net-corrupt or ckpt-torn)"
        ),
    };
    Ok(Fault { epoch, step, rank, kind })
}

fn entry_spec(f: &Fault) -> String {
    let head = format!("{}@{}.{}.{}", f.kind.name(), f.epoch, f.step, f.rank);
    match f.kind {
        FaultKind::Straggle { ms }
        | FaultKind::NetDelay { ms }
        | FaultKind::NetStall { ms } => format!("{head}:ms={ms}"),
        FaultKind::CkptTorn { byte } => format!("{head}:byte={byte}"),
        FaultKind::PanicWorker | FaultKind::Abort | FaultKind::NetDrop | FaultKind::NetCorrupt => {
            head
        }
    }
}

/// A compute-fault decision, resolved leader-side at submit time and
/// carried into the worker's job. The worker calls [`ComputeFault::fire`]
/// before running the job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ComputeFault {
    pub kind: ComputeFaultKind,
    pub epoch: usize,
    pub step: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeFaultKind {
    Straggle { ms: u64 },
    Panic,
    Abort,
}

impl ComputeFault {
    /// Execute the fault. `Straggle` sleeps and returns `Ok` (the job
    /// proceeds, bits unchanged); `Panic` panics (the engine's
    /// `catch_unwind` turns it into a loud epoch error); `Abort` returns
    /// a contextful error that fails the step through the normal drain
    /// path.
    pub fn fire(&self) -> Result<()> {
        match self.kind {
            ComputeFaultKind::Straggle { ms } => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                Ok(())
            }
            ComputeFaultKind::Panic => panic!(
                "fault injected: compute worker panic (epoch {}, step {})",
                self.epoch, self.step
            ),
            ComputeFaultKind::Abort => bail!(
                "fault injected: compute worker abort mid-step (epoch {}, step {})",
                self.epoch, self.step
            ),
        }
    }
}

/// A network-fault decision for one rank at the current position.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetFault {
    Delay { ms: u64 },
    Stall { ms: u64 },
    Drop,
    Corrupt,
}

/// The runtime half: a parsed plan plus the trainer's current
/// `(epoch, step)` position. The step pipeline advances the position;
/// injection sites query it. Held as `Option<Arc<FaultInjector>>`
/// everywhere, `None` unless `train.faults.plan` is non-empty — the
/// disabled hot path is one pointer check.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    epoch: AtomicUsize,
    step: AtomicUsize,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        Self { plan, epoch: AtomicUsize::new(0), step: AtomicUsize::new(0) }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Advance the trajectory clock. Called by the step pipeline at the
    /// top of every step, before any collective op of that step runs.
    pub fn set_position(&self, epoch: usize, step: usize) {
        self.epoch.store(epoch, Ordering::SeqCst);
        self.step.store(step, Ordering::SeqCst);
    }

    pub fn position(&self) -> (usize, usize) {
        (self.epoch.load(Ordering::SeqCst), self.step.load(Ordering::SeqCst))
    }

    /// Per-worker compute-fault decisions for one step, resolved on the
    /// leader before submit so workers never consult shared state. First
    /// matching entry per worker wins.
    pub fn step_faults(&self, epoch: usize, step: usize, workers: usize) -> Vec<Option<ComputeFault>> {
        (0..workers)
            .map(|w| {
                self.plan.faults.iter().find_map(|f| {
                    if f.epoch != epoch || f.step != step || f.rank != w {
                        return None;
                    }
                    let kind = match f.kind {
                        FaultKind::Straggle { ms } => ComputeFaultKind::Straggle { ms },
                        FaultKind::PanicWorker => ComputeFaultKind::Panic,
                        FaultKind::Abort => ComputeFaultKind::Abort,
                        _ => return None,
                    };
                    Some(ComputeFault { kind, epoch, step })
                })
            })
            .collect()
    }

    /// The network fault (if any) scheduled for `rank` at the current
    /// position. Queried by the TCP endpoint before driving an op.
    pub fn net_fault(&self, rank: usize) -> Option<NetFault> {
        let (epoch, step) = self.position();
        self.plan.faults.iter().find_map(|f| {
            if f.epoch != epoch || f.step != step || f.rank != rank {
                return None;
            }
            match f.kind {
                FaultKind::NetDelay { ms } => Some(NetFault::Delay { ms }),
                FaultKind::NetStall { ms } => Some(NetFault::Stall { ms }),
                FaultKind::NetDrop => Some(NetFault::Drop),
                FaultKind::NetCorrupt => Some(NetFault::Corrupt),
                _ => None,
            }
        })
    }

    /// The torn-write byte (if any) scheduled for the rolling checkpoint
    /// written once `epochs_completed` epochs have finished.
    pub fn ckpt_fault(&self, epochs_completed: usize) -> Option<u64> {
        self.plan.faults.iter().find_map(|f| match f.kind {
            FaultKind::CkptTorn { byte } if f.epoch == epochs_completed => Some(byte),
            _ => None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_canonical_reemission_round_trip() {
        // deliberately unsorted, ragged whitespace, trailing semicolon
        let spec = " net-stall@2.0.1:ms=5000; straggle@1.3.0:ms=7 ;;panic@1.0.1; \
                     ckpt-torn@4.0.0:byte=64;";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(plan.faults().len(), 4);
        let canon = plan.to_spec();
        assert_eq!(
            canon,
            "panic@1.0.1;straggle@1.3.0:ms=7;net-stall@2.0.1:ms=5000;ckpt-torn@4.0.0:byte=64"
        );
        // idempotent: parse(emit(p)) == p, emit(parse(emit(p))) == emit(p)
        let back = FaultPlan::parse(&canon).unwrap();
        assert_eq!(back, plan);
        assert_eq!(back.to_spec(), canon);
    }

    #[test]
    fn empty_and_whitespace_specs_parse_to_the_empty_plan() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ; ;; ").unwrap().is_empty());
        assert_eq!(FaultPlan::parse("").unwrap().to_spec(), "");
    }

    #[test]
    fn malformed_entries_are_contextful_errors() {
        for (spec, needle) in [
            ("nope@1.2.3", "unknown fault kind"),
            ("straggle@1.2", "epoch.step.rank"),
            ("straggle@1.2.x:ms=5", "rank coordinate"),
            ("straggle@1.2.3", "missing required parameter ms"),
            ("straggle@1.2.3:ms=abc", "must be an integer"),
            ("straggle@1.2.3:ms=5,ms=6", "duplicate parameter"),
            ("straggle@1.2.3:ms=5,color=red", "unknown parameter"),
            ("panic@1.2.3:ms=5", "unknown parameter"),
            ("ckpt-torn@4.0.0", "missing required parameter byte"),
            ("straggle 1.2.3", "no '@'"),
            ("net-delay@1.2.3:ms", "not key=value"),
        ] {
            let err = FaultPlan::parse(spec).unwrap_err();
            let text = format!("{err:#}");
            assert!(text.contains(needle), "{spec}: expected {needle:?} in {text}");
            assert!(text.contains("fault spec entry"), "{spec}: no entry context in {text}");
        }
    }

    #[test]
    fn step_faults_resolve_per_worker_at_the_exact_coordinate() {
        let inj = FaultInjector::new(
            FaultPlan::parse("straggle@1.2.0:ms=3;abort@1.2.1;panic@2.0.0").unwrap(),
        );
        // wrong epoch/step: nothing fires
        assert_eq!(inj.step_faults(0, 2, 2), vec![None, None]);
        assert_eq!(inj.step_faults(1, 1, 2), vec![None, None]);
        // exact coordinate: per-worker decisions
        let faults = inj.step_faults(1, 2, 2);
        assert_eq!(
            faults[0],
            Some(ComputeFault { kind: ComputeFaultKind::Straggle { ms: 3 }, epoch: 1, step: 2 })
        );
        assert_eq!(
            faults[1],
            Some(ComputeFault { kind: ComputeFaultKind::Abort, epoch: 1, step: 2 })
        );
        // net faults never leak into compute decisions
        let inj = FaultInjector::new(FaultPlan::parse("net-drop@1.2.0").unwrap());
        assert_eq!(inj.step_faults(1, 2, 1), vec![None]);
    }

    #[test]
    fn net_faults_follow_the_position_clock_and_the_rank() {
        let inj =
            FaultInjector::new(FaultPlan::parse("net-corrupt@1.0.1;net-delay@2.1.0:ms=4").unwrap());
        assert_eq!(inj.position(), (0, 0));
        assert_eq!(inj.net_fault(1), None, "clock at (0,0): nothing scheduled");
        inj.set_position(1, 0);
        assert_eq!(inj.net_fault(1), Some(NetFault::Corrupt));
        assert_eq!(inj.net_fault(0), None, "rank 0 has no entry at (1,0)");
        inj.set_position(2, 1);
        assert_eq!(inj.net_fault(0), Some(NetFault::Delay { ms: 4 }));
        // compute faults never leak into net decisions
        let inj = FaultInjector::new(FaultPlan::parse("abort@0.0.0").unwrap());
        assert_eq!(inj.net_fault(0), None);
    }

    #[test]
    fn ckpt_fault_keys_on_completed_epochs_only() {
        let inj = FaultInjector::new(FaultPlan::parse("ckpt-torn@4.0.0:byte=100").unwrap());
        assert_eq!(inj.ckpt_fault(3), None);
        assert_eq!(inj.ckpt_fault(4), Some(100));
        assert_eq!(inj.ckpt_fault(5), None);
    }

    #[test]
    fn abort_fires_a_contextful_error_and_straggle_is_ok() {
        let abort =
            ComputeFault { kind: ComputeFaultKind::Abort, epoch: 3, step: 1 };
        let err = abort.fire().unwrap_err().to_string();
        assert!(err.contains("fault injected"), "{err}");
        assert!(err.contains("epoch 3, step 1"), "{err}");
        let straggle =
            ComputeFault { kind: ComputeFaultKind::Straggle { ms: 1 }, epoch: 0, step: 0 };
        straggle.fire().unwrap();
    }
}
