//! An exhaustive-interleaving model checker for the crate's thread
//! protocols.
//!
//! The bucket-sync protocol (workers publish over a bounded queue, a
//! persistent accumulator reduces, the leader collects — see
//! `pipeline/reduce.rs`) is example-tested at fixed seeds, but a seed only
//! exercises the interleavings the OS scheduler happens to produce. This
//! module checks *every* interleaving of a small model: a protocol is
//! expressed as a [`Model`] — a deterministic state machine where each
//! thread's next action is a pure function of the state — and
//! [`explore`] walks the full reachable state space by depth-first
//! search over scheduler choices, deduplicating states so diamond-shaped
//! schedules don't explode. It reports the first deadlock (some thread
//! blocked, none runnable), invariant violation, or rejected terminal
//! state, together with the schedule (thread-id sequence) that reaches
//! it — a counterexample a test failure message can print.
//!
//! This is the same state-space-enumeration idea as
//! [loom](https://docs.rs/loom) (CDSChecker lineage), minus the memory
//! -ordering model: models here are sequentially consistent, which matches
//! the protocols under test — they communicate exclusively through
//! `mpsc` channels (acquire/release pairs on send/recv), never through
//! racing atomics. The trade buys a dependency-free checker the offline
//! build can actually run; `crate::sync` keeps the `cfg(loom)` hook open
//! for the real thing. Protocol models for the bucket pipeline live in
//! `rust/tests/loom_bucket.rs`.

use std::collections::HashSet;
use std::fmt;
use std::hash::Hash;

/// What one thread did when offered the CPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    /// The thread advanced; the state may have changed.
    Progress,
    /// The thread is waiting on another thread (full/empty channel, join).
    /// The callee must leave the state untouched.
    Blocked,
    /// The thread has exited. The callee must leave the state untouched.
    Done,
}

/// A protocol as a deterministic multi-threaded state machine.
///
/// `Clone + Eq + Hash` carry the exploration: states are cloned at each
/// branch point and deduplicated in a visited set. Keep models small —
/// the reachable space is exponential in threads × steps.
pub trait Model: Clone + Eq + Hash {
    /// Number of threads; thread ids are `0..threads()`, fixed for the
    /// model's lifetime.
    fn threads(&self) -> usize;

    /// Run thread `tid` until its next scheduling point. Must be
    /// deterministic, and must not mutate `self` when returning
    /// [`Step::Blocked`] / [`Step::Done`].
    fn step(&mut self, tid: usize) -> Step;

    /// Safety invariant, checked at every reachable state.
    fn check(&self) -> Result<(), String> {
        Ok(())
    }

    /// Terminal-state acceptance (all threads [`Step::Done`]), e.g. "the
    /// leader holds every bucket exactly once".
    fn accept(&self) -> Result<(), String> {
        Ok(())
    }
}

/// Exploration statistics for a passing run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Report {
    /// Distinct states visited.
    pub states: usize,
    /// Terminal states reached (all accepted).
    pub terminals: usize,
}

/// Why an exploration failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ViolationKind {
    /// Threads alive, none runnable.
    Deadlock,
    /// [`Model::check`] failed at a reachable state.
    Invariant,
    /// [`Model::accept`] rejected a terminal state.
    Accept,
    /// The visited-state cap was exceeded (model too large, or a
    /// state-component leak such as an unbounded counter).
    StateSpace,
}

/// A failed exploration: what went wrong plus the scheduler decisions
/// that reproduce it.
#[derive(Debug, Clone)]
pub struct Violation {
    pub kind: ViolationKind,
    pub message: String,
    /// Thread ids in execution order from the initial state to the bad
    /// state: a deterministic replay recipe.
    pub schedule: Vec<usize>,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let kind = match self.kind {
            ViolationKind::Deadlock => "deadlock",
            ViolationKind::Invariant => "invariant violation",
            ViolationKind::Accept => "terminal state rejected",
            ViolationKind::StateSpace => "state space exceeded",
        };
        write!(f, "{kind}: {} (schedule: {:?})", self.message, self.schedule)
    }
}

impl std::error::Error for Violation {}

/// [`explore_bounded`] with a cap generous enough for every protocol
/// model in this crate (they stay under ~100k states).
pub fn explore<M: Model>(init: M) -> Result<Report, Violation> {
    explore_bounded(init, 1_000_000)
}

/// Walk every interleaving of `init` by DFS over scheduler choices.
///
/// Returns the exploration stats, or the first violation found. States
/// are deduplicated, so a state reached by two schedules is expanded
/// once; the schedule reported for a violation is the first DFS path
/// that reaches it.
pub fn explore_bounded<M: Model>(init: M, max_states: usize) -> Result<Report, Violation> {
    let mut visited: HashSet<M> = HashSet::new();
    let mut schedule = Vec::new();
    let mut report = Report { states: 0, terminals: 0 };
    dfs(&init, &mut visited, &mut schedule, &mut report, max_states)?;
    Ok(report)
}

fn dfs<M: Model>(
    state: &M,
    visited: &mut HashSet<M>,
    schedule: &mut Vec<usize>,
    report: &mut Report,
    max_states: usize,
) -> Result<(), Violation> {
    if !visited.insert(state.clone()) {
        return Ok(());
    }
    if visited.len() > max_states {
        return Err(Violation {
            kind: ViolationKind::StateSpace,
            message: format!("more than {max_states} distinct states"),
            schedule: schedule.clone(),
        });
    }
    report.states = visited.len();
    if let Err(m) = state.check() {
        return Err(Violation {
            kind: ViolationKind::Invariant,
            message: m,
            schedule: schedule.clone(),
        });
    }
    let mut progressed = false;
    let mut done = 0;
    for tid in 0..state.threads() {
        let mut next = state.clone();
        match next.step(tid) {
            Step::Progress => {
                progressed = true;
                schedule.push(tid);
                dfs(&next, visited, schedule, report, max_states)?;
                schedule.pop();
            }
            Step::Blocked => {}
            Step::Done => done += 1,
        }
    }
    if progressed {
        return Ok(());
    }
    if done == state.threads() {
        report.terminals += 1;
        return state.accept().map_err(|m| Violation {
            kind: ViolationKind::Accept,
            message: m,
            schedule: schedule.clone(),
        });
    }
    Err(Violation {
        kind: ViolationKind::Deadlock,
        message: format!(
            "{} of {} threads blocked, none runnable",
            state.threads() - done,
            state.threads()
        ),
        schedule: schedule.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two threads, each incrementing a shared counter twice: every
    /// interleaving must terminate with the counter at 4.
    #[derive(Clone, PartialEq, Eq, Hash)]
    struct Counter {
        value: u8,
        left: [u8; 2],
    }

    impl Model for Counter {
        fn threads(&self) -> usize {
            2
        }

        fn step(&mut self, tid: usize) -> Step {
            if self.left[tid] == 0 {
                return Step::Done;
            }
            self.left[tid] -= 1;
            self.value += 1;
            Step::Progress
        }

        fn accept(&self) -> Result<(), String> {
            if self.value == 4 {
                Ok(())
            } else {
                Err(format!("counter ended at {}", self.value))
            }
        }
    }

    #[test]
    fn counter_terminates_at_four_in_every_interleaving() {
        let r = explore(Counter { value: 0, left: [2, 2] }).unwrap();
        assert!(r.states > 1);
        assert_eq!(r.terminals, 1, "dedup folds all schedules into one terminal");
    }

    /// Classic ABBA lock ordering: thread 0 takes lock A then B, thread 1
    /// takes B then A. Some interleaving must deadlock.
    #[derive(Clone, PartialEq, Eq, Hash)]
    struct Abba {
        // lock holder per lock: None = free
        locks: [Option<usize>; 2],
        // per-thread program counter: 0 = want first lock, 1 = want
        // second, 2 = done (locks released at exit for model brevity)
        pc: [u8; 2],
    }

    impl Model for Abba {
        fn threads(&self) -> usize {
            2
        }

        fn step(&mut self, tid: usize) -> Step {
            let order = if tid == 0 { [0, 1] } else { [1, 0] };
            match self.pc[tid] {
                0 | 1 => {
                    let want = order[self.pc[tid] as usize];
                    match self.locks[want] {
                        Some(holder) if holder != tid => Step::Blocked,
                        _ => {
                            self.locks[want] = Some(tid);
                            self.pc[tid] += 1;
                            if self.pc[tid] == 2 {
                                self.locks = [None, None];
                            }
                            Step::Progress
                        }
                    }
                }
                _ => Step::Done,
            }
        }
    }

    #[test]
    fn abba_lock_order_deadlock_is_found() {
        let v = explore(Abba { locks: [None, None], pc: [0, 0] }).unwrap_err();
        assert_eq!(v.kind, ViolationKind::Deadlock);
        assert!(!v.schedule.is_empty(), "counterexample schedule must replay");
    }

    /// An invariant violated mid-execution (not just at terminals) is
    /// caught at the first state that exhibits it.
    #[derive(Clone, PartialEq, Eq, Hash)]
    struct BadInvariant {
        value: u8,
    }

    impl Model for BadInvariant {
        fn threads(&self) -> usize {
            1
        }

        fn step(&mut self, _tid: usize) -> Step {
            if self.value >= 3 {
                return Step::Done;
            }
            self.value += 1;
            Step::Progress
        }

        fn check(&self) -> Result<(), String> {
            if self.value == 2 {
                Err("value reached 2".into())
            } else {
                Ok(())
            }
        }
    }

    #[test]
    fn mid_execution_invariant_violation_is_caught() {
        let v = explore(BadInvariant { value: 0 }).unwrap_err();
        assert_eq!(v.kind, ViolationKind::Invariant);
        assert_eq!(v.schedule, vec![0, 0], "flagged at the first bad state");
    }

    /// A bounded channel whose consumer may exit early: the producer
    /// blocks forever on the full queue. The checker must find that
    /// interleaving even though the happy path (consumer drains first)
    /// exists — exactly the bug class seed-based tests miss.
    #[derive(Clone, PartialEq, Eq, Hash)]
    struct EarlyExitConsumer {
        queued: u8,
        cap: u8,
        to_send: u8,
        // consumer pc: 0 = may recv once, 1 = exited (rx dropped is NOT
        // modeled: the producer keeps blocking, as with a leaked rx)
        consumer_done: bool,
    }

    impl Model for EarlyExitConsumer {
        fn threads(&self) -> usize {
            2
        }

        fn step(&mut self, tid: usize) -> Step {
            if tid == 0 {
                // producer
                if self.to_send == 0 {
                    return Step::Done;
                }
                if self.queued == self.cap {
                    return Step::Blocked;
                }
                self.queued += 1;
                self.to_send -= 1;
                Step::Progress
            } else {
                // consumer: takes at most one item, then leaves
                if self.consumer_done {
                    return Step::Done;
                }
                if self.queued == 0 {
                    return Step::Blocked;
                }
                self.queued -= 1;
                self.consumer_done = true;
                Step::Progress
            }
        }
    }

    #[test]
    fn early_exit_consumer_deadlock_is_found() {
        let v = explore(EarlyExitConsumer {
            queued: 0,
            cap: 1,
            to_send: 3,
            consumer_done: false,
        })
        .unwrap_err();
        assert_eq!(v.kind, ViolationKind::Deadlock);
    }

    #[test]
    fn state_cap_is_enforced() {
        let v = explore_bounded(Counter { value: 0, left: [2, 2] }, 2).unwrap_err();
        assert_eq!(v.kind, ViolationKind::StateSpace);
    }
}
