//! Per-epoch measurements + memory accounting.

/// One epoch's measurements (one CSV row in the figure harnesses).
#[derive(Debug, Clone)]
pub struct EpochStats {
    pub epoch: usize,
    /// Phase the epoch's steps ran in: "full" | "warmup" | "lora".
    pub phase: &'static str,
    pub train_loss: f64,
    pub train_acc: f64,
    /// NaN on epochs without evaluation.
    pub val_loss: f64,
    pub val_acc: f64,
    pub lr: f64,
    pub epoch_seconds: f64,
    /// Seconds inside PJRT execute summed over workers ("device time").
    pub execute_seconds: f64,
    pub images_per_sec: f64,
    pub trainable_params: usize,
    /// Semantic accelerator-memory model in bytes (see MemoryBreakdown).
    pub memory_model_bytes: usize,
    /// Optimizer state bytes a single worker holds. Equal to the full
    /// state without ZeRO; ~1/workers of it with `train.zero.enabled`.
    pub opt_state_bytes_per_worker: usize,
    /// Gradient buffer bytes a single worker holds after the reduce.
    /// Equal to the live buffers' full size except at ZeRO stage 2, where
    /// the terminal reduce-scatter leaves each worker ~1/workers of it.
    pub grad_bytes_per_worker: usize,
    pub grad_norm: f64,
}

/// Accelerator-memory accounting, mirroring what DDP training would hold
/// per rank. The paper's Fig. 7 memory claim comes from dropping the
/// frozen base's gradients + optimizer state; this model measures exactly
/// that, using *assigned* ranks for LoRA state (a rank-specialized
/// implementation's footprint — our CPU buffers over-allocate at r_max,
/// which is an implementation artifact, not the algorithm's cost).
#[derive(Debug, Clone, Copy)]
pub struct MemoryBreakdown {
    /// Base weights (always resident).
    pub base_param_bytes: usize,
    /// LoRA weights at r_max as actually allocated.
    pub lora_param_bytes: usize,
    /// Gradient buffer bytes *this rank* holds for the current phase.
    /// Without ZeRO-2 every rank keeps the full buffers; at stage 2 the
    /// reduce-scatter is terminal and this is the largest owned partition
    /// (~1/workers of `grad_total_bytes`, plus chunk rounding).
    pub grad_bytes: usize,
    /// Gradient buffer bytes summed over all partitions (the replicated
    /// footprint; equals `grad_bytes` when gradients are not sharded).
    pub grad_total_bytes: usize,
    /// Optimizer state bytes *this rank* holds. Without ZeRO every rank
    /// replicates the full state; with `train.zero.enabled` this is the
    /// largest shard (~1/workers of the total).
    pub optimizer_bytes: usize,
    /// Optimizer state bytes summed over all shards (the unsharded
    /// footprint; equals `optimizer_bytes` when state is not sharded).
    pub optimizer_total_bytes: usize,
    /// Trainable parameter count (assigned ranks).
    pub trainable_params: usize,
}

impl MemoryBreakdown {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        n_base: usize,
        n_lora: usize,
        trainable: usize,
        grad_bytes: usize,
        grad_total_bytes: usize,
        optimizer_bytes: usize,
        optimizer_total_bytes: usize,
    ) -> Self {
        Self {
            base_param_bytes: n_base * 4,
            lora_param_bytes: n_lora * 4,
            grad_bytes,
            grad_total_bytes,
            optimizer_bytes,
            optimizer_total_bytes,
            trainable_params: trainable,
        }
    }

    /// The paper-comparable per-rank total: weights + the grads and
    /// optimizer state *this rank* holds.
    pub fn model_bytes(&self) -> usize {
        self.base_param_bytes + self.lora_param_bytes + self.grad_bytes + self.optimizer_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lora_phase_is_smaller_than_full_phase() {
        let n = 1_000_000usize;
        // full: grads n*4, adam 8n
        let full = MemoryBreakdown::new(n, 0, n, n * 4, n * 4, n * 8, n * 8);
        // lora at 10%: grads 0.1n*4, adam 0.8n, lora weights 0.1n*4
        let nl = n / 10;
        let lora = MemoryBreakdown::new(n, nl, nl, nl * 4, nl * 4, nl * 8, nl * 8);
        assert!(lora.model_bytes() < full.model_bytes());
        let saving = 1.0 - lora.model_bytes() as f64 / full.model_bytes() as f64;
        // dropping grads+opt of 90% of params saves a large fraction
        assert!(saving > 0.5, "saving {saving}");
    }

    #[test]
    fn zero1_sharding_shrinks_per_rank_optimizer_memory() {
        let n = 1_000_000usize;
        let replicated = MemoryBreakdown::new(n, 0, n, n * 4, n * 4, n * 8, n * 8);
        // 4-way ZeRO-1: the rank holds its shard of the moments only;
        // gradients stay replicated
        let sharded = MemoryBreakdown::new(n, 0, n, n * 4, n * 4, n * 2, n * 8);
        assert_eq!(sharded.optimizer_total_bytes, replicated.optimizer_total_bytes);
        assert_eq!(sharded.grad_bytes, sharded.grad_total_bytes);
        assert!(sharded.model_bytes() < replicated.model_bytes());
    }

    #[test]
    fn zero2_sharding_shrinks_per_rank_gradient_memory_too() {
        let n = 1_000_000usize;
        let zero1 = MemoryBreakdown::new(n, 0, n, n * 4, n * 4, n * 2, n * 8);
        // 4-way ZeRO-2: grads per rank drop to ~1/4 of the total as well
        let zero2 = MemoryBreakdown::new(n, 0, n, n, n * 4, n * 2, n * 8);
        assert_eq!(zero2.grad_total_bytes, zero1.grad_total_bytes);
        assert_eq!(zero2.grad_bytes * 4, zero2.grad_total_bytes);
        assert!(zero2.model_bytes() < zero1.model_bytes());
    }
}
