//! Per-epoch measurements + memory accounting.

use anyhow::{bail, Result};

use crate::util::json::Json;

/// One epoch's measurements (one CSV row in the figure harnesses).
#[derive(Debug, Clone)]
pub struct EpochStats {
    pub epoch: usize,
    /// Phase the epoch's steps ran in: "full" | "warmup" | "lora".
    pub phase: &'static str,
    pub train_loss: f64,
    pub train_acc: f64,
    /// NaN on epochs without evaluation.
    pub val_loss: f64,
    pub val_acc: f64,
    pub lr: f64,
    pub epoch_seconds: f64,
    /// Seconds inside PJRT execute summed over workers ("device time").
    pub execute_seconds: f64,
    pub images_per_sec: f64,
    pub trainable_params: usize,
    /// Semantic accelerator-memory model in bytes (see MemoryBreakdown).
    pub memory_model_bytes: usize,
    /// Optimizer state bytes a single worker holds. Equal to the full
    /// state without ZeRO; ~1/workers of it from ZeRO stage 1 up.
    pub opt_state_bytes_per_worker: usize,
    /// Gradient buffer bytes a single worker holds after the reduce.
    /// Equal to the live buffers' full size except at ZeRO stage 2, where
    /// the terminal reduce-scatter leaves each worker ~1/workers of it.
    pub grad_bytes_per_worker: usize,
    pub grad_norm: f64,
    /// Wall seconds the leader spent blocked on gradient communication
    /// this epoch — waiting on unreduced buckets under bucketed sync, or
    /// inside the whole-buffer sync otherwise. Timing telemetry only:
    /// never part of any bitwise trajectory comparison.
    pub comm_wait_s: f64,
}

impl EpochStats {
    /// Serialize for the v3 checkpoint's trajectory block, so a resumed
    /// run's final summary covers the whole trajectory and the resume
    /// harness can compare restored epochs bitwise. Floats use the
    /// bit-exact encoding (`val_loss`/`val_acc` are NaN on epochs that
    /// skipped evaluation).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("epoch", Json::from_usize(self.epoch)),
            ("phase", Json::Str(self.phase.to_string())),
            ("train_loss", Json::from_f64_bits(self.train_loss)),
            ("train_acc", Json::from_f64_bits(self.train_acc)),
            ("val_loss", Json::from_f64_bits(self.val_loss)),
            ("val_acc", Json::from_f64_bits(self.val_acc)),
            ("lr", Json::from_f64_bits(self.lr)),
            ("epoch_seconds", Json::from_f64_bits(self.epoch_seconds)),
            ("execute_seconds", Json::from_f64_bits(self.execute_seconds)),
            ("images_per_sec", Json::from_f64_bits(self.images_per_sec)),
            ("trainable_params", Json::from_usize(self.trainable_params)),
            ("memory_model_bytes", Json::from_usize(self.memory_model_bytes)),
            (
                "opt_state_bytes_per_worker",
                Json::from_usize(self.opt_state_bytes_per_worker),
            ),
            ("grad_bytes_per_worker", Json::from_usize(self.grad_bytes_per_worker)),
            ("grad_norm", Json::from_f64_bits(self.grad_norm)),
            ("comm_wait_s", Json::from_f64_bits(self.comm_wait_s)),
        ])
    }

    /// Parse a value written by [`to_json`](Self::to_json).
    pub fn from_json(v: &Json) -> Result<Self> {
        let phase: &'static str = match v.req("phase")?.as_str()? {
            "full" => "full",
            "warmup" => "warmup",
            "lora" => "lora",
            other => bail!("unknown epoch phase label {other:?}"),
        };
        Ok(Self {
            epoch: v.req("epoch")?.as_usize()?,
            phase,
            train_loss: v.req("train_loss")?.as_f64_bits()?,
            train_acc: v.req("train_acc")?.as_f64_bits()?,
            val_loss: v.req("val_loss")?.as_f64_bits()?,
            val_acc: v.req("val_acc")?.as_f64_bits()?,
            lr: v.req("lr")?.as_f64_bits()?,
            epoch_seconds: v.req("epoch_seconds")?.as_f64_bits()?,
            execute_seconds: v.req("execute_seconds")?.as_f64_bits()?,
            images_per_sec: v.req("images_per_sec")?.as_f64_bits()?,
            trainable_params: v.req("trainable_params")?.as_usize()?,
            memory_model_bytes: v.req("memory_model_bytes")?.as_usize()?,
            opt_state_bytes_per_worker: v.req("opt_state_bytes_per_worker")?.as_usize()?,
            grad_bytes_per_worker: v.req("grad_bytes_per_worker")?.as_usize()?,
            grad_norm: v.req("grad_norm")?.as_f64_bits()?,
            // optional: checkpoints written before the comm/compute
            // telemetry existed load with a zero wait
            comm_wait_s: match v.get("comm_wait_s") {
                Some(x) => x.as_f64_bits()?,
                None => 0.0,
            },
        })
    }
}

/// Accelerator-memory accounting, mirroring what DDP training would hold
/// per rank. The paper's Fig. 7 memory claim comes from dropping the
/// frozen base's gradients + optimizer state; this model measures exactly
/// that, using *assigned* ranks for LoRA state (a rank-specialized
/// implementation's footprint — our CPU buffers over-allocate at r_max,
/// which is an implementation artifact, not the algorithm's cost).
#[derive(Debug, Clone, Copy)]
pub struct MemoryBreakdown {
    /// Base weights (always resident).
    pub base_param_bytes: usize,
    /// LoRA weights at r_max as actually allocated.
    pub lora_param_bytes: usize,
    /// Parameter bytes *this rank* holds persistently. Equal to
    /// `base_param_bytes + lora_param_bytes` except under ZeRO-3
    /// parameter sharding, where a rank owns only its contiguous
    /// partition of each space (~1/workers of the total, plus chunk
    /// rounding) and the gathered per-step working view is transient.
    pub param_bytes_per_rank: usize,
    /// Gradient buffer bytes *this rank* holds for the current phase.
    /// Without ZeRO-2 every rank keeps the full buffers; at stage 2 the
    /// reduce-scatter is terminal and this is the largest owned partition
    /// (~1/workers of `grad_total_bytes`, plus chunk rounding).
    pub grad_bytes: usize,
    /// Gradient buffer bytes summed over all partitions (the replicated
    /// footprint; equals `grad_bytes` when gradients are not sharded).
    pub grad_total_bytes: usize,
    /// Optimizer state bytes *this rank* holds. Without ZeRO every rank
    /// replicates the full state; from stage 1 up this is the largest
    /// shard (~1/workers of the total).
    pub optimizer_bytes: usize,
    /// Optimizer state bytes summed over all shards (the unsharded
    /// footprint; equals `optimizer_bytes` when state is not sharded).
    pub optimizer_total_bytes: usize,
    /// Trainable parameter count (assigned ranks).
    pub trainable_params: usize,
}

impl MemoryBreakdown {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        n_base: usize,
        n_lora: usize,
        trainable: usize,
        param_bytes_per_rank: usize,
        grad_bytes: usize,
        grad_total_bytes: usize,
        optimizer_bytes: usize,
        optimizer_total_bytes: usize,
    ) -> Self {
        Self {
            base_param_bytes: n_base * 4,
            lora_param_bytes: n_lora * 4,
            param_bytes_per_rank,
            grad_bytes,
            grad_total_bytes,
            optimizer_bytes,
            optimizer_total_bytes,
            trainable_params: trainable,
        }
    }

    /// The paper-comparable per-rank total: the weights, grads and
    /// optimizer state *this rank* holds. Identical to the replicated
    /// accounting except under ZeRO-3, where the weight term is the
    /// rank's owned partition.
    pub fn model_bytes(&self) -> usize {
        self.param_bytes_per_rank + self.grad_bytes + self.optimizer_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_stats_json_roundtrips_bitwise() {
        let s = EpochStats {
            epoch: 7,
            phase: "warmup",
            train_loss: 1.234567890123,
            train_acc: 0.5,
            val_loss: f64::NAN, // skipped-eval epoch
            val_acc: f64::NAN,
            lr: 1e-3,
            epoch_seconds: 2.25,
            execute_seconds: 1.75,
            images_per_sec: 1234.5,
            trainable_params: 19496,
            memory_model_bytes: 1 << 20,
            opt_state_bytes_per_worker: 4096,
            grad_bytes_per_worker: 2048,
            grad_norm: 0.75,
            comm_wait_s: 0.125,
        };
        let text = s.to_json().dump();
        let back = EpochStats::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.epoch, 7);
        assert_eq!(back.phase, "warmup");
        assert_eq!(back.train_loss.to_bits(), s.train_loss.to_bits());
        assert_eq!(back.val_loss.to_bits(), s.val_loss.to_bits(), "NaN must survive");
        assert_eq!(back.grad_norm.to_bits(), s.grad_norm.to_bits());
        assert_eq!(back.comm_wait_s.to_bits(), s.comm_wait_s.to_bits());
        assert_eq!(back.trainable_params, s.trainable_params);
        // checkpoints written before the comm telemetry existed still load
        let mut old = s.to_json();
        if let Json::Obj(m) = &mut old {
            m.remove("comm_wait_s");
        }
        let compat = EpochStats::from_json(&old).unwrap();
        assert_eq!(compat.comm_wait_s, 0.0, "missing field defaults to zero");
        // unknown labels rejected (the label becomes a &'static str)
        let mut j = s.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("phase".into(), Json::Str("thawed".into()));
        }
        assert!(EpochStats::from_json(&j).is_err());
    }

    #[test]
    fn lora_phase_is_smaller_than_full_phase() {
        let n = 1_000_000usize;
        // full: params n*4 per rank, grads n*4, adam 8n
        let full = MemoryBreakdown::new(n, 0, n, n * 4, n * 4, n * 4, n * 8, n * 8);
        // lora at 10%: grads 0.1n*4, adam 0.8n, lora weights 0.1n*4
        let nl = n / 10;
        let lora = MemoryBreakdown::new(n, nl, nl, (n + nl) * 4, nl * 4, nl * 4, nl * 8, nl * 8);
        assert!(lora.model_bytes() < full.model_bytes());
        let saving = 1.0 - lora.model_bytes() as f64 / full.model_bytes() as f64;
        // dropping grads+opt of 90% of params saves a large fraction
        assert!(saving > 0.5, "saving {saving}");
    }

    #[test]
    fn zero1_sharding_shrinks_per_rank_optimizer_memory() {
        let n = 1_000_000usize;
        let replicated = MemoryBreakdown::new(n, 0, n, n * 4, n * 4, n * 4, n * 8, n * 8);
        // 4-way ZeRO-1: the rank holds its shard of the moments only;
        // gradients and parameters stay replicated
        let sharded = MemoryBreakdown::new(n, 0, n, n * 4, n * 4, n * 4, n * 2, n * 8);
        assert_eq!(sharded.optimizer_total_bytes, replicated.optimizer_total_bytes);
        assert_eq!(sharded.grad_bytes, sharded.grad_total_bytes);
        assert_eq!(sharded.param_bytes_per_rank, n * 4, "params replicated at stage 1");
        assert!(sharded.model_bytes() < replicated.model_bytes());
    }

    #[test]
    fn zero2_sharding_shrinks_per_rank_gradient_memory_too() {
        let n = 1_000_000usize;
        let zero1 = MemoryBreakdown::new(n, 0, n, n * 4, n * 4, n * 4, n * 2, n * 8);
        // 4-way ZeRO-2: grads per rank drop to ~1/4 of the total as well
        let zero2 = MemoryBreakdown::new(n, 0, n, n * 4, n, n * 4, n * 2, n * 8);
        assert_eq!(zero2.grad_total_bytes, zero1.grad_total_bytes);
        assert_eq!(zero2.grad_bytes * 4, zero2.grad_total_bytes);
        assert!(zero2.model_bytes() < zero1.model_bytes());
    }

    #[test]
    fn zero3_sharding_shrinks_per_rank_parameter_memory_too() {
        let n = 1_000_000usize;
        let zero2 = MemoryBreakdown::new(n, 0, n, n * 4, n, n * 4, n * 2, n * 8);
        // 4-way ZeRO-3: the rank's persistent weights are its owned
        // partition — every per-rank term is now ~1/4 of its total
        let zero3 = MemoryBreakdown::new(n, 0, n, n, n, n * 4, n * 2, n * 8);
        assert_eq!(
            zero3.base_param_bytes + zero3.lora_param_bytes,
            zero2.base_param_bytes + zero2.lora_param_bytes,
            "total parameter footprint is layout-free"
        );
        assert_eq!(zero3.param_bytes_per_rank * 4, zero3.base_param_bytes);
        assert!(zero3.model_bytes() < zero2.model_bytes());
    }
}
