//! Training loop: wires the data pipeline, DP engine, optimizer and the
//! PreLoRA controller into epochs, and measures everything the paper's
//! evaluation section reports.
//!
//! The per-step mechanics live in `crate::pipeline`: `run_epoch` here only
//! picks the phase's [`StepMode`], hands the epoch to the
//! [`StepPipeline`], and applies the controller's decision at the epoch
//! barrier (where every in-flight step has drained — phase switches are
//! deterministic by construction).
//!
//! Everything distributed goes through the run's `dist::Strategy`: the
//! trainer builds it once from the configured stage and thereafter only
//! trait-dispatches — parking parameters into the strategy's storage
//! layout, routing phase switches through `Repartition` events,
//! gathering on checkpoint save and re-scattering on restore. The
//! trainer contains no layout branching of its own.

mod metrics;

pub use crate::checkpoint::{Checkpoint, TrajectoryState};
pub use metrics::{EpochStats, MemoryBreakdown};

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::config::RunConfig;
use crate::coordinator::{Decision, Phase, PreLoraController};
use crate::data::{Dataset, EpochLoader, SynthSpec};
use crate::dist::{self, ParamSpace, Repartition, Strategy};
use crate::dp::{Algorithm, GradEngine, StepMode};
use crate::manifest::Manifest;
use crate::optim::LrSchedule;
use crate::pipeline::{ModelState, StepPipeline, UpdateStage};
use crate::rank::{build_adapter_cfg, AdapterCfg};
use crate::report::RunSummary;
use crate::telemetry::{NormHistory, NormSnapshot};
use crate::tensor::Pcg64;

/// A fully wired training run.
pub struct Trainer {
    pub cfg: RunConfig,
    pub manifest: Arc<Manifest>,
    engine: GradEngine,
    loader: EpochLoader,
    strategy: Arc<dyn Strategy>,
    pipeline: StepPipeline,
    update: UpdateStage,
    train_spec: SynthSpec,
    train_data: Arc<Dataset>,
    val_data: Arc<Dataset>,
    lr: LrSchedule,
    controller: PreLoraController,
    history: NormHistory,
    model: ModelState,
    /// Deterministic fault injection (`train.faults.plan`): `None` outside
    /// adversity testing. The pipeline drives its (epoch, step) clock; the
    /// trainer only consults it for scheduled checkpoint tearing.
    faults: Option<Arc<crate::faults::FaultInjector>>,
    /// Epoch a v3 checkpoint was restored at, if this run resumed one
    /// (surfaces as the summary's provenance note).
    resumed_from: Option<usize>,

    pub stats: Vec<EpochStats>,
}

impl Trainer {
    pub fn new(cfg: RunConfig) -> Result<Self> {
        cfg.validate()?;
        let manifest = Arc::new(Manifest::load(cfg.model_dir())?);
        let c = &manifest.config;
        let algorithm: Algorithm = cfg
            .train
            .dp
            .allreduce
            .parse()
            .map_err(|e: String| anyhow!(e))?;
        // the data-parallel world: the tcp peer list when this process is
        // one rank of a multi-process group, the simulated worker count
        // otherwise. The loader and the strategy are always sized to the
        // world, so batch order and shard layout are transport-invariant.
        let world = cfg.train.world();
        // fault injection (adversity testing): one injector shared by the
        // pipeline (compute faults + the step clock), the endpoint (wire
        // faults) and the checkpoint path (torn writes). None when
        // train.faults is absent — the default hot path is untouched.
        let faults = cfg.train.faults.injector()?;
        let endpoint = if cfg.train.dist.is_tcp() && world > 1 {
            Some(dist::TcpEndpoint::connect_with_faults(
                algorithm,
                cfg.train.dist.rank,
                &cfg.train.dist.peers,
                std::time::Duration::from_millis(cfg.train.dist.connect_timeout_ms),
                faults.clone(),
            )?)
        } else {
            None
        };
        // a tcp rank computes exactly one shard locally; the local mode
        // simulates every rank in-process
        let local_workers = if endpoint.is_some() { 1 } else { world };
        let engine =
            GradEngine::new(manifest.clone(), local_workers, cfg.train.dp.threaded, algorithm)?;
        // one strategy for the whole run, built over the same summation
        // schedule the engine reduces with (same collective => the
        // bit-equivalence contract holds across every layout). The tcp
        // endpoint adapts onto the same Collective seam, running the
        // identical schedule at the group's root.
        let collective: Arc<dyn dist::Collective> = match &endpoint {
            Some(ep) => Arc::new(dist::EndpointCollective::new(ep.clone())),
            None => dist::collective_for(algorithm),
        };
        let strategy = dist::strategy_for(cfg.train.zero.effective_stage(), world, collective);
        let mut pipeline = StepPipeline::new(&cfg.train.pipeline, strategy.clone())?;
        pipeline.set_faults(faults.clone());
        let update = UpdateStage::new(cfg.train.grad_clip);
        let loader = EpochLoader::new(c.batch_size, world, cfg.seed);
        let train_spec = SynthSpec {
            samples: cfg.train.data.train_samples,
            image_size: c.image_size,
            channels: c.in_channels,
            num_classes: c.num_classes,
            noise: cfg.train.data.noise,
            phase_jitter: cfg.train.data.phase_jitter,
            seed: cfg.seed ^ 0xda7a_5eed_u64,
        };
        let train_data = Arc::new(Dataset::generate(&train_spec));
        let val_data = Arc::new(Dataset::generate(&SynthSpec {
            samples: cfg.train.data.val_samples,
            image_size: c.image_size,
            channels: c.in_channels,
            num_classes: c.num_classes,
            noise: cfg.train.data.noise,
            phase_jitter: cfg.train.data.phase_jitter,
            seed: cfg.seed ^ 0x7a1_5eed_u64,
        }));
        let base = manifest.load_init_base()?;
        let opt_base = strategy.optimizer(&cfg.train, base.len());
        let model = ModelState::new(strategy.park_params(base), opt_base);
        let lr = LrSchedule::new(&cfg.train);
        let controller = PreLoraController::new(cfg.prelora.clone(), &manifest)?;
        Ok(Self {
            cfg,
            manifest,
            engine,
            loader,
            strategy,
            pipeline,
            update,
            train_spec,
            train_data,
            val_data,
            lr,
            controller,
            history: NormHistory::new(),
            model,
            faults,
            resumed_from: None,
            stats: Vec::new(),
        })
    }

    pub fn phase(&self) -> Phase {
        self.controller.phase()
    }

    pub fn controller(&self) -> &PreLoraController {
        &self.controller
    }

    pub fn history(&self) -> &NormHistory {
        &self.history
    }

    /// The run's distributed strategy (telemetry/inspection).
    pub fn strategy(&self) -> &dyn Strategy {
        &*self.strategy
    }

    /// The full base-parameter vector, gathered from the strategy's
    /// storage layout (a copy; telemetry and test convenience).
    pub fn base_params(&self) -> Vec<f32> {
        self.model.base.to_full()
    }

    pub fn adapter_cfg(&self) -> Option<&AdapterCfg> {
        self.model.adapter_cfg.as_ref()
    }

    /// Mean Frobenius norm of one module's LoRA adapters across layers
    /// (per-layer norm of the stacked [A; B] pair) — the Fig. 6b series.
    /// None before the switch.
    pub fn lora_module_norm(&self, module: &str) -> Option<f64> {
        let store = self.model.lora.as_ref()?;
        let lora = store.full();
        let mut acc = 0.0;
        let mut n = 0usize;
        for ad in self.manifest.adapters.iter().filter(|a| a.module == module) {
            let a2 = crate::tensor::sq_norm(&lora[ad.a_offset..ad.a_offset + ad.a_size]);
            let b2 = crate::tensor::sq_norm(&lora[ad.b_offset..ad.b_offset + ad.b_size]);
            acc += (a2 + b2).sqrt();
            n += 1;
        }
        if n == 0 {
            None
        } else {
            Some(acc / n as f64)
        }
    }

    /// Trainable parameters in the current phase (the paper's 300M -> 30M
    /// headline number).
    pub fn trainable_params(&self) -> usize {
        match self.controller.phase() {
            Phase::FullParam => self.manifest.full_trainable(),
            Phase::Warmup { .. } => {
                self.manifest.full_trainable()
                    + self.model.adapter_cfg.as_ref().map_or(0, |a| a.trainable_params)
            }
            Phase::LoraOnly { .. } => {
                self.model.adapter_cfg.as_ref().map_or(0, |a| a.trainable_params)
            }
        }
    }

    /// Current memory accounting (see `MemoryBreakdown` docs). Parameter,
    /// gradient *and* optimizer bytes are per-rank quantities under the
    /// run's strategy: a rank holds its shard of the moments, its owned
    /// gradient partition once the reduce-scatter is terminal, and — when
    /// the parameters themselves are sharded — its owned parameter
    /// partition (the gathered per-step working view is transient and
    /// deliberately not counted).
    pub fn memory(&self) -> MemoryBreakdown {
        let n_base = self.manifest.base.size;
        let n_lora = self.manifest.lora.size;
        let trainable = self.trainable_params();
        let st = self.strategy.state_bytes(&self.model);
        // manifest-level parameter accounting (allocation-independent,
        // like base_param_bytes/lora_param_bytes): the largest owned
        // partition of each space under the strategy's parameter plan
        let param_bytes_per_rank = self
            .strategy
            .plan(&ParamSpace::new("base", n_base))
            .param_bytes_per_rank()
            + self
                .strategy
                .plan(&ParamSpace::new("lora", n_lora))
                .param_bytes_per_rank();
        let (base_live, lora_live) = match self.controller.phase() {
            Phase::FullParam => (n_base, 0),
            Phase::Warmup { .. } => (n_base, n_lora),
            Phase::LoraOnly { .. } => (0, n_lora),
        };
        let grad_total_bytes = (base_live + lora_live) * 4;
        // per-rank: the largest partition() chunk of each live buffer,
        // which is ceil(len / parts) for non-empty buffers
        let parts = self.strategy.grad_parts().max(1);
        let grad_bytes = (base_live.div_ceil(parts) + lora_live.div_ceil(parts)) * 4;
        MemoryBreakdown::new(
            n_base,
            n_lora,
            trainable,
            param_bytes_per_rank,
            grad_bytes,
            grad_total_bytes,
            st.opt_bytes_per_rank,
            st.opt_total_bytes,
        )
    }

    /// Run one epoch: steps (through the pipeline), telemetry, controller
    /// decision, optional eval.
    pub fn run_epoch(&mut self) -> Result<EpochStats> {
        let epoch = self.history.epochs();
        if self.cfg.train.data.fresh_per_epoch {
            // infinite-data regime (see DataConfig::fresh_per_epoch)
            self.train_data = Arc::new(Dataset::generate(&self.train_spec.fresh_epoch(epoch)));
        }
        let t0 = std::time::Instant::now();
        let steps = self.loader.steps_per_epoch(&self.train_data);
        anyhow::ensure!(steps > 0, "dataset too small for one global step");
        let lr = self.lr.lr_at(epoch) as f32;
        let mode = match self.controller.phase() {
            Phase::FullParam => StepMode::Full,
            Phase::Warmup { .. } => StepMode::Warmup,
            Phase::LoraOnly { .. } => StepMode::LoraOnly,
        };
        let run = self.pipeline.run_epoch(
            &mut self.engine,
            &self.loader,
            &self.train_data,
            &mut self.model,
            &self.update,
            mode,
            epoch,
            steps,
            lr,
        )?;
        let epoch_seconds = t0.elapsed().as_secs_f64();
        let train_loss = run.loss_sum / steps as f64;
        let train_acc = run.correct / run.samples as f64;

        // telemetry + controller (the epoch boundary is the pipeline's
        // phase-switch barrier: every step above has drained)
        let snapshot = NormSnapshot::measure(&self.manifest, epoch, &self.model.base.full());
        self.history.push(snapshot, train_loss);
        let decision = self.controller.on_epoch_end(&self.history);
        self.apply(decision)?;

        // validation
        let (val_loss, val_acc) = if (epoch + 1) % self.cfg.train.eval_every == 0 {
            self.evaluate()?
        } else {
            (f64::NAN, f64::NAN)
        };

        let mem = self.memory();
        let stats = EpochStats {
            epoch,
            phase: self.history_phase_label(epoch),
            train_loss,
            train_acc,
            val_loss,
            val_acc,
            lr: lr as f64,
            epoch_seconds,
            execute_seconds: run.execute_seconds,
            images_per_sec: run.samples as f64 / epoch_seconds,
            trainable_params: self.trainable_params(),
            memory_model_bytes: mem.model_bytes(),
            opt_state_bytes_per_worker: mem.optimizer_bytes,
            grad_bytes_per_worker: mem.grad_bytes,
            grad_norm: run.grad_norms.mean(),
            comm_wait_s: run.comm_wait_s,
        };
        self.stats.push(stats.clone());
        Ok(stats)
    }

    /// Phase label for an epoch that just ran (decisions apply *after* the
    /// epoch's steps, so the label reflects the mode the steps used).
    fn history_phase_label(&self, epoch: usize) -> &'static str {
        match (self.controller.switch_epoch(), self.controller.freeze_epoch()) {
            (Some(s), _) if epoch < s => "full",
            (Some(_), Some(f)) if epoch >= f => "lora",
            (Some(_), _) => "warmup",
            (None, _) => "full",
        }
    }

    /// Evaluate on the validation split.
    pub fn evaluate(&mut self) -> Result<(f64, f64)> {
        // the engine needs the full working views (a gather under
        // parameter sharding; free otherwise)
        self.strategy.materialize_params(&mut self.model);
        let batches = self.loader.eval_batches(&self.val_data);
        let (loss, acc, _) =
            self.engine
                .evaluate(self.model.base_view(), self.model.lora_pair(), batches)?;
        // evaluation is done with the gathered views — drop them (under
        // parameter sharding they are per-use transients, not state)
        self.model.drop_views();
        Ok((loss, acc))
    }

    fn apply(&mut self, decision: Decision) -> Result<()> {
        match decision {
            Decision::Stay => {}
            Decision::SwitchToWarmup { assignment, report } => {
                // compile the warmup/lora artifacts now, outside epoch timing
                self.engine
                    .precompile(&["warmup_grads", "lora_grads", "eval_lora"])?;
                let acfg = build_adapter_cfg(
                    &self.manifest,
                    &assignment,
                    self.manifest.config.lora_alpha,
                )?;
                // LoRA init: A ~ N(0, 0.02), B = 0 => adapters start inert
                let mut lora = vec![0.0f32; self.manifest.lora.size];
                let mut rng = Pcg64::new(self.cfg.seed ^ 0x10ca_c0de);
                for t in &self.manifest.lora.tensors {
                    if t.module == "lora_a" {
                        rng.fill_normal(&mut lora[t.offset..t.offset + t.size], 0.02);
                    }
                }
                eprintln!(
                    "[prelora] epoch {}: convergence passed (max dW {:.3}%, max dL {:.3}%) -> warmup; ranks {:?}",
                    self.history.epochs(),
                    report.max_weight_delta,
                    report.max_loss_delta,
                    assignment.histogram()
                );
                // the adapter space enters training as a first-class
                // re-partition event: the strategy parks the fresh vector
                // in its own layout and builds the (sharded) optimizer —
                // layouts re-derive per space length, so the (much
                // smaller) adapter vector re-partitions automatically
                self.strategy.repartition(
                    &mut self.model,
                    Repartition::AdaptersInit { lora, adapter_cfg: acfg },
                    &self.cfg.train,
                );
            }
            Decision::FreezeBase => {
                self.strategy
                    .repartition(&mut self.model, Repartition::FreezeBase, &self.cfg.train);
                eprintln!(
                    "[prelora] epoch {}: warmup done -> base frozen, LoRA-only ({} trainable params, {:.1}% of full)",
                    self.history.epochs(),
                    self.trainable_params(),
                    100.0 * self.trainable_params() as f64 / self.manifest.full_trainable() as f64
                );
            }
        }
        Ok(())
    }

    /// Run up to the configured number of epochs and summarize. Counts
    /// from the epochs already completed — a freshly built trainer runs
    /// all of them, a restored one continues mid-trajectory from the
    /// checkpoint's epoch cursor. With `train.checkpoint_every > 0`, a
    /// checkpoint is (atomically) saved to [`checkpoint_path`] at that
    /// interval, so a preempted run resumes via `prelora train --resume`.
    ///
    /// [`checkpoint_path`]: Self::checkpoint_path
    pub fn run(&mut self) -> Result<RunSummary> {
        while self.history.epochs() < self.cfg.train.epochs {
            let s = self.run_epoch()?;
            eprintln!(
                "[{}] epoch {:>3} [{}] loss {:.4} acc {:.3} val_loss {:.4} val_acc {:.3} {:.2}s {:.0} img/s",
                self.cfg.run_name,
                s.epoch,
                s.phase,
                s.train_loss,
                s.train_acc,
                s.val_loss,
                s.val_acc,
                s.epoch_seconds,
                s.images_per_sec,
            );
            let every = self.cfg.train.checkpoint_every;
            if every > 0 && self.history.epochs() % every == 0 && self.is_primary() {
                let path = self.checkpoint_path();
                // scheduled tearing (ckpt-torn@<epochs_completed>.0.0):
                // models a crash that left a truncated file on disk —
                // written through save_torn so the cut is exact and the
                // next load fails loudly, never silently
                match self.faults.as_ref().and_then(|i| i.ckpt_fault(self.history.epochs())) {
                    Some(byte) => self.checkpoint().save_torn(&path, byte)?,
                    None => self.checkpoint().save(&path)?,
                }
                eprintln!(
                    "[{}] checkpoint saved to {} (epoch {})",
                    self.cfg.run_name,
                    path.display(),
                    self.history.epochs()
                );
            }
        }
        Ok(self.summary())
    }

    /// Whether this process owns the run's file outputs: rank 0 of a tcp
    /// group, or the only process of a local run. Every rank holds the
    /// full (bitwise-identical) model state, so any one of them could
    /// write the checkpoint — rank 0 does, and the rest skip it rather
    /// than race on the same path.
    pub fn is_primary(&self) -> bool {
        !self.cfg.train.dist.is_tcp() || self.cfg.train.dist.rank == 0
    }

    /// Where periodic checkpoints land: `<results_dir>/<run_name>.ckpt`.
    /// One rolling file — the atomic save makes overwriting safe.
    pub fn checkpoint_path(&self) -> std::path::PathBuf {
        std::path::Path::new(&self.cfg.results_dir).join(format!("{}.ckpt", self.cfg.run_name))
    }

    pub fn summary(&self) -> RunSummary {
        let mut s = RunSummary::from_stats(
            &self.cfg,
            &self.manifest,
            &self.stats,
            self.controller.switch_epoch(),
            self.controller.freeze_epoch(),
            self.model.adapter_cfg.as_ref(),
        );
        s.resumed_from = self.resumed_from;
        s
    }

    /// Save current model state. The payload is gathered through the
    /// strategy — full parameter vectors (a parameter-sharded run's owned
    /// partitions are all-gathered) and full-length optimizer state — so
    /// the file is shard-layout independent and restores onto any stage
    /// and worker count (the v3 contract). The trajectory block carries
    /// the phase machine (controller cursors + convergence evidence), the
    /// full norm/loss history, the LR-schedule position and the
    /// data-order seed — everything `restore` needs to make the resumed
    /// run a true bitwise continuation.
    pub fn checkpoint(&self) -> Checkpoint {
        Checkpoint {
            epoch: self.history.epochs(),
            base: self.strategy.export_params(&self.model.base),
            lora: self.model.lora.as_ref().map(|l| self.strategy.export_params(l)),
            adapter_cfg: self.model.adapter_cfg.as_ref().map(|a| a.values.clone()),
            ranks: self.model.adapter_cfg.as_ref().map(|a| a.ranks.clone()),
            opt_base: self.model.opt_base.as_ref().map(|o| o.export_state()),
            opt_lora: self.model.opt_lora.as_ref().map(|o| o.export_state()),
            zero_shards: self.strategy.opt_shards(),
            stage: self.strategy.stage(),
            trajectory: Some(TrajectoryState {
                seed: self.cfg.seed,
                phase: self.controller.phase(),
                switch_epoch: self.controller.switch_epoch(),
                freeze_epoch: self.controller.freeze_epoch(),
                lr_schedule: self.cfg.train.lr_schedule.as_str().to_string(),
                lr_epochs_total: self.cfg.train.epochs,
                checks: self.controller.checks.clone(),
                snapshots: self.history.snapshots().to_vec(),
                losses: self.history.losses().to_vec(),
                stats: self.stats.clone(),
            }),
        }
    }

    /// Restore model state — base, LoRA params *and* the adapter config
    /// that makes them meaningful. The gathered payload is scattered back
    /// through *this* run's strategy: parameters park into its storage
    /// layout and checkpointed optimizer state re-partitions onto its
    /// shard layout — the saving run's stage and worker count are
    /// irrelevant, so a single-worker trainer restores an N-way sharded
    /// run unchanged (and a parameter-sharded trainer restores an
    /// unsharded file).
    ///
    /// A v3 checkpoint additionally carries the trajectory block; this
    /// rebuilds the phase machine (controller cursors + convergence
    /// evidence), the norm/loss history, the per-epoch stats and the
    /// LR-schedule position, making the resumed run a *true mid-run
    /// continuation*: for a fixed seed, resuming is bitwise-identical to
    /// never having stopped (asserted by `rust/tests/resume.rs`). v1/v2
    /// checkpoints keep the old eval/analysis semantics — parameters and
    /// optimizer state load, phase detection replays from scratch.
    pub fn restore(&mut self, ckpt: &Checkpoint) -> Result<()> {
        anyhow::ensure!(
            ckpt.base.len() == self.model.base.len(),
            "checkpoint base size {} != model {}",
            ckpt.base.len(),
            self.model.base.len()
        );
        // validate the trajectory against this run's config *before* any
        // mutation: a half-restored trainer must not be reachable through
        // a config mismatch
        if let Some(tr) = &ckpt.trajectory {
            anyhow::ensure!(
                tr.seed == self.cfg.seed,
                "checkpoint was trained with seed {} but this run uses {} — every RNG stream \
                 (epoch shuffles, dataset, LoRA init) keys off the seed, so the trajectories \
                 would diverge; rerun with --seed {} (prelora train --resume adopts it \
                 automatically)",
                tr.seed,
                self.cfg.seed,
                tr.seed
            );
            anyhow::ensure!(
                tr.lr_schedule == self.cfg.train.lr_schedule.as_str(),
                "checkpoint used LR schedule {:?} but this run is configured for {:?}",
                tr.lr_schedule,
                self.cfg.train.lr_schedule.as_str()
            );
            anyhow::ensure!(
                tr.lr_epochs_total == self.cfg.train.epochs,
                "checkpoint's LR schedule spans {} total epochs but this run is configured for \
                 {} — the warmup/decay shape is a function of the total, so resuming would \
                 change the schedule mid-run",
                tr.lr_epochs_total,
                self.cfg.train.epochs
            );
            // a disabled controller can never continue a warmup/freeze
            // schedule: its on_epoch_end is a constant Stay, so a
            // mid-warmup checkpoint would train base+LoRA forever —
            // silently neither the baseline nor the PreLoRA continuation
            anyhow::ensure!(
                self.cfg.prelora.enabled || tr.phase.is_full(),
                "checkpoint was saved mid-trajectory ({}) but this run's PreLoRA controller is \
                 disabled — the warmup/freeze schedule cannot continue; resume with `prelora \
                 train` (controller enabled) instead",
                tr.phase
            );
            // the phase must agree with the state the payload carries
            match tr.phase {
                Phase::FullParam => anyhow::ensure!(
                    ckpt.lora.is_none() && ckpt.opt_base.is_some(),
                    "full-param trajectory with inconsistent payload (lora present: {}, base \
                     optimizer present: {})",
                    ckpt.lora.is_some(),
                    ckpt.opt_base.is_some()
                ),
                Phase::Warmup { .. } => anyhow::ensure!(
                    ckpt.lora.is_some() && ckpt.opt_base.is_some() && ckpt.opt_lora.is_some(),
                    "warmup trajectory must carry LoRA params and both optimizer states"
                ),
                Phase::LoraOnly { .. } => anyhow::ensure!(
                    ckpt.lora.is_some() && ckpt.opt_base.is_none() && ckpt.opt_lora.is_some(),
                    "lora-only trajectory must carry LoRA params + LoRA optimizer state and no \
                     base optimizer state (the frozen base keeps none)"
                ),
            }
        }
        match (&ckpt.lora, &ckpt.adapter_cfg, &ckpt.ranks) {
            (None, None, None) => {
                self.strategy.import_params(&mut self.model.base, &ckpt.base)?;
                self.model.lora = None;
                self.model.adapter_cfg = None;
            }
            (Some(lora), Some(values), Some(ranks)) => {
                anyhow::ensure!(
                    lora.len() == self.manifest.lora.size,
                    "checkpoint lora size {} != manifest {}",
                    lora.len(),
                    self.manifest.lora.size
                );
                anyhow::ensure!(
                    values.len() == self.manifest.adapter_cfg_size,
                    "checkpoint adapter_cfg size {} != manifest {}",
                    values.len(),
                    self.manifest.adapter_cfg_size
                );
                anyhow::ensure!(
                    ranks.len() == self.manifest.adapters.len(),
                    "checkpoint rank layout ({} adapters) does not match manifest ({})",
                    ranks.len(),
                    self.manifest.adapters.len()
                );
                let r_max = self.manifest.config.r_max;
                anyhow::ensure!(
                    ranks.iter().all(|&r| (1..=r_max).contains(&r)),
                    "checkpoint rank outside [1, {r_max}]: {ranks:?}"
                );
                let trainable_params = self.manifest.lora_trainable(ranks);
                self.strategy.import_params(&mut self.model.base, &ckpt.base)?;
                self.model.lora = Some(self.strategy.park_params(lora.clone()));
                self.model.adapter_cfg = Some(AdapterCfg {
                    values: values.clone(),
                    ranks: ranks.clone(),
                    trainable_params,
                });
            }
            _ => bail!(
                "checkpoint has partial LoRA state (lora, adapter_cfg and ranks must all be present or all absent)"
            ),
        }
        // the phase machine, before the optimizers: a failure here leaves
        // the parameters restored but no optimizer replaced
        if let Some(tr) = &ckpt.trajectory {
            self.history = NormHistory::from_parts(tr.snapshots.clone(), tr.losses.clone())?;
            anyhow::ensure!(
                self.history.epochs() == ckpt.epoch,
                "trajectory history spans {} epochs but the checkpoint was saved at epoch {}",
                self.history.epochs(),
                ckpt.epoch
            );
            self.controller.restore_state(
                tr.phase,
                tr.switch_epoch,
                tr.freeze_epoch,
                tr.checks.clone(),
            )?;
            self.stats = tr.stats.clone();
            self.resumed_from = Some(ckpt.epoch);
            // compile the restored phase's artifacts now, like the live
            // switch does — outside epoch timing, and so a resumed
            // LoraOnly run never compiles the warmup artifact at all
            match tr.phase {
                Phase::FullParam => {}
                Phase::Warmup { .. } => {
                    self.engine.precompile(&["warmup_grads", "lora_grads", "eval_lora"])?;
                }
                Phase::LoraOnly { .. } => {
                    self.engine.precompile(&["lora_grads", "eval_lora"])?;
                }
            }
        }
        // optimizer state: rebuild on this run's strategy layout and
        // scatter the gathered buffers into it. With a trajectory,
        // absence is authoritative — a lora-only checkpoint restores to a
        // frozen base with *no* optimizer state. Without one (v1/v2),
        // absent state leaves the current optimizers untouched — the
        // pre-v2 eval/analysis semantics.
        if ckpt.trajectory.is_some() {
            self.model.opt_base = None;
            self.model.opt_lora = None;
        }
        if let Some(st) = &ckpt.opt_base {
            let mut opt = self.strategy.optimizer(&self.cfg.train, self.model.base.len());
            opt.import_state(st)
                .map_err(|e| anyhow!("restoring base optimizer state: {e}"))?;
            self.model.opt_base = Some(opt);
        }
        if let Some(st) = &ckpt.opt_lora {
            let lora_len = self
                .model
                .lora
                .as_ref()
                .map(|l| l.len())
                .ok_or_else(|| anyhow!("checkpoint has LoRA optimizer state but no LoRA params"))?;
            let mut opt = self.strategy.optimizer(&self.cfg.train, lora_len);
            opt.import_state(st)
                .map_err(|e| anyhow!("restoring lora optimizer state: {e}"))?;
            self.model.opt_lora = Some(opt);
        }
        Ok(())
    }
}
