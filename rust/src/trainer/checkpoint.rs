//! Checkpointing: flat vectors + a JSON header in one file.
//!
//! Format: one JSON header line (sizes, epoch, ranks) followed by the raw
//! little-endian f32 payloads in header order. Self-describing enough for
//! the analysis binaries and stable across runs.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub epoch: usize,
    pub base: Vec<f32>,
    pub lora: Option<Vec<f32>>,
    pub adapter_cfg: Option<Vec<f32>>,
    pub ranks: Option<Vec<usize>>,
}

struct Header {
    magic: String,
    epoch: usize,
    base_len: usize,
    lora_len: usize,
    adapter_cfg_len: usize,
    ranks: Option<Vec<usize>>,
}

impl Header {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("magic", Json::Str(self.magic.clone())),
            ("epoch", Json::from_usize(self.epoch)),
            ("base_len", Json::from_usize(self.base_len)),
            ("lora_len", Json::from_usize(self.lora_len)),
            ("adapter_cfg_len", Json::from_usize(self.adapter_cfg_len)),
            (
                "ranks",
                match &self.ranks {
                    Some(r) => Json::arr_usize(r),
                    None => Json::Null,
                },
            ),
        ])
    }

    fn from_json(v: &Json) -> Result<Self> {
        let ranks = match v.req("ranks")? {
            Json::Null => None,
            arr => Some(arr.as_arr()?.iter().map(|x| x.as_usize()).collect::<Result<_>>()?),
        };
        Ok(Self {
            magic: v.req("magic")?.as_str()?.to_string(),
            epoch: v.req("epoch")?.as_usize()?,
            base_len: v.req("base_len")?.as_usize()?,
            lora_len: v.req("lora_len")?.as_usize()?,
            adapter_cfg_len: v.req("adapter_cfg_len")?.as_usize()?,
            ranks,
        })
    }
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        let mut w = BufWriter::new(file);
        let header = Header {
            magic: "prelora-ckpt-v1".into(),
            epoch: self.epoch,
            base_len: self.base.len(),
            lora_len: self.lora.as_ref().map_or(0, |v| v.len()),
            adapter_cfg_len: self.adapter_cfg.as_ref().map_or(0, |v| v.len()),
            ranks: self.ranks.clone(),
        };
        w.write_all(header.to_json().dump().as_bytes())?;
        w.write_all(b"\n")?;
        write_f32s(&mut w, &self.base)?;
        if let Some(l) = &self.lora {
            write_f32s(&mut w, l)?;
        }
        if let Some(a) = &self.adapter_cfg {
            write_f32s(&mut w, a)?;
        }
        w.flush()?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let file = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let mut r = BufReader::new(file);
        let mut header_line = Vec::new();
        // read until newline
        let mut byte = [0u8; 1];
        loop {
            r.read_exact(&mut byte)?;
            if byte[0] == b'\n' {
                break;
            }
            header_line.push(byte[0]);
            ensure!(header_line.len() < 1 << 20, "header too large");
        }
        let header = Header::from_json(&Json::parse(std::str::from_utf8(&header_line)?)?)?;
        ensure!(header.magic == "prelora-ckpt-v1", "bad checkpoint magic");
        let base = read_f32s(&mut r, header.base_len)?;
        let lora = if header.lora_len > 0 {
            Some(read_f32s(&mut r, header.lora_len)?)
        } else {
            None
        };
        let adapter_cfg = if header.adapter_cfg_len > 0 {
            Some(read_f32s(&mut r, header.adapter_cfg_len)?)
        } else {
            None
        };
        Ok(Self { epoch: header.epoch, base, lora, adapter_cfg, ranks: header.ranks })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("prelora_{}_{}", std::process::id(), name))
    }

    #[test]
    fn roundtrip_full_phase() {
        let c = Checkpoint {
            epoch: 7,
            base: vec![1.0, -2.5, 3.25],
            lora: None,
            adapter_cfg: None,
            ranks: None,
        };
        let p = tmp("full.ckpt");
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.epoch, 7);
        assert_eq!(back.base, c.base);
        assert!(back.lora.is_none() && back.adapter_cfg.is_none());
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn roundtrip_lora_phase() {
        let c = Checkpoint {
            epoch: 42,
            base: vec![0.5; 10],
            lora: Some(vec![0.25; 6]),
            adapter_cfg: Some(vec![1.0, 0.0, 4.0]),
            ranks: Some(vec![2, 4]),
        };
        let p = tmp("lora.ckpt");
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.lora.unwrap(), vec![0.25; 6]);
        assert_eq!(back.adapter_cfg.unwrap(), vec![1.0, 0.0, 4.0]);
        assert_eq!(back.ranks.unwrap(), vec![2, 4]);
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage.ckpt");
        std::fs::write(&p, b"{\"magic\":\"nope\"}\n").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).unwrap();
    }
}
