//! Checkpointing: flat vectors + a JSON header in one file.
//!
//! Format (v2, see `docs/checkpoint-format.md`): one JSON header line
//! (sizes, epoch, ranks, optimizer-state descriptors, ZeRO shard/stage
//! metadata — see also `docs/zero.md`) followed by the raw little-endian
//! f32 payloads in header
//! order: base, lora, adapter_cfg, then each optimizer state buffer.
//! Optimizer state is always written *gathered* (full-length buffers,
//! shard-layout independent), so a checkpoint from an N-way ZeRO run
//! restores onto any worker count — including a single worker. v1 files
//! (no optimizer state) still load.
//!
//! Durability: `save` writes to a temp file in the destination directory
//! and atomically renames it into place, so a crash mid-write can never
//! leave a partially-written file under the checkpoint's name. `load`
//! rejects files whose payload is truncated *or* that carry trailing
//! bytes beyond what the header declares.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::config::OptimizerKind;
use crate::optim::OptState;
use crate::util::json::Json;

const MAGIC_V2: &str = "prelora-ckpt-v2";
const MAGIC_V1: &str = "prelora-ckpt-v1";

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub epoch: usize,
    pub base: Vec<f32>,
    pub lora: Option<Vec<f32>>,
    pub adapter_cfg: Option<Vec<f32>>,
    pub ranks: Option<Vec<usize>>,
    /// Gathered (full-length) base optimizer state, if the phase held one.
    pub opt_base: Option<OptState>,
    /// Gathered LoRA optimizer state, present after the switch.
    pub opt_lora: Option<OptState>,
    /// ZeRO shard count of the run that saved this checkpoint (1 =
    /// unsharded). Metadata only: the payload is always gathered, and a
    /// restore re-scatters onto the restoring run's own layout.
    pub zero_shards: usize,
    /// ZeRO stage of the saving run (1 = optimizer state sharded, 2 = +
    /// gradient buffers; 1 also for unsharded runs). Metadata only, like
    /// `zero_shards`: gradient shards are transient within a step and are
    /// never checkpointed, so the payload is stage-independent. Absent in
    /// files written before the stage knob existed — read as 1.
    pub zero_stage: u8,
}

struct Header {
    magic: String,
    epoch: usize,
    base_len: usize,
    lora_len: usize,
    adapter_cfg_len: usize,
    ranks: Option<Vec<usize>>,
    zero_shards: usize,
    zero_stage: u8,
    opt_base: Option<OptDescriptor>,
    opt_lora: Option<OptDescriptor>,
}

/// Header description of one serialized optimizer state: the payload
/// carries `bufs` buffers of the owning section's length.
struct OptDescriptor {
    kind: OptimizerKind,
    steps: u64,
    bufs: usize,
}

impl OptDescriptor {
    fn of(state: &OptState) -> Self {
        Self { kind: state.kind, steps: state.t, bufs: state.bufs.len() }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str(self.kind.as_str().to_string())),
            ("steps", Json::from_usize(self.steps as usize)),
            ("bufs", Json::from_usize(self.bufs)),
        ])
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            kind: v.req("kind")?.as_str()?.parse()?,
            steps: v.req("steps")?.as_usize()? as u64,
            bufs: v.req("bufs")?.as_usize()?,
        })
    }
}

impl Header {
    fn to_json(&self) -> Json {
        let opt = |d: &Option<OptDescriptor>| d.as_ref().map_or(Json::Null, |d| d.to_json());
        Json::obj(vec![
            ("magic", Json::Str(self.magic.clone())),
            ("epoch", Json::from_usize(self.epoch)),
            ("base_len", Json::from_usize(self.base_len)),
            ("lora_len", Json::from_usize(self.lora_len)),
            ("adapter_cfg_len", Json::from_usize(self.adapter_cfg_len)),
            (
                "ranks",
                match &self.ranks {
                    Some(r) => Json::arr_usize(r),
                    None => Json::Null,
                },
            ),
            ("zero_shards", Json::from_usize(self.zero_shards)),
            ("zero_stage", Json::from_usize(self.zero_stage as usize)),
            ("opt_base", opt(&self.opt_base)),
            ("opt_lora", opt(&self.opt_lora)),
        ])
    }

    fn from_json(v: &Json) -> Result<Self> {
        let ranks = match v.req("ranks")? {
            Json::Null => None,
            arr => Some(arr.as_arr()?.iter().map(|x| x.as_usize()).collect::<Result<_>>()?),
        };
        let magic = v.req("magic")?.as_str()?.to_string();
        // v1 headers have no optimizer/shard fields
        let opt = |key: &str| -> Result<Option<OptDescriptor>> {
            match v.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(d) => Ok(Some(OptDescriptor::from_json(d)?)),
            }
        };
        let zero_shards = match v.get("zero_shards") {
            None => 1,
            Some(x) => x.as_usize()?.max(1),
        };
        // absent in v1 files and in v2 files written before the stage
        // knob; those runs sharded at most the optimizer state
        let zero_stage = match v.get("zero_stage") {
            None => 1,
            Some(x) => x.as_usize()?.clamp(1, 2) as u8,
        };
        Ok(Self {
            magic,
            epoch: v.req("epoch")?.as_usize()?,
            base_len: v.req("base_len")?.as_usize()?,
            lora_len: v.req("lora_len")?.as_usize()?,
            adapter_cfg_len: v.req("adapter_cfg_len")?.as_usize()?,
            ranks,
            zero_shards,
            zero_stage,
            opt_base: opt("opt_base")?,
            opt_lora: opt("opt_lora")?,
        })
    }
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)
        .context("checkpoint payload truncated")?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_opt_state(
    r: &mut impl Read,
    desc: &Option<OptDescriptor>,
    len: usize,
) -> Result<Option<OptState>> {
    let Some(d) = desc else { return Ok(None) };
    let bufs = (0..d.bufs)
        .map(|_| read_f32s(r, len))
        .collect::<Result<Vec<_>>>()?;
    Ok(Some(OptState { kind: d.kind, t: d.steps, bufs }))
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(st) = &self.opt_base {
            ensure!(
                st.bufs.iter().all(|b| b.len() == self.base.len()),
                "opt_base state buffers must be base-length (gathered)"
            );
        }
        if let Some(st) = &self.opt_lora {
            let lora_len = self.lora.as_ref().map_or(0, |v| v.len());
            ensure!(
                st.bufs.iter().all(|b| b.len() == lora_len),
                "opt_lora state buffers must be lora-length (gathered)"
            );
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        // write-to-temp + atomic rename: a crash mid-write leaves only a
        // stale .tmp, never a corrupt file under the checkpoint's name
        let tmp = path.with_file_name(format!(
            "{}.{}.tmp",
            path.file_name().and_then(|n| n.to_str()).unwrap_or("ckpt"),
            std::process::id()
        ));
        let write = (|| -> Result<()> {
            let file = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            let mut w = BufWriter::new(file);
            let header = Header {
                magic: MAGIC_V2.into(),
                epoch: self.epoch,
                base_len: self.base.len(),
                lora_len: self.lora.as_ref().map_or(0, |v| v.len()),
                adapter_cfg_len: self.adapter_cfg.as_ref().map_or(0, |v| v.len()),
                ranks: self.ranks.clone(),
                zero_shards: self.zero_shards.max(1),
                zero_stage: self.zero_stage.clamp(1, 2),
                opt_base: self.opt_base.as_ref().map(OptDescriptor::of),
                opt_lora: self.opt_lora.as_ref().map(OptDescriptor::of),
            };
            w.write_all(header.to_json().dump().as_bytes())?;
            w.write_all(b"\n")?;
            write_f32s(&mut w, &self.base)?;
            if let Some(l) = &self.lora {
                write_f32s(&mut w, l)?;
            }
            if let Some(a) = &self.adapter_cfg {
                write_f32s(&mut w, a)?;
            }
            for st in [&self.opt_base, &self.opt_lora].into_iter().flatten() {
                for b in &st.bufs {
                    write_f32s(&mut w, b)?;
                }
            }
            // durability, not just process-crash safety: the data blocks
            // must be on disk before the rename is allowed to replace the
            // previous good checkpoint
            let file = w
                .into_inner()
                .map_err(|e| anyhow::anyhow!("flushing checkpoint: {e}"))?;
            file.sync_all().context("syncing checkpoint to disk")?;
            Ok(())
        })();
        if let Err(e) = write {
            let _ = std::fs::remove_file(&tmp);
            return Err(e);
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
        // make the rename itself durable (best-effort: directory fsync is
        // not supported on every platform)
        if let Some(dir) = path.parent() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let file = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let mut r = BufReader::new(file);
        let mut header_line = Vec::new();
        // read until newline
        let mut byte = [0u8; 1];
        loop {
            r.read_exact(&mut byte)?;
            if byte[0] == b'\n' {
                break;
            }
            header_line.push(byte[0]);
            ensure!(header_line.len() < 1 << 20, "header too large");
        }
        let header = Header::from_json(&Json::parse(std::str::from_utf8(&header_line)?)?)?;
        match header.magic.as_str() {
            MAGIC_V2 => {}
            MAGIC_V1 => {
                ensure!(
                    header.opt_base.is_none() && header.opt_lora.is_none(),
                    "v1 checkpoint cannot declare optimizer state"
                );
            }
            other => bail!("bad checkpoint magic {other:?}"),
        }
        let base = read_f32s(&mut r, header.base_len)?;
        let lora = if header.lora_len > 0 {
            Some(read_f32s(&mut r, header.lora_len)?)
        } else {
            None
        };
        let adapter_cfg = if header.adapter_cfg_len > 0 {
            Some(read_f32s(&mut r, header.adapter_cfg_len)?)
        } else {
            None
        };
        let opt_base = read_opt_state(&mut r, &header.opt_base, header.base_len)?;
        let opt_lora = read_opt_state(&mut r, &header.opt_lora, header.lora_len)?;
        // strict bounds: anything after the declared payload means the
        // file is not what the header says it is
        let mut probe = [0u8; 1];
        ensure!(
            r.read(&mut probe)? == 0,
            "checkpoint has trailing bytes beyond the header-declared payload"
        );
        Ok(Self {
            epoch: header.epoch,
            base,
            lora,
            adapter_cfg,
            ranks: header.ranks,
            opt_base,
            opt_lora,
            zero_shards: header.zero_shards,
            zero_stage: header.zero_stage,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("prelora_{}_{}", std::process::id(), name))
    }

    fn full_ckpt() -> Checkpoint {
        Checkpoint {
            epoch: 7,
            base: vec![1.0, -2.5, 3.25],
            lora: None,
            adapter_cfg: None,
            ranks: None,
            opt_base: None,
            opt_lora: None,
            zero_shards: 1,
            zero_stage: 1,
        }
    }

    #[test]
    fn roundtrip_full_phase() {
        let c = full_ckpt();
        let p = tmp("full.ckpt");
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.epoch, 7);
        assert_eq!(back.base, c.base);
        assert!(back.lora.is_none() && back.adapter_cfg.is_none());
        assert!(back.opt_base.is_none() && back.opt_lora.is_none());
        assert_eq!(back.zero_shards, 1);
        assert_eq!(back.zero_stage, 1);
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn roundtrip_lora_phase_with_optimizer_state() {
        let c = Checkpoint {
            epoch: 42,
            base: vec![0.5; 10],
            lora: Some(vec![0.25; 6]),
            adapter_cfg: Some(vec![1.0, 0.0, 4.0]),
            ranks: Some(vec![2, 4]),
            opt_base: Some(OptState {
                kind: OptimizerKind::AdamW,
                t: 9,
                bufs: vec![vec![0.1; 10], vec![0.2; 10]],
            }),
            opt_lora: Some(OptState {
                kind: OptimizerKind::AdamW,
                t: 3,
                bufs: vec![vec![0.3; 6], vec![0.4; 6]],
            }),
            zero_shards: 4,
            zero_stage: 2,
        };
        let p = tmp("lora.ckpt");
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.lora.unwrap(), vec![0.25; 6]);
        assert_eq!(back.adapter_cfg.unwrap(), vec![1.0, 0.0, 4.0]);
        assert_eq!(back.ranks.unwrap(), vec![2, 4]);
        assert_eq!(back.zero_shards, 4);
        assert_eq!(back.zero_stage, 2, "stage metadata must roundtrip");
        let ob = back.opt_base.unwrap();
        assert_eq!(ob.kind, OptimizerKind::AdamW);
        assert_eq!(ob.t, 9);
        assert_eq!(ob.bufs, vec![vec![0.1; 10], vec![0.2; 10]]);
        let ol = back.opt_lora.unwrap();
        assert_eq!(ol.t, 3);
        assert_eq!(ol.bufs[1], vec![0.4; 6]);
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage.ckpt");
        std::fs::write(&p, b"{\"magic\":\"nope\"}\n").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn loads_v1_checkpoints_without_optimizer_state() {
        // a file written by the v1 code: header without the v2 fields
        let p = tmp("v1.ckpt");
        let header = "{\"magic\":\"prelora-ckpt-v1\",\"epoch\":3,\"base_len\":2,\
                      \"lora_len\":0,\"adapter_cfg_len\":0,\"ranks\":null}";
        let mut bytes = Vec::new();
        bytes.extend_from_slice(header.as_bytes());
        bytes.push(b'\n');
        for x in [1.5f32, -2.0] {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(&p, &bytes).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.epoch, 3);
        assert_eq!(back.base, vec![1.5, -2.0]);
        assert!(back.opt_base.is_none());
        assert_eq!(back.zero_shards, 1);
        assert_eq!(back.zero_stage, 1, "pre-stage files read as stage 1");
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn rejects_truncated_payload() {
        let c = full_ckpt();
        let p = tmp("trunc.ckpt");
        c.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn rejects_trailing_bytes() {
        let c = full_ckpt();
        let p = tmp("oversize.ckpt");
        c.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.extend_from_slice(&[0u8; 3]);
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn save_is_atomic_and_leaves_no_temp_file() {
        let dir = std::env::temp_dir().join(format!("prelora_atomic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("model.ckpt");
        // overwriting an existing checkpoint goes through the temp file too
        full_ckpt().save(&p).unwrap();
        full_ckpt().save(&p).unwrap();
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["model.ckpt".to_string()], "stray files: {names:?}");
        assert!(Checkpoint::load(&p).is_ok());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn save_rejects_ungathered_optimizer_state() {
        let mut c = full_ckpt();
        c.opt_base = Some(OptState {
            kind: OptimizerKind::AdamW,
            t: 1,
            bufs: vec![vec![0.0; 2], vec![0.0; 2]], // base is 3 long
        });
        let p = tmp("badopt.ckpt");
        assert!(c.save(&p).is_err(), "shard-length state must be rejected");
        let _ = std::fs::remove_file(p);
    }
}
