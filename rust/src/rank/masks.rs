//! adapter_cfg construction: rank assignment -> the flat mask/scale vector
//! consumed by the AOT artifacts.
//!
//! Layout (manifest adapter order): per adapter, `r_max` mask entries
//! (first r_l ones) followed by one scale entry `alpha / r_l`. This is the
//! static-shape encoding of Algorithm 2's dynamic ranks — one compiled HLO
//! serves every assignment.

use anyhow::{ensure, Result};

use super::RankAssignment;
use crate::manifest::Manifest;

/// A materialized adapter_cfg vector plus its bookkeeping.
#[derive(Debug, Clone)]
pub struct AdapterCfg {
    pub values: Vec<f32>,
    /// Per-adapter rank in manifest order.
    pub ranks: Vec<usize>,
    /// Trainable LoRA parameters implied by the ranks.
    pub trainable_params: usize,
}

/// Build adapter_cfg from a rank assignment.
pub fn build_adapter_cfg(
    manifest: &Manifest,
    assignment: &RankAssignment,
    alpha: f64,
) -> Result<AdapterCfg> {
    let r_max = manifest.config.r_max;
    let mut values = vec![0.0f32; manifest.adapter_cfg_size];
    let mut ranks = Vec::with_capacity(manifest.adapters.len());
    for ad in &manifest.adapters {
        let r = assignment
            .rank_of(&ad.module, ad.layer as usize)
            .ok_or_else(|| anyhow::anyhow!("no rank for adapter {}", ad.name))?;
        ensure!(r >= 1 && r <= r_max, "rank {r} out of [1, {r_max}] for {}", ad.name);
        for i in 0..r {
            values[ad.cfg_offset + i] = 1.0;
        }
        values[ad.cfg_offset + r_max] = (alpha / r as f64) as f32;
        ranks.push(r);
    }
    let trainable_params = manifest.lora_trainable(&ranks);
    Ok(AdapterCfg { values, ranks, trainable_params })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{Manifest, ADAPTED_MODULES};
    use crate::rank::{assign_ranks, uniform_ranks};
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    fn micro() -> Manifest {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/vit-micro");
        Manifest::load(dir).expect("run `make artifacts` first")
    }

    #[test]
    fn uniform_cfg_layout() {
        let m = micro();
        let modules: Vec<String> = ADAPTED_MODULES.iter().map(|s| s.to_string()).collect();
        let a = uniform_ranks(&modules, m.config.depth, 2);
        let cfg = build_adapter_cfg(&m, &a, m.config.lora_alpha).unwrap();
        assert_eq!(cfg.values.len(), m.adapter_cfg_size);
        assert!(cfg.ranks.iter().all(|&r| r == 2));
        let r_max = m.config.r_max;
        let first = &cfg.values[..r_max + 1];
        assert_eq!(&first[..2], &[1.0, 1.0]);
        assert!(first[2..r_max].iter().all(|&x| x == 0.0));
        assert!((first[r_max] - (m.config.lora_alpha / 2.0) as f32).abs() < 1e-6);
    }

    #[test]
    fn dynamic_cfg_trainable_counts_match_manifest() {
        let m = micro();
        // ramp deltas so layer 0 -> r_min, last layer -> r_max
        let mut deltas = BTreeMap::new();
        for md in ADAPTED_MODULES {
            let d: Vec<f64> = (0..m.config.depth).map(|l| l as f64).collect();
            deltas.insert(md.to_string(), d);
        }
        let a = assign_ranks(&deltas, m.config.r_min, m.config.r_max);
        let cfg = build_adapter_cfg(&m, &a, m.config.lora_alpha).unwrap();
        assert_eq!(cfg.trainable_params, m.lora_trainable(&cfg.ranks));
        // layer 0 adapters at r_min, last layer at r_max
        assert_eq!(cfg.ranks[0], m.config.r_min);
        assert_eq!(*cfg.ranks.last().unwrap(), m.config.r_max);
    }

    #[test]
    fn scale_is_alpha_over_rank() {
        let m = micro();
        let modules: Vec<String> = ADAPTED_MODULES.iter().map(|s| s.to_string()).collect();
        for r in [1usize, 2, 4] {
            let a = uniform_ranks(&modules, m.config.depth, r);
            let cfg = build_adapter_cfg(&m, &a, 8.0).unwrap();
            let r_max = m.config.r_max;
            assert!((cfg.values[r_max] - (8.0 / r as f64) as f32).abs() < 1e-6);
        }
    }
}
