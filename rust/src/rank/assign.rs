//! Algorithm 2: dynamic rank assignment.
//!
//! After the partial convergence test passes over k windows, the per-layer
//! weight-norm changes between the last two windows, DeltaW_k^{a_l}, are
//! min-max normalized *within each module* and bucketed into the
//! power-of-two rank set R = [r_min, 2*r_min, ..., r_max]:
//!
//! ```text
//! v = (|dW_l| - min) / (max - min)            in [0, 1]
//! i = ceil(v * |R|) - 1   if v != 0  else  0
//! rank(l) = R[i]
//! ```
//!
//! Layers that moved most since the previous window (least converged) get
//! the largest adapters; fully settled layers get r_min. When every layer
//! of a module moved identically (min == max, normalization degenerate)
//! the middle bucket is assigned — documented deviation, the paper leaves
//! this case unspecified.

use std::collections::BTreeMap;

/// The outcome of one rank assignment, keyed like the manifest adapters.
#[derive(Debug, Clone, PartialEq)]
pub struct RankAssignment {
    /// module -> per-layer rank (layer order).
    pub by_module: BTreeMap<String, Vec<usize>>,
    pub r_min: usize,
    pub r_max: usize,
}

impl RankAssignment {
    pub fn rank_of(&self, module: &str, layer: usize) -> Option<usize> {
        self.by_module.get(module)?.get(layer).copied()
    }

    /// Flatten to manifest adapter order (layer-major, module order given).
    pub fn in_adapter_order(&self, modules: &[&str], layers: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(modules.len() * layers);
        for l in 0..layers {
            for m in modules {
                out.push(self.by_module[*m][l]);
            }
        }
        out
    }

    /// Histogram over the bucket set (for run summaries).
    pub fn histogram(&self) -> BTreeMap<usize, usize> {
        let mut h = BTreeMap::new();
        for ranks in self.by_module.values() {
            for &r in ranks {
                *h.entry(r).or_insert(0) += 1;
            }
        }
        h
    }
}

/// Powers of two from r_min to r_max inclusive (Algorithm 2, lines 3-6).
pub fn rank_buckets(r_min: usize, r_max: usize) -> Vec<usize> {
    assert!(r_min.is_power_of_two() && r_max.is_power_of_two() && r_min <= r_max);
    let mut r = Vec::new();
    let mut p = r_min;
    while p <= r_max {
        r.push(p);
        p *= 2;
    }
    r
}

/// Algorithm 2 over per-module, per-layer |DeltaW_k^{a_l}| (percent,
/// absolute value taken here).
pub fn assign_ranks(
    deltas: &BTreeMap<String, Vec<f64>>,
    r_min: usize,
    r_max: usize,
) -> RankAssignment {
    let buckets = rank_buckets(r_min, r_max);
    let nb = buckets.len();
    let mut by_module = BTreeMap::new();
    for (module, dw) in deltas {
        let abs: Vec<f64> = dw.iter().map(|d| d.abs()).collect();
        let lo = abs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = abs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let ranks: Vec<usize> = if (hi - lo).abs() < 1e-15 {
            // degenerate normalization: middle bucket (see module doc)
            vec![buckets[(nb - 1) / 2]; abs.len()]
        } else {
            abs.iter()
                .map(|&a| {
                    let v = (a - lo) / (hi - lo);
                    let i = if v == 0.0 {
                        0
                    } else {
                        ((v * nb as f64).ceil() as usize).saturating_sub(1).min(nb - 1)
                    };
                    buckets[i]
                })
                .collect()
        };
        by_module.insert(module.clone(), ranks);
    }
    RankAssignment { by_module, r_min, r_max }
}

/// Uniform-rank ablation: every adapter at the same rank.
pub fn uniform_ranks(modules: &[String], layers: usize, rank: usize) -> RankAssignment {
    let by_module = modules
        .iter()
        .map(|m| (m.clone(), vec![rank; layers]))
        .collect();
    RankAssignment { by_module, r_min: rank, r_max: rank }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn deltas(pairs: &[(&str, &[f64])]) -> BTreeMap<String, Vec<f64>> {
        pairs.iter().map(|(m, d)| (m.to_string(), d.to_vec())).collect()
    }

    #[test]
    fn buckets_are_powers_of_two() {
        assert_eq!(rank_buckets(8, 64), vec![8, 16, 32, 64]);
        assert_eq!(rank_buckets(4, 4), vec![4]);
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_panics() {
        rank_buckets(3, 12);
    }

    #[test]
    fn extremes_map_to_extreme_buckets() {
        let d = deltas(&[("query", &[0.0, 0.1, 0.5, 1.0])]);
        let a = assign_ranks(&d, 8, 64);
        let q = &a.by_module["query"];
        assert_eq!(q[0], 8, "most converged layer -> r_min");
        assert_eq!(q[3], 64, "least converged layer -> r_max");
        assert!(q[1] <= q[2]);
    }

    #[test]
    fn monotonic_in_delta() {
        let d = deltas(&[("dense", &[0.05, 0.2, 0.4, 0.6, 0.8, 1.0])]);
        let a = assign_ranks(&d, 8, 64);
        let r = &a.by_module["dense"];
        for w in r.windows(2) {
            assert!(w[0] <= w[1], "{r:?}");
        }
    }

    #[test]
    fn negative_deltas_use_magnitude() {
        let d = deltas(&[("query", &[-1.0, 0.0, 0.5])]);
        let a = assign_ranks(&d, 8, 64);
        assert_eq!(a.by_module["query"][0], 64);
        assert_eq!(a.by_module["query"][1], 8);
    }

    #[test]
    fn degenerate_module_gets_middle_bucket() {
        let d = deltas(&[("key", &[0.3, 0.3, 0.3])]);
        let a = assign_ranks(&d, 8, 64);
        assert_eq!(a.by_module["key"], vec![16, 16, 16]);
    }

    #[test]
    fn normalization_is_per_module() {
        // query's 0.2 is its max -> r_max; dense's 0.2 is its min -> r_min
        let d = deltas(&[("query", &[0.0, 0.2]), ("dense", &[0.2, 2.0])]);
        let a = assign_ranks(&d, 8, 64);
        assert_eq!(a.rank_of("query", 1), Some(64));
        assert_eq!(a.rank_of("dense", 0), Some(8));
    }

    #[test]
    fn adapter_order_flattening() {
        let d = deltas(&[("dense", &[0.0, 1.0]), ("query", &[1.0, 0.0])]);
        let a = assign_ranks(&d, 8, 16);
        let flat = a.in_adapter_order(&["query", "dense"], 2);
        assert_eq!(flat, vec![16, 8, 8, 16]);
    }

    #[test]
    fn histogram_counts() {
        let d = deltas(&[("q", &[0.0, 1.0, 1.0])]);
        let a = assign_ranks(&d, 8, 64);
        let h = a.histogram();
        assert_eq!(h[&8], 1);
        assert_eq!(h[&64], 2);
    }

    #[test]
    fn uniform_assignment() {
        let a = uniform_ranks(&["query".into(), "dense".into()], 3, 8);
        assert_eq!(a.by_module["query"], vec![8, 8, 8]);
        assert_eq!(a.histogram()[&8], 6);
    }
}
