//! Rank assignment (the paper's Algorithm 2) + adapter_cfg construction.

mod assign;
mod masks;

pub use assign::{assign_ranks, rank_buckets, uniform_ranks, RankAssignment};
pub use masks::{build_adapter_cfg, AdapterCfg};
