//! Checkpointing: flat vectors + a JSON header in one file.
//!
//! Format (v3, see `docs/checkpoint-format.md`): one JSON header line
//! (sizes, epoch, ranks, optimizer-state descriptors, ZeRO shard/stage
//! metadata, a payload CRC-32 and the **trajectory block** — the phase
//! machine, norm/loss history layout, LR-schedule position, data-order
//! seed and per-epoch stats) followed by the raw little-endian payloads
//! in header order: base, lora, adapter_cfg, each optimizer state buffer
//! (all `f32`), then the trajectory's loss and per-module norm series
//! (`f64`, bit-exact). The payload is always written **gathered** — full
//! parameter vectors and full-length optimizer state buffers, whatever
//! `dist::Strategy` the saving run partitioned them with (parameters
//! included: a ZeRO-3 run's owned partitions are all-gathered on save) —
//! so files stay shard-layout independent and a checkpoint from an N-way
//! sharded run restores onto any stage and worker count. v1 files (no
//! optimizer state) and v2 files (no trajectory, no checksum) still load.
//!
//! Durability: `save` writes to a temp file in the destination directory
//! and atomically renames it into place, so a crash mid-write can never
//! leave a partially-written file under the checkpoint's name. `load`
//! rejects files whose payload is truncated *or* that carry trailing
//! bytes beyond what the header declares, and (v3) whose payload fails
//! the header's CRC-32 — single-byte corruption is an error, not a
//! silently-wrong restore.

use std::io::{BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::config::OptimizerKind;
use crate::convergence::ConvergenceReport;
use crate::coordinator::Phase;
use crate::dist::ZeroStage;
use crate::optim::OptState;
use crate::telemetry::NormSnapshot;
use crate::trainer::EpochStats;
use crate::util::crc::Crc32;
use crate::util::json::Json;

/// Load-side cap on the header line; enforced at save time too, so a
/// long run can never write a rolling checkpoint it cannot read back
/// (the header grows O(epochs) through the per-epoch stats).
const MAX_HEADER_BYTES: usize = 1 << 22;

const MAGIC_V3: &str = "prelora-ckpt-v3";
const MAGIC_V2: &str = "prelora-ckpt-v2";
const MAGIC_V1: &str = "prelora-ckpt-v1";

/// Everything beyond the parameters that makes resumption a true
/// continuation: the controller's phase machine, the telemetry history it
/// decides from, the LR-schedule position and the data-order seed. A v3
/// checkpoint always carries this; restoring it makes `Trainer::restore`
/// resume mid-trajectory instead of replaying convergence detection.
#[derive(Debug, Clone)]
pub struct TrajectoryState {
    /// Seed of the saving run. All RNG streams (epoch shuffles, dataset
    /// generation, LoRA init at the switch) are pure functions of
    /// `(seed, epoch)`, so the seed *is* the serialized data-order RNG
    /// state; a resuming run must use the same one.
    pub seed: u64,
    /// Controller phase at the save point.
    pub phase: Phase,
    pub switch_epoch: Option<usize>,
    pub freeze_epoch: Option<usize>,
    /// LR schedule kind of the saving run (`LrScheduleKind::as_str`).
    /// The schedule is a pure function of `(kind, total epochs, epoch)`,
    /// so position = the epoch cursor — but only if kind and total match.
    pub lr_schedule: String,
    /// Total epochs the saving run's schedule was built for.
    pub lr_epochs_total: usize,
    /// The controller's convergence-check evidence log.
    pub checks: Vec<(usize, ConvergenceReport)>,
    /// Per-epoch norm snapshots (the controller's window evidence).
    pub snapshots: Vec<NormSnapshot>,
    /// Per-epoch training losses, index-aligned with `snapshots`.
    pub losses: Vec<f64>,
    /// Per-epoch stats of the completed epochs (summary continuity).
    pub stats: Vec<EpochStats>,
}

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub epoch: usize,
    pub base: Vec<f32>,
    pub lora: Option<Vec<f32>>,
    pub adapter_cfg: Option<Vec<f32>>,
    pub ranks: Option<Vec<usize>>,
    /// Gathered (full-length) base optimizer state, if the phase held one.
    pub opt_base: Option<OptState>,
    /// Gathered LoRA optimizer state, present after the switch.
    pub opt_lora: Option<OptState>,
    /// ZeRO shard count of the run that saved this checkpoint (1 =
    /// unsharded). Metadata only: the payload is always gathered, and a
    /// restore re-scatters onto the restoring run's own layout.
    pub zero_shards: usize,
    /// `dist::Strategy` stage of the saving run. Metadata only, like
    /// `zero_shards`: gradient shards are transient within a step, and
    /// parameters/optimizer state are gathered on save, so the payload is
    /// stage-independent — a stage-3 file restores under stage 0 and vice
    /// versa. Serialized as the `zero_stage` header integer; absent in
    /// files written before the stage knob existed — read as stage 1
    /// (those runs sharded at most the optimizer state).
    pub stage: ZeroStage,
    /// Phase-machine / telemetry trajectory (v3). `None` in v1/v2 files:
    /// those restore parameters and optimizer state but replay phase
    /// detection from scratch.
    pub trajectory: Option<TrajectoryState>,
}

struct Header {
    magic: String,
    epoch: usize,
    base_len: usize,
    lora_len: usize,
    adapter_cfg_len: usize,
    ranks: Option<Vec<usize>>,
    zero_shards: usize,
    zero_stage: u8,
    opt_base: Option<OptDescriptor>,
    opt_lora: Option<OptDescriptor>,
    /// CRC-32 of the whole file in canonical form — the header line
    /// re-serialized with this field zeroed, the newline, then the
    /// binary payload (v3 only). Covering the header too means a bit
    /// flip in a rank, a stats float or any other header field is a
    /// loud checksum error, not a silently-wrong restore.
    file_crc32: Option<u32>,
    trajectory: Option<TrajHeader>,
}

/// Header description of one serialized optimizer state: the payload
/// carries `bufs` buffers of the owning section's length.
struct OptDescriptor {
    kind: OptimizerKind,
    steps: u64,
    bufs: usize,
}

/// The trajectory block's header half: everything except the f64 series,
/// which live in the binary payload laid out per `modules`.
struct TrajHeader {
    seed: u64,
    phase: Phase,
    switch_epoch: Option<usize>,
    freeze_epoch: Option<usize>,
    lr_schedule: String,
    lr_epochs_total: usize,
    checks: Vec<(usize, ConvergenceReport)>,
    /// `(module name, layer count)` in serialization order; each module
    /// contributes `epoch * layers` f64s to the payload.
    modules: Vec<(String, usize)>,
    stats: Vec<EpochStats>,
}

impl OptDescriptor {
    fn of(state: &OptState) -> Self {
        Self { kind: state.kind, steps: state.t, bufs: state.bufs.len() }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str(self.kind.as_str().to_string())),
            ("steps", Json::from_usize(self.steps as usize)),
            ("bufs", Json::from_usize(self.bufs)),
        ])
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            kind: v.req("kind")?.as_str()?.parse()?,
            steps: v.req("steps")?.as_usize()? as u64,
            bufs: v.req("bufs")?.as_usize()?,
        })
    }
}

fn opt_usize(x: Option<usize>) -> Json {
    x.map_or(Json::Null, Json::from_usize)
}

fn usize_opt(v: &Json) -> Result<Option<usize>> {
    match v {
        Json::Null => Ok(None),
        x => Ok(Some(x.as_usize()?)),
    }
}

impl TrajHeader {
    /// Derive the header half from a full trajectory, validating the
    /// invariants the payload layout relies on (one loss/snapshot/stat
    /// row per completed epoch, identical module layout in every
    /// snapshot) — a malformed trajectory must fail at save time, not
    /// produce a file that cannot be read back.
    fn of(tr: &TrajectoryState, epoch: usize) -> Result<Self> {
        ensure!(
            tr.snapshots.len() == epoch && tr.losses.len() == epoch && tr.stats.len() == epoch,
            "trajectory length mismatch: {} snapshots / {} losses / {} stats for epoch {epoch}",
            tr.snapshots.len(),
            tr.losses.len(),
            tr.stats.len()
        );
        let modules: Vec<(String, usize)> = tr.snapshots.first().map_or_else(Vec::new, |s| {
            s.by_module.iter().map(|(k, v)| (k.clone(), v.len())).collect()
        });
        for (i, s) in tr.snapshots.iter().enumerate() {
            ensure!(s.epoch == i, "snapshot {i} carries epoch {}", s.epoch);
            ensure!(
                s.by_module.len() == modules.len()
                    && modules
                        .iter()
                        .all(|(name, layers)| s.by_module.get(name).map(Vec::len) == Some(*layers)),
                "snapshot {i} does not match the module layout of snapshot 0"
            );
        }
        Ok(Self {
            seed: tr.seed,
            phase: tr.phase,
            switch_epoch: tr.switch_epoch,
            freeze_epoch: tr.freeze_epoch,
            lr_schedule: tr.lr_schedule.clone(),
            lr_epochs_total: tr.lr_epochs_total,
            checks: tr.checks.clone(),
            modules,
            stats: tr.stats.clone(),
        })
    }

    /// f64 count of the trajectory's binary payload. Checked: a crafted
    /// header with huge layer counts must not wrap into a small total.
    fn payload_f64s(&self, epoch: usize) -> Option<usize> {
        let mut layers = 0usize;
        for (_, l) in &self.modules {
            layers = layers.checked_add(*l)?;
        }
        epoch.checked_mul(layers)?.checked_add(epoch)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            // decimal string: u64 seeds can exceed f64's exact-integer range
            ("seed", Json::Str(self.seed.to_string())),
            ("phase", self.phase.to_json()),
            ("switch_epoch", opt_usize(self.switch_epoch)),
            ("freeze_epoch", opt_usize(self.freeze_epoch)),
            ("lr_schedule", Json::Str(self.lr_schedule.clone())),
            ("lr_epochs_total", Json::from_usize(self.lr_epochs_total)),
            (
                "checks",
                Json::Arr(
                    self.checks
                        .iter()
                        .map(|(e, r)| {
                            Json::obj(vec![
                                ("epoch", Json::from_usize(*e)),
                                ("report", r.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "history_modules",
                Json::Arr(
                    self.modules
                        .iter()
                        .map(|(name, layers)| {
                            Json::obj(vec![
                                ("name", Json::Str(name.clone())),
                                ("layers", Json::from_usize(*layers)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("stats", Json::Arr(self.stats.iter().map(EpochStats::to_json).collect())),
        ])
    }

    fn from_json(v: &Json) -> Result<Self> {
        let seed = v
            .req("seed")?
            .as_str()?
            .parse::<u64>()
            .context("trajectory seed must be a decimal u64 string")?;
        let checks = v
            .req("checks")?
            .as_arr()?
            .iter()
            .map(|c| {
                Ok((
                    c.req("epoch")?.as_usize()?,
                    ConvergenceReport::from_json(c.req("report")?)?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let modules = v
            .req("history_modules")?
            .as_arr()?
            .iter()
            .map(|m| Ok((m.req("name")?.as_str()?.to_string(), m.req("layers")?.as_usize()?)))
            .collect::<Result<Vec<_>>>()?;
        let stats = v
            .req("stats")?
            .as_arr()?
            .iter()
            .map(EpochStats::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            seed,
            phase: Phase::from_json(v.req("phase")?)?,
            switch_epoch: usize_opt(v.req("switch_epoch")?)?,
            freeze_epoch: usize_opt(v.req("freeze_epoch")?)?,
            lr_schedule: v.req("lr_schedule")?.as_str()?.to_string(),
            lr_epochs_total: v.req("lr_epochs_total")?.as_usize()?,
            checks,
            modules,
            stats,
        })
    }
}

impl Header {
    fn to_json(&self) -> Json {
        let opt = |d: &Option<OptDescriptor>| d.as_ref().map_or(Json::Null, |d| d.to_json());
        Json::obj(vec![
            ("magic", Json::Str(self.magic.clone())),
            ("epoch", Json::from_usize(self.epoch)),
            ("base_len", Json::from_usize(self.base_len)),
            ("lora_len", Json::from_usize(self.lora_len)),
            ("adapter_cfg_len", Json::from_usize(self.adapter_cfg_len)),
            (
                "ranks",
                match &self.ranks {
                    Some(r) => Json::arr_usize(r),
                    None => Json::Null,
                },
            ),
            ("zero_shards", Json::from_usize(self.zero_shards)),
            ("zero_stage", Json::from_usize(self.zero_stage as usize)),
            ("opt_base", opt(&self.opt_base)),
            ("opt_lora", opt(&self.opt_lora)),
            (
                "file_crc32",
                self.file_crc32.map_or(Json::Null, |c| Json::from_usize(c as usize)),
            ),
            (
                "trajectory",
                self.trajectory.as_ref().map_or(Json::Null, TrajHeader::to_json),
            ),
        ])
    }

    fn from_json(v: &Json) -> Result<Self> {
        let ranks = match v.req("ranks")? {
            Json::Null => None,
            arr => Some(arr.as_arr()?.iter().map(|x| x.as_usize()).collect::<Result<_>>()?),
        };
        let magic = v.req("magic")?.as_str()?.to_string();
        // v1 headers have no optimizer/shard fields
        let opt = |key: &str| -> Result<Option<OptDescriptor>> {
            match v.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(d) => Ok(Some(OptDescriptor::from_json(d)?)),
            }
        };
        // strict range checks rather than clamping: no writer ever
        // produced out-of-range values, so an out-of-range read is
        // corruption — and clamping would let a corrupted byte round-trip
        // to a canonical form identical to the original, slipping past
        // the file checksum (in-range flips re-serialize faithfully and
        // fail the checksum instead)
        let zero_shards = match v.get("zero_shards") {
            None => 1,
            Some(x) => {
                let s = x.as_usize()?;
                ensure!(s >= 1, "zero_shards must be >= 1");
                s
            }
        };
        // absent in v1 files and in v2 files written before the stage
        // knob; those runs sharded at most the optimizer state. Files
        // written before ZeRO-3 / the `dist` API carry 1 or 2; current
        // files carry the full 0..=3 range (0 = unsharded)
        let zero_stage = match v.get("zero_stage") {
            None => 1,
            Some(x) => {
                let s = x.as_usize()?;
                ensure!(s <= 3, "zero_stage must be 0..=3, got {s}");
                s as u8
            }
        };
        let file_crc32 = match v.get("file_crc32") {
            None | Some(Json::Null) => None,
            Some(x) => {
                let c = x.as_usize()?;
                ensure!(c <= u32::MAX as usize, "file_crc32 out of range");
                Some(c as u32)
            }
        };
        let trajectory = match v.get("trajectory") {
            None | Some(Json::Null) => None,
            Some(t) => Some(TrajHeader::from_json(t)?),
        };
        Ok(Self {
            magic,
            epoch: v.req("epoch")?.as_usize()?,
            base_len: v.req("base_len")?.as_usize()?,
            lora_len: v.req("lora_len")?.as_usize()?,
            adapter_cfg_len: v.req("adapter_cfg_len")?.as_usize()?,
            ranks,
            zero_shards,
            zero_stage,
            opt_base: opt("opt_base")?,
            opt_lora: opt("opt_lora")?,
            file_crc32,
            trajectory,
        })
    }

    /// The canonical checksum over this header (with its crc field
    /// zeroed), the newline separator, and the binary payload. Our JSON
    /// writer is canonical — sorted keys, integer numbers, bit-exact
    /// float strings, deterministic escapes — so `dump(parse(header))`
    /// reproduces the written header byte-for-byte and save/load compute
    /// the identical value over an intact file. Any single-bit flip
    /// anywhere in the file either breaks parsing outright or changes
    /// the canonical bytes, and therefore this checksum.
    fn file_crc(&mut self, payload: &[u8]) -> u32 {
        let declared = self.file_crc32.take();
        self.file_crc32 = Some(0);
        let mut crc = Crc32::new();
        crc.update(self.to_json().dump().as_bytes());
        crc.update(b"\n");
        crc.update(payload);
        self.file_crc32 = declared;
        crc.finish()
    }

    /// Exact byte count the header declares for the binary payload.
    /// `None` when the declared sizes overflow `usize` — a crafted header
    /// must degrade to a clean rejection, not a wrapped total that lets
    /// the cursor reads slice out of bounds.
    fn payload_bytes(&self) -> Option<usize> {
        let mut f32s = self.base_len;
        f32s = f32s.checked_add(self.lora_len)?;
        f32s = f32s.checked_add(self.adapter_cfg_len)?;
        if let Some(d) = &self.opt_base {
            f32s = f32s.checked_add(d.bufs.checked_mul(self.base_len)?)?;
        }
        if let Some(d) = &self.opt_lora {
            f32s = f32s.checked_add(d.bufs.checked_mul(self.lora_len)?)?;
        }
        let f64s = match &self.trajectory {
            Some(t) => t.payload_f64s(self.epoch)?,
            None => 0,
        };
        f32s.checked_mul(4)?.checked_add(f64s.checked_mul(8)?)
    }
}

fn push_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    buf.reserve(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn push_f64s(buf: &mut Vec<u8>, xs: &[f64]) {
    buf.reserve(xs.len() * 8);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Cursor reads over the (length-prevalidated) payload buffer.
fn take_f32s(buf: &[u8], pos: &mut usize, n: usize) -> Vec<f32> {
    let bytes = &buf[*pos..*pos + n * 4];
    *pos += n * 4;
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

fn take_f64s(buf: &[u8], pos: &mut usize, n: usize) -> Vec<f64> {
    let bytes = &buf[*pos..*pos + n * 8];
    *pos += n * 8;
    bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect()
}

impl Checkpoint {
    /// Serialize the binary payload (everything after the header line).
    fn payload(&self, traj: &Option<TrajHeader>) -> Vec<u8> {
        let mut buf = Vec::new();
        push_f32s(&mut buf, &self.base);
        if let Some(l) = &self.lora {
            push_f32s(&mut buf, l);
        }
        if let Some(a) = &self.adapter_cfg {
            push_f32s(&mut buf, a);
        }
        for st in [&self.opt_base, &self.opt_lora].into_iter().flatten() {
            for b in &st.bufs {
                push_f32s(&mut buf, b);
            }
        }
        if let (Some(tr), Some(th)) = (&self.trajectory, traj) {
            push_f64s(&mut buf, &tr.losses);
            // module-major: each watched module's full per-epoch,
            // per-layer series is contiguous
            for (name, _layers) in &th.modules {
                for snap in &tr.snapshots {
                    push_f64s(&mut buf, &snap.by_module[name]);
                }
            }
        }
        buf
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(st) = &self.opt_base {
            ensure!(
                st.bufs.iter().all(|b| b.len() == self.base.len()),
                "opt_base state buffers must be base-length (gathered)"
            );
        }
        if let Some(st) = &self.opt_lora {
            let lora_len = self.lora.as_ref().map_or(0, |v| v.len());
            ensure!(
                st.bufs.iter().all(|b| b.len() == lora_len),
                "opt_lora state buffers must be lora-length (gathered)"
            );
        }
        let traj = match &self.trajectory {
            Some(tr) => Some(TrajHeader::of(tr, self.epoch)?),
            None => None,
        };
        let payload = self.payload(&traj);
        let mut header = Header {
            magic: MAGIC_V3.into(),
            epoch: self.epoch,
            base_len: self.base.len(),
            lora_len: self.lora.as_ref().map_or(0, |v| v.len()),
            adapter_cfg_len: self.adapter_cfg.as_ref().map_or(0, |v| v.len()),
            ranks: self.ranks.clone(),
            zero_shards: self.zero_shards.max(1),
            zero_stage: self.stage.as_u8(),
            opt_base: self.opt_base.as_ref().map(OptDescriptor::of),
            opt_lora: self.opt_lora.as_ref().map(OptDescriptor::of),
            file_crc32: None,
            trajectory: traj,
        };
        header.file_crc32 = Some(header.file_crc(&payload));
        debug_assert_eq!(header.payload_bytes(), Some(payload.len()));
        let header_json = header.to_json().dump();
        // mirror the load-side cap: a rolling checkpoint that could not
        // be read back must fail loudly *before* the atomic rename
        // replaces the previous good file
        ensure!(
            header_json.len() < MAX_HEADER_BYTES,
            "checkpoint header is {} bytes, over the {} byte load limit (a very long run's \
             per-epoch stats no longer fit — raise MAX_HEADER_BYTES in a coordinated format \
             change)",
            header_json.len(),
            MAX_HEADER_BYTES
        );
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        // write-to-temp + atomic rename: a crash mid-write leaves only a
        // stale .tmp, never a corrupt file under the checkpoint's name
        let tmp = path.with_file_name(format!(
            "{}.{}.tmp",
            path.file_name().and_then(|n| n.to_str()).unwrap_or("ckpt"),
            std::process::id()
        ));
        let write = (|| -> Result<()> {
            let file = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            let mut w = BufWriter::new(file);
            w.write_all(header_json.as_bytes())?;
            w.write_all(b"\n")?;
            w.write_all(&payload)?;
            // durability, not just process-crash safety: the data blocks
            // must be on disk before the rename is allowed to replace the
            // previous good checkpoint
            let file = w
                .into_inner()
                .map_err(|e| anyhow::anyhow!("flushing checkpoint: {e}"))?;
            file.sync_all().context("syncing checkpoint to disk")?;
            Ok(())
        })();
        if let Err(e) = write {
            let _ = std::fs::remove_file(&tmp);
            return Err(e);
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
        // make the rename itself durable (best-effort: directory fsync is
        // not supported on every platform)
        if let Some(dir) = path.parent() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    }

    /// Adversity-testing seam (`ckpt-torn@e.0.0:byte=b`): save normally,
    /// then truncate the file at byte `byte` — simulating a crash that
    /// left a torn write under the checkpoint's name, the failure mode
    /// the atomic tmp+rename path prevents but a rename-free filesystem
    /// (or a lost directory entry) can still produce. A cut inside the
    /// header line fails the next load's header parse; a cut inside the
    /// payload fails its strict length check — either way loudly, never
    /// as silent corruption (asserted by `rust/tests/adversity.rs`).
    pub fn save_torn(&self, path: impl AsRef<Path>, byte: u64) -> Result<()> {
        let path = path.as_ref();
        self.save(path)?;
        let file = std::fs::OpenOptions::new()
            .write(true)
            .open(path)
            .with_context(|| format!("reopening {} to tear it", path.display()))?;
        let len = file.metadata()?.len();
        ensure!(
            byte < len,
            "torn-write fault asks for a cut at byte {byte} but the checkpoint is only {len} \
             bytes — the fault would be a no-op, which is never what an adversity cell means"
        );
        file.set_len(byte)
            .with_context(|| format!("truncating {} at byte {byte}", path.display()))?;
        file.sync_all().context("syncing the torn checkpoint")?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let file = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let mut r = std::io::BufReader::new(file);
        let mut header_line = Vec::new();
        // read until newline
        let mut byte = [0u8; 1];
        loop {
            r.read_exact(&mut byte).context("checkpoint header truncated")?;
            if byte[0] == b'\n' {
                break;
            }
            header_line.push(byte[0]);
            ensure!(header_line.len() < MAX_HEADER_BYTES, "header too large");
        }
        let mut header = Header::from_json(&Json::parse(std::str::from_utf8(&header_line)?)?)?;
        match header.magic.as_str() {
            MAGIC_V3 => {
                ensure!(
                    header.file_crc32.is_some(),
                    "v3 checkpoint is missing its file checksum"
                );
            }
            MAGIC_V2 => {
                ensure!(
                    header.trajectory.is_none(),
                    "v2 checkpoint cannot declare a trajectory block"
                );
            }
            MAGIC_V1 => {
                ensure!(
                    header.opt_base.is_none()
                        && header.opt_lora.is_none()
                        && header.trajectory.is_none(),
                    "v1 checkpoint cannot declare optimizer or trajectory state"
                );
            }
            other => bail!("bad checkpoint magic {other:?}"),
        }
        if let Some(th) = &header.trajectory {
            ensure!(
                th.stats.len() == header.epoch,
                "trajectory carries {} epoch stats for epoch {}",
                th.stats.len(),
                header.epoch
            );
        }
        // strict bounds: the payload must be byte-for-byte what the
        // header declares — shorter is truncation, longer is trailing
        // garbage, and (v3) a checksum mismatch is corruption
        let want = header.payload_bytes().ok_or_else(|| {
            anyhow::anyhow!("checkpoint header declares payload sizes that overflow")
        })?;
        let mut payload = Vec::with_capacity(want.min(1 << 30));
        r.read_to_end(&mut payload)?;
        ensure!(
            payload.len() >= want,
            "checkpoint payload truncated: {} bytes, header declares {}",
            payload.len(),
            want
        );
        ensure!(
            payload.len() == want,
            "checkpoint has trailing bytes beyond the header-declared payload ({} > {})",
            payload.len(),
            want
        );
        if let Some(crc) = header.file_crc32 {
            // recompute over the canonical re-serialization (crc zeroed)
            // + payload; a flip in *either* region fails here if it got
            // past parsing at all
            let got = header.file_crc(&payload);
            ensure!(
                got == crc,
                "checkpoint checksum mismatch (crc32 {got:#010x}, header declares {crc:#010x}) — the file is corrupt"
            );
        }
        let mut pos = 0usize;
        let base = take_f32s(&payload, &mut pos, header.base_len);
        let lora = if header.lora_len > 0 {
            Some(take_f32s(&payload, &mut pos, header.lora_len))
        } else {
            None
        };
        let adapter_cfg = if header.adapter_cfg_len > 0 {
            Some(take_f32s(&payload, &mut pos, header.adapter_cfg_len))
        } else {
            None
        };
        let mut opt_state = |desc: &Option<OptDescriptor>, len: usize| -> Option<OptState> {
            let d = desc.as_ref()?;
            let bufs = (0..d.bufs).map(|_| take_f32s(&payload, &mut pos, len)).collect();
            Some(OptState { kind: d.kind, t: d.steps, bufs })
        };
        let opt_base = opt_state(&header.opt_base, header.base_len);
        let opt_lora = opt_state(&header.opt_lora, header.lora_len);
        let trajectory = match &header.trajectory {
            None => None,
            Some(th) => {
                let losses = take_f64s(&payload, &mut pos, header.epoch);
                // module-major payload -> per-epoch snapshots
                let mut series: Vec<Vec<Vec<f64>>> = Vec::with_capacity(th.modules.len());
                for (_, layers) in &th.modules {
                    let per_epoch =
                        (0..header.epoch).map(|_| take_f64s(&payload, &mut pos, *layers)).collect();
                    series.push(per_epoch);
                }
                let snapshots = (0..header.epoch)
                    .map(|e| NormSnapshot {
                        epoch: e,
                        by_module: th
                            .modules
                            .iter()
                            .zip(&mut series)
                            .map(|((name, _), s)| (name.clone(), std::mem::take(&mut s[e])))
                            .collect(),
                    })
                    .collect();
                Some(TrajectoryState {
                    seed: th.seed,
                    phase: th.phase,
                    switch_epoch: th.switch_epoch,
                    freeze_epoch: th.freeze_epoch,
                    lr_schedule: th.lr_schedule.clone(),
                    lr_epochs_total: th.lr_epochs_total,
                    checks: th.checks.clone(),
                    snapshots,
                    losses,
                    stats: th.stats.clone(),
                })
            }
        };
        debug_assert_eq!(pos, payload.len());
        Ok(Self {
            epoch: header.epoch,
            base,
            lora,
            adapter_cfg,
            ranks: header.ranks,
            opt_base,
            opt_lora,
            zero_shards: header.zero_shards,
            stage: ZeroStage::from_usize(header.zero_stage as usize)
                .map_err(|e| anyhow::anyhow!(e))?,
            trajectory,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Arbitrary};
    use std::collections::BTreeMap;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("prelora_{}_{}", std::process::id(), name))
    }

    fn full_ckpt() -> Checkpoint {
        Checkpoint {
            epoch: 7,
            base: vec![1.0, -2.5, 3.25],
            lora: None,
            adapter_cfg: None,
            ranks: None,
            opt_base: None,
            opt_lora: None,
            zero_shards: 1,
            stage: ZeroStage::Off,
            trajectory: None,
        }
    }

    fn stat(epoch: usize, phase: &'static str) -> EpochStats {
        EpochStats {
            epoch,
            phase,
            train_loss: 2.0 - 0.125 * epoch as f64,
            train_acc: 0.25 + 0.01 * epoch as f64,
            val_loss: if epoch % 2 == 0 { 2.1 } else { f64::NAN },
            val_acc: if epoch % 2 == 0 { 0.3 } else { f64::NAN },
            lr: 1e-3,
            epoch_seconds: 0.5,
            execute_seconds: 0.25,
            images_per_sec: 100.0,
            trainable_params: 1000,
            memory_model_bytes: 4096,
            opt_state_bytes_per_worker: 2048,
            grad_bytes_per_worker: 1024,
            grad_norm: 0.5 + epoch as f64,
            comm_wait_s: 0.0625 * epoch as f64,
        }
    }

    fn snapshot(epoch: usize) -> NormSnapshot {
        let mut by_module = BTreeMap::new();
        by_module.insert("dense".to_string(), vec![5.0 + epoch as f64, 5.5]);
        by_module.insert("query".to_string(), vec![10.0, 10.0 + 0.25 * epoch as f64]);
        NormSnapshot { epoch, by_module }
    }

    /// A post-switch checkpoint carrying the full trajectory block.
    fn traj_ckpt() -> Checkpoint {
        let epoch = 4;
        Checkpoint {
            epoch,
            base: vec![0.5; 10],
            lora: Some(vec![0.25; 6]),
            adapter_cfg: Some(vec![1.0, 0.0, 4.0]),
            ranks: Some(vec![2, 4]),
            opt_base: Some(OptState {
                kind: OptimizerKind::AdamW,
                t: 9,
                bufs: vec![vec![0.1; 10], vec![0.2; 10]],
            }),
            opt_lora: Some(OptState {
                kind: OptimizerKind::AdamW,
                t: 3,
                bufs: vec![vec![0.3; 6], vec![0.4; 6]],
            }),
            zero_shards: 4,
            stage: ZeroStage::Zero2,
            trajectory: Some(TrajectoryState {
                seed: u64::MAX - 12345, // beyond f64's exact-integer range
                phase: Phase::Warmup { since_epoch: 3 },
                switch_epoch: Some(3),
                freeze_epoch: None,
                lr_schedule: "warmup_cosine".into(),
                lr_epochs_total: 16,
                checks: vec![(
                    3,
                    ConvergenceReport {
                        converged: true,
                        max_weight_delta: 0.125,
                        max_loss_delta: f64::INFINITY,
                        fail_reason: None,
                    },
                )],
                snapshots: (0..epoch).map(snapshot).collect(),
                losses: vec![2.0, 1.5, 1.25, f64::NAN],
                stats: (0..epoch)
                    .map(|e| stat(e, if e < 3 { "full" } else { "warmup" }))
                    .collect(),
            }),
        }
    }

    #[test]
    fn roundtrip_full_phase() {
        let c = full_ckpt();
        let p = tmp("full.ckpt");
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.epoch, 7);
        assert_eq!(back.base, c.base);
        assert!(back.lora.is_none() && back.adapter_cfg.is_none());
        assert!(back.opt_base.is_none() && back.opt_lora.is_none());
        assert!(back.trajectory.is_none());
        assert_eq!(back.zero_shards, 1);
        assert_eq!(back.stage, ZeroStage::Off);
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn roundtrip_lora_phase_with_optimizer_state() {
        let c = traj_ckpt();
        let p = tmp("lora.ckpt");
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.lora.unwrap(), vec![0.25; 6]);
        assert_eq!(back.adapter_cfg.unwrap(), vec![1.0, 0.0, 4.0]);
        assert_eq!(back.ranks.unwrap(), vec![2, 4]);
        assert_eq!(back.zero_shards, 4);
        assert_eq!(back.stage, ZeroStage::Zero2, "stage metadata must roundtrip");
        let ob = back.opt_base.unwrap();
        assert_eq!(ob.kind, OptimizerKind::AdamW);
        assert_eq!(ob.t, 9);
        assert_eq!(ob.bufs, vec![vec![0.1; 10], vec![0.2; 10]]);
        let ol = back.opt_lora.unwrap();
        assert_eq!(ol.t, 3);
        assert_eq!(ol.bufs[1], vec![0.4; 6]);
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn trajectory_roundtrips_bitwise() {
        let c = traj_ckpt();
        let want = c.trajectory.as_ref().unwrap();
        let p = tmp("traj.ckpt");
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        let tr = back.trajectory.expect("trajectory must survive disk");
        assert_eq!(tr.seed, want.seed, "seed beyond 2^53 must be exact");
        assert_eq!(tr.phase, Phase::Warmup { since_epoch: 3 });
        assert_eq!(tr.switch_epoch, Some(3));
        assert_eq!(tr.freeze_epoch, None);
        assert_eq!(tr.lr_schedule, "warmup_cosine");
        assert_eq!(tr.lr_epochs_total, 16);
        // losses bitwise, including the NaN
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&tr.losses), bits(&want.losses));
        // snapshots bitwise, module layout preserved
        assert_eq!(tr.snapshots.len(), 4);
        for (got, want) in tr.snapshots.iter().zip(&want.snapshots) {
            assert_eq!(got, want);
        }
        // checks with ±inf deltas
        assert_eq!(tr.checks.len(), 1);
        assert_eq!(tr.checks[0].0, 3);
        assert!(tr.checks[0].1.max_loss_delta.is_infinite());
        // stats bitwise (NaN val columns included)
        assert_eq!(tr.stats.len(), 4);
        for (got, want) in tr.stats.iter().zip(&want.stats) {
            assert_eq!(got.phase, want.phase);
            assert_eq!(got.train_loss.to_bits(), want.train_loss.to_bits());
            assert_eq!(got.val_loss.to_bits(), want.val_loss.to_bits());
            assert_eq!(got.grad_norm.to_bits(), want.grad_norm.to_bits());
        }
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn malformed_trajectory_is_a_save_error() {
        // lengths disagreeing with the epoch counter must fail at save
        let mut c = traj_ckpt();
        c.trajectory.as_mut().unwrap().losses.pop();
        assert!(c.save(tmp("badtraj1.ckpt")).is_err(), "short losses must be rejected");
        let mut c = traj_ckpt();
        c.trajectory.as_mut().unwrap().snapshots[2].epoch = 9;
        assert!(c.save(tmp("badtraj2.ckpt")).is_err(), "epoch holes must be rejected");
        let mut c = traj_ckpt();
        c.trajectory.as_mut().unwrap().snapshots[1].by_module.remove("dense");
        assert!(c.save(tmp("badtraj3.ckpt")).is_err(), "layout drift must be rejected");
        let mut c = traj_ckpt();
        c.trajectory.as_mut().unwrap().stats.pop();
        assert!(c.save(tmp("badtraj4.ckpt")).is_err(), "short stats must be rejected");
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage.ckpt");
        std::fs::write(&p, b"{\"magic\":\"nope\"}\n").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).unwrap();
    }

    /// The back-compat load matrix: files written by every prior format
    /// version, byte-crafted the way the old writers laid them out.
    #[test]
    fn loads_v1_and_v2_checkpoints() {
        struct Case {
            name: &'static str,
            header: &'static str,
            f32s: &'static [f32],
            epoch: usize,
            has_opt: bool,
        }
        let cases = [
            Case {
                // v1: no optimizer/shard fields at all
                name: "v1-minimal",
                header: "{\"magic\":\"prelora-ckpt-v1\",\"epoch\":3,\"base_len\":2,\
                         \"lora_len\":0,\"adapter_cfg_len\":0,\"ranks\":null}",
                f32s: &[1.5, -2.0],
                epoch: 3,
                has_opt: false,
            },
            Case {
                // v2 without optimizer state (a frozen-base save)
                name: "v2-no-opt",
                header: "{\"magic\":\"prelora-ckpt-v2\",\"epoch\":5,\"base_len\":2,\
                         \"lora_len\":0,\"adapter_cfg_len\":0,\"ranks\":null,\
                         \"zero_shards\":2,\"opt_base\":null,\"opt_lora\":null}",
                f32s: &[0.5, 0.25],
                epoch: 5,
                has_opt: false,
            },
            Case {
                // v2 with gathered SGD state (1 buffer of base_len)
                name: "v2-with-opt",
                header: "{\"magic\":\"prelora-ckpt-v2\",\"epoch\":8,\"base_len\":2,\
                         \"lora_len\":0,\"adapter_cfg_len\":0,\"ranks\":null,\
                         \"zero_shards\":1,\"zero_stage\":2,\
                         \"opt_base\":{\"kind\":\"sgd\",\"steps\":4,\"bufs\":1},\
                         \"opt_lora\":null}",
                f32s: &[0.5, 0.25, 0.125, -0.125],
                epoch: 8,
                has_opt: true,
            },
        ];
        for case in cases {
            let p = tmp(case.name);
            let mut bytes = Vec::new();
            bytes.extend_from_slice(case.header.as_bytes());
            bytes.push(b'\n');
            for x in case.f32s {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
            std::fs::write(&p, &bytes).unwrap();
            let back = Checkpoint::load(&p)
                .unwrap_or_else(|e| panic!("{} must still load: {e:#}", case.name));
            assert_eq!(back.epoch, case.epoch, "{}", case.name);
            assert_eq!(back.base, case.f32s[..2], "{}", case.name);
            assert_eq!(back.opt_base.is_some(), case.has_opt, "{}", case.name);
            assert!(back.trajectory.is_none(), "{}: pre-v3 files have no trajectory", case.name);
            if case.name == "v1-minimal" {
                assert_eq!(back.zero_shards, 1);
                assert_eq!(back.stage, ZeroStage::Zero1, "pre-stage files read as stage 1");
            }
            std::fs::remove_file(p).unwrap();
        }
    }

    #[test]
    fn rejects_truncated_payload() {
        let c = full_ckpt();
        let p = tmp("trunc.ckpt");
        c.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn rejects_trailing_bytes() {
        let c = full_ckpt();
        let p = tmp("oversize.ckpt");
        c.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.extend_from_slice(&[0u8; 3]);
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn rejects_corrupted_payload_via_checksum() {
        let c = traj_ckpt();
        let p = tmp("corrupt.ckpt");
        c.save(&p).unwrap();
        let clean = std::fs::read(&p).unwrap();
        let payload_start = clean.iter().position(|&b| b == b'\n').unwrap() + 1;
        // flip one bit in the middle of the f32 payload: without the crc
        // this would silently restore a wrong parameter
        let mut bytes = clean.clone();
        bytes[payload_start + 9] ^= 0x10;
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn rejects_corrupted_header_via_checksum() {
        // the insidious header case: change one hex digit of a bit-exact
        // stats float — the JSON still parses, every length still lines
        // up, and without the header-covering crc the restore would
        // silently carry a wrong loss. The checksum spans the canonical
        // header, so this must be a loud error.
        let c = traj_ckpt();
        let p = tmp("corrupt_header.ckpt");
        c.save(&p).unwrap();
        let clean = std::fs::read(&p).unwrap();
        let newline = clean.iter().position(|&b| b == b'\n').unwrap();
        let header = std::str::from_utf8(&clean[..newline]).unwrap();
        // locate a train_loss hex field and flip a digit inside it
        let at = header.find("\"train_loss\":\"").unwrap() + "\"train_loss\":\"".len();
        let mut bytes = clean.clone();
        bytes[at] = if bytes[at] == b'0' { b'1' } else { b'0' };
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("checksum"), "header corruption must be detected: {err}");
        std::fs::remove_file(p).unwrap();
    }

    /// Random fuzz positions over a v3 file image: byte index and bit to
    /// flip, truncation length, trailing-garbage length.
    #[derive(Debug, Clone)]
    struct FuzzCase {
        flip_at: usize,
        flip_bit: u8,
        keep: usize,
        extra: usize,
    }

    impl Arbitrary for FuzzCase {
        fn generate(rng: &mut crate::tensor::Pcg64) -> Self {
            FuzzCase {
                flip_at: rng.next_below(1 << 16),
                flip_bit: rng.next_below(8) as u8,
                keep: rng.next_below(1 << 16),
                extra: 1 + rng.next_below(16),
            }
        }

        fn shrink(&self) -> Vec<Self> {
            let mut out = Vec::new();
            if self.flip_at > 0 {
                let mut c = self.clone();
                c.flip_at /= 2;
                out.push(c);
            }
            if self.keep > 0 {
                let mut c = self.clone();
                c.keep /= 2;
                out.push(c);
            }
            out
        }
    }

    #[test]
    fn prop_v3_rejects_truncation_trailing_and_corruption_anywhere() {
        let p = tmp("fuzz.ckpt");
        traj_ckpt().save(&p).unwrap();
        let clean = std::fs::read(&p).unwrap();
        let total = clean.len();
        check::<FuzzCase, _>(707, 200, |case| {
            // truncation anywhere strictly inside the file must fail
            let keep = case.keep % total;
            std::fs::write(&p, &clean[..keep]).unwrap();
            if Checkpoint::load(&p).is_ok() {
                return false;
            }
            // trailing bytes must fail
            let mut longer = clean.clone();
            longer.extend(std::iter::repeat(0xAB_u8).take(case.extra));
            std::fs::write(&p, &longer).unwrap();
            if Checkpoint::load(&p).is_ok() {
                return false;
            }
            // single-bit corruption anywhere in the file — header bytes
            // included — must fail: either the JSON/length validation
            // breaks, or the file checksum (computed over the canonical
            // header + payload) mismatches
            let at = case.flip_at % total;
            let mut corrupt = clean.clone();
            corrupt[at] ^= 1 << case.flip_bit;
            std::fs::write(&p, &corrupt).unwrap();
            Checkpoint::load(&p).is_err()
        });
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn save_is_atomic_and_leaves_no_temp_file() {
        let dir = std::env::temp_dir().join(format!("prelora_atomic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("model.ckpt");
        // overwriting an existing checkpoint goes through the temp file too
        full_ckpt().save(&p).unwrap();
        full_ckpt().save(&p).unwrap();
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["model.ckpt".to_string()], "stray files: {names:?}");
        assert!(Checkpoint::load(&p).is_ok());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn save_rejects_ungathered_optimizer_state() {
        let mut c = full_ckpt();
        c.opt_base = Some(OptState {
            kind: OptimizerKind::AdamW,
            t: 1,
            bufs: vec![vec![0.0; 2], vec![0.0; 2]], // base is 3 long
        });
        let p = tmp("badopt.ckpt");
        assert!(c.save(&p).is_err(), "shard-length state must be rejected");
        let _ = std::fs::remove_file(p);
    }
}
