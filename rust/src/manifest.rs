//! Artifact manifest: the L2↔L3 contract emitted by `python/compile/aot.py`.
//!
//! The manifest is the *single source of truth* for how the flat f32
//! parameter vectors are laid out (tensor offsets/shapes/modules/layers),
//! which adapters exist, and what each HLO artifact's input/output
//! signature is. Everything the coordinator does — optimizer masking,
//! weight-norm telemetry, rank assignment, adapter_cfg construction —
//! is driven by these tables, so the Rust side never hard-codes model
//! architecture.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, ensure, Context, Result};

use crate::util::json::Json;

/// The paper's target-module set alpha (Section 4.1) in canonical order.
pub const ADAPTED_MODULES: [&str; 5] = ["query", "key", "value", "output", "dense"];

/// One tensor inside a flat parameter vector.
#[derive(Debug, Clone)]
pub struct TensorEntry {
    pub name: String,
    pub offset: usize,
    pub size: usize,
    pub shape: Vec<usize>,
    /// Module taxonomy: query|key|value|output|dense|mlp_out|ln|embed|head|lora_a|lora_b
    pub module: String,
    /// Transformer block index, or -1 for global tensors (embeddings, head).
    pub layer: i32,
}

impl TensorEntry {
    /// Whether this is a weight *matrix* tracked by the paper's weight-norm
    /// telemetry (biases / layernorm vectors are excluded).
    pub fn is_weight_matrix(&self) -> bool {
        self.shape.len() == 2
    }
}

/// A LoRA adapter (A/B pair) attached to one base matrix.
#[derive(Debug, Clone)]
pub struct AdapterEntry {
    pub name: String,
    pub layer: i32,
    pub module: String,
    pub in_dim: usize,
    pub out_dim: usize,
    pub a_offset: usize,
    pub a_size: usize,
    pub b_offset: usize,
    pub b_size: usize,
    /// Offset of this adapter's `[mask(r_max) ++ scale]` block in adapter_cfg.
    pub cfg_offset: usize,
}

impl AdapterEntry {
    /// Trainable parameters when this adapter is assigned rank `r`.
    pub fn trainable_at_rank(&self, r: usize) -> usize {
        r * (self.in_dim + self.out_dim)
    }
}

#[derive(Debug, Clone)]
pub struct SectionEntry {
    pub size: usize,
    pub tensors: Vec<TensorEntry>,
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

/// Scaled model hyper-parameters (mirrors `python/compile/configs.py`).
#[derive(Debug, Clone)]
pub struct ModelDims {
    pub image_size: usize,
    pub patch_size: usize,
    pub in_channels: usize,
    pub hidden_dim: usize,
    pub depth: usize,
    pub num_heads: usize,
    pub mlp_dim: usize,
    pub num_classes: usize,
    pub batch_size: usize,
    pub tokens: usize,
    pub r_min: usize,
    pub r_max: usize,
    pub lora_alpha: f64,
    pub rank_buckets: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub schema_version: u32,
    pub model: String,
    pub backend: String,
    pub seed: u64,
    pub config: ModelDims,
    pub base: SectionEntry,
    pub lora: SectionEntry,
    pub adapters: Vec<AdapterEntry>,
    pub adapter_cfg_size: usize,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
    /// Directory the manifest was loaded from (not serialized).
    pub dir: PathBuf,
}


impl TensorEntry {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            name: v.req("name")?.as_str()?.to_string(),
            offset: v.req("offset")?.as_usize()?,
            size: v.req("size")?.as_usize()?,
            shape: v
                .req("shape")?
                .as_arr()?
                .iter()
                .map(|x| x.as_usize())
                .collect::<Result<_>>()?,
            module: v.req("module")?.as_str()?.to_string(),
            layer: v.req("layer")?.as_i64()? as i32,
        })
    }
}

impl AdapterEntry {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            name: v.req("name")?.as_str()?.to_string(),
            layer: v.req("layer")?.as_i64()? as i32,
            module: v.req("module")?.as_str()?.to_string(),
            in_dim: v.req("in_dim")?.as_usize()?,
            out_dim: v.req("out_dim")?.as_usize()?,
            a_offset: v.req("a_offset")?.as_usize()?,
            a_size: v.req("a_size")?.as_usize()?,
            b_offset: v.req("b_offset")?.as_usize()?,
            b_size: v.req("b_size")?.as_usize()?,
            cfg_offset: v.req("cfg_offset")?.as_usize()?,
        })
    }
}

impl SectionEntry {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            size: v.req("size")?.as_usize()?,
            tensors: v
                .req("tensors")?
                .as_arr()?
                .iter()
                .map(TensorEntry::from_json)
                .collect::<Result<_>>()?,
        })
    }
}

impl ArtifactEntry {
    fn from_json(v: &Json) -> Result<Self> {
        let strs = |key: &str| -> Result<Vec<String>> {
            v.req(key)?
                .as_arr()?
                .iter()
                .map(|x| Ok(x.as_str()?.to_string()))
                .collect()
        };
        Ok(Self {
            file: v.req("file")?.as_str()?.to_string(),
            inputs: strs("inputs")?,
            outputs: strs("outputs")?,
        })
    }
}

impl ModelDims {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            image_size: v.req("image_size")?.as_usize()?,
            patch_size: v.req("patch_size")?.as_usize()?,
            in_channels: v.req("in_channels")?.as_usize()?,
            hidden_dim: v.req("hidden_dim")?.as_usize()?,
            depth: v.req("depth")?.as_usize()?,
            num_heads: v.req("num_heads")?.as_usize()?,
            mlp_dim: v.req("mlp_dim")?.as_usize()?,
            num_classes: v.req("num_classes")?.as_usize()?,
            batch_size: v.req("batch_size")?.as_usize()?,
            tokens: v.req("tokens")?.as_usize()?,
            r_min: v.req("r_min")?.as_usize()?,
            r_max: v.req("r_max")?.as_usize()?,
            lora_alpha: v.req("lora_alpha")?.as_f64()?,
            rank_buckets: v
                .req("rank_buckets")?
                .as_arr()?
                .iter()
                .map(|x| x.as_usize())
                .collect::<Result<_>>()?,
        })
    }
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let doc = Json::parse(&text).context("parsing manifest.json")?;
        let mut m = Self::from_json(&doc).context("decoding manifest.json")?;
        m.dir = dir.to_path_buf();
        m.validate()?;
        Ok(m)
    }

    /// Decode from a parsed JSON document (see `python/compile/aot.py`).
    pub fn from_json(doc: &Json) -> Result<Self> {
        let mut artifacts = BTreeMap::new();
        for (k, v) in doc.req("artifacts")?.as_obj()? {
            artifacts.insert(k.clone(), ArtifactEntry::from_json(v)?);
        }
        Ok(Self {
            schema_version: doc.req("schema_version")?.as_usize()? as u32,
            model: doc.req("model")?.as_str()?.to_string(),
            backend: doc.req("backend")?.as_str()?.to_string(),
            seed: doc.req("seed")?.as_i64()? as u64,
            config: ModelDims::from_json(doc.req("config")?)?,
            base: SectionEntry::from_json(doc.req("base")?)?,
            lora: SectionEntry::from_json(doc.req("lora")?)?,
            adapters: doc
                .req("adapters")?
                .as_arr()?
                .iter()
                .map(AdapterEntry::from_json)
                .collect::<Result<_>>()?,
            adapter_cfg_size: doc.req("adapter_cfg_size")?.as_usize()?,
            artifacts,
            dir: PathBuf::new(),
        })
    }

    /// Structural invariants: contiguous offsets, adapter table consistent,
    /// all expected artifacts present.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.schema_version == 1, "unsupported manifest schema");
        for (name, sec) in [("base", &self.base), ("lora", &self.lora)] {
            let mut off = 0;
            for t in &sec.tensors {
                ensure!(t.offset == off, "{name}: tensor {} not contiguous", t.name);
                ensure!(
                    t.size == t.shape.iter().product::<usize>(),
                    "{name}: {} size/shape mismatch",
                    t.name
                );
                off += t.size;
            }
            ensure!(off == sec.size, "{name}: section size mismatch");
        }
        let r = self.config.r_max;
        for (i, a) in self.adapters.iter().enumerate() {
            ensure!(a.a_size == a.in_dim * r, "adapter {}: a_size", a.name);
            ensure!(a.b_size == r * a.out_dim, "adapter {}: b_size", a.name);
            ensure!(a.b_offset == a.a_offset + a.a_size, "adapter {}: layout", a.name);
            ensure!(a.cfg_offset == i * (r + 1), "adapter {}: cfg offset", a.name);
            ensure!(
                ADAPTED_MODULES.contains(&a.module.as_str()),
                "adapter {}: module {} not in alpha",
                a.name,
                a.module
            );
        }
        ensure!(
            self.adapter_cfg_size == self.adapters.len() * (r + 1),
            "adapter_cfg size mismatch"
        );
        ensure!(
            self.adapters.len() == self.config.depth * ADAPTED_MODULES.len(),
            "expected depth * |alpha| adapters"
        );
        for key in ["full_grads", "warmup_grads", "lora_grads", "eval_full", "eval_lora"] {
            ensure!(self.artifacts.contains_key(key), "missing artifact {key}");
        }
        Ok(())
    }

    /// Path to one artifact's HLO text file.
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let a = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        Ok(self.dir.join(&a.file))
    }

    /// Load the initial base parameters dumped by aot.py.
    pub fn load_init_base(&self) -> Result<Vec<f32>> {
        let path = self.dir.join("init_base.f32");
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        ensure!(
            bytes.len() == self.base.size * 4,
            "init_base.f32: expected {} f32, got {} bytes",
            self.base.size,
            bytes.len()
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Weight matrices of one target module across layers, in layer order.
    pub fn module_weight_tensors(&self, module: &str) -> Vec<&TensorEntry> {
        let mut v: Vec<&TensorEntry> = self
            .base
            .tensors
            .iter()
            .filter(|t| t.module == module && t.is_weight_matrix() && t.layer >= 0)
            .collect();
        v.sort_by_key(|t| t.layer);
        v
    }

    /// All per-layer modules that have weight matrices (telemetry set:
    /// superset of alpha — includes mlp_out for the Fig. 1-style plots).
    pub fn telemetry_modules(&self) -> Vec<String> {
        let mut seen = Vec::new();
        for t in &self.base.tensors {
            if t.layer >= 0 && t.is_weight_matrix() && !seen.contains(&t.module) {
                seen.push(t.module.clone());
            }
        }
        seen
    }

    /// Total trainable parameters in the full-training phase.
    pub fn full_trainable(&self) -> usize {
        self.base.size
    }

    /// Trainable parameters post-switch for a given per-adapter rank list
    /// (manifest adapter order).
    pub fn lora_trainable(&self, ranks: &[usize]) -> usize {
        self.adapters
            .iter()
            .zip(ranks)
            .map(|(a, &r)| a.trainable_at_rank(r))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn micro_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/vit-micro")
    }

    #[test]
    fn load_and_validate_micro() {
        let m = Manifest::load(micro_dir()).expect("run `make artifacts` first");
        assert_eq!(m.model, "vit-micro");
        assert_eq!(m.config.depth, 2);
        assert_eq!(m.adapters.len(), 2 * ADAPTED_MODULES.len());
        assert!(m.base.size > 10_000);
    }

    #[test]
    fn init_base_loads_with_ln_ones() {
        let m = Manifest::load(micro_dir()).unwrap();
        let init = m.load_init_base().unwrap();
        assert_eq!(init.len(), m.base.size);
        let ln = m
            .base
            .tensors
            .iter()
            .find(|t| t.name == "layer0.ln1.scale")
            .unwrap();
        assert!(init[ln.offset..ln.offset + ln.size].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn module_weight_tensors_ordered_by_layer() {
        let m = Manifest::load(micro_dir()).unwrap();
        let q = m.module_weight_tensors("query");
        assert_eq!(q.len(), m.config.depth);
        for (l, t) in q.iter().enumerate() {
            assert_eq!(t.layer as usize, l);
            assert_eq!(t.shape, vec![m.config.hidden_dim, m.config.hidden_dim]);
        }
    }

    #[test]
    fn trainable_counts() {
        let m = Manifest::load(micro_dir()).unwrap();
        let ranks = vec![1usize; m.adapters.len()];
        let lo = m.lora_trainable(&ranks);
        assert!(lo > 0 && lo < m.full_trainable());
        let hi = m.lora_trainable(&vec![m.config.r_max; m.adapters.len()]);
        assert!(hi > lo);
    }

    #[test]
    fn telemetry_modules_cover_alpha() {
        let m = Manifest::load(micro_dir()).unwrap();
        let mods = m.telemetry_modules();
        for a in ADAPTED_MODULES {
            assert!(mods.iter().any(|s| s == a), "missing {a}");
        }
        assert!(mods.iter().any(|s| s == "mlp_out"));
    }
}
