//! Streaming gradient-norm statistics for one epoch of steps.
//!
//! The epoch loop used to report only the *last* step's *post-clip* norm,
//! which both discards the other steps and saturates at the clip
//! threshold — Fig. 2-style telemetry read as "gradients stopped growing"
//! the moment clipping engaged. [`GradNormStats`] accumulates the
//! pre-clip norm of every step and exposes the mean/max plus how often the
//! clip fired.

/// Mean/max accumulator over per-step pre-clip gradient norms.
#[derive(Debug, Default, Clone, Copy)]
pub struct GradNormStats {
    sum: f64,
    max: f64,
    steps: usize,
    clipped_steps: usize,
}

impl GradNormStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one step's pre-clip norm and whether clipping rescaled it.
    pub fn record(&mut self, pre_clip: f64, clipped: bool) {
        self.sum += pre_clip;
        self.max = self.max.max(pre_clip);
        self.steps += 1;
        if clipped {
            self.clipped_steps += 1;
        }
    }

    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Mean pre-clip norm over the recorded steps (0.0 before any step).
    pub fn mean(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.sum / self.steps as f64
        }
    }

    /// Largest pre-clip norm seen.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Fraction of steps where the clip rescaled the gradient.
    pub fn clipped_frac(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.clipped_steps as f64 / self.steps as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_mean_max_and_clip_fraction() {
        let mut s = GradNormStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.clipped_frac(), 0.0);
        s.record(1.0, false);
        s.record(3.0, true);
        s.record(2.0, true);
        assert_eq!(s.steps(), 3);
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert_eq!(s.max(), 3.0);
        assert!((s.clipped_frac() - 2.0 / 3.0).abs() < 1e-12);
    }
}
