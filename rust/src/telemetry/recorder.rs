//! CSV series recorder: every figure harness writes its data through this.
//!
//! Files are plain CSV with a header row; the figure binaries document the
//! column meanings so external plotting (the paper's matplotlib scripts)
//! can consume them directly.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// A buffered CSV writer with a fixed schema.
pub struct CsvRecorder {
    path: PathBuf,
    writer: BufWriter<File>,
    columns: usize,
    rows: usize,
}

impl CsvRecorder {
    /// Create `<dir>/<name>.csv` with the given header.
    pub fn create(dir: impl AsRef<Path>, name: &str, header: &[&str]) -> Result<Self> {
        std::fs::create_dir_all(dir.as_ref())?;
        let path = dir.as_ref().join(format!("{name}.csv"));
        let file = File::create(&path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut writer = BufWriter::new(file);
        writeln!(writer, "{}", header.join(","))?;
        Ok(Self { path, writer, columns: header.len(), rows: 0 })
    }

    /// Append one row of f64 values (formatted with enough precision for
    /// downstream plotting).
    pub fn row(&mut self, values: &[f64]) -> Result<()> {
        assert_eq!(values.len(), self.columns, "row width mismatch");
        let mut line = String::with_capacity(values.len() * 12);
        for (i, v) in values.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            line.push_str(&format!("{v:.6}"));
        }
        writeln!(self.writer, "{line}")?;
        self.rows += 1;
        Ok(())
    }

    /// Append a row with a leading string tag (e.g. a run label).
    pub fn tagged_row(&mut self, tag: &str, values: &[f64]) -> Result<()> {
        assert_eq!(values.len() + 1, self.columns, "row width mismatch");
        let mut line = String::from(tag);
        for v in values {
            line.push(',');
            line.push_str(&format!("{v:.6}"));
        }
        writeln!(self.writer, "{line}")?;
        self.rows += 1;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.writer.flush()?;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
}

impl Drop for CsvRecorder {
    fn drop(&mut self) {
        let _ = self.writer.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join(format!("prelora_csv_{}", std::process::id()));
        let mut rec = CsvRecorder::create(&dir, "test", &["epoch", "loss"]).unwrap();
        rec.row(&[0.0, 2.5]).unwrap();
        rec.row(&[1.0, 2.25]).unwrap();
        rec.flush().unwrap();
        let text = std::fs::read_to_string(rec.path()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "epoch,loss");
        assert!(lines[1].starts_with("0.000000,2.5"));
        assert_eq!(rec.rows(), 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn tagged_rows() {
        let dir = std::env::temp_dir().join(format!("prelora_csv_t_{}", std::process::id()));
        let mut rec = CsvRecorder::create(&dir, "tagged", &["run", "epoch", "v"]).unwrap();
        rec.tagged_row("exp1", &[1.0, 2.0]).unwrap();
        rec.flush().unwrap();
        let text = std::fs::read_to_string(rec.path()).unwrap();
        assert!(text.contains("exp1,1.000000,2.000000"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let dir = std::env::temp_dir().join(format!("prelora_csv_w_{}", std::process::id()));
        let mut rec = CsvRecorder::create(&dir, "w", &["a", "b"]).unwrap();
        let _ = rec.row(&[1.0]);
    }
}
