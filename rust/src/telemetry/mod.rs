//! Training telemetry: the measurements the PreLoRA controller consumes.
//!
//! The paper's Algorithm 1 observes (a) per-module weight norms averaged
//! across layers and (b) training loss, both aggregated over windows of
//! `m` epochs; Algorithm 2 additionally needs the per-layer norm deltas
//! between the final two windows. [`NormHistory`] owns those series;
//! [`GradNormStats`] accumulates per-step pre-clip gradient norms inside
//! an epoch (fed by the pipeline's update stage — the norm it records is
//! the same ordered-fold global norm for replicated and ZeRO-sharded
//! gradient layouts, see `dp::sq_sum_in_order`, so the series is
//! layout-independent by construction); [`recorder`] persists everything
//! as CSV for the figure harnesses.

mod grad;
mod norms;
pub mod recorder;

pub use grad::GradNormStats;
pub use norms::{NormHistory, NormSnapshot};
