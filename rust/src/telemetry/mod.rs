//! Training telemetry: the measurements the PreLoRA controller consumes.
//!
//! The paper's Algorithm 1 observes (a) per-module weight norms averaged
//! across layers and (b) training loss, both aggregated over windows of
//! `m` epochs; Algorithm 2 additionally needs the per-layer norm deltas
//! between the final two windows. [`NormHistory`] owns those series;
//! [`recorder`] persists everything as CSV for the figure harnesses.

mod norms;
pub mod recorder;

pub use norms::{NormHistory, NormSnapshot};
