//! Per-(module, layer) weight-norm history with windowed aggregation.

use std::collections::BTreeMap;

use crate::manifest::Manifest;
use crate::tensor::tensor_norm;

/// Frobenius norms of every tracked weight matrix at one epoch, organized
/// as module -> per-layer vector (layer order).
#[derive(Debug, Clone, PartialEq)]
pub struct NormSnapshot {
    pub epoch: usize,
    pub by_module: BTreeMap<String, Vec<f64>>,
}

impl NormSnapshot {
    /// Measure from the current base parameter vector.
    pub fn measure(manifest: &Manifest, epoch: usize, base: &[f32]) -> Self {
        let mut by_module = BTreeMap::new();
        for module in manifest.telemetry_modules() {
            let norms: Vec<f64> = manifest
                .module_weight_tensors(&module)
                .iter()
                .map(|t| tensor_norm(base, t))
                .collect();
            by_module.insert(module, norms);
        }
        Self { epoch, by_module }
    }

    /// Module-level norm: mean across layers (the paper's W_t^a).
    pub fn module_mean(&self, module: &str) -> Option<f64> {
        let v = self.by_module.get(module)?;
        Some(v.iter().sum::<f64>() / v.len() as f64)
    }
}

/// Epoch-indexed history of norm snapshots + training losses.
#[derive(Debug, Default, Clone)]
pub struct NormHistory {
    snapshots: Vec<NormSnapshot>,
    losses: Vec<f64>,
}

impl NormHistory {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild a history from serialized parts (the v3 checkpoint's
    /// trajectory block). Validates the invariants `push` maintains:
    /// one loss per snapshot and contiguous epoch numbering from 0 —
    /// a resumed controller reading a history with holes would compute
    /// windows over the wrong epochs.
    pub fn from_parts(snapshots: Vec<NormSnapshot>, losses: Vec<f64>) -> anyhow::Result<Self> {
        anyhow::ensure!(
            snapshots.len() == losses.len(),
            "history has {} snapshots but {} losses",
            snapshots.len(),
            losses.len()
        );
        for (i, s) in snapshots.iter().enumerate() {
            anyhow::ensure!(
                s.epoch == i,
                "history snapshot {i} carries epoch {} (must be contiguous from 0)",
                s.epoch
            );
        }
        Ok(Self { snapshots, losses })
    }

    /// All snapshots in epoch order (serialized by checkpoints).
    pub fn snapshots(&self) -> &[NormSnapshot] {
        &self.snapshots
    }

    pub fn push(&mut self, snapshot: NormSnapshot, epoch_loss: f64) {
        debug_assert_eq!(snapshot.epoch, self.snapshots.len());
        self.snapshots.push(snapshot);
        self.losses.push(epoch_loss);
    }

    pub fn epochs(&self) -> usize {
        self.snapshots.len()
    }

    pub fn losses(&self) -> &[f64] {
        &self.losses
    }

    pub fn snapshot(&self, epoch: usize) -> &NormSnapshot {
        &self.snapshots[epoch]
    }

    pub fn last(&self) -> Option<&NormSnapshot> {
        self.snapshots.last()
    }

    /// Mean loss over the trailing window `[end-m, end)` of epochs.
    pub fn window_loss(&self, end: usize, m: usize) -> f64 {
        let s = &self.losses[end - m..end];
        s.iter().sum::<f64>() / m as f64
    }

    /// Module-level windowed weight norm W_t^a: per-layer norms averaged
    /// across layers, then across the window's epochs.
    ///
    /// A module missing from any snapshot (misspelled or untracked by the
    /// manifest) returns NaN rather than silently contributing 0 — a zero
    /// norm would make the tau test trivially pass, so the poison value
    /// guarantees downstream comparisons read as *not* converged.
    /// Configured module lists are additionally validated against the
    /// manifest at startup (`PreLoraController::new`).
    pub fn window_module_norm(&self, module: &str, end: usize, m: usize) -> f64 {
        let mut acc = 0.0;
        for snap in &self.snapshots[end - m..end] {
            match snap.module_mean(module) {
                Some(v) => acc += v,
                None => return f64::NAN,
            }
        }
        acc / m as f64
    }

    /// Per-layer windowed norms for one module (Algorithm 2's inputs).
    pub fn window_layer_norms(&self, module: &str, end: usize, m: usize) -> Vec<f64> {
        let snaps = &self.snapshots[end - m..end];
        let layers = snaps[0].by_module[module].len();
        let mut out = vec![0.0; layers];
        for snap in snaps {
            for (o, v) in out.iter_mut().zip(&snap.by_module[module]) {
                *o += v;
            }
        }
        for o in &mut out {
            *o /= m as f64;
        }
        out
    }

    /// Percentage change of per-layer norms between the last two complete
    /// windows ending at `end` — the paper's DeltaW_k^{a_l} used for rank
    /// assignment. Returns None with fewer than 2m epochs of history.
    pub fn last_two_window_layer_deltas(
        &self,
        module: &str,
        end: usize,
        m: usize,
    ) -> Option<Vec<f64>> {
        if end < 2 * m {
            return None;
        }
        let prev = self.window_layer_norms(module, end - m, m);
        let cur = self.window_layer_norms(module, end, m);
        Some(
            prev.iter()
                .zip(&cur)
                .map(|(&p, &c)| if p == 0.0 { 0.0 } else { (c - p) / p * 100.0 })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(epoch: usize, q: &[f64], d: &[f64]) -> NormSnapshot {
        let mut by_module = BTreeMap::new();
        by_module.insert("query".to_string(), q.to_vec());
        by_module.insert("dense".to_string(), d.to_vec());
        NormSnapshot { epoch, by_module }
    }

    fn history(n: usize) -> NormHistory {
        let mut h = NormHistory::new();
        for e in 0..n {
            // query norms grow then flatten; dense stays flat
            let g = 10.0 + (e as f64).min(4.0);
            h.push(snap(e, &[g, g + 1.0], &[5.0, 5.0]), 3.0 - 0.1 * e as f64);
        }
        h
    }

    #[test]
    fn module_mean_averages_layers() {
        let s = snap(0, &[1.0, 3.0], &[2.0, 2.0]);
        assert_eq!(s.module_mean("query"), Some(2.0));
        assert_eq!(s.module_mean("nope"), None);
    }

    #[test]
    fn window_aggregates() {
        let h = history(6);
        // window over epochs 3..6 of dense = 5.0
        assert_eq!(h.window_module_norm("dense", 6, 3), 5.0);
        let loss = h.window_loss(6, 3);
        assert!((loss - (2.7 + 2.6 + 2.5) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn untracked_module_window_norm_is_nan_not_zero() {
        // regression: this used to read 0.0, which made the convergence
        // test's |dW| = 0 and trivially passed tau for a misspelled module
        let h = history(6);
        let w = h.window_module_norm("qurey", 6, 3);
        assert!(w.is_nan(), "missing module must poison the window, got {w}");
    }

    #[test]
    fn from_parts_roundtrips_and_validates() {
        let h = history(6);
        let back =
            NormHistory::from_parts(h.snapshots().to_vec(), h.losses().to_vec()).unwrap();
        assert_eq!(back.epochs(), 6);
        assert_eq!(back.losses(), h.losses());
        assert_eq!(back.snapshot(3), h.snapshot(3));
        // mismatched lengths rejected
        assert!(NormHistory::from_parts(h.snapshots().to_vec(), vec![1.0]).is_err());
        // non-contiguous epochs rejected
        let mut snaps = h.snapshots().to_vec();
        snaps[2].epoch = 7;
        assert!(NormHistory::from_parts(snaps, h.losses().to_vec()).is_err());
    }

    #[test]
    fn layer_deltas_between_windows() {
        let h = history(8);
        let deltas = h.last_two_window_layer_deltas("dense", 8, 3).unwrap();
        assert_eq!(deltas, vec![0.0, 0.0]); // dense never moves
        let q = h.last_two_window_layer_deltas("query", 8, 3).unwrap();
        assert_eq!(q.len(), 2);
        // query flattens after epoch 4: windows 2..5 vs 5..8 differ slightly
        assert!(q[0].abs() < 10.0);
        assert!(h.last_two_window_layer_deltas("query", 3, 3).is_none());
    }
}
