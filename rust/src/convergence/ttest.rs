//! Welch t-test convergence baseline (Dahal et al., HPT).
//!
//! HPT runs the full model and a LoRA copy in parallel and t-tests their
//! losses; the paper's related-work section criticizes the dual-model
//! memory cost. We implement the statistical core as a *single-model*
//! variant — Welch's t-test between the losses of two consecutive epoch
//! windows; "converged" when the windows are statistically
//! indistinguishable (p >= alpha). Used by the strategy ablation bench to
//! quantify how the paper's thresholded test compares.

use super::{ConvergenceStrategy, windowed::ConvergenceReport};
use crate::telemetry::NormHistory;

pub struct WelchTTest {
    k: usize,
    m: usize,
    alpha: f64,
}

impl WelchTTest {
    pub fn new(k: usize, m: usize, alpha: f64) -> Self {
        assert!(k >= 2 && m >= 2, "t-test needs windows of >= 2 samples");
        Self { k, m, alpha }
    }
}

fn mean_var(xs: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, var)
}

/// Two-sided Welch t-test p-value.
pub fn welch_p_value(a: &[f64], b: &[f64]) -> f64 {
    let (ma, va) = mean_var(a);
    let (mb, vb) = mean_var(b);
    let (na, nb) = (a.len() as f64, b.len() as f64);
    let se2 = va / na + vb / nb;
    if se2 <= 0.0 {
        // identical constant windows: indistinguishable
        return if (ma - mb).abs() < 1e-12 { 1.0 } else { 0.0 };
    }
    let t = (ma - mb) / se2.sqrt();
    // Welch-Satterthwaite degrees of freedom
    let df = se2 * se2
        / ((va / na) * (va / na) / (na - 1.0) + (vb / nb) * (vb / nb) / (nb - 1.0));
    2.0 * (1.0 - student_t_cdf(t.abs(), df))
}

/// Student-t CDF via the regularized incomplete beta function.
fn student_t_cdf(t: f64, df: f64) -> f64 {
    let x = df / (df + t * t);
    1.0 - 0.5 * inc_beta(df / 2.0, 0.5, x)
}

/// Regularized incomplete beta I_x(a, b) by continued fraction (Lentz).
fn inc_beta(a: f64, b: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_beta = ln_gamma(a) + ln_gamma(b) - ln_gamma(a + b);
    let front = (a * x.ln() + b * (1.0 - x).ln() - ln_beta).exp();
    // symmetry for faster convergence
    if x > (a + 1.0) / (a + b + 2.0) {
        return 1.0 - inc_beta(b, a, 1.0 - x);
    }
    let mut f = 1.0f64;
    let mut c = 1.0f64;
    let mut d = 0.0f64;
    for i in 0..200 {
        let m = i / 2;
        let numerator = if i == 0 {
            1.0
        } else if i % 2 == 0 {
            (m as f64) * (b - m as f64) * x / ((a + 2.0 * m as f64 - 1.0) * (a + 2.0 * m as f64))
        } else {
            -((a + m as f64) * (a + b + m as f64) * x)
                / ((a + 2.0 * m as f64) * (a + 2.0 * m as f64 + 1.0))
        };
        d = 1.0 + numerator * d;
        if d.abs() < 1e-30 {
            d = 1e-30;
        }
        d = 1.0 / d;
        c = 1.0 + numerator / c;
        if c.abs() < 1e-30 {
            c = 1e-30;
        }
        let delta = c * d;
        f *= delta;
        if (delta - 1.0).abs() < 1e-12 {
            break;
        }
    }
    front * (f - 1.0) / a
}

/// Lanczos log-gamma.
fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut acc = G[0];
    for (i, &g) in G.iter().enumerate().skip(1) {
        acc += g / (x + i as f64);
    }
    let t = x + 7.5;
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + acc.ln()
}

impl ConvergenceStrategy for WelchTTest {
    fn check(&self, history: &NormHistory, end: usize) -> ConvergenceReport {
        if end < self.required_epochs() || history.epochs() < end {
            return ConvergenceReport::not_enough_history();
        }
        // compare every adjacent window pair among the last k windows
        let mut min_p = 1.0f64;
        let losses = history.losses();
        for t in 1..self.k {
            let b_end = end - (self.k - 1 - t) * self.m;
            let a_end = b_end - self.m;
            let a = &losses[a_end - self.m..a_end];
            let b = &losses[b_end - self.m..b_end];
            min_p = min_p.min(welch_p_value(a, b));
        }
        let converged = min_p >= self.alpha;
        ConvergenceReport {
            converged,
            max_weight_delta: 0.0,
            max_loss_delta: min_p, // repurposed: the minimum p-value
            fail_reason: if converged {
                None
            } else {
                Some(format!("welch p={min_p:.4} < alpha={:.3}", self.alpha))
            },
        }
    }

    fn required_epochs(&self) -> usize {
        self.k * self.m
    }

    fn name(&self) -> &'static str {
        "welch_ttest"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::NormSnapshot;
    use std::collections::BTreeMap;

    fn history(losses: &[f64]) -> NormHistory {
        let mut h = NormHistory::new();
        for (e, &l) in losses.iter().enumerate() {
            let mut by_module = BTreeMap::new();
            by_module.insert("query".into(), vec![1.0]);
            h.push(NormSnapshot { epoch: e, by_module }, l);
        }
        h
    }

    #[test]
    fn ln_gamma_known_values() {
        assert!((ln_gamma(1.0)).abs() < 1e-10);
        assert!((ln_gamma(5.0) - (24.0f64).ln()).abs() < 1e-9);
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-9);
    }

    #[test]
    fn p_value_high_for_same_distribution() {
        let a = [2.01, 1.99, 2.0, 2.02, 1.98];
        let b = [2.0, 2.01, 1.99, 2.0, 2.02];
        assert!(welch_p_value(&a, &b) > 0.3);
    }

    #[test]
    fn p_value_low_for_shifted_means() {
        let a = [3.0, 3.02, 2.98, 3.01, 2.99];
        let b = [2.0, 2.01, 1.99, 2.02, 1.98];
        assert!(welch_p_value(&a, &b) < 0.001);
    }

    #[test]
    fn converges_on_plateaued_loss() {
        let mut losses = vec![4.0, 3.6, 3.2, 2.9, 2.7, 2.55];
        losses.extend([2.0, 2.02, 1.98, 2.01, 1.99, 2.0, 2.01, 1.99, 2.0]);
        let s = WelchTTest::new(3, 3, 0.05);
        let r = s.check(&history(&losses), losses.len());
        assert!(r.converged, "{:?}", r.fail_reason);
    }

    #[test]
    fn keeps_training_on_steep_loss() {
        let losses: Vec<f64> = (0..12).map(|i| 4.0 - 0.25 * i as f64).collect();
        let s = WelchTTest::new(3, 3, 0.05);
        let r = s.check(&history(&losses), losses.len());
        assert!(!r.converged);
    }
}
