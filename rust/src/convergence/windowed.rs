//! Algorithm 1: the partial convergence test.
//!
//! For k consecutive windows of m epochs, the test passes iff for every
//! target module `a` and every adjacent window pair (t-1, t):
//!
//! ```text
//! |DeltaW_t^a| = |(W_t^a - W_{t-1}^a) / W_{t-1}^a| * 100  <= tau
//! |DeltaL_t|   = |(L_t - L_{t-1}) / L_{t-1}| * 100        <= zeta
//! ```
//!
//! where W_t^a is the module's weight norm averaged across layers and the
//! window's epochs, and L_t the window-mean training loss. Increasing
//! (k, m) and decreasing (tau, zeta) makes the criterion stricter
//! (Table 1's Exp1..Exp3).

use anyhow::Result;

use super::ConvergenceStrategy;
use crate::telemetry::NormHistory;
use crate::util::json::Json;

/// Outcome of one convergence check, with the evidence that produced it
/// (logged by the controller and surfaced in run summaries).
#[derive(Debug, Clone)]
pub struct ConvergenceReport {
    pub converged: bool,
    /// Largest |DeltaW| seen across modules/windows (percent).
    pub max_weight_delta: f64,
    /// Largest |DeltaL| seen across windows (percent).
    pub max_loss_delta: f64,
    /// Human-readable reason for the first failure, if any.
    pub fail_reason: Option<String>,
}

impl ConvergenceReport {
    pub fn not_enough_history() -> Self {
        Self {
            converged: false,
            max_weight_delta: f64::NAN,
            max_loss_delta: f64::NAN,
            fail_reason: Some("insufficient history".into()),
        }
    }

    /// Serialize for the v3 checkpoint's trajectory block. The deltas are
    /// legitimately NaN (insufficient history) or +inf (degenerate
    /// window), so they use the bit-exact f64 encoding.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("converged", Json::Bool(self.converged)),
            ("max_weight_delta", Json::from_f64_bits(self.max_weight_delta)),
            ("max_loss_delta", Json::from_f64_bits(self.max_loss_delta)),
            (
                "fail_reason",
                match &self.fail_reason {
                    Some(r) => Json::Str(r.clone()),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Parse a value written by [`to_json`](Self::to_json).
    pub fn from_json(v: &Json) -> Result<Self> {
        let fail_reason = match v.req("fail_reason")? {
            Json::Null => None,
            s => Some(s.as_str()?.to_string()),
        };
        Ok(Self {
            converged: v.req("converged")?.as_bool()?,
            max_weight_delta: v.req("max_weight_delta")?.as_f64_bits()?,
            max_loss_delta: v.req("max_loss_delta")?.as_f64_bits()?,
            fail_reason,
        })
    }
}

pub struct WindowedThreshold {
    k: usize,
    m: usize,
    tau: f64,
    zeta: f64,
    modules: Vec<String>,
}

impl WindowedThreshold {
    pub fn new(k: usize, m: usize, tau: f64, zeta: f64, modules: Vec<String>) -> Self {
        assert!(k >= 2 && m >= 1);
        Self { k, m, tau, zeta, modules }
    }

    /// Window-mean module norms W_t^a for t = 1..k ending at `end`.
    fn window_series(&self, history: &NormHistory, module: &str, end: usize) -> Vec<f64> {
        (0..self.k)
            .map(|t| {
                let w_end = end - (self.k - 1 - t) * self.m;
                history.window_module_norm(module, w_end, self.m)
            })
            .collect()
    }

    fn loss_series(&self, history: &NormHistory, end: usize) -> Vec<f64> {
        (0..self.k)
            .map(|t| {
                let w_end = end - (self.k - 1 - t) * self.m;
                history.window_loss(w_end, self.m)
            })
            .collect()
    }
}

/// Percentage change from `prev` to `cur`.
///
/// A window whose mean collapses to exactly zero (or is NaN-poisoned by an
/// untracked module) carries no convergence evidence, so the zero-prev
/// case reports an *infinite* delta — it must read as "not converged", not
/// as the 0% = "fully converged" it used to return, which could fire the
/// LoRA switch on a degenerate window.
fn pct_change(prev: f64, cur: f64) -> f64 {
    if prev == 0.0 {
        f64::INFINITY
    } else {
        (cur - prev) / prev * 100.0
    }
}

impl ConvergenceStrategy for WindowedThreshold {
    fn check(&self, history: &NormHistory, end: usize) -> ConvergenceReport {
        if end < self.required_epochs() || history.epochs() < end {
            return ConvergenceReport::not_enough_history();
        }
        let mut max_w: f64 = 0.0;
        let mut max_l: f64 = 0.0;
        let mut fail: Option<String> = None;

        // loss windows (module-independent, checked once). NaN deltas from
        // a poisoned window are checked explicitly: `NaN > thr` is false,
        // so a plain threshold comparison would silently pass them.
        let losses = self.loss_series(history, end);
        for t in 1..self.k {
            let dl = pct_change(losses[t - 1], losses[t]).abs();
            max_l = max_l.max(dl);
            if (dl.is_nan() || dl > self.zeta) && fail.is_none() {
                fail = Some(if dl.is_finite() {
                    format!("loss window {t}: |dL|={dl:.3}% > zeta={:.3}%", self.zeta)
                } else {
                    format!(
                        "loss window {t}: degenerate window (mean loss {} -> {}; zero or untracked evidence cannot certify convergence)",
                        losses[t - 1],
                        losses[t]
                    )
                });
            }
        }
        // per-module weight-norm windows
        for module in &self.modules {
            let series = self.window_series(history, module, end);
            for t in 1..self.k {
                let dw = pct_change(series[t - 1], series[t]).abs();
                max_w = max_w.max(dw);
                if (dw.is_nan() || dw > self.tau) && fail.is_none() {
                    fail = Some(if dw.is_finite() {
                        format!(
                            "module {module} window {t}: |dW|={dw:.3}% > tau={:.3}%",
                            self.tau
                        )
                    } else {
                        format!(
                            "module {module} window {t}: degenerate window (norm {} -> {}; zero or untracked evidence cannot certify convergence)",
                            series[t - 1],
                            series[t]
                        )
                    });
                }
            }
        }
        ConvergenceReport {
            converged: fail.is_none(),
            max_weight_delta: max_w,
            max_loss_delta: max_l,
            fail_reason: fail,
        }
    }

    fn required_epochs(&self) -> usize {
        self.k * self.m
    }

    fn name(&self) -> &'static str {
        "windowed_threshold"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::NormSnapshot;
    use std::collections::BTreeMap;

    /// Build a history whose module norm and loss follow given sequences.
    fn make_history(norms: &[f64], losses: &[f64]) -> NormHistory {
        let mut h = NormHistory::new();
        for (e, (&n, &l)) in norms.iter().zip(losses).enumerate() {
            let mut by_module = BTreeMap::new();
            by_module.insert("query".into(), vec![n, n]);
            h.push(NormSnapshot { epoch: e, by_module }, l);
        }
        h
    }

    fn strat(tau: f64, zeta: f64) -> WindowedThreshold {
        WindowedThreshold::new(3, 3, tau, zeta, vec!["query".into()])
    }

    #[test]
    fn report_json_roundtrips_bitwise_including_nan_and_inf() {
        let reports = [
            ConvergenceReport {
                converged: true,
                max_weight_delta: 0.123456789,
                max_loss_delta: 2.5,
                fail_reason: None,
            },
            ConvergenceReport {
                converged: false,
                max_weight_delta: f64::INFINITY,
                max_loss_delta: f64::NAN,
                fail_reason: Some("module query window 1: degenerate window".into()),
            },
            ConvergenceReport::not_enough_history(),
        ];
        for r in reports {
            let text = r.to_json().dump();
            let back = ConvergenceReport::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.converged, r.converged, "{text}");
            assert_eq!(back.max_weight_delta.to_bits(), r.max_weight_delta.to_bits());
            assert_eq!(back.max_loss_delta.to_bits(), r.max_loss_delta.to_bits());
            assert_eq!(back.fail_reason, r.fail_reason);
        }
    }

    #[test]
    fn passes_when_flat() {
        let h = make_history(&[10.0; 9], &[2.0; 9]);
        let r = strat(0.5, 2.5).check(&h, 9);
        assert!(r.converged, "{:?}", r.fail_reason);
        assert_eq!(r.max_weight_delta, 0.0);
        assert_eq!(r.max_loss_delta, 0.0);
    }

    #[test]
    fn fails_on_moving_weights() {
        // windows: [10,10,10] [11,11,11] [12,12,12] => dW = 10%, 9.1%
        let norms = [10., 10., 10., 11., 11., 11., 12., 12., 12.];
        let h = make_history(&norms, &[2.0; 9]);
        let r = strat(0.5, 2.5).check(&h, 9);
        assert!(!r.converged);
        assert!(r.max_weight_delta > 9.0);
        assert!(r.fail_reason.unwrap().contains("tau"));
    }

    #[test]
    fn fails_on_moving_loss() {
        let losses = [3.0, 3.0, 3.0, 2.5, 2.5, 2.5, 2.0, 2.0, 2.0];
        let h = make_history(&[10.0; 9], &losses);
        let r = strat(0.5, 2.5).check(&h, 9);
        assert!(!r.converged);
        assert!(r.fail_reason.unwrap().contains("zeta"));
    }

    #[test]
    fn relaxed_thresholds_pass_where_strict_fail() {
        // ~0.8% weight drift per window, ~3% loss drift
        let norms = [10.0, 10.0, 10.0, 10.08, 10.08, 10.08, 10.16, 10.16, 10.16];
        let losses = [2.0, 2.0, 2.0, 1.94, 1.94, 1.94, 1.88, 1.88, 1.88];
        let h = make_history(&norms, &losses);
        let relaxed = strat(1.0, 5.0).check(&h, 9); // Exp1
        let strict = strat(0.25, 1.0).check(&h, 9); // Exp3
        assert!(relaxed.converged, "{:?}", relaxed.fail_reason);
        assert!(!strict.converged);
    }

    #[test]
    fn zero_norm_window_is_not_converged() {
        // regression: a window whose norm collapses to exactly 0 used to
        // read as dW = 0% ("fully converged") and could fire the switch
        let h = make_history(&[0.0; 9], &[2.0; 9]);
        let r = strat(0.5, 2.5).check(&h, 9);
        assert!(!r.converged, "zero-norm windows must never certify convergence");
        assert!(r.max_weight_delta.is_infinite());
        let reason = r.fail_reason.unwrap();
        assert!(reason.contains("degenerate"), "{reason}");
    }

    #[test]
    fn zero_loss_window_is_not_converged() {
        let h = make_history(&[10.0; 9], &[0.0; 9]);
        let r = strat(0.5, 2.5).check(&h, 9);
        assert!(!r.converged, "zero-loss windows must never certify convergence");
        assert!(r.max_loss_delta.is_infinite());
        let reason = r.fail_reason.unwrap();
        assert!(reason.contains("degenerate") && reason.contains("loss"), "{reason}");
    }

    #[test]
    fn nan_poisoned_window_is_not_converged() {
        // an untracked module makes window_module_norm NaN; the comparison
        // must treat that as failure, not let `NaN > tau == false` pass it
        let h = make_history(&[10.0; 9], &[2.0; 9]);
        let s = WindowedThreshold::new(3, 3, 0.5, 2.5, vec!["qurey".into()]);
        let r = s.check(&h, 9);
        assert!(!r.converged, "NaN-poisoned module must fail the test");
        assert!(r.fail_reason.unwrap().contains("qurey"));
    }

    #[test]
    fn insufficient_history() {
        let h = make_history(&[10.0; 5], &[2.0; 5]);
        let r = strat(0.5, 2.5).check(&h, 5);
        assert!(!r.converged);
        assert_eq!(r.fail_reason.as_deref(), Some("insufficient history"));
    }

    #[test]
    fn uses_trailing_windows_only() {
        // noisy early history must not matter once the tail is flat
        let mut norms = vec![5.0, 20.0, 3.0, 17.0];
        norms.extend_from_slice(&[10.0; 9]);
        let mut losses = vec![4.0, 3.5, 3.2, 3.1];
        losses.extend_from_slice(&[2.0; 9]);
        let h = make_history(&norms, &losses);
        let r = strat(0.5, 2.5).check(&h, 13);
        assert!(r.converged, "{:?}", r.fail_reason);
    }
}
