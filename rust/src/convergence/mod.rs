//! Partial-convergence detection: when is it safe to switch to LoRA?
//!
//! [`WindowedThreshold`] is the paper's Algorithm 1. [`WelchTTest`] is the
//! dual-loss t-test strategy of Dahal et al. (HPT) that the related-work
//! section argues is heavier than necessary — implemented here as the
//! comparison baseline for the strategy ablation bench.

mod ttest;
mod windowed;

pub use ttest::WelchTTest;
pub use windowed::{ConvergenceReport, WindowedThreshold};

use crate::config::{ConvergenceStrategyKind, PreLoraConfig};
use crate::telemetry::NormHistory;

/// A convergence detector consulted at window boundaries.
pub trait ConvergenceStrategy {
    /// Inspect the history up to (and excluding) epoch `end`; return a
    /// report whose `converged` flag triggers the phase switch.
    fn check(&self, history: &NormHistory, end: usize) -> ConvergenceReport;

    /// Epochs of history required before `check` is meaningful.
    fn required_epochs(&self) -> usize;

    fn name(&self) -> &'static str;
}

/// Build the configured strategy over the paper's target module set.
pub fn build(cfg: &PreLoraConfig, modules: Vec<String>) -> Box<dyn ConvergenceStrategy + Send> {
    match cfg.strategy {
        ConvergenceStrategyKind::WindowedThreshold => Box::new(WindowedThreshold::new(
            cfg.windows,
            cfg.window_epochs,
            cfg.tau,
            cfg.zeta,
            modules,
        )),
        ConvergenceStrategyKind::WelchTTest => Box::new(WelchTTest::new(
            cfg.windows,
            cfg.window_epochs,
            cfg.ttest_alpha,
        )),
    }
}
