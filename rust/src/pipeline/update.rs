//! Update stage: clip + optimizer step + gradient-norm telemetry.
//!
//! Extracted from the old `Trainer::run_epoch` inline block so the
//! pipelined and sequential epoch drivers share one implementation — any
//! divergence here would break the bit-equivalence contract. Also owns
//! [`ModelState`], the mutable parameter/optimizer bundle the stage
//! operates on.
//!
//! The stage accepts gradients in either [`Reduced`] layout. For the
//! ZeRO-2 sharded layout each worker's chunk updates only that worker's
//! owned parameter slice through its optimizer shard; because the slices
//! of the shared full vector are disjoint, writing them back *is* the
//! post-update **parameter** all-gather (gradients are never gathered —
//! the scattered chunks are dropped once applied) — the replicated
//! parameter vector the next step's forward pass needs is re-assembled in
//! place. The clip scale is computed from the global pre-clip norm, which
//! the sharded path assembles from the shards' squared sums through the
//! ordered scalar reduction [`sq_sum_in_order`]; that fold is bitwise the
//! full-vector [`l2_norm`] accumulation (f64 left-fold over a
//! concatenation equals the fold over the chunks carried in order), so
//! sharded and replicated updates clip — and therefore train — identically
//! even for odd worker counts and ragged partition lengths.
//!
//! [`sq_sum_in_order`]: crate::dp::sq_sum_in_order

use anyhow::{anyhow, Result};

use crate::dp::{GradResult, Reduced};
use crate::optim::ShardedOptimizer;
use crate::rank::AdapterCfg;
use crate::tensor::{clip_by_global_norm, l2_norm};

/// The mutable model the update stage advances: flat parameter vectors
/// plus their (possibly ZeRO-sharded) optimizers. `lora`/`adapter_cfg`/
/// `opt_lora` appear at the warmup switch; `opt_base` is dropped at the
/// freeze (the paper's memory saving made literal).
pub struct ModelState {
    pub base: Vec<f32>,
    pub lora: Option<Vec<f32>>,
    pub adapter_cfg: Option<AdapterCfg>,
    pub opt_base: Option<ShardedOptimizer>,
    pub opt_lora: Option<ShardedOptimizer>,
}

impl ModelState {
    pub fn new(base: Vec<f32>, opt_base: ShardedOptimizer) -> Self {
        Self { base, lora: None, adapter_cfg: None, opt_base: Some(opt_base), opt_lora: None }
    }

    /// The `(lora_params, adapter_cfg)` input pair for the engine, present
    /// only once both halves exist.
    pub fn lora_pair(&self) -> Option<(&[f32], &[f32])> {
        match (&self.lora, &self.adapter_cfg) {
            (Some(l), Some(a)) => Some((l.as_slice(), a.values.as_slice())),
            _ => None,
        }
    }

    /// Freeze the base: drop its optimizer state entirely (the paper's
    /// memory saving made literal) — the controller's FreezeBase
    /// decision. Checkpoint restores reach the same end state
    /// differently: they clear *both* optimizers and rebuild whichever
    /// states the checkpoint carries, so a lora-only restore leaves
    /// `opt_base` at `None` without going through this transition.
    pub fn freeze_base(&mut self) {
        self.opt_base = None;
    }
}

/// One step's gradient-norm observation.
#[derive(Debug, Clone, Copy)]
pub struct StepNorms {
    /// Global L2 norm over all gradient buffers *before* clipping — the
    /// quantity Fig. 2-style telemetry wants (the post-clip norm saturates
    /// at the clip threshold and hides gradient growth).
    pub pre_clip: f64,
    /// Whether any buffer was rescaled by the clip.
    pub clipped: bool,
}

/// Stateless per-step update: clip each gradient buffer by global norm,
/// then apply the phase's optimizer(s).
pub struct UpdateStage {
    grad_clip: f64,
}

impl UpdateStage {
    /// `grad_clip <= 0` disables clipping.
    pub fn new(grad_clip: f64) -> Self {
        Self { grad_clip }
    }

    /// Clip one buffer (either layout) by global norm in place, returning
    /// its pre-clip norm. Mirrors [`clip_by_global_norm`] bit-for-bit on
    /// the sharded layout: same accumulated norm, same `(max/norm) as f32`
    /// scale applied per element.
    fn clip(&self, g: &mut Reduced) -> f64 {
        match g {
            Reduced::Full(v) => {
                if self.grad_clip > 0.0 {
                    clip_by_global_norm(v, self.grad_clip)
                } else {
                    l2_norm(v)
                }
            }
            Reduced::Sharded(chunks) => {
                // ZeRO-2: every rank needs the *global* norm to compute
                // the clip scale; the shards' squared sums combine through
                // the ordered scalar reduce (see the module docs for why
                // the order is pinned)
                let norm = crate::dp::sq_sum_in_order(chunks).sqrt();
                if self.grad_clip > 0.0 && norm > self.grad_clip && norm > 0.0 {
                    let s = (self.grad_clip / norm) as f32;
                    for c in chunks.iter_mut() {
                        crate::tensor::scale(c, s);
                    }
                }
                norm
            }
        }
    }

    /// Apply one reduced step to the model. Buffers are clipped
    /// independently (base and LoRA live on different scales), matching
    /// the pre-pipeline trainer numerics exactly.
    pub fn apply(&self, model: &mut ModelState, r: &mut GradResult, lr: f32) -> Result<StepNorms> {
        let mut sq = 0.0f64;
        let mut clipped = false;
        if let Some(ref mut g) = r.d_base {
            let pre = self.clip(g);
            clipped |= self.grad_clip > 0.0 && pre > self.grad_clip;
            sq += pre * pre;
            let opt = model
                .opt_base
                .as_mut()
                .ok_or_else(|| anyhow!("base optimizer missing"))?;
            opt.step_reduced(&mut model.base, g, lr);
        }
        if let Some(ref mut g) = r.d_lora {
            let pre = self.clip(g);
            clipped |= self.grad_clip > 0.0 && pre > self.grad_clip;
            sq += pre * pre;
            let lora = model
                .lora
                .as_mut()
                .ok_or_else(|| anyhow!("lora params missing"))?;
            let opt = model
                .opt_lora
                .as_mut()
                .ok_or_else(|| anyhow!("lora optimizer missing"))?;
            opt.step_reduced(lora, g, lr);
        }
        Ok(StepNorms { pre_clip: sq.sqrt(), clipped })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::dp::scatter;
    use crate::optim::ShardedOptimizer;

    fn model_sharded(n: usize, shards: usize) -> ModelState {
        let cfg = TrainConfig::default();
        ModelState::new(vec![0.5; n], ShardedOptimizer::new(&cfg, n, shards))
    }

    fn model(n: usize) -> ModelState {
        model_sharded(n, 1)
    }

    fn result(d_base: Option<Reduced>) -> GradResult {
        GradResult {
            d_base,
            d_lora: None,
            loss: 1.0,
            correct: 0.0,
            samples: 4,
            execute_seconds: 0.0,
        }
    }

    #[test]
    fn reports_pre_clip_norm_and_updates_params() {
        let mut m = model(4);
        let before = m.base.clone();
        let stage = UpdateStage::new(1.0);
        // norm 5 -> clipped
        let mut r = result(Some(Reduced::Full(vec![3.0, 4.0, 0.0, 0.0])));
        let norms = stage.apply(&mut m, &mut r, 0.1).unwrap();
        assert!((norms.pre_clip - 5.0).abs() < 1e-9, "pre-clip, not post-clip");
        assert!(norms.clipped);
        assert_ne!(m.base, before, "optimizer must have stepped");
        // the applied gradient was the clipped one
        let Some(Reduced::Full(g)) = &r.d_base else { panic!("layout changed") };
        assert!((l2_norm(g) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn no_clip_reports_raw_norm() {
        let mut m = model(2);
        let stage = UpdateStage::new(0.0);
        let mut r = result(Some(Reduced::Full(vec![3.0, 4.0])));
        let norms = stage.apply(&mut m, &mut r, 0.1).unwrap();
        assert!((norms.pre_clip - 5.0).abs() < 1e-9);
        assert!(!norms.clipped);
    }

    #[test]
    fn missing_optimizer_is_an_error() {
        let mut m = model(2);
        m.opt_base = None;
        let stage = UpdateStage::new(1.0);
        let mut r = result(Some(Reduced::Full(vec![1.0, 1.0])));
        assert!(stage.apply(&mut m, &mut r, 0.1).is_err());
    }

    #[test]
    fn sharded_apply_is_bitwise_identical_to_full() {
        // same gradient through both layouts (ragged 3-way split of 7),
        // with a clip that engages: parameters and norms must match bitwise
        let n = 7;
        let g: Vec<f32> = vec![1.5, -2.0, 0.25, 3.0, -0.5, 2.25, -1.0];
        let stage = UpdateStage::new(1.0);

        let mut mf = model(n);
        let mut rf = result(Some(Reduced::Full(g.clone())));
        let nf = stage.apply(&mut mf, &mut rf, 0.1).unwrap();

        let mut ms = model_sharded(n, 3);
        let mut rs = result(Some(Reduced::Sharded(scatter(&g, 3))));
        let ns = stage.apply(&mut ms, &mut rs, 0.1).unwrap();

        assert_eq!(nf.pre_clip, ns.pre_clip, "norms must match bitwise");
        assert_eq!(nf.clipped, ns.clipped);
        assert_eq!(mf.base, ms.base, "sharded update diverged from full");
        // clipped gradients agree across layouts too
        let Some(Reduced::Full(gf)) = rf.d_base else { panic!() };
        let Some(gs) = rs.d_base.map(Reduced::into_full) else { panic!() };
        assert_eq!(gf, gs);
    }
}
