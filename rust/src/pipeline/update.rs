//! Update stage: clip + optimizer step + gradient-norm telemetry.
//!
//! Extracted from the old `Trainer::run_epoch` inline block so the
//! pipelined and sequential epoch drivers share one implementation — any
//! divergence here would break the bit-equivalence contract.
//!
//! The stage is layout-blind: gradients arrive in whatever layout the
//! run's [`Strategy`] produced, and both the global-norm clip and the
//! optimizer step dispatch through the strategy
//! ([`Strategy::clip_grad`] / [`Strategy::step`]). Sharded clipping
//! assembles the global pre-clip norm through the collective's ordered
//! scalar reduce, which is bitwise the full-buffer fold, so sharded and
//! replicated updates clip — and therefore train — identically even for
//! odd worker counts and ragged partition lengths (see
//! `dist::clip_reduced`). Under ZeRO-3 the step also drops the gathered
//! parameter view, completing the per-step materialize/update cycle.

use anyhow::{anyhow, Result};

use crate::dist::{ModelState, Strategy};
use crate::dp::GradResult;

/// One step's gradient-norm observation.
#[derive(Debug, Clone, Copy)]
pub struct StepNorms {
    /// Global L2 norm over all gradient buffers *before* clipping — the
    /// quantity Fig. 2-style telemetry wants (the post-clip norm saturates
    /// at the clip threshold and hides gradient growth).
    pub pre_clip: f64,
    /// Whether any buffer was rescaled by the clip.
    pub clipped: bool,
}

/// Stateless per-step update: clip each gradient buffer by global norm,
/// then apply the phase's optimizer(s) through the strategy.
pub struct UpdateStage {
    grad_clip: f64,
}

impl UpdateStage {
    /// `grad_clip <= 0` disables clipping.
    pub fn new(grad_clip: f64) -> Self {
        Self { grad_clip }
    }

    /// Apply one reduced step to the model. Buffers are clipped
    /// independently (base and LoRA live on different scales), matching
    /// the pre-pipeline trainer numerics exactly.
    pub fn apply(
        &self,
        strategy: &dyn Strategy,
        model: &mut ModelState,
        r: &mut GradResult,
        lr: f32,
    ) -> Result<StepNorms> {
        let mut sq = 0.0f64;
        let mut clipped = false;
        if let Some(ref mut g) = r.d_base {
            let pre = strategy.clip_grad(g, self.grad_clip);
            clipped |= self.grad_clip > 0.0 && pre > self.grad_clip;
            sq += pre * pre;
            let opt = model
                .opt_base
                .as_mut()
                .ok_or_else(|| anyhow!("base optimizer missing"))?;
            strategy.step(opt, &mut model.base, g, lr);
        }
        if let Some(ref mut g) = r.d_lora {
            let pre = strategy.clip_grad(g, self.grad_clip);
            clipped |= self.grad_clip > 0.0 && pre > self.grad_clip;
            sq += pre * pre;
            let lora = model
                .lora
                .as_mut()
                .ok_or_else(|| anyhow!("lora params missing"))?;
            let opt = model
                .opt_lora
                .as_mut()
                .ok_or_else(|| anyhow!("lora optimizer missing"))?;
            strategy.step(opt, lora, g, lr);
        }
        // the step is over: drop every transient gathered view, including
        // stores this step did not update (a frozen ZeRO-3 base would
        // otherwise keep its full gather resident across the LoraOnly
        // phase, falsifying the per-rank parameter accounting)
        model.drop_views();
        Ok(StepNorms { pre_clip: sq.sqrt(), clipped })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::dist::{collective_for, strategy_for, Strategy, ZeroStage};
    use crate::dp::Algorithm;
    use std::sync::Arc;

    fn strat(stage: ZeroStage, workers: usize) -> Arc<dyn Strategy> {
        strategy_for(stage, workers, collective_for(Algorithm::Naive))
    }

    fn model(s: &dyn Strategy, n: usize) -> ModelState {
        let cfg = TrainConfig::default();
        ModelState::new(s.park_params(vec![0.5; n]), s.optimizer(&cfg, n))
    }

    fn result(s: &dyn Strategy, g: Vec<f32>) -> GradResult {
        GradResult {
            d_base: s.grad_sync(vec![g]),
            d_lora: None,
            loss: 1.0,
            correct: 0.0,
            samples: 4,
            execute_seconds: 0.0,
        }
    }

    #[test]
    fn reports_pre_clip_norm_and_updates_params() {
        let s = strat(ZeroStage::Off, 1);
        let mut m = model(&*s, 4);
        let before = m.base.to_full();
        let stage = UpdateStage::new(1.0);
        // norm 5 -> clipped
        let mut r = result(&*s, vec![3.0, 4.0, 0.0, 0.0]);
        let norms = stage.apply(&*s, &mut m, &mut r, 0.1).unwrap();
        assert!((norms.pre_clip - 5.0).abs() < 1e-9, "pre-clip, not post-clip");
        assert!(norms.clipped);
        assert_ne!(m.base.to_full(), before, "optimizer must have stepped");
        // the applied gradient was the clipped one
        let g = r.d_base.unwrap().into_full();
        assert!((crate::tensor::l2_norm(&g) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn no_clip_reports_raw_norm() {
        let s = strat(ZeroStage::Off, 1);
        let mut m = model(&*s, 2);
        let stage = UpdateStage::new(0.0);
        let mut r = result(&*s, vec![3.0, 4.0]);
        let norms = stage.apply(&*s, &mut m, &mut r, 0.1).unwrap();
        assert!((norms.pre_clip - 5.0).abs() < 1e-9);
        assert!(!norms.clipped);
    }

    #[test]
    fn missing_optimizer_is_an_error() {
        let s = strat(ZeroStage::Off, 1);
        let mut m = model(&*s, 2);
        m.opt_base = None;
        let stage = UpdateStage::new(1.0);
        let mut r = result(&*s, vec![1.0, 1.0]);
        assert!(stage.apply(&*s, &mut m, &mut r, 0.1).is_err());
    }

    #[test]
    fn every_stage_applies_bitwise_identically() {
        // the same gradient through every strategy layout (ragged 3-way
        // split of 7), with a clip that engages: parameters and norms
        // must match the unsharded apply bitwise
        let n = 7;
        let g: Vec<f32> = vec![1.5, -2.0, 0.25, 3.0, -0.5, 2.25, -1.0];
        let stage = UpdateStage::new(1.0);

        let s_off = strat(ZeroStage::Off, 3);
        let mut mf = model(&*s_off, n);
        let mut rf = result(&*s_off, g.clone());
        let nf = stage.apply(&*s_off, &mut mf, &mut rf, 0.1).unwrap();

        for zstage in [ZeroStage::Zero1, ZeroStage::Zero2, ZeroStage::Zero3] {
            let s = strat(zstage, 3);
            let mut ms = model(&*s, n);
            let mut rs = result(&*s, g.clone());
            let ns = stage.apply(&*s, &mut ms, &mut rs, 0.1).unwrap();
            assert_eq!(nf.pre_clip, ns.pre_clip, "{zstage:?}: norms must match bitwise");
            assert_eq!(nf.clipped, ns.clipped, "{zstage:?}");
            assert_eq!(mf.base.to_full(), ms.base.to_full(), "{zstage:?}: update diverged");
            // clipped gradients agree across layouts too
            let gf = rf.d_base.clone().map(|x| x.into_full());
            let gs = rs.d_base.clone().map(|x| x.into_full());
            assert_eq!(gf, gs, "{zstage:?}");
        }
    }
}
