//! Update stage: clip + optimizer step + gradient-norm telemetry.
//!
//! Extracted from the old `Trainer::run_epoch` inline block so the
//! pipelined and sequential epoch drivers share one implementation — any
//! divergence here would break the bit-equivalence contract. Also owns
//! [`ModelState`], the mutable parameter/optimizer bundle the stage
//! operates on.

use anyhow::{anyhow, Result};

use crate::dp::GradResult;
use crate::optim::Optimizer;
use crate::rank::AdapterCfg;
use crate::tensor::{clip_by_global_norm, l2_norm};

/// The mutable model the update stage advances: flat parameter vectors
/// plus their optimizers. `lora`/`adapter_cfg`/`opt_lora` appear at the
/// warmup switch; `opt_base` is dropped at the freeze (the paper's memory
/// saving made literal).
pub struct ModelState {
    pub base: Vec<f32>,
    pub lora: Option<Vec<f32>>,
    pub adapter_cfg: Option<AdapterCfg>,
    pub opt_base: Option<Box<dyn Optimizer + Send>>,
    pub opt_lora: Option<Box<dyn Optimizer + Send>>,
}

impl ModelState {
    pub fn new(base: Vec<f32>, opt_base: Box<dyn Optimizer + Send>) -> Self {
        Self { base, lora: None, adapter_cfg: None, opt_base: Some(opt_base), opt_lora: None }
    }

    /// The `(lora_params, adapter_cfg)` input pair for the engine, present
    /// only once both halves exist.
    pub fn lora_pair(&self) -> Option<(&[f32], &[f32])> {
        match (&self.lora, &self.adapter_cfg) {
            (Some(l), Some(a)) => Some((l.as_slice(), a.values.as_slice())),
            _ => None,
        }
    }
}

/// One step's gradient-norm observation.
#[derive(Debug, Clone, Copy)]
pub struct StepNorms {
    /// Global L2 norm over all gradient buffers *before* clipping — the
    /// quantity Fig. 2-style telemetry wants (the post-clip norm saturates
    /// at the clip threshold and hides gradient growth).
    pub pre_clip: f64,
    /// Whether any buffer was rescaled by the clip.
    pub clipped: bool,
}

/// Stateless per-step update: clip each gradient buffer by global norm,
/// then apply the phase's optimizer(s).
pub struct UpdateStage {
    grad_clip: f64,
}

impl UpdateStage {
    /// `grad_clip <= 0` disables clipping.
    pub fn new(grad_clip: f64) -> Self {
        Self { grad_clip }
    }

    /// Apply one reduced step to the model. Buffers are clipped
    /// independently (base and LoRA live on different scales), matching
    /// the pre-pipeline trainer numerics exactly.
    pub fn apply(&self, model: &mut ModelState, r: &mut GradResult, lr: f32) -> Result<StepNorms> {
        let mut sq = 0.0f64;
        let mut clipped = false;
        if let Some(ref mut g) = r.d_base {
            let pre = if self.grad_clip > 0.0 {
                clip_by_global_norm(g, self.grad_clip)
            } else {
                l2_norm(g)
            };
            clipped |= self.grad_clip > 0.0 && pre > self.grad_clip;
            sq += pre * pre;
            model
                .opt_base
                .as_mut()
                .ok_or_else(|| anyhow!("base optimizer missing"))?
                .step(&mut model.base, g, lr);
        }
        if let Some(ref mut g) = r.d_lora {
            let pre = if self.grad_clip > 0.0 {
                clip_by_global_norm(g, self.grad_clip)
            } else {
                l2_norm(g)
            };
            clipped |= self.grad_clip > 0.0 && pre > self.grad_clip;
            sq += pre * pre;
            let lora = model
                .lora
                .as_mut()
                .ok_or_else(|| anyhow!("lora params missing"))?;
            model
                .opt_lora
                .as_mut()
                .ok_or_else(|| anyhow!("lora optimizer missing"))?
                .step(lora, g, lr);
        }
        Ok(StepNorms { pre_clip: sq.sqrt(), clipped })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::optim;

    fn model(n: usize) -> ModelState {
        let cfg = TrainConfig::default();
        ModelState::new(vec![0.5; n], optim::build(&cfg, n))
    }

    #[test]
    fn reports_pre_clip_norm_and_updates_params() {
        let mut m = model(4);
        let before = m.base.clone();
        let stage = UpdateStage::new(1.0);
        let mut r = GradResult {
            d_base: Some(vec![3.0, 4.0, 0.0, 0.0]), // norm 5 -> clipped
            d_lora: None,
            loss: 1.0,
            correct: 0.0,
            samples: 4,
            execute_seconds: 0.0,
        };
        let norms = stage.apply(&mut m, &mut r, 0.1).unwrap();
        assert!((norms.pre_clip - 5.0).abs() < 1e-9, "pre-clip, not post-clip");
        assert!(norms.clipped);
        assert_ne!(m.base, before, "optimizer must have stepped");
        // the applied gradient was the clipped one
        assert!((l2_norm(r.d_base.as_ref().unwrap()) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn no_clip_reports_raw_norm() {
        let mut m = model(2);
        let stage = UpdateStage::new(0.0);
        let mut r = GradResult {
            d_base: Some(vec![3.0, 4.0]),
            d_lora: None,
            loss: 1.0,
            correct: 0.0,
            samples: 2,
            execute_seconds: 0.0,
        };
        let norms = stage.apply(&mut m, &mut r, 0.1).unwrap();
        assert!((norms.pre_clip - 5.0).abs() < 1e-9);
        assert!(!norms.clipped);
    }

    #[test]
    fn missing_optimizer_is_an_error() {
        let mut m = model(2);
        m.opt_base = None;
        let stage = UpdateStage::new(1.0);
        let mut r = GradResult {
            d_base: Some(vec![1.0, 1.0]),
            d_lora: None,
            loss: 1.0,
            correct: 0.0,
            samples: 2,
            execute_seconds: 0.0,
        };
        assert!(stage.apply(&mut m, &mut r, 0.1).is_err());
    }
}
