//! The pipelined step engine: data -> compute -> reduce -> update.
//!
//! `Trainer::run_epoch` used to generate batches, fan out gradients,
//! all-reduce, clip and step the optimizer strictly one phase after
//! another on one thread. This module decomposes that hot loop into four
//! stages that overlap wherever synchronous-SGD semantics allow:
//!
//! * **data** ([`Prefetcher`]) — a background thread materializes the next
//!   global step's per-worker batches (one epoch-order shuffle, reused for
//!   every step) while the current step computes;
//! * **compute** — the [`GradEngine`] workers, driven through the
//!   `submit`/`collect` split so the leader re-dispatches step *k+1*
//!   immediately after the step-*k* update and does its bookkeeping while
//!   the workers are already busy;
//! * **reduce** ([`ReduceStage`]) — a double-buffered accumulation pair:
//!   with `overlap_reduce` on, the base-gradient all-reduce runs on the
//!   stage thread concurrently with the LoRA-gradient reduce on the
//!   leader (the warmup phase carries both buffers);
//! * **update** ([`UpdateStage`]) — clip + optimizer step + gradient-norm
//!   telemetry, shared verbatim by the pipelined and the retained
//!   sequential path.
//!
//! **Determinism contract.** With a fixed seed the pipelined loop produces
//! bit-identical per-step losses and parameters to the sequential path:
//! batches depend only on `(seed, epoch, step)`, worker outputs are
//! reduced in worker order by the same [`reduce_mean`] summation schedule
//! regardless of which thread runs it, and updates apply in step order.
//! Phase switches act as barriers — an epoch drains every in-flight step
//! before the controller's decision can change the [`StepMode`], so the
//! Full -> Warmup -> LoraOnly transition is deterministic.
//!
//! [`reduce_mean`]: crate::dp::reduce_mean

mod prefetch;
mod reduce;
mod update;

pub use prefetch::Prefetcher;
pub use reduce::ReduceStage;
pub use update::{ModelState, StepNorms, UpdateStage};

use std::sync::Arc;

use anyhow::Result;

use crate::config::PipelineConfig;
use crate::data::{Dataset, EpochLoader};
use crate::dp::{Algorithm, GradEngine, StepMode};
use crate::telemetry::GradNormStats;

/// Aggregated results of one epoch of training steps (either path).
#[derive(Debug, Default, Clone)]
pub struct EpochRun {
    /// Per-step mean losses summed over steps (divide by `steps`).
    pub loss_sum: f64,
    /// Top-1 hits summed over all shards and steps.
    pub correct: f64,
    /// Samples consumed.
    pub samples: usize,
    /// Wall seconds inside PJRT execute, summed over workers and steps.
    pub execute_seconds: f64,
    /// Pre-clip gradient-norm statistics over the epoch's steps (its
    /// `steps()` is also the number of steps executed).
    pub grad_norms: GradNormStats,
}

impl EpochRun {
    fn ingest(&mut self, r: &crate::dp::GradResult, norms: StepNorms) {
        self.loss_sum += r.loss;
        self.correct += r.correct;
        self.samples += r.samples;
        self.execute_seconds += r.execute_seconds;
        self.grad_norms.record(norms.pre_clip, norms.clipped);
    }
}

/// The staged step driver. Owns the reduce stage's worker thread; the
/// prefetch thread is per-epoch (it terminates when the epoch drains).
///
/// `grad_parts > 1` switches the reduce stage to the ZeRO-2 terminal
/// reduce-scatter: gradients arrive at the update stage as per-worker
/// owned partitions (no replicated mean vector exists after the reduce)
/// and each optimizer shard updates its parameter slice, rebuilding the
/// replicas by the disjoint writes' implicit parameter all-gather (see
/// [`UpdateStage`]/[`crate::optim::ShardedOptimizer`]).
/// Bitwise-identical losses either way — the scattered chunks are the
/// replicated vector. ZeRO-1 passes `grad_parts == 1` (replicated
/// gradients, sharded optimizer state only); the gradient partition is
/// re-derived per buffer length, so the LoRA buffer appearing at the
/// phase switch re-partitions automatically.
pub struct StepPipeline {
    cfg: PipelineConfig,
    grad_parts: usize,
    reduce: ReduceStage,
}

impl StepPipeline {
    pub fn new(cfg: &PipelineConfig, algorithm: Algorithm, grad_parts: usize) -> Result<Self> {
        let grad_parts = grad_parts.max(1);
        let reduce = ReduceStage::new(algorithm, cfg.enabled && cfg.overlap_reduce, grad_parts)?;
        Ok(Self { cfg: cfg.clone(), grad_parts, reduce })
    }

    /// Run one epoch of `steps` training steps in mode `mode`, dispatching
    /// to the pipelined or the sequential driver per config. Both produce
    /// bit-identical results (see the module docs).
    #[allow(clippy::too_many_arguments)]
    pub fn run_epoch(
        &mut self,
        engine: &mut GradEngine,
        loader: &EpochLoader,
        data: &Arc<Dataset>,
        model: &mut ModelState,
        update: &UpdateStage,
        mode: StepMode,
        epoch: usize,
        steps: usize,
        lr: f32,
    ) -> Result<EpochRun> {
        if !self.cfg.enabled {
            return Self::run_sequential_sharded(
                engine,
                loader,
                data,
                model,
                update,
                mode,
                epoch,
                steps,
                lr,
                self.grad_parts,
            );
        }
        let mut prefetch = Prefetcher::spawn(
            loader.clone(),
            data.clone(),
            epoch,
            steps,
            self.cfg.prefetch_depth,
        )?;
        let mut out = EpochRun::default();
        // Prime the compute stage with step 0, then keep exactly one step
        // in flight: collect k, reduce k, update k, submit k+1, account k.
        // The accounting and the next prefetch overlap the workers' compute.
        let run = (|| -> Result<()> {
            if steps > 0 {
                engine.submit(mode, &model.base, model.lora_pair(), prefetch.recv()?)?;
            }
            for step in 0..steps {
                let outs = engine.collect()?;
                let mut r = self.reduce.reduce(outs)?;
                let norms = update.apply(model, &mut r, lr)?;
                if step + 1 < steps {
                    engine.submit(mode, &model.base, model.lora_pair(), prefetch.recv()?)?;
                }
                out.ingest(&r, norms);
            }
            Ok(())
        })();
        if run.is_err() {
            // barrier on the error path too: never leave a step in flight
            // across a phase switch or the next epoch
            engine.drain();
        }
        run.map(|()| out)
    }

    /// The fully serial reference loop (pipeline disabled), with an
    /// explicit gradient partition count (`grad_parts <= 1` = classic
    /// replicated gradients; `> 1` = ZeRO-2 terminal reduce-scatter).
    /// Shares the [`UpdateStage`] and the reduce summation schedule with
    /// the pipelined path — this is the other half of the determinism
    /// contract.
    #[allow(clippy::too_many_arguments)]
    pub fn run_sequential_sharded(
        engine: &mut GradEngine,
        loader: &EpochLoader,
        data: &Arc<Dataset>,
        model: &mut ModelState,
        update: &UpdateStage,
        mode: StepMode,
        epoch: usize,
        steps: usize,
        lr: f32,
        grad_parts: usize,
    ) -> Result<EpochRun> {
        let order = loader.epoch_order(data, epoch);
        let algorithm = engine.algorithm();
        let mut out = EpochRun::default();
        for step in 0..steps {
            let batches = loader.step_batches_in(data, &order, step);
            engine.submit(mode, &model.base, model.lora_pair(), batches)?;
            let mut r = engine.collect()?.reduce_sharded(algorithm, grad_parts);
            let norms = update.apply(model, &mut r, lr)?;
            out.ingest(&r, norms);
        }
        Ok(out)
    }
}
