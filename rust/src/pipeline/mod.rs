//! The pipelined step engine: data -> compute -> reduce -> update.
//!
//! `Trainer::run_epoch` used to generate batches, fan out gradients,
//! all-reduce, clip and step the optimizer strictly one phase after
//! another on one thread. This module decomposes that hot loop into four
//! stages that overlap wherever synchronous-SGD semantics allow:
//!
//! * **data** ([`Prefetcher`]) — a background thread materializes the next
//!   global step's per-worker batches (one epoch-order shuffle, reused for
//!   every step) while the current step computes;
//! * **compute** — the [`GradEngine`] workers, driven through the
//!   `submit`/`collect` split so the leader re-dispatches step *k+1*
//!   immediately after the step-*k* update and does its bookkeeping while
//!   the workers are already busy;
//! * **reduce** ([`ReduceStage`]) — phase-level overlap runs the
//!   base-gradient sync on the stage thread concurrently with the
//!   LoRA-gradient sync on the leader (the warmup phase carries both
//!   buffers); bucket-level overlap (`train.pipeline.bucket_bytes > 0`)
//!   goes further: workers publish size-bounded gradient buckets as each
//!   backward completes and a persistent accumulator thread reduces
//!   early buckets while later ones are still computing. The leader's
//!   blocking time in this stage is measured as `comm_wait_s`;
//! * **update** ([`UpdateStage`]) — clip + optimizer step + gradient-norm
//!   telemetry, shared verbatim by the pipelined and the retained
//!   sequential path.
//!
//! **Distribution.** Everything the pipeline knows about sharding goes
//! through the run's [`Strategy`] (`crate::dist`): the reduce stage asks
//! it for the gradient sync (replicated all-reduce or terminal
//! reduce-scatter), the update stage routes clipping and the optimizer
//! step through it, and each step begins by asking it to materialize the
//! full parameter views (the ZeRO-3 per-step all-gather; a no-op for
//! replicated storage). There is no stage-conditional branching here —
//! the strategy *is* the layout. When the strategy's collective exposes a
//! per-rank [`CollectiveEndpoint`] (the multi-process TCP transport), the
//! pipeline switches to per-process execution: one local compute worker
//! runs this rank's batch slice, phase overlap is disabled so exactly one
//! thread issues wire ops, and the step's loss/accuracy scalars are
//! folded across ranks through the endpoint.
//!
//! **Determinism contract.** With a fixed seed the pipelined loop produces
//! bit-identical per-step losses and parameters to the sequential path:
//! batches depend only on `(seed, epoch, step)`, worker outputs are
//! reduced in worker order by the strategy's one summation schedule
//! regardless of which thread runs it, and updates apply in step order.
//! Phase switches act as barriers — an epoch drains every in-flight step
//! before the controller's decision can change the [`StepMode`] or the
//! shard layout, so the Full -> Warmup -> LoraOnly transition is
//! deterministic.

mod prefetch;
mod reduce;
mod update;

pub use prefetch::Prefetcher;
pub use reduce::ReduceStage;
pub use update::{StepNorms, UpdateStage};

// The mutable model bundle lives with the distribution API now; re-export
// the old path for existing callers.
pub use crate::dist::ModelState;

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::config::PipelineConfig;
use crate::data::{Batch, Dataset, EpochLoader};
use crate::dist::{CollectiveEndpoint, Strategy};
use crate::dp::{GradEngine, GradResult, StepMode};
use crate::faults::FaultInjector;
use crate::telemetry::GradNormStats;

/// Aggregated results of one epoch of training steps (either path).
#[derive(Debug, Default, Clone)]
pub struct EpochRun {
    /// Per-step mean losses summed over steps (divide by `steps`).
    pub loss_sum: f64,
    /// Top-1 hits summed over all shards and steps.
    pub correct: f64,
    /// Samples consumed.
    pub samples: usize,
    /// Wall seconds inside PJRT execute, summed over workers and steps.
    pub execute_seconds: f64,
    /// Pre-clip gradient-norm statistics over the epoch's steps (its
    /// `steps()` is also the number of steps executed).
    pub grad_norms: GradNormStats,
    /// Wall seconds the leader spent blocked in the reduce stage —
    /// waiting on unreduced buckets (bucketed sync) or inside the
    /// whole-buffer gradient sync. The comm/compute-overlap telemetry:
    /// timing only, never part of any bitwise comparison.
    pub comm_wait_s: f64,
}

impl EpochRun {
    fn ingest(&mut self, r: &crate::dp::GradResult, norms: StepNorms) {
        self.loss_sum += r.loss;
        self.correct += r.correct;
        self.samples += r.samples;
        self.execute_seconds += r.execute_seconds;
        self.grad_norms.record(norms.pre_clip, norms.clipped);
    }
}

/// The staged step driver. Owns the reduce stage's worker thread; the
/// prefetch thread is per-epoch (it terminates when the epoch drains).
///
/// The driver is strategy-parameterized: gradient layout, parameter
/// materialization and the optimizer routing all come from the
/// [`Strategy`] it was built with, and are bitwise-equivalent across
/// strategies by the `dist` contract.
pub struct StepPipeline {
    cfg: PipelineConfig,
    strategy: Arc<dyn Strategy>,
    reduce: ReduceStage,
    /// `Some` when this process is one rank of a multi-process group: the
    /// strategy's collective drives a per-rank [`CollectiveEndpoint`]
    /// (e.g. the TCP transport). The pipeline then computes only this
    /// rank's shard of each step and exchanges step scalars on the wire.
    endpoint: Option<Arc<dyn CollectiveEndpoint>>,
    /// Deterministic fault injection (`train.faults.plan`): `None` outside
    /// adversity testing, leaving the step loop's only overhead a single
    /// `Option` check per step.
    faults: Option<Arc<FaultInjector>>,
}

impl StepPipeline {
    pub fn new(cfg: &PipelineConfig, strategy: Arc<dyn Strategy>) -> Result<Self> {
        let endpoint = strategy.endpoint();
        // A live endpoint serializes the group's collective ops in
        // lockstep, so exactly one thread per process may issue them:
        // phase overlap (which syncs base grads on the stage thread while
        // the leader syncs LoRA grads) is forced off, and the local stage
        // sizing is one worker — this process computes one rank only.
        let multi = endpoint.as_ref().is_some_and(|ep| ep.world_size() > 1);
        let overlap = cfg.enabled && cfg.effective_overlap() && !multi;
        let bucket_bytes = if cfg.enabled { cfg.effective_bucket_bytes() } else { 0 };
        let workers = if multi { 1 } else { strategy.workers() };
        let reduce = ReduceStage::new(strategy.clone(), overlap, bucket_bytes, workers)?;
        let endpoint = if multi { endpoint } else { None };
        Ok(Self { cfg: cfg.clone(), strategy, reduce, endpoint, faults: None })
    }

    /// Install the run's fault injector (adversity testing only). The
    /// pipeline advances the injector's (epoch, step) clock as steps are
    /// dispatched and arms the engine's per-worker compute faults; the
    /// collective endpoint consults the same injector for wire faults.
    pub fn set_faults(&mut self, faults: Option<Arc<FaultInjector>>) {
        self.faults = faults;
    }

    /// Advance the fault clock to (epoch, step) and arm that coordinate's
    /// compute faults on the engine — called right before each submit so
    /// every wire op a step issues observes its own coordinate.
    fn arm_step_faults(&self, engine: &mut GradEngine, epoch: usize, step: usize) {
        if let Some(inj) = &self.faults {
            inj.set_position(epoch, step);
            engine.set_step_faults(inj.step_faults(epoch, step, engine.worker_count()));
        }
    }

    /// Keep only this rank's batch when the process is one rank of a
    /// multi-process group. The loader still shards each step over the
    /// *global* worker count, so every rank derives the same global batch
    /// order and picks its own slice — the data layout is identical to
    /// the in-memory run.
    fn local_batches(&self, batches: Vec<Batch>) -> Result<Vec<Batch>> {
        let Some(ep) = &self.endpoint else { return Ok(batches) };
        ensure!(
            batches.len() == ep.world_size(),
            "loader produced {} per-step batches for a {}-rank group",
            batches.len(),
            ep.world_size()
        );
        let mut batches = batches;
        Ok(vec![batches.swap_remove(ep.rank())])
    }

    /// Fold the step's loss/accuracy scalars across the group. Each rank
    /// contributes its single local worker's row; the fold runs in rank
    /// order, so the result is bitwise-identical to the in-memory
    /// worker-order fold in `GradEngine::collect` (a one-worker local
    /// mean divides by 1.0, which is exact, and f64 scalars travel
    /// bit-exact on the wire).
    ///
    /// Ordering matters: this issues wire ops on the leader thread and
    /// therefore must run after `reduce` returns and *before* the next
    /// `submit` — once step k+1 is in flight, the bucket accumulator
    /// thread owns the endpoint.
    fn exchange_step_scalars(&self, r: &mut GradResult) -> Result<()> {
        let Some(ep) = &self.endpoint else { return Ok(()) };
        let rows = ep.gather_scalars(&[r.loss, r.correct, r.samples as f64, r.execute_seconds])?;
        let (mut loss, mut correct, mut samples, mut exec) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for row in &rows {
            ensure!(row.len() == 4, "step-scalar row carries {} values, expected 4", row.len());
            loss += row[0];
            correct += row[1];
            samples += row[2];
            exec += row[3];
        }
        r.loss = loss / rows.len() as f64;
        r.correct = correct;
        r.samples = samples as usize;
        r.execute_seconds = exec;
        Ok(())
    }

    /// Run one epoch of `steps` training steps in mode `mode`, dispatching
    /// to the pipelined or the sequential driver per config. Both produce
    /// bit-identical results (see the module docs).
    #[allow(clippy::too_many_arguments)]
    pub fn run_epoch(
        &mut self,
        engine: &mut GradEngine,
        loader: &EpochLoader,
        data: &Arc<Dataset>,
        model: &mut ModelState,
        update: &UpdateStage,
        mode: StepMode,
        epoch: usize,
        steps: usize,
        lr: f32,
    ) -> Result<EpochRun> {
        if !self.cfg.enabled {
            return self.run_sequential(engine, loader, data, model, update, mode, epoch, steps, lr);
        }
        // Derive this epoch's bucket route from the mode's live gradient
        // spaces (mode is constant within an epoch; the epoch barrier
        // means nothing is in flight). Re-deriving here is what picks up
        // fresh layouts after a Repartition event changed space lengths.
        let base_len =
            if mode != StepMode::LoraOnly { Some(model.base.len()) } else { None };
        let lora_len =
            if mode != StepMode::Full { model.lora.as_ref().map(|l| l.len()) } else { None };
        engine.set_bucket_route(self.reduce.epoch_route(base_len, lora_len));
        let mut prefetch = Prefetcher::spawn(
            loader.clone(),
            data.clone(),
            epoch,
            steps,
            self.cfg.prefetch_depth,
        )?;
        let mut out = EpochRun::default();
        // Prime the compute stage with step 0, then keep exactly one step
        // in flight: collect k, reduce k, update k, submit k+1, account k.
        // The accounting and the next prefetch overlap the workers' compute.
        // Every submit is preceded by the strategy's parameter
        // materialization — the per-step all-gather when parameters are
        // sharded, free otherwise.
        let run = (|| -> Result<()> {
            if steps > 0 {
                self.arm_step_faults(engine, epoch, 0);
                self.strategy.materialize_params(model);
                let batches = self.local_batches(prefetch.recv()?)?;
                engine.submit(mode, model.base_view(), model.lora_pair(), batches)?;
            }
            for step in 0..steps {
                let outs = engine.collect()?;
                let wait = std::time::Instant::now();
                let mut r = self.reduce.reduce(outs)?;
                self.exchange_step_scalars(&mut r)?;
                out.comm_wait_s += wait.elapsed().as_secs_f64();
                let norms = update.apply(&*self.strategy, model, &mut r, lr)?;
                if step + 1 < steps {
                    self.arm_step_faults(engine, epoch, step + 1);
                    self.strategy.materialize_params(model);
                    let batches = self.local_batches(prefetch.recv()?)?;
                    engine.submit(mode, model.base_view(), model.lora_pair(), batches)?;
                }
                out.ingest(&r, norms);
            }
            Ok(())
        })();
        if run.is_err() {
            // barrier on the error path too: never leave a step in flight
            // across a phase switch or the next epoch
            engine.drain();
        }
        // Retire the engine's route sender clones at the epoch barrier
        // (success or failure): the reduce stage must stay joinable
        // without waiting on the engine's drop order, and the next epoch
        // re-derives its own route anyway.
        engine.set_bucket_route(None);
        run.map(|()| out)
    }

    /// The fully serial reference loop (pipeline disabled). Shares the
    /// [`UpdateStage`] and the strategy's gradient-sync schedule with the
    /// pipelined path — this is the other half of the determinism
    /// contract.
    #[allow(clippy::too_many_arguments)]
    fn run_sequential(
        &mut self,
        engine: &mut GradEngine,
        loader: &EpochLoader,
        data: &Arc<Dataset>,
        model: &mut ModelState,
        update: &UpdateStage,
        mode: StepMode,
        epoch: usize,
        steps: usize,
        lr: f32,
    ) -> Result<EpochRun> {
        engine.set_bucket_route(None); // the serial path reduces inline
        let order = loader.epoch_order(data, epoch);
        let mut out = EpochRun::default();
        for step in 0..steps {
            self.arm_step_faults(engine, epoch, step);
            let batches = self.local_batches(loader.step_batches_in(data, &order, step))?;
            self.strategy.materialize_params(model);
            engine.submit(mode, model.base_view(), model.lora_pair(), batches)?;
            let outs = engine.collect()?;
            let wait = std::time::Instant::now();
            let mut r = self.strategy.try_reduce_step(outs)?;
            self.exchange_step_scalars(&mut r)?;
            out.comm_wait_s += wait.elapsed().as_secs_f64();
            let norms = update.apply(&*self.strategy, model, &mut r, lr)?;
            out.ingest(&r, norms);
        }
        Ok(out)
    }
}
