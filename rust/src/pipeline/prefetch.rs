//! Data stage: background materialization of per-worker batches.
//!
//! One thread per epoch. It computes the epoch's shuffle order once (the
//! serial loop used to redo the O(N) Fisher-Yates for every step) and
//! pushes each global step's `Vec<Batch>` through a bounded channel, so at
//! most `depth` steps of batches are resident ahead of the consumer.
//! Batches depend only on `(seed, epoch, step)`, so prefetching cannot
//! change what the compute stage sees — only when it is ready.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use crate::data::{Batch, Dataset, EpochLoader};

/// Handle to one epoch's prefetch thread.
pub struct Prefetcher {
    rx: Option<mpsc::Receiver<Vec<Batch>>>,
    join: Option<JoinHandle<()>>,
}

impl Prefetcher {
    /// Start prefetching `steps` global steps of epoch `epoch`, keeping at
    /// most `depth` steps buffered.
    pub fn spawn(
        loader: EpochLoader,
        data: Arc<Dataset>,
        epoch: usize,
        steps: usize,
        depth: usize,
    ) -> Result<Self> {
        let (tx, rx) = mpsc::sync_channel(depth.max(1));
        // lint: thread: joined — Drop closes the channel (unblocking a
        // producer stuck on the full queue) and joins the handle.
        let join = std::thread::Builder::new()
            .name("data-prefetch".into())
            .spawn(move || {
                let order = loader.epoch_order(&data, epoch);
                for step in 0..steps {
                    let batches = loader.step_batches_in(&data, &order, step);
                    if tx.send(batches).is_err() {
                        return; // consumer stopped early
                    }
                }
            })
            .context("spawning prefetch thread")?;
        Ok(Self { rx: Some(rx), join: Some(join) })
    }

    /// Receive the next step's batches, blocking until materialized.
    pub fn recv(&mut self) -> Result<Vec<Batch>> {
        self.rx
            .as_ref()
            .ok_or_else(|| anyhow!("prefetcher already shut down"))?
            .recv()
            .map_err(|_| anyhow!("prefetch thread terminated early"))
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // disconnect first so a producer blocked on a full channel unblocks
        drop(self.rx.take());
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;

    fn data() -> Arc<Dataset> {
        Arc::new(Dataset::generate(&SynthSpec {
            samples: 96,
            image_size: 8,
            channels: 1,
            num_classes: 4,
            noise: 0.1,
            phase_jitter: false,
            seed: 5,
        }))
    }

    #[test]
    fn prefetched_batches_match_direct_loader_calls() {
        let d = data();
        let loader = EpochLoader::new(8, 2, 9);
        let steps = loader.steps_per_epoch(&d);
        let mut pf = Prefetcher::spawn(loader.clone(), d.clone(), 3, steps, 2).unwrap();
        for step in 0..steps {
            let got = pf.recv().unwrap();
            let want = loader.step_batches(&d, 3, step);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.labels, w.labels);
                assert_eq!(g.images, w.images);
            }
        }
        assert!(pf.recv().is_err(), "exactly `steps` sends then EOF");
    }

    #[test]
    fn early_drop_does_not_hang() {
        let d = data();
        let loader = EpochLoader::new(8, 1, 0);
        let steps = loader.steps_per_epoch(&d);
        // depth 1 forces the producer to block mid-epoch; dropping the
        // consumer must still shut it down cleanly
        let mut pf = Prefetcher::spawn(loader, d, 0, steps, 1).unwrap();
        let _ = pf.recv().unwrap();
        drop(pf);
    }
}
