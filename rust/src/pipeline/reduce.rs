//! Reduce stage: gradient synchronization with optional cross-buffer
//! overlap.
//!
//! A step in the warmup phase carries two independent gradient buffers
//! (base + LoRA). With overlap on, they reduce as a double-buffered pair:
//! the base buffers go to the stage's worker thread while the leader
//! reduces the LoRA buffers, so both accumulations are active at once and
//! the warmup step's reduce critical path is max(base, lora) instead of
//! base + lora. Which thread runs a reduce cannot change the bits — both
//! call the same [`Strategy::grad_sync`], which runs the collective's one
//! summation schedule (the determinism contract in the module docs).
//!
//! The *layout* the stage produces is the strategy's choice: a replicated
//! mean under classic DDP / ZeRO-1, or — when the strategy shards
//! gradients — a **terminal** reduce-scatter whose owned partitions are
//! all that survives (no replicated mean vector is materialized and the
//! per-worker input buffers are consumed), dropping per-rank gradient
//! memory to ~1/N. Either way the result gathers bitwise to the
//! all-reduce output, so the layout cannot change losses.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use crate::dist::Strategy;
use crate::dp::{GradResult, Reduced, StepOutputs};

/// Persistent reduce stage; the worker thread exists only when overlap is
/// requested.
pub struct ReduceStage {
    strategy: Arc<dyn Strategy>,
    tx: Option<mpsc::Sender<Vec<Vec<f32>>>>,
    rx: Option<mpsc::Receiver<Option<Reduced>>>,
    join: Option<JoinHandle<()>>,
}

impl ReduceStage {
    pub fn new(strategy: Arc<dyn Strategy>, overlap: bool) -> Result<Self> {
        if !overlap {
            return Ok(Self { strategy, tx: None, rx: None, join: None });
        }
        let (tx, job_rx) = mpsc::channel::<Vec<Vec<f32>>>();
        let (out_tx, rx) = mpsc::channel::<Option<Reduced>>();
        let stage_strategy = strategy.clone();
        let join = std::thread::Builder::new()
            .name("reduce-stage".into())
            .spawn(move || {
                while let Ok(bufs) = job_rx.recv() {
                    if out_tx.send(stage_strategy.grad_sync(bufs)).is_err() {
                        break;
                    }
                }
            })
            .context("spawning reduce-stage thread")?;
        Ok(Self { strategy, tx: Some(tx), rx: Some(rx), join: Some(join) })
    }

    /// Reduce one step's worker outputs to mean gradients in the
    /// strategy's layout. Overlaps the base reduce with the LoRA reduce
    /// when both are present and a stage thread exists; otherwise defers
    /// to [`Strategy::reduce_step`] — the serial path's epilogue — so the
    /// two can never diverge.
    pub fn reduce(&mut self, outs: StepOutputs) -> Result<GradResult> {
        let (tx, rx) = match (&self.tx, &self.rx) {
            (Some(tx), Some(rx))
                if !outs.base_grads.is_empty() && !outs.lora_grads.is_empty() =>
            {
                (tx, rx)
            }
            _ => return Ok(self.strategy.reduce_step(outs)),
        };
        let StepOutputs {
            base_grads,
            lora_grads,
            loss,
            correct,
            samples,
            execute_seconds,
        } = outs;
        tx.send(base_grads)
            .map_err(|_| anyhow!("reduce stage hung up"))?;
        let d_lora = self.strategy.grad_sync(lora_grads);
        let d_base = rx.recv().map_err(|_| anyhow!("reduce stage died"))?;
        Ok(GradResult { d_base, d_lora, loss, correct, samples, execute_seconds })
    }
}

impl Drop for ReduceStage {
    fn drop(&mut self) {
        drop(self.tx.take());
        drop(self.rx.take());
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{collective_for, strategy_for, ZeroStage};
    use crate::dp::Algorithm;

    fn strat(stage: ZeroStage, workers: usize) -> Arc<dyn Strategy> {
        strategy_for(stage, workers, collective_for(Algorithm::Tree))
    }

    fn outs(base_workers: usize, lora_workers: usize, len: usize) -> StepOutputs {
        let buf = |w: usize| (0..len).map(|i| ((w * 13 + i * 5) % 11) as f32 - 5.0).collect();
        StepOutputs {
            base_grads: (0..base_workers).map(buf).collect(),
            lora_grads: (0..lora_workers).map(|w| buf(w + 100)).collect(),
            loss: 1.5,
            correct: 3.0,
            samples: 8,
            execute_seconds: 0.01,
        }
    }

    #[test]
    fn overlapped_reduce_is_bitwise_identical_to_inline() {
        for (nb, nl) in [(4usize, 4usize), (3, 3), (2, 0), (0, 5)] {
            let mut overlapped = ReduceStage::new(strat(ZeroStage::Off, 4), true).unwrap();
            let mut inline = ReduceStage::new(strat(ZeroStage::Off, 4), false).unwrap();
            let a = overlapped.reduce(outs(nb, nl, 97)).unwrap();
            let b = inline.reduce(outs(nb, nl, 97)).unwrap();
            assert_eq!(a.d_base, b.d_base);
            assert_eq!(a.d_lora, b.d_lora);
            assert_eq!(a.loss, b.loss);
        }
    }

    #[test]
    fn sharded_strategies_gather_to_the_full_reduce_bitwise() {
        // whatever layout the strategy picks, overlapped and inline must
        // both produce it, and its gather must equal the full reduce
        for (nb, nl) in [(3usize, 3usize), (4, 0)] {
            for stage in [ZeroStage::Zero2, ZeroStage::Zero3] {
                let mut full = ReduceStage::new(strat(ZeroStage::Off, 3), false).unwrap();
                let mut inline = ReduceStage::new(strat(stage, 3), false).unwrap();
                let mut overlapped = ReduceStage::new(strat(stage, 3), true).unwrap();
                let want = full.reduce(outs(nb, nl, 101)).unwrap();
                let a = inline.reduce(outs(nb, nl, 101)).unwrap();
                let b = overlapped.reduce(outs(nb, nl, 101)).unwrap();
                for got in [a, b] {
                    let gb = got.d_base.clone().expect("base gradients present");
                    assert!(
                        gb.per_rank_elems() < 101,
                        "{stage:?}: the stage must produce owned partitions, got a replicated buffer"
                    );
                    assert_eq!(
                        gb.into_full(),
                        want.d_base.clone().unwrap().into_full(),
                        "{stage:?}"
                    );
                    if nl > 0 {
                        assert_eq!(
                            got.d_lora.clone().map(|x| x.into_full()),
                            want.d_lora.clone().map(|x| x.into_full()),
                            "{stage:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn scalars_pass_through() {
        let mut stage = ReduceStage::new(strat(ZeroStage::Off, 2), false).unwrap();
        let r = stage.reduce(outs(2, 0, 8)).unwrap();
        assert_eq!(r.loss, 1.5);
        assert_eq!(r.correct, 3.0);
        assert_eq!(r.samples, 8);
        assert!(r.d_base.is_some() && r.d_lora.is_none());
    }
}
