//! Reduce stage: gradient synchronization with optional cross-buffer
//! and bucket-level overlap.
//!
//! A step in the warmup phase carries two independent gradient buffers
//! (base + LoRA). With overlap on, they reduce as a double-buffered pair:
//! the base buffers go to the stage's worker thread while the leader
//! reduces the LoRA buffers, so both accumulations are active at once and
//! the warmup step's reduce critical path is max(base, lora) instead of
//! base + lora. Which thread runs a reduce cannot change the bits — both
//! call the same [`Strategy::grad_sync`], which runs the collective's one
//! summation schedule (the determinism contract in the module docs).
//!
//! **Bucket-level overlap** (`train.pipeline.bucket_bytes > 0`) goes
//! further: the parameter space is split into size-bounded buckets
//! aligned to the strategy's gradient partition boundaries
//! ([`Strategy::bucket_plan`]), workers publish each bucket's slice the
//! moment their backward output is ready (see
//! `GradEngine::set_bucket_route`), and this stage's persistent
//! accumulator thread reduces bucket *k* while later buckets are still
//! being computed or published. [`ReduceStage::reduce`] then assembles
//! the reduced buckets **in deterministic index order**, so the result is
//! bitwise the whole-buffer reduce (each bucket runs the collective's one
//! summation schedule over the same element positions —
//! [`Strategy::grad_sync_bucket`]'s contract) and downstream clipping
//! still folds the global norm via `sq_sum_in_order` unchanged. Bucket
//! layouts re-derive at every epoch start ([`ReduceStage::epoch_route`]),
//! which is what picks up new space lengths after a `Repartition` event.
//!
//! The *layout* the stage produces is the strategy's choice: a replicated
//! mean under classic DDP / ZeRO-1, or — when the strategy shards
//! gradients — a **terminal** reduce-scatter whose owned partitions are
//! all that survives (no replicated mean vector is materialized and the
//! per-worker input buffers are consumed), dropping per-rank gradient
//! memory to ~1/N. Either way the result gathers bitwise to the
//! all-reduce output, so the layout cannot change losses.
//!
//! **Thread lifecycle**: both stage threads (phase overlap + bucket
//! accumulator) are joined in [`Drop`]. The accumulator's queue carries
//! lifecycle signals alongside buckets ([`BucketCtrl`]) — `Shutdown`
//! terminates it even while the engine still holds route sender clones,
//! so the join can never block on a foreign drop order, and `Reset` at
//! each epoch barrier clears partial accumulation an aborted step left
//! behind. The exhaustive interleaving checks for this protocol live in
//! `rust/tests/loom_bucket.rs` (via [`crate::mc`]).

use std::collections::BTreeMap;
use std::thread::JoinHandle;

use anyhow::{anyhow, ensure, Context, Result};

use crate::dist::Strategy;
use crate::dp::{
    BucketCtrl, BucketPlan, BucketRoute, BucketTx, GradResult, GradSpace, Reduced, StepOutputs,
};
use crate::sync::{mpsc, thread, Arc};

/// Depth of the bucket job queue, per publishing worker: enough slack
/// that a worker publishing its whole backward output in one burst never
/// stalls on the accumulator, while still bounding memory to a few
/// buckets per worker.
const BUCKET_QUEUE_JOBS_PER_WORKER: usize = 4;

/// One reduced bucket — or the accumulator's report of a broken protocol
/// (duplicate/out-of-range publish, strategy refusal), which the leader
/// surfaces as a step error instead of waiting on a bucket that can never
/// complete.
type ReducedMsg = Result<(GradSpace, usize, Vec<f32>)>;

/// The bucket plans live this epoch (a space is `None` when its gradients
/// still flow whole-buffer — e.g. the frozen base after the switch).
#[derive(Clone)]
struct ActiveBuckets {
    base: Option<Arc<BucketPlan>>,
    lora: Option<Arc<BucketPlan>>,
}

/// Persistent reduce stage; the phase-overlap worker thread exists only
/// when overlap is requested, the bucket accumulator thread only when
/// `bucket_bytes > 0` and the strategy opts into bucketed sync.
pub struct ReduceStage {
    strategy: Arc<dyn Strategy>,
    tx: Option<mpsc::Sender<Vec<Vec<f32>>>>,
    rx: Option<mpsc::Receiver<Option<Reduced>>>,
    join: Option<JoinHandle<()>>,
    /// Bucket size bound (elements are f32; 0 = bucketing off).
    bucket_bytes: usize,
    /// Sender handed to the engine each epoch (workers publish here);
    /// also carries the stage-private lifecycle signals.
    bucket_tx: Option<BucketTx>,
    /// Reduced buckets (or the accumulator's error) back to the leader.
    reduced_rx: Option<mpsc::Receiver<ReducedMsg>>,
    /// The accumulator thread, joined on drop.
    bucket_join: Option<JoinHandle<()>>,
    /// Plans of the epoch in flight (`None` = whole-buffer this epoch).
    active: Option<ActiveBuckets>,
}

/// Body of the persistent "bucket-reduce" accumulator thread: collect
/// every worker's slice of each bucket, reduce complete buckets through
/// the strategy's one summation schedule, stream results to the leader.
/// A protocol violation is reported over `rtx` and stops the thread — the
/// leader's next [`ReduceStage::reduce`] fails loudly instead of waiting
/// on a bucket that can never complete.
fn accumulate_buckets(
    brx: &mpsc::Receiver<BucketCtrl>,
    rtx: &mpsc::Sender<ReducedMsg>,
    n: usize,
    strategy: &dyn Strategy,
) {
    // BTreeMap, not HashMap (PL001): nothing may ever iterate this map in
    // hash order on the reduce path, and a keyed lookup loses nothing.
    let mut pending: BTreeMap<(GradSpace, usize), Vec<Option<Vec<f32>>>> = BTreeMap::new();
    while let Ok(ctrl) = brx.recv() {
        let msg = match ctrl {
            BucketCtrl::Bucket(msg) => msg,
            BucketCtrl::Reset => {
                pending.clear();
                continue;
            }
            BucketCtrl::Shutdown => return,
        };
        let key = (msg.space, msg.bucket);
        let slots = pending.entry(key).or_insert_with(|| vec![None; n]);
        let violation = if msg.worker >= n {
            Some("out-of-range")
        } else if slots[msg.worker].is_some() {
            Some("duplicate")
        } else {
            None
        };
        if let Some(what) = violation {
            let _ = rtx.send(Err(anyhow!(
                "bucket-sync protocol violation: {what} publish of {:?}/{} by worker {}",
                msg.space,
                msg.bucket,
                msg.worker
            )));
            return;
        }
        slots[msg.worker] = Some(msg.data);
        if slots.iter().all(Option::is_some) {
            let Some(slots) = pending.remove(&key) else { continue };
            let bufs: Vec<Vec<f32>> = slots.into_iter().flatten().collect();
            let reduced = match strategy.try_grad_sync_bucket(bufs, msg.lo, msg.full_len) {
                Err(e) => Err(e),
                Ok(Some(r)) => Ok(r),
                Ok(None) => Err(anyhow!(
                    "strategy stopped supporting bucketed sync for {:?}/{}",
                    msg.space,
                    msg.bucket
                )),
            };
            let failed = reduced.is_err();
            if rtx.send(reduced.map(|r| (msg.space, msg.bucket, r))).is_err() || failed {
                return; // leader gone, or nothing left to accumulate for
            }
        }
    }
}

impl ReduceStage {
    pub fn new(
        strategy: Arc<dyn Strategy>,
        overlap: bool,
        bucket_bytes: usize,
        n_workers: usize,
    ) -> Result<Self> {
        let mut stage = Self {
            strategy,
            tx: None,
            rx: None,
            join: None,
            bucket_bytes: 0,
            bucket_tx: None,
            reduced_rx: None,
            bucket_join: None,
            active: None,
        };
        if bucket_bytes > 0 && stage.strategy.bucketed_sync() {
            // bounded job queue: throttles publishers without ever filling
            // faster than the accumulator drains
            let (btx, brx) = BucketTx::channel(BUCKET_QUEUE_JOBS_PER_WORKER * n_workers.max(1));
            // lint: allow(PL008): at most one ReducedMsg is ever in flight
            // per published bucket, and publishing is throttled by the
            // bounded job queue above — depth is structurally capped.
            let (rtx, rrx) = mpsc::channel::<ReducedMsg>();
            let n = n_workers.max(1);
            let acc_strategy = stage.strategy.clone();
            // lint: thread: joined — Drop sends `BucketCtrl::Shutdown`
            // (which overrides the engine's live route sender clones, so
            // the join cannot block on foreign drop order) and joins.
            let handle = thread::Builder::new()
                .name("bucket-reduce".into())
                .spawn(move || accumulate_buckets(&brx, &rtx, n, &*acc_strategy))
                .context("spawning bucket-reduce thread")?;
            stage.bucket_bytes = bucket_bytes;
            stage.bucket_tx = Some(btx);
            stage.reduced_rx = Some(rrx);
            stage.bucket_join = Some(handle);
        }
        if !overlap {
            return Ok(stage);
        }
        // lint: allow(PL008): strict request/response — the leader sends
        // one grad_sync job, then blocks on the result before sending the
        // next; at most one message sits in either queue.
        let (tx, job_rx) = mpsc::channel::<Vec<Vec<f32>>>();
        // lint: allow(PL008): response half of the pair above — depth ≤ 1
        // by the same alternation.
        let (out_tx, rx) = mpsc::channel::<Option<Reduced>>();
        let stage_strategy = stage.strategy.clone();
        // lint: thread: joined — Drop closes the job channel and joins.
        let join = thread::Builder::new()
            .name("reduce-stage".into())
            .spawn(move || {
                while let Ok(bufs) = job_rx.recv() {
                    if out_tx.send(stage_strategy.grad_sync(bufs)).is_err() {
                        break;
                    }
                }
            })
            .context("spawning reduce-stage thread")?;
        stage.tx = Some(tx);
        stage.rx = Some(rx);
        stage.join = Some(join);
        Ok(stage)
    }

    /// Derive this epoch's bucket layouts and hand back the route the
    /// engine should publish through (`None` = bucketing inactive: knob
    /// off, strategy without bucketed sync, or no live gradient space).
    /// Called at every epoch start — the epoch barrier guarantees nothing
    /// is in flight, and re-deriving per call is what makes a
    /// `Repartition` event's new space lengths pick up fresh layouts.
    pub fn epoch_route(
        &mut self,
        base_len: Option<usize>,
        lora_len: Option<usize>,
    ) -> Option<BucketRoute> {
        let tx = match &self.bucket_tx {
            Some(tx) if self.bucket_bytes > 0 => tx.clone(),
            _ => {
                self.active = None;
                return None;
            }
        };
        // epoch barrier: clear any partial accumulation an aborted step
        // left behind before the new epoch starts publishing (a closed
        // queue is fine — the next reduce reports the dead accumulator)
        let _ = tx.reset();
        let base = base_len
            .filter(|&l| l > 0)
            .map(|l| Arc::new(self.strategy.bucket_plan(l, self.bucket_bytes)));
        let lora = lora_len
            .filter(|&l| l > 0)
            .map(|l| Arc::new(self.strategy.bucket_plan(l, self.bucket_bytes)));
        if base.is_none() && lora.is_none() {
            self.active = None;
            return None;
        }
        self.active = Some(ActiveBuckets { base: base.clone(), lora: lora.clone() });
        Some(BucketRoute { base, lora, tx })
    }

    /// Reduce one step's worker outputs to mean gradients in the
    /// strategy's layout. With bucket plans active, the gradients already
    /// arrived through the bucket queue — this waits for the remaining
    /// reduced buckets and assembles them in index order. Otherwise it
    /// overlaps the base reduce with the LoRA reduce when both are
    /// present and a stage thread exists, or defers to
    /// [`Strategy::reduce_step`] — the serial path's epilogue — so the
    /// paths can never diverge.
    pub fn reduce(&mut self, outs: StepOutputs) -> Result<GradResult> {
        if let Some(active) = self.active.clone() {
            return self.reduce_bucketed(&active, outs);
        }
        let (tx, rx) = match (&self.tx, &self.rx) {
            (Some(tx), Some(rx))
                if !outs.base_grads.is_empty() && !outs.lora_grads.is_empty() =>
            {
                (tx, rx)
            }
            _ => return self.strategy.try_reduce_step(outs),
        };
        let StepOutputs {
            base_grads,
            lora_grads,
            loss,
            correct,
            samples,
            execute_seconds,
        } = outs;
        tx.send(base_grads)
            .map_err(|_| anyhow!("reduce stage hung up"))?;
        let d_lora = self.strategy.grad_sync(lora_grads);
        let d_base = rx.recv().map_err(|_| anyhow!("reduce stage died"))?;
        Ok(GradResult { d_base, d_lora, loss, correct, samples, execute_seconds })
    }

    /// Drain the accumulator's reduced buckets for one step and assemble
    /// each space in bucket-index order — bitwise the whole-buffer reduce.
    /// The blocking `recv` here is exactly the comm-wait the pipeline
    /// measures: time the update stage stalls on unreduced buckets.
    fn reduce_bucketed(&mut self, active: &ActiveBuckets, outs: StepOutputs) -> Result<GradResult> {
        let StepOutputs { base_grads, lora_grads, loss, correct, samples, execute_seconds } = outs;
        let rx = self
            .reduced_rx
            .as_ref()
            .ok_or_else(|| anyhow!("bucketed reduce without a result channel"))?;
        let expect_base = active.base.as_ref().map_or(0, |p| p.count());
        let expect_lora = active.lora.as_ref().map_or(0, |p| p.count());
        ensure!(
            expect_base == 0 || base_grads.is_empty(),
            "base gradients arrived whole-buffer despite an active bucket route"
        );
        ensure!(
            expect_lora == 0 || lora_grads.is_empty(),
            "LoRA gradients arrived whole-buffer despite an active bucket route"
        );
        let mut base_slots: Vec<Option<Vec<f32>>> = vec![None; expect_base];
        let mut lora_slots: Vec<Option<Vec<f32>>> = vec![None; expect_lora];
        let mut remaining = expect_base + expect_lora;
        while remaining > 0 {
            let (space, idx, data) = rx
                .recv()
                .map_err(|_| anyhow!("bucket-reduce thread died"))?
                .context("bucket-reduce accumulator failed")?;
            let slot = match space {
                GradSpace::Base => base_slots.get_mut(idx),
                GradSpace::Lora => lora_slots.get_mut(idx),
            }
            .ok_or_else(|| anyhow!("bucket index {idx} out of range for {space:?}"))?;
            ensure!(slot.is_none(), "duplicate reduced bucket {space:?}/{idx}");
            *slot = Some(data);
            remaining -= 1;
        }
        let d_base = match active.base.as_deref() {
            Some(plan) => Some(assemble(plan, base_slots)?),
            None => self.strategy.try_grad_sync(base_grads)?,
        };
        let d_lora = match active.lora.as_deref() {
            Some(plan) => Some(assemble(plan, lora_slots)?),
            None => self.strategy.try_grad_sync(lora_grads)?,
        };
        Ok(GradResult { d_base, d_lora, loss, correct, samples, execute_seconds })
    }
}

/// Concatenate reduced buckets in index order into the strategy's layout:
/// one full vector when gradients are replicated, per-partition chunks
/// (grouped by each bucket's owning partition, preserving index order
/// within it) when they shard — mirroring `reduce_scatter`'s output shape
/// including empty chunks for empty partitions. A missing bucket can only
/// mean a counting bug in the caller's drain loop.
fn assemble(plan: &BucketPlan, slots: Vec<Option<Vec<f32>>>) -> Result<Reduced> {
    if plan.parts <= 1 {
        let mut full = Vec::with_capacity(plan.len);
        for (i, s) in slots.into_iter().enumerate() {
            full.extend(s.ok_or_else(|| anyhow!("bucket {i} missing from assembly"))?);
        }
        Ok(Reduced::Full(full))
    } else {
        let mut chunks = vec![Vec::new(); plan.parts];
        for (i, (b, s)) in plan.buckets.iter().zip(slots).enumerate() {
            chunks[b.part].extend(s.ok_or_else(|| anyhow!("bucket {i} missing from assembly"))?);
        }
        Ok(Reduced::Sharded(chunks))
    }
}

impl Drop for ReduceStage {
    fn drop(&mut self) {
        drop(self.tx.take());
        drop(self.rx.take());
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        // `Shutdown` terminates the accumulator even while the engine
        // still holds route sender clones, so this join cannot block on a
        // foreign drop order. A closed queue means the accumulator
        // already exited (protocol violation) — the join returns at once
        // either way.
        if let Some(tx) = self.bucket_tx.take() {
            let _ = tx.shutdown();
        }
        drop(self.reduced_rx.take());
        if let Some(j) = self.bucket_join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{collective_for, strategy_for, ZeroStage};
    use crate::dp::{Algorithm, BucketMsg, BucketQueueClosed};

    fn strat(stage: ZeroStage, workers: usize) -> Arc<dyn Strategy> {
        strategy_for(stage, workers, collective_for(Algorithm::Tree))
    }

    fn outs(base_workers: usize, lora_workers: usize, len: usize) -> StepOutputs {
        let buf = |w: usize| (0..len).map(|i| ((w * 13 + i * 5) % 11) as f32 - 5.0).collect();
        StepOutputs {
            base_grads: (0..base_workers).map(buf).collect(),
            lora_grads: (0..lora_workers).map(|w| buf(w + 100)).collect(),
            loss: 1.5,
            correct: 3.0,
            samples: 8,
            execute_seconds: 0.01,
        }
    }

    /// Play the engine's role: slice each worker's buffer per the plan and
    /// push the bucket messages through the route.
    fn publish(route: &crate::dp::BucketRoute, space: GradSpace, grads: &[Vec<f32>]) {
        let plan = match space {
            GradSpace::Base => route.base.as_deref().expect("base plan"),
            GradSpace::Lora => route.lora.as_deref().expect("lora plan"),
        };
        for (w, d) in grads.iter().enumerate() {
            for (i, b) in plan.buckets.iter().enumerate() {
                route
                    .tx
                    .send(BucketMsg {
                        space,
                        bucket: i,
                        worker: w,
                        lo: b.lo,
                        full_len: plan.len,
                        data: d[b.lo..b.hi].to_vec(),
                    })
                    .unwrap();
            }
        }
    }

    #[test]
    fn overlapped_reduce_is_bitwise_identical_to_inline() {
        for (nb, nl) in [(4usize, 4usize), (3, 3), (2, 0), (0, 5)] {
            let mut overlapped = ReduceStage::new(strat(ZeroStage::Off, 4), true, 0, 4).unwrap();
            let mut inline = ReduceStage::new(strat(ZeroStage::Off, 4), false, 0, 4).unwrap();
            let a = overlapped.reduce(outs(nb, nl, 97)).unwrap();
            let b = inline.reduce(outs(nb, nl, 97)).unwrap();
            assert_eq!(a.d_base, b.d_base);
            assert_eq!(a.d_lora, b.d_lora);
            assert_eq!(a.loss, b.loss);
        }
    }

    #[test]
    fn bucketed_reduce_is_bitwise_identical_to_whole_buffer() {
        // every ZeRO stage, base-only and warmup shapes, a bucket size
        // that produces ragged final buckets: the assembled result must
        // match the whole-buffer stage bit-for-bit in the same layout
        let len = 101;
        let workers = 3;
        for stage in [ZeroStage::Off, ZeroStage::Zero1, ZeroStage::Zero2, ZeroStage::Zero3] {
            for (nb, nl) in [(3usize, 0usize), (3, 3), (0, 3)] {
                let mut whole = ReduceStage::new(strat(stage, workers), false, 0, workers).unwrap();
                let want = whole.reduce(outs(nb, nl, len)).unwrap();

                let mut bucketed =
                    ReduceStage::new(strat(stage, workers), false, 52, workers).unwrap();
                let route = bucketed
                    .epoch_route(
                        (nb > 0).then_some(len),
                        (nl > 0).then_some(len),
                    )
                    .expect("route must exist for a stock strategy with bucketing on");
                let mut o = outs(nb, nl, len);
                let base_grads = std::mem::take(&mut o.base_grads);
                let lora_grads = std::mem::take(&mut o.lora_grads);
                if route.base.is_some() {
                    publish(&route, GradSpace::Base, &base_grads);
                }
                if route.lora.is_some() {
                    publish(&route, GradSpace::Lora, &lora_grads);
                }
                let got = bucketed.reduce(o).unwrap();
                assert_eq!(got.d_base, want.d_base, "{stage:?} nb={nb} nl={nl}");
                assert_eq!(got.d_lora, want.d_lora, "{stage:?} nb={nb} nl={nl}");
                assert_eq!(got.loss, want.loss);
                assert_eq!(got.samples, want.samples);
            }
        }
    }

    #[test]
    fn epoch_route_rederives_plans_per_length() {
        // the Repartition contract: a new space length at the next epoch
        // start gets a freshly derived layout
        let w = 2;
        let mut stage = ReduceStage::new(strat(ZeroStage::Zero2, w), false, 64, w).unwrap();
        let r1 = stage.epoch_route(Some(101), None).unwrap();
        assert_eq!(r1.base.as_ref().unwrap().len, 101);
        assert!(r1.lora.is_none());
        let r2 = stage.epoch_route(Some(101), Some(33)).unwrap();
        assert_eq!(r2.lora.as_ref().unwrap().len, 33);
        let r3 = stage.epoch_route(None, Some(33)).unwrap();
        assert!(r3.base.is_none(), "frozen base must drop out of the route");
        // no live space => no route, and the stage falls back to inline
        assert!(stage.epoch_route(None, None).is_none());
        let r = stage.reduce(outs(w, 0, 16)).unwrap();
        assert!(r.d_base.is_some());
    }

    #[test]
    fn bucketing_is_inert_when_off_or_unsupported() {
        // knob off
        let mut off = ReduceStage::new(strat(ZeroStage::Off, 2), false, 0, 2).unwrap();
        assert!(off.epoch_route(Some(100), None).is_none());
        // a custom strategy that never opted into bucketed sync keeps
        // whole-buffer behavior even with the knob on
        struct Custom(Arc<dyn Strategy>);
        impl Strategy for Custom {
            fn stage(&self) -> ZeroStage {
                self.0.stage()
            }
            fn workers(&self) -> usize {
                self.0.workers()
            }
            fn collective(&self) -> &dyn crate::dist::Collective {
                self.0.collective()
            }
        }
        let custom: Arc<dyn Strategy> = Arc::new(Custom(strat(ZeroStage::Off, 2)));
        let mut stage = ReduceStage::new(custom, false, 4096, 2).unwrap();
        assert!(stage.epoch_route(Some(100), None).is_none());
        let r = stage.reduce(outs(2, 0, 16)).unwrap();
        assert!(r.d_base.is_some(), "whole-buffer fallback must still reduce");
    }

    #[test]
    fn sharded_strategies_gather_to_the_full_reduce_bitwise() {
        // whatever layout the strategy picks, overlapped and inline must
        // both produce it, and its gather must equal the full reduce
        for (nb, nl) in [(3usize, 3usize), (4, 0)] {
            for stage in [ZeroStage::Zero2, ZeroStage::Zero3] {
                let mut full = ReduceStage::new(strat(ZeroStage::Off, 3), false, 0, 3).unwrap();
                let mut inline = ReduceStage::new(strat(stage, 3), false, 0, 3).unwrap();
                let mut overlapped = ReduceStage::new(strat(stage, 3), true, 0, 3).unwrap();
                let want = full.reduce(outs(nb, nl, 101)).unwrap();
                let a = inline.reduce(outs(nb, nl, 101)).unwrap();
                let b = overlapped.reduce(outs(nb, nl, 101)).unwrap();
                for got in [a, b] {
                    let gb = got.d_base.clone().expect("base gradients present");
                    assert!(
                        gb.per_rank_elems() < 101,
                        "{stage:?}: stage must produce owned partitions, not replicated"
                    );
                    assert_eq!(
                        gb.into_full(),
                        want.d_base.clone().unwrap().into_full(),
                        "{stage:?}"
                    );
                    if nl > 0 {
                        assert_eq!(
                            got.d_lora.clone().map(|x| x.into_full()),
                            want.d_lora.clone().map(|x| x.into_full()),
                            "{stage:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn scalars_pass_through() {
        let mut stage = ReduceStage::new(strat(ZeroStage::Off, 2), false, 0, 2).unwrap();
        let r = stage.reduce(outs(2, 0, 8)).unwrap();
        assert_eq!(r.loss, 1.5);
        assert_eq!(r.correct, 3.0);
        assert_eq!(r.samples, 8);
        assert!(r.d_base.is_some() && r.d_lora.is_none());
    }

    #[test]
    fn drop_joins_accumulator_despite_live_route_senders() {
        // the old stage detached the accumulator: dropping the stage
        // while someone (the engine) still held a route sender leaked a
        // live thread. Now Shutdown ends it and Drop joins — observable
        // from outside because a publish on the surviving sender reports
        // the closed queue instead of quietly feeding a leaked thread.
        let workers = 2;
        let mut stage =
            ReduceStage::new(strat(ZeroStage::Off, workers), false, 64, workers).unwrap();
        let route = stage.epoch_route(Some(100), None).unwrap();
        drop(stage);
        let late = route.tx.send(BucketMsg {
            space: GradSpace::Base,
            bucket: 0,
            worker: 0,
            lo: 0,
            full_len: 100,
            data: vec![0.0; 16],
        });
        assert_eq!(late, Err(BucketQueueClosed));
    }

    #[test]
    fn aborted_step_leftovers_are_cleared_at_next_epoch_route() {
        // a failed step can leave partial accumulation behind (worker 0
        // published, worker 1's step errored before publishing); without
        // the Reset at the next epoch barrier, worker 0's fresh publishes
        // would collide with its stale ones as duplicates
        let workers = 2;
        let len = 40;
        let mut stage =
            ReduceStage::new(strat(ZeroStage::Off, workers), false, 64, workers).unwrap();
        let r1 = stage.epoch_route(Some(len), None).unwrap();
        let plan = r1.base.clone().expect("base plan");
        for (i, b) in plan.buckets.iter().enumerate() {
            r1.tx
                .send(BucketMsg {
                    space: GradSpace::Base,
                    bucket: i,
                    worker: 0,
                    lo: b.lo,
                    full_len: plan.len,
                    data: vec![9.0; b.hi - b.lo],
                })
                .unwrap();
        }
        drop(r1);
        let r2 = stage.epoch_route(Some(len), None).unwrap();
        let grads = vec![vec![2.0f32; len]; workers];
        publish(&r2, GradSpace::Base, &grads);
        let got = stage.reduce(outs(0, 0, len)).unwrap();
        let full = got.d_base.expect("base reduced").into_full();
        assert_eq!(full, vec![2.0f32; len], "stale epoch-1 slices leaked into epoch 2");
    }

    #[test]
    fn protocol_violation_surfaces_as_contextful_error() {
        // a duplicate publish is a logic bug; the old accumulator
        // panicked on it (an assert in a detached thread), the new one
        // reports it through the result channel so reduce() fails loudly
        let workers = 2;
        let len = 16;
        let mut stage =
            ReduceStage::new(strat(ZeroStage::Off, workers), false, 1024, workers).unwrap();
        let route = stage.epoch_route(Some(len), None).unwrap();
        let msg = |worker| BucketMsg {
            space: GradSpace::Base,
            bucket: 0,
            worker,
            lo: 0,
            full_len: len,
            data: vec![1.0; len],
        };
        route.tx.send(msg(0)).unwrap();
        route.tx.send(msg(0)).unwrap(); // duplicate: the protocol bug
        let err = stage.reduce(outs(0, 0, len)).unwrap_err();
        assert!(format!("{err:#}").contains("protocol violation"), "{err:#}");
    }
}
