//! Reduce stage: gradient all-reduce with optional cross-buffer overlap.
//!
//! A step in the warmup phase carries two independent gradient buffers
//! (base + LoRA). With overlap on, they reduce as a double-buffered pair:
//! the base buffers go to the stage's worker thread while the leader
//! reduces the LoRA buffers, so both accumulations are active at once and
//! the warmup step's reduce critical path is max(base, lora) instead of
//! base + lora. Each reduce runs the exact same [`reduce_mean`] summation
//! schedule as the serial path — which thread executes it cannot change
//! the bits (the determinism contract in the module docs).

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use crate::dp::allreduce::reduce_owned;
use crate::dp::{Algorithm, GradResult, StepOutputs};

/// Persistent reduce stage; the worker thread exists only when overlap is
/// requested.
pub struct ReduceStage {
    algorithm: Algorithm,
    tx: Option<mpsc::Sender<Vec<Vec<f32>>>>,
    rx: Option<mpsc::Receiver<Option<Vec<f32>>>>,
    join: Option<JoinHandle<()>>,
}

impl ReduceStage {
    pub fn new(algorithm: Algorithm, overlap: bool) -> Result<Self> {
        if !overlap {
            return Ok(Self { algorithm, tx: None, rx: None, join: None });
        }
        let (tx, job_rx) = mpsc::channel::<Vec<Vec<f32>>>();
        let (out_tx, rx) = mpsc::channel::<Option<Vec<f32>>>();
        let join = std::thread::Builder::new()
            .name("reduce-stage".into())
            .spawn(move || {
                while let Ok(bufs) = job_rx.recv() {
                    if out_tx.send(reduce_owned(algorithm, bufs)).is_err() {
                        break;
                    }
                }
            })
            .context("spawning reduce-stage thread")?;
        Ok(Self { algorithm, tx: Some(tx), rx: Some(rx), join: Some(join) })
    }

    /// Reduce one step's worker outputs to mean gradients. Overlaps the
    /// base reduce with the LoRA reduce when both are present and a stage
    /// thread exists; otherwise defers to [`StepOutputs::reduce`] — the
    /// serial path's epilogue — so the two can never diverge.
    pub fn reduce(&mut self, outs: StepOutputs) -> Result<GradResult> {
        let (tx, rx) = match (&self.tx, &self.rx) {
            (Some(tx), Some(rx))
                if !outs.base_grads.is_empty() && !outs.lora_grads.is_empty() =>
            {
                (tx, rx)
            }
            _ => return Ok(outs.reduce(self.algorithm)),
        };
        let StepOutputs {
            base_grads,
            lora_grads,
            loss,
            correct,
            samples,
            execute_seconds,
        } = outs;
        tx.send(base_grads)
            .map_err(|_| anyhow!("reduce stage hung up"))?;
        let d_lora = reduce_owned(self.algorithm, lora_grads);
        let d_base = rx.recv().map_err(|_| anyhow!("reduce stage died"))?;
        Ok(GradResult { d_base, d_lora, loss, correct, samples, execute_seconds })
    }
}

impl Drop for ReduceStage {
    fn drop(&mut self) {
        drop(self.tx.take());
        drop(self.rx.take());
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outs(base_workers: usize, lora_workers: usize, len: usize) -> StepOutputs {
        let buf = |w: usize| (0..len).map(|i| ((w * 13 + i * 5) % 11) as f32 - 5.0).collect();
        StepOutputs {
            base_grads: (0..base_workers).map(buf).collect(),
            lora_grads: (0..lora_workers).map(|w| buf(w + 100)).collect(),
            loss: 1.5,
            correct: 3.0,
            samples: 8,
            execute_seconds: 0.01,
        }
    }

    #[test]
    fn overlapped_reduce_is_bitwise_identical_to_inline() {
        for (nb, nl) in [(4usize, 4usize), (3, 3), (2, 0), (0, 5)] {
            let mut overlapped = ReduceStage::new(Algorithm::Tree, true).unwrap();
            let mut inline = ReduceStage::new(Algorithm::Tree, false).unwrap();
            let a = overlapped.reduce(outs(nb, nl, 97)).unwrap();
            let b = inline.reduce(outs(nb, nl, 97)).unwrap();
            assert_eq!(a.d_base, b.d_base);
            assert_eq!(a.d_lora, b.d_lora);
            assert_eq!(a.loss, b.loss);
        }
    }

    #[test]
    fn scalars_pass_through() {
        let mut stage = ReduceStage::new(Algorithm::Naive, false).unwrap();
        let r = stage.reduce(outs(2, 0, 8)).unwrap();
        assert_eq!(r.loss, 1.5);
        assert_eq!(r.correct, 3.0);
        assert_eq!(r.samples, 8);
        assert!(r.d_base.is_some() && r.d_lora.is_none());
    }
}
