//! Reduce stage: gradient all-reduce with optional cross-buffer overlap.
//!
//! A step in the warmup phase carries two independent gradient buffers
//! (base + LoRA). With overlap on, they reduce as a double-buffered pair:
//! the base buffers go to the stage's worker thread while the leader
//! reduces the LoRA buffers, so both accumulations are active at once and
//! the warmup step's reduce critical path is max(base, lora) instead of
//! base + lora. Each reduce runs the exact same [`reduce_mean`] summation
//! schedule as the serial path — which thread executes it cannot change
//! the bits (the determinism contract in the module docs).
//!
//! With ZeRO-2 enabled (`grad_parts > 1`) the stage reduce-*scatters*
//! instead, and the scatter is **terminal**: each worker keeps only its
//! owned partition of the mean gradient ([`Reduced::Sharded`]), no
//! replicated mean vector is materialized after the reduce, and the
//! per-worker input buffers are consumed by it — per-rank gradient memory
//! drops to ~1/parts. The scattered chunks concatenate bitwise to the
//! replicated vector (see `dp::reduce_scatter`), so turning ZeRO on
//! cannot change losses. At ZeRO-1 (`grad_parts == 1`) gradients stay
//! replicated and only the optimizer state is sharded downstream.

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use crate::dp::{Algorithm, GradResult, Reduced, StepOutputs};

/// Persistent reduce stage; the worker thread exists only when overlap is
/// requested.
pub struct ReduceStage {
    algorithm: Algorithm,
    /// Gradient partition count for the ZeRO-2 terminal reduce-scatter;
    /// `<= 1` reduces to the replicated full vector.
    grad_parts: usize,
    tx: Option<mpsc::Sender<Vec<Vec<f32>>>>,
    rx: Option<mpsc::Receiver<Option<Reduced>>>,
    join: Option<JoinHandle<()>>,
}

impl ReduceStage {
    pub fn new(algorithm: Algorithm, overlap: bool, grad_parts: usize) -> Result<Self> {
        let grad_parts = grad_parts.max(1);
        if !overlap {
            return Ok(Self { algorithm, grad_parts, tx: None, rx: None, join: None });
        }
        let (tx, job_rx) = mpsc::channel::<Vec<Vec<f32>>>();
        let (out_tx, rx) = mpsc::channel::<Option<Reduced>>();
        let join = std::thread::Builder::new()
            .name("reduce-stage".into())
            .spawn(move || {
                while let Ok(bufs) = job_rx.recv() {
                    if out_tx.send(reduce_one(algorithm, bufs, grad_parts)).is_err() {
                        break;
                    }
                }
            })
            .context("spawning reduce-stage thread")?;
        Ok(Self { algorithm, grad_parts, tx: Some(tx), rx: Some(rx), join: Some(join) })
    }

    /// Reduce one step's worker outputs to mean gradients. Overlaps the
    /// base reduce with the LoRA reduce when both are present and a stage
    /// thread exists; otherwise defers to [`StepOutputs::reduce_sharded`]
    /// — the serial path's epilogue — so the two can never diverge.
    pub fn reduce(&mut self, outs: StepOutputs) -> Result<GradResult> {
        let (tx, rx) = match (&self.tx, &self.rx) {
            (Some(tx), Some(rx))
                if !outs.base_grads.is_empty() && !outs.lora_grads.is_empty() =>
            {
                (tx, rx)
            }
            _ => return Ok(outs.reduce_sharded(self.algorithm, self.grad_parts)),
        };
        let StepOutputs {
            base_grads,
            lora_grads,
            loss,
            correct,
            samples,
            execute_seconds,
        } = outs;
        tx.send(base_grads)
            .map_err(|_| anyhow!("reduce stage hung up"))?;
        let d_lora = reduce_one(self.algorithm, lora_grads, self.grad_parts);
        let d_base = rx.recv().map_err(|_| anyhow!("reduce stage died"))?;
        Ok(GradResult { d_base, d_lora, loss, correct, samples, execute_seconds })
    }
}

/// Reduce one buffer set into the stage's configured layout. With
/// `grad_parts > 1` the reduce-scatter is the terminal op: `bufs` is
/// consumed, and only the owned partitions survive.
fn reduce_one(algorithm: Algorithm, bufs: Vec<Vec<f32>>, grad_parts: usize) -> Option<Reduced> {
    if grad_parts > 1 {
        crate::dp::reduce_scatter(algorithm, bufs, grad_parts).map(Reduced::Sharded)
    } else {
        crate::dp::reduce_owned(algorithm, bufs).map(Reduced::Full)
    }
}

impl Drop for ReduceStage {
    fn drop(&mut self) {
        drop(self.tx.take());
        drop(self.rx.take());
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outs(base_workers: usize, lora_workers: usize, len: usize) -> StepOutputs {
        let buf = |w: usize| (0..len).map(|i| ((w * 13 + i * 5) % 11) as f32 - 5.0).collect();
        StepOutputs {
            base_grads: (0..base_workers).map(buf).collect(),
            lora_grads: (0..lora_workers).map(|w| buf(w + 100)).collect(),
            loss: 1.5,
            correct: 3.0,
            samples: 8,
            execute_seconds: 0.01,
        }
    }

    #[test]
    fn overlapped_reduce_is_bitwise_identical_to_inline() {
        for (nb, nl) in [(4usize, 4usize), (3, 3), (2, 0), (0, 5)] {
            let mut overlapped = ReduceStage::new(Algorithm::Tree, true, 1).unwrap();
            let mut inline = ReduceStage::new(Algorithm::Tree, false, 1).unwrap();
            let a = overlapped.reduce(outs(nb, nl, 97)).unwrap();
            let b = inline.reduce(outs(nb, nl, 97)).unwrap();
            assert_eq!(a.d_base, b.d_base);
            assert_eq!(a.d_lora, b.d_lora);
            assert_eq!(a.loss, b.loss);
        }
    }

    #[test]
    fn zero_sharded_reduce_matches_full_bitwise() {
        // with ZeRO the overlapped and inline paths must both produce the
        // sharded layout, and its gather must equal the full reduce
        for (nb, nl) in [(3usize, 3usize), (4, 0)] {
            let mut full = ReduceStage::new(Algorithm::Ring, false, 1).unwrap();
            let mut inline = ReduceStage::new(Algorithm::Ring, false, 3).unwrap();
            let mut overlapped = ReduceStage::new(Algorithm::Ring, true, 3).unwrap();
            let want = full.reduce(outs(nb, nl, 101)).unwrap();
            let a = inline.reduce(outs(nb, nl, 101)).unwrap();
            let b = overlapped.reduce(outs(nb, nl, 101)).unwrap();
            for got in [a, b] {
                match (&got.d_base, &want.d_base) {
                    (Some(Reduced::Sharded(chunks)), Some(Reduced::Full(v))) => {
                        assert_eq!(chunks.len(), 3);
                        assert_eq!(&crate::dp::all_gather(chunks), v);
                    }
                    (None, None) => {}
                    other => panic!("unexpected layouts: {other:?}"),
                }
                if nl > 0 {
                    assert_eq!(
                        got.d_lora.clone().map(Reduced::into_full),
                        want.d_lora.clone().map(Reduced::into_full)
                    );
                }
            }
        }
    }

    #[test]
    fn scalars_pass_through() {
        let mut stage = ReduceStage::new(Algorithm::Naive, false, 1).unwrap();
        let r = stage.reduce(outs(2, 0, 8)).unwrap();
        assert_eq!(r.loss, 1.5);
        assert_eq!(r.correct, 3.0);
        assert_eq!(r.samples, 8);
        assert!(r.d_base.is_some() && r.d_lora.is_none());
    }
}
