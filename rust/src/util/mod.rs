//! Offline substrates: the build environment has no network access and
//! only the `xla` + `anyhow` crates vendored, so the pieces a production
//! crate would pull from the ecosystem are implemented in-tree:
//!
//! * [`json`]    — strict JSON parser/writer (manifest, checkpoints, summaries)
//! * [`tomlish`] — TOML-subset config parser (run configs)
//! * [`args`]    — CLI flag parser (the `prelora` binary)
//! * [`bench`]   — micro-benchmark harness (`benches/*.rs`, harness = false)
//! * [`prop`]    — property-testing driver with shrinking (invariant tests)
//! * [`crc`]     — CRC-32 payload checksums (v3 checkpoint integrity)

pub mod args;
pub mod bench;
pub mod crc;
pub mod json;
pub mod prop;
pub mod tomlish;
