//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320).
//!
//! The v3 checkpoint format carries a payload checksum so that a file
//! whose header parses but whose binary payload was corrupted in transit
//! (bit rot, partial copy, concatenation accidents) is rejected with a
//! clear error instead of silently restoring garbage parameters. The
//! bitwise implementation needs no lookup table; checkpoint payloads are
//! small relative to training time, so throughput is irrelevant here.

/// Incremental CRC-32 accumulator.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    /// Fold `bytes` into the running checksum.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut s = self.state;
        for &b in bytes {
            s ^= b as u32;
            for _ in 0..8 {
                // branch-free: mask is all-ones iff the low bit is set
                let mask = (s & 1).wrapping_neg();
                s = (s >> 1) ^ (0xEDB8_8320 & mask);
            }
        }
        self.state = s;
    }

    /// Final checksum value (the accumulator stays usable).
    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot convenience over [`Crc32`].
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_the_standard_check_value() {
        // the canonical CRC-32/ISO-HDLC test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn incremental_equals_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(1013).collect();
        let mut inc = Crc32::new();
        for chunk in data.chunks(7) {
            inc.update(chunk);
        }
        assert_eq!(inc.finish(), crc32(&data));
    }

    #[test]
    fn single_bit_flip_changes_the_checksum() {
        let mut data = vec![0u8; 64];
        let clean = crc32(&data);
        for byte in 0..data.len() {
            for bit in 0..8 {
                data[byte] ^= 1 << bit;
                assert_ne!(crc32(&data), clean, "flip at {byte}:{bit} undetected");
                data[byte] ^= 1 << bit;
            }
        }
    }
}
