//! Minimal JSON parser/writer.
//!
//! The build environment is offline (no serde_json), and the L2↔L3
//! contract (`manifest.json`), checkpoints and run summaries all speak
//! JSON — so this is a from-scratch substrate: a strict recursive-descent
//! parser covering the full JSON grammar (escapes, \uXXXX, exponents) plus
//! a writer with stable key order.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value. Objects preserve sorted key order via BTreeMap
/// (the manifest's semantic content is order-independent).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------- accessors ----------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Field access that errors with the key name (manifest parsing).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > 2f64.powi(53) {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("expected integer, got {n}");
        }
        Ok(n as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    // ---------- builders ----------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn from_usize(x: usize) -> Json {
        Json::Num(x as f64)
    }

    /// Encode an `f64` as its exact bit pattern (16 lowercase hex digits).
    /// `Json::Num` round-trips through the shortest-decimal formatter,
    /// which is exact for finite values but cannot represent NaN or the
    /// infinities JSON lacks — the checkpoint trajectory block (losses,
    /// convergence deltas that are legitimately ±inf/NaN) therefore uses
    /// this bit-exact encoding instead.
    pub fn from_f64_bits(x: f64) -> Json {
        Json::Str(format!("{:016x}", x.to_bits()))
    }

    /// Decode a value written by [`from_f64_bits`]. Strictly lowercase:
    /// `from_str_radix` would also accept uppercase hex, which has a
    /// different byte representation for the same value — a corrupted
    /// byte ('a' -> 'A' is a single bit) could then canonicalize back to
    /// the original and slip past a content checksum.
    pub fn as_f64_bits(&self) -> Result<f64> {
        let s = self.as_str()?;
        if s.len() != 16 || !s.bytes().all(|b| matches!(b, b'0'..=b'9' | b'a'..=b'f')) {
            bail!("expected 16 lowercase hex digits of f64 bits, got {s:?}");
        }
        let bits = u64::from_str_radix(s, 16)
            .map_err(|e| anyhow!("bad f64 bit pattern {s:?}: {e}"))?;
        Ok(f64::from_bits(bits))
    }

    // ---------- parse ----------

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---------- write ----------

    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn dump_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            if let Some(n) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(n * d));
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    x.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at byte {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            // surrogate pairs: accept but map lone ones to U+FFFD
                            let ch = char::from_u32(cp).unwrap_or('\u{FFFD}');
                            s.push(ch);
                        }
                        c => bail!("invalid escape \\{}", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // multi-byte UTF-8: copy the full sequence
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    if start + len > self.bytes.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..start + len])?);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        let arr = v.req("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].req("b").unwrap(), &Json::Null);
        assert_eq!(v.req("c").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn escapes_roundtrip() {
        let s = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let text = s.dump();
        assert_eq!(Json::parse(&text).unwrap(), s);
    }

    #[test]
    fn unicode_and_u_escape() {
        let v = Json::parse(r#""café λ""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café λ");
    }

    #[test]
    fn dump_roundtrips_manifest_like_doc() {
        let text = r#"{"model":"vit","size":19496,"tensors":[{"name":"q.w","shape":[32,32],"layer":-1}],"ok":true}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.dump_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,", "\"unterminated", "12..5", "{\"a\" 1}", "nul", "[1] extra"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn integer_precision_preserved_in_dump() {
        let v = Json::Num(1_234_567_890.0);
        assert_eq!(v.dump(), "1234567890");
    }

    #[test]
    fn accessor_errors_name_field() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        let err = v.req("missing").unwrap_err().to_string();
        assert!(err.contains("missing"));
        assert!(v.req("a").unwrap().as_str().is_err());
        assert_eq!(v.req("a").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn negative_and_fractional_usize_rejected() {
        assert!(Json::Num(-1.0).as_usize().is_err());
        assert!(Json::Num(1.5).as_usize().is_err());
        assert!(Json::Num(2.0).as_i64().is_ok());
    }

    #[test]
    fn f64_bits_roundtrip_including_nan_and_inf() {
        for x in [
            0.0,
            -0.0,
            1.0,
            -2.5e-300,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MIN_POSITIVE,
            std::f64::consts::PI,
        ] {
            let j = Json::from_f64_bits(x);
            let back = Json::parse(&j.dump()).unwrap().as_f64_bits().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x}");
        }
        // NaN payload bits survive too (== would fail, bits must not)
        let nan = f64::from_bits(0x7ff8_0000_dead_beef);
        let back = Json::from_f64_bits(nan).as_f64_bits().unwrap();
        assert_eq!(back.to_bits(), nan.to_bits());
        // malformed encodings are rejected
        assert!(Json::Str("123".into()).as_f64_bits().is_err());
        assert!(Json::Str("zzzzzzzzzzzzzzzz".into()).as_f64_bits().is_err());
        assert!(Json::Num(1.0).as_f64_bits().is_err());
        // uppercase hex is rejected: it decodes to the same bits but has
        // different bytes, which would defeat canonical-form checksums
        assert!(Json::Str("3FF0000000000000".into()).as_f64_bits().is_err());
    }
}
