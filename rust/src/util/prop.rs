//! Property-testing driver (offline substrate; no `proptest` available).
//!
//! [`check`] runs a property over many PCG-generated random cases and, on
//! failure, performs greedy input shrinking via the case's [`Shrink`]
//! implementation before panicking with the minimal counterexample. Used
//! by the coordinator-invariant tests (rank assignment, all-reduce,
//! loader determinism, convergence monotonicity).

use crate::tensor::Pcg64;

/// Types that can generate themselves from an RNG and shrink toward
/// simpler counterexamples.
pub trait Arbitrary: Sized + std::fmt::Debug + Clone {
    fn generate(rng: &mut Pcg64) -> Self;

    /// Candidate simplifications (smaller vectors, smaller numbers...).
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

/// Run `prop` over `cases` random inputs (seeded deterministically per
/// test by `seed`). Panics with a shrunk counterexample on failure.
pub fn check<T: Arbitrary, F: Fn(&T) -> bool>(seed: u64, cases: usize, prop: F) {
    let mut rng = Pcg64::new(seed);
    for case in 0..cases {
        let input = T::generate(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(input, &prop);
            panic!("property failed on case {case}: {minimal:#?}");
        }
    }
}

fn shrink_loop<T: Arbitrary, F: Fn(&T) -> bool>(mut failing: T, prop: &F) -> T {
    // greedy descent: keep taking the first shrink that still fails
    let mut budget = 1000;
    'outer: while budget > 0 {
        for cand in failing.shrink() {
            budget -= 1;
            if !prop(&cand) {
                failing = cand;
                continue 'outer;
            }
        }
        break;
    }
    failing
}

// ---- common generators ----

impl Arbitrary for f64 {
    fn generate(rng: &mut Pcg64) -> Self {
        (rng.next_f64() - 0.5) * 200.0
    }

    fn shrink(&self) -> Vec<Self> {
        let mut v = Vec::new();
        if *self != 0.0 {
            v.push(0.0);
            v.push(self / 2.0);
        }
        v
    }
}

impl Arbitrary for usize {
    fn generate(rng: &mut Pcg64) -> Self {
        rng.next_below(1000)
    }

    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            Vec::new()
        } else {
            vec![0, self / 2, self - 1]
        }
    }
}

impl<T: Arbitrary> Arbitrary for Vec<T> {
    fn generate(rng: &mut Pcg64) -> Self {
        let n = 1 + rng.next_below(32);
        (0..n).map(|_| T::generate(rng)).collect()
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.len() > 1 {
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[1..].to_vec());
        }
        // shrink one element
        if let Some(first) = self.first() {
            for s in first.shrink().into_iter().take(2) {
                let mut v = self.clone();
                v[0] = s;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Arbitrary, B: Arbitrary> Arbitrary for (A, B) {
    fn generate(rng: &mut Pcg64) -> Self {
        (A::generate(rng), B::generate(rng))
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check::<Vec<usize>, _>(1, 200, |v| !v.is_empty());
    }

    #[test]
    fn failing_property_shrinks() {
        let r = std::panic::catch_unwind(|| {
            check::<Vec<usize>, _>(2, 200, |v| v.iter().sum::<usize>() < 100);
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("property failed"), "{msg}");
        // shrunk example should be small (one or two elements)
        let brackets = msg.matches(',').count();
        assert!(brackets <= 4, "not shrunk enough: {msg}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::new(9);
        let mut b = Pcg64::new(9);
        let x: Vec<f64> = Arbitrary::generate(&mut a);
        let y: Vec<f64> = Arbitrary::generate(&mut b);
        assert_eq!(x, y);
    }
}
