//! Minimal TOML-subset parser for run configs (offline substrate; no
//! `toml` crate available).
//!
//! Supported grammar — the subset `RunConfig` round-trips through:
//!
//! ```toml
//! # comment
//! key = "string"
//! key2 = 42
//! [section.subsection]
//! flag = true
//! rate = 1.5e-3
//! ```
//!
//! Values: quoted strings, booleans, integers, floats. Keys are flattened
//! to dotted paths (`section.subsection.flag`). Duplicate keys and unknown
//! syntax are hard errors — config typos should fail loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Ok(*i as usize),
            _ => bail!("expected non-negative integer, got {self:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Ok(*i as u64),
            _ => bail!("expected non-negative integer, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_f32(&self) -> Result<f32> {
        Ok(self.as_f64()? as f32)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

/// Parse a document into dotted-path -> value.
pub fn parse(text: &str) -> Result<BTreeMap<String, Value>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            let name = name.trim();
            if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.') {
                bail!("line {}: invalid section name {name:?}", lineno + 1);
            }
            section = name.to_string();
            continue;
        }
        let Some((key, val)) = line.split_once('=') else {
            bail!("line {}: expected `key = value`", lineno + 1);
        };
        let key = key.trim();
        if key.is_empty() || !key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
            bail!("line {}: invalid key {key:?}", lineno + 1);
        }
        let path = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        let value = parse_value(val.trim(), lineno + 1)?;
        if out.insert(path.clone(), value).is_some() {
            bail!("line {}: duplicate key {path}", lineno + 1);
        }
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value> {
    if let Some(body) = s.strip_prefix('"') {
        let Some(body) = body.strip_suffix('"') else {
            bail!("line {lineno}: unterminated string");
        };
        // simple escapes only
        let mut out = String::new();
        let mut chars = body.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => bail!("line {lineno}: bad escape {other:?}"),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(Value::Str(out));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("line {lineno}: cannot parse value {s:?}")
}

/// Serialize helpers for writing configs back out.
pub fn escape_str(s: &str) -> String {
    let mut out = String::from("\"");
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = r#"
# top comment
model = "vit-small"   # trailing comment
seed = 42
[train]
lr = 1.5e-3
epochs = 60
[train.dp]
threaded = true
allreduce = "ring"
"#;
        let m = parse(doc).unwrap();
        assert_eq!(m["model"], Value::Str("vit-small".into()));
        assert_eq!(m["seed"], Value::Int(42));
        assert_eq!(m["train.lr"], Value::Float(1.5e-3));
        assert_eq!(m["train.epochs"], Value::Int(60));
        assert_eq!(m["train.dp.threaded"], Value::Bool(true));
        assert_eq!(m["train.dp.allreduce"], Value::Str("ring".into()));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let m = parse("name = \"exp#1\"").unwrap();
        assert_eq!(m["name"], Value::Str("exp#1".into()));
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("a = ???").is_err());
        assert!(parse("a = 1\na = 2").is_err());
        assert!(parse("bad key = 1").is_err());
    }

    #[test]
    fn value_accessors() {
        assert_eq!(Value::Int(3).as_usize().unwrap(), 3);
        assert!(Value::Int(-1).as_usize().is_err());
        assert_eq!(Value::Int(2).as_f64().unwrap(), 2.0);
        assert!(Value::Str("x".into()).as_f64().is_err());
        assert_eq!(Value::Bool(true).as_bool().unwrap(), true);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "a\"b\\c\nd";
        let doc = format!("k = {}", escape_str(s));
        let m = parse(&doc).unwrap();
        assert_eq!(m["k"], Value::Str(s.into()));
    }
}
