//! Micro-benchmark harness (offline substrate; no `criterion` available).
//!
//! `harness = false` benches call [`Bench::run`] per case: warmup, then
//! timed iterations until both a minimum iteration count and a minimum
//! wall budget are met, reporting mean / p50 / p95 and allowing throughput
//! annotation. Deliberately simple but honest: per-iteration timings, no
//! batching tricks, outliers visible in the p95.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    /// Optional unit count per iteration (e.g. images) for throughput.
    pub units_per_iter: Option<f64>,
}

impl Measurement {
    pub fn throughput(&self) -> Option<f64> {
        self.units_per_iter.map(|u| u / self.mean.as_secs_f64())
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "{:<44} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p95  ({} iters)",
            self.name, self.mean, self.p50, self.p95, self.iters
        );
        if let Some(t) = self.throughput() {
            s.push_str(&format!("  {t:.1} units/s"));
        }
        s
    }
}

/// Harness configuration.
pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_time: Duration,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1_000_000,
            min_time: Duration::from_millis(300),
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Heavier cases (whole epochs): fewer, longer iterations.
    pub fn heavy() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 50,
            min_time: Duration::from_millis(500),
            results: Vec::new(),
        }
    }

    /// Smoke mode: one iteration per case, no warmup — CI runs this to
    /// keep the bench trajectory populated without paying bench latency.
    pub fn smoke() -> Self {
        Self {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 1,
            min_time: Duration::ZERO,
            results: Vec::new(),
        }
    }

    /// Time `f`, which must perform one full iteration per call.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Measurement {
        self.run_with_units(name, None, &mut f)
    }

    /// Time `f` and annotate each iteration as processing `units` items.
    pub fn run_units<F: FnMut()>(&mut self, name: &str, units: f64, mut f: F) -> &Measurement {
        self.run_with_units(name, Some(units), &mut f)
    }

    fn run_with_units(
        &mut self,
        name: &str,
        units: Option<f64>,
        f: &mut dyn FnMut(),
    ) -> &Measurement {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut times: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while (times.len() < self.min_iters || start.elapsed() < self.min_time)
            && times.len() < self.max_iters
        {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        times.sort();
        let total: Duration = times.iter().sum();
        let m = Measurement {
            name: name.to_string(),
            iters: times.len(),
            mean: total / times.len() as u32,
            p50: times[times.len() / 2],
            p95: times[((times.len() as f64 * 0.95) as usize).min(times.len() - 1)],
            units_per_iter: units,
        };
        println!("{}", m.render());
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Write results as CSV next to the figure data.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = String::from("name,iters,mean_s,p50_s,p95_s,units_per_s\n");
        for m in &self.results {
            out.push_str(&format!(
                "{},{},{:.9},{:.9},{:.9},{}\n",
                m.name,
                m.iters,
                m.mean.as_secs_f64(),
                m.p50.as_secs_f64(),
                m.p95.as_secs_f64(),
                m.throughput().map_or(String::from(""), |t| format!("{t:.3}")),
            ));
        }
        std::fs::write(path, out)
    }

    /// Write results as a JSON array (CI artifact format: one object per
    /// measurement, seconds as numbers), plus free-form metadata pairs.
    pub fn write_json(&self, path: &str, metadata: &[(&str, String)]) -> std::io::Result<()> {
        use crate::util::json::Json;
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let results = Json::Arr(
            self.results
                .iter()
                .map(|m| {
                    Json::obj(vec![
                        ("name", Json::Str(m.name.clone())),
                        ("iters", Json::from_usize(m.iters)),
                        ("mean_s", Json::Num(m.mean.as_secs_f64())),
                        ("p50_s", Json::Num(m.p50.as_secs_f64())),
                        ("p95_s", Json::Num(m.p95.as_secs_f64())),
                        (
                            "units_per_s",
                            m.throughput().map_or(Json::Null, Json::Num),
                        ),
                    ])
                })
                .collect(),
        );
        let mut top = vec![("results", results)];
        let meta: Vec<(&str, Json)> = metadata
            .iter()
            .map(|(k, v)| (*k, Json::Str(v.clone())))
            .collect();
        top.extend(meta);
        std::fs::write(path, Json::obj(top).dump_pretty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_orders_percentiles() {
        let mut b = Bench {
            warmup_iters: 0,
            min_iters: 20,
            max_iters: 20,
            min_time: Duration::ZERO,
            results: Vec::new(),
        };
        let mut x = 0u64;
        let m = b.run("spin", || {
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
        });
        assert_eq!(m.iters, 20);
        assert!(m.p50 <= m.p95);
        assert!(m.mean > Duration::ZERO);
        std::hint::black_box(x);
    }

    #[test]
    fn smoke_mode_runs_each_case_once() {
        let mut b = Bench::smoke();
        let mut calls = 0usize;
        b.run("once", || calls += 1);
        assert_eq!(calls, 1, "smoke mode must not warm up or repeat");
    }

    #[test]
    fn json_output_contains_results_and_metadata() {
        let mut b = Bench::smoke();
        b.run_units("case_a", 10.0, || {});
        let path = std::env::temp_dir()
            .join(format!("prelora_bench_{}.json", std::process::id()));
        b.write_json(path.to_str().unwrap(), &[("mode", "smoke".to_string())])
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::Json::parse(&text).unwrap();
        let results = doc.req("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].req("name").unwrap().as_str().unwrap(), "case_a");
        assert_eq!(doc.req("mode").unwrap().as_str().unwrap(), "smoke");
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn throughput_annotation() {
        let mut b = Bench {
            warmup_iters: 0,
            min_iters: 5,
            max_iters: 5,
            min_time: Duration::ZERO,
            results: Vec::new(),
        };
        let m = b.run_units("units", 100.0, || {
            std::thread::sleep(Duration::from_micros(200));
        });
        let t = m.throughput().unwrap();
        assert!(t > 0.0 && t < 1_000_000.0);
    }
}
