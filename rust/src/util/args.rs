//! Tiny CLI argument parser (offline substrate; no `clap` available).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and a
//! leading subcommand word. Unknown flags are hard errors; `--help` text
//! is assembled from registered flags.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone)]
struct FlagSpec {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
}

/// Declarative flag set + parsed values for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    specs: Vec<FlagSpec>,
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
}

impl Args {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a value-taking flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, help, takes_value: true });
        self
    }

    /// Register a boolean switch.
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, help, takes_value: false });
        self
    }

    pub fn usage(&self, cmd: &str) -> String {
        let mut s = format!("usage: prelora {cmd} [flags]\n");
        for spec in &self.specs {
            let arg = if spec.takes_value {
                format!("--{} <value>", spec.name)
            } else {
                format!("--{}", spec.name)
            };
            s.push_str(&format!("  {arg:<24} {}\n", spec.help));
        }
        s
    }

    /// Parse raw args (after the subcommand). Returns Err on unknown flags
    /// or a missing value; `--help` produces a special error containing
    /// the usage text.
    pub fn parse(mut self, cmd: &str, raw: &[String]) -> Result<Self> {
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage(cmd));
            }
            let Some(body) = a.strip_prefix("--") else {
                bail!("unexpected positional argument {a:?}\n{}", self.usage(cmd));
            };
            let (name, inline) = match body.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (body, None),
            };
            let Some(spec) = self.specs.iter().find(|s| s.name == name) else {
                bail!("unknown flag --{name}\n{}", self.usage(cmd));
            };
            if spec.takes_value {
                let value = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        let Some(v) = raw.get(i) else {
                            bail!("flag --{name} requires a value");
                        };
                        v.clone()
                    }
                };
                self.values.insert(name.to_string(), value);
            } else {
                if inline.is_some() {
                    bail!("flag --{name} takes no value");
                }
                self.bools.insert(name.to_string(), true);
            }
            i += 1;
        }
        Ok(self)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(v) => match v.parse::<T>() {
                Ok(x) => Ok(Some(x)),
                Err(e) => bail!("invalid value for --{name}: {e}"),
            },
        }
    }

    pub fn get_switch(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    fn spec() -> Args {
        Args::new()
            .flag("model", "model name")
            .flag("epochs", "epoch count")
            .switch("verbose", "chatty output")
    }

    #[test]
    fn parses_values_and_switches() {
        let a = spec()
            .parse("train", &raw(&["--model", "vit-micro", "--epochs=12", "--verbose"]))
            .unwrap();
        assert_eq!(a.get("model"), Some("vit-micro"));
        assert_eq!(a.get_parsed::<usize>("epochs").unwrap(), Some(12));
        assert!(a.get_switch("verbose"));
        assert_eq!(a.get_or("missing", "dflt"), "dflt");
    }

    #[test]
    fn rejects_unknown_and_missing() {
        assert!(spec().parse("t", &raw(&["--nope"])).is_err());
        assert!(spec().parse("t", &raw(&["--model"])).is_err());
        assert!(spec().parse("t", &raw(&["positional"])).is_err());
        assert!(spec().parse("t", &raw(&["--verbose=1"])).is_err());
    }

    #[test]
    fn help_contains_flags() {
        let err = spec().parse("train", &raw(&["--help"])).unwrap_err().to_string();
        assert!(err.contains("--model"));
        assert!(err.contains("usage: prelora train"));
    }

    #[test]
    fn bad_parse_reports_flag_name() {
        let a = spec().parse("t", &raw(&["--epochs", "abc"])).unwrap();
        let err = a.get_parsed::<usize>("epochs").unwrap_err().to_string();
        assert!(err.contains("--epochs"));
    }
}
