//! Thread-local PJRT client + artifact compilation cache.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use super::executable::Executable;
use crate::manifest::Manifest;

/// A PJRT CPU client plus a name-keyed cache of compiled executables.
///
/// Construction and compilation are one-time costs (recorded for the
/// metrics report); `execute` is the request-path operation.
pub struct Runtime {
    client: xla::PjRtClient,
    // BTreeMap, not HashMap (PL001): anything that ever iterates the
    // cache (diagnostics, eviction) must see name order, not hash order.
    cache: BTreeMap<String, Executable>,
    /// Cumulative compile time, exposed to the metrics report.
    pub compile_seconds: f64,
}

impl Runtime {
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, cache: BTreeMap::new(), compile_seconds: 0.0 })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact (uncached).
    pub fn load_hlo_text(&mut self, name: &str, path: &Path) -> Result<Executable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        self.compile_seconds += t0.elapsed().as_secs_f64();
        Ok(Executable::new(name.to_string(), exe))
    }

    /// Compile (or fetch from cache) one artifact of a manifest.
    pub fn artifact(&mut self, manifest: &Manifest, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let path = manifest.artifact_path(name)?;
            let exe = self.load_hlo_text(name, &path)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Pre-compile a set of artifacts (worker startup).
    pub fn preload(&mut self, manifest: &Manifest, names: &[&str]) -> Result<()> {
        for n in names {
            self.artifact(manifest, n)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Input;

    fn micro() -> Manifest {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/vit-micro");
        Manifest::load(dir).expect("run `make artifacts` first")
    }

    #[test]
    fn load_and_execute_eval_full() {
        let m = micro();
        let mut rt = Runtime::new().unwrap();
        let exe = rt.artifact(&m, "eval_full").unwrap();
        let base = m.load_init_base().unwrap();
        let c = &m.config;
        let images = vec![0.1f32; c.batch_size * c.image_size * c.image_size * c.in_channels];
        let labels = vec![0i32; c.batch_size];
        let img_shape = [
            c.batch_size as i64,
            c.image_size as i64,
            c.image_size as i64,
            c.in_channels as i64,
        ];
        let outs = exe
            .run(&[
                Input::f32(&base, &[m.base.size as i64]),
                Input::f32(&images, &img_shape),
                Input::i32(&labels, &[c.batch_size as i64]),
            ])
            .unwrap();
        assert_eq!(outs.len(), 2); // loss, correct
        let loss = outs[0][0];
        // zero-init head => loss == ln(num_classes)
        assert!((loss - (c.num_classes as f32).ln()).abs() < 0.05, "loss {loss}");
        let correct = outs[1][0];
        assert!((0.0..=c.batch_size as f32).contains(&correct));
    }

    #[test]
    fn full_grads_artifact_returns_gradient_of_right_size() {
        let m = micro();
        let mut rt = Runtime::new().unwrap();
        let exe = rt.artifact(&m, "full_grads").unwrap();
        let base = m.load_init_base().unwrap();
        let c = &m.config;
        let n = c.batch_size * c.image_size * c.image_size * c.in_channels;
        let images: Vec<f32> = (0..n).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
        let labels: Vec<i32> = (0..c.batch_size as i32).map(|i| i % c.num_classes as i32).collect();
        let img_shape = [
            c.batch_size as i64,
            c.image_size as i64,
            c.image_size as i64,
            c.in_channels as i64,
        ];
        let outs = exe
            .run(&[
                Input::f32(&base, &[m.base.size as i64]),
                Input::f32(&images, &img_shape),
                Input::i32(&labels, &[c.batch_size as i64]),
            ])
            .unwrap();
        assert_eq!(outs.len(), 3); // d_base, loss, correct
        assert_eq!(outs[0].len(), m.base.size);
        let gmax = outs[0].iter().fold(0f32, |a, &b| a.max(b.abs()));
        assert!(gmax > 0.0, "gradient must be non-zero");
        assert!(outs[1][0].is_finite());
    }

    #[test]
    fn cache_hits_do_not_recompile() {
        let m = micro();
        let mut rt = Runtime::new().unwrap();
        rt.artifact(&m, "eval_full").unwrap();
        let t = rt.compile_seconds;
        rt.artifact(&m, "eval_full").unwrap();
        assert_eq!(rt.compile_seconds, t);
    }
}
