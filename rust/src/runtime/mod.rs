//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! This is the only module that touches the `xla` crate. The interchange
//! format is HLO *text* (see DESIGN.md and `python/compile/aot.py`): jax
//! >= 0.5 serialized protos carry 64-bit instruction ids that
//! xla_extension 0.5.1 rejects, while the text parser reassigns ids.
//!
//! One [`Runtime`] per thread (the underlying `PjRtClient` is `Rc`-based
//! and not `Send`); the data-parallel engine gives each worker thread its
//! own runtime + compiled executables.

mod client;
mod executable;

pub use client::Runtime;
pub use executable::{Executable, Input};
