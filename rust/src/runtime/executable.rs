//! Compiled executable + typed input bridging between flat vectors and
//! PJRT literals.

use std::time::Instant;

use anyhow::{ensure, Context, Result};

/// One typed input buffer (borrowed; literal creation copies once).
pub enum Input<'a> {
    F32 { data: &'a [f32], shape: &'a [i64] },
    I32 { data: &'a [i32], shape: &'a [i64] },
}

impl<'a> Input<'a> {
    pub fn f32(data: &'a [f32], shape: &'a [i64]) -> Self {
        Input::F32 { data, shape }
    }

    pub fn i32(data: &'a [i32], shape: &'a [i64]) -> Self {
        Input::I32 { data, shape }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Input::F32 { data, shape } => {
                let n: i64 = shape.iter().product();
                ensure!(n as usize == data.len(), "f32 input shape/len mismatch");
                let flat = xla::Literal::vec1(data);
                if shape.len() == 1 { flat } else { flat.reshape(shape)? }
            }
            Input::I32 { data, shape } => {
                let n: i64 = shape.iter().product();
                ensure!(n as usize == data.len(), "i32 input shape/len mismatch");
                let flat = xla::Literal::vec1(data);
                if shape.len() == 1 { flat } else { flat.reshape(shape)? }
            }
        };
        Ok(lit)
    }
}

/// A compiled artifact. `run` returns every tuple element as a flat f32
/// vector (all our artifact outputs are f32: gradients, loss, correct).
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    /// Cumulative wall time spent inside PJRT execute (metrics).
    pub execute_seconds: std::cell::Cell<f64>,
    /// Number of run() calls (metrics).
    pub executions: std::cell::Cell<u64>,
}

impl Executable {
    pub(super) fn new(name: String, exe: xla::PjRtLoadedExecutable) -> Self {
        Self {
            name,
            exe,
            execute_seconds: std::cell::Cell::new(0.0),
            executions: std::cell::Cell::new(0),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with the given inputs; decompose the (return_tuple=True)
    /// result into per-output f32 vectors.
    pub fn run(&self, inputs: &[Input<'_>]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|i| i.to_literal()).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = tuple.decompose_tuple().context("decomposing result tuple")?;
        let mut outs = Vec::with_capacity(parts.len());
        for p in parts {
            outs.push(p.to_vec::<f32>().context("reading f32 output")?);
        }
        self.execute_seconds
            .set(self.execute_seconds.get() + t0.elapsed().as_secs_f64());
        self.executions.set(self.executions.get() + 1);
        Ok(outs)
    }

    /// Mean execute latency so far (seconds).
    pub fn mean_latency(&self) -> f64 {
        let n = self.executions.get();
        if n == 0 {
            0.0
        } else {
            self.execute_seconds.get() / n as f64
        }
    }
}
