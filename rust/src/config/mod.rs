//! Configuration system: TOML-subset-loadable (see `util::tomlish`),
//! validated, with the paper's experimental presets (Table 1 thresholds,
//! warmup sweeps) built in. Unknown keys are hard errors.

mod prelora;
mod train;

pub use prelora::{ConvergenceStrategyKind, PreLoraConfig, StrictnessPreset};
pub use train::{
    DataConfig, DistConfig, DpConfig, LrScheduleKind, OptimizerKind, PipelineConfig, TrainConfig,
    ZeroConfig,
};

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::tomlish::{self, escape_str, Value};

/// Top-level run configuration (one TOML file or built programmatically).
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Model name — must match an `artifacts/<model>/` directory.
    pub model: String,
    /// Root of the AOT artifacts tree.
    pub artifacts_dir: String,
    /// Where CSV/JSONL series are written.
    pub results_dir: String,
    /// Run label used in output file names.
    pub run_name: String,
    pub seed: u64,
    pub train: TrainConfig,
    pub prelora: PreLoraConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            model: "vit-small".into(),
            artifacts_dir: "artifacts".into(),
            results_dir: "results".into(),
            run_name: "run".into(),
            seed: 0,
            train: TrainConfig::default(),
            prelora: PreLoraConfig::default(),
        }
    }
}

impl RunConfig {
    pub fn from_toml_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        Self::from_toml_str(&text)
    }

    /// Parse from the TOML subset; every key must be known.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let map = tomlish::parse(text)?;
        let mut cfg = RunConfig::default();
        for (path, value) in &map {
            cfg.set(path, value).with_context(|| format!("config key {path}"))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    fn set(&mut self, path: &str, v: &Value) -> Result<()> {
        let t = &mut self.train;
        let p = &mut self.prelora;
        match path {
            "model" => self.model = v.as_str()?.to_string(),
            "artifacts_dir" => self.artifacts_dir = v.as_str()?.to_string(),
            "results_dir" => self.results_dir = v.as_str()?.to_string(),
            "run_name" => self.run_name = v.as_str()?.to_string(),
            "seed" => self.seed = v.as_u64()?,
            "train.epochs" => t.epochs = v.as_usize()?,
            "train.optimizer" => t.optimizer = v.as_str()?.parse()?,
            "train.lr_schedule" => t.lr_schedule = v.as_str()?.parse()?,
            "train.lr" => t.lr = v.as_f64()?,
            "train.lr_warmup_frac" => t.lr_warmup_frac = v.as_f64()?,
            "train.min_lr" => t.min_lr = v.as_f64()?,
            "train.weight_decay" => t.weight_decay = v.as_f64()?,
            "train.beta1" => t.beta1 = v.as_f64()?,
            "train.beta2" => t.beta2 = v.as_f64()?,
            "train.eps" => t.eps = v.as_f64()?,
            "train.grad_clip" => t.grad_clip = v.as_f64()?,
            "train.eval_every" => t.eval_every = v.as_usize()?,
            "train.checkpoint_every" => t.checkpoint_every = v.as_usize()?,
            "train.resume" => t.resume = Some(v.as_str()?.to_string()),
            "train.data.train_samples" => t.data.train_samples = v.as_usize()?,
            "train.data.val_samples" => t.data.val_samples = v.as_usize()?,
            "train.data.noise" => t.data.noise = v.as_f32()?,
            "train.data.phase_jitter" => t.data.phase_jitter = v.as_bool()?,
            "train.data.fresh_per_epoch" => t.data.fresh_per_epoch = v.as_bool()?,
            "train.dp.workers" => t.dp.workers = v.as_usize()?,
            "train.dp.allreduce" => t.dp.allreduce = v.as_str()?.to_string(),
            "train.dp.threaded" => t.dp.threaded = v.as_bool()?,
            "train.dist.transport" => t.dist.transport = v.as_str()?.to_string(),
            "train.dist.rank" => t.dist.rank = v.as_usize()?,
            // comma-separated rank-ordered host:port list (the TOML
            // subset has no arrays; same treatment as
            // prelora.convergence_modules)
            "train.dist.peers" => {
                t.dist.peers = v
                    .as_str()?
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect();
            }
            "train.dist.connect_timeout_ms" => t.dist.connect_timeout_ms = v.as_u64()?,
            "train.faults.plan" => t.faults.plan = v.as_str()?.to_string(),
            "train.pipeline.enabled" => t.pipeline.enabled = v.as_bool()?,
            "train.pipeline.prefetch_depth" => t.pipeline.prefetch_depth = v.as_usize()?,
            // deprecated shim (same treatment as train.zero.enabled below)
            "train.pipeline.overlap_reduce" => t.pipeline.overlap_reduce = Some(v.as_bool()?),
            "train.pipeline.bucket_bytes" => t.pipeline.bucket_bytes = v.as_usize()?,
            // deprecated shim; the deprecation warning is surfaced once
            // through TrainConfig::lint() (printed by `prelora train` at
            // startup and by `prelora config-lint`), not at parse time —
            // parsing happens in contexts that print lint anyway
            "train.zero.enabled" => t.zero.enabled = Some(v.as_bool()?),
            "train.zero.stage" => {
                t.zero.stage = Some(
                    crate::dist::ZeroStage::from_usize(v.as_usize()?)
                        .map_err(|e| anyhow::anyhow!("train.zero.stage: {e}"))?,
                );
            }
            "prelora.enabled" => p.enabled = v.as_bool()?,
            "prelora.windows" => p.windows = v.as_usize()?,
            "prelora.window_epochs" => p.window_epochs = v.as_usize()?,
            "prelora.tau" => p.tau = v.as_f64()?,
            "prelora.zeta" => p.zeta = v.as_f64()?,
            "prelora.warmup_epochs" => p.warmup_epochs = v.as_usize()?,
            "prelora.r_min" => p.r_min = Some(v.as_usize()?),
            "prelora.r_max" => p.r_max = Some(v.as_usize()?),
            "prelora.dynamic_ranks" => p.dynamic_ranks = v.as_bool()?,
            "prelora.uniform_rank" => p.uniform_rank = v.as_usize()?,
            "prelora.strategy" => p.strategy = v.as_str()?.parse()?,
            "prelora.ttest_alpha" => p.ttest_alpha = v.as_f64()?,
            "prelora.min_epochs_before_switch" => p.min_epochs_before_switch = v.as_usize()?,
            // comma-separated list (the TOML subset has no arrays)
            "prelora.convergence_modules" => {
                p.convergence_modules = v
                    .as_str()?
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect();
            }
            other => bail!("unknown config key {other:?}"),
        }
        Ok(())
    }

    /// Serialize to the same TOML subset (round-trips through
    /// `from_toml_str`).
    pub fn to_toml(&self) -> String {
        let t = &self.train;
        let p = &self.prelora;
        let mut s = String::new();
        s.push_str(&format!("model = {}\n", escape_str(&self.model)));
        s.push_str(&format!("artifacts_dir = {}\n", escape_str(&self.artifacts_dir)));
        s.push_str(&format!("results_dir = {}\n", escape_str(&self.results_dir)));
        s.push_str(&format!("run_name = {}\n", escape_str(&self.run_name)));
        s.push_str(&format!("seed = {}\n\n", self.seed));
        s.push_str("[train]\n");
        s.push_str(&format!("epochs = {}\n", t.epochs));
        s.push_str(&format!("optimizer = {}\n", escape_str(t.optimizer.as_str())));
        s.push_str(&format!("lr_schedule = {}\n", escape_str(t.lr_schedule.as_str())));
        s.push_str(&format!("lr = {:e}\n", t.lr));
        s.push_str(&format!("lr_warmup_frac = {}\n", fmt_f64(t.lr_warmup_frac)));
        s.push_str(&format!("min_lr = {:e}\n", t.min_lr));
        s.push_str(&format!("weight_decay = {}\n", fmt_f64(t.weight_decay)));
        s.push_str(&format!("beta1 = {}\n", fmt_f64(t.beta1)));
        s.push_str(&format!("beta2 = {}\n", fmt_f64(t.beta2)));
        s.push_str(&format!("eps = {:e}\n", t.eps));
        s.push_str(&format!("grad_clip = {}\n", fmt_f64(t.grad_clip)));
        s.push_str(&format!("eval_every = {}\n", t.eval_every));
        s.push_str(&format!("checkpoint_every = {}\n", t.checkpoint_every));
        if let Some(r) = &t.resume {
            s.push_str(&format!("resume = {}\n", escape_str(r)));
        }
        s.push('\n');
        s.push_str("[train.data]\n");
        s.push_str(&format!("train_samples = {}\n", t.data.train_samples));
        s.push_str(&format!("val_samples = {}\n", t.data.val_samples));
        s.push_str(&format!("noise = {}\n", fmt_f64(t.data.noise as f64)));
        s.push_str(&format!("phase_jitter = {}\n", t.data.phase_jitter));
        s.push_str(&format!("fresh_per_epoch = {}\n\n", t.data.fresh_per_epoch));
        s.push_str("[train.dp]\n");
        s.push_str(&format!("workers = {}\n", t.dp.workers));
        s.push_str(&format!("allreduce = {}\n", escape_str(&t.dp.allreduce)));
        s.push_str(&format!("threaded = {}\n\n", t.dp.threaded));
        s.push_str("[train.dist]\n");
        s.push_str(&format!("transport = {}\n", escape_str(&t.dist.transport)));
        if t.dist.is_tcp() {
            s.push_str(&format!("rank = {}\n", t.dist.rank));
            s.push_str(&format!("peers = {}\n", escape_str(&t.dist.peers.join(","))));
        }
        s.push_str(&format!("connect_timeout_ms = {}\n\n", t.dist.connect_timeout_ms));
        // canonical form only: the deprecated `overlap_reduce` shim is
        // resolved into the bucket size it implies (overlap is pure
        // scheduling — it cannot change a bit — so only bucket_bytes
        // needs re-emitting), mirroring the `[train.zero]` treatment
        s.push_str("[train.pipeline]\n");
        s.push_str(&format!("enabled = {}\n", t.pipeline.enabled));
        s.push_str(&format!("prefetch_depth = {}\n", t.pipeline.prefetch_depth));
        s.push_str(&format!("bucket_bytes = {}\n\n", t.pipeline.effective_bucket_bytes()));
        // canonical form only: the deprecated `enabled` shim is resolved
        // into the stage it means, so re-emitted configs never carry it
        s.push_str("[train.zero]\n");
        s.push_str(&format!("stage = {}\n\n", t.zero.effective_stage().as_u8()));
        // fault injection is off by default and stays out of the TOML
        // when disabled (same treatment as `resume`); the plan re-emits
        // in its canonical sorted spelling
        if t.faults.is_enabled() {
            s.push_str("[train.faults]\n");
            s.push_str(&format!("plan = {}\n\n", escape_str(&t.faults.canonical_plan())));
        }
        s.push_str("[prelora]\n");
        s.push_str(&format!("enabled = {}\n", p.enabled));
        s.push_str(&format!("windows = {}\n", p.windows));
        s.push_str(&format!("window_epochs = {}\n", p.window_epochs));
        s.push_str(&format!("tau = {}\n", fmt_f64(p.tau)));
        s.push_str(&format!("zeta = {}\n", fmt_f64(p.zeta)));
        s.push_str(&format!("warmup_epochs = {}\n", p.warmup_epochs));
        if let Some(r) = p.r_min {
            s.push_str(&format!("r_min = {r}\n"));
        }
        if let Some(r) = p.r_max {
            s.push_str(&format!("r_max = {r}\n"));
        }
        s.push_str(&format!("dynamic_ranks = {}\n", p.dynamic_ranks));
        s.push_str(&format!("uniform_rank = {}\n", p.uniform_rank));
        s.push_str(&format!("strategy = {}\n", escape_str(p.strategy.as_str())));
        s.push_str(&format!("ttest_alpha = {}\n", fmt_f64(p.ttest_alpha)));
        s.push_str(&format!(
            "min_epochs_before_switch = {}\n",
            p.min_epochs_before_switch
        ));
        if !p.convergence_modules.is_empty() {
            s.push_str(&format!(
                "convergence_modules = {}\n",
                escape_str(&p.convergence_modules.join(","))
            ));
        }
        s
    }

    pub fn validate(&self) -> Result<()> {
        self.train.validate()?;
        self.prelora.validate()?;
        Ok(())
    }

    /// Directory holding this run's model artifacts.
    pub fn model_dir(&self) -> std::path::PathBuf {
        Path::new(&self.artifacts_dir).join(&self.model)
    }
}

/// Format a float so the tomlish parser reads it back as Float (or Int
/// where exact — both re-parse to the same f64).
fn fmt_f64(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}.0", x as i64)
    } else {
        format!("{x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn toml_roundtrip() {
        let mut cfg = RunConfig::default();
        cfg.model = "vit-micro".into();
        cfg.prelora.r_min = Some(2);
        cfg.prelora.r_max = Some(8);
        cfg.train.dp.workers = 4;
        let text = cfg.to_toml();
        let back = RunConfig::from_toml_str(&text).unwrap();
        assert_eq!(back.model, cfg.model);
        assert_eq!(back.prelora.tau, cfg.prelora.tau);
        assert_eq!(back.prelora.r_min, Some(2));
        assert_eq!(back.train.epochs, cfg.train.epochs);
        assert_eq!(back.train.dp.workers, 4);
        assert_eq!(back.train.lr, cfg.train.lr);
        assert_eq!(back.train.pipeline.enabled, cfg.train.pipeline.enabled);
        assert_eq!(back.train.pipeline.prefetch_depth, cfg.train.pipeline.prefetch_depth);
    }

    #[test]
    fn pipeline_keys_parse() {
        let cfg = RunConfig::from_toml_str(
            "[train.pipeline]\nenabled = false\nprefetch_depth = 4\noverlap_reduce = false\n",
        )
        .unwrap();
        assert!(!cfg.train.pipeline.enabled);
        assert_eq!(cfg.train.pipeline.prefetch_depth, 4);
        assert_eq!(cfg.train.pipeline.overlap_reduce, Some(false));
        assert!(!cfg.train.pipeline.effective_overlap());
        let cfg =
            RunConfig::from_toml_str("[train.pipeline]\nbucket_bytes = 4096\n").unwrap();
        assert_eq!(cfg.train.pipeline.bucket_bytes, 4096);
        assert_eq!(cfg.train.pipeline.effective_bucket_bytes(), 4096);
    }

    #[test]
    fn deprecated_overlap_reduce_key_canonicalizes_away() {
        // legacy false forces whole-buffer sync; the re-emission resolves
        // the shim into the bucket size it implies and drops the key
        let cfg = RunConfig::from_toml_str(
            "[train.pipeline]\noverlap_reduce = false\n",
        )
        .unwrap();
        assert_eq!(cfg.train.pipeline.overlap_reduce, Some(false));
        assert_eq!(cfg.train.pipeline.effective_bucket_bytes(), 0);
        let text = cfg.to_toml();
        assert!(
            !text.contains("overlap_reduce"),
            "deprecated key must not be re-emitted: {text}"
        );
        assert!(text.contains("bucket_bytes = 0"), "{text}");
        let back = RunConfig::from_toml_str(&text).unwrap();
        assert_eq!(back.train.pipeline.overlap_reduce, None);
        assert!(back.train.pipeline.effective_overlap());
        // an explicit bucket size survives the roundtrip
        let cfg = RunConfig::from_toml_str("[train.pipeline]\nbucket_bytes = 256\n").unwrap();
        let back = RunConfig::from_toml_str(&cfg.to_toml()).unwrap();
        assert_eq!(back.train.pipeline.bucket_bytes, 256);
        // the contradiction is rejected at validate
        assert!(
            RunConfig::from_toml_str(
                "[train.pipeline]\noverlap_reduce = false\nbucket_bytes = 256\n"
            )
            .is_err(),
            "overlap_reduce = false + bucket_bytes > 0 must be rejected"
        );
    }

    #[test]
    fn deprecated_zero_enabled_key_still_means_stage_2() {
        let cfg =
            RunConfig::from_toml_str("[train.zero]\nenabled = true\n[train.dp]\nworkers = 4\n")
                .unwrap();
        assert_eq!(cfg.train.zero.enabled, Some(true));
        assert_eq!(
            cfg.train.zero.effective_stage(),
            crate::dist::ZeroStage::Zero2,
            "legacy enable = stage 2"
        );
        assert_eq!(cfg.train.zero_shards(), 4);
        assert_eq!(cfg.train.zero_grad_parts(), 4);
        // the canonical re-emission resolves the shim away (the zero
        // block carries only the stage; other sections have their own
        // legitimate `enabled` keys)
        let text = cfg.to_toml();
        assert!(text.contains("[train.zero]\nstage = 2"), "{text}");
        let zero_block = text.split("[train.zero]").nth(1).unwrap();
        let zero_block = zero_block.split('[').next().unwrap();
        assert!(!zero_block.contains("enabled"), "deprecated key must not be re-emitted: {text}");
        let back = RunConfig::from_toml_str(&text).unwrap();
        assert_eq!(back.train.zero.enabled, None);
        assert_eq!(back.train.zero.effective_stage(), crate::dist::ZeroStage::Zero2);
        // off by default
        assert_eq!(RunConfig::default().train.zero.effective_stage(), crate::dist::ZeroStage::Off);
        // the contradiction is rejected at validate
        assert!(
            RunConfig::from_toml_str("[train.zero]\nenabled = true\nstage = 0\n").is_err(),
            "enabled = true + stage = 0 must be rejected"
        );
    }

    #[test]
    fn zero_stage_key_parses_the_full_range_and_roundtrips() {
        use crate::dist::ZeroStage;
        for (n, stage) in [
            (0usize, ZeroStage::Off),
            (1, ZeroStage::Zero1),
            (2, ZeroStage::Zero2),
            (3, ZeroStage::Zero3),
        ] {
            let cfg = RunConfig::from_toml_str(&format!(
                "[train.zero]\nstage = {n}\n[train.dp]\nworkers = 4\n"
            ))
            .unwrap();
            assert_eq!(cfg.train.zero.effective_stage(), stage);
            let back = RunConfig::from_toml_str(&cfg.to_toml()).unwrap();
            assert_eq!(back.train.zero.effective_stage(), stage, "stage {n} must roundtrip");
        }
        let cfg = RunConfig::from_toml_str("[train.zero]\nstage = 1\n[train.dp]\nworkers = 4\n")
            .unwrap();
        assert_eq!(cfg.train.zero_shards(), 4, "stage 1 shards optimizer state");
        assert_eq!(cfg.train.zero_grad_parts(), 1, "stage 1 keeps gradients replicated");
        let cfg = RunConfig::from_toml_str("[train.zero]\nstage = 3\n[train.dp]\nworkers = 4\n")
            .unwrap();
        assert_eq!(cfg.train.zero_param_parts(), 4, "stage 3 shards the parameters");
        let err = RunConfig::from_toml_str("[train.zero]\nstage = 4\n").unwrap_err().to_string();
        assert!(err.contains("ZeRO stage"), "stage outside 0..=3 must be rejected: {err}");
    }

    #[test]
    fn dist_keys_parse_and_roundtrip() {
        let cfg = RunConfig::from_toml_str(
            "[train.dist]\ntransport = \"tcp\"\nrank = 1\n\
             peers = \"127.0.0.1:7001, 127.0.0.1:7002\"\nconnect_timeout_ms = 2500\n",
        )
        .unwrap();
        assert!(cfg.train.dist.is_tcp());
        assert_eq!(cfg.train.dist.rank, 1);
        assert_eq!(cfg.train.dist.peers, vec!["127.0.0.1:7001", "127.0.0.1:7002"]);
        assert_eq!(cfg.train.dist.connect_timeout_ms, 2500);
        assert_eq!(cfg.train.world(), 2);
        let back = RunConfig::from_toml_str(&cfg.to_toml()).unwrap();
        assert_eq!(back.train.dist.transport, "tcp");
        assert_eq!(back.train.dist.rank, 1);
        assert_eq!(back.train.dist.peers, cfg.train.dist.peers);
        assert_eq!(back.train.dist.connect_timeout_ms, 2500);
        // the default emits the local transport and no dead peer knobs
        let text = RunConfig::default().to_toml();
        assert!(text.contains("[train.dist]\ntransport = \"local\""), "{text}");
        assert!(!text.contains("peers"), "{text}");
        RunConfig::from_toml_str(&text).unwrap();
        // tcp without peers is rejected at validate
        assert!(RunConfig::from_toml_str("[train.dist]\ntransport = \"tcp\"\n").is_err());
    }

    #[test]
    fn convergence_modules_parse_as_comma_list() {
        let cfg = RunConfig::from_toml_str(
            "[prelora]\nconvergence_modules = \"query, value ,dense\"\n",
        )
        .unwrap();
        assert_eq!(cfg.prelora.convergence_modules, vec!["query", "value", "dense"]);
        let back = RunConfig::from_toml_str(&cfg.to_toml()).unwrap();
        assert_eq!(back.prelora.convergence_modules, cfg.prelora.convergence_modules);
        // default: empty = the paper's alpha set
        assert!(RunConfig::default().prelora.convergence_modules.is_empty());
    }

    #[test]
    fn resume_key_parses_and_roundtrips() {
        let cfg = RunConfig::from_toml_str(
            "[train]\nresume = \"results/run.ckpt\"\ncheckpoint_every = 5\n",
        )
        .unwrap();
        assert_eq!(cfg.train.resume.as_deref(), Some("results/run.ckpt"));
        assert_eq!(cfg.train.checkpoint_every, 5);
        let back = RunConfig::from_toml_str(&cfg.to_toml()).unwrap();
        assert_eq!(back.train.resume, cfg.train.resume);
        // absent by default, and absent keys stay out of the TOML
        assert!(RunConfig::default().train.resume.is_none());
        assert!(!RunConfig::default().to_toml().contains("resume"));
    }

    #[test]
    fn faults_plan_key_parses_canonicalizes_and_roundtrips() {
        let cfg = RunConfig::from_toml_str(
            "[train.faults]\nplan = \" panic@2.0.1 ; straggle@1.0.0:ms=3 \"\n",
        )
        .unwrap();
        assert!(cfg.train.faults.is_enabled());
        // re-emission is canonical: trimmed, sorted by coordinate
        let text = cfg.to_toml();
        assert!(
            text.contains("[train.faults]\nplan = \"straggle@1.0.0:ms=3;panic@2.0.1\""),
            "{text}"
        );
        let back = RunConfig::from_toml_str(&text).unwrap();
        assert_eq!(back.train.faults.canonical_plan(), cfg.train.faults.canonical_plan());
        // off by default, and the disabled block stays out of the TOML
        assert!(!RunConfig::default().train.faults.is_enabled());
        assert!(!RunConfig::default().to_toml().contains("[train.faults]"));
        // malformed plans are rejected at validate, with the key named
        let err = RunConfig::from_toml_str("[train.faults]\nplan = \"meteor@1.0.0\"\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("train.faults.plan"), "{err}");
    }

    #[test]
    fn rejects_unknown_fields() {
        let bad = "model = \"vit-small\"\nnot_a_field = 3\n";
        let err = RunConfig::from_toml_str(bad).unwrap_err().to_string();
        assert!(err.contains("not_a_field"), "{err}");
    }

    #[test]
    fn partial_toml_fills_defaults() {
        let cfg = RunConfig::from_toml_str("model = \"vit-micro\"").unwrap();
        assert_eq!(cfg.model, "vit-micro");
        assert_eq!(cfg.train.epochs, TrainConfig::default().epochs);
    }

    #[test]
    fn enum_keys_parse() {
        let cfg = RunConfig::from_toml_str(
            "[train]\noptimizer = \"sgd\"\nlr_schedule = \"constant\"\n[prelora]\nstrategy = \"welch_ttest\"\n",
        )
        .unwrap();
        assert_eq!(cfg.train.optimizer, OptimizerKind::Sgd);
        assert_eq!(cfg.train.lr_schedule, LrScheduleKind::Constant);
        assert_eq!(cfg.prelora.strategy, ConvergenceStrategyKind::WelchTTest);
        assert!(RunConfig::from_toml_str("[train]\noptimizer = \"adagrad\"").is_err());
    }

    #[test]
    fn invalid_values_rejected_at_validate() {
        assert!(RunConfig::from_toml_str("[train]\nepochs = 0").is_err());
        assert!(RunConfig::from_toml_str("[prelora]\nwindows = 1").is_err());
    }
}
