//! Trainer / optimizer / data / data-parallel configuration.

use anyhow::{bail, ensure, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    AdamW,
    Sgd,
}

impl OptimizerKind {
    pub fn as_str(self) -> &'static str {
        match self {
            OptimizerKind::AdamW => "adam_w",
            OptimizerKind::Sgd => "sgd",
        }
    }
}

impl std::str::FromStr for OptimizerKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "adam_w" | "adamw" => Ok(OptimizerKind::AdamW),
            "sgd" => Ok(OptimizerKind::Sgd),
            other => bail!("unknown optimizer {other:?}"),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LrScheduleKind {
    /// Linear warmup then cosine decay to `min_lr` (Steiner et al. recipe).
    WarmupCosine,
    Constant,
    /// Step decay: lr *= 0.1 at 60% and 85% of training.
    Step,
}

impl LrScheduleKind {
    pub fn as_str(self) -> &'static str {
        match self {
            LrScheduleKind::WarmupCosine => "warmup_cosine",
            LrScheduleKind::Constant => "constant",
            LrScheduleKind::Step => "step",
        }
    }
}

impl std::str::FromStr for LrScheduleKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "warmup_cosine" => Ok(LrScheduleKind::WarmupCosine),
            "constant" => Ok(LrScheduleKind::Constant),
            "step" => Ok(LrScheduleKind::Step),
            other => bail!("unknown lr schedule {other:?}"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct DataConfig {
    /// Training set size (samples); synthetic, generated once per run.
    pub train_samples: usize,
    /// Validation set size.
    pub val_samples: usize,
    /// Additive Gaussian pixel noise sigma (task difficulty knob).
    pub noise: f32,
    /// Random phase jitter in the class pattern (prevents memorizing pixels).
    pub phase_jitter: bool,
    /// Regenerate the training split every epoch (infinite-data regime):
    /// train loss then floors at the task's irreducible error while weight
    /// norms stabilize — the exact Fig. 1 regime the paper's convergence
    /// test assumes. Off = classic fixed-epoch dataset.
    pub fresh_per_epoch: bool,
}

impl Default for DataConfig {
    fn default() -> Self {
        Self { train_samples: 2048, val_samples: 512, noise: 0.35, phase_jitter: true, fresh_per_epoch: false }
    }
}

#[derive(Debug, Clone)]
pub struct DpConfig {
    /// Simulated data-parallel worker count (paper: 64 GPUs; each worker
    /// computes gradients on its own local batch, coordinator all-reduces).
    pub workers: usize,
    /// Gradient all-reduce algorithm: "naive" | "tree" | "ring".
    pub allreduce: String,
    /// Run workers on real OS threads (each owns a PJRT client); `false`
    /// executes shards sequentially on the leader (deterministic debug).
    pub threaded: bool,
}

impl Default for DpConfig {
    fn default() -> Self {
        Self { workers: 1, allreduce: "tree".into(), threaded: true }
    }
}

#[derive(Debug, Clone)]
pub struct ZeroConfig {
    /// Shard training state across the data-parallel workers (ZeRO,
    /// Rajbhandari et al.). Per-epoch losses stay bit-identical to the
    /// replicated path for a fixed seed regardless of `stage` (the
    /// reduce-scatter reuses the all-reduce summation schedule). A no-op
    /// at `workers = 1`. Off by default.
    pub enabled: bool,
    /// Which state is sharded when `enabled`:
    ///
    /// * `1` — optimizer state only: gradients all-reduce to replicated
    ///   full buffers, each worker holds AdamW moments for its owned
    ///   contiguous partition (~1/workers of the total).
    /// * `2` — optimizer state *and* gradient buffers: the reduce is a
    ///   terminal reduce-scatter (no replicated mean-gradient vector is
    ///   ever materialized), each worker keeps only its owned gradient
    ///   partition, updates its parameter slice in place, and the
    ///   replicated parameters are rebuilt by the all-gather the disjoint
    ///   slice writes amount to. `MemoryBreakdown.grad_bytes` shrinks to
    ///   ~1/workers of `grad_total_bytes`.
    pub stage: u8,
}

impl Default for ZeroConfig {
    fn default() -> Self {
        // stage 2 is the default for `enabled = true`: it is what the
        // pre-`stage` `--zero` flag did (terminal reduce-scatter), so old
        // configs keep their exact behavior
        Self { enabled: false, stage: 2 }
    }
}

#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Drive epochs through the staged step pipeline
    /// (prefetch -> compute -> reduce -> update, see `crate::pipeline`);
    /// `false` runs the fully serial reference loop. Both paths produce
    /// bit-identical losses for a fixed seed.
    pub enabled: bool,
    /// Global steps of batches the prefetch stage may materialize ahead of
    /// the compute stage (>= 1).
    pub prefetch_depth: usize,
    /// Reduce the base gradients on the stage thread concurrently with the
    /// LoRA gradients on the leader when a step carries both (warmup).
    pub overlap_reduce: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { enabled: true, prefetch_depth: 2, overlap_reduce: true }
    }
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Total training epochs (paper: 300 on ImageNet; scaled here).
    pub epochs: usize,
    pub optimizer: OptimizerKind,
    pub lr_schedule: LrScheduleKind,
    /// Peak learning rate.
    pub lr: f64,
    /// Fraction of total epochs spent in linear LR warmup.
    pub lr_warmup_frac: f64,
    /// Floor LR for cosine decay.
    pub min_lr: f64,
    pub weight_decay: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    /// Global-norm gradient clip; 0 disables.
    pub grad_clip: f64,
    /// Evaluate on the validation set every this many epochs.
    pub eval_every: usize,
    /// Checkpoint every this many epochs; 0 disables. Checkpoints are v3
    /// (full trajectory: phase machine, norm history, LR position, data
    /// seed) and land atomically at `<results_dir>/<run_name>.ckpt`, so a
    /// preempted run resumes bitwise via `--resume` / `train.resume`.
    pub checkpoint_every: usize,
    /// Checkpoint file to resume from before training (the CLI `--resume`
    /// flag overrides this). The restored run continues mid-trajectory;
    /// see `docs/checkpoint-format.md` § Resuming a run.
    pub resume: Option<String>,
    pub data: DataConfig,
    pub dp: DpConfig,
    pub pipeline: PipelineConfig,
    pub zero: ZeroConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 60,
            optimizer: OptimizerKind::AdamW,
            lr_schedule: LrScheduleKind::WarmupCosine,
            lr: 1e-3,
            lr_warmup_frac: 0.1,
            min_lr: 1e-5,
            weight_decay: 0.05,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            grad_clip: 1.0,
            eval_every: 1,
            checkpoint_every: 0,
            resume: None,
            data: DataConfig::default(),
            dp: DpConfig::default(),
            pipeline: PipelineConfig::default(),
            zero: ZeroConfig::default(),
        }
    }
}

impl TrainConfig {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.epochs >= 1, "epochs >= 1");
        ensure!(self.lr > 0.0, "lr > 0");
        ensure!((0.0..1.0).contains(&self.lr_warmup_frac), "warmup frac in [0,1)");
        ensure!(self.min_lr <= self.lr, "min_lr <= lr");
        ensure!(self.beta1 < 1.0 && self.beta2 < 1.0, "betas < 1");
        ensure!(self.eval_every >= 1, "eval_every >= 1");
        ensure!(self.train_batchable(), "train_samples must be > 0");
        ensure!(self.dp.workers >= 1, "workers >= 1");
        self.dp
            .allreduce
            .parse::<crate::dp::Algorithm>()
            .map_err(|e| anyhow::anyhow!(e))?;
        ensure!(self.pipeline.prefetch_depth >= 1, "pipeline.prefetch_depth >= 1");
        ensure!(
            matches!(self.zero.stage, 1 | 2),
            "zero.stage must be 1 (optimizer state) or 2 (+ gradients), got {}",
            self.zero.stage
        );
        Ok(())
    }

    /// Optimizer-state partition count the run's ZeRO setting implies:
    /// one shard per data-parallel worker when sharding is on, a single
    /// (unsharded) partition otherwise. Stages 1 and 2 both shard the
    /// optimizer state.
    pub fn zero_shards(&self) -> usize {
        if self.zero.enabled {
            self.dp.workers
        } else {
            1
        }
    }

    /// Gradient-buffer partition count: one owned partition per worker at
    /// ZeRO stage 2 (reduce-scatter is terminal), a single replicated
    /// buffer otherwise (stage 1 or sharding off).
    pub fn zero_grad_parts(&self) -> usize {
        if self.zero.enabled && self.zero.stage >= 2 {
            self.dp.workers
        } else {
            1
        }
    }

    fn train_batchable(&self) -> bool {
        self.data.train_samples > 0 && self.data.val_samples > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn bad_allreduce_rejected() {
        let mut cfg = TrainConfig::default();
        cfg.dp.allreduce = "butterfly".into();
        assert!(cfg.validate().is_err());
        // case-insensitive spellings are fine (FromStr is the one parser)
        let mut cfg = TrainConfig::default();
        cfg.dp.allreduce = "Ring".into();
        cfg.validate().unwrap();
    }

    #[test]
    fn zero_shards_follow_workers_only_when_enabled() {
        let mut cfg = TrainConfig::default();
        cfg.dp.workers = 4;
        assert_eq!(cfg.zero_shards(), 1, "off by default");
        assert_eq!(cfg.zero_grad_parts(), 1);
        cfg.zero.enabled = true;
        assert_eq!(cfg.zero_shards(), 4);
        assert_eq!(cfg.zero_grad_parts(), 4, "default stage is 2");
        cfg.dp.workers = 1;
        assert_eq!(cfg.zero_shards(), 1, "single worker: sharding degenerates");
        cfg.validate().unwrap();
    }

    #[test]
    fn zero_stage_gates_gradient_sharding() {
        let mut cfg = TrainConfig::default();
        cfg.dp.workers = 4;
        cfg.zero.enabled = true;
        cfg.zero.stage = 1;
        cfg.validate().unwrap();
        assert_eq!(cfg.zero_shards(), 4, "stage 1 still shards optimizer state");
        assert_eq!(cfg.zero_grad_parts(), 1, "stage 1 keeps gradients replicated");
        cfg.zero.stage = 2;
        cfg.validate().unwrap();
        assert_eq!(cfg.zero_grad_parts(), 4);
        for bad in [0u8, 3] {
            cfg.zero.stage = bad;
            assert!(cfg.validate().is_err(), "stage {bad} must be rejected");
        }
    }

    #[test]
    fn bad_pipeline_depth_rejected() {
        let mut cfg = TrainConfig::default();
        cfg.pipeline.prefetch_depth = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn bad_lr_rejected() {
        let mut cfg = TrainConfig::default();
        cfg.lr = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default();
        cfg.min_lr = 1.0;
        assert!(cfg.validate().is_err());
    }
}
