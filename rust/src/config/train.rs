//! Trainer / optimizer / data / data-parallel configuration.

use anyhow::{bail, ensure, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    AdamW,
    Sgd,
}

impl OptimizerKind {
    pub fn as_str(self) -> &'static str {
        match self {
            OptimizerKind::AdamW => "adam_w",
            OptimizerKind::Sgd => "sgd",
        }
    }
}

impl std::str::FromStr for OptimizerKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "adam_w" | "adamw" => Ok(OptimizerKind::AdamW),
            "sgd" => Ok(OptimizerKind::Sgd),
            other => bail!("unknown optimizer {other:?}"),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LrScheduleKind {
    /// Linear warmup then cosine decay to `min_lr` (Steiner et al. recipe).
    WarmupCosine,
    Constant,
    /// Step decay: lr *= 0.1 at 60% and 85% of training.
    Step,
}

impl LrScheduleKind {
    pub fn as_str(self) -> &'static str {
        match self {
            LrScheduleKind::WarmupCosine => "warmup_cosine",
            LrScheduleKind::Constant => "constant",
            LrScheduleKind::Step => "step",
        }
    }
}

impl std::str::FromStr for LrScheduleKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "warmup_cosine" => Ok(LrScheduleKind::WarmupCosine),
            "constant" => Ok(LrScheduleKind::Constant),
            "step" => Ok(LrScheduleKind::Step),
            other => bail!("unknown lr schedule {other:?}"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct DataConfig {
    /// Training set size (samples); synthetic, generated once per run.
    pub train_samples: usize,
    /// Validation set size.
    pub val_samples: usize,
    /// Additive Gaussian pixel noise sigma (task difficulty knob).
    pub noise: f32,
    /// Random phase jitter in the class pattern (prevents memorizing pixels).
    pub phase_jitter: bool,
    /// Regenerate the training split every epoch (infinite-data regime):
    /// train loss then floors at the task's irreducible error while weight
    /// norms stabilize — the exact Fig. 1 regime the paper's convergence
    /// test assumes. Off = classic fixed-epoch dataset.
    pub fresh_per_epoch: bool,
}

impl Default for DataConfig {
    fn default() -> Self {
        Self { train_samples: 2048, val_samples: 512, noise: 0.35, phase_jitter: true, fresh_per_epoch: false }
    }
}

#[derive(Debug, Clone)]
pub struct DpConfig {
    /// Simulated data-parallel worker count (paper: 64 GPUs; each worker
    /// computes gradients on its own local batch, coordinator all-reduces).
    pub workers: usize,
    /// Gradient all-reduce algorithm: "naive" | "tree" | "ring".
    pub allreduce: String,
    /// Run workers on real OS threads (each owns a PJRT client); `false`
    /// executes shards sequentially on the leader (deterministic debug).
    pub threaded: bool,
}

impl Default for DpConfig {
    fn default() -> Self {
        Self { workers: 1, allreduce: "tree".into(), threaded: true }
    }
}

#[derive(Debug, Clone)]
pub struct DistConfig {
    /// Collective transport the run's ranks communicate over:
    ///
    /// * `"local"` — the in-memory collective; all ranks are simulated
    ///   worker threads inside this one process (the historical mode).
    /// * `"tcp"` — each rank is a separate OS process; gradients travel
    ///   over loopback/LAN TCP through a per-rank
    ///   `dist::CollectiveEndpoint`. Launch one `prelora train` per rank
    ///   with the same `peers` list and distinct `rank`s. Trajectories
    ///   stay bitwise identical to `"local"` at the same seed.
    pub transport: String,
    /// This process's rank in the tcp group (0 hosts the rendezvous).
    pub rank: usize,
    /// Rank-ordered `host:port` list, one entry per rank; `peers[0]` is
    /// the address rank 0 binds and everyone else connects to. Its length
    /// *is* the world size under the tcp transport.
    pub peers: Vec<String>,
    /// Connect/accept retry budget and per-op stall timeout (ms).
    pub connect_timeout_ms: u64,
}

impl Default for DistConfig {
    fn default() -> Self {
        Self { transport: "local".into(), rank: 0, peers: Vec::new(), connect_timeout_ms: 10_000 }
    }
}

impl DistConfig {
    pub fn is_tcp(&self) -> bool {
        self.transport == "tcp"
    }
}

#[derive(Debug, Clone, Default)]
pub struct ZeroConfig {
    /// **Deprecated** legacy knob, kept only so old configs and the old
    /// `--zero` flag still work: `true` means "shard at the default
    /// stage 2" (exactly what it always meant), `false` forces sharding
    /// off even when `stage` is set. Setting it is called out loudly by
    /// [`TrainConfig::lint`] (printed at `prelora train` startup and by
    /// `prelora config-lint`) — write `stage = 0|1|2|3` instead.
    pub enabled: Option<bool>,
    /// The canonical knob: which training state is sharded across the
    /// data-parallel workers (ZeRO, Rajbhandari et al.; the
    /// `dist::Strategy` the run is built with). Stages are cumulative:
    ///
    /// * `0` — off: classic replicated DDP.
    /// * `1` — optimizer state (~1/workers of the AdamW moments per rank).
    /// * `2` — + gradient buffers: the reduce is a terminal
    ///   reduce-scatter; each rank keeps only its owned gradient
    ///   partition (`MemoryBreakdown.grad_bytes` ~ 1/workers).
    /// * `3` — + the parameters themselves: each rank owns a contiguous
    ///   partition, the full working view is all-gathered per step and
    ///   dropped after the update (`MemoryBreakdown.param_bytes_per_rank`
    ///   ~ 1/workers).
    ///
    /// Per-epoch losses stay bit-identical to the replicated path for a
    /// fixed seed at every stage (the reduce-scatter reuses the
    /// all-reduce summation schedule and the parameter gather is an exact
    /// concatenation). A no-op at `workers = 1`. Off (`None`) by default.
    pub stage: Option<crate::dist::ZeroStage>,
}

impl ZeroConfig {
    /// Resolve the deprecated `enabled` shim and the `stage` knob into
    /// the stage the run actually uses: `enabled = false` forces off,
    /// `enabled = true` alone means the historical default (stage 2),
    /// otherwise `stage` (off when neither is set).
    pub fn effective_stage(&self) -> crate::dist::ZeroStage {
        use crate::dist::ZeroStage;
        match (self.enabled, self.stage) {
            (Some(false), _) => ZeroStage::Off,
            (Some(true), None) => ZeroStage::Zero2,
            (_, Some(stage)) => stage,
            (None, None) => ZeroStage::Off,
        }
    }
}

#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Drive epochs through the staged step pipeline
    /// (prefetch -> compute -> reduce -> update, see `crate::pipeline`);
    /// `false` runs the fully serial reference loop. Both paths produce
    /// bit-identical losses for a fixed seed.
    pub enabled: bool,
    /// Global steps of batches the prefetch stage may materialize ahead of
    /// the compute stage (>= 1).
    pub prefetch_depth: usize,
    /// **Deprecated** legacy knob, kept only so old configs keep working
    /// (the `train.zero.enabled` pattern): `true` keeps its historical
    /// meaning — reduce the base gradients on the stage thread
    /// concurrently with the LoRA gradients on the leader — and `false`
    /// additionally forces `bucket_bytes` off. Setting it is called out by
    /// [`TrainConfig::lint`]; phase-level overlap is on by default and
    /// `bucket_bytes` is the knob that actually changes the overlap
    /// granularity. Overlap is pure scheduling: it cannot change a bit of
    /// the trajectory, which is why the canonical config no longer spells
    /// it.
    pub overlap_reduce: Option<bool>,
    /// Bucket-level gradient sync: split each gradient space into buckets
    /// of at most this many bytes (aligned to the ZeRO partition
    /// boundaries), publish each bucket as its slice of backward
    /// completes, and reduce early buckets while later ones are still
    /// computing. `0` (default) = whole-buffer sync. Bitwise identical to
    /// `0` for a fixed seed at any setting — bucketing changes *when*
    /// reduction work happens, never the summation order.
    pub bucket_bytes: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { enabled: true, prefetch_depth: 2, overlap_reduce: None, bucket_bytes: 0 }
    }
}

impl PipelineConfig {
    /// Resolve the deprecated `overlap_reduce` shim: phase-level overlap
    /// is on unless the legacy knob forces it off.
    pub fn effective_overlap(&self) -> bool {
        self.overlap_reduce.unwrap_or(true)
    }

    /// The bucket size the run actually uses: the legacy
    /// `overlap_reduce = false` forces whole-buffer sync (bucketing *is*
    /// reduce overlap, just finer-grained), otherwise `bucket_bytes`.
    pub fn effective_bucket_bytes(&self) -> usize {
        match self.overlap_reduce {
            Some(false) => 0,
            _ => self.bucket_bytes,
        }
    }
}

/// Deterministic fault injection (`prelora::faults`). Off by default:
/// with an empty plan no [`crate::faults::FaultInjector`] is built and
/// every injection site reduces to a single `Option` check — the full
/// parity and bench suites run bitwise-unchanged.
#[derive(Debug, Clone, Default)]
pub struct FaultsConfig {
    /// Fault plan spec: `;`-separated `kind@epoch.step.rank[:key=value]`
    /// entries (see `prelora::faults::FaultPlan` for the grammar and the
    /// kind catalog). Empty = no injection. Validated by
    /// [`TrainConfig::validate`]; re-emitted canonically (sorted entries,
    /// fixed parameter order) by `RunConfig::to_toml`.
    pub plan: String,
}

impl FaultsConfig {
    pub fn is_enabled(&self) -> bool {
        !self.plan.trim().is_empty()
    }

    /// Build the run's injector: `None` when the plan is empty (the
    /// zero-overhead default), an error when the spec is malformed.
    pub fn injector(&self) -> Result<Option<std::sync::Arc<crate::faults::FaultInjector>>> {
        if !self.is_enabled() {
            return Ok(None);
        }
        let plan = crate::faults::FaultPlan::parse(&self.plan)?;
        Ok(Some(std::sync::Arc::new(crate::faults::FaultInjector::new(plan))))
    }

    /// The canonical spelling of the plan for config re-emission. Falls
    /// back to the raw string if the plan does not parse (validate()
    /// rejects that on every load path, so the fallback is defensive).
    pub fn canonical_plan(&self) -> String {
        crate::faults::FaultPlan::parse(&self.plan)
            .map(|p| p.to_spec())
            .unwrap_or_else(|_| self.plan.trim().to_string())
    }
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Total training epochs (paper: 300 on ImageNet; scaled here).
    pub epochs: usize,
    pub optimizer: OptimizerKind,
    pub lr_schedule: LrScheduleKind,
    /// Peak learning rate.
    pub lr: f64,
    /// Fraction of total epochs spent in linear LR warmup.
    pub lr_warmup_frac: f64,
    /// Floor LR for cosine decay.
    pub min_lr: f64,
    pub weight_decay: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    /// Global-norm gradient clip; 0 disables.
    pub grad_clip: f64,
    /// Evaluate on the validation set every this many epochs.
    pub eval_every: usize,
    /// Checkpoint every this many epochs; 0 disables. Checkpoints are v3
    /// (full trajectory: phase machine, norm history, LR position, data
    /// seed) and land atomically at `<results_dir>/<run_name>.ckpt`, so a
    /// preempted run resumes bitwise via `--resume` / `train.resume`.
    pub checkpoint_every: usize,
    /// Checkpoint file to resume from before training (the CLI `--resume`
    /// flag overrides this). The restored run continues mid-trajectory;
    /// see `docs/checkpoint-format.md` § Resuming a run.
    pub resume: Option<String>,
    pub data: DataConfig,
    pub dp: DpConfig,
    pub dist: DistConfig,
    pub pipeline: PipelineConfig,
    pub zero: ZeroConfig,
    pub faults: FaultsConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 60,
            optimizer: OptimizerKind::AdamW,
            lr_schedule: LrScheduleKind::WarmupCosine,
            lr: 1e-3,
            lr_warmup_frac: 0.1,
            min_lr: 1e-5,
            weight_decay: 0.05,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            grad_clip: 1.0,
            eval_every: 1,
            checkpoint_every: 0,
            resume: None,
            data: DataConfig::default(),
            dp: DpConfig::default(),
            dist: DistConfig::default(),
            pipeline: PipelineConfig::default(),
            zero: ZeroConfig::default(),
            faults: FaultsConfig::default(),
        }
    }
}

impl TrainConfig {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.epochs >= 1, "epochs >= 1");
        ensure!(self.lr > 0.0, "lr > 0");
        ensure!((0.0..1.0).contains(&self.lr_warmup_frac), "warmup frac in [0,1)");
        ensure!(self.min_lr <= self.lr, "min_lr <= lr");
        ensure!(self.beta1 < 1.0 && self.beta2 < 1.0, "betas < 1");
        ensure!(self.eval_every >= 1, "eval_every >= 1");
        ensure!(self.train_batchable(), "train_samples must be > 0");
        ensure!(self.dp.workers >= 1, "workers >= 1");
        self.dp
            .allreduce
            .parse::<crate::dp::Algorithm>()
            .map_err(|e| anyhow::anyhow!(e))?;
        ensure!(self.pipeline.prefetch_depth >= 1, "pipeline.prefetch_depth >= 1");
        ensure!(
            !(self.pipeline.overlap_reduce == Some(false) && self.pipeline.bucket_bytes > 0),
            "train.pipeline.overlap_reduce = false contradicts train.pipeline.bucket_bytes > 0 \
             — drop the deprecated overlap knob and set the bucket size you mean"
        );
        ensure!(
            !(self.zero.enabled == Some(true)
                && self.zero.stage == Some(crate::dist::ZeroStage::Off)),
            "train.zero.enabled = true contradicts train.zero.stage = 0 — drop the deprecated \
             enabled knob and set the stage you mean"
        );
        match self.dist.transport.as_str() {
            "local" | "tcp" => {}
            other => bail!(
                "unknown dist transport {other:?} (train.dist.transport / --dist takes \
                 \"local\" or \"tcp\")"
            ),
        }
        if self.dist.is_tcp() {
            ensure!(
                !self.dist.peers.is_empty(),
                "train.dist.transport = \"tcp\" needs a rank-ordered peer list \
                 (train.dist.peers / --peers host:port,host:port,...)"
            );
            ensure!(
                self.dist.rank < self.dist.peers.len(),
                "train.dist.rank = {} is out of range for the {}-rank peer list",
                self.dist.rank,
                self.dist.peers.len()
            );
            ensure!(
                self.dist.peers.iter().all(|p| !p.trim().is_empty()),
                "train.dist.peers contains an empty address"
            );
        }
        ensure!(self.dist.connect_timeout_ms >= 1, "train.dist.connect_timeout_ms >= 1");
        if self.faults.is_enabled() {
            crate::faults::FaultPlan::parse(&self.faults.plan)
                .map_err(|e| anyhow::anyhow!("train.faults.plan: {e:#}"))?;
        }
        Ok(())
    }

    /// The data-parallel world size the run actually trains with: the
    /// length of the tcp peer list when the tcp transport is selected
    /// (the group *is* the peer list; each process computes one rank),
    /// `train.dp.workers` otherwise.
    pub fn world(&self) -> usize {
        if self.dist.is_tcp() {
            self.dist.peers.len()
        } else {
            self.dp.workers
        }
    }

    /// Optimizer-state partition count the run's ZeRO stage implies: one
    /// shard per data-parallel worker from stage 1 up, a single
    /// (unsharded) partition otherwise.
    pub fn zero_shards(&self) -> usize {
        self.zero.effective_stage().opt_shards(self.world())
    }

    /// Gradient-buffer partition count: one owned partition per worker
    /// from ZeRO stage 2 up (reduce-scatter is terminal), a single
    /// replicated buffer otherwise.
    pub fn zero_grad_parts(&self) -> usize {
        self.zero.effective_stage().grad_parts(self.world())
    }

    /// Parameter partition count: one owned partition per worker at ZeRO
    /// stage 3, a single replicated vector otherwise.
    pub fn zero_param_parts(&self) -> usize {
        self.zero.effective_stage().param_parts(self.world())
    }

    /// Non-fatal configuration smells in the `train.zero.*` /
    /// `train.pipeline.*` / `train.dp.*` blocks — surfaced by
    /// `prelora config-lint` (and cheap enough to print anywhere) without
    /// starting a run. Hard errors belong in [`validate`](Self::validate);
    /// these are legal-but-probably-not-what-you-meant setups.
    pub fn lint(&self) -> Vec<String> {
        let mut warnings = Vec::new();
        if self.zero.enabled.is_some() {
            warnings.push(
                "the legacy ZeRO enable knob (train.zero.enabled / --zero) is deprecated: \
                 write train.zero.stage = 0|1|2|3 (or --zero-stage) instead — enabling keeps \
                 its historical meaning, stage 2"
                    .to_string(),
            );
        }
        if self.zero.enabled == Some(false)
            && self.zero.stage.is_some_and(|s| s != crate::dist::ZeroStage::Off)
        {
            warnings.push(format!(
                "train.zero.enabled = false overrides train.zero.stage = {} (legacy \
                 precedence): sharding is OFF — drop the enabled knob if the stage is what \
                 you mean",
                self.zero.stage.unwrap()
            ));
        }
        let stage = self.zero.effective_stage();
        if stage != crate::dist::ZeroStage::Off && self.dp.workers == 1 {
            warnings.push(format!(
                "train.zero.stage = {stage} with train.dp.workers = 1: sharding degenerates to \
                 the unsharded layout (nothing to partition across)"
            ));
        }
        if stage != crate::dist::ZeroStage::Off && self.dp.workers > 64 {
            warnings.push(format!(
                "train.dp.workers = {} simulated ranks with sharding on: partitions get tiny \
                 and chunk-rounding dominates the per-rank accounting",
                self.dp.workers
            ));
        }
        if self.pipeline.prefetch_depth > 16 {
            warnings.push(format!(
                "train.pipeline.prefetch_depth = {} buffers that many global steps of batches \
                 ahead of compute — memory for no additional overlap beyond a small depth",
                self.pipeline.prefetch_depth
            ));
        }
        if self.pipeline.overlap_reduce.is_some() {
            warnings.push(
                "the legacy reduce-overlap knob (train.pipeline.overlap_reduce) is deprecated: \
                 phase-level overlap is always on, and train.pipeline.bucket_bytes is the knob \
                 that changes overlap granularity — overlap_reduce = false keeps its historical \
                 meaning (whole-buffer inline sync, bucketing forced off)"
                    .to_string(),
            );
        }
        if !self.pipeline.enabled
            && (self.pipeline.overlap_reduce == Some(true) || self.pipeline.bucket_bytes > 0)
        {
            warnings.push(
                "train.pipeline.overlap_reduce / train.pipeline.bucket_bytes have no effect \
                 with train.pipeline.enabled = false (the serial reference loop reduces inline)"
                    .to_string(),
            );
        }
        if self.pipeline.bucket_bytes > 0 && self.pipeline.bucket_bytes < 4 {
            warnings.push(format!(
                "train.pipeline.bucket_bytes = {} is smaller than one f32 element: buckets \
                 clamp to one element each and queue overhead dominates the reduce",
                self.pipeline.bucket_bytes
            ));
        }
        if self.pipeline.bucket_bytes >= (1 << 20) {
            warnings.push(format!(
                "train.pipeline.bucket_bytes = {} is larger than the parameter spaces trained \
                 here: every partition fits one bucket, which degenerates to whole-buffer sync \
                 (same as 0)",
                self.pipeline.bucket_bytes
            ));
        }
        if self.dp.workers > 1 && !self.dp.threaded {
            warnings.push(format!(
                "train.dp.workers = {} with train.dp.threaded = false runs every simulated \
                 rank sequentially on the leader (deterministic debug mode, not a speedup)",
                self.dp.workers
            ));
        }
        if self.dist.is_tcp() {
            if self.dp.workers > 1 && self.dp.workers != self.dist.peers.len() {
                warnings.push(format!(
                    "train.dp.workers = {} disagrees with the {}-rank train.dist.peers list: \
                     under the tcp transport the peer list is the world size and each process \
                     computes one rank — drop the workers knob or make them match",
                    self.dp.workers,
                    self.dist.peers.len()
                ));
            }
            if self.dp.workers > 1 && self.dp.threaded {
                warnings.push(format!(
                    "train.dp.workers = {} compute threads with train.dist.transport = \
                     \"tcp\": a tcp rank runs exactly one local compute worker (its shard of \
                     the group), so the extra threads never run",
                    self.dp.workers
                ));
            }
        } else if !self.dist.peers.is_empty() || self.dist.rank != 0 {
            warnings.push(format!(
                "train.dist.rank / train.dist.peers are set ({} peer(s), rank {}) but \
                 train.dist.transport = \"local\" ignores them — set transport = \"tcp\" \
                 (--dist tcp) if a multi-process group is what you mean",
                self.dist.peers.len(),
                self.dist.rank
            ));
        }
        if self.faults.is_enabled() {
            let entries = crate::faults::FaultPlan::parse(&self.faults.plan)
                .map(|p| p.faults().len())
                .unwrap_or(0);
            warnings.push(format!(
                "train.faults.plan is set ({entries} entries): fault injection is armed — \
                 this run may stall, abort, drop peers or tear checkpoints by design \
                 (adversity testing; see docs/testing.md)"
            ));
        }
        warnings
    }

    fn train_batchable(&self) -> bool {
        self.data.train_samples > 0 && self.data.val_samples > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn bad_allreduce_rejected() {
        let mut cfg = TrainConfig::default();
        cfg.dp.allreduce = "butterfly".into();
        assert!(cfg.validate().is_err());
        // case-insensitive spellings are fine (FromStr is the one parser)
        let mut cfg = TrainConfig::default();
        cfg.dp.allreduce = "Ring".into();
        cfg.validate().unwrap();
    }

    #[test]
    fn zero_shards_follow_workers_only_when_sharding() {
        use crate::dist::ZeroStage;
        let mut cfg = TrainConfig::default();
        cfg.dp.workers = 4;
        assert_eq!(cfg.zero.effective_stage(), ZeroStage::Off, "off by default");
        assert_eq!(cfg.zero_shards(), 1);
        assert_eq!(cfg.zero_grad_parts(), 1);
        assert_eq!(cfg.zero_param_parts(), 1);
        // the deprecated knob keeps its historical meaning: stage 2
        cfg.zero.enabled = Some(true);
        assert_eq!(cfg.zero.effective_stage(), ZeroStage::Zero2);
        assert_eq!(cfg.zero_shards(), 4);
        assert_eq!(cfg.zero_grad_parts(), 4, "legacy enable means stage 2");
        assert_eq!(cfg.zero_param_parts(), 1);
        // enabled = false forces off even with a stage set
        cfg.zero.enabled = Some(false);
        cfg.zero.stage = Some(ZeroStage::Zero3);
        assert_eq!(cfg.zero.effective_stage(), ZeroStage::Off);
        cfg.validate().unwrap();
        cfg.zero.enabled = None;
        cfg.dp.workers = 1;
        assert_eq!(cfg.zero_shards(), 1, "single worker: sharding degenerates");
        cfg.validate().unwrap();
    }

    #[test]
    fn zero_stage_gates_each_sharded_dimension() {
        use crate::dist::ZeroStage;
        let mut cfg = TrainConfig::default();
        cfg.dp.workers = 4;
        cfg.zero.stage = Some(ZeroStage::Zero1);
        cfg.validate().unwrap();
        assert_eq!(cfg.zero_shards(), 4, "stage 1 shards optimizer state");
        assert_eq!(cfg.zero_grad_parts(), 1, "stage 1 keeps gradients replicated");
        assert_eq!(cfg.zero_param_parts(), 1);
        cfg.zero.stage = Some(ZeroStage::Zero2);
        cfg.validate().unwrap();
        assert_eq!(cfg.zero_grad_parts(), 4);
        assert_eq!(cfg.zero_param_parts(), 1, "stage 2 keeps parameters replicated");
        cfg.zero.stage = Some(ZeroStage::Zero3);
        cfg.validate().unwrap();
        assert_eq!(cfg.zero_shards(), 4);
        assert_eq!(cfg.zero_grad_parts(), 4);
        assert_eq!(cfg.zero_param_parts(), 4, "stage 3 shards the parameters");
        // the contradiction is a hard error
        cfg.zero.enabled = Some(true);
        cfg.zero.stage = Some(ZeroStage::Off);
        assert!(cfg.validate().is_err(), "enabled = true + stage = 0 must be rejected");
    }

    #[test]
    fn lint_flags_degenerate_and_deprecated_setups() {
        use crate::dist::ZeroStage;
        let cfg = TrainConfig::default();
        assert!(cfg.lint().is_empty(), "the default config must lint clean: {:?}", cfg.lint());
        // deprecated knob
        let mut cfg = TrainConfig::default();
        cfg.zero.enabled = Some(true);
        cfg.dp.workers = 2;
        let w = cfg.lint();
        assert!(w.iter().any(|m| m.contains("deprecated")), "{w:?}");
        // sharding with one worker
        let mut cfg = TrainConfig::default();
        cfg.zero.stage = Some(ZeroStage::Zero3);
        assert!(cfg.lint().iter().any(|m| m.contains("degenerates")), "{:?}", cfg.lint());
        // the legacy knob silently overriding an explicit stage is called out
        let mut cfg = TrainConfig::default();
        cfg.zero.enabled = Some(false);
        cfg.zero.stage = Some(ZeroStage::Zero3);
        cfg.dp.workers = 2;
        assert!(cfg.lint().iter().any(|m| m.contains("overrides")), "{:?}", cfg.lint());
        // excessive prefetch + dead overlap knob + sequential workers
        let mut cfg = TrainConfig::default();
        cfg.pipeline.prefetch_depth = 64;
        cfg.pipeline.enabled = false;
        cfg.pipeline.overlap_reduce = Some(true);
        cfg.dp.workers = 4;
        cfg.dp.threaded = false;
        let w = cfg.lint();
        assert!(w.iter().any(|m| m.contains("prefetch_depth")), "{w:?}");
        assert!(w.iter().any(|m| m.contains("no effect")), "{w:?}");
        assert!(w.iter().any(|m| m.contains("overlap_reduce") && m.contains("deprecated")), "{w:?}");
        assert!(w.iter().any(|m| m.contains("sequentially")), "{w:?}");
        // lint never reports on valid sharded multi-worker runs
        let mut cfg = TrainConfig::default();
        cfg.zero.stage = Some(ZeroStage::Zero2);
        cfg.dp.workers = 4;
        assert!(cfg.lint().is_empty(), "{:?}", cfg.lint());
    }

    #[test]
    fn bad_pipeline_depth_rejected() {
        let mut cfg = TrainConfig::default();
        cfg.pipeline.prefetch_depth = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn overlap_shim_resolves_like_the_zero_shim() {
        // default: overlap on, bucketing off, no lint noise
        let cfg = TrainConfig::default();
        assert!(cfg.pipeline.effective_overlap());
        assert_eq!(cfg.pipeline.effective_bucket_bytes(), 0);
        // legacy true: historical meaning, bucket size passes through
        let mut cfg = TrainConfig::default();
        cfg.pipeline.overlap_reduce = Some(true);
        cfg.pipeline.bucket_bytes = 4096;
        cfg.validate().unwrap();
        assert!(cfg.pipeline.effective_overlap());
        assert_eq!(cfg.pipeline.effective_bucket_bytes(), 4096);
        assert!(cfg.lint().iter().any(|m| m.contains("deprecated")), "{:?}", cfg.lint());
        // legacy false forces both overlap layers off
        let mut cfg = TrainConfig::default();
        cfg.pipeline.overlap_reduce = Some(false);
        cfg.validate().unwrap();
        assert!(!cfg.pipeline.effective_overlap());
        assert_eq!(cfg.pipeline.effective_bucket_bytes(), 0);
        // ...and contradicting it with an explicit bucket size is fatal
        cfg.pipeline.bucket_bytes = 4096;
        assert!(cfg.validate().is_err(), "overlap_reduce = false + bucket_bytes > 0");
    }

    #[test]
    fn lint_flags_degenerate_bucket_sizes() {
        // smaller than one element
        let mut cfg = TrainConfig::default();
        cfg.pipeline.bucket_bytes = 2;
        assert!(cfg.lint().iter().any(|m| m.contains("one f32 element")), "{:?}", cfg.lint());
        // larger than any space trained here
        let mut cfg = TrainConfig::default();
        cfg.pipeline.bucket_bytes = 8 << 20;
        assert!(cfg.lint().iter().any(|m| m.contains("whole-buffer")), "{:?}", cfg.lint());
        // bucketing under a disabled pipeline is dead config
        let mut cfg = TrainConfig::default();
        cfg.pipeline.enabled = false;
        cfg.pipeline.bucket_bytes = 4096;
        assert!(cfg.lint().iter().any(|m| m.contains("no effect")), "{:?}", cfg.lint());
        // a reasonable bucket size lints clean
        let mut cfg = TrainConfig::default();
        cfg.pipeline.bucket_bytes = 4096;
        assert!(cfg.lint().is_empty(), "{:?}", cfg.lint());
    }

    #[test]
    fn dist_transport_is_validated() {
        // default: local transport, no peers — valid and lint-clean
        let cfg = TrainConfig::default();
        assert!(!cfg.dist.is_tcp());
        assert_eq!(cfg.world(), cfg.dp.workers);
        cfg.validate().unwrap();
        // unknown transports are rejected with the accepted spellings
        let mut cfg = TrainConfig::default();
        cfg.dist.transport = "rdma".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("local") && err.contains("tcp"), "{err}");
        // tcp without a peer list is unusable
        let mut cfg = TrainConfig::default();
        cfg.dist.transport = "tcp".into();
        assert!(cfg.validate().unwrap_err().to_string().contains("peer list"));
        // rank must index into the peer list
        cfg.dist.peers = vec!["127.0.0.1:7001".into(), "127.0.0.1:7002".into()];
        cfg.dist.rank = 2;
        assert!(cfg.validate().unwrap_err().to_string().contains("out of range"));
        cfg.dist.rank = 1;
        cfg.validate().unwrap();
        // under tcp the peer list is the world size
        assert_eq!(cfg.world(), 2);
        cfg.zero.stage = Some(crate::dist::ZeroStage::Zero3);
        assert_eq!(cfg.zero_param_parts(), 2, "sharding follows the tcp world");
        // a zero-length timeout can only hang
        let mut cfg = TrainConfig::default();
        cfg.dist.connect_timeout_ms = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn dist_lint_flags_contradictory_knobs() {
        // tcp with threaded local workers: the threads never run
        let mut cfg = TrainConfig::default();
        cfg.dist.transport = "tcp".into();
        cfg.dist.peers = vec!["127.0.0.1:7001".into(), "127.0.0.1:7002".into()];
        cfg.dp.workers = 4;
        let w = cfg.lint();
        assert!(w.iter().any(|m| m.contains("never run")), "{w:?}");
        assert!(w.iter().any(|m| m.contains("disagrees")), "{w:?}");
        // matching workers silences the mismatch but threading is still moot
        cfg.dp.workers = 2;
        let w = cfg.lint();
        assert!(!w.iter().any(|m| m.contains("disagrees")), "{w:?}");
        // peers under the local transport are dead config
        let mut cfg = TrainConfig::default();
        cfg.dist.peers = vec!["127.0.0.1:7001".into()];
        assert!(cfg.lint().iter().any(|m| m.contains("ignores them")), "{:?}", cfg.lint());
        // a clean two-process setup lints clean
        let mut cfg = TrainConfig::default();
        cfg.dist.transport = "tcp".into();
        cfg.dist.peers = vec!["127.0.0.1:7001".into(), "127.0.0.1:7002".into()];
        assert!(cfg.lint().is_empty(), "{:?}", cfg.lint());
    }

    #[test]
    fn faults_plan_is_validated_linted_and_canonicalized() {
        // the default is off: no injector, no lint noise, no emission
        let cfg = TrainConfig::default();
        assert!(!cfg.faults.is_enabled());
        assert!(cfg.faults.injector().unwrap().is_none());
        cfg.validate().unwrap();
        // a valid plan validates, builds an injector, and lints loudly
        let mut cfg = TrainConfig::default();
        cfg.faults.plan = " panic@2.0.1 ; straggle@1.0.0:ms=3 ".into();
        cfg.validate().unwrap();
        assert!(cfg.faults.injector().unwrap().is_some());
        assert!(cfg.lint().iter().any(|m| m.contains("fault injection is armed")), "{:?}", cfg.lint());
        // canonical re-emission sorts entries and strips the whitespace
        assert_eq!(cfg.faults.canonical_plan(), "straggle@1.0.0:ms=3;panic@2.0.1");
        // a malformed plan is a hard validate error naming the key
        let mut cfg = TrainConfig::default();
        cfg.faults.plan = "meteor@1.0.0".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("train.faults.plan"), "{err}");
        assert!(err.contains("unknown fault kind"), "{err}");
    }

    #[test]
    fn bad_lr_rejected() {
        let mut cfg = TrainConfig::default();
        cfg.lr = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default();
        cfg.min_lr = 1.0;
        assert!(cfg.validate().is_err());
    }
}
