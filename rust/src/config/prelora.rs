//! PreLoRA hyper-parameters: the paper's (k, m, tau, zeta, w, r_min, r_max)
//! plus the Table 1 strictness presets and the convergence-strategy ablation.

use anyhow::{bail, ensure, Result};

/// Which partial-convergence detector drives the switch (ablation:
/// the paper's Algorithm 1 vs the dual-loss Welch t-test of Dahal et al.
/// that the related-work section argues against).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConvergenceStrategyKind {
    /// Algorithm 1: windowed weight-norm + loss percentage thresholds.
    WindowedThreshold,
    /// Welch t-test on consecutive loss windows (HPT-style baseline).
    WelchTTest,
}

impl ConvergenceStrategyKind {
    pub fn as_str(self) -> &'static str {
        match self {
            ConvergenceStrategyKind::WindowedThreshold => "windowed_threshold",
            ConvergenceStrategyKind::WelchTTest => "welch_ttest",
        }
    }
}

impl std::str::FromStr for ConvergenceStrategyKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "windowed_threshold" => Ok(ConvergenceStrategyKind::WindowedThreshold),
            "welch_ttest" => Ok(ConvergenceStrategyKind::WelchTTest),
            other => bail!("unknown convergence strategy {other:?}"),
        }
    }
}

/// Table 1 presets: strictness of the partial convergence test.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrictnessPreset {
    /// tau = 1.00%, zeta = 5.00% — relaxed, earliest switch (~40% speedup).
    Exp1,
    /// tau = 0.50%, zeta = 2.50% — the paper's default for the w sweep.
    Exp2,
    /// tau = 0.25%, zeta = 1.00% — strict, latest switch (~28% speedup).
    Exp3,
}

impl StrictnessPreset {
    pub fn thresholds(self) -> (f64, f64) {
        match self {
            StrictnessPreset::Exp1 => (1.00, 5.00),
            StrictnessPreset::Exp2 => (0.50, 2.50),
            StrictnessPreset::Exp3 => (0.25, 1.00),
        }
    }

    pub fn all() -> [StrictnessPreset; 3] {
        [StrictnessPreset::Exp1, StrictnessPreset::Exp2, StrictnessPreset::Exp3]
    }
}

#[derive(Debug, Clone)]
pub struct PreLoraConfig {
    /// Master switch: `false` trains the full baseline end-to-end.
    pub enabled: bool,
    /// Number of consecutive windows k in Algorithm 1 (paper: 3).
    pub windows: usize,
    /// Window size m in epochs (paper: 3).
    pub window_epochs: usize,
    /// Weight-norm threshold tau, percent (Table 1).
    pub tau: f64,
    /// Loss threshold zeta, percent (Table 1).
    pub zeta: f64,
    /// Warmup epochs w: base + LoRA train jointly before the base freezes
    /// (paper sweeps 5/10/15; 10 found best).
    pub warmup_epochs: usize,
    /// Rank bucket bounds (powers of two, inclusive). `None` defers to the
    /// model's manifest defaults.
    pub r_min: Option<usize>,
    pub r_max: Option<usize>,
    /// Use Algorithm 2's dynamic per-layer ranks; `false` = uniform-rank
    /// ablation at `uniform_rank`.
    pub dynamic_ranks: bool,
    /// Rank used when `dynamic_ranks = false`.
    pub uniform_rank: usize,
    pub strategy: ConvergenceStrategyKind,
    /// Significance level for the Welch t-test strategy.
    pub ttest_alpha: f64,
    /// Don't test for convergence before this many epochs (guards the
    /// highly non-stationary early phase, cf. paper's local-minima remark).
    pub min_epochs_before_switch: usize,
    /// Modules whose windowed weight norms the convergence test watches.
    /// Empty = the paper's target set alpha (restricted to what the model
    /// manifest tracks). Every listed module must exist in the manifest's
    /// telemetry set — an unknown name is a startup error, because a
    /// missing module would otherwise read as norm 0 and trivially pass
    /// the tau test.
    pub convergence_modules: Vec<String>,
}

impl Default for PreLoraConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            windows: 3,
            window_epochs: 3,
            tau: 0.50,
            zeta: 2.50,
            warmup_epochs: 10,
            r_min: None,
            r_max: None,
            dynamic_ranks: true,
            uniform_rank: 8,
            strategy: ConvergenceStrategyKind::WindowedThreshold,
            ttest_alpha: 0.05,
            min_epochs_before_switch: 0,
            convergence_modules: Vec::new(),
        }
    }
}

impl PreLoraConfig {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.windows >= 2, "need k >= 2 windows to compare");
        ensure!(self.window_epochs >= 1, "window size m must be >= 1");
        ensure!(self.tau > 0.0 && self.zeta > 0.0, "thresholds must be positive");
        ensure!(self.uniform_rank >= 1, "uniform rank must be >= 1");
        ensure!(
            (0.0..1.0).contains(&self.ttest_alpha) && self.ttest_alpha > 0.0,
            "ttest alpha in (0, 1)"
        );
        if let (Some(lo), Some(hi)) = (self.r_min, self.r_max) {
            ensure!(lo <= hi, "r_min <= r_max");
            ensure!(lo.is_power_of_two() && hi.is_power_of_two(), "ranks are powers of two");
        }
        ensure!(
            self.convergence_modules.iter().all(|m| !m.trim().is_empty()),
            "convergence_modules must not contain empty names"
        );
        Ok(())
    }

    /// Apply a Table 1 preset.
    pub fn with_preset(mut self, p: StrictnessPreset) -> Self {
        let (tau, zeta) = p.thresholds();
        self.tau = tau;
        self.zeta = zeta;
        self
    }

    /// Epochs of history the convergence test needs (k windows of m).
    pub fn history_epochs(&self) -> usize {
        self.windows * self.window_epochs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_table1() {
        assert_eq!(StrictnessPreset::Exp1.thresholds(), (1.00, 5.00));
        assert_eq!(StrictnessPreset::Exp2.thresholds(), (0.50, 2.50));
        assert_eq!(StrictnessPreset::Exp3.thresholds(), (0.25, 1.00));
    }

    #[test]
    fn preset_application() {
        let cfg = PreLoraConfig::default().with_preset(StrictnessPreset::Exp3);
        assert_eq!((cfg.tau, cfg.zeta), (0.25, 1.00));
        cfg.validate().unwrap();
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut cfg = PreLoraConfig::default();
        cfg.windows = 1;
        assert!(cfg.validate().is_err());
        let mut cfg = PreLoraConfig::default();
        cfg.tau = -1.0;
        assert!(cfg.validate().is_err());
        let mut cfg = PreLoraConfig::default();
        cfg.r_min = Some(3);
        cfg.r_max = Some(8);
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn history_epochs_is_k_times_m() {
        let cfg = PreLoraConfig::default();
        assert_eq!(cfg.history_epochs(), 9);
    }
}
