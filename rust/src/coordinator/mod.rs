//! L3 coordinator: the paper's system contribution.
//!
//! [`PreLoraController`] glues together the telemetry stream, the partial
//! convergence test (Algorithm 1), the rank-assignment algorithm
//! (Algorithm 2) and the warmup schedule (§3.3) into the phase machine
//!
//! ```text
//! FullParam --(convergence test passes at a window boundary)--> Warmup(w)
//! Warmup    --(w epochs elapsed)------------------------------> LoraOnly
//! ```
//!
//! The controller is deliberately model-agnostic: it sees only the
//! manifest-driven norm history and epoch losses, which is what makes the
//! framework "generalizable ... across diverse domains" (paper §5).

mod controller;
mod phase;

pub use controller::{resolve_watch_modules, Decision, PreLoraController};
pub use phase::Phase;
