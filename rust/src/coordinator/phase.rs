//! Training-phase state machine.

use std::fmt;

use anyhow::{bail, Result};

use crate::util::json::Json;

/// The three phases of a PreLoRA run (paper Figure 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Full-parameter training; convergence monitored at window boundaries.
    FullParam,
    /// Base + LoRA train jointly; base still updating (paper §3.3).
    Warmup { since_epoch: usize },
    /// Base frozen; only LoRA adapters train.
    LoraOnly { since_epoch: usize },
}

impl Phase {
    pub fn is_full(&self) -> bool {
        matches!(self, Phase::FullParam)
    }

    pub fn is_warmup(&self) -> bool {
        matches!(self, Phase::Warmup { .. })
    }

    pub fn is_lora_only(&self) -> bool {
        matches!(self, Phase::LoraOnly { .. })
    }

    /// Stable label used in CSV output.
    pub fn label(&self) -> &'static str {
        match self {
            Phase::FullParam => "full",
            Phase::Warmup { .. } => "warmup",
            Phase::LoraOnly { .. } => "lora",
        }
    }

    /// Serialize for the v3 checkpoint's trajectory block: the [`label`]
    /// plus `since_epoch` for the phases that carry one.
    ///
    /// [`label`]: Self::label
    pub fn to_json(&self) -> Json {
        match self {
            Phase::FullParam => Json::obj(vec![("kind", Json::Str("full".into()))]),
            Phase::Warmup { since_epoch } => Json::obj(vec![
                ("kind", Json::Str("warmup".into())),
                ("since_epoch", Json::from_usize(*since_epoch)),
            ]),
            Phase::LoraOnly { since_epoch } => Json::obj(vec![
                ("kind", Json::Str("lora".into())),
                ("since_epoch", Json::from_usize(*since_epoch)),
            ]),
        }
    }

    /// Parse a value written by [`to_json`](Self::to_json).
    pub fn from_json(v: &Json) -> Result<Phase> {
        let kind = v.req("kind")?.as_str()?;
        match kind {
            "full" => Ok(Phase::FullParam),
            "warmup" => Ok(Phase::Warmup { since_epoch: v.req("since_epoch")?.as_usize()? }),
            "lora" => Ok(Phase::LoraOnly { since_epoch: v.req("since_epoch")?.as_usize()? }),
            other => bail!("unknown phase kind {other:?}"),
        }
    }
}

impl fmt::Display for Phase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Phase::FullParam => write!(f, "full-param"),
            Phase::Warmup { since_epoch } => write!(f, "warmup(since={since_epoch})"),
            Phase::LoraOnly { since_epoch } => write!(f, "lora-only(since={since_epoch})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrips_every_phase() {
        for p in [
            Phase::FullParam,
            Phase::Warmup { since_epoch: 9 },
            Phase::LoraOnly { since_epoch: 14 },
        ] {
            let text = p.to_json().dump();
            let back = Phase::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, p, "{text}");
        }
        let bad = Json::obj(vec![("kind", Json::Str("frozen".into()))]);
        assert!(Phase::from_json(&bad).is_err());
        // warmup/lora without since_epoch are malformed
        let partial = Json::obj(vec![("kind", Json::Str("warmup".into()))]);
        assert!(Phase::from_json(&partial).is_err());
    }

    #[test]
    fn labels_and_predicates() {
        assert_eq!(Phase::FullParam.label(), "full");
        assert!(Phase::FullParam.is_full());
        let w = Phase::Warmup { since_epoch: 3 };
        assert!(w.is_warmup() && !w.is_full());
        assert_eq!(w.label(), "warmup");
        let l = Phase::LoraOnly { since_epoch: 9 };
        assert!(l.is_lora_only());
        assert_eq!(format!("{l}"), "lora-only(since=9)");
    }
}
