//! Training-phase state machine.

use std::fmt;

/// The three phases of a PreLoRA run (paper Figure 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Full-parameter training; convergence monitored at window boundaries.
    FullParam,
    /// Base + LoRA train jointly; base still updating (paper §3.3).
    Warmup { since_epoch: usize },
    /// Base frozen; only LoRA adapters train.
    LoraOnly { since_epoch: usize },
}

impl Phase {
    pub fn is_full(&self) -> bool {
        matches!(self, Phase::FullParam)
    }

    pub fn is_warmup(&self) -> bool {
        matches!(self, Phase::Warmup { .. })
    }

    pub fn is_lora_only(&self) -> bool {
        matches!(self, Phase::LoraOnly { .. })
    }

    /// Stable label used in CSV output.
    pub fn label(&self) -> &'static str {
        match self {
            Phase::FullParam => "full",
            Phase::Warmup { .. } => "warmup",
            Phase::LoraOnly { .. } => "lora",
        }
    }
}

impl fmt::Display for Phase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Phase::FullParam => write!(f, "full-param"),
            Phase::Warmup { since_epoch } => write!(f, "warmup(since={since_epoch})"),
            Phase::LoraOnly { since_epoch } => write!(f, "lora-only(since={since_epoch})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_and_predicates() {
        assert_eq!(Phase::FullParam.label(), "full");
        assert!(Phase::FullParam.is_full());
        let w = Phase::Warmup { since_epoch: 3 };
        assert!(w.is_warmup() && !w.is_full());
        assert_eq!(w.label(), "warmup");
        let l = Phase::LoraOnly { since_epoch: 9 };
        assert!(l.is_lora_only());
        assert_eq!(format!("{l}"), "lora-only(since=9)");
    }
}
