//! The PreLoRA controller: telemetry in, phase decisions out.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::config::PreLoraConfig;
use crate::convergence::{self, ConvergenceReport, ConvergenceStrategy};
use crate::manifest::{Manifest, ADAPTED_MODULES};
use crate::rank::{assign_ranks, uniform_ranks, RankAssignment};
use crate::telemetry::NormHistory;

use super::Phase;

/// What the trainer must do at an epoch boundary.
#[derive(Debug, Clone)]
pub enum Decision {
    /// Keep training in the current phase.
    Stay,
    /// Convergence detected: initialize adapters with this assignment and
    /// enter the warmup phase (base + LoRA jointly).
    SwitchToWarmup { assignment: RankAssignment, report: ConvergenceReport },
    /// Warmup window elapsed: freeze the base, train adapters only.
    FreezeBase,
}

/// Resolve the module watch list the convergence test will observe.
/// Errors when an explicitly configured module is not tracked by the
/// manifest's telemetry, or when the resolved list is empty — unless
/// `strict` is false, which skips the failures (a disabled controller
/// never consults its strategy, so a baseline run must not fail on
/// convergence config it will not use). `prelora config-lint` calls this
/// with `strict = true` to surface the same validation without a run.
pub fn resolve_watch_modules(
    cfg: &PreLoraConfig,
    manifest: &Manifest,
    strict: bool,
) -> Result<Vec<String>> {
    let tracked = manifest.telemetry_modules();
    let target_modules: Vec<String> = if cfg.convergence_modules.is_empty() {
        // default: the paper's alpha set, restricted to what this
        // manifest exposes
        ADAPTED_MODULES
            .iter()
            .map(|s| s.to_string())
            .filter(|m| tracked.contains(m))
            .collect()
    } else {
        for m in &cfg.convergence_modules {
            ensure!(
                !strict || tracked.contains(m),
                "convergence module {m:?} is not tracked by the manifest (telemetry set: {tracked:?})"
            );
        }
        cfg.convergence_modules.clone()
    };
    ensure!(!strict || !target_modules.is_empty(), "no convergence modules to watch");
    Ok(target_modules)
}

/// Drives the Full -> Warmup -> LoraOnly phase machine from telemetry.
pub struct PreLoraController {
    cfg: PreLoraConfig,
    strategy: Box<dyn ConvergenceStrategy + Send>,
    phase: Phase,
    /// Target modules (the paper's alpha set, filtered to what the
    /// manifest actually exposes).
    target_modules: Vec<String>,
    r_min: usize,
    r_max: usize,
    depth: usize,
    switch_epoch: Option<usize>,
    freeze_epoch: Option<usize>,
    /// Evidence from the convergence checks (logged by harnesses).
    pub checks: Vec<(usize, ConvergenceReport)>,
}

impl PreLoraController {
    /// Build the controller. Errors when `cfg.convergence_modules` names a
    /// module the manifest's telemetry does not track: an untracked module
    /// would otherwise contribute no norm signal and could silently pass
    /// the tau test (a misspelling must fail at startup, not train for
    /// hours and switch on garbage evidence). A disabled controller
    /// (`prelora.enabled = false`) skips the validation — its strategy is
    /// never consulted, and a baseline run must not fail on convergence
    /// config it will not use.
    pub fn new(cfg: PreLoraConfig, manifest: &Manifest) -> Result<Self> {
        let target_modules = resolve_watch_modules(&cfg, manifest, cfg.enabled)?;
        let strategy = convergence::build(&cfg, target_modules.clone());
        let r_min = cfg.r_min.unwrap_or(manifest.config.r_min);
        let r_max = cfg.r_max.unwrap_or(manifest.config.r_max);
        Ok(Self {
            cfg,
            strategy,
            phase: Phase::FullParam,
            target_modules,
            r_min,
            r_max,
            depth: manifest.config.depth,
            switch_epoch: None,
            freeze_epoch: None,
            checks: Vec::new(),
        })
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    pub fn switch_epoch(&self) -> Option<usize> {
        self.switch_epoch
    }

    pub fn freeze_epoch(&self) -> Option<usize> {
        self.freeze_epoch
    }

    pub fn strategy_name(&self) -> &'static str {
        self.strategy.name()
    }

    /// Restore the phase machine from a checkpoint's trajectory block so
    /// a resumed run continues mid-trajectory instead of replaying
    /// convergence detection. Validates the phase/epoch invariants the
    /// state machine maintains (a warmup phase *is* its switch epoch, a
    /// frozen phase carries both cursors) — a checkpoint that violates
    /// them would make `on_epoch_end` schedule the freeze off the wrong
    /// epoch.
    pub fn restore_state(
        &mut self,
        phase: Phase,
        switch_epoch: Option<usize>,
        freeze_epoch: Option<usize>,
        checks: Vec<(usize, ConvergenceReport)>,
    ) -> Result<()> {
        match phase {
            Phase::FullParam => ensure!(
                switch_epoch.is_none() && freeze_epoch.is_none(),
                "full-param phase cannot carry switch/freeze epochs ({switch_epoch:?}/{freeze_epoch:?})"
            ),
            Phase::Warmup { since_epoch } => {
                ensure!(
                    switch_epoch == Some(since_epoch),
                    "warmup since epoch {since_epoch} disagrees with switch epoch {switch_epoch:?}"
                );
                ensure!(
                    freeze_epoch.is_none(),
                    "warmup phase cannot already carry a freeze epoch ({freeze_epoch:?})"
                );
            }
            Phase::LoraOnly { since_epoch } => {
                ensure!(
                    freeze_epoch == Some(since_epoch),
                    "lora-only since epoch {since_epoch} disagrees with freeze epoch {freeze_epoch:?}"
                );
                ensure!(
                    switch_epoch.is_some_and(|s| s <= since_epoch),
                    "lora-only phase needs a switch epoch <= {since_epoch}, got {switch_epoch:?}"
                );
            }
        }
        self.phase = phase;
        self.switch_epoch = switch_epoch;
        self.freeze_epoch = freeze_epoch;
        self.checks = checks;
        Ok(())
    }

    /// Consult the controller after `history` has absorbed an epoch.
    /// `history.epochs()` is the number of completed epochs.
    pub fn on_epoch_end(&mut self, history: &NormHistory) -> Decision {
        if !self.cfg.enabled {
            return Decision::Stay;
        }
        let epoch = history.epochs();
        match self.phase {
            Phase::FullParam => {
                // test only at window boundaries (paper §4.1: testing too
                // frequently risks false positives from local minima)
                let m = self.cfg.window_epochs;
                if epoch < self.cfg.min_epochs_before_switch
                    || epoch % m != 0
                    || epoch < self.strategy.required_epochs()
                {
                    return Decision::Stay;
                }
                let report = self.strategy.check(history, epoch);
                self.checks.push((epoch, report.clone()));
                if !report.converged {
                    return Decision::Stay;
                }
                let assignment = self.make_assignment(history, epoch);
                self.phase = Phase::Warmup { since_epoch: epoch };
                self.switch_epoch = Some(epoch);
                Decision::SwitchToWarmup { assignment, report }
            }
            Phase::Warmup { since_epoch } => {
                if epoch >= since_epoch + self.cfg.warmup_epochs {
                    self.phase = Phase::LoraOnly { since_epoch: epoch };
                    self.freeze_epoch = Some(epoch);
                    Decision::FreezeBase
                } else {
                    Decision::Stay
                }
            }
            Phase::LoraOnly { .. } => Decision::Stay,
        }
    }

    /// Algorithm 2 inputs: per-layer weight deltas between the last two
    /// windows at the switch point.
    fn make_assignment(&self, history: &NormHistory, epoch: usize) -> RankAssignment {
        if !self.cfg.dynamic_ranks {
            return uniform_ranks(&self.target_modules, self.depth, self.cfg.uniform_rank);
        }
        let m = self.cfg.window_epochs;
        let mut deltas = BTreeMap::new();
        for module in &self.target_modules {
            let d = history
                .last_two_window_layer_deltas(module, epoch, m)
                .unwrap_or_else(|| vec![0.0; self.depth]);
            deltas.insert(module.clone(), d);
        }
        assign_ranks(&deltas, self.r_min, self.r_max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::NormSnapshot;
    use std::path::PathBuf;

    fn micro() -> Manifest {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/vit-micro");
        Manifest::load(dir).expect("run `make artifacts` first")
    }

    /// History where norms/losses move by `slope` per epoch (percent-ish).
    fn feed(h: &mut NormHistory, epochs: usize, norm0: f64, slope: f64, loss0: f64, lslope: f64) {
        let start = h.epochs();
        for e in start..start + epochs {
            let mut by_module = BTreeMap::new();
            for md in ADAPTED_MODULES {
                let base = norm0 + slope * e as f64;
                // layers diverge slightly so rank assignment has signal
                by_module.insert(md.to_string(), vec![base, base * 1.01]);
            }
            by_module.insert("mlp_out".into(), vec![norm0, norm0]);
            h.push(NormSnapshot { epoch: e, by_module }, loss0 + lslope * e as f64);
        }
    }

    fn cfg() -> PreLoraConfig {
        let mut c = PreLoraConfig::default();
        c.windows = 3;
        c.window_epochs = 3;
        c.tau = 0.5;
        c.zeta = 2.5;
        c.warmup_epochs = 2;
        c
    }

    #[test]
    fn unknown_convergence_module_is_a_startup_error() {
        let m = micro();
        let mut c = cfg();
        c.convergence_modules = vec!["query".into(), "qurey".into()]; // misspelled
        let err = match PreLoraController::new(c, &m) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("misspelled module must be rejected at startup"),
        };
        assert!(err.contains("qurey"), "{err}");
        // a correctly spelled explicit list is accepted
        let mut c = cfg();
        c.convergence_modules = vec!["query".into(), "dense".into()];
        PreLoraController::new(c, &m).unwrap();
        // a disabled controller never consults the strategy, so a
        // baseline run must not fail on convergence config it won't use
        let mut c = cfg();
        c.enabled = false;
        c.convergence_modules = vec!["qurey".into()];
        PreLoraController::new(c, &m).unwrap();
    }

    #[test]
    fn stays_while_training_moves() {
        let m = micro();
        let mut ctl = PreLoraController::new(cfg(), &m).unwrap();
        let mut h = NormHistory::new();
        feed(&mut h, 12, 10.0, 0.5, 3.0, -0.2); // 5%/epoch norm growth
        for _ in 0..h.epochs() {
            // replay epoch ends — phase must remain FullParam
        }
        let d = ctl.on_epoch_end(&h);
        assert!(matches!(d, Decision::Stay));
        assert!(ctl.phase().is_full());
    }

    #[test]
    fn full_lifecycle_switches_then_freezes() {
        let m = micro();
        let mut ctl = PreLoraController::new(cfg(), &m).unwrap();
        let mut h = NormHistory::new();
        // plateau from the start: converges at the first eligible boundary
        feed(&mut h, 9, 10.0, 0.0001, 2.0, -0.0001);
        let d = ctl.on_epoch_end(&h);
        let assignment = match d {
            Decision::SwitchToWarmup { assignment, report } => {
                assert!(report.converged);
                assignment
            }
            other => panic!("expected switch, got {other:?}"),
        };
        assert_eq!(ctl.switch_epoch(), Some(9));
        assert!(ctl.phase().is_warmup());
        // every target module got per-layer ranks within bounds
        for md in ADAPTED_MODULES {
            let ranks = &assignment.by_module[md];
            assert_eq!(ranks.len(), m.config.depth);
            for &r in ranks {
                assert!(r >= m.config.r_min && r <= m.config.r_max);
            }
        }
        // warmup lasts exactly w epochs
        feed(&mut h, 1, 10.0, 0.0, 2.0, 0.0);
        assert!(matches!(ctl.on_epoch_end(&h), Decision::Stay));
        feed(&mut h, 1, 10.0, 0.0, 2.0, 0.0);
        assert!(matches!(ctl.on_epoch_end(&h), Decision::FreezeBase));
        assert!(ctl.phase().is_lora_only());
        assert_eq!(ctl.freeze_epoch(), Some(11));
        // further epochs: stay
        feed(&mut h, 1, 10.0, 0.0, 2.0, 0.0);
        assert!(matches!(ctl.on_epoch_end(&h), Decision::Stay));
    }

    #[test]
    fn disabled_controller_never_switches() {
        let m = micro();
        let mut c = cfg();
        c.enabled = false;
        let mut ctl = PreLoraController::new(c, &m).unwrap();
        let mut h = NormHistory::new();
        feed(&mut h, 20, 10.0, 0.0, 2.0, 0.0);
        assert!(matches!(ctl.on_epoch_end(&h), Decision::Stay));
        assert!(ctl.phase().is_full());
    }

    #[test]
    fn only_checks_at_window_boundaries() {
        let m = micro();
        let mut ctl = PreLoraController::new(cfg(), &m).unwrap();
        let mut h = NormHistory::new();
        feed(&mut h, 10, 10.0, 0.0, 2.0, 0.0); // epoch 10: not a multiple of 3
        let _ = ctl.on_epoch_end(&h);
        assert!(ctl.checks.is_empty(), "no check off-boundary");
    }

    #[test]
    fn min_epochs_guard_delays_switch() {
        let m = micro();
        let mut c = cfg();
        c.min_epochs_before_switch = 12;
        let mut ctl = PreLoraController::new(c, &m).unwrap();
        let mut h = NormHistory::new();
        feed(&mut h, 9, 10.0, 0.0, 2.0, 0.0);
        assert!(matches!(ctl.on_epoch_end(&h), Decision::Stay));
        feed(&mut h, 3, 10.0, 0.0, 2.0, 0.0);
        assert!(matches!(ctl.on_epoch_end(&h), Decision::SwitchToWarmup { .. }));
    }

    #[test]
    fn resolve_watch_modules_lint_cases() {
        let m = micro();
        // ok: explicit list of tracked modules resolves verbatim
        let mut c = cfg();
        c.convergence_modules = vec!["query".into(), "dense".into()];
        let mods = resolve_watch_modules(&c, &m, true).unwrap();
        assert_eq!(mods, vec!["query".to_string(), "dense".to_string()]);
        // ok: empty list resolves to the paper's alpha set (non-empty)
        let c = cfg();
        let mods = resolve_watch_modules(&c, &m, true).unwrap();
        assert!(!mods.is_empty(), "default alpha set must resolve");
        // unknown module is an error in strict mode, named in the message
        let mut c = cfg();
        c.convergence_modules = vec!["qurey".into()];
        let err = resolve_watch_modules(&c, &m, true).unwrap_err().to_string();
        assert!(err.contains("qurey"), "{err}");
        // ...but tolerated when not strict (disabled controller)
        resolve_watch_modules(&c, &m, false).unwrap();
    }

    #[test]
    fn restore_state_resumes_mid_trajectory() {
        let m = micro();
        // restore into mid-warmup: the freeze must fire exactly
        // warmup_epochs after the restored switch epoch
        let mut ctl = PreLoraController::new(cfg(), &m).unwrap(); // warmup_epochs = 2
        ctl.restore_state(Phase::Warmup { since_epoch: 9 }, Some(9), None, Vec::new())
            .unwrap();
        assert!(ctl.phase().is_warmup());
        assert_eq!(ctl.switch_epoch(), Some(9));
        let mut h = NormHistory::new();
        feed(&mut h, 10, 10.0, 0.0, 2.0, 0.0);
        assert!(matches!(ctl.on_epoch_end(&h), Decision::Stay), "epoch 10: warmup continues");
        feed(&mut h, 1, 10.0, 0.0, 2.0, 0.0);
        assert!(
            matches!(ctl.on_epoch_end(&h), Decision::FreezeBase),
            "epoch 11 = switch + w: freeze"
        );
        assert_eq!(ctl.freeze_epoch(), Some(11));
        // restore into lora-only: no further transitions
        let mut ctl = PreLoraController::new(cfg(), &m).unwrap();
        ctl.restore_state(Phase::LoraOnly { since_epoch: 11 }, Some(9), Some(11), Vec::new())
            .unwrap();
        assert!(ctl.phase().is_lora_only());
        feed(&mut h, 1, 10.0, 0.0, 2.0, 0.0);
        assert!(matches!(ctl.on_epoch_end(&h), Decision::Stay));
    }

    #[test]
    fn restore_state_rejects_inconsistent_cursors() {
        let m = micro();
        let mut ctl = PreLoraController::new(cfg(), &m).unwrap();
        // full phase with a switch epoch
        assert!(ctl.restore_state(Phase::FullParam, Some(3), None, Vec::new()).is_err());
        // warmup whose since_epoch disagrees with the switch cursor
        assert!(ctl
            .restore_state(Phase::Warmup { since_epoch: 5 }, Some(4), None, Vec::new())
            .is_err());
        // warmup that already carries a freeze epoch
        assert!(ctl
            .restore_state(Phase::Warmup { since_epoch: 5 }, Some(5), Some(7), Vec::new())
            .is_err());
        // lora-only without a switch epoch, or with switch after freeze
        assert!(ctl
            .restore_state(Phase::LoraOnly { since_epoch: 7 }, None, Some(7), Vec::new())
            .is_err());
        assert!(ctl
            .restore_state(Phase::LoraOnly { since_epoch: 7 }, Some(9), Some(7), Vec::new())
            .is_err());
        // the failed restores must not have mutated the machine
        assert!(ctl.phase().is_full());
        assert_eq!(ctl.switch_epoch(), None);
    }

    #[test]
    fn uniform_rank_ablation() {
        let m = micro();
        let mut c = cfg();
        c.dynamic_ranks = false;
        c.uniform_rank = 4;
        let mut ctl = PreLoraController::new(c, &m).unwrap();
        let mut h = NormHistory::new();
        feed(&mut h, 9, 10.0, 0.0, 2.0, 0.0);
        match ctl.on_epoch_end(&h) {
            Decision::SwitchToWarmup { assignment, .. } => {
                assert!(assignment.histogram().keys().all(|&r| r == 4));
            }
            other => panic!("expected switch, got {other:?}"),
        }
    }
}
