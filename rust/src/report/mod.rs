//! Run summaries: aggregate per-epoch stats into the quantities the
//! paper's evaluation section reports (Fig. 4 speedups, Fig. 7 resource
//! table, the trainable-parameter headline).

use std::collections::BTreeMap;

use crate::config::RunConfig;
use crate::manifest::Manifest;
use crate::rank::AdapterCfg;
use crate::trainer::EpochStats;
use crate::util::json::Json;

/// Phase-level aggregates.
#[derive(Debug, Clone, Default)]
pub struct PhaseAggregate {
    pub epochs: usize,
    pub mean_epoch_seconds: f64,
    pub mean_images_per_sec: f64,
    pub mean_memory_bytes: f64,
    /// Optimizer state a single worker held (ZeRO: ~1/workers of the
    /// total; the run summary's evidence for the sharding claim).
    pub mean_opt_state_bytes_per_worker: f64,
    /// Gradient buffer bytes a single worker held after the reduce
    /// (ZeRO-2: ~1/workers of the replicated footprint — the summary's
    /// evidence for the gradient-sharding claim).
    pub mean_grad_bytes_per_worker: f64,
    /// Mean wall seconds per epoch the leader spent blocked on gradient
    /// communication (unreduced buckets under bucketed sync, the whole
    /// sync otherwise) — the comm/compute-overlap evidence for
    /// `train.pipeline.bucket_bytes`.
    pub mean_comm_wait_s: f64,
    pub final_train_loss: f64,
}

/// Everything a figure harness or the CLI needs to print about one run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub run_name: String,
    pub model: String,
    pub epochs: usize,
    pub switch_epoch: Option<usize>,
    pub freeze_epoch: Option<usize>,
    /// rank -> count over adapters (present after a switch).
    pub rank_histogram: Option<BTreeMap<usize, usize>>,
    pub trainable_full: usize,
    pub trainable_lora: Option<usize>,
    pub by_phase: BTreeMap<String, PhaseAggregate>,
    pub final_train_loss: f64,
    pub final_val_loss: f64,
    pub final_val_acc: f64,
    /// Fig. 7 ratios (present when both phases were observed).
    pub epoch_time_ratio: Option<f64>,
    pub throughput_ratio: Option<f64>,
    pub memory_saving_frac: Option<f64>,
    /// Epoch this run was resumed from (v3 checkpoint), if it was — the
    /// per-epoch aggregates above still cover the *whole* trajectory
    /// (restored epochs ride the checkpoint's stats), so a resumed run's
    /// summary is comparable to an uninterrupted one; this field is the
    /// provenance note. `None` for runs that started from scratch.
    pub resumed_from: Option<usize>,
}

impl RunSummary {
    pub fn from_stats(
        cfg: &RunConfig,
        manifest: &Manifest,
        stats: &[EpochStats],
        switch_epoch: Option<usize>,
        freeze_epoch: Option<usize>,
        adapter_cfg: Option<&AdapterCfg>,
    ) -> Self {
        let mut by_phase: BTreeMap<String, PhaseAggregate> = BTreeMap::new();
        for s in stats {
            let agg = by_phase.entry(s.phase.to_string()).or_default();
            agg.epochs += 1;
            agg.mean_epoch_seconds += s.epoch_seconds;
            agg.mean_images_per_sec += s.images_per_sec;
            agg.mean_memory_bytes += s.memory_model_bytes as f64;
            agg.mean_opt_state_bytes_per_worker += s.opt_state_bytes_per_worker as f64;
            agg.mean_grad_bytes_per_worker += s.grad_bytes_per_worker as f64;
            agg.mean_comm_wait_s += s.comm_wait_s;
            agg.final_train_loss = s.train_loss;
        }
        for agg in by_phase.values_mut() {
            let n = agg.epochs.max(1) as f64;
            agg.mean_epoch_seconds /= n;
            agg.mean_images_per_sec /= n;
            agg.mean_memory_bytes /= n;
            agg.mean_opt_state_bytes_per_worker /= n;
            agg.mean_grad_bytes_per_worker /= n;
            agg.mean_comm_wait_s /= n;
        }
        let last = stats.last();
        let last_val = stats.iter().rev().find(|s| !s.val_loss.is_nan());
        let (full, lora) = (by_phase.get("full"), by_phase.get("lora"));
        let epoch_time_ratio = match (full, lora) {
            (Some(f), Some(l)) if l.mean_epoch_seconds > 0.0 => {
                Some(f.mean_epoch_seconds / l.mean_epoch_seconds)
            }
            _ => None,
        };
        let throughput_ratio = match (full, lora) {
            (Some(f), Some(l)) if f.mean_images_per_sec > 0.0 => {
                Some(l.mean_images_per_sec / f.mean_images_per_sec)
            }
            _ => None,
        };
        let memory_saving_frac = match (full, lora) {
            (Some(f), Some(l)) if f.mean_memory_bytes > 0.0 => {
                Some(1.0 - l.mean_memory_bytes / f.mean_memory_bytes)
            }
            _ => None,
        };
        let rank_histogram = adapter_cfg.map(|a| {
            let mut h = BTreeMap::new();
            for &r in &a.ranks {
                *h.entry(r).or_insert(0usize) += 1;
            }
            h
        });
        Self {
            run_name: cfg.run_name.clone(),
            model: cfg.model.clone(),
            epochs: stats.len(),
            switch_epoch,
            freeze_epoch,
            rank_histogram,
            trainable_full: manifest.full_trainable(),
            trainable_lora: adapter_cfg.map(|a| a.trainable_params),
            by_phase,
            final_train_loss: last.map_or(f64::NAN, |s| s.train_loss),
            final_val_loss: last_val.map_or(f64::NAN, |s| s.val_loss),
            final_val_acc: last_val.map_or(f64::NAN, |s| s.val_acc),
            epoch_time_ratio,
            throughput_ratio,
            memory_saving_frac,
            resumed_from: None,
        }
    }

    /// Multi-line human-readable report (CLI + examples).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "run {} (model {}) — {} epochs\n",
            self.run_name, self.model, self.epochs
        ));
        if let Some(k) = self.resumed_from {
            out.push_str(&format!(
                "  resumed from a checkpoint at epoch {k} (trajectory restored)\n"
            ));
        }
        match (self.switch_epoch, self.freeze_epoch) {
            (Some(s), Some(f)) => {
                out.push_str(&format!("  switch->warmup at epoch {s}, base frozen at {f}\n"))
            }
            (Some(s), None) => out.push_str(&format!("  switch->warmup at epoch {s}\n")),
            _ => out.push_str("  never switched (full baseline)\n"),
        }
        if let Some(h) = &self.rank_histogram {
            out.push_str(&format!("  rank histogram: {h:?}\n"));
        }
        if let Some(t) = self.trainable_lora {
            out.push_str(&format!(
                "  trainable params: {} -> {} ({:.1}% of full)\n",
                self.trainable_full,
                t,
                100.0 * t as f64 / self.trainable_full as f64
            ));
        }
        for (phase, agg) in &self.by_phase {
            out.push_str(&format!(
                "  [{phase:>6}] {:>3} epochs, {:.2}s/epoch, {:.0} img/s, {:.1} MiB model-mem, {:.2} MiB opt-state/worker, {:.2} MiB grads/worker, {:.3}s comm-wait/epoch\n",
                agg.epochs,
                agg.mean_epoch_seconds,
                agg.mean_images_per_sec,
                agg.mean_memory_bytes / (1 << 20) as f64,
                agg.mean_opt_state_bytes_per_worker / (1 << 20) as f64,
                agg.mean_grad_bytes_per_worker / (1 << 20) as f64,
                agg.mean_comm_wait_s,
            ));
        }
        if let Some(r) = self.epoch_time_ratio {
            out.push_str(&format!("  epoch-time ratio (full/lora): {r:.2}x\n"));
        }
        if let Some(r) = self.throughput_ratio {
            out.push_str(&format!("  throughput ratio (lora/full): {r:.2}x\n"));
        }
        if let Some(r) = self.memory_saving_frac {
            out.push_str(&format!("  memory saving: {:.1}%\n", r * 100.0));
        }
        out.push_str(&format!(
            "  final: train_loss {:.4}, val_loss {:.4}, val_acc {:.3}\n",
            self.final_train_loss, self.final_val_loss, self.final_val_acc
        ));
        out
    }

    pub fn to_json(&self) -> String {
        let opt_num = |o: Option<usize>| o.map_or(Json::Null, Json::from_usize);
        let opt_f = |o: Option<f64>| o.map_or(Json::Null, Json::Num);
        let phases = Json::Obj(
            self.by_phase
                .iter()
                .map(|(k, a)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("epochs", Json::from_usize(a.epochs)),
                            ("mean_epoch_seconds", Json::Num(a.mean_epoch_seconds)),
                            ("mean_images_per_sec", Json::Num(a.mean_images_per_sec)),
                            ("mean_memory_bytes", Json::Num(a.mean_memory_bytes)),
                            (
                                "mean_opt_state_bytes_per_worker",
                                Json::Num(a.mean_opt_state_bytes_per_worker),
                            ),
                            (
                                "mean_grad_bytes_per_worker",
                                Json::Num(a.mean_grad_bytes_per_worker),
                            ),
                            ("mean_comm_wait_s", Json::Num(a.mean_comm_wait_s)),
                            ("final_train_loss", Json::Num(a.final_train_loss)),
                        ]),
                    )
                })
                .collect(),
        );
        let hist = self.rank_histogram.as_ref().map_or(Json::Null, |h| {
            Json::Obj(
                h.iter()
                    .map(|(k, v)| (k.to_string(), Json::from_usize(*v)))
                    .collect(),
            )
        });
        let nan_safe = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
        Json::obj(vec![
            ("run_name", Json::Str(self.run_name.clone())),
            ("model", Json::Str(self.model.clone())),
            ("epochs", Json::from_usize(self.epochs)),
            ("switch_epoch", opt_num(self.switch_epoch)),
            ("freeze_epoch", opt_num(self.freeze_epoch)),
            ("rank_histogram", hist),
            ("trainable_full", Json::from_usize(self.trainable_full)),
            ("trainable_lora", opt_num(self.trainable_lora)),
            ("by_phase", phases),
            ("final_train_loss", nan_safe(self.final_train_loss)),
            ("final_val_loss", nan_safe(self.final_val_loss)),
            ("final_val_acc", nan_safe(self.final_val_acc)),
            ("epoch_time_ratio", opt_f(self.epoch_time_ratio)),
            ("throughput_ratio", opt_f(self.throughput_ratio)),
            ("memory_saving_frac", opt_f(self.memory_saving_frac)),
            ("resumed_from", opt_num(self.resumed_from)),
        ])
        .dump_pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(epoch: usize, phase: &'static str, secs: f64, mem: usize) -> EpochStats {
        EpochStats {
            epoch,
            phase,
            train_loss: 2.0 - epoch as f64 * 0.01,
            train_acc: 0.5,
            val_loss: 2.1,
            val_acc: 0.4,
            lr: 1e-3,
            epoch_seconds: secs,
            execute_seconds: secs * 0.9,
            images_per_sec: 1000.0 / secs,
            trainable_params: 1000,
            memory_model_bytes: mem,
            opt_state_bytes_per_worker: mem / 2,
            grad_bytes_per_worker: mem / 4,
            grad_norm: 1.0,
            comm_wait_s: secs * 0.1,
        }
    }

    fn summary() -> RunSummary {
        let cfg = RunConfig::default();
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/vit-micro");
        let manifest = Manifest::load(dir).unwrap();
        let stats: Vec<EpochStats> = (0..6)
            .map(|e| {
                if e < 4 {
                    stat(e, "full", 2.0, 1000)
                } else {
                    stat(e, "lora", 1.0, 600)
                }
            })
            .collect();
        RunSummary::from_stats(&cfg, &manifest, &stats, Some(4), Some(4), None)
    }

    #[test]
    fn ratios_reflect_phase_aggregates() {
        let s = summary();
        assert!((s.epoch_time_ratio.unwrap() - 2.0).abs() < 1e-9);
        assert!((s.throughput_ratio.unwrap() - 2.0).abs() < 1e-9);
        assert!((s.memory_saving_frac.unwrap() - 0.4).abs() < 1e-9);
        assert_eq!(s.by_phase["full"].epochs, 4);
        assert_eq!(s.by_phase["lora"].epochs, 2);
        // per-worker optimizer state flows through to the aggregates
        // (stat() sets it to mem/2)
        assert!((s.by_phase["full"].mean_opt_state_bytes_per_worker - 500.0).abs() < 1e-9);
        assert!((s.by_phase["lora"].mean_opt_state_bytes_per_worker - 300.0).abs() < 1e-9);
        // per-worker gradient bytes too (stat() sets them to mem/4)
        assert!((s.by_phase["full"].mean_grad_bytes_per_worker - 250.0).abs() < 1e-9);
        assert!((s.by_phase["lora"].mean_grad_bytes_per_worker - 150.0).abs() < 1e-9);
        // comm-wait means (stat() sets it to secs * 0.1)
        assert!((s.by_phase["full"].mean_comm_wait_s - 0.2).abs() < 1e-9);
        assert!((s.by_phase["lora"].mean_comm_wait_s - 0.1).abs() < 1e-9);
        let j = s.to_json();
        assert!(j.contains("mean_opt_state_bytes_per_worker"), "{j}");
        assert!(j.contains("mean_grad_bytes_per_worker"), "{j}");
        assert!(j.contains("mean_comm_wait_s"), "{j}");
    }

    #[test]
    fn render_and_json() {
        let s = summary();
        let text = s.render();
        assert!(text.contains("epoch-time ratio"));
        assert!(text.contains("switch->warmup at epoch 4"));
        assert!(!text.contains("resumed from"), "fresh runs carry no resume note");
        let j = s.to_json();
        assert!(j.contains("\"epoch_time_ratio\""));
    }

    #[test]
    fn resumed_runs_carry_a_provenance_note() {
        let mut s = summary();
        s.resumed_from = Some(3);
        let text = s.render();
        assert!(text.contains("resumed from a checkpoint at epoch 3"), "{text}");
        let j = s.to_json();
        assert!(j.contains("\"resumed_from\": 3"), "{j}");
    }
}
