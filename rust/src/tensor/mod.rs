//! Flat-vector math + deterministic RNG.
//!
//! All model state crosses the L3↔runtime boundary as flat `f32` vectors
//! (see `manifest.rs`), so the coordinator's numeric needs reduce to a
//! handful of dense-slice primitives kept in one place for profiling.

pub mod flat;
pub mod rng;

pub use flat::*;
pub use rng::Pcg64;
