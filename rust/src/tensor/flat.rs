//! Dense primitives over flat f32 parameter vectors.
//!
//! These are the coordinator's hot-path numeric kernels (optimizer update,
//! gradient reduction, weight-norm telemetry). They are written as simple
//! slice loops — LLVM auto-vectorizes all of them — and benchmarked in
//! `benches/controller.rs`.

use crate::manifest::TensorEntry;

/// `acc += x`, elementwise. Panics on length mismatch.
#[inline]
pub fn add_assign(acc: &mut [f32], x: &[f32]) {
    assert_eq!(acc.len(), x.len());
    for (a, b) in acc.iter_mut().zip(x) {
        *a += b;
    }
}

/// `acc *= s`, elementwise.
#[inline]
pub fn scale(acc: &mut [f32], s: f32) {
    for a in acc.iter_mut() {
        *a *= s;
    }
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (a, b) in y.iter_mut().zip(x) {
        *a += alpha * b;
    }
}

/// Squared L2 norm (f64 accumulation for stability on large vectors).
#[inline]
pub fn sq_norm(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// Frobenius norm of one manifest tensor inside a flat vector.
#[inline]
pub fn tensor_norm(flat: &[f32], t: &TensorEntry) -> f64 {
    sq_norm(&flat[t.offset..t.offset + t.size]).sqrt()
}

/// Global L2 norm of a gradient vector (for clipping / logging).
pub fn l2_norm(x: &[f32]) -> f64 {
    sq_norm(x).sqrt()
}

/// In-place gradient clipping by global norm; returns the pre-clip norm.
pub fn clip_by_global_norm(grads: &mut [f32], max_norm: f64) -> f64 {
    let norm = l2_norm(grads);
    if norm > max_norm && norm > 0.0 {
        scale(grads, (max_norm / norm) as f32);
    }
    norm
}

/// Mean of `n` same-length vectors, written into `out` (all-reduce epilogue).
pub fn mean_into(out: &mut [f32], parts: &[&[f32]]) {
    assert!(!parts.is_empty());
    out.copy_from_slice(parts[0]);
    for p in &parts[1..] {
        add_assign(out, p);
    }
    scale(out, 1.0 / parts.len() as f32);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_and_add() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![3.0, 4.0, 5.0]);
        add_assign(&mut y, &[1.0, 0.0, -1.0]);
        assert_eq!(y, vec![4.0, 4.0, 4.0]);
    }

    #[test]
    fn norms() {
        assert_eq!(sq_norm(&[3.0, 4.0]), 25.0);
        assert_eq!(l2_norm(&[3.0, 4.0]), 5.0);
    }

    #[test]
    fn clip() {
        let mut g = vec![3.0, 4.0];
        let pre = clip_by_global_norm(&mut g, 1.0);
        assert_eq!(pre, 5.0);
        assert!((l2_norm(&g) - 1.0).abs() < 1e-6);
        // under the cap: untouched
        let mut h = vec![0.3, 0.4];
        clip_by_global_norm(&mut h, 1.0);
        assert_eq!(h, vec![0.3, 0.4]);
    }

    #[test]
    fn mean() {
        let a = vec![1.0, 2.0];
        let b = vec![3.0, 6.0];
        let mut out = vec![0.0; 2];
        mean_into(&mut out, &[&a, &b]);
        assert_eq!(out, vec![2.0, 4.0]);
    }
}
