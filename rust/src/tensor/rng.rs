//! PCG64 (XSL-RR) pseudo-random generator + Box-Muller normal sampling.
//!
//! Self-contained so every run is reproducible from a single `u64` seed
//! without pulling in external RNG crates; used for dataset generation,
//! epoch shuffles, and LoRA A-matrix init at switch time.

/// PCG XSL-RR 128/64 — the same family numpy's `default_rng` builds on.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with SplitMix64-expanded state so nearby seeds decorrelate.
    pub fn new(seed: u64) -> Self {
        fn splitmix(x: &mut u64) -> u64 {
            *x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = *x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        }
        let mut s = seed;
        let hi = splitmix(&mut s) as u128;
        let lo = splitmix(&mut s) as u128;
        let inc_hi = splitmix(&mut s) as u128;
        let inc_lo = splitmix(&mut s) as u128;
        let mut rng = Self {
            state: (hi << 64) | lo,
            inc: ((inc_hi << 64) | inc_lo) | 1,
        };
        rng.next_u64(); // advance away from the seed
        rng
    }

    /// Derive an independent stream (worker shards, per-epoch shuffles).
    pub fn fork(&self, stream: u64) -> Self {
        let mut child = Self::new(self.state as u64 ^ stream.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        child.state ^= (stream as u128) << 64;
        child.next_u64();
        child
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        // Lemire's multiply-shift; bias negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Fill `out` with N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out {
            *v = self.next_normal() * sigma;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut rng = Pcg64::new(7);
        let n = 100_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(9);
        let n = 200_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = rng.next_normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(1);
        let mut v: Vec<usize> = (0..1000).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(v[..10], sorted[..10]);
    }

    #[test]
    fn forks_decorrelate() {
        let root = Pcg64::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn next_below_bounds() {
        let mut rng = Pcg64::new(3);
        for n in [1usize, 2, 7, 1000] {
            for _ in 0..200 {
                assert!(rng.next_below(n) < n);
            }
        }
    }
}
